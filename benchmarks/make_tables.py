"""Render the dry-run JSON into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.make_tables dryrun_results.json
"""
import json
import sys


def fmt_table(rows, mesh):
    out = [
        f"### Mesh {mesh}",
        "",
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "step_s | peak GB/dev | MODEL_FLOPs/HLO_FLOPs | tokens/step |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['step_s']:.4f} | "
            f"{r['peak_memory_gb']:.2f} | {r['useful_flops_ratio']:.3f} | "
            f"{r['tokens_per_step']:,} |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    rs = [r for r in json.load(open(path)) if r.get("status") == "ok"]
    for mesh in ("16x16", "2x16x16"):
        rows = [r for r in rs if r["mesh"] == mesh]
        print(fmt_table(rows, mesh))
        print()
    bad = [r for r in json.load(open(path)) if r.get("status") != "ok"]
    if bad:
        print("### FAILED CELLS")
        for r in bad:
            print(f"- {r['arch']} × {r['shape']} × {r['mesh']}: "
                  f"{r.get('error')}")


if __name__ == "__main__":
    main()
