"""Render benchmark JSON into the EXPERIMENTS.md markdown tables.

Two modes:

    # the dry-run roofline grid (launch.dryrun output)
    PYTHONPATH=src python -m benchmarks.make_tables dryrun_results.json

    # the perf trajectory: row x rev from every committed BENCH_*.json
    PYTHONPATH=src python -m benchmarks.make_tables --trajectory [--mode smoke]

The trajectory table is the history the perf gate's budgets are anchored
to: one column per benchmarked revision (git order), us/call per cell,
with the newest revision's achieved Mpts/s and roofline fraction broken
out in their own columns.  Interpret-mode Pallas rows are tagged ``*`` —
their absolute numbers are CPU-emulation artifacts (correctness tools,
excluded from the gate's roofline floors).
"""
import argparse
import glob
import json
import os
import re
import subprocess
import sys

BENCH_DIR = os.path.dirname(os.path.abspath(__file__))


# ------------------------------------------------------------ dryrun tables
def fmt_table(rows, mesh):
    out = [
        f"### Mesh {mesh}",
        "",
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "step_s | peak GB/dev | MODEL_FLOPs/HLO_FLOPs | tokens/step |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['step_s']:.4f} | "
            f"{r['peak_memory_gb']:.2f} | {r['useful_flops_ratio']:.3f} | "
            f"{r['tokens_per_step']:,} |")
    return "\n".join(out)


def dryrun_tables(path):
    rs = [r for r in json.load(open(path)) if r.get("status") == "ok"]
    for mesh in ("16x16", "2x16x16"):
        rows = [r for r in rs if r["mesh"] == mesh]
        print(fmt_table(rows, mesh))
        print()
    bad = [r for r in json.load(open(path)) if r.get("status") != "ok"]
    if bad:
        print("### FAILED CELLS")
        for r in bad:
            print(f"- {r['arch']} × {r['shape']} × {r['mesh']}: "
                  f"{r.get('error')}")


# -------------------------------------------------------- trajectory tables
def _git_rev_order():
    """Map short-rev -> position in first-parent history (oldest first)."""
    try:
        out = subprocess.run(
            ["git", "log", "--format=%h", "--reverse"],
            cwd=BENCH_DIR, capture_output=True, text=True, check=True)
        return {h: i for i, h in enumerate(out.stdout.split())}
    except Exception:  # noqa: BLE001 — outside a checkout: timestamp order
        return {}


# BENCH_<rev>.json (full run) / BENCH_<rev>_smoke.json / BENCH_<rev>_quick.json.
# The mode suffix is matched against the known set, so revs containing
# underscores (or the "norev" fallback) parse correctly.
_BENCH_RE = re.compile(r"^BENCH_(?P<rev>.+?)(?:_(?P<mode>smoke|quick))?\.json$")


def _rev_position(rev, order):
    """Position of ``rev`` in first-parent history.  Matches by hash prefix
    in either direction — ``git log --format=%h`` and the bench writer may
    abbreviate the same commit to different lengths.  Unknown revs sort
    after all known history (then by timestamp) instead of crashing."""
    if rev in order:
        return order[rev]
    for h, i in order.items():
        if h.startswith(rev) or rev.startswith(h):
            return i
    return len(order)


def load_trajectory(mode="smoke", bench_dir=BENCH_DIR):
    """Committed BENCH files for ``mode`` ("smoke"/"quick"/"full"), oldest
    rev first.  One run per (rev, mode): when several files claim the same
    rev (re-runs, embedded rev overriding the filename) the newest
    timestamp wins.  Unparseable filenames and corrupt JSON are skipped."""
    by_rev = {}
    for p in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        m = _BENCH_RE.match(os.path.basename(p))
        if not m:
            continue
        fmode = m.group("mode") or "full"
        if fmode != mode:
            continue
        try:
            with open(p) as f:
                d = json.load(f)
        except (json.JSONDecodeError, OSError):
            continue  # half-written bench drop: skip, don't kill the table
        d.setdefault("rev", m.group("rev"))
        prev = by_rev.get(d["rev"])
        if prev is None or d.get("timestamp", "") > prev.get("timestamp", ""):
            by_rev[d["rev"]] = d
    order = _git_rev_order()
    runs = list(by_rev.values())
    runs.sort(key=lambda d: (_rev_position(d["rev"], order),
                             d.get("timestamp", "")))
    return runs


def _cell(row):
    if row is None:
        return "—"
    if row.get("status", "ok") != "ok":
        return "FAIL"
    tag = "\\*" if row.get("interpret") else ""
    return f"{row['us_per_call']:.1f}{tag}"


def trajectory_table(runs):
    if not runs:
        return "(no BENCH files found)"
    revs = [d["rev"] for d in runs]
    by_rev = {d["rev"]: {r["name"]: r for r in d["rows"]} for d in runs}
    names = []                                     # first-appearance order
    for d in runs:
        for r in d["rows"]:
            if r["name"] not in names:
                names.append(r["name"])
    latest = revs[-1]

    head = ("| row | " + " | ".join(f"{r} us" for r in revs)
            + f" | {latest} Mpts/s | {latest} roofline |")
    sep = "|---|" + "---|" * (len(revs) + 2)
    lines = [head, sep]
    for name in names:
        cells = [_cell(by_rev[rev].get(name)) for rev in revs]
        last = by_rev[latest].get(name) or {}
        mpts = last.get("mpts_per_s")
        frac = last.get("roofline_frac")
        tag = "\\*" if last.get("interpret") else ""
        mp = f"{mpts:.2f}{tag}" if mpts is not None else "—"
        fr = f"{frac:.2%}{tag}" if frac is not None else "—"
        lines.append(f"| {name} | " + " | ".join(cells)
                     + f" | {mp} | {fr} |")
    bw = runs[-1].get("bandwidth_gbps")
    src = runs[-1].get("bandwidth_source", "model")
    lines.append("")
    lines.append(f"us/call are min-of-reps; \\* = interpret-mode Pallas "
                 f"(CPU emulation — correctness row, absolute numbers not "
                 f"meaningful, excluded from gate roofline floors). "
                 f"Latest ceilings vs {bw} GB/s ({src}).")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default=None,
                    help="dryrun_results.json (dryrun-table mode)")
    ap.add_argument("--trajectory", action="store_true",
                    help="render the row x rev perf-trajectory table")
    ap.add_argument("--mode", default="smoke",
                    help="BENCH file suffix to aggregate (default: smoke)")
    args = ap.parse_args()
    if args.trajectory:
        print(f"### Perf trajectory ({args.mode})")
        print()
        print(trajectory_table(load_trajectory(args.mode)))
    else:
        dryrun_tables(args.path or "dryrun_results.json")


if __name__ == "__main__":
    main()
