"""Benchmark harness — one entry per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's headline
quantity) and writes the same rows machine-readably to
``benchmarks/BENCH_<git-rev>.json`` so the perf trajectory is tracked across
PRs. Run: PYTHONPATH=src python -m benchmarks.run [--quick|--smoke] [--gate]

Every hot-path row carries the fields the roofline-anchored perf gate
(``repro.launch.perfgate``) consumes:

* ``us_per_call``   min-of-reps timing (the ``timing`` dict records the
  rep/iter/warmup counts — means hide bimodal host noise, minima don't);
* ``mpts_per_s`` / ``fits_per_s``   achieved throughput;
* ``roofline_frac``   achieved Mpts/s over the memory-bound ceiling from the
  measured-bandwidth STREAM triad (header field ``bandwidth_gbps``);
* ``backend`` / ``interpret``   provenance, so an interpret-mode Pallas
  number can never be mistaken for a hardware number.

``--smoke`` is the CI regression tripwire: tiny shapes, every bench still
exercised end to end, and every row is asserted to produce finite numbers.
``--gate`` additionally checks the run against the committed per-row
budgets in ``benchmarks/baseline.json`` and exits nonzero on any breach
(see README §Performance gate; ``--rebaseline`` rewrites the budgets after
an intentional change).  A bench that raises no longer aborts the run: it
lands as a ``"status": "failed"`` row so the trajectory shows holes instead
of pretending coverage.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.core import streaming
from repro.data import curve_dataset
from repro.kernels import moments as kernel
from repro.kernels import ops as kernel_ops
from repro.launch import perfgate


class Timed(float):
    """A µs-per-call float carrying its timing provenance."""

    meta: dict

    def __new__(cls, us: float, meta: dict | None = None):
        obj = super().__new__(cls, us)
        obj.meta = meta or {}
        return obj


def _time(fn, *args, iters=20, warmup=3, reps=5) -> Timed:
    """Min-of-reps µs/call: ``reps`` timed loops of ``iters`` calls each,
    keep the best loop's mean.  The minimum estimates the clean-machine
    cost; host-load noise only ever inflates a rep, never deflates it."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = None
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    return Timed(best, {"stat": "min_of_reps", "reps": reps, "iters": iters,
                        "warmup": warmup})


ROWS: list[dict] = []
SMOKE = False   # set by --smoke: tiny shapes + finite-number assertions
BW: perfgate.Bandwidth | None = None   # measured once per run (main())

# the rows the committed baseline budgets (benchmarks/baseline.json) gate —
# every hot path with a stable workload shape at a given mode
GATED_ROWS = ("moments_jnp", "moments_blocked", "moments_packed",
              "moments_packed_db", "fused_report", "streaming_update",
              "batched_fits", "select_sweep", "api_dispatch", "solve_ge",
              "serve_fit", "serve_fleet", "lspia_momentum", "lspia_async",
              "obs_overhead")


def _injected_slowdown(name: str) -> float | None:
    """PERFGATE_SLOW="row=factor,..." inflates named rows' measured time —
    the hook the gate's own failure test drives (never set in real runs)."""
    env = os.environ.get("PERFGATE_SLOW", "")
    for part in env.split(","):
        if "=" in part:
            k, v = part.split("=", 1)
            if k.strip() == name:
                return float(v)
    return None


def row(name, us, derived, *, n_points=None, n_fits=None, streams=2,
        interpret=False):
    """Record one bench row.

    ``n_points`` / ``n_fits`` are PER TIMED CALL, so ``n_points / us`` is
    Mpts/s directly.  ``streams`` is how many contiguous f32 arrays the
    pass reads per point (x, y [, w]) — the denominator of the memory-bound
    ceiling.  ``interpret=True`` tags emulated-Pallas rows so they are
    never read as hardware numbers (and are excluded from absolute
    roofline floors by the gate).
    """
    slow = _injected_slowdown(name)
    if slow is not None:
        us = Timed(float(us) * slow, getattr(us, "meta", {}))
    print(f"{name},{float(us):.1f},{derived}")
    if SMOKE:
        import math
        import re
        assert math.isfinite(float(us)), f"{name}: non-finite us={us}"
        bad = re.search(r"(?<![a-z])(nan|inf)(?![a-z])", str(derived),
                        re.IGNORECASE)
        assert not bad, f"{name}: non-finite derived: {derived}"
    r = {"name": name, "us_per_call": round(float(us), 1),
         "derived": derived, "status": "ok",
         "backend": jax.default_backend(), "interpret": bool(interpret)}
    if getattr(us, "meta", None):
        r["timing"] = us.meta
    if n_points is not None:
        mpts = n_points / float(us)          # n/µs == Mpts/s
        r["mpts_per_s"] = round(mpts, 3)
        if BW is not None:
            r["roofline_frac"] = round(perfgate.roofline_fraction(
                mpts, BW, streams=streams), 5)
            r["streams"] = streams
    if n_fits is not None:
        r["fits_per_s"] = round(n_fits / float(us) * 1e6, 1)
    if slow is not None:
        r["slowdown_injected"] = slow
    ROWS.append(r)


def _interp() -> bool:
    """Do Pallas rows run in interpret mode on this backend?"""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------- Table II-V
def bench_accuracy(quick: bool):
    """Paper Tables II-V: coefficients + Σe² vs the QR (polyfit) baseline on
    the paper's dataset. derived = max |coeff - polyfit coeff| at order 3."""
    x = jnp.asarray([39.206, 29.74, 21.31, 12.087, 1.812, 0.001])
    y = jnp.asarray([751.912, 567.121, 403.746, 221.738, 18.8418, 1.88672])
    for order in (1, 2, 3):
        us = _time(lambda: core.polyfit(x, y, order))
        gauss = core.polyfit(x, y, order)
        qr = core.polyfit(x, y, order, solver="qr_vandermonde")
        sse = float(core.fit_report(gauss, x, y).sse)
        gap = float(jnp.max(jnp.abs(gauss.coeffs - qr.coeffs)))
        row(f"table2-4_order{order}_fit", us,
            f"sse={sse:.4f};max_coeff_gap_vs_qr={gap:.2e}")


# ------------------------------------------------------------------ §IV perf
def bench_speedup(quick: bool):
    """Paper §IV: matricized parallel accumulation vs the sequential
    per-point scalar loop (the pre-matricization implementation the paper
    benchmarks against; their GPU port reached ~100x over it). derived =
    speedup of the matricized path on this host."""
    sizes = ([10_000] if SMOKE
             else [10_000, 100_000] if quick
             else [10_000, 100_000, 1_000_000])

    def sequential_power_sums(xs, ys, m=3):
        """Faithful scalar baseline: one point at a time, plain floats."""
        s = [0.0] * (2 * m + 1)
        t = [0.0] * (m + 1)
        for xi, yi in zip(xs, ys):
            p = 1.0
            for k in range(2 * m + 1):
                s[k] += p
                if k <= m:
                    t[k] += p * yi
                p *= xi
        return s, t

    for n in sizes:
        x, y, _ = curve_dataset(n, degree=3, seed=0)
        mat = jax.jit(lambda x, y: core.gram_moments(x, y, 3).gram)
        us_mat = _time(mat, x, y, iters=10)

        n_seq = min(n, 20_000)  # time a slice, extrapolate linearly
        xs = [float(v) for v in np.asarray(x[:n_seq])]
        ys = [float(v) for v in np.asarray(y[:n_seq])]
        t0 = time.perf_counter()
        sequential_power_sums(xs, ys)
        us_seq_full = (time.perf_counter() - t0) * 1e6 * (n / n_seq)
        row(f"speedup_n{n}", us_mat,
            f"seq_us={us_seq_full:.0f};speedup={us_seq_full / us_mat:.1f}x",
            n_points=n)


def bench_kernel(quick: bool):
    """Pallas moments kernel (interpret mode on CPU): correctness-equivalent
    throughput vs the jnp path; derived = Mpoints/s of the jnp path (the
    kernel's CPU interpret timing is NOT the TPU number — the row's
    interpret flag says so machine-readably)."""
    n = 1 << 14 if SMOKE else 1 << 18 if quick else 1 << 20
    x, y, _ = curve_dataset(n, degree=3, seed=1)
    jnp_path = jax.jit(lambda x, y: core.gram_moments(x, y, 3).gram)
    us = _time(jnp_path, x, y, iters=10)
    blocked = jax.jit(
        lambda x, y: core.gram_moments_blocked(x, y, 3, block=1 << 14).gram)
    us_b = _time(blocked, x, y, iters=10)
    k = jax.jit(lambda x, y: kernel_ops.moments(x, y, 3).gram)
    us_k = _time(k, x, y, iters=2, warmup=1, reps=3)
    row("moments_jnp", us, f"{n / us:.1f}Mpts/s", n_points=n)
    row("moments_blocked", us_b, f"{n / us_b:.1f}Mpts/s", n_points=n)
    row("moments_pallas_interpret", us_k,
        f"{n / us_k:.2f}Mpts/s(interpret)" if _interp()
        else f"{n / us_k:.2f}Mpts/s",
        n_points=n, streams=3, interpret=_interp())


def bench_kernel_packed(quick: bool):
    """Packed multi-series kernel on the batched degree-3 workload (the
    monitors/serving hot path). derived = MXU-FLOPs-per-fit ratio vs the
    plain one-series-per-tile layout (the hardware-independent speedup; 25×
    at degree 3), interpret-mode wall speedup, and max relative error of the
    packed Gram vs core.gram_moments.  ``moments_packed_db`` is the same
    workload through the manually double-buffered DMA pipeline
    (kernels.moments nbuf=2) with the autotuned block_n — parity asserted;
    its wall time only means something on real hardware."""
    from repro.kernels import tune

    deg = 3
    b = 8 if SMOKE else 32 if quick else 64
    n = 512 if SMOKE else 2048 if quick else 4096
    x, y, _ = curve_dataset(n, degree=deg, seed=4, batch=(b,))

    plain = jax.jit(lambda x, y: kernel_ops.moments(
        x, y, deg, packing="plain").gram)
    packed = jax.jit(lambda x, y: kernel_ops.moments(
        x, y, deg, packing="packed").gram)
    us_plain = _time(plain, x, y, iters=2, warmup=1, reps=3)
    us_packed = _time(packed, x, y, iters=2, warmup=1, reps=3)

    # MXU work is identical per (128, n)x(n, 128) tile product; the packed
    # layout amortizes each product over P fits instead of 1.
    pfac = kernel.packing_factor(deg)
    groups = -(-b // pfac)
    flops_per_fit_plain = 2 * kernel.K_PAD ** 2 * n            # b tiles / b
    flops_per_fit_packed = 2 * kernel.K_PAD ** 2 * n * groups / b
    ratio = flops_per_fit_plain / flops_per_fit_packed

    g_ref = core.gram_moments(x, y, deg, accum_dtype=jnp.float32).gram
    rel = float(jnp.max(jnp.abs(packed(x, y) - g_ref)
                        / jnp.maximum(jnp.abs(g_ref), 1e-9)))
    row("moments_packed", us_packed,
        f"flops_per_fit_ratio={ratio:.1f}x;interpret_speedup="
        f"{us_plain / us_packed:.1f}x;max_rel_err_vs_gram={rel:.2e}",
        n_points=b * n, streams=3, interpret=_interp())

    # double-buffered DMA pipeline at the autotuned block size
    bn = tune.autotune_block_n(deg, n, dtype=jnp.float32)
    packed_db = jax.jit(lambda x, y: kernel_ops.moments(
        x, y, deg, packing="packed", nbuf=2, block_n=bn).gram)
    us_db = _time(packed_db, x, y, iters=2, warmup=1, reps=3)
    rel_db = float(jnp.max(jnp.abs(packed_db(x, y) - g_ref)
                           / jnp.maximum(jnp.abs(g_ref), 1e-9)))
    row("moments_packed_db", us_db,
        f"nbuf=2;block_n={bn};max_rel_err_vs_gram={rel_db:.2e}",
        n_points=b * n, streams=3, interpret=_interp())
    if SMOKE:
        assert rel_db < 1e-5, f"double-buffered kernel diverged: {rel_db}"


def bench_fused_report(quick: bool):
    """Fused evaluate+residual+SSE/R pass vs the materializing fit_report.
    derived = Mpts/s of the fused pass and the HBM bytes it avoids writing
    (fitted + residuals arrays)."""
    b = 4 if SMOKE else 16 if quick else 32
    n = 1 << 12 if SMOKE else 1 << 14 if quick else 1 << 16
    x, y, _ = curve_dataset(n, degree=3, seed=5, batch=(b,))
    poly = core.polyfit(x, y, 3)

    base = jax.jit(lambda p, x, y: core.fit_report(p, x, y).sse)
    fused = jax.jit(lambda p, x, y: core.fit_report_streamed(p, x, y).sse)
    us_base = _time(base, poly, x, y, iters=3, warmup=1)
    us_fused = _time(fused, poly, x, y, iters=3, warmup=1)
    saved = 2 * b * n * 4  # fitted + residuals f32, never hit HBM
    row("fused_report", us_fused,
        f"{b * n / us_fused:.1f}Mpts/s;materializing_us={us_base:.1f};"
        f"hbm_bytes_avoided={saved}", n_points=b * n)


def bench_solver_stack(quick: bool):
    """Condition-aware solver stack (PR-3): the explicit ladder's hot rung
    (batched GE), the SVD rescue on an ill-conditioned degree-9 Gram, IRLS
    robust fitting under 20% contamination, and the matrix-free LSPIA
    iteration.  Every derived field is finite-asserted under --smoke, so a
    solver regression that starts shipping NaNs trips CI here."""
    rng = np.random.default_rng(9)

    # solve_ge: the paper's solver, batched over a slot-pool-sized stack
    deg = 3
    b = 64 if SMOKE else 1024
    a = rng.normal(0, 1, (b, deg + 1, deg + 1))
    a = a @ a.transpose(0, 2, 1) + (deg + 1) * np.eye(deg + 1)
    rhs = rng.normal(0, 1, (b, deg + 1))
    aj = jnp.asarray(a, jnp.float32)
    bj = jnp.asarray(rhs, jnp.float32)
    ge = jax.jit(core.gaussian_elimination)
    us = _time(ge, aj, bj)
    resid = float(jnp.max(jnp.abs(
        jnp.einsum("bij,bj->bi", aj, ge(aj, bj)) - bj)))
    row("solve_ge", us, f"{b / us * 1e6:.0f}solves/s;max_resid={resid:.2e}",
        n_fits=b)

    # solve_svd_fallback: degree-9 raw-monomial Gram on [0, 8] — κ far past
    # the f32 cap, GE alone degrades; the guard must swap in the SVD and
    # stay finite
    n = 1 << 10 if SMOKE else 1 << 14
    x9 = jnp.asarray(np.linspace(0.0, 8.0, n), jnp.float32)
    y9 = jnp.asarray(np.polyval(rng.normal(0, 1, 10)[::-1],
                                np.linspace(0.0, 8.0, n)), jnp.float32)
    m9 = core.gram_moments(x9, y9, 9)
    fb = jax.jit(lambda a, b: core.solve_with_fallback(a, b, method="gauss",
                                                       fallback="svd"))
    us = _time(fb, m9.gram, m9.vty, iters=10)
    coeffs, cond, used = fb(m9.gram, m9.vty)
    ok = bool(jnp.all(jnp.isfinite(coeffs)))
    row("solve_svd_fallback", us,
        f"fallback_used={bool(used)};finite_coeffs={ok};"
        f"cond_past_cap={float(cond) > core.cond_cap_for(jnp.float32)}")
    if SMOKE:
        assert bool(used) and ok, "SVD rescue failed to produce finite output"

    # irls: Tukey robust fit under 20% gross contamination
    n = 1 << 10 if SMOKE else 1 << 13
    xr = rng.uniform(-2, 2, n)
    true = np.array([1.0, -2.0, 0.5, 0.8])
    yr = np.polyval(true[::-1], xr) + rng.normal(0, 0.05, n)
    out = rng.choice(n, n // 5, replace=False)
    yr[out] += rng.choice([-1.0, 1.0], out.size) * 50.0
    xrj = jnp.asarray(xr, jnp.float32)
    yrj = jnp.asarray(yr, jnp.float32)
    irls = jax.jit(lambda x, y: core.robust_polyfit(x, y, 3,
                                                    loss="tukey").poly.coeffs)
    us = _time(irls, xrj, yrj, iters=5, warmup=1)
    rfit = core.robust_polyfit(xrj, yrj, 3, loss="tukey")
    rel = float(np.linalg.norm(np.asarray(rfit.poly.monomial_coeffs(),
                                          np.float64) - true)
                / np.linalg.norm(true))
    row("irls", us, f"rel_err_20pct_outliers={rel:.2e};"
        f"iters={int(rfit.iterations)};converged={bool(rfit.converged)}")
    if SMOKE:
        assert rel < 0.05, f"IRLS accuracy regression: {rel:.3f}"

    # lspia: the Gram-free iteration on its natural (Chebyshev) basis
    n = 1 << 10 if SMOKE else 1 << 14
    xl = jnp.asarray(rng.uniform(-3, 3, n), jnp.float32)
    yl = jnp.asarray(np.sin(np.asarray(xl)) + 0.02 * rng.normal(0, 1, n),
                     jnp.float32)
    lsp = jax.jit(lambda x, y: core.lspia_fit(x, y, 5,
                                              basis="chebyshev").poly.coeffs)
    us = _time(lsp, xl, yl, iters=5, warmup=1)
    lf = core.lspia_fit(xl, yl, 5, basis="chebyshev")
    ref = core.polyfit(xl, yl, 5, basis="chebyshev", normalize=True)
    gap = float(jnp.max(jnp.abs(lf.poly.coeffs - ref.coeffs)))
    row("lspia", us, f"iters={int(lf.iterations)};"
        f"converged={bool(lf.converged)};max_coeff_gap_vs_lse={gap:.2e}")
    if SMOKE:
        assert bool(lf.converged), "LSPIA failed to converge on smoke shapes"

    # lspia_momentum: heavy-ball PIA-with-memory (β = 0.5, the measured
    # optimum) — same fixed point, multiples fewer sweeps at one extra
    # axpy per sweep
    lspm = jax.jit(lambda x, y: core.lspia_fit(
        x, y, 5, basis="chebyshev", momentum=0.5).poly.coeffs)
    us_m = _time(lspm, xl, yl, iters=5, warmup=1)
    lfm = core.lspia_fit(xl, yl, 5, basis="chebyshev", momentum=0.5)
    gap_m = float(jnp.max(jnp.abs(lfm.poly.coeffs - ref.coeffs)))
    row("lspia_momentum", us_m,
        f"iters={int(lfm.iterations)};plain_iters={int(lf.iterations)};"
        f"converged={bool(lfm.converged)};max_coeff_gap_vs_lse={gap_m:.2e}")
    if SMOKE:
        assert bool(lfm.converged), "momentum LSPIA failed to converge"
        assert int(lfm.iterations) < int(lf.iterations), (
            f"momentum did not accelerate: {int(lfm.iterations)} vs "
            f"plain {int(lf.iterations)}")

    # lspia_async: barrier-free sharded LSPIA — a python coordinator over
    # jitted shard gradients on the virtual-tick mailbox substrate, so the
    # row times one whole fault-free fit (wall time), not a kernel call
    from repro.api.spec import FitSpec, LSPIAOptions
    from repro.core import distributed as dist_lib
    from repro.engine.plan import NumericsPolicy
    # normalize=True: LSPIA needs the [-1, 1] domain map for a contractive
    # iteration (the lspia_fit shim defaults it on, FitSpec defaults it off)
    aspec = FitSpec(degree=5, basis="chebyshev", method="lspia",
                    numerics=NumericsPolicy(solver="auto", normalize=True),
                    lspia=LSPIAOptions(momentum=0.5))
    n_sh = 4
    dist_lib.async_lspia_fit(xl, yl, aspec, n_shards=n_sh)  # warm the jits
    t0 = time.perf_counter()
    af = dist_lib.async_lspia_fit(xl, yl, aspec, n_shards=n_sh)
    us_a = Timed((time.perf_counter() - t0) * 1e6,
                 {"stat": "single_call", "reps": 1, "iters": 1, "warmup": 1})
    gap_a = float(jnp.max(jnp.abs(af.poly(xl) - ref(xl))))
    # no n_points: this row is wall time of a python tick coordinator, not
    # a memory-bound kernel — regression-gated only, no roofline floor
    row("lspia_async", us_a,
        f"versions={int(af.iterations)};ticks={int(af.ticks)};"
        f"shards={n_sh};converged={bool(af.converged)};"
        f"max_pred_gap_vs_lse={gap_a:.2e}")
    if SMOKE:
        assert bool(af.converged), "async LSPIA failed to converge"


def bench_streaming(quick: bool):
    """Streaming O(1)-state fitter: points/s through update() + solve cost.
    derived = Mpts/s and the (constant) state size."""
    chunk = 1 << 14
    x, y, _ = curve_dataset(chunk, degree=2, seed=2)
    state = streaming.StreamState.create(2)
    upd = jax.jit(streaming.update)
    us = _time(upd, state, x, y, iters=20)
    state_bytes = sum(np.asarray(l).nbytes
                      for l in jax.tree.leaves(state))
    us_solve = _time(jax.jit(lambda s: streaming.current_fit(s).coeffs),
                     upd(state, x, y))
    row("streaming_update", us, f"{chunk / us:.1f}Mpts/s", n_points=chunk)
    row("streaming_solve", us_solve, f"state_bytes={state_bytes}")


def bench_batched_fits(quick: bool):
    """Batched (vmapped-by-construction) fitting — the monitors' workload:
    fit 4096 independent series at once. derived = fits/s."""
    b = 128 if SMOKE else 512 if quick else 4096
    x, y, _ = curve_dataset(256, degree=1, seed=3, batch=(b,))
    fit = jax.jit(lambda x, y: core.polyfit(x, y, 1).coeffs)
    us = _time(fit, x, y, iters=10)
    row("batched_fits", us, f"{b / (us / 1e6):.0f}fits/s",
        n_points=b * 256, n_fits=b)


def bench_select(quick: bool):
    """Single-pass model selection (repro.select).  ``select_sweep``:
    the degree ladder from ONE degree-M accumulation vs the naive
    refit-per-degree loop (M+1 accumulations) — derived = wall speedup +
    the chosen degree.  ``select_cv``: the full k-fold moment-space CV
    path end to end (eager entry point, fold accumulation included)."""
    from repro import select as select_lib

    max_deg = 8
    n = 1 << 12 if SMOKE else 1 << 15 if quick else 1 << 18
    rng = np.random.default_rng(21)
    xs = rng.uniform(-1.0, 1.0, n)
    true = np.array([0.5, -1.0, 0.3, 0.9])          # planted cubic
    sig = np.polyval(true[::-1], xs)
    ys = sig + (sig.std() / 10.0) * rng.normal(0, 1, n)   # SNR 10
    x = jnp.asarray(xs, jnp.float32)
    y = jnp.asarray(ys, jnp.float32)

    sweep = jax.jit(lambda x, y: select_lib.sweep_from_moments(
        core.gram_moments(x, y, max_deg)).scores.aicc)

    def naive(x, y):
        # the pre-select workflow: one full accumulation per degree
        return tuple(core.gram_moments(x, y, d).gram for d in
                     range(max_deg + 1))

    naive_j = jax.jit(naive)
    us_sweep = _time(sweep, x, y, iters=10)
    us_naive = _time(naive_j, x, y, iters=10)
    aicc = np.asarray(sweep(x, y))
    best = int(np.argmin(aicc))
    row("select_sweep", us_sweep,
        f"best=deg{best};naive_refit_us={us_naive:.1f};"
        f"speedup_vs_refit={us_naive / us_sweep:.1f}x", n_points=n)
    if SMOKE:
        assert best == 3, f"sweep missed the planted cubic: {best}"
        assert np.all(np.isfinite(aicc)), "non-finite AICc in sweep"

    def cv_path():
        return select_lib.select_degree(x, y, max_degree=max_deg, folds=5)

    for _ in range(2):
        cv_path()                                     # compile both halves
    best_us = float("inf")
    reps, iters = 3, 5
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            sel = cv_path()
        best_us = min(best_us, (time.perf_counter() - t0) / iters * 1e6)
    us_cv = Timed(best_us, {"stat": "min_of_reps", "reps": reps,
                            "iters": iters, "warmup": 2})
    cv = np.asarray(sel.sweep.scores.cv)
    row("select_cv", us_cv,
        f"best=deg{sel.best_degree};folds=5;"
        f"cv_min={float(np.min(cv)):.4g}", n_points=n)
    if SMOKE:
        assert sel.best_degree == 3, f"CV missed the planted cubic: {sel}"
        assert np.all(np.isfinite(cv)), "non-finite CV scores"


def bench_api_dispatch(quick: bool):
    """The declarative-API tax: spec-based ``api.fit()`` vs the direct
    jitted ``_polyfit_fixed`` on the same n=1e6 fit.  The spec is the jit
    static arg, so both paths run ONE compiled executable — the measured
    gap is pure host-side dispatch (spec hash, cache lookup, FitResult
    wrap).  derived = overhead %; --smoke asserts it stays under 25%."""
    from repro import api
    from repro.core import fit as fit_lib

    n = 1_000_000
    x, y, _ = curve_dataset(n, degree=3, seed=7)
    spec = api.FitSpec(degree=3)

    def spec_fit():
        return api.fit(x, y, spec).poly.coeffs

    def direct():
        return fit_lib._polyfit_fixed(x, y, 3).coeffs

    # min-of-reps on both paths: they are compared on a ~12ms compute-bound
    # op, so host-load noise (±25% observed) would swamp the few-us
    # dispatch gap at any single rep
    iters = 5 if SMOKE or quick else 10
    us_direct = _time(direct, iters=iters, warmup=3, reps=5)
    us_spec = _time(spec_fit, iters=iters, warmup=3, reps=5)
    ratio = us_spec / us_direct
    row("api_dispatch", us_spec,
        f"direct_us={us_direct:.1f};overhead={(ratio - 1) * 100:+.2f}%;"
        f"n={n}", n_points=n)
    if SMOKE:
        # regression tripwire, not the headline claim: the row reports the
        # measured overhead; the assertion only catches a dispatch-path
        # BLOWUP (2x+).  The two sides are timed sequentially, so a host
        # load window during one phase skews the ratio ±20% even at
        # min-of-reps — budget accordingly
        assert ratio < 1.25, (
            f"spec dispatch overhead {ratio:.3f}x exceeds the 25% budget "
            f"({us_spec:.1f}us vs {us_direct:.1f}us)")


def bench_serve_fit(quick: bool):
    """Continuous-batching fit server on a ragged request trace (1k requests
    in the full run), served through the fused ingest+solve executable.
    derived = sustained fits/s and Mpts/s after warmup, min over full trace
    reps, with the no-recompile invariant asserted (zero new executables
    across every steady-state wave)."""
    from repro.serve import FitServeConfig, FitServeEngine

    n_req = 32 if SMOKE else 200 if quick else 1000
    lo, hi = (8, 512) if SMOKE else (16, 4096)
    engine = FitServeEngine(FitServeConfig(
        degree=3, n_slots=8, buckets=(256, 2048), ridge=1e-9))
    rng = np.random.default_rng(11)
    series = []
    for _ in range(n_req):
        n = int(np.exp(rng.uniform(np.log(lo), np.log(hi))))
        x = rng.uniform(-2, 2, n).astype(np.float32)
        y = (0.3 * x**3 - 0.5 * x + 1.0
             + rng.normal(0, 0.1, n)).astype(np.float32)
        series.append((x, y))

    execs = engine.warmup()        # compiles every bucket + the solve
    reps = 3 if (SMOKE or quick) else 2
    best_dt = float("inf")
    for _ in range(reps):
        reqs = [engine.submit(x, y) for x, y in series]
        t0 = time.perf_counter()
        engine.run()
        best_dt = min(best_dt, time.perf_counter() - t0)
        assert all(r.done for r in reqs)
    recompiles = engine.compiled_executables() - execs
    assert recompiles == 0, f"{recompiles} recompiles in steady state"
    pts = sum(x.shape[0] for x, _ in series)
    dt = best_dt
    us = Timed(dt / n_req * 1e6, {"stat": "min_of_reps", "reps": reps,
                                  "iters": n_req, "warmup": 1})
    row("serve_fit", us,
        f"{n_req / dt:.1f}fits/s;{pts / dt / 1e6:.2f}Mpts/s;"
        f"executables={execs};recompiles_after_warmup={recompiles}",
        n_points=pts / n_req, n_fits=1, streams=3)


def bench_serve_fleet(quick: bool):
    """Fault-tolerant fleet (PR-6): the same ragged trace served by 4
    replicated workers, fault-free vs one worker crash-killed mid-run.
    derived = fits/s + p99 tick latency in both regimes, with zero lost
    requests asserted under the fault — the recovery machinery (journal
    replay, restart, hedging) must absorb the crash, not drop work."""
    from repro.runtime.chaos import ChaosSchedule, FaultEvent
    from repro.serve import FitServeConfig, FleetConfig, FitFleet

    n_req = 16 if SMOKE else 48 if quick else 200
    lo, hi = (64, 512) if SMOKE else (128, 4096)
    rng = np.random.default_rng(11)
    series = []
    for _ in range(n_req):
        n = int(np.exp(rng.uniform(np.log(lo), np.log(hi))))
        xs = rng.uniform(-2, 2, n).astype(np.float32)
        ys = (0.3 * xs**3 - 0.5 * xs + 1.0
              + rng.normal(0, 0.1, n)).astype(np.float32)
        series.append((xs, ys))

    def run(chaos):
        fleet = FitFleet(FleetConfig(
            fit=FitServeConfig(degree=3), n_workers=4, chaos=chaos,
            straggler_threshold=2.0))
        fleet.warmup()
        reqs = [fleet.submit(xs, ys) for xs, ys in series]
        t0 = time.perf_counter()
        fleet.run(max_ticks=50_000)
        dt = time.perf_counter() - t0
        lost = sum(1 for r in reqs if not r.done or r.failed)
        return fleet, dt, lost

    base, dt0, lost0 = run(None)
    chaos = ChaosSchedule((FaultEvent(2, 1, "crash"),))
    faulted, dt1, lost1 = run(chaos)
    assert lost0 == 0 and lost1 == 0, f"lost requests: {lost0}/{lost1}"
    assert faulted.stats["worker_deaths"] == 1
    q0, q1 = base.latency_quantiles(), faulted.latency_quantiles()
    us = Timed(dt1 / n_req * 1e6, {"stat": "single_faulted_run", "reps": 1,
                                   "iters": n_req, "warmup": 1})
    row("serve_fleet", us,
        f"{n_req / dt1:.1f}fits/s_under_crash;"
        f"faultfree={n_req / dt0:.1f}fits/s;"
        f"p99_ticks={q1['p99']:.0f}(vs{q0['p99']:.0f});"
        f"replays={faulted.stats['replays']};lost=0", n_fits=1)


def bench_obs_overhead(quick: bool):
    """The observability tax (PR-9): the serve_fit ragged trace served
    twice by the same engine config — once with the default ``NULL_OBS``
    recorders, once with ``Observability.on()`` (live metric registry +
    trace spans on every request).  All instrumentation is host-side
    python outside the jitted executables, so the measured gap is pure
    recording cost.  derived = overhead %; --smoke asserts it stays
    under 5% (the "observability is free" invariant the README claims).
    The two paths are timed in interleaved reps (min-of-reps each) so a
    host-load window skews both sides, not one."""
    from repro import obs as obs_lib
    from repro.serve import FitServeConfig, FitServeEngine

    n_req = 32 if SMOKE else 100 if quick else 400
    # recording cost is fixed per request, so the denominator must be a
    # *representative* request — multi-step series like the full-run
    # serve trace, not the smoke-tier 8-point degenerate, where the
    # percentage would measure dispatch-bound pathology instead
    lo, hi = (1024, 8192) if SMOKE else (1024, 16384)
    rng = np.random.default_rng(11)
    series = []
    for _ in range(n_req):
        n = int(np.exp(rng.uniform(np.log(lo), np.log(hi))))
        x = rng.uniform(-2, 2, n).astype(np.float32)
        y = (0.3 * x**3 - 0.5 * x + 1.0
             + rng.normal(0, 0.1, n)).astype(np.float32)
        series.append((x, y))

    def build(obs):
        engine = FitServeEngine(FitServeConfig(
            degree=3, n_slots=8, buckets=(256, 2048), ridge=1e-9), obs=obs)
        engine.warmup()
        return engine

    def one_rep(engine):
        reqs = [engine.submit(x, y) for x, y in series]
        t0 = time.perf_counter()
        engine.run()
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        return dt

    eng_null = build(None)
    obs = obs_lib.Observability.on()
    eng_on = build(obs)
    reps = 7 if SMOKE else 5
    dt_null = dt_on = float("inf")
    for _ in range(reps):
        dt_null = min(dt_null, one_rep(eng_null))
        dt_on = min(dt_on, one_rep(eng_on))
    # the enabled side really recorded: full trace chains + live metrics
    assert obs.metrics.counter("completed").value >= n_req * reps
    assert obs.metrics.histogram("fit_latency_steps").count >= n_req * reps
    assert any(e["name"] == "respond" for e in obs.tracer.events)
    ratio = dt_on / dt_null
    us = Timed(dt_on / n_req * 1e6, {"stat": "min_of_reps", "reps": reps,
                                     "iters": n_req, "warmup": 1})
    row("obs_overhead", us,
        f"overhead={(ratio - 1) * 100:+.2f}%;"
        f"null_us={dt_null / n_req * 1e6:.1f};"
        f"events={len(obs.tracer.events)};n_req={n_req}", n_fits=1)
    if SMOKE:
        assert ratio < 1.05, (
            f"obs-enabled serving is {ratio:.3f}x the null path — the "
            f"<=5% observability budget is breached "
            f"({dt_on * 1e3:.1f}ms vs {dt_null * 1e3:.1f}ms)")


def bench_e2e_train(quick: bool):
    """Smoke-scale end-to-end train step (framework overhead check).
    derived = tokens/s on this CPU host."""
    from repro import configs
    from repro.models import get_model
    from repro.train import TrainConfig, init_train_state, make_train_step
    cfg = configs.get_smoke_config("internlm2-1.8b")
    model = get_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, TrainConfig()))
    b, s = 4, 128
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32),
             "loss_mask": jnp.ones((b, s), jnp.float32)}
    state, _ = step(state, batch)  # compile

    def run(state):
        state, m = step(state, batch)
        return state, m

    best = float("inf")
    reps = 2
    iters = 5 if quick else 20
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            state, m = run(state)
        jax.block_until_ready(m["loss"])
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    us = Timed(best, {"stat": "min_of_reps", "reps": reps, "iters": iters,
                      "warmup": 1})
    row("train_step_smoke", us, f"{b * s / (us / 1e6):.0f}tok/s")


BENCHES = [bench_accuracy, bench_speedup, bench_kernel, bench_kernel_packed,
           bench_fused_report, bench_solver_stack, bench_select,
           bench_streaming, bench_batched_fits, bench_api_dispatch,
           bench_serve_fit, bench_serve_fleet, bench_obs_overhead,
           bench_e2e_train]


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, check=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip()
    except Exception:  # noqa: BLE001 — no git / not a checkout
        return "norev"


def _bench_dir() -> str:
    return os.path.dirname(os.path.abspath(__file__))


def _write_json(quick: bool) -> str:
    rev = _git_rev()
    # quick/smoke runs get their own file so a smoke check at the same rev
    # never overwrites the full-run numbers the perf trajectory tracks
    suffix = "_smoke" if SMOKE else "_quick" if quick else ""
    path = os.path.join(_bench_dir(), f"BENCH_{rev}{suffix}.json")
    payload = {
        "rev": rev,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "backend": jax.default_backend(),
        "quick": quick,
        "smoke": SMOKE,
        "bandwidth_gbps": round(BW.gbps, 2) if BW else None,
        "bandwidth_source": BW.source if BW else None,
        "rows": ROWS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path


def _mode_name(quick: bool) -> str:
    return "smoke" if SMOKE else "quick" if quick else "full"


def _run_gate(quick: bool) -> int:
    """Check this run against benchmarks/baseline.json; write the report."""
    base_path = os.path.join(_bench_dir(), "baseline.json")
    report_path = os.path.join(_bench_dir(), "gate_report.json")
    if not os.path.exists(base_path):
        print(f"perf gate: no baseline at {base_path} — run "
              "--rebaseline first", file=sys.stderr)
        return 2
    with open(base_path) as f:
        baseline = json.load(f)
    mode = _mode_name(quick)
    if baseline.get("mode", mode) != mode:
        print(f"perf gate: baseline was captured in mode="
              f"{baseline.get('mode')!r} but this run is {mode!r}; "
              "budgets are shape-dependent — not comparable",
              file=sys.stderr)
        return 2
    report = perfgate.check_gate(ROWS, baseline)
    payload = report.summary()
    payload["mode"] = mode
    payload["rev"] = _git_rev()
    payload["bandwidth_gbps"] = round(BW.gbps, 2) if BW else None
    with open(report_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(report.render(), file=sys.stderr)
    print(f"wrote {report_path}", file=sys.stderr)
    return 0 if report.ok else 1


def _write_baseline(quick: bool) -> None:
    base_path = os.path.join(_bench_dir(), "baseline.json")
    baseline = perfgate.make_baseline(ROWS, gated=GATED_ROWS)
    baseline["mode"] = _mode_name(quick)
    baseline["rev"] = _git_rev()
    baseline["bandwidth_gbps"] = round(BW.gbps, 2) if BW else None
    baseline["note"] = ("per-row perf budgets; regenerate with "
                        "`python -m benchmarks.run --smoke --rebaseline` "
                        "after an INTENTIONAL perf change (see README "
                        "§Performance gate)")
    with open(base_path, "w") as f:
        json.dump(baseline, f, indent=2, sort_keys=True)
    print(f"wrote {base_path}", file=sys.stderr)


def main() -> None:
    global SMOKE, BW
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + finite-number assertions on every "
                         "row (CI kernel-regression tripwire)")
    ap.add_argument("--gate", action="store_true",
                    help="check this run against benchmarks/baseline.json "
                         "and exit nonzero on any budget breach")
    ap.add_argument("--rebaseline", action="store_true",
                    help="rewrite benchmarks/baseline.json from this run "
                         "(after an intentional perf change)")
    args = ap.parse_args()
    SMOKE = args.smoke
    quick = args.quick or args.smoke
    BW = perfgate.measure_bandwidth()
    print(f"# bandwidth: {BW.gbps:.1f} GB/s ({BW.source}, {BW.backend})",
          file=sys.stderr)
    print("name,us_per_call,derived")
    failed: list[str] = []
    # BENCH_<rev>.json is ALWAYS emitted, and a bench that raises records a
    # "failed" row and the run continues — the trajectory shows holes
    # instead of silently dropping every row after the first crash.
    try:
        for bench in BENCHES:
            try:
                bench(quick)
            except Exception as e:  # noqa: BLE001
                print(f"{bench.__name__},ERROR,{type(e).__name__}: {e}",
                      file=sys.stderr)
                ROWS.append({"name": bench.__name__, "status": "failed",
                             "error": f"{type(e).__name__}: {e}",
                             "backend": jax.default_backend()})
                failed.append(bench.__name__)
    finally:
        print(f"wrote {_write_json(quick)}", file=sys.stderr)
    if args.rebaseline:
        _write_baseline(quick)
    rc = 0
    if args.gate:
        rc = max(rc, _run_gate(quick))
    if failed:
        print(f"{len(failed)} bench(es) failed: {', '.join(failed)}",
              file=sys.stderr)
        rc = max(rc, 1)
    sys.exit(rc)


if __name__ == "__main__":
    main()
