"""Benchmark harness — one entry per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's headline
quantity). Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.core import streaming
from repro.data import curve_dataset
from repro.kernels import ops as kernel_ops


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------- Table II-V
def bench_accuracy(quick: bool):
    """Paper Tables II-V: coefficients + Σe² vs the QR (polyfit) baseline on
    the paper's dataset. derived = max |coeff - polyfit coeff| at order 3."""
    x = jnp.asarray([39.206, 29.74, 21.31, 12.087, 1.812, 0.001])
    y = jnp.asarray([751.912, 567.121, 403.746, 221.738, 18.8418, 1.88672])
    for order in (1, 2, 3):
        us = _time(lambda: core.polyfit(x, y, order))
        gauss = core.polyfit(x, y, order)
        qr = core.polyfit_qr(x, y, order)
        sse = float(core.fit_report(gauss, x, y).sse)
        gap = float(jnp.max(jnp.abs(gauss.coeffs - qr.coeffs)))
        row(f"table2-4_order{order}_fit", us,
            f"sse={sse:.4f};max_coeff_gap_vs_qr={gap:.2e}")


# ------------------------------------------------------------------ §IV perf
def bench_speedup(quick: bool):
    """Paper §IV: matricized parallel accumulation vs the sequential
    per-point scalar loop (the pre-matricization implementation the paper
    benchmarks against; their GPU port reached ~100x over it). derived =
    speedup of the matricized path on this host."""
    sizes = [10_000, 100_000] if quick else [10_000, 100_000, 1_000_000]

    def sequential_power_sums(xs, ys, m=3):
        """Faithful scalar baseline: one point at a time, plain floats."""
        s = [0.0] * (2 * m + 1)
        t = [0.0] * (m + 1)
        for xi, yi in zip(xs, ys):
            p = 1.0
            for k in range(2 * m + 1):
                s[k] += p
                if k <= m:
                    t[k] += p * yi
                p *= xi
        return s, t

    for n in sizes:
        x, y, _ = curve_dataset(n, degree=3, seed=0)
        mat = jax.jit(lambda x, y: core.gram_moments(x, y, 3).gram)
        us_mat = _time(mat, x, y, iters=10)

        n_seq = min(n, 20_000)  # time a slice, extrapolate linearly
        xs = [float(v) for v in np.asarray(x[:n_seq])]
        ys = [float(v) for v in np.asarray(y[:n_seq])]
        t0 = time.perf_counter()
        sequential_power_sums(xs, ys)
        us_seq_full = (time.perf_counter() - t0) * 1e6 * (n / n_seq)
        row(f"speedup_n{n}", us_mat,
            f"seq_us={us_seq_full:.0f};speedup={us_seq_full / us_mat:.1f}x")


def bench_kernel(quick: bool):
    """Pallas moments kernel (interpret mode on CPU): correctness-equivalent
    throughput vs the jnp path; derived = Mpoints/s of the jnp path (the
    kernel's CPU interpret timing is NOT the TPU number — see EXPERIMENTS.md
    §Roofline for the TPU projection)."""
    n = 1 << 18 if quick else 1 << 20
    x, y, _ = curve_dataset(n, degree=3, seed=1)
    jnp_path = jax.jit(lambda x, y: core.gram_moments(x, y, 3).gram)
    us = _time(jnp_path, x, y, iters=10)
    blocked = jax.jit(
        lambda x, y: core.gram_moments_blocked(x, y, 3, block=1 << 14).gram)
    us_b = _time(blocked, x, y, iters=10)
    k = jax.jit(lambda x, y: kernel_ops.moments(x, y, 3).gram)
    us_k = _time(k, x, y, iters=2, warmup=1)
    row("moments_jnp", us, f"{n / us:.1f}Mpts/s")
    row("moments_blocked", us_b, f"{n / us_b:.1f}Mpts/s")
    row("moments_pallas_interpret", us_k, f"{n / us_k:.2f}Mpts/s(interpret)")


def bench_streaming(quick: bool):
    """Streaming O(1)-state fitter: points/s through update() + solve cost.
    derived = Mpts/s and the (constant) state size."""
    chunk = 1 << 14
    x, y, _ = curve_dataset(chunk, degree=2, seed=2)
    state = streaming.StreamState.create(2)
    upd = jax.jit(streaming.update)
    us = _time(upd, state, x, y, iters=20)
    state_bytes = sum(np.asarray(l).nbytes
                      for l in jax.tree.leaves(state))
    us_solve = _time(jax.jit(lambda s: streaming.current_fit(s).coeffs),
                     upd(state, x, y))
    row("streaming_update", us, f"{chunk / us:.1f}Mpts/s")
    row("streaming_solve", us_solve, f"state_bytes={state_bytes}")


def bench_batched_fits(quick: bool):
    """Batched (vmapped-by-construction) fitting — the monitors' workload:
    fit 4096 independent series at once. derived = fits/s."""
    b = 512 if quick else 4096
    x, y, _ = curve_dataset(256, degree=1, seed=3, batch=(b,))
    fit = jax.jit(lambda x, y: core.polyfit(x, y, 1).coeffs)
    us = _time(fit, x, y, iters=10)
    row("batched_fits", us, f"{b / (us / 1e6):.0f}fits/s")


def bench_e2e_train(quick: bool):
    """Smoke-scale end-to-end train step (framework overhead check).
    derived = tokens/s on this CPU host."""
    from repro import configs
    from repro.models import get_model
    from repro.train import TrainConfig, init_train_state, make_train_step
    cfg = configs.get_smoke_config("internlm2-1.8b")
    model = get_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, TrainConfig()))
    b, s = 4, 128
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32),
             "loss_mask": jnp.ones((b, s), jnp.float32)}
    state, _ = step(state, batch)  # compile

    def run(state):
        state, m = step(state, batch)
        return state, m

    t0 = time.perf_counter()
    iters = 5 if quick else 20
    for _ in range(iters):
        state, m = run(state)
    jax.block_until_ready(m["loss"])
    us = (time.perf_counter() - t0) / iters * 1e6
    row("train_step_smoke", us, f"{b * s / (us / 1e6):.0f}tok/s")


BENCHES = [bench_accuracy, bench_speedup, bench_kernel, bench_streaming,
           bench_batched_fits, bench_e2e_train]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for bench in BENCHES:
        try:
            bench(args.quick)
        except Exception as e:  # noqa: BLE001
            print(f"{bench.__name__},ERROR,{type(e).__name__}: {e}",
                  file=sys.stderr)
            raise


if __name__ == "__main__":
    main()
