"""§Perf hillclimb driver: measure one (arch × shape) cell under a named
sequence of changes and print the roofline deltas.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen:train --step v2
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import argparse
import dataclasses
import json

from repro import configs  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch.dryrun import analyze_cell, lower_cell  # noqa: E402


def measure(arch, shape, *, microbatches, cfg_mods=None, exact=True):
    import repro.launch.dryrun as dr
    mesh = mesh_lib.make_production_mesh()
    cfg = configs.get_config(arch)
    if cfg_mods:
        cfg = dataclasses.replace(cfg, **cfg_mods)
    # patch the registry lookup so analyze_cell's reduced configs inherit mods
    orig = configs.get_config
    configs.get_config = lambda a: (cfg if a == arch else orig(a))
    dr.MICROBATCHES = microbatches
    try:
        meta = analyze_cell(arch, shape, mesh, exact=exact)
    finally:
        configs.get_config = orig
    return meta


def report(tag, meta):
    print(json.dumps({
        "tag": tag, "arch": meta["arch"], "shape": meta["shape"],
        "compute_s": round(meta["compute_s"], 4),
        "memory_s": round(meta["memory_s"], 4),
        "collective_s": round(meta["collective_s"], 4),
        "step_s": round(meta["step_s"], 4),
        "dominant": meta["dominant"],
        "peak_gb": round(meta["peak_memory_gb"], 2),
        "useful": round(meta["useful_flops_ratio"], 4),
    }))


STEPS = {
    # qwen1.5-4b train_4k: worst useful-FLOPs cell
    "qwen-v1": lambda: measure("qwen1.5-4b", "train_4k", microbatches=1),
    "qwen-v2": lambda: measure("qwen1.5-4b", "train_4k", microbatches=8),
    "qwen-v3": lambda: measure("qwen1.5-4b", "train_4k", microbatches=8,
                               cfg_mods={"attn_seq_shard": True}),
    "qwen-v3p": lambda: measure("qwen1.5-4b", "prefill_32k", microbatches=1,
                                cfg_mods={"attn_seq_shard": True}),
    # rwkv6 train_4k: most collective-bound cell
    "rwkv-v2": lambda: measure("rwkv6-1.6b", "train_4k", microbatches=1),
    "rwkv-v3": lambda: measure("rwkv6-1.6b", "train_4k", microbatches=8),
    # dbrx train_4k: paper-representative (EP + DP-reduction) cell
    "dbrx-v1": lambda: measure("dbrx-132b", "train_4k", microbatches=1),
    "dbrx-v2": lambda: measure("dbrx-132b", "train_4k", microbatches=8),
    "dbrx-v3": lambda: measure("dbrx-132b", "train_4k", microbatches=8,
                               cfg_mods={"capacity_factor": 1.0}),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--step", required=True, choices=sorted(STEPS))
    args = ap.parse_args()
    report(args.step, STEPS[args.step]())


if __name__ == "__main__":
    main()
