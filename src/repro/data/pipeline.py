"""Deterministic, per-host-sharded synthetic data pipeline.

Every host generates only its own shard of each global batch from a seeded
counter (no cross-host I/O): batch `i`, host `h` derives its examples from
fold_in(seed, i * n_hosts + h). Restart-safe (the batch index is part of the
checkpoint) and elastic-safe (resharding only changes the host→example map,
not the example stream).

Token streams follow a Zipfian unigram draw with a Markov low-rank structure
so models actually learn (loss decreases) in the end-to-end examples.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


class TokenPipeline:
    """iter(batches) of {'tokens','labels','loss_mask'} for one host."""

    def __init__(self, dcfg: DataConfig, host_id: int = 0, n_hosts: int = 1,
                 start_batch: int = 0):
        assert dcfg.global_batch % n_hosts == 0
        self.dcfg = dcfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.batch_idx = start_batch
        self.local_batch = dcfg.global_batch // n_hosts
        # Zipf-ish unigram over vocab, fixed by seed
        ranks = np.arange(1, dcfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-dcfg.zipf_a)
        self._logits = jnp.asarray(np.log(probs / probs.sum()), jnp.float32)

    def _rng(self):
        key = jax.random.PRNGKey(self.dcfg.seed)
        return jax.random.fold_in(
            key, self.batch_idx * self.n_hosts + self.host_id)

    def next(self):
        d = self.dcfg
        rng = self._rng()
        r1, r2 = jax.random.split(rng)
        base = jax.random.categorical(
            r1, self._logits, shape=(self.local_batch, d.seq_len + 1))
        # Markov structure: with p=0.5 the next token repeats (t + 1) mod V
        rep = jax.random.bernoulli(r2, 0.5,
                                   (self.local_batch, d.seq_len + 1))
        toks = jnp.where(
            rep, jnp.roll((base + 1) % d.vocab_size, 1, axis=1), base)
        self.batch_idx += 1
        return {
            "tokens": toks[:, :-1].astype(jnp.int32),
            "labels": toks[:, 1:].astype(jnp.int32),
            "loss_mask": jnp.ones((self.local_batch, d.seq_len),
                                  jnp.float32),
        }

    def state(self) -> dict:
        return {"batch_idx": self.batch_idx}

    def restore(self, state: dict) -> None:
        self.batch_idx = int(state["batch_idx"])


def curve_dataset(n: int, degree: int = 3, noise: float = 1.0,
                  seed: int = 0, batch: tuple[int, ...] = ()):
    """Synthetic polynomial datasets for the paper's own workload: returns
    (x, y, true_coeffs). x ~ U[-10, 10]; y = poly(x) + N(0, noise)."""
    rng = np.random.default_rng(seed)
    coeffs = rng.normal(0, 1, batch + (degree + 1,))
    x = rng.uniform(-10, 10, batch + (n,))
    powers = np.stack([x ** k for k in range(degree + 1)], axis=-1)
    y = np.einsum("...nk,...k->...n", powers, coeffs)
    y = y + rng.normal(0, noise, y.shape)
    return (jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32),
            jnp.asarray(coeffs, jnp.float32))
