from repro.data.pipeline import DataConfig, TokenPipeline, curve_dataset

__all__ = ["DataConfig", "TokenPipeline", "curve_dataset"]
