"""k-fold cross-validation entirely in moment space — zero extra data passes.

The additive-moments property does all the work: partition the points into
K folds (round-robin), accumulate each fold's own ``Moments`` partial sum —
ONE batched accumulation call over a (K, n/K) layout, every point touched
exactly once — and then

* the training state of fold j is a *subtraction*: ``total − fold_j``
  (O(m²) arithmetic, no refit over data);
* the held-out score of fold j is ``sse_from_moments(fold_j, coeffs)`` —
  the fold's own (gram, vty, yty) is a complete scorer for any coefficient
  vector.

So K-fold CV over the whole degree ladder costs O(K·m²) state and
O(K·M⁴) tiny solves, independent of n.  Distributed, the fold partials
just psum like any other moments (``core.distributed``): fold identity is
preserved across shards because addition is, making CV mesh-parallel with
one O(K·m²) collective.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import basis as basis_lib
from repro.core import fit as fit_lib
from repro.core import moments as moments_lib


def fold_moments(x: jax.Array, y: jax.Array, k: int, degree: int, *,
                 weights: jax.Array | None = None,
                 basis: str = basis_lib.MONOMIAL,
                 engine: str = "auto",
                 accum_dtype=None,
                 plan=None) -> moments_lib.Moments:
    """Per-fold moment partials with a leading fold axis (k, ..., m+1, m+1).

    Point i goes to fold ``i % k`` (round-robin keeps every fold's x-range
    representative even for sorted input — the failure mode of contiguous
    blocks).  The tail is zero-weight padded; the fold axis rides as a
    leading batch axis through ONE ``compute_moments`` call, so the packed
    Pallas kernel accumulates all folds in the same pass it would have
    spent on a plain fit.  ``x`` must already be domain-mapped (the Domain
    lives with the caller, as everywhere in the engine layer)."""
    from repro import engine as engine_lib
    if k < 2:
        raise ValueError(f"k-fold CV needs k >= 2, got {k}")
    n = x.shape[-1]
    nper = -(-n // k)
    pad = nper * k - n
    spec = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    w = jnp.ones_like(x) if weights is None else weights
    xp = jnp.pad(x, spec)
    yp = jnp.pad(y, spec)
    wp = jnp.pad(w, spec)          # padding carries weight 0: contributes 0
    # (..., nper*k) -> (..., nper, k) -> fold axis to the front
    fold_shape = x.shape[:-1] + (nper, k)
    to_folds = lambda a: jnp.moveaxis(a.reshape(fold_shape), -1, 0)
    if plan is None:
        plan = engine_lib.plan_fit(
            (k,) + x.shape[:-1] + (nper,), degree, basis=basis,
            dtype=x.dtype, weighted=True, engine=engine,
            accum_dtype=accum_dtype, workload="select")
    return engine_lib.compute_moments(plan, to_folds(xp), to_folds(yp),
                                      to_folds(wp))


def sum_folds(folds: moments_lib.Moments) -> moments_lib.Moments:
    """Collapse the leading fold axis: the total-state the sweep solves."""
    return jax.tree.map(lambda a: jnp.sum(a, axis=0), folds)


def complement_moments(folds: moments_lib.Moments,
                       total: moments_lib.Moments | None = None
                       ) -> moments_lib.Moments:
    """Training state of every fold at once: ``total − fold_j``, batched
    over the fold axis.  The subtraction IS the refit-avoidance — the
    K training sets' sufficient statistics for free."""
    if total is None:
        total = sum_folds(folds)
    return jax.tree.map(lambda t, f: t - f, total, folds)


def cv_scores(folds: moments_lib.Moments, *,
              solver: str = "auto",
              fallback: str | None = "svd",
              cond_cap: float | None = None,
              basis: str = basis_lib.MONOMIAL,
              normalized: bool = False):
    """k-fold held-out SSE (PRESS) + its standard error, per ladder rung.

    For each fold: solve the ladder on ``total − fold`` (condition-aware,
    batched over the fold axis), score the held-out SSE from the fold's
    own (gram, vty, yty), sum over folds.  Matches explicit held-out
    refits to fp tolerance — asserted by ``tests/test_select.py``.

    Returns ``(press, se)``, both (..., M+1): ``se[d]`` is the standard
    error (Bessel-corrected, √k·std_{ddof=1} on the sum scale) of the
    PAIRED per-fold difference ``h_j[d] − h_j[argmin]`` — the statistic
    behind the parsimony rule in ``criteria.best_degree``.  Pairing by
    fold cancels the fold-content variance that inflates an unpaired SE:
    past the true degree the held-out curve is flat and pure argmin
    follows fold noise into overfitting, while degrees genuinely worse
    than the minimum show a systematic paired deficit in every fold (the
    one-SE-rule idea of ESL §7.10, sized as a paired t-test because k is
    small — see ``criteria.CV_TCRIT``)."""
    from repro.select import sweep as sweep_lib
    train = complement_moments(folds)
    coeffs, _, _ = sweep_lib.solve_ladder(train, solver=solver,
                                          fallback=fallback,
                                          cond_cap=cond_cap, basis=basis,
                                          normalized=normalized)
    held = fit_lib.sse_from_moments(folds, coeffs)   # (k, ..., M+1)
    k = held.shape[0]
    press = jnp.sum(held, axis=0)
    imin = jnp.argmin(press, axis=-1)
    hmin = jnp.take_along_axis(held, imin[None, ..., None], axis=-1)
    diff = held - hmin
    se = jnp.std(diff, axis=0, ddof=1) * jnp.sqrt(
        jnp.asarray(float(k), held.dtype))
    return press, se
