"""Automatic model selection from a single data pass (``repro.select``).

The paper matricizes one fit into additive O(m²) sufficient statistics;
this subsystem matricizes *model selection*: because the degree-M state
nests every lower degree (``Moments.truncate``), one accumulation carries
the whole ladder d = 0..M — per-degree condition-aware solves, moment-space
information criteria, and k-fold cross-validation by fold subtraction —
with no refits and no extra passes over the data.

Entry points:

* ``select_degree(x, y, max_degree=...)``  — one-pass search over raw data;
* ``core.polyfit(..., degree="auto" | DegreeSearch(...))`` — same, inline;
* ``api.FitSpec(degree=DegreeSearch(...))`` — the declarative spelling:
  the same search runs on every execution surface (eager, streaming,
  distributed, serve), composed with any method — including IRLS, where
  the ladder rides on the converged robust weights;
* ``sweep_from_moments`` / ``solve_ladder`` — from an existing state
  (streaming ``current_selection``, the fit server's auto-degree requests,
  ``core.make_distributed_select`` on a mesh).
"""
from repro.select.criteria import (ScoreTable, score_table, best_degree,
                                   CRITERIA, MOMENT_CRITERIA)
from repro.select.sweep import (SweepResult, DegreeSearch, Selection,
                                solve_ladder, sweep_from_moments,
                                selection_from_sweep, select_degree)
from repro.select.crossval import (fold_moments, sum_folds,
                                   complement_moments, cv_scores)

__all__ = [
    "ScoreTable", "score_table", "best_degree", "CRITERIA",
    "MOMENT_CRITERIA",
    "SweepResult", "DegreeSearch", "Selection", "solve_ladder",
    "sweep_from_moments", "selection_from_sweep", "select_degree",
    "fold_moments", "sum_folds", "complement_moments", "cv_scores",
]
