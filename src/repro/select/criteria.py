"""Model-selection criteria computed purely from moment-space quantities.

Every criterion here is a function of (SSE_d, n, k_d) — the per-degree
residual sum of squares, the number of contributing points, and the
parameter count k_d = d + 1 — plus the degree-free total sum of squares
for R².  All of those come from the O(m²) sufficient statistics alone
(``core.fit.sse_from_moments`` over the zero-padded coefficient ladder),
so scoring the whole ladder costs O(M·m²) with **zero** passes over the
data: exactly the paper's "matricize so it scales" move applied to model
selection instead of to a single fit.

Criteria (classic definitions, Gaussian-likelihood form):

* ``sse``   raw Σe² — monotone non-increasing in degree, never selects;
* ``r2``    1 − SSE/SST — monotone too, reported for the tables;
* ``aic``   n·ln(SSE/n) + 2k;
* ``aicc``  AIC + 2k(k+1)/(n−k−1) — the small-sample correction, +inf
            once n ≤ k + 1 (an honest "not enough data for this degree");
* ``bic``   n·ln(SSE/n) + k·ln(n) — consistent: picks the true degree
            with probability → 1 as n grows;
* ``gcv``   (SSE/n) / (1 − k/n)² — leave-one-out CV's rotation-invariant
            approximation, no folds needed;
* ``cv``    k-fold held-out SSE (PRESS), accumulated in moment space by
            ``repro.select.crossval`` — the only entry that needs fold
            partials, and still zero extra data passes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# criteria that SELECT a degree (argmin).  "sse"/"r2" are reported but
# monotone in degree; "cv" additionally needs fold moments.
CRITERIA = ("aic", "aicc", "bic", "gcv", "cv")
MOMENT_CRITERIA = ("aic", "aicc", "bic", "gcv")   # no folds required
REPORTED = ("sse", "r2") + CRITERIA

# "cv" parsimony rule: degrees whose paired held-out deficit vs the CV
# minimum is below CV_TCRIT × its paired standard error count as TIES and
# the smallest wins.  This is the ESL one-SE rule sized as a paired
# t-test: with the usual small fold counts (k−1 ≈ 4 dof) a ~98%
# one-sided threshold sits near t = 3, and the measured selection table
# (EXPERIMENTS.md §Degree selection) shows t = 1 still overfits on flat
# CV curves while t = 3 recovers the planted degree without underfitting
# well-posed signals.
CV_TCRIT = 3.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScoreTable:
    """Per-degree scores, ladder axis last: every field is (..., M+1).

    ``cv`` is the k-fold held-out SSE when fold moments were available,
    else +inf (so ``best_degree(..., "cv")`` on a fold-less sweep is a
    loud degenerate answer — degree 0 everywhere — rather than a wrong
    quiet one; callers validate the criterion up front).  ``cv_se`` is
    the across-fold standard error of ``cv``, which drives the
    one-standard-error selection rule."""

    sse: jax.Array
    r2: jax.Array
    aic: jax.Array
    aicc: jax.Array
    bic: jax.Array
    gcv: jax.Array
    cv: jax.Array
    cv_se: jax.Array

    @property
    def max_degree(self) -> int:
        return self.sse.shape[-1] - 1

    def by_name(self, criterion: str) -> jax.Array:
        if criterion not in REPORTED:
            raise ValueError(f"criterion={criterion!r}; expected one of "
                             f"{REPORTED}")
        return getattr(self, criterion)


def _safe_log_mean_sse(sse: jax.Array, n: jax.Array) -> jax.Array:
    """ln(SSE/n) with exact-interpolation states clamped to the dtype
    floor instead of -inf (a noiseless planted polynomial hits SSE == 0
    at the true degree; the penalty terms must still order the ladder)."""
    tiny = jnp.asarray(jnp.finfo(sse.dtype).tiny, sse.dtype)
    return jnp.log(jnp.maximum(sse, tiny) / jnp.maximum(n, 1.0))


def score_table(sse: jax.Array, n: jax.Array, sst: jax.Array,
                cv: jax.Array | None = None,
                cv_se: jax.Array | None = None) -> ScoreTable:
    """Assemble every criterion for a ladder of SSEs.

    ``sse``: (..., M+1) per-degree residual sums; ``n``: (...,) contributing
    points; ``sst``: (...,) centered total sum of squares (Σw(y−ȳ)², from
    moments: yty − (Σwy)²/Σw); ``cv``: optional (..., M+1) held-out SSE.
    Degrees whose parameter count exhausts the data (n ≤ k, or n ≤ k+1 for
    AICc's correction) score +inf — underdetermined fits never win.
    """
    m1 = sse.shape[-1]
    k = jnp.arange(1, m1 + 1, dtype=sse.dtype)        # params at degree d
    n = jnp.asarray(n, sse.dtype)[..., None]
    inf = jnp.asarray(jnp.inf, sse.dtype)
    log_ms = _safe_log_mean_sse(sse, n)
    aic = n * log_ms + 2.0 * k
    dof = n - k - 1.0
    aicc = jnp.where(dof > 0, aic + 2.0 * k * (k + 1.0)
                     / jnp.where(dof > 0, dof, 1.0), inf)
    bic = n * log_ms + k * jnp.log(jnp.maximum(n, 1.0))
    shrink = 1.0 - k / jnp.maximum(n, 1.0)
    gcv = jnp.where(shrink > 0,
                    (sse / jnp.maximum(n, 1.0))
                    / jnp.where(shrink > 0, shrink, 1.0) ** 2, inf)
    underdet = n <= k
    aic = jnp.where(underdet, inf, aic)
    bic = jnp.where(underdet, inf, bic)
    sst_pos = jnp.maximum(jnp.asarray(sst, sse.dtype)[..., None],
                          jnp.finfo(sse.dtype).tiny)
    r2 = 1.0 - sse / sst_pos
    if cv is None:
        cv = jnp.full_like(sse, jnp.inf)
    if cv_se is None:
        cv_se = jnp.zeros_like(sse)
    return ScoreTable(sse=sse, r2=r2, aic=aic, aicc=aicc, bic=bic,
                      gcv=gcv, cv=cv, cv_se=cv_se)


def best_degree(scores: ScoreTable, criterion: str = "aicc") -> jax.Array:
    """The selected degree under a criterion, over the ladder axis: int32.

    Information criteria take the plain argmin (ties break toward the
    LOWER degree — jnp's first-hit rule, the parsimony direction).  "cv"
    takes the SMALLEST degree whose paired held-out deficit vs the CV
    minimum is statistically insignificant (< ``CV_TCRIT`` × paired SE) —
    past the true degree the held-out curve is flat and pure argmin
    follows fold noise into overfitting (ESL §7.10's one-SE rule, sized
    as a paired t-test for small fold counts)."""
    if criterion not in CRITERIA:
        raise ValueError(
            f"criterion={criterion!r} cannot select a degree; pick one of "
            f"{CRITERIA} ('sse'/'r2' are monotone in degree)")
    vals = scores.by_name(criterion)
    if criterion == "cv":
        # vals − vmin is exactly the mean paired difference (sum scale),
        # cv_se its per-degree paired standard error
        vmin = jnp.min(vals, axis=-1, keepdims=True)
        within = vals <= vmin + CV_TCRIT * scores.cv_se
        return jnp.argmax(within, axis=-1).astype(jnp.int32)
    return jnp.argmin(vals, axis=-1).astype(jnp.int32)
