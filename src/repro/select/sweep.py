"""Single-pass degree sweep: the whole ladder d = 0..M from ONE accumulation.

The degree-M Gram/moment state *contains* every lower-degree state as a
leading submatrix/prefix (``Moments.truncate``), because column k of the
Vandermonde depends only on k for the monomial and Chebyshev bases.  So the
paper's one heavy step — the O(n·m²) moment accumulation — is paid once at
the maximum candidate degree, and the entire model-selection problem is then
solved on the O(M²) sufficient statistics:

* ``solve_ladder``       one condition-aware ``solve_with_fallback`` per
                         rung (solver picked per degree when "auto" —
                         low rungs take GE, high rungs escalate exactly as
                         ``core.solve.select_solver`` prescribes), results
                         zero-padded into a (M+1, M+1) coefficient ladder;
* ``sweep_from_moments`` scores every rung with SSE/R²/AIC/AICc/BIC/GCV
                         (and k-fold CV when fold partials are supplied)
                         computed purely from moments;
* ``select_degree``      the top-level one-pass entry point over raw data;
* ``DegreeSearch``       the hashable spec ``core.polyfit`` accepts as
                         ``degree=`` for automatic selection.

Cost: one data pass + O(M·m²) state + an O(M⁴) stack of tiny solves —
versus M+1 full refits (M+1 data passes) for the naive sweep.  The bench
row ``select_sweep`` measures the gap.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import basis as basis_lib
from repro.core import fit as fit_lib
from repro.core import moments as moments_lib
from repro.core import solve as solve_lib
from repro.select import criteria


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Every degree's fit + score from one moment state.

    ``coeffs[..., d, :]`` is the degree-d solution zero-padded to M+1
    entries (padding contributes nothing when evaluated or scored, so the
    ladder is directly usable in batched expressions); ``condition`` /
    ``fallback_used`` are the per-rung solve diagnostics on the TRUNCATED
    Gram — the honest per-degree κ, not the max-degree one."""

    coeffs: jax.Array           # (..., M+1, M+1) zero-padded ladder
    condition: jax.Array        # (..., M+1) κ(truncated Gram) per degree
    fallback_used: jax.Array    # (..., M+1) bool, rescue engaged per degree
    scores: criteria.ScoreTable

    @property
    def max_degree(self) -> int:
        return self.coeffs.shape[-1] - 1

    def best(self, criterion: str = "aicc") -> jax.Array:
        return criteria.best_degree(self.scores, criterion)


@dataclasses.dataclass(frozen=True)
class DegreeSearch:
    """Hashable spec for ``polyfit(..., degree=DegreeSearch(...))``.

    ``degree="auto"`` is shorthand for ``DegreeSearch()``.  ``criterion``
    None resolves to "cv" when ``folds >= 2``, else "aicc"."""

    max_degree: int = 8
    folds: int = 5
    criterion: str | None = None
    solver: str = "auto"
    fallback: str | None = "svd"
    cond_cap: float | None = None


@dataclasses.dataclass(frozen=True)
class Selection:
    """Host-side result of a degree search (not a pytree).

    ``poly`` is the winning fit ready to evaluate: for unbatched input its
    coefficient vector is sliced to the chosen degree; for batched input
    (per-series winners may differ) it keeps the zero-padded M+1 layout,
    which evaluates identically."""

    sweep: SweepResult
    best_degree: int | np.ndarray
    criterion: str
    poly: fit_lib.Polynomial


def solve_ladder(m: moments_lib.Moments, *, solver: str = "auto",
                 fallback: str | None = "svd",
                 cond_cap: float | None = None,
                 basis: str = basis_lib.MONOMIAL,
                 normalized: bool = False):
    """Solve all nested normal-equation systems d = 0..m.degree.

    Returns ``(coeffs, condition, fallback_used)`` with a ladder axis at
    -2 / -1.  Each rung is a ``solve_with_fallback`` on the truncated Gram
    — condition-aware per degree, vectorized over any batch axes of ``m``
    (fold axes, slot pools, series batches).  ``solver="auto"`` re-picks
    the static rung per degree via ``core.solve.select_solver``."""
    max_degree = m.degree
    coeffs, conds, used = [], [], []
    for d in range(max_degree + 1):
        mt = m.truncate(d)
        rung = (solve_lib.select_solver(d, m.gram.dtype, basis=basis,
                                        normalized=normalized)
                if solver == "auto" else solver)
        c, cond, fb = solve_lib.solve_with_fallback(
            mt.gram, mt.vty, method=rung, fallback=fallback,
            cond_cap=cond_cap)
        pad = [(0, 0)] * (c.ndim - 1) + [(0, max_degree - d)]
        coeffs.append(jnp.pad(c, pad))
        conds.append(cond)
        used.append(fb)
    return (jnp.stack(coeffs, axis=-2), jnp.stack(conds, axis=-1),
            jnp.stack(used, axis=-1))


def sweep_from_moments(m: moments_lib.Moments, *,
                       fold_moments: moments_lib.Moments | None = None,
                       score_moments: moments_lib.Moments | None = None,
                       solver: str = "auto",
                       fallback: str | None = "svd",
                       cond_cap: float | None = None,
                       basis: str = basis_lib.MONOMIAL,
                       normalized: bool = False) -> SweepResult:
    """The full degree sweep from one degree-M moment state.

    ``fold_moments`` (leading fold axis, summing to ``m`` up to any
    regularization applied to ``m``) enables the "cv" column: k-fold
    held-out SSE computed entirely in moment space
    (``repro.select.crossval``).  ``score_moments`` splits the solve from
    the scoring: ridge-stabilized callers (streaming, the fit server's
    pooled slots) solve the ladder on the regularized ``m`` but must
    score on the RAW state, else every SSE — and the criteria built on it
    — is inflated by λ‖a‖² and disagrees with the fixed-degree report
    path.  Everything is O(M·m²) on sufficient statistics — zero passes
    over data."""
    coeffs, cond, fb = solve_ladder(m, solver=solver, fallback=fallback,
                                    cond_cap=cond_cap, basis=basis,
                                    normalized=normalized)
    ms = score_moments if score_moments is not None else m
    sse = fit_lib.sse_from_moments(ms, coeffs)
    sw = jnp.maximum(ms.weight_sum, jnp.finfo(ms.gram.dtype).tiny)
    sst = ms.yty - ms.vty[..., 0] ** 2 / sw
    cv = cv_se = None
    if fold_moments is not None:
        from repro.select import crossval
        cv, cv_se = crossval.cv_scores(fold_moments, solver=solver,
                                       fallback=fallback, cond_cap=cond_cap,
                                       basis=basis, normalized=normalized)
    scores = criteria.score_table(sse, ms.count, sst, cv, cv_se)
    return SweepResult(coeffs=coeffs, condition=cond, fallback_used=fb,
                       scores=scores)


_JIT_SWEEP = partial(jax.jit, static_argnames=(
    "solver", "fallback", "cond_cap", "basis", "normalized"))(
        lambda m, fold_moments, score_moments, solver, fallback, cond_cap,
        basis, normalized: sweep_from_moments(
            m, fold_moments=fold_moments, score_moments=score_moments,
            solver=solver, fallback=fallback,
            cond_cap=cond_cap, basis=basis, normalized=normalized))


def selection_from_sweep(sweep: SweepResult, criterion: str, *,
                         domain: basis_lib.Domain | None = None,
                         basis: str = basis_lib.MONOMIAL,
                         solver: str = "auto",
                         fallback: str | None = "svd") -> Selection:
    """Pick the winner out of a sweep and package it as a ``Polynomial``.

    Host-side (reads the argmin back): the eager tail of the selection
    entry points.  Batched sweeps keep the zero-padded coefficient layout
    with per-series winners gathered along the ladder axis."""
    best = sweep.best(criterion)
    dom = domain or basis_lib.Domain.identity(sweep.coeffs.dtype)
    if best.ndim == 0:
        b = int(best)
        coeffs = sweep.coeffs[..., b, :b + 1]
        cond = sweep.condition[..., b]
        fb = sweep.fallback_used[..., b]
        best_out: int | np.ndarray = b
    else:
        coeffs = jnp.take_along_axis(
            sweep.coeffs, best[..., None, None], axis=-2)[..., 0, :]
        cond = jnp.take_along_axis(sweep.condition, best[..., None],
                                   axis=-1)[..., 0]
        fb = jnp.take_along_axis(sweep.fallback_used, best[..., None],
                                 axis=-1)[..., 0]
        best_out = np.asarray(best)
    diag = fit_lib.FitDiagnostics(condition=cond, fallback_used=fb,
                                  solver=solver, fallback=fallback or "none")
    poly = fit_lib.Polynomial(coeffs=coeffs, domain_shift=dom.shift,
                              domain_scale=dom.scale, basis=basis,
                              diagnostics=diag)
    return Selection(sweep=sweep, best_degree=best_out, criterion=criterion,
                     poly=poly)


def select_degree(x: jax.Array, y: jax.Array, max_degree: int = 8, *,
                  folds: int = 5,
                  criterion: str | None = None,
                  weights: jax.Array | None = None,
                  basis: str = basis_lib.MONOMIAL,
                  normalize: bool | None = None,
                  engine: str = "auto",
                  solver: str = "auto",
                  fallback: str | None = "svd",
                  cond_cap: float | None = None,
                  accum_dtype: Any = None,
                  ridge: float = 0.0) -> Selection:
    """Pick the polynomial degree analytically from ONE pass over the data.

    One degree-``max_degree`` moment accumulation (k-fold partials when
    ``folds >= 2``, assigned round-robin so every point is touched exactly
    once) feeds the whole ladder: per-degree condition-aware solves,
    SSE/R²/AIC/AICc/BIC/GCV, and moment-space k-fold CV.  The plan layer
    (``workload="select"``) routes the accumulation exactly like a fit —
    the packed Pallas kernel picks up the fold axis as a series batch on
    TPU.

    ``criterion`` defaults to "cv" (with folds) / "aicc" (without);
    ``normalize=None`` lets the numerics policy auto-normalize at the
    degrees where a raw-domain Gram is unsalvageable (the decision is made
    once, at ``max_degree`` — the rung where conditioning is worst).
    ``ridge`` adds λI to the ladder SOLVES while the scores stay on the
    raw state (the streaming/serve convention — see
    ``sweep_from_moments``'s ``score_moments``), so a ridge-stabilized
    spec selects on the same SSE scale as an unridged one.

    Eager by design (the winning degree is read back to slice the
    coefficients): the moment pass and the ladder solve are jitted
    internally; only the tiny argmin crosses to the host.
    """
    from repro import engine as engine_lib
    from repro.select import crossval
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    folds = int(folds)
    if criterion is None:
        criterion = "cv" if folds >= 2 else "aicc"
    if criterion == "cv" and folds < 2:
        raise ValueError("criterion='cv' needs folds >= 2")
    if criterion not in criteria.CRITERIA:
        raise ValueError(f"criterion={criterion!r}; expected one of "
                         f"{criteria.CRITERIA}")

    batch = x.shape[:-1]
    if folds >= 2:
        plan_shape = (folds,) + batch + (-(-x.shape[-1] // folds),)
    else:
        plan_shape = x.shape
    plan = engine_lib.plan_fit(
        plan_shape, max_degree, basis=basis, dtype=x.dtype,
        weighted=folds >= 2 or weights is not None, engine=engine,
        accum_dtype=accum_dtype, normalize=bool(normalize or False),
        solver=solver if solver != "auto" else "auto", fallback=fallback,
        cond_cap=cond_cap, workload="select")
    pol = plan.numerics
    do_norm = pol.normalize if normalize is None else bool(normalize)
    dom = (basis_lib.Domain.from_data(x) if do_norm
           else basis_lib.Domain.identity(x.dtype))
    xt = dom.apply(x)

    if folds >= 2:
        fold_m = crossval.fold_moments(xt, y, folds, max_degree,
                                       weights=weights, basis=basis,
                                       plan=plan)
        total = crossval.sum_folds(fold_m)
    else:
        fold_m = None
        total = engine_lib.compute_moments(plan, xt, y, weights)

    solve_m, score_m = total, None
    if ridge:
        solve_m, score_m = total.regularized(ridge), total
    sweep = _JIT_SWEEP(solve_m, fold_m, score_m, solver, fallback,
                       cond_cap, basis, do_norm)
    return selection_from_sweep(sweep, criterion, domain=dom, basis=basis,
                                solver=solver, fallback=fallback)
