"""Logical-axis → mesh-axis sharding rules.

Model code annotates every param/state leaf with logical axis names
(tuples like ("embed", "q_heads", "head_dim")); this module maps them to
``PartitionSpec``s for a given mesh. Strategy (MaxText-style):

  * tensor-parallel axes (heads/mlp/vocab/experts) → "model"
  * FSDP: the d_model ("embed") weight axis → "data" (weights gathered
    per-layer inside the scan; optimizer state inherits → ZeRO-3)
  * batch → all data-parallel axes ("pod","data")
  * long-context decode (batch=1): kv_seq → "data" (flash-decoding-style
    partial-softmax combine emerges from SPMD reductions)

A mesh axis may appear at most once in a PartitionSpec; when two logical
axes map to the same mesh axis, the later one is dropped (replicated) —
e.g. zamba's (embed, embed) projections.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (None = replicate)
BASE_RULES: dict[str, str | tuple[str, ...] | None] = {
    "layers": None,
    "embed": ("pod", "data"),  # FSDP; extends across pods when present
                               # (132B-class state only fits multi-pod)
    "heads_embed": "model",   # square d×d projections' output side (rwkv)
    "q_heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    # projection input dims that must stay replicated (small models where
    # FSDP-sharding the contraction dim makes XLA psum full activations over
    # the data axis instead of gathering the far smaller weight — measured
    # 12 GB/step of f32 activation all-reduces on rwkv6 before this)
    "act_in": None,
    # the embedding table's d_model axis stays replicated: FSDP-sharding it
    # puts the contraction dim of the LM head on the data axis and XLA emits
    # full-logits all-gathers/all-reduces (measured: 24 GB/op on internlm2)
    "table_embed": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "conv": None,
    "state": None,
    "lora": None,
    "heads": "model",
    # activations / cache
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
}

# decode: the cache updates in place each step (donated buffers), so its
# kv_seq dim must stay UNSHARDED — a dynamic-update-slice on a sharded dim
# makes SPMD copy the whole cache through temps (measured +13.4 GB/step on
# qwen decode_32k). Shard kv_heads over "model" instead, with head_dim as
# the dedupe fallback when heads don't divide (qwen's kv=20 ∤ 16 shards
# head_dim=128); attention then contracts hd with a small psum.
DECODE_OVERRIDES = {
    "kv_seq": None,
    "head_dim": "model",
}

LONG_CONTEXT_OVERRIDES = {
    "batch": None,                    # batch=1: cannot shard
    "kv_seq": ("data", "model"),      # shard the long KV/sequence instead
}


def _mesh_axes(mesh: Mesh, name) -> tuple[str, ...]:
    if name is None:
        return ()
    names = name if isinstance(name, tuple) else (name,)
    return tuple(n for n in names if n in mesh.axis_names)


def spec_for(mesh: Mesh, logical: tuple, rules: dict | None = None,
             dims: tuple[int, ...] | None = None) -> P:
    """Build a PartitionSpec from logical axes; dedupe repeated mesh axes.

    If ``dims`` is given, any mapping where the dim is not divisible by the
    mesh-axis size is dropped (replicated) — keeps odd dims lowerable.
    """
    rules = rules or BASE_RULES
    used: set[str] = set()
    out = []
    for i, ax in enumerate(logical):
        mapped = _mesh_axes(mesh, rules.get(ax) if ax is not None else None)
        mapped = tuple(m for m in mapped if m not in used)
        if mapped and dims is not None:
            total = 1
            for m in mapped:
                total *= mesh.shape[m]
            if dims[i] % total:
                mapped = ()
        if mapped:
            used.update(mapped)
            out.append(mapped if len(mapped) > 1 else mapped[0])
        else:
            out.append(None)
    return P(*out)


def tree_shardings(mesh: Mesh, spec_tree, shape_tree=None, *,
                   overrides: dict | None = None):
    """Map a logical-spec tree (+ optional matching ShapeDtypeStruct tree for
    divisibility checks) to a NamedSharding tree."""
    rules = dict(BASE_RULES)
    if overrides:
        rules.update(overrides)

    is_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)

    if shape_tree is None:
        return jax.tree.map(
            lambda spec: NamedSharding(mesh, spec_for(mesh, spec, rules)),
            spec_tree, is_leaf=is_leaf)
    return jax.tree.map(
        lambda spec, sds: NamedSharding(
            mesh, spec_for(mesh, spec, rules, dims=sds.shape)),
        spec_tree, shape_tree, is_leaf=is_leaf)


def constrain(x, *logical, overrides: dict | None = None):
    """Activation sharding constraint by logical axis names; no-op outside a
    mesh context (host tests) or when a dim doesn't divide its mesh axes.

    XLA's sharding propagation can silently replicate activations when an
    adjacent weight axis fails to shard (measured: qwen1.5's 20 heads on the
    16-way model axis replicated whole-batch attention activations — 832 GB
    buffers). Pinning the batch/head layout at block boundaries prevents it.
    """
    import jax.numpy as jnp  # local: avoid cycle at module import

    get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_mesh is None:
        # jax < 0.4.38: no abstract-mesh context API; constraints are
        # best-effort there, and host tests run without a mesh anyway.
        return x
    mesh = get_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    rules = dict(BASE_RULES)
    if overrides:
        rules.update(overrides)
    spec = spec_for(mesh, logical, rules, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, spec)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P(data_axes(mesh)))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
