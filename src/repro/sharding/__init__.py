from repro.sharding.rules import (BASE_RULES, LONG_CONTEXT_OVERRIDES,
                                  DECODE_OVERRIDES,
                                  spec_for, tree_shardings, data_axes,
                                  batch_sharding, replicated)

__all__ = ["BASE_RULES", "LONG_CONTEXT_OVERRIDES", "DECODE_OVERRIDES",
           "spec_for", "tree_shardings", "data_axes", "batch_sharding",
           "replicated"]
