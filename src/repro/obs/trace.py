"""Trace spans on the fleet's virtual tick clock.

The fleet never sleeps — time is an injected tick counter — so a trace of
its request lifecycle is *deterministic*: same seed, same chaos schedule,
same byte-identical event log.  That turns tracing from a debugging aid
into an assertable artifact (CI's ``obs-smoke`` job diffs invariants over
it, ``tests/test_obs.py`` diffs whole logs across runs).

Events are append-only records ``{seq, ph, uid, name, tick, args}``:

* ``ph="B"/"E"`` — span begin/end (``queue``, ``ingest``, ``solve``);
  begins are idempotent per (uid, name) and ends without a matching open
  begin are dropped, so retry/hedge re-sends cannot corrupt the chain.
* ``ph="i"`` — instant annotations (``submit``, ``admit``, ``degrade``,
  ``shed``, ``retry``, ``replay``, ``hedge``, ``poisoned``, ``respond``,
  ``failed``, fleet-scope ``worker_death`` / ``revival`` under uid -1).

Exports: JSONL (one sorted-keys JSON object per line — byte-stable) and
the Chrome trace-event view (`chrome://tracing` / Perfetto; one tid per
request uid, 1 tick = 1µs).  ``validate_events`` checks the span-chain
invariants the CI job asserts: every admitted uid reaches a terminal
annotation, every replay/hedge/degrade surfaced on the request object has
a matching annotation, and B/E pairs nest correctly.
"""
from __future__ import annotations

import json

FLEET_UID = -1                       # uid for fleet-scope (non-request) events
TERMINAL = ("respond", "failed")     # terminal instant names


class Tracer:
    """Append-only deterministic event recorder.

    The record path is the serving hot loop's cost, so it appends one
    plain tuple per event and defers the dict view (seq numbers, int
    coercion) to first read — the ``obs_overhead`` bench row holds the
    whole enabled layer to <= 5% of the null path."""

    enabled = True

    def __init__(self):
        self._log: list[tuple] = []          # (ph, uid, name, tick, attrs)
        self._view: list[dict] = []          # materialized dict view
        self._open: set[tuple[int, str]] = set()

    @property
    def events(self) -> list[dict]:
        """The event log as dicts ``{seq, ph, uid, name, tick, args}``
        (materialized incrementally from the raw append log)."""
        log, view = self._log, self._view
        for i in range(len(view), len(log)):
            ph, uid, name, tick, attrs = log[i]
            view.append({"seq": i, "ph": ph, "uid": int(uid),
                         "name": name, "tick": int(tick), "args": attrs})
        return view

    def begin(self, uid: int, name: str, tick: int, **attrs) -> None:
        key = (uid, name)
        if key in self._open:        # re-begin (retry/hedge): keep the span
            return
        self._open.add(key)
        self._log.append(("B", uid, name, tick, attrs))

    def end(self, uid: int, name: str, tick: int, **attrs) -> None:
        key = (uid, name)
        if key not in self._open:    # no open span: drop, never corrupt
            return
        self._open.discard(key)
        self._log.append(("E", uid, name, tick, attrs))

    def instant(self, uid: int, name: str, tick: int, **attrs) -> None:
        self._log.append(("i", uid, name, tick, attrs))

    # ------------------------------------------------------------ queries
    def events_for(self, uid: int) -> list[dict]:
        return [e for e in self.events if e["uid"] == uid]

    def names_for(self, uid: int) -> list[str]:
        return [e["name"] for e in self.events if e["uid"] == uid]

    # ------------------------------------------------------------ exports
    def to_jsonl(self) -> str:
        """One sorted-keys JSON object per line: byte-identical across
        runs with the same seed/chaos schedule."""
        return "".join(json.dumps(e, sort_keys=True) + "\n"
                       for e in self.events)

    def export_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())

    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON (load in Perfetto / chrome://tracing).
        One thread row per request uid; 1 virtual tick = 1µs."""
        out = []
        for e in self.events:
            ev = {"name": e["name"], "ph": e["ph"], "ts": e["tick"],
                  "pid": 0, "tid": e["uid"], "cat": "fleet",
                  "args": e["args"]}
            if e["ph"] == "i":
                ev["s"] = "t"        # thread-scoped instant
            out.append(ev)
        return {"traceEvents": out,
                "displayTimeUnit": "ms",
                "otherData": {"clock": "virtual ticks (1 tick = 1us)"}}

    def export_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, sort_keys=True)


class NullTracer:
    """The disabled twin: every record is one empty method call."""

    enabled = False
    events: list = []

    def begin(self, uid, name, tick, **attrs) -> None:
        pass

    def end(self, uid, name, tick, **attrs) -> None:
        pass

    def instant(self, uid, name, tick, **attrs) -> None:
        pass

    def events_for(self, uid) -> list:
        return []

    def names_for(self, uid) -> list:
        return []

    def to_jsonl(self) -> str:
        return ""

    def chrome_trace(self) -> dict:
        return {"traceEvents": []}


NULL_TRACER = NullTracer()


# ------------------------------------------------------------- validation
def parse_jsonl(text: str) -> list[dict]:
    return [json.loads(line) for line in text.splitlines() if line.strip()]


def validate_events(events: list[dict]) -> list[str]:
    """Check the span-chain invariants over an event log (a ``Tracer``'s
    ``events`` or a parsed JSONL artifact).  Returns a list of problems —
    empty means the log is well-formed:

    * every admitted uid reaches exactly one terminal annotation
      (``respond`` or ``failed``);
    * every ``replay`` / ``hedge`` / ``retry`` annotation belongs to an
      admitted request;
    * span begins/ends pair up (no dangling E, no unclosed B on a
      terminated request);
    * per-uid ticks are non-decreasing in event order.
    """
    problems: list[str] = []
    by_uid: dict[int, list[dict]] = {}
    for e in events:
        by_uid.setdefault(e["uid"], []).append(e)
    for uid, evs in sorted(by_uid.items()):
        if uid == FLEET_UID:
            continue
        names = [e["name"] for e in evs]
        admitted = "admit" in names
        terminals = [n for n in names if n in TERMINAL]
        if admitted and len(terminals) != 1:
            problems.append(f"uid {uid}: admitted but {len(terminals)} "
                            f"terminal events {terminals}")
        if not admitted and terminals and "shed" not in names:
            problems.append(f"uid {uid}: terminal without admit")
        for n in ("replay", "hedge", "retry"):
            if n in names and not admitted:
                problems.append(f"uid {uid}: {n} on unadmitted request")
        open_spans: set[str] = set()
        last_tick = None
        for e in evs:
            if last_tick is not None and e["tick"] < last_tick:
                problems.append(f"uid {uid}: tick went backwards at "
                                f"seq {e['seq']}")
            last_tick = e["tick"]
            if e["ph"] == "B":
                if e["name"] in open_spans:
                    problems.append(f"uid {uid}: double-begin "
                                    f"{e['name']!r}")
                open_spans.add(e["name"])
            elif e["ph"] == "E":
                if e["name"] not in open_spans:
                    problems.append(f"uid {uid}: end without begin "
                                    f"{e['name']!r}")
                open_spans.discard(e["name"])
        if terminals and open_spans:
            problems.append(f"uid {uid}: terminated with open spans "
                            f"{sorted(open_spans)}")
    return problems


def assert_valid(events: list[dict]) -> None:
    problems = validate_events(events)
    if problems:
        raise AssertionError("trace invariants violated:\n  "
                             + "\n  ".join(problems))
