"""Metrics registry: counters, gauges, and mergeable quantile sketches.

The serving stack (``serve.fit_engine``, ``serve.fleet``), the async-LSPIA
executor (``core.distributed``) and the streaming ingestors each grew an
ad-hoc ``stats`` dict; quantiles were a one-shot ``np.percentile`` over a
retained latency list at shutdown.  This module is the shared replacement:

* ``Counter`` / ``Gauge`` — monotone event counts and level samples; the
  gauge keeps a high-water mark so "peak queue depth" is a first-class
  readable, not a post-hoc scan.
* ``HistogramSketch`` — a DDSketch-style log-bucketed streaming quantile
  sketch (arXiv:1908.10693's scheme in miniature): bucket ``i`` holds all
  values in ``(gamma^(i-1), gamma^i]`` with ``gamma = (1+alpha)/(1-alpha)``,
  so any quantile is answered to relative error ``alpha`` from O(log range)
  integer counts — **no sample retention**, O(1) amortised per observe, and
  two sketches over the same ``alpha`` merge by bucket-count addition, which
  makes merge associative and commutative *by construction* (tested under
  hypothesis in ``tests/test_obs.py``).
* ``MetricsRegistry`` — get-or-create by name, deterministic ``snapshot()``
  (sorted keys, plain python scalars — snapshot equality is run equality),
  and Prometheus-style text exposition for scraping / eyeballing.
* ``NullRegistry`` / ``NULL_REGISTRY`` — the no-op twin.  Instrumented code
  takes a registry object and calls it unconditionally; handing it the null
  twin makes the whole layer a few empty method calls (the ``obs_overhead``
  bench row gates this at <= 5% of the serve path).

Everything here is host-side python over python ints/floats: none of it is
traced, none of it appears inside jitted code.
"""
from __future__ import annotations

import json
import math


class Counter:
    """Monotone event count."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0

    def inc(self, n: int = 1) -> None:
        self._value += n

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Level sample with a high-water mark (peak value ever set)."""

    __slots__ = ("name", "_value", "_hwm")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._hwm = 0.0

    def set(self, v: float) -> None:
        v = float(v)
        self._value = v
        if v > self._hwm:
            self._hwm = v

    @property
    def value(self) -> float:
        return self._value

    @property
    def hwm(self) -> float:
        return self._hwm


class HistogramSketch:
    """Log-bucketed streaming quantile sketch (DDSketch scheme).

    ``observe(x)`` increments the count of bucket ``ceil(log_gamma(x))``;
    non-positive values land in a dedicated zero bucket.  ``quantile(q)``
    walks the cumulative counts and returns the bucket midpoint
    ``2·gamma^i / (gamma+1)``, whose relative error against any value in
    the bucket is at most ``alpha``.  ``merge`` adds bucket counts —
    exact, order-independent, associative.
    """

    __slots__ = ("name", "alpha", "gamma", "_inv_lg", "buckets",
                 "zero_count", "count", "total", "min", "max")

    def __init__(self, name: str, alpha: float = 0.01):
        if not 0.0 < alpha < 1.0:
            raise ValueError(f"alpha must lie in (0, 1), got {alpha}")
        self.name = name
        self.alpha = alpha
        self.gamma = (1.0 + alpha) / (1.0 - alpha)
        self._inv_lg = 1.0 / math.log(self.gamma)
        self.buckets: dict[int, int] = {}
        self.zero_count = 0
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, x: float, n: int = 1) -> None:
        x = float(x)
        self.count += n
        self.total += x * n
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        if x <= 0.0:
            self.zero_count += n
            return
        i = math.ceil(math.log(x) * self._inv_lg)
        self.buckets[i] = self.buckets.get(i, 0) + n

    def _bucket_value(self, i: int) -> float:
        return 2.0 * self.gamma ** i / (self.gamma + 1.0)

    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1], to relative error ``alpha``."""
        if self.count == 0:
            return 0.0
        q = min(max(q, 0.0), 1.0)
        rank = q * (self.count - 1)       # 0-indexed rank to reach
        if rank < self.zero_count:
            return 0.0
        cum = self.zero_count
        for i in sorted(self.buckets):
            cum += self.buckets[i]
            if cum > rank:
                return self._bucket_value(i)
        return self._bucket_value(max(self.buckets))

    def quantiles(self, qs=(0.5, 0.99)) -> dict:
        return {f"p{round(q * 100):d}": self.quantile(q) for q in qs}

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def merge(self, other: "HistogramSketch") -> "HistogramSketch":
        """Return a new sketch holding both streams (same ``alpha``)."""
        if abs(other.alpha - self.alpha) > 1e-12:
            raise ValueError(f"cannot merge sketches with alpha="
                             f"{self.alpha} and {other.alpha}")
        out = HistogramSketch(self.name, self.alpha)
        out.buckets = dict(self.buckets)
        for i, n in other.buckets.items():
            out.buckets[i] = out.buckets.get(i, 0) + n
        out.zero_count = self.zero_count + other.zero_count
        out.count = self.count + other.count
        out.total = self.total + other.total
        out.min = min(self.min, other.min)
        out.max = max(self.max, other.max)
        return out

    def snapshot(self) -> dict:
        return {"alpha": self.alpha, "count": self.count,
                "zero_count": self.zero_count, "total": self.total,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "buckets": {str(i): n
                            for i, n in sorted(self.buckets.items())}}

    @classmethod
    def from_snapshot(cls, name: str, snap: dict) -> "HistogramSketch":
        h = cls(name, snap["alpha"])
        h.count = int(snap["count"])
        h.zero_count = int(snap["zero_count"])
        h.total = float(snap["total"])
        h.min = float(snap["min"]) if h.count else math.inf
        h.max = float(snap["max"]) if h.count else -math.inf
        h.buckets = {int(i): int(n) for i, n in snap["buckets"].items()}
        return h


class MetricsRegistry:
    """Named get-or-create metric store with deterministic snapshots."""

    enabled = True

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, HistogramSketch] = {}

    # ------------------------------------------------------ get-or-create
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, alpha: float = 0.01) -> HistogramSketch:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = HistogramSketch(name, alpha)
        return h

    # ----------------------------------------------------------- readouts
    def counters(self) -> dict:
        return {n: c.value for n, c in sorted(self._counters.items())}

    def snapshot(self) -> dict:
        """Plain-scalar nested dict, keys sorted: two runs produced the
        same snapshot iff they took the same instrumented path."""
        return {
            "counters": self.counters(),
            "gauges": {n: {"value": g.value, "hwm": g.hwm}
                       for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.snapshot()
                           for n, h in sorted(self._hists.items())},
        }

    def snapshot_json(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True)

    def render_prometheus(self) -> str:
        """Prometheus text exposition (counters, gauges + ``_hwm``,
        histograms as summaries with p50/p90/p99 quantile samples)."""
        lines: list[str] = []
        for n, c in sorted(self._counters.items()):
            lines.append(f"# TYPE {n} counter")
            lines.append(f"{n} {c.value}")
        for n, g in sorted(self._gauges.items()):
            lines.append(f"# TYPE {n} gauge")
            lines.append(f"{n} {g.value:g}")
            lines.append(f"{n}_hwm {g.hwm:g}")
        for n, h in sorted(self._hists.items()):
            lines.append(f"# TYPE {n} summary")
            for q in (0.5, 0.9, 0.99):
                lines.append(f'{n}{{quantile="{q:g}"}} '
                             f"{h.quantile(q):g}")
            lines.append(f"{n}_sum {h.total:g}")
            lines.append(f"{n}_count {h.count}")
        return "\n".join(lines) + "\n"


# ------------------------------------------------------------ no-op twins


class _NullCounter:
    __slots__ = ()
    name = ""
    value = 0

    def inc(self, n: int = 1) -> None:
        pass


class _NullGauge:
    __slots__ = ()
    name = ""
    value = 0.0
    hwm = 0.0

    def set(self, v: float) -> None:
        pass


class _NullHistogram:
    __slots__ = ()
    name = ""
    alpha = 0.01
    count = 0
    total = 0.0
    mean = 0.0

    def observe(self, x: float, n: int = 1) -> None:
        pass

    def quantile(self, q: float) -> float:
        return 0.0

    def quantiles(self, qs=(0.5, 0.99)) -> dict:
        return {f"p{round(q * 100):d}": 0.0 for q in qs}

    def snapshot(self) -> dict:
        return {}


_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()
_NULL_HIST = _NullHistogram()


class NullRegistry:
    """The disabled recorder: every lookup returns a shared no-op metric.
    Instrumented code never branches on "is obs on?" — it just records,
    and recording into this registry is a few empty method calls."""

    enabled = False

    def counter(self, name: str) -> _NullCounter:
        return _NULL_COUNTER

    def gauge(self, name: str) -> _NullGauge:
        return _NULL_GAUGE

    def histogram(self, name: str, alpha: float = 0.01) -> _NullHistogram:
        return _NULL_HIST

    def counters(self) -> dict:
        return {}

    def snapshot(self) -> dict:
        return {"counters": {}, "gauges": {}, "histograms": {}}

    def snapshot_json(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True)

    def render_prometheus(self) -> str:
        return "\n"


NULL_REGISTRY = NullRegistry()
