"""SLO monitors that dogfood the paper's own streaming fit stack.

The thesis of the repo is that matricized LSE moments make curve fitting
O(1)-state and streamable (arXiv:1512.08017).  This module turns that
machinery on the serving stack itself: each watched metric (fleet p99
latency, queue depth, staleness lag, ...) feeds a decayed ``StreamState``
polynomial fit of metric-vs-tick — exactly the ``train.monitors``
LossCurveMonitor pattern — and the fitted curve answers the two questions
a pager cares about *online*:

* **is the trend regressing?** — the fitted slope at the current tick;
* **when does it breach?** — ``breach_eta`` extrapolates the fitted curve
  forward and returns the first tick at which it crosses the SLO
  threshold (coarse scan + fine refinement, same scheme as
  ``LossCurveMonitor.eta_to``), i.e. a forecast *before* the raw metric
  itself crosses.

``SLOBoard`` wires monitors to a ``MetricsRegistry``: a metric reference
is ``"latency_ticks:p99"`` (histogram quantile), ``"queue_depth"`` /
``"queue_depth:hwm"`` (gauge), or a counter name; ``update(tick)``
resolves each reference against the live registry and folds one
observation per monitor.  All fits run on tiny (degree+1)² moment states
— the observability layer costs what one more fit costs.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import streaming


@dataclasses.dataclass
class SLOMonitor:
    """One metric's trend: a decayed moment-space polynomial fit of
    (tick, value), plus threshold crossing forecast."""

    metric: str
    threshold: float
    degree: int = 1
    decay: float = 0.98
    ridge: float = 1e-6
    horizon: int = 4096            # ticks searched for a breach crossing
    tick_scale: float = 256.0      # ticks scaled to keep Gram conditioned

    def __post_init__(self):
        self._state = streaming.StreamState.create(
            self.degree, decay=self.decay, dtype=jnp.float32)
        self._n = 0
        self.last_value: float | None = None
        self.last_tick: int = -1

    def observe(self, tick: int, value: float) -> None:
        x = jnp.asarray([tick / self.tick_scale], jnp.float32)
        y = jnp.asarray([float(value)], jnp.float32)
        self._state = streaming.update(self._state, x, y)
        self._n += 1
        self.last_value = float(value)
        self.last_tick = int(tick)

    @property
    def ready(self) -> bool:
        return self._n >= self.degree + 2

    def _coeffs(self) -> np.ndarray:
        poly = streaming.current_fit(self._state, ridge=self.ridge)
        return np.asarray(poly.coeffs, np.float64)

    def level(self, tick: int) -> float:
        """Fitted metric level at ``tick`` (denoised current value)."""
        c = self._coeffs()
        t = tick / self.tick_scale
        return float(np.polyval(c[::-1], t))

    def slope(self, tick: int) -> float:
        """d(metric)/d(tick) of the fitted trend at ``tick``."""
        c = self._coeffs()
        t = tick / self.tick_scale
        ks = np.arange(1, len(c))
        return float(np.sum(ks * c[1:] * t ** (ks - 1)) / self.tick_scale)

    def breach_eta(self, tick: int) -> int | None:
        """Ticks until the fitted curve crosses ``threshold`` (0 if the
        fitted level is already past it; None if no crossing within
        ``horizon`` ticks).  Coarse scan + fine refinement inside the
        first crossing bucket — robust for any fit degree."""
        if not self.ready:
            return None
        c = self._coeffs()

        def first_hit(lo: float, hi: float, n: int) -> float | None:
            ts = np.linspace(lo, hi, n)
            vals = np.polyval(c[::-1], ts / self.tick_scale)
            hit = np.nonzero(vals >= self.threshold)[0]
            return float(ts[hit[0]]) if hit.size else None

        coarse = first_hit(tick, tick + self.horizon, 1024)
        if coarse is None:
            return None
        bucket = max(1.0, self.horizon / 1024)
        fine = first_hit(max(tick, coarse - bucket), coarse, 64)
        at = fine if fine is not None else coarse
        return max(0, int(round(at - tick)))

    def report(self, tick: int) -> dict:
        eta = self.breach_eta(tick) if self.ready else None
        return {
            "metric": self.metric,
            "threshold": self.threshold,
            "value": self.last_value,
            "fitted": self.level(tick) if self.ready else None,
            "slope": self.slope(tick) if self.ready else None,
            "breach_eta_ticks": eta,
            "breached": bool(self.last_value is not None
                             and self.last_value >= self.threshold),
            "observations": self._n,
        }


def resolve_metric(registry, ref: str) -> float | None:
    """Resolve a metric reference against a ``MetricsRegistry``.

    ``"name:pNN"`` — histogram quantile (None while the sketch is empty);
    ``"name:hwm"`` — gauge high-water mark; ``"name:mean"`` — histogram
    mean; bare ``"name"`` — gauge value if one exists under that name,
    else counter value."""
    if ":" in ref:
        base, stat = ref.rsplit(":", 1)
        if stat == "hwm":
            return float(registry.gauge(base).hwm)
        h = registry.histogram(base)
        if h.count == 0:
            return None
        if stat == "mean":
            return float(h.mean)
        if stat.startswith("p"):
            return float(h.quantile(int(stat[1:]) / 100.0))
        raise ValueError(f"unknown metric stat {stat!r} in {ref!r}")
    gauges = getattr(registry, "_gauges", {})
    if ref in gauges:
        return float(gauges[ref].value)
    return float(registry.counter(ref).value)


class SLOBoard:
    """A set of SLO monitors fed from one live metrics registry."""

    def __init__(self, registry):
        self.registry = registry
        self.monitors: dict[str, SLOMonitor] = {}

    def watch(self, ref: str, threshold: float, **kw) -> SLOMonitor:
        mon = SLOMonitor(metric=ref, threshold=threshold, **kw)
        self.monitors[ref] = mon
        return mon

    def update(self, tick: int) -> None:
        """Fold one observation per monitor from the live registry
        (metrics with no data yet are skipped, not zero-filled)."""
        for ref, mon in self.monitors.items():
            v = resolve_metric(self.registry, ref)
            if v is not None:
                mon.observe(tick, v)

    def report(self, tick: int) -> dict:
        return {ref: mon.report(tick)
                for ref, mon in sorted(self.monitors.items())}

    def breaching(self, tick: int, within: int) -> list[str]:
        """Metric refs whose forecast crossing lands within ``within``
        ticks (includes already-breached monitors at eta 0)."""
        out = []
        for ref, mon in sorted(self.monitors.items()):
            eta = mon.breach_eta(tick)
            if eta is not None and eta <= within:
                out.append(ref)
        return out


class NullBoard:
    """Disabled twin for the off-path."""

    monitors: dict = {}

    def watch(self, ref, threshold, **kw):
        return None

    def update(self, tick) -> None:
        pass

    def report(self, tick) -> dict:
        return {}

    def breaching(self, tick, within) -> list:
        return []


NULL_BOARD = NullBoard()
