"""Runtime observability for every fit surface: metrics, traces, SLOs.

``Observability`` bundles the three recorders the serving stack takes as
one injectable handle.  ``Observability.off()`` (the default everywhere)
is the no-op twin — instrumented code records unconditionally and the
null recorders make that a few empty method calls, which the
``obs_overhead`` perf-gate row holds to <= 5% of the serve path.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.obs.metrics import (Counter, Gauge, HistogramSketch,
                               MetricsRegistry, NullRegistry, NULL_REGISTRY)
from repro.obs.trace import (Tracer, NullTracer, NULL_TRACER, FLEET_UID,
                             parse_jsonl, validate_events, assert_valid)
from repro.obs.slo import (SLOMonitor, SLOBoard, NullBoard, NULL_BOARD,
                           resolve_metric)


@dataclasses.dataclass
class Observability:
    """One injectable handle: metrics registry + tracer + SLO board."""

    metrics: Any = dataclasses.field(default_factory=MetricsRegistry)
    tracer: Any = NULL_TRACER
    slo: Any = NULL_BOARD
    enabled: bool = True

    @staticmethod
    def on(*, trace: bool = True) -> "Observability":
        reg = MetricsRegistry()
        return Observability(metrics=reg,
                             tracer=Tracer() if trace else NULL_TRACER,
                             slo=SLOBoard(reg), enabled=True)

    @staticmethod
    def off() -> "Observability":
        return NULL_OBS


NULL_OBS = Observability(metrics=NULL_REGISTRY, tracer=NULL_TRACER,
                         slo=NULL_BOARD, enabled=False)

__all__ = [
    "Observability", "NULL_OBS",
    "Counter", "Gauge", "HistogramSketch", "MetricsRegistry",
    "NullRegistry", "NULL_REGISTRY",
    "Tracer", "NullTracer", "NULL_TRACER", "FLEET_UID",
    "parse_jsonl", "validate_events", "assert_valid",
    "SLOMonitor", "SLOBoard", "NullBoard", "NULL_BOARD", "resolve_metric",
]
