from repro.train.optimizer import AdamWConfig, init_state, apply_updates, schedule
from repro.train.train_step import (TrainConfig, make_train_step,
                                    make_eval_step, init_train_state,
                                    abstract_train_state, train_state_specs,
                                    cross_entropy)
from repro.train.monitors import LossCurveMonitor, StepTimeMonitor

__all__ = ["AdamWConfig", "TrainConfig", "make_train_step", "make_eval_step",
           "init_train_state", "abstract_train_state", "train_state_specs",
           "cross_entropy", "LossCurveMonitor", "StepTimeMonitor",
           "init_state", "apply_updates", "schedule"]
