"""Train-step builder: loss, mixed precision, microbatch gradient
accumulation, MoE aux-loss, z-loss — one jitted function per (model, shape).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.registry import ModelAPI
from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: opt_lib.AdamWConfig = opt_lib.AdamWConfig()
    microbatches: int = 1          # grad accumulation splits of the batch
    z_loss: float = 1e-4
    aux_loss_weight: float = 1e-2  # MoE load-balance loss


def cross_entropy(logits, labels, loss_mask):
    """logits (B,S,V) any float dtype; labels (B,S) int32; mask (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = loss_mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom, jnp.sum(lse * lse * mask) / denom


def _loss_fn(model: ModelAPI, tc: TrainConfig, params, batch):
    compute = jnp.dtype(model.cfg.compute_dtype)
    cparams = jax.tree.map(
        lambda a: a.astype(compute)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, params)
    logits, aux = model.forward_train(cparams, batch)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    ce, zsq = cross_entropy(logits, labels, mask)
    loss = ce + tc.z_loss * zsq + tc.aux_loss_weight * aux
    return loss, {"ce": ce, "aux": aux, "z": zsq}


def init_train_state(model: ModelAPI, rng):
    params = model.init_params(rng, dtype=jnp.dtype(model.cfg.param_dtype))
    return {"params": params, "opt": opt_lib.init_state(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(model: ModelAPI):
    return jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0)))


def train_state_specs(model: ModelAPI):
    """Logical-axis tree matching the train-state structure."""
    pspecs = model.param_specs()
    return {"params": pspecs,
            "opt": {"mu": pspecs, "nu": pspecs, "count": ()},
            "step": ()}


def make_train_step(model: ModelAPI, tc: TrainConfig):
    """Returns fn(state, batch) -> (state, metrics). jit-ready (donate state).

    microbatches > 1 scans over batch splits, accumulating f32 grads —
    the standard large-batch/low-HBM trade (see EXPERIMENTS.md §Perf).
    """

    def grads_of(params, batch):
        (loss, m), grads = jax.value_and_grad(
            lambda p: _loss_fn(model, tc, p, batch), has_aux=True)(params)
        return loss, m, grads

    def step(state, batch):
        params = state["params"]
        if tc.microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape((tc.microbatches, b // tc.microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def body(carry, micro):
                acc, loss_acc = carry
                loss, _, g = grads_of(params, micro)
                acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), acc, g)
                return (acc, loss_acc + loss), None

            from repro.models import common as cm
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), _ = cm.scan(
                body, (zero, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / tc.microbatches, gsum)
            loss = loss_sum / tc.microbatches
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)
        new_params, new_opt, om = opt_lib.apply_updates(
            tc.optimizer, params, grads, state["opt"])
        out = {"loss": loss, **metrics, **om}
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, out)

    return step


def make_eval_step(model: ModelAPI, tc: TrainConfig):
    def step(params, batch):
        loss, m = _loss_fn(model, tc, params, batch)
        return {"loss": loss, **m}
    return step
