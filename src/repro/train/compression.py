"""Gradient compression for cross-pod (DCN) data parallelism.

int8 block-quantized all-reduce with error feedback (1-bit-Adam-family trick,
arXiv:1812.07478 lineage): each DP rank quantizes its local gradient shard to
int8 with a per-block f32 scale, all-reduces (sum) the int8 payload in f32,
and keeps the quantization residual locally, adding it back into the next
step's gradient — unbiased over time, 4× less DCN traffic than f32.

Used inside ``shard_map`` over the ("pod",) axis (cross-pod sync is the
expensive hop; intra-pod reduction stays full-precision). The pure functions
here are mesh-agnostic and property-tested in tests/test_compression.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    flat = jnp.concatenate([x.reshape(-1), jnp.zeros((pad,), x.dtype)])
    return flat.reshape(-1, BLOCK), n


def quantize(x: jax.Array):
    """x (any shape, float) -> (q int8 (nblk, BLOCK), scale f32 (nblk, 1))."""
    blocks, n = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, shape, dtype=jnp.float32):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compress_residual(x: jax.Array, residual: jax.Array):
    """Error-feedback step: quantize (x + residual), return the payload and
    the new residual = (x + residual) - dequant(payload)."""
    target = x.astype(jnp.float32) + residual
    q, scale = quantize(target)
    deq = dequantize(q, scale, x.shape)
    return (q, scale), target - deq


def allreduce_compressed(x: jax.Array, residual: jax.Array, axis_name: str):
    """Inside shard_map: error-feedback int8 all-reduce-mean over axis_name.

    The int8 payload is summed in f32 (TPU all-reduces don't sum int8
    natively; the wire format is int8 + per-block scale, modeled here by
    psumming the dequantized blocks — bytes-on-wire accounting uses the int8
    payload size, see launch/roofline.py).
    """
    (q, scale), new_residual = compress_residual(x, residual)
    contrib = dequantize(q, scale, x.shape)
    n = jax.lax.psum(1, axis_name)
    total = jax.lax.psum(contrib, axis_name)
    return total / n, new_residual


def init_residuals(tree):
    return jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), tree)


def tree_allreduce_compressed(grads, residuals, axis_name: str):
    out = jax.tree.map(
        lambda g, r: allreduce_compressed(g, r, axis_name), grads, residuals)
    new_g = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_r
