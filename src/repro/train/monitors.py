"""Training monitors built on the paper's streaming matricized LSE core —
the technique as a first-class framework feature (DESIGN.md §3).

LossCurveMonitor: O(1)-state polynomial fit of loss-vs-step. Because the
paper's moments are additive, each `observe` folds one point into the running
Gram/moment statistics; divergence detection reads the fitted slope, and
`eta_to(target)` extrapolates. An exponential-forgetting window tracks the
recent trend exactly (γ-weighted least squares).

StepTimeMonitor: per-host step-time series fitted with degree-1 LSE; hosts
whose fitted level exceeds the fleet median fit by `threshold`× are flagged
as stragglers (see repro.runtime.straggler for the mitigation hooks).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fit as fit_lib
from repro.core import streaming


@dataclasses.dataclass
class LossCurveMonitor:
    degree: int = 2
    decay: float = 0.995          # exponential forgetting per observation
    ridge: float = 1e-6

    def __post_init__(self):
        self._state = streaming.StreamState.create(
            self.degree, decay=self.decay, dtype=jnp.float32)
        self._n = 0
        self._x_scale = 1000.0     # steps scaled to keep Gram conditioned

    def observe(self, step: int, loss: float) -> None:
        x = jnp.asarray([step / self._x_scale], jnp.float32)
        y = jnp.asarray([loss], jnp.float32)
        self._state = streaming.update(self._state, x, y)
        self._n += 1

    @property
    def ready(self) -> bool:
        return self._n >= self.degree + 2

    def fit(self) -> fit_lib.Polynomial:
        return streaming.current_fit(self._state, ridge=self.ridge)

    def slope_at(self, step: int) -> float:
        """d(loss)/d(step) of the fitted curve at `step`."""
        poly = self.fit()
        c = np.asarray(poly.coeffs, np.float64)
        t = step / self._x_scale
        ks = np.arange(1, len(c))
        return float(np.sum(ks * c[1:] * t ** (ks - 1)) / self._x_scale)

    def predict(self, step: int) -> float:
        return float(self.fit()(jnp.asarray(step / self._x_scale,
                                            jnp.float32)))

    def diverging(self, step: int, patience_slope: float = 0.0) -> bool:
        """True when the recent fitted trend slopes upward."""
        return self.ready and self.slope_at(step) > patience_slope

    def eta_to(self, target_loss: float, step: int,
               horizon: int = 10_000_000) -> int | None:
        """Steps until the fitted curve reaches target_loss (None if never
        within horizon). Coarse scan of the extrapolated curve (robust for
        any degree) + fine refinement inside the first crossing bucket."""
        if not self.ready:
            return None
        poly = self.fit()

        def first_hit(lo: int, hi: int, n: int) -> int | None:
            steps = np.linspace(lo, hi, n)
            vals = np.asarray(poly(jnp.asarray(steps / self._x_scale,
                                               jnp.float32)))
            hit = np.nonzero(vals <= target_loss)[0]
            return int(steps[hit[0]]) if hit.size else None

        coarse = first_hit(step, step + horizon, 4096)
        if coarse is None:
            return None
        bucket = max(1, horizon // 4096)
        fine = first_hit(max(step, coarse - bucket), coarse + 1,
                         min(4096, 2 * bucket + 2))
        return (fine if fine is not None else coarse) - step


@dataclasses.dataclass
class StepTimeMonitor:
    """Fleet-wide straggler detection from per-host step times.

    Keeps one streaming degree-1 fit per host (batched Moments — the paper's
    matricization makes the per-host fits one vmapped solve)."""
    n_hosts: int
    decay: float = 0.98
    threshold: float = 1.25       # fitted level vs fleet median

    def __post_init__(self):
        self._state = streaming.StreamState.create(
            1, batch=(self.n_hosts,), decay=self.decay, dtype=jnp.float32)
        self._n = 0

    def observe(self, step: int, times_s) -> None:
        x = jnp.full((self.n_hosts, 1), step / 1000.0, jnp.float32)
        y = jnp.asarray(times_s, jnp.float32)[:, None]
        self._state = streaming.update(self._state, x, y)
        self._n += 1

    def fitted_levels(self, step: int) -> np.ndarray:
        poly = streaming.current_fit(self._state, ridge=1e-6)
        t = jnp.full((self.n_hosts,), step / 1000.0, jnp.float32)
        # evaluate per-host fits at the current step
        c = poly.coeffs            # (hosts, 2)
        return np.asarray(c[:, 0] + c[:, 1] * t, np.float64)

    def stragglers(self, step: int) -> list[int]:
        if self._n < 3:
            return []
        lv = self.fitted_levels(step)
        med = np.median(lv)
        return [int(i) for i in np.nonzero(lv > self.threshold * med)[0]]
