"""AdamW optimizer in pure JAX (no optax dependency), with:

  * f32 master params + bf16 compute casting handled by the train step
  * optimizer state trees inherit the params' NamedShardings → with the FSDP
    ("embed"→data) rules this IS ZeRO-3: params, grads and both moments are
    fully sharded; ZeRO-1 falls out on meshes without an FSDP axis
  * global-norm clipping, decoupled weight decay, cosine/linear schedules
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio·peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_state(params):
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"mu": zeros(params), "nu": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = schedule(cfg, count)
    c = count.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** c
    bc2 = 1 - cfg.b2 ** c

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        step = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p
        return p - lr * step, mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(opt_state["mu"])
    flat_nu = tdef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}, metrics
