"""Fault-tolerant fit fleet: replicated workers behind one dispatcher.

``FitServeEngine`` next door is one synchronous process: a worker death
loses every in-flight series, one straggler stalls the batch loop, and
overload has nowhere to push back.  This module is the layer that makes
the ROADMAP's "millions of users" survivable:

* ``FleetWorker`` — a replicated fit worker speaking a mailbox protocol
  (``Ingest`` / ``Restore`` / ``Solve`` / ``Cancel`` in, ``Ack`` /
  ``Result`` out).  Each in-flight request is one spec-carrying
  ``StreamState``; the solve side reuses the *same* compiled
  ``make_spec_solve`` / ``make_spec_sweep`` executables as the
  single-process engine, so a fleet answer is the engine's answer.
* ``FitFleet`` — the dispatcher: routes requests to the least-loaded
  live worker, detects death by missed heartbeats
  (``runtime.fault_tolerance.FailureDetector``), retries silently
  dropped chunks, hedges requests stuck on fitted-step-time-verdicted
  stragglers (the paper's own LSE doing fleet introspection), restarts
  crashed workers under a jittered ``RestartPolicy``, and validates
  every reply — a poisoned (non-finite) result quarantines its worker
  and is re-solved elsewhere instead of reaching the caller.
* the **moment journal** — because ``Moments`` is additive and O(m²),
  each chunk ack carries a snapshot of the request's accumulated state
  (``StreamState.snapshot``, a few hundred bytes).  A worker death
  mid-ingest replays from the last snapshot on a survivor instead of
  re-reading the data, and idempotent (request-key, chunk-seq) delivery
  means a retried chunk is acked, never re-accumulated: replay cannot
  double-count, so a faulted run returns bit-identical coefficients to
  a fault-free one (the chaos parity invariant, tested).
* **graceful degradation** — a bounded admission queue sheds beyond
  ``max_queue``, but first (beyond ``degrade_watermark``) DegreeSearch
  requests are downgraded to fixed-degree fits — cheaper to serve, and
  the downgrade is surfaced in the result metadata (``req.degraded``)
  rather than silently applied.

Time is an injected virtual tick clock — the scheduling loop never
sleeps — so every recovery path above is exercised deterministically by
``runtime.chaos`` fault schedules.  The asynchronous-LSPIA result
(arXiv:2211.06556) is why this is safe for the *fit itself*: moment
accumulation tolerates reordered and partial contributions, so the only
invariant the dispatcher must police is exactly-once accumulation — the
journal's job.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.api.spec import ServicePolicy
from repro.core import streaming
from repro.obs import metrics as obs_metrics
from repro.obs import slo as slo_lib
from repro.obs import trace as trace_lib
from repro.runtime import chaos as chaos_lib
from repro.runtime.fault_tolerance import FailureDetector, RestartPolicy
from repro.serve import fit_engine as fe

# every fleet counter, predefined so ``stats`` always exposes the full
# vocabulary (a zero count is an assertable fact, not a missing key)
_STAT_KEYS = (
    "completed", "shed", "degraded", "failed", "replays", "hedges",
    "hedge_wins", "hedge_losses", "resends", "retries_timeout",
    "retries_invalid", "poisoned", "worker_deaths", "revivals",
    "async_harvests", "async_updates",
)

# ----------------------------------------------------------------- protocol


class ProtocolError(RuntimeError):
    """A message whose ``kind`` no dispatcher branch claims.  The mailbox
    vocabulary is closed-world — ``repro.analysis``'s RL-PROTOCOL checker
    verifies every constructed kind has a handler and every dispatcher
    raises this instead of silently dropping (a dropped *reply* is
    unrecoverable: no timeout fires on it)."""

    def __init__(self, where: str, kind):
        self.where = where
        self.kind = kind
        super().__init__(f"{where}: unknown message kind {kind!r}")


@dataclasses.dataclass
class Ingest:
    """Chunk ``seq`` (1-based) of request ``key``; ``w`` masks padding."""
    key: int
    seq: int
    x: np.ndarray
    y: np.ndarray
    w: np.ndarray
    spec: Any
    want_snapshot: bool = True
    kind: str = "ingest"


@dataclasses.dataclass
class Restore:
    """Reset request ``key`` to the journaled state after chunk ``seq``."""
    key: int
    seq: int
    snapshot: dict | None
    spec: Any
    kind: str = "restore"


@dataclasses.dataclass
class Solve:
    key: int
    spec: Any
    kind: str = "solve"


@dataclasses.dataclass
class Cancel:
    key: int
    kind: str = "cancel"


@dataclasses.dataclass
class Ack:
    """Worker's applied watermark for ``key`` (idempotence: a duplicate or
    out-of-window chunk is acked at the current watermark, never
    re-accumulated)."""
    key: int
    seq: int
    snapshot: dict | None
    worker: int
    kind: str = "ack"


@dataclasses.dataclass
class Result:
    key: int
    worker: int
    fixed: tuple | None = None   # make_spec_solve outputs (numpy)
    auto: dict | None = None     # auto_outputs dict
    kind: str = "result"

    def poisoned(self) -> "Result":
        """The chaos injector's silent-corruption fault: same reply shape,
        NaN coefficients."""
        msg = dataclasses.replace(self)
        if msg.fixed is not None:
            c = np.full_like(np.asarray(msg.fixed[0]), np.nan)
            msg.fixed = (c,) + tuple(msg.fixed[1:])
        if msg.auto is not None:
            outs = dict(msg.auto)
            outs["ladder"] = np.full_like(outs["ladder"], np.nan)
            msg.auto = outs
        return msg


# ------------------------------------------------------------------ request


@dataclasses.dataclass
class FleetRequest(fe.FitRequest):
    """A ``FitRequest`` plus the fleet's service metadata: every recovery
    or degradation action taken on this request's behalf is surfaced."""

    service: ServicePolicy = ServicePolicy()
    degraded: str | None = None    # e.g. "degree_search->fixed"
    shed: bool = False             # rejected at admission (queue bound)
    failed: str | None = None      # terminal error ("deadline", ...)
    retries: int = 0               # resends + invalid-result retries
    replays: int = 0               # journal replays onto another worker
    hedged: bool = False           # duplicate-dispatched for a straggler
    admit_tick: int = -1
    done_tick: int = -1
    workers: list[int] = dataclasses.field(default_factory=list)

    @property
    def latency_ticks(self) -> int:
        return self.done_tick - self.admit_tick


@dataclasses.dataclass
class AsyncFitHandle:
    """Parent handle for one sharded async-LSPIA submission
    (``FitFleet.submit_async_lspia``).

    Each shard is an ordinary child ``FleetRequest`` riding the existing
    journal machinery (per-shard chunk sequence numbers, idempotent
    delivery, snapshot replay); the dispatcher harvests a shard's final
    journal snapshot the moment its ingest completes — no ``Solve``
    round-trip — and re-solves the merged moment state with moment-space
    LSPIA after EVERY harvest.  ``coeffs`` therefore progresses while a
    chaos-stalled shard's contribution is still missing
    (``updates_while_partial`` counts those partial re-solves); ``done``
    only once every shard has landed, so the final answer is exact."""

    uid: int
    spec: Any
    n_shards: int
    shard_uids: list[int] = dataclasses.field(default_factory=list)
    harvested: int = 0
    updates: int = 0
    updates_while_partial: int = 0
    coeffs: np.ndarray | None = None
    sse: float | None = None
    r: float | None = None
    count: float | None = None
    condition: float | None = None
    converged: bool = False
    failed: str | None = None
    done: bool = False
    done_tick: int = -1


# ------------------------------------------------------------------- worker


class FleetWorker:
    """One replicated fit worker: per-request spec-carrying stream states
    plus the pool's shared compiled solve/sweep.

    Stateless between requests except for the states it is explicitly
    ingesting — ``reset()`` (crash, restart) drops everything, which is
    safe because the dispatcher's journal owns durability."""

    def __init__(self, worker_id: int, pool_specs: fe.PoolSpecs,
                 dtype, solve, sweep):
        self.worker_id = worker_id
        self.pool = pool_specs.pool
        self.dtype = dtype
        self._solve = solve
        self._sweep = sweep
        self.states: dict[int, streaming.StreamState] = {}
        self.applied: dict[int, int] = {}
        self.snaps: dict[int, dict | None] = {}
        self.processed = 0

    def reset(self) -> None:
        self.states.clear()
        self.applied.clear()
        self.snaps.clear()

    def _accum_spec(self, rspec):
        """The spec the request's state accumulates under: the request's
        own method/basis/numerics at the POOL degree, so nested degrees
        and DegreeSearch ladders are truncate views — exactly the
        single-process engine's accumulation contract."""
        if rspec.max_degree == self.pool.max_degree \
                and not rspec.is_search:
            return rspec
        return dataclasses.replace(rspec, degree=self.pool.max_degree)

    def process(self, msg, tick: int) -> list:
        self.processed += 1
        key = msg.key
        if msg.kind == "ingest":
            applied = self.applied.get(key, 0)
            if msg.seq != applied + 1:
                # duplicate (<= applied) or out-of-window: ack the
                # watermark, touch nothing — the idempotence that makes
                # journal replay and retry racing safe
                return [Ack(key, applied, self.snaps.get(key),
                            self.worker_id)]
            st = self.states.get(key)
            if st is None:
                st = streaming.StreamState.create(
                    self.pool.max_degree, (), decay=self.pool.decay,
                    dtype=self.dtype, spec=self._accum_spec(msg.spec))
            st = streaming.update(st, jnp.asarray(msg.x),
                                  jnp.asarray(msg.y),
                                  weights=jnp.asarray(msg.w))
            self.states[key] = st
            self.applied[key] = msg.seq
            snap = st.snapshot() if msg.want_snapshot else None
            if snap is not None:
                self.snaps[key] = snap
            return [Ack(key, msg.seq, snap, self.worker_id)]
        if msg.kind == "restore":
            if msg.seq == 0 or msg.snapshot is None:
                st = streaming.StreamState.create(
                    self.pool.max_degree, (), decay=self.pool.decay,
                    dtype=self.dtype, spec=self._accum_spec(msg.spec))
                self.snaps[key] = None
            else:
                st = streaming.StreamState.restore(
                    msg.snapshot, spec=self._accum_spec(msg.spec))
                self.snaps[key] = msg.snapshot
            self.states[key] = st
            self.applied[key] = msg.seq
            return [Ack(key, msg.seq, self.snaps.get(key), self.worker_id)]
        if msg.kind == "solve":
            st = self.states.get(key)
            if st is None:
                # state lost (restarted worker got a stale solve): stay
                # silent — the dispatcher's timeout replays from the
                # journal
                return []
            if msg.spec.is_search:
                outs = fe.auto_outputs(*self._sweep(st, msg.spec))
                return [Result(key, self.worker_id, auto=outs)]
            solved = tuple(np.asarray(a)
                           for a in self._solve(st, msg.spec))
            return [Result(key, self.worker_id, fixed=solved)]
        if msg.kind == "cancel":
            self.states.pop(key, None)
            self.applied.pop(key, None)
            self.snaps.pop(key, None)
            return []
        raise ProtocolError(f"worker {self.worker_id}", msg.kind)


# --------------------------------------------------------------- dispatcher


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Dispatcher policy.  ``fit`` supplies the pool spec family (degree,
    basis, solver ladder, decay — same vocabulary as the single-process
    engine); everything else is fleet mechanics in virtual ticks."""

    fit: fe.FitServeConfig = fe.FitServeConfig()
    n_workers: int = 4
    chunk_width: int = 256
    max_inflight: int = 4           # concurrent requests per worker
    max_queue: int = 1024           # admission bound: shed beyond this
    degrade_watermark: int | None = None   # default max_queue // 2:
    # DegreeSearch requests admitted above this backlog run fixed-degree
    service: ServicePolicy = ServicePolicy()
    work_per_tick: int = 2          # mailbox messages per worker per tick
    heartbeat_timeout: float = 4.0  # ticks without a beat = dead
    straggler_every: int = 4        # fitted step-time observation cadence
    straggler_threshold: float = 3.0
    quarantine_ticks: int = 16      # poisoned-reply penalty box
    max_restarts: int = 2           # per-worker revival budget
    restart_backoff: float = 4.0    # base backoff in ticks (jittered)
    max_restart_backoff: float = 32.0
    snapshot_every: int = 1         # journal granularity in chunks
    parallel_pump: bool = False     # pump worker mailboxes in threads
    seed: int = 0                   # restart-jitter determinism
    chaos: chaos_lib.ChaosSchedule | None = None
    trace: bool = False             # record per-request trace spans
    slo_p99: float | None = None    # watch latency_ticks:p99 vs this SLO
    slo_every: int = 8              # SLO observation cadence in ticks

    def __post_init__(self):
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got "
                             f"{self.n_workers}")
        if self.chunk_width < 1 or self.max_inflight < 1 \
                or self.work_per_tick < 1 or self.snapshot_every < 1:
            raise ValueError("chunk_width/max_inflight/work_per_tick/"
                             "snapshot_every must all be >= 1")
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got "
                             f"{self.max_queue}")
        dw = self.degrade_watermark
        if dw is not None and not 0 <= dw <= self.max_queue:
            raise ValueError(f"degrade_watermark={dw} must lie in "
                             f"[0, max_queue={self.max_queue}]")
        if self.slo_every < 1:
            raise ValueError(f"slo_every must be >= 1, got "
                             f"{self.slo_every}")


@dataclasses.dataclass
class _Assignment:
    """One worker's copy of one request (two exist while hedged)."""
    worker: int
    acked: int               # chunks this worker has applied
    last_progress: int       # tick of last forward progress
    resends: int = 0
    solving: bool = False


@dataclasses.dataclass
class _Flight:
    """One admitted request in service: its pre-split chunks, the moment
    journal (highest snapshotted seq + snapshot), and its assignments."""
    req: FleetRequest
    chunks: list[tuple[np.ndarray, np.ndarray, np.ndarray]]
    journal_seq: int = 0
    journal_snap: dict | None = None
    assignments: list[_Assignment] = dataclasses.field(default_factory=list)
    hedge_workers: set[int] = dataclasses.field(default_factory=set)

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)


class FitFleet:
    """The dispatcher: N chaos-wrappable ``FleetWorker``s, one virtual
    clock, and a recovery policy for every fault class the chaos injector
    can throw (see module docstring)."""

    def __init__(self, cfg: FleetConfig | None = None):
        self.cfg = cfg = cfg or FleetConfig()
        self.pool_specs = fe.derive_pool_specs(cfg.fit)
        self.spec = self.pool_specs.pool
        pool_degree = self.spec.max_degree
        self._solve = fe.make_spec_solve(pool_degree)
        self._sweep = fe.make_spec_sweep(pool_degree)
        schedule = cfg.chaos or chaos_lib.ChaosSchedule()
        self.workers = [
            chaos_lib.ChaosWorker(
                FleetWorker(w, self.pool_specs, cfg.fit.dtype,
                            self._solve, self._sweep),
                w, schedule.for_worker(w))
            for w in range(cfg.n_workers)]
        self._inbox: list[list] = [[] for _ in range(cfg.n_workers)]
        self._replies: list[tuple[int, int, Any]] = []   # (due, n, reply)
        self._reply_seq = 0
        self._queue: list[FleetRequest] = []
        self._flights: dict[int, _Flight] = {}
        self._uid = 0
        self.tick = 0
        self.fits_done = 0
        self.points_ingested = 0
        self.detector = FailureDetector(
            cfg.n_workers, timeout_s=cfg.heartbeat_timeout,
            straggler_threshold=cfg.straggler_threshold)
        self._restart = [
            RestartPolicy(max_restarts=cfg.max_restarts,
                          base_backoff_s=cfg.restart_backoff,
                          max_backoff_s=cfg.max_restart_backoff,
                          seed=cfg.seed * 1000 + w)
            for w in range(cfg.n_workers)]
        self._down: set[int] = set()
        self._revive_at: dict[int, int] = {}
        self._quarantined_until = [0] * cfg.n_workers
        self._stragglers: set[int] = set()
        # per-worker service-time model feeding the fitted verdicts
        self._ema = np.ones(cfg.n_workers)
        self._last_reply = np.zeros(cfg.n_workers)
        self._obs_step = 0
        self._pool = None
        if cfg.parallel_pump:
            from concurrent.futures import ThreadPoolExecutor
            self._pool = ThreadPoolExecutor(max_workers=cfg.n_workers)
        # observability: the registry is always live (counter increments
        # cost what the old dict increments cost, and the stats contract
        # below reads from it); the tracer is opt-in via cfg.trace
        self.metrics = obs_metrics.MetricsRegistry()
        self._counters = {k: self.metrics.counter(k) for k in _STAT_KEYS}
        self._lat = self.metrics.histogram("latency_ticks")
        self._queue_depth = self.metrics.gauge("queue_depth")
        self.tracer = (trace_lib.Tracer() if cfg.trace
                       else trace_lib.NULL_TRACER)
        self.slo = slo_lib.SLOBoard(self.metrics)
        if cfg.slo_p99 is not None:
            self.slo.watch("latency_ticks:p99", cfg.slo_p99)
        # sharded async-LSPIA parents: child uid -> (handle, shard index),
        # and the per-parent harvested shard snapshots
        self._async_children: dict[int, tuple[AsyncFitHandle, int]] = {}
        self._async_snaps: dict[int, dict[int, dict]] = {}

    # ------------------------------------------------------------ admission
    @property
    def degrade_watermark(self) -> int:
        dw = self.cfg.degrade_watermark
        return self.cfg.max_queue // 2 if dw is None else dw

    def submit(self, x, y, *, degree: int | str | None = None,
               spec=None, service: ServicePolicy | None = None
               ) -> FleetRequest:
        """Queue one series.  Overload policy at admission: beyond
        ``degrade_watermark`` queued requests, DegreeSearch work is
        downgraded to a fixed-degree fit (surfaced in ``req.degraded``);
        beyond ``max_queue`` the request is shed outright
        (``req.shed``)."""
        rspec = fe.resolve_request_spec(self.pool_specs, degree, spec)
        x, y = fe.validate_series(x, y, rspec)
        req = FleetRequest(self._uid, x, y, spec=rspec,
                           auto=rspec.is_search,
                           service=service or self.cfg.service)
        self._uid += 1
        backlog = len(self._queue)
        self.tracer.instant(req.uid, "submit", self.tick, n=int(req.n),
                            auto=bool(req.auto))
        if backlog >= self.cfg.max_queue:
            req.shed = True
            req.failed = "shed"
            req.done = True
            self._counters["shed"].inc()
            self.tracer.instant(req.uid, "shed", self.tick,
                                backlog=backlog)
            return req
        if backlog >= self.degrade_watermark and rspec.is_search:
            req.spec = dataclasses.replace(rspec,
                                           degree=rspec.max_degree)
            req.auto = False
            req.degraded = "degree_search->fixed"
            self._counters["degraded"].inc()
            self.tracer.instant(req.uid, "degrade", self.tick,
                                what="degree_search->fixed",
                                backlog=backlog)
        self._queue.append(req)
        self.tracer.begin(req.uid, "queue", self.tick)
        self._queue_depth.set(len(self._queue))
        return req

    def submit_async_lspia(self, x, y, *, spec=None,
                           n_shards: int = 2) -> AsyncFitHandle:
        """Queue one series as ``n_shards`` barrier-free shard ingests
        (asynchronous LSPIA, arXiv:2211.06556).

        Each shard is an ordinary child request — its chunks carry the
        journal's per-shard sequence numbers, so retry/replay/idempotence
        all work unchanged — but the dispatcher intercepts the completed
        ingest journal instead of sending a ``Solve``: the shard's final
        moment snapshot is harvested, merged with the other shards'
        (moments are additive), and the merged state is re-solved with
        moment-space LSPIA (momentum included) after every harvest.  A
        chaos-stalled worker therefore delays only its own shard's
        contribution: the handle's ``coeffs`` keep updating from the
        shards already in hand, and the exact answer lands when the
        straggler does.  Requires a ``method="lspia"`` spec (default:
        the pool spec switched to LSPIA) and a non-forgetting pool
        (``decay == 1.0`` — shard chunks interleave arbitrarily)."""
        if self.spec.decay != 1.0:
            raise ValueError(
                "sharded async ingest has no global age order: the pool "
                f"must not decay (decay={self.spec.decay})")
        if spec is None:
            spec = dataclasses.replace(self.pool_specs.fixed,
                                       method="lspia")
        rspec = fe.resolve_request_spec(self.pool_specs, None, spec)
        if rspec.method != "lspia":
            raise ValueError(f"submit_async_lspia needs method='lspia', "
                             f"got {rspec.method!r}")
        if rspec.is_search:
            raise ValueError("async LSPIA serves fixed degrees; use "
                             "degree='auto' on plain submit")
        x, y = fe.validate_series(x, y, rspec)
        if x.shape[0] < n_shards:
            raise ValueError(f"{x.shape[0]} points cannot fill "
                             f"{n_shards} shards")
        handle = AsyncFitHandle(uid=self._uid, spec=rspec,
                                n_shards=n_shards)
        self._uid += 1
        bounds = np.linspace(0, x.shape[0], n_shards + 1).astype(int)
        for s in range(n_shards):
            sl = slice(bounds[s], bounds[s + 1])
            child = self.submit(x[sl], y[sl], spec=rspec)
            handle.shard_uids.append(child.uid)
            if child.shed:
                handle.failed = "shed"
                handle.done = True
                return handle
            self._async_children[child.uid] = (handle, s)
        self._async_snaps[handle.uid] = {}
        return handle

    def warmup(self) -> int:
        """Compile the default executables (ingest update + fixed solve +
        auto sweep) through the full dispatch path; returns
        ``compiled_executables()`` — the no-recompile baseline."""
        if self._queue or self._flights:
            raise RuntimeError("warmup() requires an idle fleet")
        n = max(self.cfg.chunk_width, self.spec.max_degree + 1)
        x = np.linspace(-1.0, 1.0, n, dtype=np.float32)
        self.submit(x, x, spec=self.pool_specs.fixed)
        self.submit(x, x, spec=self.pool_specs.auto)
        self.run()
        return self.compiled_executables()

    def compiled_executables(self) -> int:
        """Solve/sweep executables (shared by ALL workers — replication
        adds zero compilations).  The chunk-ingest executable lives in the
        module-wide ``streaming.update`` cache and is likewise compiled
        once per (spec, chunk width)."""
        return self._solve._cache_size() + self._sweep._cache_size()

    @property
    def pending(self) -> int:
        return len(self._queue) + len(self._flights)

    @property
    def stats(self) -> dict:
        """Event counts, read live from the metrics registry (the old
        ad-hoc dict's contract, now one view over first-class metrics)."""
        return {k: c.value for k, c in self._counters.items()}

    # ------------------------------------------------------------- helpers
    def _split_chunks(self, req: FleetRequest):
        w = self.cfg.chunk_width
        out = []
        for lo in range(0, req.n, w):
            xs = req.x[lo:lo + w]
            m = xs.shape[0]
            xc = np.zeros(w, np.float32)
            yc = np.zeros(w, np.float32)
            wc = np.zeros(w, np.float32)
            xc[:m] = xs
            yc[:m] = req.y[lo:lo + w]
            wc[:m] = 1.0
            out.append((xc, yc, wc))
        return out

    def _alive(self, w: int) -> bool:
        return self.workers[w].alive and w not in self._down

    def _eligible(self, w: int) -> bool:
        return (self._alive(w)
                and self._quarantined_until[w] <= self.tick)

    def _load(self, w: int) -> int:
        return sum(1 for fl in self._flights.values()
                   for a in fl.assignments if a.worker == w)

    def _pick_worker(self, exclude: set[int] = frozenset(),
                     respect_capacity: bool = False) -> int | None:
        cand = [w for w in range(self.cfg.n_workers)
                if self._eligible(w) and w not in exclude]
        healthy = [w for w in cand if w not in self._stragglers]
        cand = healthy or cand
        if respect_capacity:
            cand = [w for w in cand
                    if self._load(w) < self.cfg.max_inflight]
        if not cand:
            return None
        return min(cand, key=lambda w: (self._load(w), w))

    def _send(self, w: int, msg) -> None:
        self._inbox[w].append(msg)

    def _send_next(self, fl: _Flight, asg: _Assignment) -> None:
        """Advance one assignment: next chunk, or the solve."""
        req = fl.req
        if asg.acked >= fl.n_chunks:
            if req.uid in self._async_children:
                # async-LSPIA shard whose journal lags its ack watermark
                # (sparse snapshots): re-ask for the last chunk — the
                # worker's duplicate-ack carries its latest snapshot and
                # never re-accumulates, so the journal catches up
                x, y, w_ = fl.chunks[-1]
                self._send(asg.worker, Ingest(req.uid, fl.n_chunks, x, y,
                                              w_, req.spec,
                                              want_snapshot=True))
                return
            if not asg.solving:
                asg.solving = True
                self.tracer.end(req.uid, "ingest", self.tick)
                self.tracer.begin(req.uid, "solve", self.tick,
                                  worker=asg.worker)
                self._send(asg.worker, Solve(req.uid, req.spec))
            return
        seq = asg.acked + 1
        x, y, w_ = fl.chunks[seq - 1]
        want = (seq % self.cfg.snapshot_every == 0
                or seq == fl.n_chunks)
        self._send(asg.worker, Ingest(req.uid, seq, x, y, w_, req.spec,
                                      want_snapshot=want))

    def _assign(self, fl: _Flight, worker: int) -> None:
        """Start (or restart) the request on ``worker`` from the journal."""
        asg = _Assignment(worker=worker, acked=fl.journal_seq,
                          last_progress=self.tick)
        fl.assignments.append(asg)
        fl.req.workers.append(worker)
        if fl.journal_seq > 0:
            self._send(worker, Restore(fl.req.uid, fl.journal_seq,
                                       fl.journal_snap, fl.req.spec))
        else:
            self._send_next(fl, asg)

    def _drop_assignment(self, fl: _Flight, asg: _Assignment,
                         cancel: bool = True) -> None:
        fl.assignments.remove(asg)
        if cancel and self._alive(asg.worker):
            self._send(asg.worker, Cancel(fl.req.uid))

    def _replay(self, fl: _Flight, exclude: set[int]) -> None:
        """Journal replay: resume the request on a fresh worker from the
        last snapshot — no data re-read, no double accumulation."""
        w = self._pick_worker(exclude)
        if w is None:
            return      # retried next tick (flight has no assignment)
        fl.req.replays += 1
        self._counters["replays"].inc()
        self.tracer.instant(fl.req.uid, "replay", self.tick, worker=w,
                            from_seq=fl.journal_seq)
        self._assign(fl, w)

    def _fail(self, fl: _Flight, reason: str) -> None:
        for asg in list(fl.assignments):
            self._drop_assignment(fl, asg)
        fl.req.failed = reason
        fl.req.done = True
        fl.req.done_tick = self.tick
        self._flights.pop(fl.req.uid)
        self._counters["failed"].inc()
        self.tracer.end(fl.req.uid, "ingest", self.tick)
        self.tracer.end(fl.req.uid, "solve", self.tick)
        self.tracer.instant(fl.req.uid, "failed", self.tick,
                            reason=reason)
        entry = self._async_children.pop(fl.req.uid, None)
        if entry is not None:
            # a lost shard makes the parent's exact answer unreachable:
            # surface the failure, keep the last partial coefficients
            handle, _ = entry
            handle.failed = reason
            handle.done = True
            handle.done_tick = self.tick
            self._async_snaps.pop(handle.uid, None)

    # ------------------------------------------------------------ the loop
    def step(self) -> None:
        """One virtual tick: revive → heartbeat → admit → pump mailboxes →
        handle replies → failure/straggler verdicts → timeouts."""
        cfg = self.cfg
        self.tick += 1
        tick = self.tick
        for w, due in list(self._revive_at.items()):
            if due <= tick:
                del self._revive_at[w]
                self.workers[w].revive()
                self._inbox[w].clear()    # a restarted worker's stale
                # mail targets state it no longer holds
                self._down.discard(w)
                self.detector.hb.beat(w, float(tick))
                self._counters["revivals"].inc()
                self.tracer.instant(trace_lib.FLEET_UID, "revival", tick,
                                    worker=w)
        for w, wk in enumerate(self.workers):
            wk.begin_tick(tick)
            if wk.alive:
                self.detector.hb.beat(w, float(tick))
        # admit queued requests onto workers with capacity
        while self._queue:
            w = self._pick_worker(respect_capacity=True)
            if w is None:
                break
            req = self._queue.pop(0)
            req.admit_tick = tick
            self.tracer.end(req.uid, "queue", tick)
            self.tracer.instant(req.uid, "admit", tick, worker=w)
            self.tracer.begin(req.uid, "ingest", tick, worker=w)
            fl = _Flight(req=req, chunks=self._split_chunks(req))
            self._flights[req.uid] = fl
            self._assign(fl, w)
        self._queue_depth.set(len(self._queue))
        self._pump(tick)
        self._handle_replies(tick)
        self._verdicts(tick)
        self._timeouts(tick)
        if self.slo.monitors and tick % cfg.slo_every == 0:
            self.slo.update(tick)

    def _pump_one(self, w: int, tick: int) -> list[tuple[int, Any]]:
        wk = self.workers[w]
        if not wk.alive or wk.stalled(tick):
            return []
        out = []
        for _ in range(self.cfg.work_per_tick):
            if not self._inbox[w]:
                break
            msg = self._inbox[w].pop(0)
            out.extend(wk.process(msg, tick))
        return out

    def _pump(self, tick: int) -> None:
        """Drain up to ``work_per_tick`` messages per worker.  With
        ``parallel_pump`` the workers run in threads behind a per-tick
        barrier — real thread parallelism, same deterministic reply order
        (replies are merged in worker-id order)."""
        if self._pool is not None:
            batches = list(self._pool.map(
                lambda w: self._pump_one(w, tick),
                range(self.cfg.n_workers)))
        else:
            batches = [self._pump_one(w, tick)
                       for w in range(self.cfg.n_workers)]
        for batch in batches:
            for delay, rep in batch:
                heapq.heappush(self._replies,
                               (tick + delay, self._reply_seq, rep))
                self._reply_seq += 1

    def _handle_replies(self, tick: int) -> None:
        while self._replies and self._replies[0][0] <= tick:
            _, _, rep = heapq.heappop(self._replies)
            w = rep.worker
            if self._last_reply[w] > 0:
                gap = max(1.0, tick - self._last_reply[w])
                self._ema[w] = 0.5 * self._ema[w] + 0.5 * gap
            self._last_reply[w] = tick
            fl = self._flights.get(rep.key)
            if fl is None:
                # late reply for a finished request: GC the worker copy
                if self._alive(w):
                    self._send(w, Cancel(rep.key))
                continue
            if rep.kind == "ack":
                self._on_ack(fl, rep, tick)
            elif rep.kind == "result":
                self._on_result(fl, rep, tick)
            else:
                raise ProtocolError("dispatcher", rep.kind)

    def _on_ack(self, fl: _Flight, ack: Ack, tick: int) -> None:
        asg = next((a for a in fl.assignments if a.worker == ack.worker),
                   None)
        if asg is None:
            return
        if ack.seq > asg.acked:
            if asg.acked < fl.n_chunks:
                self.points_ingested += int(
                    np.sum(fl.chunks[ack.seq - 1][2] > 0))
            asg.acked = ack.seq
            asg.resends = 0
        asg.last_progress = tick
        if (ack.seq > fl.journal_seq and ack.snapshot is not None):
            fl.journal_seq = ack.seq
            fl.journal_snap = ack.snapshot
        entry = self._async_children.get(fl.req.uid)
        if entry is not None and fl.journal_seq >= fl.n_chunks:
            # async-LSPIA shard: the completed ingest journal IS the
            # contribution — harvest it, no Solve round-trip
            self._harvest_shard(fl, *entry, tick)
            return
        self._send_next(fl, asg)

    # ------------------------------------------------- async-LSPIA shards
    def _accum_spec(self, rspec):
        """Dispatcher-side copy of ``FleetWorker._accum_spec``: snapshots
        accumulate at the pool degree."""
        if rspec.max_degree == self.spec.max_degree and not rspec.is_search:
            return rspec
        return dataclasses.replace(rspec, degree=self.spec.max_degree)

    def _harvest_shard(self, fl: _Flight, handle: AsyncFitHandle,
                       shard: int, tick: int) -> None:
        req = fl.req
        req.done = True
        req.done_tick = tick
        for asg in list(fl.assignments):
            self._drop_assignment(fl, asg)   # Cancel frees worker state
        self._flights.pop(req.uid)
        self._async_children.pop(req.uid, None)
        snaps = self._async_snaps.get(handle.uid)
        if snaps is None or handle.done:
            return
        if shard not in snaps:
            snaps[shard] = fl.journal_snap
            handle.harvested += 1
            self._counters["async_harvests"].inc()
        self.tracer.end(req.uid, "ingest", tick)
        self.tracer.instant(req.uid, "respond", tick,
                            kind="async_harvest", shard=shard,
                            parent=handle.uid)
        self._async_resolve(handle, tick)

    def _async_resolve(self, handle: AsyncFitHandle, tick: int) -> None:
        """Merge the harvested shard snapshots (moments are additive) and
        re-solve with moment-space LSPIA — partial shards give a partial
        (progressing) answer, the full set the exact one."""
        snaps = self._async_snaps.get(handle.uid)
        if not snaps:
            return
        parts = list(snaps.values())
        # reprolint: disable=RL-DTYPE — shard merge sums in f64, then casts
        merged = {k: sum(np.asarray(p[k], np.float64) for p in parts)
                  .astype(parts[0][k].dtype)
                  for k in ("gram", "vty", "yty", "count", "weight_sum")}
        merged["decay"] = parts[0]["decay"]
        st = streaming.StreamState.restore(
            merged, spec=self._accum_spec(handle.spec))
        solved = tuple(np.asarray(a)
                       for a in self._solve(st, handle.spec))
        coeffs, sse, r, count, cond, fb = solved
        if not np.all(np.isfinite(coeffs)):
            return      # partial state degenerate: keep the last answer
        d = int(handle.spec.degree)
        handle.coeffs = coeffs[:d + 1].copy()
        handle.sse = float(sse)
        handle.r = float(r)
        handle.count = float(count)
        handle.condition = float(cond)
        handle.converged = not bool(fb)
        handle.updates += 1
        self._counters["async_updates"].inc()
        if handle.harvested < handle.n_shards:
            handle.updates_while_partial += 1
        else:
            handle.done = True
            handle.done_tick = tick
            self.fits_done += 1
            self._counters["completed"].inc()
            self._async_snaps.pop(handle.uid, None)

    def _valid(self, req: FleetRequest) -> bool:
        return (req.coeffs is not None
                and bool(np.all(np.isfinite(req.coeffs)))
                and np.isfinite(req.sse))

    def _on_result(self, fl: _Flight, rep: Result, tick: int) -> None:
        req = fl.req
        if rep.fixed is not None:
            fe.fill_fixed_result(req, req.spec, rep.fixed)
        else:
            crit = (req.spec.degree.criterion
                    or self.pool_specs.select_criterion)
            fe.fill_auto_result(req, req.spec, rep.auto, crit)
        if self._valid(req):
            req.done_tick = tick
            self._lat.observe(req.latency_ticks)
            if req.hedged:
                won = ("hedge_wins" if rep.worker in fl.hedge_workers
                       else "hedge_losses")
                self._counters[won].inc()
            for asg in list(fl.assignments):
                self._drop_assignment(fl, asg)
            self._flights.pop(req.uid)
            self.fits_done += 1
            self._counters["completed"].inc()
            self.tracer.end(req.uid, "solve", tick, worker=rep.worker)
            self.tracer.instant(req.uid, "respond", tick,
                                worker=rep.worker,
                                latency_ticks=int(req.latency_ticks))
            return
        # poisoned / corrupt reply: quarantine the producer, scrub the
        # request, and re-solve from the journal on someone else
        req.done = False
        req.coeffs = None
        req.sse = req.r = req.condition = None
        req.degree = None
        req.scores = req.condition_ladder = None
        self._counters["poisoned"].inc()
        self._counters["retries_invalid"].inc()
        self.tracer.instant(req.uid, "poisoned", tick, worker=rep.worker)
        self.tracer.instant(req.uid, "retry", tick,
                            cause="invalid-result", worker=rep.worker)
        self.tracer.end(req.uid, "solve", tick)
        req.retries += 1
        self._quarantined_until[rep.worker] = (
            tick + self.cfg.quarantine_ticks)
        bad = next((a for a in fl.assignments
                    if a.worker == rep.worker), None)
        if bad is not None:
            self._drop_assignment(fl, bad)
        if req.retries > req.service.max_retries:
            self._fail(fl, "invalid-result")
        elif not fl.assignments:
            self._replay(fl, exclude={rep.worker})

    def _verdicts(self, tick: int) -> None:
        """Drive ``FailureDetector`` end-to-end: heartbeat death →
        journal replay + jittered restart; fitted step-time straggler →
        hedged duplicate dispatch."""
        cfg = self.cfg
        if tick % cfg.straggler_every == 0:
            obs = np.array([
                max(self._ema[w], tick - self._last_reply[w])
                if (self._inbox[w] or any(
                    a.worker == w for fl in self._flights.values()
                    for a in fl.assignments)) and self._alive(w)
                else self._ema[w]
                for w in range(cfg.n_workers)])
            self.detector.steptime.observe(self._obs_step, obs)
            self._obs_step += 1
        verdict = self.detector.verdict(self._obs_step, now=float(tick))
        self._stragglers = {w for w in verdict["stragglers"]
                            if self._alive(w)}
        for w in verdict["dead"]:
            if w in self._down:
                continue
            self._down.add(w)
            self._counters["worker_deaths"].inc()
            self.tracer.instant(trace_lib.FLEET_UID, "worker_death", tick,
                                worker=w)
            backoff = self._restart[w].next_backoff()
            if backoff is not None:
                self._revive_at[w] = tick + int(np.ceil(backoff))
            for fl in list(self._flights.values()):
                lost = [a for a in fl.assignments if a.worker == w]
                for asg in lost:
                    self._drop_assignment(fl, asg, cancel=False)
                if lost and not fl.assignments:
                    self._replay(fl, exclude={w})
        if self._stragglers:
            for fl in self._flights.values():
                if (fl.req.service.hedge and not fl.req.hedged
                        and len(fl.assignments) == 1
                        and fl.assignments[0].worker in self._stragglers):
                    w = self._pick_worker(
                        exclude=self._stragglers
                        | {fl.assignments[0].worker})
                    if w is not None:
                        fl.req.hedged = True
                        fl.hedge_workers.add(w)
                        self._counters["hedges"].inc()
                        self.tracer.instant(
                            fl.req.uid, "hedge", tick, worker=w,
                            straggler=fl.assignments[0].worker)
                        self._assign(fl, w)

    def _timeouts(self, tick: int) -> None:
        for fl in list(self._flights.values()):
            req = fl.req
            svc = req.service
            if (svc.deadline is not None
                    and tick - req.admit_tick > svc.deadline):
                self._fail(fl, "deadline")
                continue
            if not fl.assignments:
                self._replay(fl, exclude=set())
                continue
            for asg in list(fl.assignments):
                if tick - asg.last_progress <= svc.retry_timeout:
                    continue
                if asg.resends < svc.max_retries \
                        and self._alive(asg.worker):
                    # silent loss (dropped chunk, delayed ack): resend
                    # the outstanding message — idempotent on the worker
                    asg.resends += 1
                    req.retries += 1
                    self._counters["resends"].inc()
                    self._counters["retries_timeout"].inc()
                    self.tracer.instant(req.uid, "retry", tick,
                                        cause="timeout",
                                        worker=asg.worker)
                    asg.last_progress = tick
                    if asg.solving:
                        asg.solving = False
                    self._send_next(fl, asg)
                else:
                    # this worker copy is beyond saving: replay elsewhere
                    bad = asg.worker
                    self._drop_assignment(fl, asg)
                    if not fl.assignments:
                        if req.replays <= svc.max_retries:
                            self._replay(fl, exclude={bad})
                        else:
                            self._fail(fl, "retries-exhausted")

    def run(self, max_ticks: int = 100_000) -> None:
        """Drive the virtual clock until every admitted request settles."""
        for _ in range(max_ticks):
            if not self.pending:
                return
            self.step()
        if self.pending:
            raise RuntimeError(f"{self.pending} requests still pending "
                               f"after {max_ticks} ticks")

    # ------------------------------------------------------------- metrics
    def latency_quantiles(self) -> dict:
        """p50/p99 of completed-request latency, read from the streaming
        histogram sketch: available mid-run, identical at every call site
        (``launch.serve`` prints exactly this), no sample retention."""
        return {"p50": self._lat.quantile(0.5),
                "p99": self._lat.quantile(0.99)}

    def snapshot(self) -> dict:
        """One deterministic observability snapshot: tick, every metric
        (counters / gauges+hwm / histogram sketches), and the SLO board's
        per-monitor report (fitted level, slope, breach ETA)."""
        return {"tick": self.tick,
                "metrics": self.metrics.snapshot(),
                "slo": self.slo.report(self.tick)}
