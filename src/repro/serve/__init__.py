"""Serving engines: continuous batching over fixed slot pools.

``fit_engine`` serves the paper's workload — matricized LSE curve fits —
and is the flagship path; ``fleet`` replicates it behind a fault-tolerant
dispatcher (retry/hedging, moment-journal replay, graceful degradation);
``engine`` is the token-decode engine the slot-pool design was first
built around.
"""
from repro.serve.engine import ServeEngine, EngineConfig, Request
from repro.serve.fit_engine import (FitServeEngine, FitServeConfig,
                                    FitRequest)
from repro.serve.fleet import (FitFleet, FleetConfig, FleetRequest,
                               FleetWorker)
from repro.serve.sampling import sample

__all__ = ["ServeEngine", "EngineConfig", "Request",
           "FitServeEngine", "FitServeConfig", "FitRequest",
           "FitFleet", "FleetConfig", "FleetRequest", "FleetWorker",
           "sample"]
