from repro.serve.engine import ServeEngine, EngineConfig, Request
from repro.serve.sampling import sample

__all__ = ["ServeEngine", "EngineConfig", "Request", "sample"]
