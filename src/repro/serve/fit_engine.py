"""Continuous-batching fit server: the paper's workload as a service.

The token engine next door (``serve.engine``) batches decode steps over a
fixed slot pool; this engine does the same for *curve fits* — the workload
this repo actually reproduces.  Ragged per-request (x, y) series arrive,
are bucketed by length onto fixed-width slot pools, and ingest through the
matricized moment accumulator (packed P-series-per-tile Pallas kernel on
TPU, via ``repro.engine`` plan dispatch) with per-slot streaming
``StreamState`` — so a million-point series occupies one slot and folds in
chunk-by-chunk while short requests churn through the other slots.

vLLM-style static shapes: every bucket owns exactly TWO compiled
executables — one ingest step of shape (n_slots, width) and one solve of
the pooled O(m²) state — warmed once and reused across arbitrary request
churn.  Padding rides in with weight 0 (contributes nothing, by the
additive-moments property), slot reuse zeroes the slot's moments with a
keep-mask inside the same compiled step, so request arrival/departure
never changes a shape and never recompiles.  ``compiled_executables()``
exposes the counter the serve benchmark asserts on.

The pooled solve is condition-aware (``core.solve`` ladder + SVD rescue,
selected by ``FitServeConfig.solver``/``fallback``): each finished request
reports the estimated κ(Gram) and whether the rescue fired
(``FitRequest.condition`` / ``fallback_used``), so degenerate series
come back finite and flagged instead of NaN-ing a whole slot pool.

The host loop is deliberately synchronous/deterministic — the scheduling
substrate an async front-end would wrap.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import select as select_lib
from repro.core import fit as fit_lib
from repro.core import moments as moments_lib
from repro.core import streaming


@dataclasses.dataclass
class FitRequest:
    """One fit job: a ragged series in, a polynomial + quality report out.

    ``auto=True`` requests (``submit(..., degree="auto")``) come back with
    the *chosen* degree plus the whole scored ladder: ``degree`` is the
    winner under the engine's ``select_criterion``, ``scores`` maps each
    criterion name to its per-degree row (0..cfg.degree), and
    ``condition_ladder`` carries κ(truncated Gram) per candidate degree —
    the response diagnostics of single-pass model selection."""

    uid: int
    x: np.ndarray                      # (n,) host-side series
    y: np.ndarray
    auto: bool = False                 # automatic degree selection requested
    coeffs: np.ndarray | None = None   # (degree+1,) when done
    sse: float | None = None
    r: float | None = None
    count: float | None = None         # points the fit actually used
    condition: float | None = None     # estimated κ(Gram) at solve time
    fallback_used: bool | None = None  # rescue solver produced the coeffs
    degree: int | None = None          # chosen degree (auto requests)
    scores: dict | None = None         # per-degree criterion rows (auto)
    condition_ladder: np.ndarray | None = None   # per-degree κ (auto)
    done: bool = False

    @property
    def n(self) -> int:
        return int(self.x.shape[0])


@dataclasses.dataclass(frozen=True)
class FitServeConfig:
    degree: int = 3                     # fixed fit degree AND the auto-
    # degree ladder's maximum candidate (slots accumulate at this degree)
    n_slots: int = 8                    # concurrent series per bucket
    buckets: tuple[int, ...] = (256, 2048)   # chunk widths, ascending
    solver: str = "auto"                # condition-aware solve (core.solve)
    fallback: str | None = "svd"        # rank-revealing rescue (None = off)
    method: str | None = None           # legacy spelling of solver=
    ridge: float = 1e-9                 # λI stabilizer for the pooled solve
    # (idle slots hold all-zero moments and degenerate series are accepted,
    # so the pooled solve must never be exactly singular)
    decay: float = 1.0                  # exponential forgetting (γ=1: off);
    # γ<1 assumes full chunks (ages are counted inside each ingest chunk)
    engine: str = "auto"                # repro.engine path selection
    select_criterion: str = "aicc"      # auto-degree criterion (moment-
    # space only: the slot pool keeps one running state per series, no
    # fold partials — AIC/AICc/BIC/GCV; "cv" would need fold slots)
    dtype: Any = jnp.float32


class _Bucket:
    """One length bucket: a slot pool + its compiled ingest step."""

    def __init__(self, width: int, n_slots: int, cfg: FitServeConfig):
        self.width = width
        self.state = streaming.StreamState.create(
            cfg.degree, (n_slots,), decay=cfg.decay, dtype=cfg.dtype)
        self.slot_req: list[FitRequest | None] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int64)    # points ingested
        self.reset = np.zeros(n_slots, bool)           # zero slot next step
        self.queue: list[FitRequest] = []

        @jax.jit
        def ingest(state, x, y, w, keep):
            # keep==0 wipes a slot's previous occupant inside the same
            # compiled step (count included: it restarts for the new series)
            m = state.moments
            k = keep.astype(m.gram.dtype)
            m = moments_lib.Moments(
                gram=m.gram * k[:, None, None], vty=m.vty * k[:, None],
                yty=m.yty * k, count=m.count * k, weight_sum=m.weight_sum * k)
            return streaming.update(
                streaming.StreamState(m, state.decay), x, y, weights=w,
                engine=cfg.engine)

        self.ingest = ingest


class FitServeEngine:
    """Host-side continuous batching around compiled moment-ingest steps."""

    def __init__(self, cfg: FitServeConfig | None = None):
        self.cfg = cfg = cfg or FitServeConfig()
        if tuple(sorted(cfg.buckets)) != tuple(cfg.buckets):
            raise ValueError(f"buckets must ascend: {cfg.buckets}")
        if cfg.select_criterion not in select_lib.MOMENT_CRITERIA:
            raise ValueError(
                f"select_criterion={cfg.select_criterion!r}; the slot pool "
                f"keeps no fold partials, so only moment-space criteria "
                f"{select_lib.MOMENT_CRITERIA} can serve auto-degree "
                "requests")
        self.buckets = [_Bucket(w, cfg.n_slots, cfg) for w in cfg.buckets]
        self._uid = 0
        self.fits_done = 0
        self.points_ingested = 0

        @jax.jit
        def solve(state):
            poly = streaming.current_fit(state, method=cfg.method,
                                         solver=cfg.solver,
                                         fallback=cfg.fallback,
                                         ridge=cfg.ridge)
            rep = fit_lib.report_from_moments(state.moments, poly.coeffs)
            d = poly.diagnostics
            return (poly.coeffs, rep.sse, rep.r, state.moments.count,
                    d.condition, d.fallback_used)

        self._solve = solve

        @jax.jit
        def sweep(state):
            # the auto-degree solve: whole ladder 0..cfg.degree from the
            # slot pool's running moments (same ridge stabilizer — idle
            # slots must stay solvable at every rung — but scored on the
            # RAW moments so sse/criteria agree with the fixed-degree
            # path), plus the per-degree R of the padded coefficient
            # ladder for the response report.  One compiled executable
            # for ALL buckets (state shapes match).
            m = state.moments.regularized(cfg.ridge)
            sw = select_lib.sweep_from_moments(
                m, score_moments=state.moments,
                solver=cfg.method or cfg.solver, fallback=cfg.fallback)
            rep = fit_lib.report_from_moments(state.moments, sw.coeffs)
            return sw, rep.r, state.moments.count

        self._sweep = sweep

    # ------------------------------------------------------------- plumbing
    def submit(self, x, y, *, degree: int | str | None = None) -> FitRequest:
        """Queue one ragged series; routed to the smallest bucket that holds
        it in one chunk, else the largest (multi-chunk streaming ingest).

        ``degree="auto"`` requests automatic degree selection over the
        ladder 0..cfg.degree: the response carries the chosen degree, the
        per-degree criterion scores, and the per-degree condition — same
        single accumulation, one extra O(m²) ladder solve at completion.
        Any other ``degree`` must equal ``cfg.degree`` (the slot pools
        accumulate at one static degree)."""
        auto = degree == "auto"
        if degree is not None and not auto and int(degree) != self.cfg.degree:
            raise ValueError(
                f"degree={degree!r}: slot pools accumulate at the static "
                f"cfg.degree={self.cfg.degree}; pass degree='auto' for "
                "selection over the ladder 0..cfg.degree")
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float32)
        if x.ndim != 1 or x.shape != y.shape or x.shape[0] == 0:
            raise ValueError(f"expected equal non-empty 1-D x/y, got "
                             f"{x.shape} vs {y.shape}")
        if not auto and x.shape[0] < self.cfg.degree + 1:
            raise ValueError(
                f"series of {x.shape[0]} points cannot determine a "
                f"degree-{self.cfg.degree} fit (need >= "
                f"{self.cfg.degree + 1}); degree='auto' accepts short "
                "series (underdetermined rungs score +inf)")
        req = FitRequest(self._uid, x, y, auto=auto)
        self._uid += 1
        for b in self.buckets[:-1]:
            if req.n <= b.width:
                b.queue.append(req)
                return req
        self.buckets[-1].queue.append(req)
        return req

    def warmup(self) -> int:
        """Compile every executable up front — one full-width synthetic
        fixed-degree request AND one auto-degree request per bucket,
        drained immediately — so steady-state serving provably never
        recompiles whatever mix of request kinds arrives.  Returns
        ``compiled_executables()`` (the baseline the no-recompile
        invariant is asserted against).  Deterministic: does not depend on
        the live traffic's lengths."""
        if self.pending:
            raise RuntimeError("warmup() requires an idle engine")
        for b in self.buckets:
            n = max(b.width, self.cfg.degree + 1)
            x = np.linspace(-1.0, 1.0, n, dtype=np.float32)
            self.submit(x, x)
            self.submit(x, x, degree="auto")
        self.run()
        return self.compiled_executables()

    def compiled_executables(self) -> int:
        """Total compiled executables across the engine's jitted steps —
        constant after warmup is the no-recompile serving invariant."""
        return (self._solve._cache_size() + self._sweep._cache_size()
                + sum(b.ingest._cache_size() for b in self.buckets))

    @property
    def pending(self) -> int:
        return (sum(len(b.queue) for b in self.buckets)
                + sum(r is not None for b in self.buckets
                      for r in b.slot_req))

    # ----------------------------------------------------------------- run
    def _step_bucket(self, b: _Bucket) -> None:
        # admit: fill free slots from this bucket's queue
        for slot, req in enumerate(b.slot_req):
            if req is None and b.queue:
                b.slot_req[slot] = b.queue.pop(0)
                b.slot_pos[slot] = 0
                b.reset[slot] = True
        active = [s for s, r in enumerate(b.slot_req) if r is not None]
        if not active:
            return

        n_slots, w = len(b.slot_req), b.width
        xh = np.zeros((n_slots, w), np.float32)
        yh = np.zeros((n_slots, w), np.float32)
        wh = np.zeros((n_slots, w), np.float32)
        for s in active:
            req = b.slot_req[s]
            lo = int(b.slot_pos[s])
            chunk = req.x[lo:lo + w]
            m = chunk.shape[0]
            xh[s, :m] = chunk
            yh[s, :m] = req.y[lo:lo + w]
            wh[s, :m] = 1.0
            b.slot_pos[s] = lo + m
            self.points_ingested += m
        keep = np.where(b.reset, 0.0, 1.0).astype(np.float32)
        b.reset[:] = False
        b.state = b.ingest(b.state, jnp.asarray(xh), jnp.asarray(yh),
                           jnp.asarray(wh), jnp.asarray(keep))

        ready = [s for s in active if b.slot_pos[s] >= b.slot_req[s].n]
        if not ready:
            return
        fixed = [s for s in ready if not b.slot_req[s].auto]
        autos = [s for s in ready if b.slot_req[s].auto]
        if fixed:
            coeffs, sse, r, count, cond, fb = (np.asarray(a) for a in
                                               self._solve(b.state))
            for s in fixed:
                req = b.slot_req[s]
                req.coeffs = coeffs[s].copy()
                req.sse = float(sse[s])
                req.r = float(r[s])
                req.count = float(count[s])
                req.condition = float(cond[s])
                req.fallback_used = bool(fb[s])
                req.degree = self.cfg.degree
                req.done = True
                b.slot_req[s] = None
                self.fits_done += 1
        if autos:
            sw, r_ladder, count = self._sweep(b.state)
            scores = {name: np.asarray(sw.scores.by_name(name))
                      for name in select_lib.MOMENT_CRITERIA + ("sse", "r2")}
            ladder = np.asarray(sw.coeffs)
            cond = np.asarray(sw.condition)
            fb = np.asarray(sw.fallback_used)
            r_ladder = np.asarray(r_ladder)
            count = np.asarray(count)
            crit = self.cfg.select_criterion
            for s in autos:
                req = b.slot_req[s]
                d = int(np.argmin(scores[crit][s]))
                req.degree = d
                req.coeffs = ladder[s, d, :d + 1].copy()
                req.sse = float(scores["sse"][s, d])
                req.r = float(r_ladder[s, d])
                req.count = float(count[s])
                req.condition = float(cond[s, d])
                req.fallback_used = bool(fb[s, d])
                req.scores = {k: v[s].copy() for k, v in scores.items()}
                req.condition_ladder = cond[s].copy()
                req.done = True
                b.slot_req[s] = None
                self.fits_done += 1

    def step(self) -> None:
        """One engine iteration: admit + one compiled ingest per non-empty
        bucket (+ one compiled solve per bucket that finished a series)."""
        for b in self.buckets:
            self._step_bucket(b)

    def run(self, max_steps: int = 1_000_000) -> None:
        """Drive until every queued request is served (or max_steps)."""
        for _ in range(max_steps):
            if not self.pending:
                return
            self.step()
        if self.pending:
            raise RuntimeError(f"{self.pending} requests still pending "
                               f"after {max_steps} steps")
