"""Continuous-batching fit server: the paper's workload as a service.

The token engine next door (``serve.engine``) batches decode steps over a
fixed slot pool; this engine does the same for *curve fits* — the workload
this repo actually reproduces.  Ragged per-request (x, y) series arrive,
are bucketed by length onto fixed-width slot pools, and ingest through the
matricized moment accumulator (packed P-series-per-tile Pallas kernel on
TPU, via ``repro.engine`` plan dispatch) with per-slot streaming
``StreamState`` — so a million-point series occupies one slot and folds in
chunk-by-chunk while short requests churn through the other slots.

vLLM-style static shapes: every bucket owns ONE compiled fused
ingest+solve executable of shape (n_slots, width) — on any step where a
request completes, the chunk accumulates into the slots' moments AND the
pool's default fixed spec is solved in the same program, so the Gram goes
matmul→solve without an HBM round-trip or a second host dispatch.
Mid-series steps (no completion — only the widest bucket ever takes
them) dispatch a plain ingest instead, skipping the wasted solve.  Both
are warmed once and reused across arbitrary request churn.  Padding rides in with weight 0 (contributes
nothing, by the additive-moments property), slot reuse zeroes the slot's
moments with a keep-mask inside the same compiled step, and per-slot IRLS
robustness is selected by RUNTIME mask/loss/c arrays — so request
arrival/departure, solver policy, and loss mix never change a shape and
never recompile.  ``compiled_executables()`` exposes the counter the serve
benchmark asserts on.

Requests carry their own ``repro.api.FitSpec`` (``submit(x, y,
spec=...)``): the solve side — solver/fallback/cond_cap ladder, ridge,
method (LSE / moment-space LSPIA), fixed degree ≤ the pool's (served from
the ``Moments.truncate`` view), or a DegreeSearch over the nested ladder —
is honored PER REQUEST.  Each distinct spec compiles its solve executable
once (the spec is the jit static arg) and coexists with every other spec
from then on: the no-recompile invariant keyed on spec identity.  The
accumulation side (basis, engine path, decay, pinned domain, max degree)
is necessarily pool-wide — it is baked into the slots' running moments —
and comes from ``FitServeConfig`` (or its ``spec=``).

The host loop is deliberately synchronous/deterministic — the scheduling
substrate an async front-end would wrap.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import select as select_lib
from repro import obs as obs_lib
from repro.core import basis as basis_lib
from repro.core import fit as fit_lib
from repro.core import lspia as lspia_lib
from repro.core import moments as moments_lib
from repro.core import robust as robust_lib
from repro.core import solve as solve_lib
from repro.core import streaming


@dataclasses.dataclass
class FitRequest:
    """One fit job: a ragged series in, a polynomial + quality report out.

    ``spec`` is the request's ``FitSpec`` (the engine's default when the
    legacy ``degree=`` spelling was used).  DegreeSearch specs
    (``auto=True``) come back with the *chosen* degree plus the whole
    scored ladder: ``degree`` is the winner under the spec's criterion,
    ``scores`` maps each criterion name to its per-degree row, and
    ``condition_ladder`` carries κ(truncated Gram) per candidate degree —
    the response diagnostics of single-pass model selection."""

    uid: int
    x: np.ndarray                      # (n,) host-side series
    y: np.ndarray
    spec: Any = None                   # the request's FitSpec
    auto: bool = False                 # automatic degree selection requested
    coeffs: np.ndarray | None = None   # (degree+1,) when done
    sse: float | None = None
    r: float | None = None
    count: float | None = None         # points the fit actually used
    condition: float | None = None     # estimated κ(Gram) at solve time
    fallback_used: bool | None = None  # rescue solver produced the coeffs
    degree: int | None = None          # chosen degree (auto requests)
    scores: dict | None = None         # per-degree criterion rows (auto)
    condition_ladder: np.ndarray | None = None   # per-degree κ (auto)
    done: bool = False

    @property
    def n(self) -> int:
        return int(self.x.shape[0])


@dataclasses.dataclass(frozen=True)
class FitServeConfig:
    degree: int = 3                     # pool accumulation degree AND the
    # ceiling for per-request degrees / DegreeSearch ladders
    n_slots: int = 8                    # concurrent series per bucket
    buckets: tuple[int, ...] = (256, 2048)   # chunk widths, ascending
    solver: str = "auto"                # condition-aware solve (core.solve)
    fallback: str | None = "svd"        # rank-revealing rescue (None = off)
    method: str | None = None           # legacy spelling of solver=
    ridge: float = 1e-9                 # λI stabilizer for the pooled solve
    # (idle slots hold all-zero moments and degenerate series are accepted,
    # so the pooled solve must never be exactly singular)
    decay: float = 1.0                  # exponential forgetting (γ=1: off);
    # γ<1 assumes full chunks (ages are counted inside each ingest chunk)
    engine: str = "auto"                # repro.engine path selection
    select_criterion: str = "aicc"      # default auto-degree criterion
    # (moment-space only: the slot pool keeps no fold partials —
    # AIC/AICc/BIC/GCV; "cv" would need fold slots)
    dtype: Any = jnp.float32
    spec: Any = None                    # a FitSpec supplying the pool-wide
    # accumulation policy (degree/basis/engine/decay/domain/numerics) AND
    # the default per-request solve; overrides the flat fields above


@dataclasses.dataclass(frozen=True)
class PoolSpecs:
    """The server-side spec family one ``FitServeConfig`` implies: what the
    slots accumulate (``pool``, fixed max degree), the default fixed and
    auto-degree request specs, and the spec a bare ``submit(x, y)`` gets.

    Derived once by ``derive_pool_specs`` and shared by every serving
    surface — the single-process ``FitServeEngine`` and the replicated
    workers of ``serve.fleet`` — so "what does this server accumulate and
    how does it answer by default" has exactly one definition."""

    pool: Any
    fixed: Any
    auto: Any
    default: Any
    select_criterion: str


def validate_pool_spec(spec) -> None:
    # only an EXPLICIT normalize request is rejected: the plan layer's
    # high-degree auto-escalation is a before-the-Gram fix the server
    # cannot apply (min/max of unseen series), so — as the engine
    # always has — high-degree pools accumulate raw-domain moments and
    # lean on solve-time solver escalation + the rank-revealing
    # fallback instead (pin FitSpec.domain to get true normalization)
    from repro.api import spec as spec_lib
    if spec.numerics.solver in spec_lib.RAW_DATA_SOLVERS:
        raise ValueError(
            f"solver={spec.numerics.solver!r} needs the raw Vandermonde "
            "rows; the slot pools only hold moments")
    if spec.numerics.normalize and spec.domain is None:
        raise ValueError(
            "this spec normalizes the domain, but the server cannot "
            "derive min/max from series it has not seen — pin it with "
            "FitSpec(domain=(shift, scale))")


def derive_pool_specs(cfg: "FitServeConfig") -> PoolSpecs:
    """Map one ``FitServeConfig`` onto the ``PoolSpecs`` family."""
    from repro.api import spec as spec_lib
    from repro.engine import plan as plan_lib
    if cfg.select_criterion not in select_lib.MOMENT_CRITERIA:
        raise ValueError(
            f"select_criterion={cfg.select_criterion!r}; the slot pool "
            f"keeps no fold partials, so only moment-space criteria "
            f"{select_lib.MOMENT_CRITERIA} can serve auto-degree "
            "requests")
    if cfg.spec is not None:
        base = cfg.spec
    else:
        solver = cfg.method or cfg.solver
        base = spec_lib.FitSpec(
            degree=cfg.degree,
            numerics=plan_lib.NumericsPolicy(solver=solver,
                                             fallback=cfg.fallback),
            decay=cfg.decay, ridge=cfg.ridge, engine=cfg.engine)
    # the pool-wide spec: what the slots accumulate (fixed max degree)
    pool = (dataclasses.replace(base, degree=base.max_degree)
            if base.is_search else base)
    validate_pool_spec(pool)
    ds = (base.degree if base.is_search
          else select_lib.DegreeSearch(
              max_degree=pool.max_degree, folds=0,
              criterion=cfg.select_criterion,
              solver=pool.numerics.solver,
              fallback=pool.numerics.fallback,
              cond_cap=pool.numerics.cond_cap))
    # a DegreeSearch rides the condition-aware ladder solve; an LSPIA
    # pool's auto requests therefore search as LSE (the accumulated
    # moments are method-free — only the solve differs)
    auto = dataclasses.replace(
        base, degree=ds,
        method="lse" if base.method == "lspia" else base.method)
    default = base if base.is_search else pool
    return PoolSpecs(pool=pool, fixed=pool, auto=auto, default=default,
                     select_criterion=cfg.select_criterion)


def validate_request_spec(specs: PoolSpecs, spec) -> None:
    """Reject request specs the pool's accumulated state cannot serve."""
    from repro.api import spec as spec_lib
    pool = specs.pool
    if spec.numerics.solver in spec_lib.RAW_DATA_SOLVERS:
        raise ValueError(
            f"solver={spec.numerics.solver!r} needs the raw Vandermonde "
            "rows; the slot pools only hold moments")
    if spec.basis != pool.basis:
        raise ValueError(
            f"request basis={spec.basis!r} but the pool accumulates "
            f"{pool.basis!r} moments — basis is pool-wide "
            "(FitServeConfig.spec)")
    if spec.domain != pool.domain:
        raise ValueError(
            f"request domain={spec.domain!r} but the pool accumulates "
            f"in domain {pool.domain!r} — the domain map is baked into "
            "the slots' moments (FitServeConfig.spec)")
    if spec.decay != pool.decay:
        raise ValueError(
            f"request decay={spec.decay} but the pool decays at "
            f"{pool.decay} — forgetting is baked into the running "
            "state (FitServeConfig.spec)")
    if spec.max_degree > pool.max_degree:
        raise ValueError(
            f"request degree {spec.max_degree} exceeds the pool's "
            f"accumulation degree {pool.max_degree}; nested degrees "
            "<= cfg.degree are served from the truncated state")
    if (spec.method == "irls"
            and spec.irls.stream_sweeps != pool.irls.stream_sweeps):
        raise ValueError(
            f"request stream_sweeps={spec.irls.stream_sweeps} but the "
            f"pool's compiled ingest runs {pool.irls.stream_sweeps} — "
            "the sweep count is baked into the ingest executable "
            "(FitServeConfig.spec); per-request loss/c ARE honored")
    if spec.is_search:
        crit = spec.degree.criterion or specs.select_criterion
        if crit not in select_lib.MOMENT_CRITERIA:
            raise ValueError(
                f"criterion={crit!r}: the slot pool keeps no fold "
                f"partials, so only {select_lib.MOMENT_CRITERIA} can "
                "serve auto-degree requests")


def resolve_request_spec(specs: PoolSpecs, degree, spec):
    """Map the (degree=, spec=) submit spellings onto one FitSpec."""
    if spec is not None:
        if degree is not None:
            raise ValueError("pass degree= or spec=, not both")
        validate_request_spec(specs, spec)
        return spec
    if degree is None:
        return specs.default
    if degree == "auto":
        return specs.auto
    if int(degree) != specs.pool.max_degree:
        raise ValueError(
            f"degree={degree!r}: slot pools accumulate at the static "
            f"cfg.degree={specs.pool.max_degree}; pass degree='auto' for "
            "selection over the ladder 0..cfg.degree, or a FitSpec "
            "(spec=) for any nested degree <= cfg.degree")
    return specs.fixed


def validate_series(x, y, rspec) -> tuple[np.ndarray, np.ndarray]:
    """Shared submit-time series validation (engine AND fleet)."""
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.float32)
    if x.ndim != 1 or x.shape != y.shape or x.shape[0] == 0:
        raise ValueError(f"expected equal non-empty 1-D x/y, got "
                         f"{x.shape} vs {y.shape}")
    if not rspec.is_search and x.shape[0] < int(rspec.degree) + 1:
        raise ValueError(
            f"series of {x.shape[0]} points cannot determine a "
            f"degree-{int(rspec.degree)} fit (need >= "
            f"{int(rspec.degree) + 1}); degree='auto' accepts short "
            "series (underdetermined rungs score +inf)")
    return x, y


def _spec_solve_from_state(state, spec, pool_degree: int):
    """The ONE definition of a per-request fixed-degree solve over a
    pool-degree state: the request's nested degree is a truncate view of
    the accumulated state; its numerics policy (solver rung, fallback,
    cond_cap, ridge) and method (LSE vs moment-space LSPIA) ride in the
    static spec.  Traced both standalone (``make_spec_solve``) and fused
    after the ingest body (``_Bucket.ingest_solve``) — same ops, same
    order, so the two executables agree bitwise."""
    d = int(spec.degree)
    m = (state.moments.truncate(d) if d < pool_degree
         else state.moments)
    ms = m.regularized(spec.ridge) if spec.ridge else m
    if spec.method == "lspia":
        opts = spec.lspia
        coeffs, cond, conv, _ = lspia_lib.lspia_solve_moments(
            ms.gram, ms.vty, tol=opts.tol, max_iter=opts.max_iter,
            power_iters=opts.power_iters, step=opts.step,
            momentum=opts.momentum)
        fb = ~conv
    else:
        rung = spec.numerics.solver
        if rung == "auto":
            rung = solve_lib.select_solver(
                d, state.moments.gram.dtype, basis=spec.basis,
                normalized=spec.domain is not None)
        coeffs, cond, fb = solve_lib.solve_with_fallback(
            ms.gram, ms.vty, method=rung,
            fallback=spec.numerics.fallback,
            cond_cap=spec.numerics.cond_cap)
    rep = fit_lib.report_from_moments(m, coeffs)
    return (coeffs, rep.sse, rep.r, state.moments.count, cond, fb)


def make_spec_solve(pool_degree: int):
    """Jitted wrapper of ``_spec_solve_from_state`` — the executable every
    serving surface (the slot-pool engine for NON-default specs, each
    fleet worker for every spec) answers a fixed-degree request with.
    Shape-polymorphic over the state's batch axes: (n_slots,) on the
    engine, () on a fleet worker's per-request state."""
    from functools import partial as _partial

    @_partial(jax.jit, static_argnames=("spec",))
    def solve(state, spec):
        return _spec_solve_from_state(state, spec, pool_degree)

    return solve


def make_spec_sweep(pool_degree: int):
    """The auto-degree ladder solve over a pool-degree state (see
    ``make_spec_solve`` for why this is a shared module-level factory)."""
    from functools import partial as _partial

    @_partial(jax.jit, static_argnames=("spec",))
    def sweep(state, spec):
        # the request's ladder 0..max_degree from the (truncated view of
        # the) accumulated running moments — same ridge stabilizer (idle
        # slots must stay solvable at every rung) but scored on the RAW
        # moments so sse/criteria agree with the fixed-degree path, plus
        # the per-degree R of the padded coefficient ladder for the
        # response report.
        ds = spec.degree
        m = (state.moments.truncate(ds.max_degree)
             if ds.max_degree < pool_degree else state.moments)
        ridge = spec.ridge
        mr = m.regularized(ridge) if ridge else m
        rung = (spec.numerics.solver
                if spec.numerics.solver != "auto" else ds.solver)
        sw = select_lib.sweep_from_moments(
            mr, score_moments=m if ridge else None, solver=rung,
            fallback=ds.fallback, cond_cap=ds.cond_cap,
            basis=spec.basis, normalized=spec.domain is not None)
        rep = fit_lib.report_from_moments(m, sw.coeffs)
        return sw, rep.r, state.moments.count

    return sweep


def fill_fixed_result(req: FitRequest, spec, solved, s=None) -> None:
    """Populate one request from a fixed-degree solve's (numpy) outputs.

    ``s`` indexes a batched (slot-pool) solve; ``None`` reads a scalar
    (fleet-worker) solve.  One definition of "what a served fit reports",
    shared by every surface."""
    pick = (lambda a: a) if s is None else (lambda a: a[s])
    coeffs, sse, r, count, cond, fb = solved
    d = int(spec.degree)
    req.coeffs = np.asarray(pick(coeffs))[:d + 1].copy()
    req.sse = float(pick(sse))
    req.r = float(pick(r))
    req.count = float(pick(count))
    req.condition = float(pick(cond))
    req.fallback_used = bool(pick(fb))
    req.degree = d
    req.done = True


def auto_outputs(sw, r_ladder, count) -> dict:
    """Convert one ``make_spec_sweep`` output to host-side numpy once per
    solve (the per-request fill then just indexes)."""
    scores = {name: np.asarray(sw.scores.by_name(name))
              for name in select_lib.MOMENT_CRITERIA + ("sse", "r2")}
    return {"scores": scores, "ladder": np.asarray(sw.coeffs),
            "cond": np.asarray(sw.condition),
            "fb": np.asarray(sw.fallback_used),
            "r": np.asarray(r_ladder), "count": np.asarray(count)}


def fill_auto_result(req: FitRequest, spec, outs: dict, criterion: str,
                     s=None) -> None:
    """Populate one auto-degree request from ``auto_outputs``."""
    pick = (lambda a: a) if s is None else (lambda a: a[s])
    scores = outs["scores"]
    d = int(np.argmin(pick(scores[criterion])))
    req.degree = d
    req.coeffs = np.asarray(pick(outs["ladder"]))[d, :d + 1].copy()
    req.sse = float(pick(scores["sse"])[d])
    req.r = float(pick(outs["r"])[d])
    req.count = float(pick(outs["count"]))
    req.condition = float(pick(outs["cond"])[d])
    req.fallback_used = bool(pick(outs["fb"])[d])
    req.scores = {k: np.asarray(pick(v)).copy() for k, v in scores.items()}
    req.condition_ladder = np.asarray(pick(outs["cond"])).copy()
    req.done = True


class _Bucket:
    """One length bucket: a slot pool + its compiled fused
    ingest+default-solve step."""

    def __init__(self, width: int, n_slots: int, engine: "FitServeEngine"):
        cfg = engine.cfg
        pool = engine.spec
        self.width = width
        self.state = streaming.StreamState.create(
            pool.max_degree, (n_slots,), decay=pool.decay, dtype=cfg.dtype)
        self.slot_req: list[FitRequest | None] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int64)    # points ingested
        self.reset = np.zeros(n_slots, bool)           # zero slot next step
        self.queue: list[FitRequest] = []
        dom = pool.domain_or(None, dtype=cfg.dtype)
        rsolver = engine._pool_solver
        ridge = max(pool.ridge, 1e-9)   # the reweight solve must tolerate
        # idle/young slots even when the request asked for ridge=0
        degree = pool.max_degree

        sweeps = pool.irls.stream_sweeps

        @jax.jit
        def ingest(state, x, y, w, keep, rmask, loss_id, cval):
            # keep==0 wipes a slot's previous occupant inside the same
            # compiled step (count included: it restarts for the new series)
            m = state.moments
            k = keep.astype(m.gram.dtype)
            m = moments_lib.Moments(
                gram=m.gram * k[:, None, None], vty=m.vty * k[:, None],
                yty=m.yty * k, count=m.count * k, weight_sum=m.weight_sum * k)
            st = streaming.StreamState(m, state.decay)
            xt = dom.apply(x) if dom is not None else x

            def solve(mm):
                coeffs, _, _ = solve_lib.solve_with_fallback(
                    mm.regularized(ridge).gram, mm.regularized(ridge).vty,
                    method=rsolver, fallback="svd")
                return coeffs

            def rw_of(coeffs, w):
                # ψ-weights with the loss/tuning selected by RUNTIME
                # per-slot arrays — one executable serves any robust/plain
                # mix with zero recompiles
                r = y - basis_lib.evaluate(coeffs, xt, basis=pool.basis)
                sigma = robust_lib.chunk_scale(r, w, y)
                wr = robust_lib.robust_weights_by_id(
                    r / sigma, loss_id[:, None], cval[:, None])
                return jnp.where((rmask > 0)[:, None], wr, 1.0)

            def reweight(w):
                # per-slot single-pass IRLS: sweep 0 against the slot's
                # RUNNING fit (where determined), then stream_sweeps − 1
                # re-accumulations of the in-hand chunk against
                # (decayed slot state + chunk) — robust from the first
                # chunk.  Mirrors streaming._streaming_irls_weights,
                # including the decay bookkeeping: old mass ages by γⁿ and
                # the chunk carries its own γ age ladder, exactly as the
                # final streaming.update accumulation will weight it.
                determined = (st.moments.count > degree)[:, None]
                wr = jnp.where(determined, rw_of(solve(st.moments), w), 1.0)
                from repro import engine as engine_lib
                plan = engine_lib.plan_fit(
                    x.shape, degree, basis=pool.basis, dtype=x.dtype,
                    weighted=True, engine=pool.engine,
                    accum_dtype=st.moments.gram.dtype)
                n = x.shape[-1]
                g = st.decay ** jnp.asarray(n, st.decay.dtype)
                old = jax.tree.map(lambda a: a * g, st.moments)
                lad = moments_lib.decay_ladder(n, st.decay, x.dtype)
                for _ in range(sweeps - 1):
                    new = engine_lib.compute_moments(plan, xt, y,
                                                     lad * w * wr)
                    wr = rw_of(solve(old + new), w)
                return wr * w

            w = jax.lax.cond(jnp.any(rmask > 0), reweight, lambda w: w, w)
            return streaming.update(st, xt, y, weights=w, basis=pool.basis,
                                    engine=pool.engine)

        self.ingest = ingest

        # The fused hot path: accumulate the chunk AND solve the pool's
        # default fixed spec in ONE executable, so the updated Gram flows
        # from the moment matmul straight into the solve without a
        # round-trip through HBM (or a second host dispatch) between
        # ticks.  The solve half is the same ``_spec_solve_from_state``
        # the standalone executable traces — non-default request specs
        # still go through ``FitServeEngine._solve`` on the returned
        # state, unchanged.
        fixed_spec = engine.fixed_spec

        @jax.jit
        def ingest_solve(state, x, y, w, keep, rmask, loss_id, cval):
            st = ingest(state, x, y, w, keep, rmask, loss_id, cval)
            return st, _spec_solve_from_state(st, fixed_spec, degree)

        self.ingest_solve = ingest_solve


class FitServeEngine:
    """Host-side continuous batching around compiled moment-ingest steps."""

    def __init__(self, cfg: FitServeConfig | None = None,
                 obs: "obs_lib.Observability | None" = None):
        from repro.api import spec as spec_lib
        self.cfg = cfg = cfg or FitServeConfig()
        # observability is injected and OFF by default: the null bundle
        # makes every record below an empty method call (the perf gate's
        # ``obs_overhead`` row holds enabled-vs-null to <= 5%)
        self.obs = obs or obs_lib.NULL_OBS
        self._m_submitted = self.obs.metrics.counter("submitted")
        self._m_completed = self.obs.metrics.counter("completed")
        self._g_queue = self.obs.metrics.gauge("queue_depth")
        self._h_points = self.obs.metrics.histogram("points_per_fit")
        self._h_latency = self.obs.metrics.histogram("fit_latency_steps")
        self._step_no = 0
        self._admit_step: dict[int, int] = {}
        if tuple(sorted(cfg.buckets)) != tuple(cfg.buckets):
            raise ValueError(f"buckets must ascend: {cfg.buckets}")
        specs = self.pool_specs = derive_pool_specs(cfg)
        self.spec = specs.pool
        # default per-request specs for the legacy degree= spellings
        self.fixed_spec = specs.fixed
        self.auto_spec = specs.auto
        self.default_spec = specs.default
        # the reweight solve's static rung (pool degree/dtype/basis)
        self._pool_solver = (
            self.spec.numerics.solver if self.spec.numerics.solver
            not in ("auto",) + spec_lib.RAW_DATA_SOLVERS
            else solve_lib.select_solver(
                self.spec.max_degree, cfg.dtype, basis=self.spec.basis,
                normalized=self.spec.domain is not None))
        self.buckets = [_Bucket(w, cfg.n_slots, self) for w in cfg.buckets]
        self._uid = 0
        self.fits_done = 0
        self.points_ingested = 0
        self._solve = make_spec_solve(self.spec.max_degree)
        self._sweep = make_spec_sweep(self.spec.max_degree)

    # ------------------------------------------------------------- plumbing
    def _resolve_spec(self, degree, spec):
        """Map the (degree=, spec=) submit spellings onto one FitSpec."""
        return resolve_request_spec(self.pool_specs, degree, spec)

    def _validate_request_spec(self, spec) -> None:
        validate_request_spec(self.pool_specs, spec)

    def submit(self, x, y, *, degree: int | str | None = None,
               spec=None) -> FitRequest:
        """Queue one ragged series; routed to the smallest bucket that holds
        it in one chunk, else the largest (multi-chunk streaming ingest).

        ``spec=`` attaches a full ``FitSpec`` to the request: its method
        (LSE / IRLS chunk-reweighting / moment-space LSPIA), its solve
        policy (solver/fallback/cond_cap/ridge), a nested fixed degree
        <= cfg.degree, or a DegreeSearch over the nested ladder.  Each
        distinct spec compiles its solve once, then coexists with every
        other spec — no recompiles.  ``degree=`` is the legacy spelling:
        the pool degree, or "auto" for selection under the engine's
        default criterion."""
        rspec = self._resolve_spec(degree, spec)
        auto = rspec.is_search
        x, y = validate_series(x, y, rspec)
        req = FitRequest(self._uid, x, y, spec=rspec, auto=auto)
        self._uid += 1
        self._m_submitted.inc()
        self.obs.tracer.instant(req.uid, "submit", self._step_no,
                                n=req.n, auto=bool(auto))
        for b in self.buckets[:-1]:
            if req.n <= b.width:
                b.queue.append(req)
                return req
        self.buckets[-1].queue.append(req)
        return req

    def warmup(self) -> int:
        """Compile every executable up front — one full-width synthetic
        fixed-degree request AND one auto-degree request per bucket,
        plus one double-width request whose mid-series chunk compiles the
        widest bucket's plain (no-solve) ingest step — drained
        immediately, so steady-state serving provably never recompiles
        whatever mix of DEFAULT-spec request kinds arrives.  (A novel
        per-request spec compiles its own solve once on first use, then
        joins the invariant.)  Returns ``compiled_executables()`` (the
        baseline the no-recompile invariant is asserted against).
        Deterministic: does not depend on the live traffic's lengths."""
        if self.pending:
            raise RuntimeError("warmup() requires an idle engine")
        for b in self.buckets:
            n = max(b.width, self.spec.max_degree + 1)
            x = np.linspace(-1.0, 1.0, n, dtype=np.float32)
            self.submit(x, x, spec=self.fixed_spec)
            self.submit(x, x, spec=self.auto_spec)
        # only the LAST bucket ever ingests multi-chunk series (routing
        # sends every shorter request to a bucket wide enough to finish
        # it in one step), so one over-length request warms its
        # mid-series path — 3 chunks long, so at least one step is
        # mid-series-only even when it shares its first step with the
        # completing requests above
        n2 = 3 * self.buckets[-1].width
        x2 = np.linspace(-1.0, 1.0, n2, dtype=np.float32)
        self.submit(x2, x2, spec=self.fixed_spec)
        self.run()
        return self.compiled_executables()

    def compiled_executables(self) -> int:
        """Total compiled executables across the engine's jitted steps —
        constant after warmup (plus one per NOVEL request spec, compiled
        at first use) is the no-recompile serving invariant.  The fused
        ingest+solve is ONE executable per bucket; the plain ingest
        compiles only where mid-series (no-completion) steps can occur —
        the widest bucket."""
        return (self._solve._cache_size() + self._sweep._cache_size()
                + sum(b.ingest._cache_size() + b.ingest_solve._cache_size()
                      for b in self.buckets))

    @property
    def pending(self) -> int:
        return (sum(len(b.queue) for b in self.buckets)
                + sum(r is not None for b in self.buckets
                      for r in b.slot_req))

    # ----------------------------------------------------------------- run
    def _step_bucket(self, b: _Bucket) -> None:
        # admit: fill free slots from this bucket's queue
        for slot, req in enumerate(b.slot_req):
            if req is None and b.queue:
                b.slot_req[slot] = b.queue.pop(0)
                b.slot_pos[slot] = 0
                b.reset[slot] = True
                if self.obs.enabled:
                    uid = b.slot_req[slot].uid
                    self._admit_step[uid] = self._step_no
                    self.obs.tracer.instant(uid, "admit", self._step_no,
                                            bucket=b.width, slot=slot)
                    self.obs.tracer.begin(uid, "serve", self._step_no)
        active = [s for s, r in enumerate(b.slot_req) if r is not None]
        if not active:
            return

        n_slots, w = len(b.slot_req), b.width
        xh = np.zeros((n_slots, w), np.float32)
        yh = np.zeros((n_slots, w), np.float32)
        wh = np.zeros((n_slots, w), np.float32)
        rmask = np.zeros(n_slots, np.float32)
        loss_id = np.zeros(n_slots, np.int32)
        cval = np.ones(n_slots, np.float32)
        for s in active:
            req = b.slot_req[s]
            lo = int(b.slot_pos[s])
            chunk = req.x[lo:lo + w]
            m = chunk.shape[0]
            xh[s, :m] = chunk
            yh[s, :m] = req.y[lo:lo + w]
            wh[s, :m] = 1.0
            b.slot_pos[s] = lo + m
            self.points_ingested += m
            if req.spec.method == "irls":
                rmask[s] = 1.0
                loss_id[s] = robust_lib.LOSS_IDS[req.spec.irls.loss]
                cval[s] = robust_lib.resolve_tuning(req.spec.irls.loss,
                                                    req.spec.irls.c)
        keep = np.where(b.reset, 0.0, 1.0).astype(np.float32)
        b.reset[:] = False
        # readiness is host-known BEFORE dispatch (slot_pos already
        # advanced), so each step picks the cheapest executable: the
        # fused ingest+solve when ≥1 request completes this chunk — the
        # Gram never round-trips through HBM (or a second dispatch)
        # between accumulate and solve — and the plain ingest on
        # mid-series steps, where a solve would be wasted work
        ready = [s for s in active if b.slot_pos[s] >= b.slot_req[s].n]
        args = (jnp.asarray(xh), jnp.asarray(yh), jnp.asarray(wh),
                jnp.asarray(keep), jnp.asarray(rmask),
                jnp.asarray(loss_id), jnp.asarray(cval))
        if not ready:
            b.state = b.ingest(b.state, *args)
            return
        b.state, fused = b.ingest_solve(b.state, *args)
        # group ready slots by their request's spec: the default fixed
        # spec is already solved (fused above); every other DISTINCT spec
        # gets one compiled solve for its whole group
        fixed_groups: dict[Any, list[int]] = {}
        auto_groups: dict[Any, list[int]] = {}
        for s in ready:
            groups = (auto_groups if b.slot_req[s].auto else fixed_groups)
            groups.setdefault(b.slot_req[s].spec, []).append(s)
        for spec, slots in fixed_groups.items():
            out = (fused if spec == self.fixed_spec
                   else self._solve(b.state, spec))
            solved = tuple(np.asarray(a) for a in out)
            for s in slots:
                req = b.slot_req[s]
                fill_fixed_result(req, spec, solved, s)
                b.slot_req[s] = None
                self._done(req)
        for spec, slots in auto_groups.items():
            outs = auto_outputs(*self._sweep(b.state, spec))
            crit = spec.degree.criterion or self.cfg.select_criterion
            for s in slots:
                req = b.slot_req[s]
                fill_auto_result(req, spec, outs, crit, s)
                b.slot_req[s] = None
                self._done(req)

    def _done(self, req: FitRequest) -> None:
        self.fits_done += 1
        self._m_completed.inc()
        self._h_points.observe(req.n)
        if self.obs.enabled:
            t0 = self._admit_step.pop(req.uid, self._step_no)
            self._h_latency.observe(self._step_no - t0)
            self.obs.tracer.end(req.uid, "serve", self._step_no)
            self.obs.tracer.instant(req.uid, "respond", self._step_no,
                                    steps=self._step_no - t0)

    def step(self) -> None:
        """One engine iteration: admit + one compiled fused ingest+solve
        per non-empty bucket (+ one compiled solve per distinct ready
        NON-default spec)."""
        self._step_no += 1
        for b in self.buckets:
            self._step_bucket(b)
        self._g_queue.set(sum(len(b.queue) for b in self.buckets))

    def run(self, max_steps: int = 1_000_000) -> None:
        """Drive until every queued request is served (or max_steps)."""
        for _ in range(max_steps):
            if not self.pending:
                return
            self.step()
        if self.pending:
            raise RuntimeError(f"{self.pending} requests still pending "
                               f"after {max_steps} steps")
