"""Token sampling: greedy / temperature / top-k."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits, temperature: float, rng, top_k: int | None = None):
    """logits: (B, V) -> (B,) int32."""
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
