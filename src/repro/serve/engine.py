"""Batched serving engine: continuous-batching decode over a fixed slot pool.

Design (vLLM-style, TPU-static shapes):
  * `n_slots` concurrent sequences share one static KV cache allocation
    (slot = batch row). Static shapes keep every decode step the same
    compiled executable — no recompilation as requests come and go.
  * Requests queue in; free slots are filled by running prefill for one
    request (its tokens right-padded to the slot's prompt bucket), then the
    slot joins the batched decode step.
  * Finished slots (EOS or max_tokens) are released.

The engine is deliberately synchronous/deterministic (host loop) — the
scheduling policy is the substrate a real async server would wrap.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelAPI
from repro.serve import sampling


@dataclasses.dataclass
class Request:
    uid: int
    tokens: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 8
    max_len: int = 512
    eos_id: int = 2
    prompt_bucket: int = 64        # prompts padded up to this length


class ServeEngine:
    """Host-side continuous batching around jitted prefill/decode."""

    def __init__(self, model: ModelAPI, params, ecfg: EngineConfig,
                 rng=None):
        self.model = model
        self.params = params
        self.ecfg = ecfg
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        cfg = model.cfg

        self._decode = jax.jit(
            lambda p, tok, st: model.decode_step(p, tok, st))
        self._prefill = jax.jit(
            lambda p, batch: model.prefill(p, batch, ecfg.max_len))

        # slot-pool state (single shared decode batch)
        self.state = model.init_decode_state(ecfg.n_slots, ecfg.max_len)
        self.slot_req: list[Request | None] = [None] * ecfg.n_slots
        self.slot_len = np.zeros(ecfg.n_slots, np.int32)
        self.last_token = np.zeros((ecfg.n_slots, 1), np.int32)
        self.queue: list[Request] = []
        self._uid = 0

    # ------------------------------------------------------------- plumbing
    def submit(self, tokens: list[int], max_new_tokens: int = 32,
               temperature: float = 0.0) -> Request:
        req = Request(self._uid, list(tokens), max_new_tokens, temperature)
        self._uid += 1
        self.queue.append(req)
        return req

    def _free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _write_slot(self, slot: int, prefill_state, req: Request,
                    first_logits):
        """Copy a single-sequence prefill cache into slot `slot` of the
        shared pool. Works on any state pytree whose per-seq arrays carry the
        batch axis in the same position as the pooled state."""
        def merge(pool, single):
            if pool.ndim == 0 or pool.shape == single.shape:
                return single  # scalars like "len" handled after
            # find the batch axis: the dim where pool==n_slots, single==1
            for ax in range(pool.ndim):
                if pool.shape[ax] == self.ecfg.n_slots and single.shape[ax] == 1:
                    return jax.lax.dynamic_update_slice_in_dim(
                        pool, single.astype(pool.dtype), slot, axis=ax)
            raise ValueError(f"no batch axis: {pool.shape} vs {single.shape}")

        plen = prefill_state["len"]
        pooled_len = self.state["len"]
        state = jax.tree.map(merge, self.state, prefill_state)
        # shared scalar length: engine slots decode in lockstep from the
        # pooled max; per-slot logical lengths tracked host-side
        state["len"] = jnp.maximum(pooled_len, plen)
        self.state = state
        self.slot_req[slot] = req
        self.slot_len[slot] = int(plen)
        tok = sampling.sample(first_logits[:, -1, :], req.temperature,
                              self._next_rng())
        self.last_token[slot] = np.asarray(tok)[:, None]
        req.out_tokens.append(int(tok[0]))

    def _next_rng(self):
        self.rng, sub = jax.random.split(self.rng)
        return sub

    # ----------------------------------------------------------------- run
    def _admit(self):
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            toks = jnp.asarray(req.tokens, jnp.int32)[None, :]
            batch = {"tokens": toks}
            logits, pstate = self._prefill(self.params, batch)
            self._write_slot(slot, pstate, req, logits)

    def step(self):
        """One engine iteration: admit + one batched decode step."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return
        tok = jnp.asarray(self.last_token)
        logits, self.state = self._decode(self.params, tok, self.state)
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            t = sampling.sample(logits[slot:slot + 1, -1, :],
                                req.temperature, self._next_rng())
            t_int = int(t[0])
            req.out_tokens.append(t_int)
            self.last_token[slot] = t_int
            self.slot_len[slot] += 1
            if (t_int == self.ecfg.eos_id
                    or len(req.out_tokens) >= req.max_new_tokens
                    or int(self.slot_len[slot]) >= self.ecfg.max_len - 1):
                req.done = True
                self.slot_req[slot] = None

    def run(self, max_steps: int = 1000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.step()
