"""Launch layer: mesh construction, multi-pod dry-run, roofline analysis,
training and serving drivers."""
