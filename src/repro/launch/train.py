"""End-to-end training driver.

Runs any zoo arch (reduced/smoke configs on CPU; full configs on a real
cluster) with the whole substrate engaged: sharded train state, synthetic
data pipeline, LSE loss-curve monitor (divergence detection + ETA), periodic
checkpointing with atomic commit + GC, and crash-resume.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --smoke --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint, configs
from repro.data import DataConfig, TokenPipeline
from repro.launch import mesh as mesh_lib
from repro.models import get_model
from repro.sharding import rules
from repro.train import (AdamWConfig, LossCurveMonitor, TrainConfig,
                         init_train_state, make_train_step,
                         train_state_specs)


def build(args):
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    model = get_model(cfg)
    tc = TrainConfig(
        optimizer=AdamWConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                              total_steps=args.steps),
        microbatches=args.microbatches)
    return cfg, model, tc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--target-loss", type=float, default=None)
    args = ap.parse_args(argv)

    cfg, model, tc = build(args)
    mesh = mesh_lib.make_host_mesh(model=args.model_parallel)
    print(f"[train] arch={cfg.arch} mesh={dict(mesh.shape)} "
          f"params≈{cfg.param_count()/1e6:.1f}M")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.global_batch)
    pipe = TokenPipeline(dcfg)

    state = init_train_state(model, jax.random.PRNGKey(args.steps))
    start_step = 0
    if args.ckpt_dir:
        last = checkpoint.latest_step(args.ckpt_dir)
        if last is not None:
            print(f"[train] resuming from step {last}")
            specs = train_state_specs(model)
            sh = rules.tree_shardings(
                mesh, specs, jax.eval_shape(lambda: state))
            state = checkpoint.restore(args.ckpt_dir, last, state,
                                       shardings=sh)
            start_step = last
            pipe.restore({"batch_idx": last * tc.microbatches or last})

    step_fn = jax.jit(make_train_step(model, tc), donate_argnums=(0,))
    monitor = LossCurveMonitor()

    t_last = time.time()
    for step in range(start_step, args.steps):
        batch = pipe.next()
        if cfg.family == "vlm":
            batch["extra_embeds"] = jnp.zeros(
                (args.global_batch // 1, cfg.n_image_tokens, cfg.d_model),
                jnp.bfloat16)
            batch["labels"] = jnp.concatenate(
                [jnp.zeros((batch["labels"].shape[0], cfg.n_image_tokens),
                           jnp.int32), batch["labels"]], axis=1)
            batch["loss_mask"] = jnp.concatenate(
                [jnp.zeros((batch["loss_mask"].shape[0], cfg.n_image_tokens),
                           jnp.float32), batch["loss_mask"]], axis=1)
        elif cfg.family == "audio":
            b = batch["tokens"].shape[0]
            batch = {"frames": jnp.zeros((b, args.seq_len, cfg.d_model),
                                         jnp.bfloat16),
                     "dec_tokens": batch["tokens"],
                     "labels": batch["labels"],
                     "loss_mask": batch["loss_mask"]}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        monitor.observe(step, loss)

        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t_last
            t_last = time.time()
            extras = ""
            if monitor.ready:
                extras = f" fit_slope={monitor.slope_at(step):+.2e}"
                if monitor.diverging(step):
                    extras += " DIVERGING"
                if args.target_loss:
                    eta = monitor.eta_to(args.target_loss, step)
                    extras += f" eta_steps={eta}"
            print(f"[train] step {step} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({dt:.1f}s){extras}", flush=True)

        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, step + 1, state)
            checkpoint.gc_old(args.ckpt_dir, keep=3)
            print(f"[train] checkpointed step {step + 1}", flush=True)

    print(f"[train] done. final loss {loss:.4f}")
    return loss


if __name__ == "__main__":
    main()
