"""Roofline-term extraction from compiled (partitioned) executables.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (DESIGN.md §6).

  compute_s    = per-device HLO FLOPs / 197e12
  memory_s     = per-device HLO bytes accessed / 819e9
  collective_s = per-device collective wire bytes / 50e9

``cost_analysis()`` on a compiled partitioned executable reports per-device
FLOPs/bytes (verified empirically in tests). Collective bytes are parsed from
the partitioned HLO text; wire-byte model per op (ring algorithm):
  all-reduce        2·(n-1)/n · bytes  ≈ 2·bytes
  all-gather        (n-1)/n · out_bytes ≈ out_bytes
  reduce-scatter    (n-1)/n · in_bytes  ≈ in_bytes
  all-to-all        (n-1)/n · bytes     ≈ bytes
  collective-permute  bytes
(n is not recovered per-op from text; the ≈ forms are used and noted.)
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# e.g. "f32[128,256]{1,0}" or "bf16[2,16]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device wire bytes by collective kind from partitioned HLO text.
    Skips the '-done' halves of async pairs (shape appears on both)."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done.1" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_txt)
        out[kind] = out.get(kind, 0.0) + b * _WIRE_FACTOR[kind]
    return out


@dataclasses.dataclass(frozen=True)
class Roofline:
    flops: float                 # per device
    bytes_accessed: float        # per device
    coll_bytes: float            # per device (wire model)
    coll_breakdown: dict
    peak_memory: int             # per device, bytes (from memory_analysis)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time = max of the three overlappable terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def summary(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "bytes_per_dev": self.bytes_accessed,
            "coll_bytes_per_dev": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s": self.step_s,
            "peak_memory_gb": self.peak_memory / 1e9,
            "coll_breakdown": self.coll_breakdown,
        }


def analyze(compiled) -> Roofline:
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    return Roofline(
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=sum(coll.values()),
        coll_breakdown=coll,
        peak_memory=int(peak),
    )


def model_flops(cfg, shape, n_tokens: int) -> float:
    """Useful-model FLOPs for the step: 6·N·D train, 2·N·D decode/prefill
    (N = active params)."""
    n_active = cfg.active_param_count()
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * n_tokens
