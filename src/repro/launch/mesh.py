"""Production mesh construction (TPU v5e target).

Functions, not module constants — importing this module never touches jax
device state. The dry-run sets XLA_FLAGS before importing anything.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.4.38; older releases have no explicit axis types
    from jax.sharding import AxisType
    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}
except ImportError:
    AxisType = None
    _AXIS_KW = lambda n: {}


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"), **_AXIS_KW(2))


def required_devices(multi_pod: bool) -> int:
    return 512 if multi_pod else 256
