"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
512 placeholder devices and extract roofline terms (no real allocation).

The os.environ lines below MUST run before any jax import (device count
locks on first backend init). Do not import this module from tests — run as
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES, shapes_for
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as roof
from repro.models import get_model
from repro.sharding import rules
from repro.train import TrainConfig, make_train_step, abstract_train_state, \
    train_state_specs


def _batch_shardings(mesh, batch_specs):
    """Shard every batch leaf's leading (batch) axis over the data axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    da = rules.data_axes(mesh)

    def one(sds):
        if sds.shape and sds.shape[0] % _axes_size(mesh, da) == 0:
            return NamedSharding(mesh, P(da, *([None] * (len(sds.shape) - 1))))
        return NamedSharding(mesh, P())
    return jax.tree.map(one, batch_specs)


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _serve_params(model):
    """Serving uses bf16 weights (halves weight reads + memory vs the f32
    training masters)."""
    import jax.numpy as jnp

    def cast(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        return s
    return jax.tree.map(cast, model.abstract_params())


def _overrides_for(shape, mesh):
    if shape.kind != "decode":
        return None
    if shape.global_batch < _axes_size(mesh, rules.data_axes(mesh)):
        return rules.LONG_CONTEXT_OVERRIDES
    return rules.DECODE_OVERRIDES


MICROBATCHES = int(os.environ.get("REPRO_MICROBATCHES", "8"))
# per-arch grad-accumulation overrides (memory floor tuning, §Perf)
ARCH_MICROBATCHES = {"dbrx-132b": 16}


def lower_cell(arch: str, shape_name: str, mesh, *, verbose: bool = False,
               cfg=None, microbatches: int | None = None):
    """Lower + compile one cell. Returns (compiled, roofline, meta)."""
    cfg = cfg or configs.get_config(arch)
    shape = SHAPES[shape_name]
    model = get_model(cfg)
    overrides = _overrides_for(shape, mesh)
    if microbatches is None:
        microbatches = ARCH_MICROBATCHES.get(arch, MICROBATCHES)

    t0 = time.time()
    # set_mesh (not `with mesh:`): activation sharding constraints inside
    # the models read the ambient abstract mesh at trace time
    jax.set_mesh(mesh)
    if True:
        if shape.kind == "train":
            tc = TrainConfig(microbatches=microbatches)
            step = make_train_step(model, tc)
            state_abs = abstract_train_state(model)
            state_specs = train_state_specs(model)
            state_sh = rules.tree_shardings(mesh, state_specs, state_abs,
                                            overrides=overrides)
            in_specs = model.input_specs(shape)
            batch_sh = _batch_shardings(mesh, in_specs)
            fn = jax.jit(step,
                         in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
            lowered = fn.lower(state_abs, in_specs)
        elif shape.kind == "prefill":
            params_abs = _serve_params(model)      # bf16 for serving
            pspecs = model.param_specs()
            params_sh = rules.tree_shardings(mesh, pspecs, params_abs,
                                             overrides=overrides)
            in_specs = model.input_specs(shape)
            batch_sh = _batch_shardings(mesh, in_specs)
            # constrain the produced cache like the decode path (otherwise
            # XLA may leave multi-TB caches unsharded — measured on qwen)
            state_abs = jax.eval_shape(
                lambda: model.init_decode_state(shape.global_batch,
                                                shape.seq_len))
            sspecs = model.decode_state_specs()
            state_sh = rules.tree_shardings(
                mesh, sspecs, state_abs,
                overrides=overrides or rules.DECODE_OVERRIDES)
            fn = jax.jit(
                lambda p, b: model.prefill(p, b, shape.seq_len),
                in_shardings=(params_sh, batch_sh),
                out_shardings=(None, state_sh))
            lowered = fn.lower(params_abs, in_specs)
        else:  # decode
            params_abs = _serve_params(model)      # bf16 for serving
            pspecs = model.param_specs()
            params_sh = rules.tree_shardings(mesh, pspecs, params_abs,
                                             overrides=overrides)
            in_specs = model.input_specs(shape)
            state_abs = in_specs["state"]
            sspecs = model.decode_state_specs()
            state_sh = rules.tree_shardings(mesh, sspecs, state_abs,
                                            overrides=overrides)
            tok_sh = _batch_shardings(mesh, {"token": in_specs["token"]})
            fn = jax.jit(model.decode_step,
                         in_shardings=(params_sh, tok_sh["token"], state_sh),
                         out_shardings=(None, state_sh),
                         donate_argnums=(2,))
            lowered = fn.lower(params_abs, in_specs["token"], state_abs)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    r = roof.analyze(compiled)
    n_tokens = model.batch_tokens(shape)
    mf = roof.model_flops(cfg, shape, n_tokens)
    n_dev = mesh.devices.size
    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": n_dev,
        "tokens_per_step": n_tokens,
        "model_flops_total": mf,
        "model_flops_per_dev": mf / n_dev,
        "useful_flops_ratio": (mf / n_dev) / max(r.flops, 1.0),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        **r.summary(),
    }
    if verbose:
        mem = compiled.memory_analysis()
        print(f"  memory_analysis: arg={mem.argument_size_in_bytes/1e9:.2f}GB "
              f"out={mem.output_size_in_bytes/1e9:.2f}GB "
              f"temp={mem.temp_size_in_bytes/1e9:.2f}GB "
              f"alias={mem.alias_size_in_bytes/1e9:.2f}GB", file=sys.stderr)
    return compiled, r, meta


# ------------------------------------------------------------ exact costs
def reduced_points(cfg):
    """Two reduced-depth configs (k_lo, cfg_lo), (k_hi, cfg_hi) + k_full such
    that every cost term is linear in k (identical per-group bodies):
        cost(full) = c_lo + (k_full - k_lo) · (c_hi - c_lo)/(k_hi - k_lo)
    k counts scan groups. zamba2 keeps its 3-layer tail in BOTH points so the
    tail contribution lands in the constant term (exact)."""
    import dataclasses as dc
    if cfg.family == "hybrid":
        tail = cfg.n_layers % cfg.attn_every
        k_full = cfg.n_layers // cfg.attn_every
        lo = dc.replace(cfg, n_layers=2 * cfg.attn_every + tail)
        hi = dc.replace(cfg, n_layers=4 * cfg.attn_every + tail)
        return (2, lo), (4, hi), k_full
    if cfg.family == "audio":
        k_full = cfg.n_enc_layers
        assert cfg.n_enc_layers == cfg.n_dec_layers
        lo = dc.replace(cfg, n_enc_layers=2, n_dec_layers=2, n_layers=4)
        hi = dc.replace(cfg, n_enc_layers=4, n_dec_layers=4, n_layers=8)
        return (2, lo), (4, hi), k_full
    from repro.models.transformer import group_size
    g = group_size(cfg) if cfg.family in ("dense", "moe", "vlm") else 1
    k_full = cfg.n_layers // g
    lo = dc.replace(cfg, n_layers=2 * g)
    hi = dc.replace(cfg, n_layers=4 * g)
    return (2, lo), (4, hi), k_full


def extrapolated_costs(arch: str, shape_name: str, mesh,
                       microbatches: int | None = None):
    """FLOPs / bytes / collective bytes with loop bodies counted correctly:
    compile reduced-depth configs fully UNROLLED (cm.UNROLL_ALL) and
    extrapolate in the scan group count — and, for train cells with gradient
    accumulation, bilinearly in (groups, microbatches): every cost term is
    α + β·L + γ·m + δ·L·m (identical bodies), solved from 4 points."""
    from repro.models import common as cm_mod
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    if microbatches is None:
        microbatches = MICROBATCHES
    (k_lo, cfg_lo), (k_hi, cfg_hi), k_full = reduced_points(cfg)
    m_target = microbatches if shape.kind == "train" else 1

    def run(c, m):
        _, r, _ = lower_cell(arch, shape_name, mesh, cfg=c, microbatches=m)
        return r

    cm_mod.UNROLL_ALL = True
    try:
        r_ll = run(cfg_lo, 1)
        r_hl = run(cfg_hi, 1)
        if m_target > 1:
            r_lm = run(cfg_lo, 2)
            r_hm = run(cfg_hi, 2)
    finally:
        cm_mod.UNROLL_ALL = False

    dk = (k_full - k_lo) / (k_hi - k_lo)

    def combine(get):
        # linear in L at m=1
        at_m1 = get(r_ll) + dk * (get(r_hl) - get(r_ll))
        if m_target == 1:
            return at_m1
        # bilinear: per-m slope also linear in L
        dm_lo = get(r_lm) - get(r_ll)          # m: 1 -> 2 at k_lo
        dm_hi = get(r_hm) - get(r_hl)
        dm_at_k = dm_lo + dk * (dm_hi - dm_lo)
        return at_m1 + (m_target - 1) * dm_at_k

    kinds = set(r_ll.coll_breakdown) | set(r_hl.coll_breakdown)
    if m_target > 1:
        kinds |= set(r_lm.coll_breakdown) | set(r_hm.coll_breakdown)
    coll = {k: combine(lambda r, k=k: r.coll_breakdown.get(k, 0.0))
            for k in kinds}
    return roof.Roofline(
        flops=combine(lambda r: r.flops),
        bytes_accessed=combine(lambda r: r.bytes_accessed),
        coll_bytes=sum(coll.values()),
        coll_breakdown=coll,
        peak_memory=0,  # memory comes from the full-depth compile
    )


def analyze_cell(arch: str, shape_name: str, mesh, *, exact: bool = True,
                 verbose: bool = False):
    """Full-depth compile (validity + memory) + exact extrapolated costs."""
    compiled, r_loop, meta = lower_cell(arch, shape_name, mesh,
                                        verbose=verbose)
    if not exact:
        return meta
    # Costs are extrapolated at microbatches=1: gradient accumulation leaves
    # per-step FLOPs / HBM bytes / collective bytes unchanged to first order
    # (same tokens, same math; it only adds 2 f32 passes over the grad
    # buffer per micro-step). Peak memory DOES depend on it and comes from
    # the full-depth compile above, which uses MICROBATCHES.
    r = extrapolated_costs(arch, shape_name, mesh, microbatches=1)
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    model = get_model(cfg)
    mf_dev = roof.model_flops(cfg, shape, model.batch_tokens(shape)) \
        / mesh.devices.size
    meta.update({
        "flops_per_dev": r.flops,
        "bytes_per_dev": r.bytes_accessed,
        "coll_bytes_per_dev": r.coll_bytes,
        "compute_s": r.compute_s,
        "memory_s": r.memory_s,
        "collective_s": r.collective_s,
        "coll_breakdown": r.coll_breakdown,
        "useful_flops_ratio": mf_dev / max(r.flops, 1.0),
        "loop_counted_flops": r_loop.flops,   # kept for reference
    })
    terms = {"compute": r.compute_s, "memory": r.memory_s,
             "collective": r.collective_s}
    meta["dominant"] = max(terms, key=terms.get)
    meta["step_s"] = max(terms.values())
    return meta


def run_cells(cells, multi_pod_modes, out_path=None, verbose=False,
              exact=True):
    results = []
    for mp in multi_pod_modes:
        mesh = mesh_lib.make_production_mesh(multi_pod=mp)
        for arch, shape_name in cells:
            tag = f"{arch} × {shape_name} × {'2x16x16' if mp else '16x16'}"
            print(f"[dryrun] {tag} ...", file=sys.stderr, flush=True)
            try:
                meta = analyze_cell(arch, shape_name, mesh, exact=exact,
                                    verbose=verbose)
                meta["status"] = "ok"
                print(f"[dryrun] {tag}: OK compute={meta['compute_s']:.4f}s "
                      f"memory={meta['memory_s']:.4f}s "
                      f"coll={meta['collective_s']:.4f}s "
                      f"dominant={meta['dominant']} "
                      f"peak={meta['peak_memory_gb']:.2f}GB "
                      f"(compile {meta['compile_s']}s)",
                      file=sys.stderr, flush=True)
            except Exception as e:  # noqa: BLE001 — record and continue
                meta = {"arch": arch, "shape": shape_name,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "error", "error": f"{type(e).__name__}: {e}"}
                print(f"[dryrun] {tag}: FAIL {meta['error']}",
                      file=sys.stderr, flush=True)
                if verbose:
                    traceback.print_exc()
            results.append(meta)
            if out_path:  # incremental write (cells are slow; crash-safe)
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1, default=str)
    if out_path:
        print(f"[dryrun] wrote {out_path}", file=sys.stderr)
    return results


def all_cells():
    cells = []
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        for shape in shapes_for(cfg):
            cells.append((arch, shape.name))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="off")
    ap.add_argument("--out", default=None)
    ap.add_argument("--verbose", action="store_true")
    ap.add_argument("--costs", choices=["exact", "loop"], default="exact",
                    help="exact = unrolled reduced-depth extrapolation; "
                         "loop = raw cost_analysis (loop bodies counted once)")
    args = ap.parse_args()

    if args.all:
        cells = all_cells()
    else:
        if not args.arch:
            ap.error("--arch or --all required")
        cfg = configs.get_config(args.arch)
        shapes = ([args.shape] if args.shape
                  else [s.name for s in shapes_for(cfg)])
        cells = [(args.arch, s) for s in shapes]
    mp = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    results = run_cells(cells, mp, args.out, args.verbose,
                        exact=args.costs == "exact")
    bad = [r for r in results if r["status"] != "ok"]
    print(json.dumps(results, indent=1, default=str))
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
