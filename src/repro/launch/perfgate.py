"""Roofline-anchored performance gate: make speed a tested invariant.

Extends ``launch.roofline`` (static HLO-derived ceilings for compiled
executables) with the *dynamic* half the bench harness needs:

1. **Measured bandwidth** — a STREAM-style triad microbenchmark run on the
   actual backend at import-of-first-use, so ceilings are anchored to the
   machine the numbers were produced on, not a hardware spec sheet.  Falls
   back to the hardware model (``roofline.HBM_BW``) when measurement is
   unavailable (and says so in the provenance).
2. **Memory-bound ceilings** for the moment/report passes.  The complexity
   analysis behind the paper (arXiv:cs/0308023) makes the moment pass
   provably memory-bound: every point is read exactly once (x, y and
   optionally w — 2 or 3 contiguous streams) against O(m²) output, so the
   floor on wall time is ``bytes_moved / bandwidth`` and the ceiling on
   throughput is ``bandwidth / bytes_per_point``.
3. **The gate** — ``check_gate`` compares one benchmark run (the rows of a
   ``BENCH_<rev>.json``) against a committed ``benchmarks/baseline.json``
   of per-row budgets: a max-slowdown factor vs the stored reference
   timing, plus a roofline-fraction floor that only binds on rows actually
   running on hardware (interpret-mode Pallas rows are correctness tools,
   ~100-1000× off; they are gated on regression only, never on absolute
   throughput).

``benchmarks/run.py --gate`` wires this into CI; a breach exits nonzero
with a report naming the row, its budget, and the measured value.
"""
from __future__ import annotations

import dataclasses
import time

from repro.launch import roofline

DTYPE_BYTES = 4                   # the fit stack streams f32 series

_BW_CACHE: dict[str, "Bandwidth"] = {}


@dataclasses.dataclass(frozen=True)
class Bandwidth:
    """Sustained memory bandwidth the ceilings are anchored to."""

    gbps: float                   # GB/s (1e9 bytes per second)
    source: str                   # "measured" | "model"
    backend: str

    @property
    def bytes_per_s(self) -> float:
        return self.gbps * 1e9


def measure_bandwidth(*, n_mb: int = 64, reps: int = 5, iters: int = 4,
                      backend: str | None = None,
                      force: bool = False) -> Bandwidth:
    """STREAM-style triad (a = b + s·c) on the running backend.

    Moves 3 arrays per call (read b, read c, write a); min-of-reps timing
    gives the *max* sustained bandwidth — the right anchor for a ceiling.
    Cached per backend.  Falls back to the ``roofline`` hardware model
    (TPU v5e HBM) if the measurement cannot run or produces nonsense.
    """
    import jax

    bk = backend or jax.default_backend()
    if not force and bk in _BW_CACHE:
        return _BW_CACHE[bk]
    try:
        import jax.numpy as jnp

        n = n_mb * (1 << 20) // DTYPE_BYTES
        b = jnp.arange(n, dtype=jnp.float32)
        c = jnp.ones((n,), jnp.float32)
        triad = jax.jit(lambda b, c: b + 0.5 * c)
        jax.block_until_ready(triad(b, c))            # compile
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = None
            for _ in range(iters):
                out = triad(b, c)
            jax.block_until_ready(out)
            best = min(best, (time.perf_counter() - t0) / iters)
        gbps = 3 * n * DTYPE_BYTES / best / 1e9
        if not (0.1 < gbps < 1e5):                    # nonsense guard
            raise ValueError(f"implausible bandwidth {gbps} GB/s")
        bw = Bandwidth(gbps=gbps, source="measured", backend=bk)
    except Exception:  # noqa: BLE001 — fall back to the hardware model
        bw = Bandwidth(gbps=roofline.HBM_BW / 1e9, source="model", backend=bk)
    _BW_CACHE[bk] = bw
    return bw


# ------------------------------------------------------------------ ceilings
def stream_bytes(n_points: int, *, streams: int = 2,
                 dtype_bytes: int = DTYPE_BYTES) -> int:
    """Bytes one single-pass accumulation must move: ``streams`` contiguous
    f32 reads per point (x, y and optionally w), O(m²) output ≈ 0."""
    if n_points < 0 or streams < 1:
        raise ValueError(f"n_points={n_points}, streams={streams}")
    return n_points * streams * dtype_bytes


def memory_s(bytes_moved: float, bandwidth: Bandwidth | float) -> float:
    """Memory-bound floor on wall time; monotone in ``bytes_moved``."""
    bps = (bandwidth.bytes_per_s if isinstance(bandwidth, Bandwidth)
           else float(bandwidth))
    if bytes_moved < 0:
        raise ValueError(f"bytes_moved={bytes_moved}")
    if bps <= 0:
        raise ValueError(f"bandwidth={bps}")
    return bytes_moved / bps


def ceiling_mpts(bandwidth: Bandwidth | float, *, streams: int = 2,
                 dtype_bytes: int = DTYPE_BYTES) -> float:
    """Memory-bound ceiling on point throughput, in Mpts/s."""
    return 1e6 / memory_s(1e6 * streams * dtype_bytes, bandwidth) / 1e6


def roofline_fraction(achieved_mpts: float, bandwidth: Bandwidth | float, *,
                      streams: int = 2,
                      dtype_bytes: int = DTYPE_BYTES) -> float:
    """Fraction of the memory-bound ceiling one measured row achieved."""
    return achieved_mpts / ceiling_mpts(bandwidth, streams=streams,
                                        dtype_bytes=dtype_bytes)


# ---------------------------------------------------------------------- gate
@dataclasses.dataclass(frozen=True)
class Breach:
    row: str
    kind: str                 # "regression" | "roofline" | "missing" | "failed"
    budget: float | None
    measured: float | None
    detail: str

    def render(self) -> str:
        return f"BREACH [{self.kind}] {self.row}: {self.detail}"


@dataclasses.dataclass
class GateReport:
    breaches: list[Breach]
    checked: list[str]
    skipped: list[str]            # baseline rows whose floor did not bind

    @property
    def ok(self) -> bool:
        return not self.breaches

    def render(self) -> str:
        lines = [f"perf gate: {len(self.checked)} rows checked, "
                 f"{len(self.breaches)} breach(es)"]
        for b in self.breaches:
            lines.append("  " + b.render())
        for s in self.skipped:
            lines.append(f"  note: {s}")
        if self.ok:
            lines.append("  PASS — every gated row within budget")
        return "\n".join(lines)

    def summary(self) -> dict:
        return {
            "ok": self.ok,
            "checked": self.checked,
            "skipped": self.skipped,
            "breaches": [dataclasses.asdict(b) for b in self.breaches],
        }


def check_gate(rows: list[dict], baseline: dict) -> GateReport:
    """Gate one benchmark run against the committed per-row budgets.

    ``rows``: the ``rows`` list of a BENCH_<rev>.json (the new schema:
    ``us_per_call``, optional ``mpts_per_s``/``fits_per_s``,
    ``roofline_frac``, ``interpret``, ``status``).
    ``baseline``: the parsed ``benchmarks/baseline.json``::

        {"default_max_slowdown": 3.0,
         "rows": {"<name>": {"ref_us": 123.4,
                             "max_slowdown": 2.5,        # optional
                             "min_roofline_frac": 0.05}, # optional
                  ...}}

    Per gated row: a ``failed`` status or a missing row is a breach (the
    trajectory must show holes, not pretend coverage); ``us_per_call``
    above ``ref_us × max_slowdown`` is a regression breach;
    ``roofline_frac`` below the floor is a breach **unless** the row ran
    in interpret mode (interpret rows are excluded from absolute floors —
    they prove correctness, not speed).
    """
    default_slow = float(baseline.get("default_max_slowdown", 3.0))
    by_name = {r.get("name"): r for r in rows}
    breaches: list[Breach] = []
    checked: list[str] = []
    skipped: list[str] = []

    for name, budget in baseline.get("rows", {}).items():
        checked.append(name)
        r = by_name.get(name)
        if r is None:
            breaches.append(Breach(name, "missing", None, None,
                                   "row absent from this run (bench did not "
                                   "produce it)"))
            continue
        if r.get("status", "ok") != "ok":
            breaches.append(Breach(
                name, "failed", None, None,
                f"row failed: {r.get('error', 'unknown error')}"))
            continue

        us = float(r["us_per_call"])
        ref = budget.get("ref_us")
        if ref is not None:
            cap = float(ref) * float(budget.get("max_slowdown",
                                                default_slow))
            if us > cap:
                breaches.append(Breach(
                    name, "regression", cap, us,
                    f"us_per_call={us:.1f} exceeds budget {cap:.1f} "
                    f"(ref {float(ref):.1f}us × "
                    f"{float(budget.get('max_slowdown', default_slow)):.2f} "
                    "max slowdown)"))

        floor = budget.get("min_roofline_frac")
        if floor is not None:
            frac = r.get("roofline_frac")
            if r.get("interpret"):
                skipped.append(f"{name}: interpret-mode row — roofline "
                               "floor not applied")
            elif frac is None:
                breaches.append(Breach(
                    name, "roofline", float(floor), None,
                    "baseline sets a roofline floor but the row carries "
                    "no roofline_frac"))
            elif float(frac) < float(floor):
                breaches.append(Breach(
                    name, "roofline", float(floor), float(frac),
                    f"roofline_frac={float(frac):.4f} below floor "
                    f"{float(floor):.4f} "
                    f"(achieved {r.get('mpts_per_s', '?')} Mpts/s vs the "
                    "memory-bound ceiling)"))
    return GateReport(breaches, checked, skipped)


def make_baseline(rows: list[dict], *, max_slowdown: float = 3.0,
                  roofline_margin: float = 0.5,
                  gated: tuple[str, ...] | None = None) -> dict:
    """Derive a fresh baseline from one run (``run.py --rebaseline``).

    ``ref_us`` is the run's min-of-reps timing; roofline floors are set at
    ``roofline_margin`` of the achieved fraction, only for rows that ran on
    hardware (never for interpret rows).
    """
    out: dict = {"default_max_slowdown": max_slowdown, "rows": {}}
    for r in rows:
        if r.get("status", "ok") != "ok":
            continue
        if gated is not None and r["name"] not in gated:
            continue
        budget: dict = {"ref_us": float(r["us_per_call"])}
        frac = r.get("roofline_frac")
        if frac is not None and not r.get("interpret"):
            budget["min_roofline_frac"] = round(float(frac)
                                                * roofline_margin, 5)
        out["rows"][r["name"]] = budget
    return out
