"""Serving drivers: continuous-batching engines over fixed slot pools.

Fit serving (the paper's workload — the flagship path):

    PYTHONPATH=src python -m repro.launch.serve --requests 200

Fault-tolerant fleet serving under chaos (replicated workers, seeded
fault injection, parity check against the fault-free run):

    PYTHONPATH=src python -m repro.launch.serve --workload fleet \
        --workers 4 --chaos "crash=1,stall=1,poison=1" --assert-parity

Token serving (the zoo-arch decode engine):

    PYTHONPATH=src python -m repro.launch.serve --workload tokens \
        --arch internlm2-1.8b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def serve_fits(args) -> None:
    from repro.serve import FitServeConfig, FitServeEngine

    cfg = FitServeConfig(degree=args.degree, n_slots=args.slots,
                         buckets=tuple(args.buckets), ridge=1e-9,
                         engine=args.engine)
    engine = FitServeEngine(cfg)

    rng = np.random.default_rng(7)
    coef = rng.normal(0, 1, args.degree + 1)

    def make_request():
        # ragged lengths, log-uniform: most requests short, a heavy tail
        n = int(np.exp(rng.uniform(np.log(args.min_n), np.log(args.max_n))))
        x = rng.uniform(-2, 2, n).astype(np.float32)
        y = (np.polyval(coef[::-1], x)
             + rng.normal(0, 0.1, n)).astype(np.float32)
        return engine.submit(x, y)

    execs = engine.warmup()   # compiles every bucket's ingest + the solve

    reqs = [make_request() for _ in range(args.requests)]
    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0
    recompiles = engine.compiled_executables() - execs
    done = sum(r.done for r in reqs)
    pts = sum(r.n for r in reqs)
    print(f"[serve-fits] {done}/{len(reqs)} fits, {pts} points in {dt:.2f}s "
          f"({done / dt:.1f} fits/s, {pts / dt / 1e6:.2f} Mpts/s, "
          f"{execs} executables, {recompiles} recompiles after warmup)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: n={r.n} R={r.r:.4f} sse={r.sse:.3g} "
              f"coeffs={np.round(r.coeffs, 3)}")
    assert done == len(reqs)
    assert recompiles == 0, f"{recompiles} recompiles during steady state"


def serve_fleet(args) -> None:
    """Drive the fault-tolerant fleet twice — fault-free, then under the
    requested chaos schedule — and report recovery numbers (and, with
    ``--assert-parity``, enforce the bitwise chaos-parity invariant)."""
    from repro.runtime.chaos import ChaosSchedule
    from repro.serve import FitServeConfig, FleetConfig, FitFleet

    rng = np.random.default_rng(7)
    coef = rng.normal(0, 1, args.degree + 1)
    series = []
    for _ in range(args.requests):
        n = int(np.exp(rng.uniform(np.log(args.min_n), np.log(args.max_n))))
        x = rng.uniform(-2, 2, n).astype(np.float32)
        y = (np.polyval(coef[::-1], x)
             + rng.normal(0, 0.1, n)).astype(np.float32)
        series.append((x, y))

    def run(chaos):
        cfg = FleetConfig(fit=FitServeConfig(degree=args.degree),
                          n_workers=args.workers, chaos=chaos,
                          straggler_threshold=2.0)
        fleet = FitFleet(cfg)
        t0 = time.perf_counter()
        reqs = [fleet.submit(x, y) for x, y in series]
        fleet.run(max_ticks=50_000)
        dt = time.perf_counter() - t0
        return fleet, reqs, dt

    base_fleet, base, base_dt = run(None)
    q0 = base_fleet.latency_quantiles()
    print(f"[fleet] fault-free: {base_fleet.stats['completed']}"
          f"/{len(base)} fits in {base_dt:.2f}s over {base_fleet.tick} "
          f"ticks (p50 {q0['p50']:.0f} / p99 {q0['p99']:.0f} ticks)")

    chaos = ChaosSchedule.parse(args.chaos, args.chaos_seed, args.workers,
                                horizon=args.chaos_horizon)
    fleet, reqs, dt = run(chaos)
    s, q = fleet.stats, fleet.latency_quantiles()
    lost = [r.uid for r in reqs if not r.done or r.failed]
    print(f"[fleet] chaos '{args.chaos}' (seed {args.chaos_seed}): "
          f"{s['completed']}/{len(reqs)} fits in {dt:.2f}s over "
          f"{fleet.tick} ticks (p50 {q['p50']:.0f} / p99 {q['p99']:.0f})")
    print(f"[fleet]   lost={len(lost)} deaths={s['worker_deaths']} "
          f"revivals={s['revivals']} replays={s['replays']} "
          f"hedges={s['hedges']} resends={s['resends']} "
          f"poisoned={s['poisoned']} shed={s['shed']}")
    assert not lost, f"lost requests: {lost}"
    if args.assert_parity:
        for b, c in zip(base, reqs):
            assert c.count == b.count, (c.uid, c.count, b.count)
            np.testing.assert_array_equal(np.asarray(c.coeffs),
                                          np.asarray(b.coeffs))
        print(f"[fleet] parity OK: {len(reqs)} requests bit-identical "
              "to the fault-free run")


def serve_tokens(args) -> None:
    from repro import configs
    from repro.models import get_model
    from repro.serve import EngineConfig, ServeEngine

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params,
                         EngineConfig(n_slots=args.slots,
                                      max_len=args.max_len))

    rng = jax.random.PRNGKey(7)
    reqs = []
    for i in range(args.requests):
        rng, sub = jax.random.split(rng)
        prompt = [int(t) for t in
                  jax.random.randint(sub, (8 + i % 8,), 3,
                                     cfg.vocab_size - 1)]
        reqs.append(engine.submit(prompt, max_new_tokens=args.max_new,
                                  temperature=0.8))
    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {done}/{len(reqs)} finished, {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {len(r.out_tokens)} tokens "
              f"{r.out_tokens[:10]}...")
    assert done == len(reqs)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("fits", "fleet", "tokens"),
                    default="fits")
    # per-workload defaults: fits churns cheap requests, tokens decodes
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--slots", type=int, default=None)
    # fit-serving knobs
    ap.add_argument("--degree", type=int, default=3)
    ap.add_argument("--buckets", type=int, nargs="+", default=[256, 2048])
    ap.add_argument("--min-n", type=int, default=16)
    ap.add_argument("--max-n", type=int, default=8192)
    ap.add_argument("--engine", default="auto",
                    help="repro.engine path: auto/reference/kernel/...")
    # fleet knobs
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--chaos", default="crash=1,stall=1",
                    help='fault counts, e.g. "crash=1,stall=1,poison=2"')
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--chaos-horizon", type=int, default=8,
                    help="fault ticks are drawn in [1, horizon); keep it "
                         "below the run length or nothing fires")
    ap.add_argument("--assert-parity", action="store_true",
                    help="require bitwise parity with the fault-free run")
    # token-serving knobs
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args(argv)
    if args.workload == "fits":
        args.requests = 200 if args.requests is None else args.requests
        args.slots = 8 if args.slots is None else args.slots
        serve_fits(args)
    elif args.workload == "fleet":
        args.requests = 32 if args.requests is None else args.requests
        serve_fleet(args)
    else:
        args.requests = 12 if args.requests is None else args.requests
        args.slots = 4 if args.slots is None else args.slots
        serve_tokens(args)


if __name__ == "__main__":
    main()
