"""Serving drivers: continuous-batching engines over fixed slot pools.

Fit serving (the paper's workload — the flagship path):

    PYTHONPATH=src python -m repro.launch.serve --requests 200

Fault-tolerant fleet serving under chaos (replicated workers, seeded
fault injection, parity check against the fault-free run):

    PYTHONPATH=src python -m repro.launch.serve --workload fleet \
        --workers 4 --chaos "crash=1,stall=1,poison=1" --assert-parity

Token serving (the zoo-arch decode engine):

    PYTHONPATH=src python -m repro.launch.serve --workload tokens \
        --arch internlm2-1.8b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def serve_fits(args) -> None:
    from repro import obs as obs_lib
    from repro.serve import FitServeConfig, FitServeEngine

    cfg = FitServeConfig(degree=args.degree, n_slots=args.slots,
                         buckets=tuple(args.buckets), ridge=1e-9,
                         engine=args.engine)
    obs = obs_lib.Observability.on() if args.obs else obs_lib.NULL_OBS
    engine = FitServeEngine(cfg, obs=obs)

    rng = np.random.default_rng(7)
    coef = rng.normal(0, 1, args.degree + 1)

    def make_request():
        # ragged lengths, log-uniform: most requests short, a heavy tail
        n = int(np.exp(rng.uniform(np.log(args.min_n), np.log(args.max_n))))
        x = rng.uniform(-2, 2, n).astype(np.float32)
        y = (np.polyval(coef[::-1], x)
             + rng.normal(0, 0.1, n)).astype(np.float32)
        return engine.submit(x, y)

    execs = engine.warmup()   # compiles every bucket's ingest + the solve

    reqs = [make_request() for _ in range(args.requests)]
    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0
    recompiles = engine.compiled_executables() - execs
    done = sum(r.done for r in reqs)
    pts = sum(r.n for r in reqs)
    print(f"[serve-fits] {done}/{len(reqs)} fits, {pts} points in {dt:.2f}s "
          f"({done / dt:.1f} fits/s, {pts / dt / 1e6:.2f} Mpts/s, "
          f"{execs} executables, {recompiles} recompiles after warmup)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: n={r.n} R={r.r:.4f} sse={r.sse:.3g} "
              f"coeffs={np.round(r.coeffs, 3)}")
    assert done == len(reqs)
    assert recompiles == 0, f"{recompiles} recompiles during steady state"
    if args.obs:
        snap = obs.metrics.snapshot()
        lat = obs.metrics.histogram("fit_latency_steps")
        print(f"[serve-fits] obs: submitted="
              f"{snap['counters']['submitted']} completed="
              f"{snap['counters']['completed']} latency p50/p99 = "
              f"{lat.quantile(0.5):.0f}/{lat.quantile(0.99):.0f} steps")
        print(obs.metrics.render_prometheus(), end="")


def serve_fleet(args) -> None:
    """Drive the fault-tolerant fleet twice — fault-free, then under the
    requested chaos schedule — and report recovery numbers (and, with
    ``--assert-parity``, enforce the bitwise chaos-parity invariant).

    ``--obs`` turns on the observability layer for the chaos run: trace
    spans on the virtual tick clock, a live summary every ``--obs-every``
    ticks (mid-run sketch quantiles + SLO breach forecast), event-log
    invariant assertions, JSONL + Chrome-trace artifacts under
    ``--obs-dir``, and a Prometheus text exposition."""
    from repro.runtime.chaos import ChaosSchedule
    from repro.serve import FitServeConfig, FleetConfig, FitFleet

    rng = np.random.default_rng(7)
    coef = rng.normal(0, 1, args.degree + 1)
    series = []
    for _ in range(args.requests):
        n = int(np.exp(rng.uniform(np.log(args.min_n), np.log(args.max_n))))
        x = rng.uniform(-2, 2, n).astype(np.float32)
        y = (np.polyval(coef[::-1], x)
             + rng.normal(0, 0.1, n)).astype(np.float32)
        series.append((x, y))

    def run(chaos, obs=False):
        cfg = FleetConfig(fit=FitServeConfig(degree=args.degree),
                          n_workers=args.workers, chaos=chaos,
                          straggler_threshold=2.0, trace=obs,
                          slo_p99=args.slo_p99 if obs else None)
        fleet = FitFleet(cfg)
        t0 = time.perf_counter()
        reqs = [fleet.submit(x, y) for x, y in series]
        if obs:
            for _ in range(50_000):
                if not fleet.pending:
                    break
                fleet.step()
                if fleet.tick % args.obs_every == 0:
                    _obs_live_line(fleet)
            else:
                raise RuntimeError(f"{fleet.pending} requests pending")
        else:
            fleet.run(max_ticks=50_000)
        dt = time.perf_counter() - t0
        return fleet, reqs, dt

    base_fleet, base, base_dt = run(None)
    q0 = base_fleet.latency_quantiles()
    print(f"[fleet] fault-free: {base_fleet.stats['completed']}"
          f"/{len(base)} fits in {base_dt:.2f}s over {base_fleet.tick} "
          f"ticks (p50 {q0['p50']:.0f} / p99 {q0['p99']:.0f} ticks)")

    chaos = ChaosSchedule.parse(args.chaos, args.chaos_seed, args.workers,
                                horizon=args.chaos_horizon)
    fleet, reqs, dt = run(chaos, obs=args.obs)
    s, q = fleet.stats, fleet.latency_quantiles()
    lost = [r.uid for r in reqs if not r.done or r.failed]
    print(f"[fleet] chaos '{args.chaos}' (seed {args.chaos_seed}): "
          f"{s['completed']}/{len(reqs)} fits in {dt:.2f}s over "
          f"{fleet.tick} ticks (p50 {q['p50']:.0f} / p99 {q['p99']:.0f})")
    print(f"[fleet]   lost={len(lost)} deaths={s['worker_deaths']} "
          f"revivals={s['revivals']} replays={s['replays']} "
          f"hedges={s['hedges']} ({s['hedge_wins']}W/{s['hedge_losses']}L) "
          f"resends={s['resends']} poisoned={s['poisoned']} "
          f"shed={s['shed']} queue_hwm="
          f"{fleet.metrics.gauge('queue_depth').hwm:.0f}")
    assert not lost, f"lost requests: {lost}"
    if args.obs:
        _obs_finish(args, fleet, reqs)
    if args.assert_parity:
        for b, c in zip(base, reqs):
            assert c.count == b.count, (c.uid, c.count, b.count)
            np.testing.assert_array_equal(np.asarray(c.coeffs),
                                          np.asarray(b.coeffs))
        print(f"[fleet] parity OK: {len(reqs)} requests bit-identical "
              "to the fault-free run")


def _obs_live_line(fleet) -> None:
    q = fleet.latency_quantiles()
    line = (f"[obs] tick {fleet.tick:>5}  completed="
            f"{fleet.stats['completed']:<4} pending={fleet.pending:<4} "
            f"p50/p99={q['p50']:.0f}/{q['p99']:.0f}")
    for ref, rep in fleet.slo.report(fleet.tick).items():
        eta = rep["breach_eta_ticks"]
        line += (f"  slo[{ref}<{rep['threshold']:g}]: "
                 f"eta={'-' if eta is None else eta}")
    print(line)


def _obs_finish(args, fleet, reqs) -> None:
    """Assert the trace invariants, write the artifacts, print the
    exposition — the obs-smoke CI job's contract."""
    import os

    from repro import obs as obs_lib

    events = fleet.tracer.events
    obs_lib.assert_valid(events)
    # every replay the request surfaced is annotated in its span chain
    for r in reqs:
        names = fleet.tracer.names_for(r.uid)
        assert names.count("replay") == r.replays, \
            (r.uid, r.replays, names)
        if r.hedged:
            assert "hedge" in names, (r.uid, names)
    terminal = sum(1 for e in events
                   if e["ph"] == "i" and e["name"] in obs_lib.trace.TERMINAL)
    print(f"[obs] trace OK: {len(events)} events, {terminal} terminal "
          f"spans, invariants hold")
    os.makedirs(args.obs_dir, exist_ok=True)
    jsonl = os.path.join(args.obs_dir, "fleet_trace.jsonl")
    chrome = os.path.join(args.obs_dir, "fleet_trace.chrome.json")
    fleet.tracer.export_jsonl(jsonl)
    fleet.tracer.export_chrome(chrome)
    with open(os.path.join(args.obs_dir, "fleet_metrics.prom"), "w") as f:
        f.write(fleet.metrics.render_prometheus())
    print(f"[obs] artifacts: {jsonl}, {chrome}")
    print(fleet.metrics.render_prometheus(), end="")


def serve_tokens(args) -> None:
    from repro import configs
    from repro.models import get_model
    from repro.serve import EngineConfig, ServeEngine

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params,
                         EngineConfig(n_slots=args.slots,
                                      max_len=args.max_len))

    rng = jax.random.PRNGKey(7)
    reqs = []
    for i in range(args.requests):
        rng, sub = jax.random.split(rng)
        prompt = [int(t) for t in
                  jax.random.randint(sub, (8 + i % 8,), 3,
                                     cfg.vocab_size - 1)]
        reqs.append(engine.submit(prompt, max_new_tokens=args.max_new,
                                  temperature=0.8))
    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {done}/{len(reqs)} finished, {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {len(r.out_tokens)} tokens "
              f"{r.out_tokens[:10]}...")
    assert done == len(reqs)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("fits", "fleet", "tokens"),
                    default="fits")
    # per-workload defaults: fits churns cheap requests, tokens decodes
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--slots", type=int, default=None)
    # fit-serving knobs
    ap.add_argument("--degree", type=int, default=3)
    ap.add_argument("--buckets", type=int, nargs="+", default=[256, 2048])
    ap.add_argument("--min-n", type=int, default=16)
    ap.add_argument("--max-n", type=int, default=8192)
    ap.add_argument("--engine", default="auto",
                    help="repro.engine path: auto/reference/kernel/...")
    # fleet knobs
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--chaos", default="crash=1,stall=1",
                    help='fault counts, e.g. "crash=1,stall=1,poison=2"')
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--chaos-horizon", type=int, default=8,
                    help="fault ticks are drawn in [1, horizon); keep it "
                         "below the run length or nothing fires")
    ap.add_argument("--assert-parity", action="store_true",
                    help="require bitwise parity with the fault-free run")
    # observability knobs
    ap.add_argument("--obs", action="store_true",
                    help="metrics + trace spans + SLO board: live summary,"
                         " invariant assertions, JSONL/Chrome artifacts")
    ap.add_argument("--obs-dir", default="obs_artifacts",
                    help="where --obs writes trace/exposition artifacts")
    ap.add_argument("--obs-every", type=int, default=64,
                    help="live summary cadence in virtual ticks")
    ap.add_argument("--slo-p99", type=float, default=200.0,
                    help="latency p99 SLO threshold (ticks) the SLO "
                         "monitor forecasts breaches against")
    # token-serving knobs
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args(argv)
    if args.workload == "fits":
        args.requests = 200 if args.requests is None else args.requests
        args.slots = 8 if args.slots is None else args.slots
        serve_fits(args)
    elif args.workload == "fleet":
        args.requests = 32 if args.requests is None else args.requests
        serve_fleet(args)
    else:
        args.requests = 12 if args.requests is None else args.requests
        args.slots = 4 if args.slots is None else args.slots
        serve_tokens(args)


if __name__ == "__main__":
    main()
