"""Batched serving driver: continuous-batching engine over a zoo arch.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import configs
from repro.models import get_model
from repro.serve import EngineConfig, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params,
                         EngineConfig(n_slots=args.slots,
                                      max_len=args.max_len))

    rng = jax.random.PRNGKey(7)
    reqs = []
    for i in range(args.requests):
        rng, sub = jax.random.split(rng)
        prompt = [int(t) for t in
                  jax.random.randint(sub, (8 + i % 8,), 3,
                                     cfg.vocab_size - 1)]
        reqs.append(engine.submit(prompt, max_new_tokens=args.max_new,
                                  temperature=0.8))
    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {done}/{len(reqs)} finished, {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {len(r.out_tokens)} tokens "
              f"{r.out_tokens[:10]}...")
    assert done == len(reqs)


if __name__ == "__main__":
    main()
