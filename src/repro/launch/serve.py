"""Serving drivers: continuous-batching engines over fixed slot pools.

Fit serving (the paper's workload — the flagship path):

    PYTHONPATH=src python -m repro.launch.serve --requests 200

Token serving (the zoo-arch decode engine):

    PYTHONPATH=src python -m repro.launch.serve --workload tokens \
        --arch internlm2-1.8b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def serve_fits(args) -> None:
    from repro.serve import FitServeConfig, FitServeEngine

    cfg = FitServeConfig(degree=args.degree, n_slots=args.slots,
                         buckets=tuple(args.buckets), ridge=1e-9,
                         engine=args.engine)
    engine = FitServeEngine(cfg)

    rng = np.random.default_rng(7)
    coef = rng.normal(0, 1, args.degree + 1)

    def make_request():
        # ragged lengths, log-uniform: most requests short, a heavy tail
        n = int(np.exp(rng.uniform(np.log(args.min_n), np.log(args.max_n))))
        x = rng.uniform(-2, 2, n).astype(np.float32)
        y = (np.polyval(coef[::-1], x)
             + rng.normal(0, 0.1, n)).astype(np.float32)
        return engine.submit(x, y)

    execs = engine.warmup()   # compiles every bucket's ingest + the solve

    reqs = [make_request() for _ in range(args.requests)]
    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0
    recompiles = engine.compiled_executables() - execs
    done = sum(r.done for r in reqs)
    pts = sum(r.n for r in reqs)
    print(f"[serve-fits] {done}/{len(reqs)} fits, {pts} points in {dt:.2f}s "
          f"({done / dt:.1f} fits/s, {pts / dt / 1e6:.2f} Mpts/s, "
          f"{execs} executables, {recompiles} recompiles after warmup)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: n={r.n} R={r.r:.4f} sse={r.sse:.3g} "
              f"coeffs={np.round(r.coeffs, 3)}")
    assert done == len(reqs)
    assert recompiles == 0, f"{recompiles} recompiles during steady state"


def serve_tokens(args) -> None:
    from repro import configs
    from repro.models import get_model
    from repro.serve import EngineConfig, ServeEngine

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params,
                         EngineConfig(n_slots=args.slots,
                                      max_len=args.max_len))

    rng = jax.random.PRNGKey(7)
    reqs = []
    for i in range(args.requests):
        rng, sub = jax.random.split(rng)
        prompt = [int(t) for t in
                  jax.random.randint(sub, (8 + i % 8,), 3,
                                     cfg.vocab_size - 1)]
        reqs.append(engine.submit(prompt, max_new_tokens=args.max_new,
                                  temperature=0.8))
    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {done}/{len(reqs)} finished, {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {len(r.out_tokens)} tokens "
              f"{r.out_tokens[:10]}...")
    assert done == len(reqs)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("fits", "tokens"), default="fits")
    # per-workload defaults: fits churns cheap requests, tokens decodes
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--slots", type=int, default=None)
    # fit-serving knobs
    ap.add_argument("--degree", type=int, default=3)
    ap.add_argument("--buckets", type=int, nargs="+", default=[256, 2048])
    ap.add_argument("--min-n", type=int, default=16)
    ap.add_argument("--max-n", type=int, default=8192)
    ap.add_argument("--engine", default="auto",
                    help="repro.engine path: auto/reference/kernel/...")
    # token-serving knobs
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args(argv)
    if args.workload == "fits":
        args.requests = 200 if args.requests is None else args.requests
        args.slots = 8 if args.slots is None else args.slots
        serve_fits(args)
    else:
        args.requests = 12 if args.requests is None else args.requests
        args.slots = 4 if args.slots is None else args.slots
        serve_tokens(args)


if __name__ == "__main__":
    main()
