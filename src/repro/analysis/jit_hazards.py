"""RL-RECOMPILE and RL-TRACERLEAK: the jit compile-cache hazard passes.

The serving stack's headline invariant is *zero recompiles across request
churn* (warmup compiles a fixed executable set; every later step reuses
it).  That invariant dies in two ways nothing type-checks:

* **RL-RECOMPILE** — something non-static reaches a compile-cache key: a
  mutable literal passed to a ``static_argnames`` position (jit retraces
  per call, or throws ``unhashable``), a mutable default on a dataclass
  that rides into specs/plans (silently shared state AND an unhashable
  static arg), an f-string or ``id()``-derived key in a compile-cache dict
  (cache misses forever / keys unstable across runs), or a
  ``static_argnames`` entry naming a parameter the function doesn't have
  (jit fails only at first call).
* **RL-TRACERLEAK** — Python control flow on traced values inside code
  reachable from a ``jax.jit`` or ``pallas_call``: ``if``/``while``/
  ``bool()`` on a ``jnp`` expression raises ``TracerBoolConversionError``
  at trace time *on the paths a test happens to trace* — the others wait
  in ambush; host callbacks inside ``lax.scan``/``fori_loop``/
  ``while_loop`` bodies force a host sync per iteration (the
  zero-recompile serving loop's silent performance killer).

Reachability is per-module: jit/pallas roots are functions decorated with
``jax.jit`` (bare or via ``functools.partial``) or passed (possibly
through ``functools.partial``) into a ``pallas_call``; the call graph is
then closed over bare-name calls within the module.
"""
from __future__ import annotations

import ast

from repro.analysis.core import (Checker, FileContext, Finding, call_name,
                                 dotted_name, iter_decorators)

MUTABLE_CALLS = {"list", "dict", "set", "bytearray"}
# jnp helpers that return static Python values — safe in `if` tests
STATIC_SAFE_JNP = {"dtype", "finfo", "iinfo", "result_type", "issubdtype",
                   "zeros", "ones"}
HOST_CALLBACKS = {"print", "jax.debug.print", "jax.debug.callback",
                  "jax.debug.breakpoint", "io_callback",
                  "jax.experimental.io_callback", "pure_callback",
                  "jax.pure_callback", "jax.experimental.host_callback.call"}
SCAN_FAMILY = {"jax.lax.scan", "lax.scan", "jax.lax.fori_loop",
               "lax.fori_loop", "jax.lax.while_loop", "lax.while_loop"}


def _is_mutable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and call_name(node) in MUTABLE_CALLS:
        return True
    return False


def _jit_static_names(fn: ast.FunctionDef | ast.AsyncFunctionDef
                      ) -> tuple[bool, tuple[str, ...]]:
    """(is_jitted, static_argnames) from the decorator list."""
    for dec, name in iter_decorators(fn):
        base = name.split(".")[-1] if name else ""
        if name in ("jax.jit", "jit") or base == "jit":
            return True, ()
        if isinstance(dec, ast.Call):
            inner = ""
            if name.endswith("partial") and dec.args:
                inner = dotted_name(dec.args[0])
            if inner in ("jax.jit", "jit") or name in ("jax.jit", "jit"):
                statics: list[str] = []
                for kw in dec.keywords:
                    if kw.arg in ("static_argnames", "static_argnums") \
                            and isinstance(kw.value, (ast.Tuple, ast.List)):
                        for elt in kw.value.elts:
                            if isinstance(elt, ast.Constant) \
                                    and isinstance(elt.value, str):
                                statics.append(elt.value)
                    elif kw.arg == "static_argnames" \
                            and isinstance(kw.value, ast.Constant) \
                            and isinstance(kw.value.value, str):
                        statics.append(kw.value.value)
                return True, tuple(statics)
    return False, ()


def _param_names(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> set[str]:
    a = fn.args
    names = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


class RecompileChecker(Checker):
    name = "recompile"
    codes = ("RL-RECOMPILE",)
    scope = None

    def check(self, tree: ast.Module, ctx: FileContext) -> list[Finding]:
        out: list[Finding] = []
        self._check_dataclasses(tree, ctx, out)
        jit_statics = self._check_jit_defs(tree, ctx, out)
        self._check_static_callsites(tree, ctx, jit_statics, out)
        self._check_cache_keys(tree, ctx, out)
        return out

    # -- mutable defaults on (FitSpec-adjacent) dataclasses ---------------
    def _check_dataclasses(self, tree, ctx, out):
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            is_dc = any(n and n.split(".")[-1] == "dataclass"
                        for _, n in _class_decorators(node))
            if not is_dc:
                continue
            for stmt in node.body:
                if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                    default = stmt.value
                    if (isinstance(default, ast.Call)
                            and call_name(default).split(".")[-1] == "field"):
                        default = next(
                            (kw.value for kw in default.keywords
                             if kw.arg == "default"), None)
                    if default is not None and _is_mutable_literal(default):
                        tgt = getattr(stmt.target, "id", "?")
                        out.append(Finding(
                            "RL-RECOMPILE", ctx.display_path, stmt.lineno,
                            f"dataclass field {tgt!r} has a mutable default "
                            "— shared across instances, and unhashable if "
                            "the class ever rides a jit static arg; use "
                            "field(default_factory=...)",
                            col=stmt.col_offset, symbol=node.name))

    # -- jit decorations --------------------------------------------------
    def _check_jit_defs(self, tree, ctx, out) -> dict[str, tuple[str, ...]]:
        statics_by_fn: dict[str, tuple[str, ...]] = {}
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            jitted, statics = _jit_static_names(node)
            if not jitted:
                continue
            params = _param_names(node)
            statics_by_fn[node.name] = statics
            for s in statics:
                if s not in params:
                    out.append(Finding(
                        "RL-RECOMPILE", ctx.display_path, node.lineno,
                        f"static_argnames names {s!r} but "
                        f"{node.name}() has no such parameter — jit "
                        "fails only at first call",
                        col=node.col_offset, symbol=node.name))
            for p, default in _defaults_of(node):
                if p in statics and _is_mutable_literal(default):
                    out.append(Finding(
                        "RL-RECOMPILE", ctx.display_path, default.lineno,
                        f"static parameter {p!r} of {node.name}() defaults "
                        "to a mutable (unhashable) value — every defaulted "
                        "call throws or retraces",
                        col=default.col_offset, symbol=node.name))
        return statics_by_fn

    def _check_static_callsites(self, tree, ctx, statics_by_fn, out):
        if not statics_by_fn:
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            fn = call_name(node).split(".")[-1]
            statics = statics_by_fn.get(fn)
            if not statics:
                continue
            for kw in node.keywords:
                if kw.arg in statics and _is_mutable_literal(kw.value):
                    out.append(Finding(
                        "RL-RECOMPILE", ctx.display_path, kw.value.lineno,
                        f"mutable value passed to static arg "
                        f"{kw.arg!r} of jitted {fn}() — unhashable at "
                        "the compile-cache key",
                        col=kw.value.col_offset,
                        symbol=ctx.symbol_at(tree, node.lineno)))

    # -- compile-cache key hygiene ----------------------------------------
    def _check_cache_keys(self, tree, ctx, out):
        for node in ast.walk(tree):
            key = None
            if isinstance(node, ast.Subscript) \
                    and _is_cache_name(dotted_name(node.value)):
                key = node.slice
            elif isinstance(node, ast.Call):
                nm = call_name(node)
                if (nm.endswith((".get", ".setdefault", ".pop"))
                        and _is_cache_name(nm.rsplit(".", 1)[0])
                        and node.args):
                    key = node.args[0]
            if key is None:
                continue
            for bad in ast.walk(key):
                if isinstance(bad, ast.JoinedStr):
                    out.append(Finding(
                        "RL-RECOMPILE", ctx.display_path, bad.lineno,
                        "f-string used as a compile-cache key — embeds "
                        "reprs that differ across processes/objects; key "
                        "on a tuple of hashable statics instead",
                        col=bad.col_offset,
                        symbol=ctx.symbol_at(tree, bad.lineno)))
                    break
                if isinstance(bad, ast.Call) and call_name(bad) == "id":
                    out.append(Finding(
                        "RL-RECOMPILE", ctx.display_path, bad.lineno,
                        "id() used in a compile-cache key — object "
                        "identity is not stable across runs (or after "
                        "GC reuse); key on value equality instead",
                        col=bad.col_offset,
                        symbol=ctx.symbol_at(tree, bad.lineno)))
                    break
                if _is_mutable_literal(bad):
                    out.append(Finding(
                        "RL-RECOMPILE", ctx.display_path, bad.lineno,
                        "mutable (unhashable) compile-cache key",
                        col=bad.col_offset,
                        symbol=ctx.symbol_at(tree, bad.lineno)))
                    break


def _is_cache_name(name: str) -> bool:
    return "cache" in name.rsplit(".", 1)[-1].lower()


def _class_decorators(node: ast.ClassDef):
    for dec in node.decorator_list:
        yield dec, (call_name(dec) if isinstance(dec, ast.Call)
                    else dotted_name(dec))


def _defaults_of(fn):
    a = fn.args
    pos = a.posonlyargs + a.args
    for p, d in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        yield p.arg, d
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None:
            yield p.arg, d


# ------------------------------------------------------------ tracer leaks
class TracerLeakChecker(Checker):
    name = "tracerleak"
    codes = ("RL-TRACERLEAK",)
    scope = None

    def check(self, tree: ast.Module, ctx: FileContext) -> list[Finding]:
        out: list[Finding] = []
        roots = self._trace_roots(tree)
        funcs = {n.name: n for n in ast.walk(tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        reachable = self._close_reachable(roots, funcs)
        for name in sorted(reachable):
            fn = funcs[name]
            self._check_control_flow(fn, ctx, out)
        # host callbacks inside lax control-flow bodies: anywhere in the
        # module (a scan body is traced whether or not its parent is)
        self._check_scan_callbacks(tree, ctx, funcs, out)
        return out

    def _trace_roots(self, tree) -> set[str]:
        roots: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                jitted, _ = _jit_static_names(node)
                if jitted:
                    roots.add(node.name)
            if isinstance(node, ast.Call):
                nm = call_name(node)
                if nm.split(".")[-1] == "pallas_call":
                    for arg in node.args[:1]:
                        roots.update(_referenced_fn_names(arg))
        return roots

    def _close_reachable(self, roots: set[str], funcs: dict) -> set[str]:
        seen = {r for r in roots if r in funcs}
        frontier = list(seen)
        while frontier:
            fn = funcs[frontier.pop()]
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    callee = call_name(node)
                    if callee in funcs and callee not in seen:
                        seen.add(callee)
                        frontier.append(callee)
        return seen

    def _check_control_flow(self, fn, ctx, out):
        for node in ast.walk(fn):
            test = None
            what = ""
            if isinstance(node, (ast.If, ast.While)):
                test = node.test
                what = "if" if isinstance(node, ast.If) else "while"
            elif isinstance(node, ast.IfExp):
                test = node.test
                what = "conditional expression"
            elif isinstance(node, ast.Assert):
                test = node.test
                what = "assert"
            elif (isinstance(node, ast.Call)
                    and call_name(node) in ("bool", "float", "int")
                    and node.args):
                leak = _find_traced_call(node.args[0])
                if leak is not None:
                    out.append(Finding(
                        "RL-TRACERLEAK", ctx.display_path, node.lineno,
                        f"{call_name(node)}() on traced expression "
                        f"{leak!r} inside jit-reachable "
                        f"{fn.name}() — concretization error at trace "
                        "time; keep it as an array op",
                        col=node.col_offset, symbol=fn.name))
                continue
            if test is None:
                continue
            leak = _find_traced_call(test)
            if leak is not None:
                out.append(Finding(
                    "RL-TRACERLEAK", ctx.display_path, node.lineno,
                    f"Python {what} on traced expression {leak!r} inside "
                    f"jit-reachable {fn.name}() — raises "
                    "TracerBoolConversionError on the traced path; use "
                    "jnp.where / jax.lax.cond / jax.lax.while_loop",
                    col=node.col_offset, symbol=fn.name))

    def _check_scan_callbacks(self, tree, ctx, funcs, out):
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            if call_name(node) not in SCAN_FAMILY:
                continue
            bodies: list[ast.AST] = []
            for arg in node.args:
                if isinstance(arg, ast.Lambda):
                    bodies.append(arg)
                else:
                    for name in _referenced_fn_names(arg):
                        if name in funcs:
                            bodies.append(funcs[name])
            for body in bodies:
                for inner in ast.walk(body):
                    if isinstance(inner, ast.Call) \
                            and _is_host_callback(call_name(inner)):
                        out.append(Finding(
                            "RL-TRACERLEAK", ctx.display_path,
                            inner.lineno,
                            f"host callback {call_name(inner)}() inside a "
                            f"{call_name(node)} body — forces a host "
                            "round-trip per iteration",
                            col=inner.col_offset,
                            symbol=ctx.symbol_at(tree, inner.lineno)))


def _is_host_callback(name: str) -> bool:
    return (name in HOST_CALLBACKS
            or name.split(".")[-1] in ("io_callback", "pure_callback"))


def _referenced_fn_names(node: ast.AST) -> set[str]:
    """Function names referenced by ``node`` — a bare Name, or inside a
    ``functools.partial(...)`` first argument."""
    names: set[str] = set()
    if isinstance(node, ast.Name):
        names.add(node.id)
    elif isinstance(node, ast.Call) \
            and call_name(node).split(".")[-1] == "partial" and node.args:
        names.update(_referenced_fn_names(node.args[0]))
    return names


def _find_traced_call(test: ast.AST) -> str | None:
    """The first ``jnp.*`` (array-returning) call inside ``test``."""
    for node in ast.walk(test):
        if not isinstance(node, ast.Call):
            continue
        nm = call_name(node)
        head, _, tail = nm.partition(".")
        if head in ("jnp", "jaxnp") or nm.startswith("jax.numpy."):
            fn = nm.rsplit(".", 1)[-1]
            if fn not in STATIC_SAFE_JNP:
                return nm
    return None
