"""reprolint core: findings, suppressions, the checker registry, the runner.

The repo's correctness story so far is *dynamic*: bit-identical chaos
replays, zero-recompile warmups, span-chain validation — all asserted at
runtime by tests that must anticipate each violation.  ``repro.analysis``
turns the same invariants into review-time machine checks: an AST pass per
invariant family, each finding carrying a stable code (RL-*), runnable as
``python -m repro.analysis`` over the whole repo and gated in CI.

Vocabulary
----------
* A **checker** subclasses :class:`Checker`, declares its ``codes`` and an
  optional ``scope`` (path suffixes it applies to; ``None`` = every file),
  and emits :class:`Finding`s from ``check(tree, ctx)``.
* A **finding** is one (code, path, line) diagnostic.  Findings on a line
  carrying ``# reprolint: disable=CODE — reason`` are recorded as
  suppressed, not dropped: the JSON report keeps the audit trail, and a
  disable comment WITHOUT a reason is itself a finding (RL-SUPPRESS) —
  the suppression policy is "allowed, but say why".
* The **runner** (:func:`run_lint`) walks the target files, parses each
  once, fans the AST to every in-scope checker, applies suppressions, and
  returns a :class:`Report` (JSON schema below, round-trip tested).

Scoped checkers (determinism on the virtual-tick domain, dtype hygiene on
the moment paths, VMEM/DMA on the kernels, the fleet protocol model) match
by path suffix so the fixture corpus can opt in by naming its files
``<anything>__<suffix>`` — see ``fixture_scope_path``.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
import tokenize
from pathlib import Path

SCHEMA_VERSION = 1

# the finding vocabulary; every checker code is registered here so the CLI
# and the docs table cannot drift from the implementation
CODE_SUPPRESS = "RL-SUPPRESS"
ALL_CODES: dict[str, str] = {
    "RL-RECOMPILE": "jit compile-cache hazard (non-static static args, "
                    "mutable dataclass defaults, f-string cache keys)",
    "RL-TRACERLEAK": "Python control flow / host callback on traced values "
                     "inside jit- or pallas-reachable code",
    "RL-DETERMINISM": "wall clock, unseeded RNG, or set-iteration order "
                      "inside the virtual-tick replay domain",
    "RL-PROTOCOL": "fleet mailbox state machine incomplete or drifted from "
                   "obs.trace.validate_events",
    "RL-DTYPE": "silent f32->f64 promotion hazard on a moment/Gram path",
    "RL-VMEM": "Pallas block shape exceeds the VMEM model, or unpaired "
               "DMA start/wait",
    CODE_SUPPRESS: "malformed suppression (disable comment without a "
                   "reason, or naming an unknown code)",
}

# spelling of a suppression comment: the marker, one or more codes after
# the equals sign, then a dash-separated reason
_DISABLE_RE = re.compile(
    r"#\s*reprolint:\s*disable=(?P<codes>[A-Z0-9,\-\s]+?)"
    r"(?:\s+(?:—|--|-)\s*(?P<reason>.+?))?\s*$")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: a stable code, a location, and the claim."""

    code: str
    path: str
    line: int
    message: str
    col: int = 0
    symbol: str = ""            # enclosing function/class, when known
    suppressed: bool = False
    suppression_reason: str = ""

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "Finding":
        return Finding(**d)

    def render(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        sup = (f"  (suppressed: {self.suppression_reason})"
               if self.suppressed else "")
        return (f"{self.path}:{self.line}:{self.col}: {self.code}{sym} "
                f"{self.message}{sup}")


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int
    codes: tuple[str, ...]
    reason: str
    standalone: bool     # comment-only line: applies to the NEXT code line


@dataclasses.dataclass
class FileContext:
    """Everything a checker gets besides the AST."""

    path: Path
    display_path: str
    source: str
    lines: list[str]

    def symbol_at(self, tree: ast.AST, line: int) -> str:
        """Innermost def/class enclosing ``line`` (best-effort)."""
        best = ""
        best_span = None
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                end = getattr(node, "end_lineno", node.lineno)
                if node.lineno <= line <= end:
                    span = end - node.lineno
                    if best_span is None or span <= best_span:
                        best, best_span = node.name, span
        return best


class Checker:
    """Base class: subclass, set ``name``/``codes``/``scope``, implement
    ``check``.  ``scope`` is a tuple of path suffixes (posix, e.g.
    ``"serve/fleet.py"``); ``None`` means every Python file."""

    name: str = ""
    codes: tuple[str, ...] = ()
    scope: tuple[str, ...] | None = None

    def applies_to(self, display_path: str) -> bool:
        if self.scope is None:
            return True
        p = display_path.replace("\\", "/")
        return any(p.endswith(sfx) or _fixture_matches(p, sfx)
                   for sfx in self.scope)

    def check(self, tree: ast.Module, ctx: FileContext) -> list[Finding]:
        raise NotImplementedError


def _fixture_matches(path: str, suffix: str) -> bool:
    """Fixture files opt into a scoped checker by embedding the scope
    suffix with ``/`` spelled ``__``: ``bad__serve__fleet.py`` runs the
    checkers scoped to ``serve/fleet.py``."""
    name = path.rsplit("/", 1)[-1]
    mangled = suffix.replace("/", "__").removesuffix(".py")
    return mangled in name


def fixture_scope_path(suffix: str, kind: str) -> str:
    """The fixture-corpus filename that opts into scope ``suffix``:
    ``fixture_scope_path("serve/fleet.py", "bad") ==
    "bad__serve__fleet.py"``."""
    return f"{kind}__{suffix.replace('/', '__')}"


# ----------------------------------------------------------- suppressions
def collect_suppressions(ctx: FileContext) -> tuple[list[Suppression],
                                                    list[Finding]]:
    """Parse every ``# reprolint: disable=...`` comment.  A disable with no
    reason, or naming a code the suite does not define, is itself a
    finding (the suppression policy is enforced by the tool)."""
    sups: list[Suppression] = []
    probs: list[Finding] = []
    try:
        tokens = list(tokenize.generate_tokens(
            iter(ctx.source.splitlines(keepends=True)).__next__))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return sups, probs
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _DISABLE_RE.search(tok.string)
        if m is None:
            if "reprolint" in tok.string and "disable" in tok.string:
                probs.append(Finding(
                    CODE_SUPPRESS, ctx.display_path, tok.start[0],
                    f"unparseable reprolint comment {tok.string.strip()!r} "
                    "(spelling: `# reprolint: disable=CODE — reason`)"))
            continue
        codes = tuple(c.strip() for c in m.group("codes").split(",")
                      if c.strip())
        reason = (m.group("reason") or "").strip()
        line = tok.start[0]
        standalone = ctx.lines[line - 1].lstrip().startswith("#")
        unknown = [c for c in codes if c not in ALL_CODES]
        if unknown:
            probs.append(Finding(
                CODE_SUPPRESS, ctx.display_path, line,
                f"disable names unknown code(s) {unknown} (known: "
                f"{sorted(ALL_CODES)})"))
        if not reason:
            probs.append(Finding(
                CODE_SUPPRESS, ctx.display_path, line,
                "suppression without a reason — spell it `# reprolint: "
                "disable=CODE — why this is deliberate`"))
            continue          # a reasonless disable does not suppress
        sups.append(Suppression(line, codes, reason, standalone))
    return sups, probs


def apply_suppressions(findings: list[Finding],
                       sups: list[Suppression]) -> list[Finding]:
    """Mark findings covered by a disable comment.  Inline comments cover
    their own line; standalone comment lines cover the next line."""
    by_line: dict[int, Suppression] = {}
    for s in sups:
        by_line[s.line + 1 if s.standalone else s.line] = s
    out = []
    for f in findings:
        s = by_line.get(f.line)
        if s is not None and f.code in s.codes:
            f = dataclasses.replace(f, suppressed=True,
                                    suppression_reason=s.reason)
        out.append(f)
    return out


# ----------------------------------------------------------------- runner
def default_checkers() -> list[Checker]:
    from repro.analysis import determinism, jit_hazards, numerics, protocol
    return [
        jit_hazards.RecompileChecker(),
        jit_hazards.TracerLeakChecker(),
        determinism.DeterminismChecker(),
        protocol.ProtocolChecker(),
        numerics.DtypeChecker(),
        numerics.VmemChecker(),
    ]


DEFAULT_ROOTS = ("src", "benchmarks", "examples")
_SKIP_PARTS = {"fixtures", "__pycache__", ".git"}


def discover_files(roots: list[str | Path]) -> list[Path]:
    files: list[Path] = []
    for root in roots:
        root = Path(root)
        if root.is_file():
            files.append(root)
            continue
        for p in sorted(root.rglob("*.py")):
            if _SKIP_PARTS.intersection(p.parts):
                continue
            files.append(p)
    return files


@dataclasses.dataclass
class Report:
    findings: list[Finding]
    files_scanned: int

    @property
    def unsuppressed(self) -> list[Finding]:
        return [f for f in self.findings if not f.suppressed]

    def counts(self, suppressed: bool | None = None) -> dict[str, int]:
        out: dict[str, int] = {}
        for f in self.findings:
            if suppressed is not None and f.suppressed != suppressed:
                continue
            out[f.code] = out.get(f.code, 0) + 1
        return out

    def to_dict(self) -> dict:
        return {"version": SCHEMA_VERSION,
                "files_scanned": self.files_scanned,
                "counts": self.counts(),
                "counts_unsuppressed": self.counts(suppressed=False),
                "findings": [f.to_dict() for f in self.findings]}

    @staticmethod
    def from_dict(d: dict) -> "Report":
        if d.get("version") != SCHEMA_VERSION:
            raise ValueError(f"unknown report version {d.get('version')!r}")
        return Report([Finding.from_dict(f) for f in d["findings"]],
                      d["files_scanned"])

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    def render_human(self) -> str:
        lines = [f.render() for f in self.findings]
        live = len(self.unsuppressed)
        supp = len(self.findings) - live
        lines.append(f"reprolint: {self.files_scanned} files, "
                     f"{live} finding(s), {supp} suppressed")
        return "\n".join(lines)


def lint_file(path: str | Path, checkers: list[Checker] | None = None,
              display_path: str | None = None,
              select: tuple[str, ...] | None = None) -> list[Finding]:
    """Run the (in-scope) checkers over one file; suppressions applied."""
    path = Path(path)
    source = path.read_text()
    display = display_path or _display(path)
    ctx = FileContext(path=path, display_path=display, source=source,
                      lines=source.splitlines())
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [Finding(CODE_SUPPRESS, display, e.lineno or 1,
                        f"file does not parse: {e.msg}")]
    sups, problems = collect_suppressions(ctx)
    findings = list(problems)
    for ch in (checkers if checkers is not None else default_checkers()):
        if not ch.applies_to(display):
            continue
        if select and not any(c in select for c in ch.codes):
            continue
        findings.extend(ch.check(tree, ctx))
    if select:
        findings = [f for f in findings
                    if f.code in select or f.code == CODE_SUPPRESS]
    findings.sort(key=lambda f: (f.line, f.col, f.code))
    return apply_suppressions(findings, sups)


def _display(path: Path) -> str:
    try:
        return path.resolve().relative_to(Path.cwd().resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def run_lint(roots: list[str | Path] | None = None,
             checkers: list[Checker] | None = None,
             select: tuple[str, ...] | None = None) -> Report:
    """Lint every Python file under ``roots`` (default: the repo's
    ``src``/``benchmarks``/``examples`` trees, relative to cwd)."""
    roots = list(roots) if roots else [r for r in DEFAULT_ROOTS
                                       if Path(r).exists()]
    checkers = checkers if checkers is not None else default_checkers()
    files = discover_files(roots)
    findings: list[Finding] = []
    for f in files:
        findings.extend(lint_file(f, checkers, select=select))
    return Report(findings, files_scanned=len(files))


# -------------------------------------------------------- shared AST utils
def dotted_name(node: ast.AST) -> str:
    """``a.b.c`` for Name/Attribute chains, else ""."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_name(node: ast.Call) -> str:
    return dotted_name(node.func)


def iter_decorators(fn: ast.FunctionDef | ast.AsyncFunctionDef):
    for dec in fn.decorator_list:
        yield dec, (call_name(dec) if isinstance(dec, ast.Call)
                    else dotted_name(dec))
