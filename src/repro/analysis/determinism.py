"""RL-DETERMINISM: the virtual-tick replay domain must stay replayable.

``serve/fleet.py``, ``runtime/chaos.py``, ``obs/trace.py`` and
``core/distributed.py`` share a committed contract: same seed + same chaos
schedule → byte-identical event logs and bit-identical coefficients.  Any
dependence on ambient nondeterminism breaks that silently — the replay
tests still pass on the machine that recorded them and diverge on the
next.  Three families are statically visible:

* **wall clock** — ``time.time()`` / ``datetime.now()`` and friends inside
  the tick domain (time here is an *injected* tick counter, never read
  from the host);
* **unseeded RNG** — ``np.random.default_rng()`` with no seed, the global
  ``np.random.*`` functions, or the stdlib ``random`` module (chaos/jitter
  randomness must flow from an explicit seed);
* **set-iteration order** — iterating a set expression directly (set
  literal, ``set()``/``frozenset()`` call, set comprehension, or a
  ``.union()``/``.intersection()``/``.difference()`` result): Python set
  order is hash-seed dependent, so any per-element side effect (message
  sends, counter bumps) lands in a different order per process.  Wrap in
  ``sorted(...)`` to fix.
"""
from __future__ import annotations

import ast

from repro.analysis.core import (Checker, FileContext, Finding, call_name,
                                 dotted_name)

TICK_DOMAIN = ("serve/fleet.py", "runtime/chaos.py", "obs/trace.py",
               "core/distributed.py")

WALL_CLOCK = {"time.time", "time.time_ns", "time.monotonic",
              "time.monotonic_ns", "time.perf_counter",
              "time.perf_counter_ns", "time.process_time"}
# matched on the trailing two segments, so datetime.datetime.now() and
# dt.now() both hit
WALL_CLOCK_TAILS = {"datetime.now", "datetime.utcnow", "datetime.today",
                    "date.today"}
# np.random attributes that are fine: explicitly seeded constructors
SEEDED_RNG_CTORS = {"default_rng", "Generator", "SeedSequence", "PCG64",
                    "Philox"}
SET_METHODS = {"union", "intersection", "difference",
               "symmetric_difference"}


class DeterminismChecker(Checker):
    name = "determinism"
    codes = ("RL-DETERMINISM",)
    scope = TICK_DOMAIN

    def check(self, tree: ast.Module, ctx: FileContext) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                self._check_call(node, tree, ctx, out)
            elif isinstance(node, (ast.For, ast.comprehension)):
                it = node.iter
                bad = _set_expr(it)
                if bad:
                    line = getattr(node, "lineno", it.lineno)
                    out.append(Finding(
                        "RL-DETERMINISM", ctx.display_path, it.lineno,
                        f"iteration over a set expression ({bad}) — order "
                        "is hash-seed dependent, so per-element effects "
                        "replay differently; iterate sorted(...) instead",
                        col=it.col_offset,
                        symbol=ctx.symbol_at(tree, it.lineno)))
        return out

    def _check_call(self, node: ast.Call, tree, ctx, out):
        nm = call_name(node)
        tail2 = ".".join(nm.split(".")[-2:])
        if nm in WALL_CLOCK or tail2 in WALL_CLOCK_TAILS \
                or tail2 in WALL_CLOCK:
            out.append(Finding(
                "RL-DETERMINISM", ctx.display_path, node.lineno,
                f"wall-clock read {nm}() inside the virtual-tick domain — "
                "time here is the injected tick counter; thread it in",
                col=node.col_offset,
                symbol=ctx.symbol_at(tree, node.lineno)))
            return
        parts = nm.split(".")
        if "random" in parts[:-1]:           # np.random.X / numpy.random.X
            fn = parts[-1]
            if fn in SEEDED_RNG_CTORS:
                if not node.args and not node.keywords:
                    out.append(Finding(
                        "RL-DETERMINISM", ctx.display_path, node.lineno,
                        f"{nm}() with no seed — entropy from the OS makes "
                        "the replay contract unsatisfiable; pass a seed",
                        col=node.col_offset,
                        symbol=ctx.symbol_at(tree, node.lineno)))
            else:
                out.append(Finding(
                    "RL-DETERMINISM", ctx.display_path, node.lineno,
                    f"{nm}() uses the global RNG stream — order-dependent "
                    "across call sites and unseeded by default; use a "
                    "seeded np.random.default_rng(seed)",
                    col=node.col_offset,
                    symbol=ctx.symbol_at(tree, node.lineno)))
        elif parts[0] == "random" and len(parts) == 2:
            out.append(Finding(
                "RL-DETERMINISM", ctx.display_path, node.lineno,
                f"stdlib {nm}() draws from the process-global RNG — "
                "seedless under pytest-randomization; use a seeded "
                "generator",
                col=node.col_offset,
                symbol=ctx.symbol_at(tree, node.lineno)))


def _set_expr(node: ast.AST) -> str:
    """Describe ``node`` if it syntactically produces a set, else ""."""
    if isinstance(node, ast.Set):
        return "set literal"
    if isinstance(node, ast.SetComp):
        return "set comprehension"
    if isinstance(node, ast.Call):
        nm = call_name(node)
        if nm in ("set", "frozenset"):
            return f"{nm}() call"
        if nm.rsplit(".", 1)[-1] in SET_METHODS:
            return f".{nm.rsplit('.', 1)[-1]}() result"
    return ""
