"""Runtime sanitizer companions to the static checkers.

Two dynamic tripwires for the hazards the AST passes can only
approximate:

* :class:`CompileCounter` / :func:`assert_no_recompiles` — the dynamic
  twin of RL-RECOMPILE.  The serve engines commit to a *zero recompiles
  after warmup* invariant; this generalizes it to any code region: count
  XLA executable compiles inside a ``with`` block and fail if any happen.
  Counting rides ``jax_log_compiles`` — JAX already logs one "Compiling
  <name> ..." record per executable build, so attaching a logging handler
  observes exactly the events the compile cache misses on, with no
  version-fragile internal patching.  The pytest wiring
  (``tests/conftest.py``, env flag ``REPRO_RECOMPILE_TRIPWIRE=1``) arms
  an autouse fixture that fails any test marked
  ``@pytest.mark.no_recompile`` that still triggers a compile.
* :func:`nan_origin` — the dynamic twin of RL-DTYPE's "where did the
  NaN come from" question.  Opt-in context manager that wraps the solver
  entry points (``repro.core.solve.solve`` /
  ``solve_with_fallback``) with eager finiteness checks on inputs and
  outputs, raising :class:`NaNOriginError` naming the entry point and
  argument the first moment a non-finite value crosses a solver
  boundary — instead of the NaN surfacing three layers later in a
  fit result.
"""
from __future__ import annotations

import contextlib
import logging
import re

import numpy as np

_FINISHED_RE = re.compile(r"Finished XLA compilation of (.+?) in ")


class _CompileLogHandler(logging.Handler):
    def __init__(self, counter: "CompileCounter"):
        super().__init__(level=logging.DEBUG)
        self.counter = counter

    def emit(self, record: logging.LogRecord) -> None:
        try:
            msg = record.getMessage()
        except Exception:
            return
        m = _FINISHED_RE.search(msg)
        if m:
            self.counter._record(m.group(1))


class CompileCounter:
    """Counts XLA executable compiles while active (re-entrant safe:
    one logging handler per instance)."""

    def __init__(self):
        self.names: list[str] = []
        self._handler = _CompileLogHandler(self)
        self._saved_flag = None

    @property
    def count(self) -> int:
        return len(self.names)

    def _record(self, name: str) -> None:
        self.names.append(name)

    def __enter__(self) -> "CompileCounter":
        import jax
        self._saved_flag = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        log = logging.getLogger("jax")
        self._saved_propagate = log.propagate
        log.propagate = False         # count quietly: no stderr spray
        log.addHandler(self._handler)
        return self

    def __exit__(self, *exc) -> None:
        import jax
        log = logging.getLogger("jax")
        log.removeHandler(self._handler)
        log.propagate = self._saved_propagate
        if self._saved_flag is not None:
            jax.config.update("jax_log_compiles", self._saved_flag)
        return None


@contextlib.contextmanager
def assert_no_recompiles(what: str = "region"):
    """Fail if any XLA executable is compiled inside the block — the
    serve warmup invariant, portable to any code region."""
    with CompileCounter() as counter:
        yield counter
    if counter.count:
        raise AssertionError(
            f"{what}: expected zero executable compiles, got "
            f"{counter.count}: {counter.names}")


# ------------------------------------------------------------- NaN origin
class NaNOriginError(FloatingPointError):
    """A non-finite value crossed a solver entry point; ``where`` names
    the boundary, ``argument`` what carried it."""

    def __init__(self, where: str, argument: str, detail: str = ""):
        self.where = where
        self.argument = argument
        super().__init__(
            f"non-finite value at {where} ({argument})"
            + (f": {detail}" if detail else ""))


def _check_finite(where: str, argument: str, value) -> None:
    arr = np.asarray(value)
    if arr.dtype.kind not in "fc":
        return
    if not bool(np.all(np.isfinite(arr))):
        bad = int(arr.size - np.count_nonzero(np.isfinite(arr)))
        raise NaNOriginError(where, argument,
                             f"{bad}/{arr.size} non-finite entries")


def _wrap_entry(module, name: str, arg_names: tuple[str, ...]):
    orig = getattr(module, name)

    def wrapped(*args, **kwargs):
        where = f"{module.__name__}.{name}"
        for label, val in list(zip(arg_names, args)) + list(kwargs.items()):
            if isinstance(label, str) and not isinstance(val, (str, type)):
                try:
                    _check_finite(where + " input", label, val)
                except (TypeError, ValueError):
                    pass      # non-array argument (spec, method string)
        out = orig(*args, **kwargs)
        try:
            if isinstance(out, tuple):
                for i, o in enumerate(out):
                    _check_finite(where + " output", f"[{i}]", o)
            else:
                _check_finite(where + " output", "result", out)
        except (TypeError, ValueError):
            pass
        return out

    wrapped.__wrapped__ = orig
    wrapped.__name__ = name
    return orig, wrapped


@contextlib.contextmanager
def nan_origin():
    """Opt-in NaN-origin mode: while active, the solver entry points
    (``repro.core.solve.solve`` / ``solve_with_fallback``) eagerly check
    argument and output finiteness and raise :class:`NaNOriginError`
    naming the boundary — NaNs are caught where they enter the solve, not
    three layers later in a fit result.

    Note: ``solve_with_fallback``'s *outputs* are exempt from the output
    check only in that a deliberate fallback still returns finite
    coefficients; its inputs are checked like any other boundary.
    """
    from repro.core import solve as solve_mod
    entries = (("solve", ("a", "b", "method")),
               ("solve_with_fallback", ("a", "b")))
    saved = []
    try:
        for name, argnames in entries:
            orig, wrapped = _wrap_entry(solve_mod, name, argnames)
            saved.append((name, orig))
            setattr(solve_mod, name, wrapped)
        yield
    finally:
        for name, orig in saved:
            setattr(solve_mod, name, orig)
