"""``python -m repro.analysis`` — lint the repo against its own invariants.

Exit status: 0 when no unsuppressed findings, 1 otherwise (2 on usage
errors).  Suppressed findings are reported (human mode) / recorded (JSON)
but do not fail the run — the audit trail stays visible either way.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.core import ALL_CODES, Report, run_lint


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: AST checks for the repo's jit/replay/"
                    "protocol/dtype/VMEM invariants")
    ap.add_argument("paths", nargs="*",
                    help="files or directories to lint (default: the "
                         "src/benchmarks/examples trees)")
    ap.add_argument("--format", choices=("human", "json"), default="human")
    ap.add_argument("--select", default="",
                    help="comma-separated finding codes to run "
                         f"(known: {', '.join(sorted(ALL_CODES))})")
    ap.add_argument("--output", default="",
                    help="also write the JSON report to this path")
    ap.add_argument("--list-codes", action="store_true",
                    help="print the finding-code table and exit")
    args = ap.parse_args(argv)

    if args.list_codes:
        for code in sorted(ALL_CODES):
            print(f"{code:15s} {ALL_CODES[code]}")
        return 0

    select = tuple(c.strip() for c in args.select.split(",") if c.strip())
    unknown = [c for c in select if c not in ALL_CODES]
    if unknown:
        print(f"unknown code(s) {unknown}; known: {sorted(ALL_CODES)}",
              file=sys.stderr)
        return 2

    report: Report = run_lint(args.paths or None, select=select or None)
    if args.output:
        with open(args.output, "w") as f:
            f.write(report.to_json() + "\n")
    if args.format == "json":
        print(report.to_json())
    else:
        print(report.render_human())
    return 1 if report.unsuppressed else 0


if __name__ == "__main__":
    sys.exit(main())
