"""RL-PROTOCOL: the fleet mailbox state machine, extracted statically.

``serve/fleet.py`` speaks a closed message vocabulary — dataclasses
carrying a ``kind: str = "<name>"`` discriminator, dispatched by
``.kind ==`` comparison chains (``FleetWorker.process`` for requests,
``FitFleet._handle_replies`` for replies).  The runtime validator
(``obs.trace.validate_events``) asserts the *dynamic* consequences: every
admitted request reaches exactly one terminal instant.  This checker
asserts the same machine *statically* so the two can't drift:

* **P1 — no orphan messages**: every message class constructed somewhere
  in the module has its ``kind`` handled by some dispatcher.
* **P2 — closed-world dispatch**: a function that dispatches on ``.kind``
  must raise a typed ``ProtocolError`` for unknown kinds; a bare fallth-
  rough silently drops the message (the exact bug class the moment
  journal cannot recover from, because no timeout fires on a reply).
* **P3 — ingest acks**: every return path of the ``kind == "ingest"``
  handler carries an ``Ack`` — the journal's watermark protocol relies on
  duplicates being acked, never ignored.
* **P4 — terminal parity with the tracer**: the ``TERMINAL`` vocabulary
  declared in ``obs/trace.py`` must match the instants the fleet emits:
  every declared terminal is emitted somewhere, and every function that
  terminates a request (assigns ``.done_tick``) while tracing emits at
  least one terminal instant.  This is the static twin of
  ``validate_events``'s "exactly one terminal per admitted uid".
"""
from __future__ import annotations

import ast
import re
from pathlib import Path

from repro.analysis.core import (Checker, FileContext, Finding, call_name,
                                 dotted_name)

_TERMINAL_RE = re.compile(r"^TERMINAL\s*=", re.M)


def _kind_compares(fn: ast.AST):
    """Yield (Compare node, kind string) for ``<x>.kind == "const"``."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Compare) or len(node.ops) != 1 \
                or not isinstance(node.ops[0], (ast.Eq, ast.NotEq)):
            continue
        left, right = node.left, node.comparators[0]
        if isinstance(left, ast.Constant):
            left, right = right, left
        if (isinstance(left, ast.Attribute) and left.attr == "kind"
                and isinstance(right, ast.Constant)
                and isinstance(right.value, str)
                and isinstance(node.ops[0], ast.Eq)):
            yield node, right.value


class ProtocolChecker(Checker):
    name = "protocol"
    codes = ("RL-PROTOCOL",)
    scope = ("serve/fleet.py",)

    def __init__(self, trace_path: str | Path | None = None):
        self.trace_path = Path(trace_path) if trace_path else None

    def check(self, tree: ast.Module, ctx: FileContext) -> list[Finding]:
        out: list[Finding] = []
        kinds = self._message_classes(tree)          # class -> kind string
        handled = self._handled_kinds(tree)
        self._check_orphans(tree, ctx, kinds, handled, out)       # P1
        self._check_closed_dispatch(tree, ctx, out)               # P2
        self._check_ingest_acks(tree, ctx, kinds, out)            # P3
        self._check_terminals(tree, ctx, out)                     # P4
        return out

    # ---------------------------------------------------------- extraction
    def _message_classes(self, tree) -> dict[str, str]:
        kinds: dict[str, str] = {}
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for stmt in node.body:
                if (isinstance(stmt, ast.AnnAssign)
                        and isinstance(stmt.target, ast.Name)
                        and stmt.target.id == "kind"
                        and isinstance(stmt.value, ast.Constant)
                        and isinstance(stmt.value.value, str)):
                    kinds[node.name] = stmt.value.value
        return kinds

    def _handled_kinds(self, tree) -> set[str]:
        return {k for _, k in _kind_compares(tree)}

    # ------------------------------------------------------------------ P1
    def _check_orphans(self, tree, ctx, kinds, handled, out):
        if not kinds:
            return
        constructed: dict[str, int] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                nm = call_name(node).rsplit(".", 1)[-1]
                if nm in kinds and nm not in constructed:
                    constructed[nm] = node.lineno
        for cls, line in sorted(constructed.items(), key=lambda kv: kv[1]):
            if kinds[cls] not in handled:
                out.append(Finding(
                    "RL-PROTOCOL", ctx.display_path, line,
                    f"message {cls} (kind={kinds[cls]!r}) is constructed "
                    "but no dispatcher handles that kind — it will hit "
                    "the unknown-message path on every delivery",
                    symbol=ctx.symbol_at(tree, line)))

    # ------------------------------------------------------------------ P2
    def _check_closed_dispatch(self, tree, ctx, out):
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            own = [n for n in _direct_walk(fn)]
            kinds = {k for node in own for _, k in _kind_compares_shallow(
                node)}
            if not kinds:
                continue
            if not self._raises_protocol_error(fn):
                out.append(Finding(
                    "RL-PROTOCOL", ctx.display_path, fn.lineno,
                    f"{fn.name}() dispatches on message kind "
                    f"({sorted(kinds)}) but has no ProtocolError raise "
                    "for unknown kinds — unrecognized messages are "
                    "silently dropped (no timeout fires on a reply)",
                    col=fn.col_offset, symbol=fn.name))

    @staticmethod
    def _raises_protocol_error(fn) -> bool:
        for node in ast.walk(fn):
            if isinstance(node, ast.Raise) and node.exc is not None:
                exc = node.exc
                nm = (call_name(exc) if isinstance(exc, ast.Call)
                      else dotted_name(exc))
                if nm.rsplit(".", 1)[-1] == "ProtocolError":
                    return True
        return False

    # ------------------------------------------------------------------ P3
    def _check_ingest_acks(self, tree, ctx, kinds, out):
        ack_classes = {c for c, k in kinds.items() if k == "ack"}
        if not ack_classes:
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.If):
                continue
            if not any(k == "ingest" for _, k in
                       _kind_compares_shallow(node.test)):
                continue
            for ret in [n for b in node.body for n in ast.walk(b)
                        if isinstance(n, ast.Return)]:
                val = ret.value
                has_ack = val is not None and any(
                    isinstance(c, ast.Call)
                    and call_name(c).rsplit(".", 1)[-1] in ack_classes
                    for c in ast.walk(val))
                if not has_ack:
                    out.append(Finding(
                        "RL-PROTOCOL", ctx.display_path, ret.lineno,
                        "ingest handler path returns without an Ack — the "
                        "journal watermark protocol requires every "
                        "delivered chunk (duplicates included) to be "
                        "acked, or retry storms never settle",
                        col=ret.col_offset,
                        symbol=ctx.symbol_at(tree, ret.lineno)))

    # ------------------------------------------------------------------ P4
    def _check_terminals(self, tree, ctx, out):
        terminals = self._load_terminals(ctx)
        if not terminals:
            return
        emitted = self._instant_names(tree)
        for t in terminals:
            if t not in emitted:
                out.append(Finding(
                    "RL-PROTOCOL", ctx.display_path, 1,
                    f"obs.trace declares terminal instant {t!r} but this "
                    "module never emits it — the static machine and "
                    "validate_events have drifted"))
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            sets_done = any(
                isinstance(n, (ast.Assign, ast.AugAssign))
                and any(isinstance(t, ast.Attribute)
                        and t.attr == "done_tick"
                        for t in (n.targets if isinstance(n, ast.Assign)
                                  else [n.target]))
                for n in ast.walk(fn))
            if not sets_done:
                continue
            names = self._instant_names(fn)
            if names and not names.intersection(terminals):
                out.append(Finding(
                    "RL-PROTOCOL", ctx.display_path, fn.lineno,
                    f"{fn.name}() terminates a request (assigns "
                    f".done_tick) and traces ({sorted(names)}) but emits "
                    f"no terminal instant from {tuple(terminals)} — "
                    "validate_events will flag every request it ends",
                    col=fn.col_offset, symbol=fn.name))

    def _load_terminals(self, ctx: FileContext) -> set[str]:
        candidates = ([self.trace_path] if self.trace_path else
                      [ctx.path.parent.parent / "obs" / "trace.py",
                       ctx.path.parent / "trace.py"])
        for cand in candidates:
            if cand is None or not cand.is_file():
                continue
            try:
                tree = ast.parse(cand.read_text())
            except SyntaxError:
                continue
            for node in tree.body:
                if (isinstance(node, ast.Assign)
                        and any(isinstance(t, ast.Name)
                                and t.id == "TERMINAL"
                                for t in node.targets)
                        and isinstance(node.value, (ast.Tuple, ast.List))):
                    return {e.value for e in node.value.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, str)}
        return set()

    @staticmethod
    def _instant_names(node: ast.AST) -> set[str]:
        names: set[str] = set()
        for n in ast.walk(node):
            if (isinstance(n, ast.Call)
                    and call_name(n).rsplit(".", 1)[-1] == "instant"
                    and len(n.args) >= 2
                    and isinstance(n.args[1], ast.Constant)
                    and isinstance(n.args[1].value, str)):
                names.add(n.args[1].value)
        return names


def _direct_walk(fn):
    """Nodes of ``fn`` excluding nested function/class bodies."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef, ast.Lambda)):
            stack.extend(ast.iter_child_nodes(node))


def _kind_compares_shallow(node: ast.AST):
    """_kind_compares over a single node's subtree."""
    yield from _kind_compares(node)
