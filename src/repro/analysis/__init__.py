"""reprolint: repo-aware static analysis + runtime sanitizers.

Run ``python -m repro.analysis`` (or see README §Static analysis)."""
from repro.analysis.core import (ALL_CODES, CODE_SUPPRESS, SCHEMA_VERSION,
                                 Checker, FileContext, Finding, Report,
                                 Suppression, default_checkers,
                                 discover_files, fixture_scope_path,
                                 lint_file, run_lint)
from repro.analysis.sanitizers import (CompileCounter, NaNOriginError,
                                       assert_no_recompiles, nan_origin)

__all__ = [
    "ALL_CODES", "CODE_SUPPRESS", "SCHEMA_VERSION", "Checker",
    "FileContext", "Finding", "Report", "Suppression", "default_checkers",
    "discover_files", "fixture_scope_path", "lint_file", "run_lint",
    "CompileCounter", "NaNOriginError", "assert_no_recompiles",
    "nan_origin",
]
