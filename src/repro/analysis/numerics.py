"""RL-DTYPE and RL-VMEM: numeric-width and kernel-resource hygiene.

* **RL-DTYPE** — the moment/Gram paths are an f32 contract: the paper's
  matricization keeps every accumulator in f32 (compensated where it
  matters) and the serving stack round-trips snapshots through numpy.
  One ``np.float64`` touch silently upcasts the whole chain (2× memory
  and DMA bytes on TPU, and a result that differs bitwise from the f32
  kernels).  Flagged: explicit ``float64``/``double`` dtypes,
  ``astype(float)`` / ``dtype=float`` (Python ``float`` IS f64), and
  dtype-less ``jnp.array(<float literal>)`` materializations whose width
  silently follows the x64 flag rather than the pipeline (weak-type
  hazard).  Deliberate f64 (e.g. a journal merge accumulating in f64
  before casting back) must carry a reasoned suppression.
* **RL-VMEM** — the packed moments kernel's multi-buffered VMEM ring is
  budgeted by the model in ``kernels/tune.py`` (``ring_vmem_bytes`` vs
  ``VMEM_BUDGET``).  The checker recomputes that model statically: a
  literal ``block_n`` that cannot fit the budget under ANY packing factor
  is dead-on-arrival config.  It also checks DMA discipline: a kernel
  that issues ``make_async_copy`` must both ``.start()`` and ``.wait()``
  (an unwaited copy races the matmul on the destination buffer), every
  copy must carry its semaphore slot, and a DMA-issuing kernel must
  allocate ``SemaphoreType.DMA`` scoped storage.
"""
from __future__ import annotations

import ast

from repro.analysis.core import (Checker, FileContext, Finding, call_name,
                                 dotted_name)

MOMENT_PATHS = ("core/moments.py", "core/streaming.py",
                "kernels/moments.py", "engine/plan.py", "serve/fleet.py",
                "core/distributed.py")

F64_ATTRS = {"np.float64", "numpy.float64", "jnp.float64", "np.double",
             "numpy.double", "jnp.double"}


class DtypeChecker(Checker):
    name = "dtype"
    codes = ("RL-DTYPE",)
    scope = MOMENT_PATHS

    def check(self, tree: ast.Module, ctx: FileContext) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute):
                nm = dotted_name(node)
                if nm in F64_ATTRS:
                    out.append(Finding(
                        "RL-DTYPE", ctx.display_path, node.lineno,
                        f"explicit {nm} on a moment/Gram path — the "
                        "accumulation contract is f32 (compensated where "
                        "needed); an f64 touch silently upcasts the chain",
                        col=node.col_offset,
                        symbol=ctx.symbol_at(tree, node.lineno)))
            elif isinstance(node, ast.Call):
                self._check_call(node, tree, ctx, out)
            elif isinstance(node, ast.keyword):
                if (node.arg == "dtype" and isinstance(node.value, ast.Name)
                        and node.value.id == "float"):
                    out.append(Finding(
                        "RL-DTYPE", ctx.display_path, node.value.lineno,
                        "dtype=float — Python float IS float64; name the "
                        "width (np.float32) on a moment path",
                        col=node.value.col_offset,
                        symbol=ctx.symbol_at(tree, node.value.lineno)))
        return out

    def _check_call(self, node: ast.Call, tree, ctx, out):
        nm = call_name(node)
        if nm.rsplit(".", 1)[-1] == "astype" and node.args:
            a = node.args[0]
            if isinstance(a, ast.Name) and a.id == "float":
                out.append(Finding(
                    "RL-DTYPE", ctx.display_path, node.lineno,
                    "astype(float) upcasts to float64 — name the width "
                    "(np.float32) on a moment path",
                    col=node.col_offset,
                    symbol=ctx.symbol_at(tree, node.lineno)))
            return
        if nm in ("jnp.array", "jnp.asarray", "jax.numpy.array",
                  "jax.numpy.asarray"):
            if (len(node.args) == 1
                    and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, float)
                    and not any(kw.arg == "dtype" for kw in node.keywords)):
                out.append(Finding(
                    "RL-DTYPE", ctx.display_path, node.lineno,
                    f"{nm}({node.args[0].value}) without dtype — a weak-"
                    "typed float literal whose width follows the x64 "
                    "flag, not the pipeline; pass dtype explicitly",
                    col=node.col_offset,
                    symbol=ctx.symbol_at(tree, node.lineno)))


# --------------------------------------------------------------------- VMEM
# Static mirror of kernels/tune.py's model.  K_PAD/VMEM_BUDGET are read
# from the scanned file when it defines them, so tune.py lints against its
# own constants; the fallbacks below match the committed model.
K_PAD_DEFAULT = 128
VMEM_BUDGET_DEFAULT = 8 << 20
NBUF_DEFAULT = 2


def min_ring_vmem_bytes(block_n: int, *, k_pad: int = K_PAD_DEFAULT,
                        nbuf: int = NBUF_DEFAULT) -> int:
    """The packed kernel's VMEM need at tile width ``block_n`` under the
    MOST favourable packing (P = 1, plain f32 accumulator) — a lower
    bound over every (degree, compensated) configuration.  A ``block_n``
    whose lower bound exceeds the budget fits no configuration at all."""
    ring = 3 * nbuf * 1 * block_n * 4
    wmat = 2 * k_pad * block_n * 4
    acc = k_pad * k_pad * 4
    return ring + wmat + acc


class VmemChecker(Checker):
    name = "vmem"
    codes = ("RL-VMEM",)
    scope = ("kernels/moments.py", "kernels/tune.py")

    def check(self, tree: ast.Module, ctx: FileContext) -> list[Finding]:
        out: list[Finding] = []
        k_pad, budget = self._model_constants(tree)
        self._check_block_literals(tree, ctx, k_pad, budget, out)
        self._check_dma_pairing(tree, ctx, out)
        return out

    @staticmethod
    def _model_constants(tree) -> tuple[int, int]:
        k_pad, budget = K_PAD_DEFAULT, VMEM_BUDGET_DEFAULT
        for node in tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                name = node.targets[0].id
                try:
                    val = ast.literal_eval(node.value)
                except ValueError:
                    continue
                if name == "K_PAD" and isinstance(val, int):
                    k_pad = val
                elif name == "VMEM_BUDGET" and isinstance(val, int):
                    budget = val
        return k_pad, budget

    def _check_block_literals(self, tree, ctx, k_pad, budget, out):
        sites: list[tuple[int, int, int, str]] = []   # (line, col, bn, how)
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if (isinstance(tgt, ast.Name)
                            and "block_n" in tgt.id.lower()
                            and isinstance(node.value, ast.Constant)
                            and isinstance(node.value.value, int)):
                        sites.append((node.lineno, node.col_offset,
                                      node.value.value, tgt.id))
            elif isinstance(node, ast.keyword):
                if (node.arg == "block_n"
                        and isinstance(node.value, ast.Constant)
                        and isinstance(node.value.value, int)):
                    sites.append((node.value.lineno, node.value.col_offset,
                                  node.value.value, "block_n="))
        for line, col, bn, how in sites:
            need = min_ring_vmem_bytes(bn, k_pad=k_pad)
            if need > budget:
                out.append(Finding(
                    "RL-VMEM", ctx.display_path, line,
                    f"{how} {bn}: the multi-buffered ring needs >= "
                    f"{need} bytes even at packing factor 1, over the "
                    f"{budget}-byte VMEM budget for every configuration",
                    col=col, symbol=ctx.symbol_at(tree, line)))

    def _check_dma_pairing(self, tree, ctx, out):
        for fn in [n for n in ast.walk(tree)
                   if isinstance(n, (ast.FunctionDef,
                                     ast.AsyncFunctionDef))]:
            copies = [n for n in ast.walk(fn)
                      if isinstance(n, ast.Call)
                      and call_name(n).rsplit(".", 1)[-1]
                      == "make_async_copy"]
            if not copies:
                continue
            # only inspect outermost DMA-issuing functions: nested helpers
            # (the `dmas`/`body` closures) share the parent's pairing
            if any(fn is not p and fn in set(ast.walk(p))
                   for p in ast.walk(tree)
                   if isinstance(p, (ast.FunctionDef,
                                     ast.AsyncFunctionDef))
                   and any(isinstance(c, ast.Call)
                           and call_name(c).rsplit(".", 1)[-1]
                           == "make_async_copy"
                           for c in ast.walk(p))):
                continue
            for cp in copies:
                if len(cp.args) < 3 and not any(
                        kw.arg == "sem" for kw in cp.keywords):
                    out.append(Finding(
                        "RL-VMEM", ctx.display_path, cp.lineno,
                        "make_async_copy without a semaphore argument — "
                        "the copy cannot be waited on",
                        col=cp.col_offset, symbol=fn.name))
            methods = {call_name(n).rsplit(".", 1)[-1]
                       for n in ast.walk(fn)
                       if isinstance(n, ast.Call)}
            for need in ("start", "wait"):
                if need not in methods:
                    out.append(Finding(
                        "RL-VMEM", ctx.display_path, fn.lineno,
                        f"{fn.name}() issues make_async_copy but never "
                        f"calls .{need}() — an un{need}ed DMA "
                        + ("never moves the bytes" if need == "start"
                           else "races the consumer on the destination "
                                "buffer"),
                        col=fn.col_offset, symbol=fn.name))
            has_sem_alloc = any(
                "SemaphoreType" in dotted_name(n)
                for n in ast.walk(fn) if isinstance(n, ast.Attribute))
            if not has_sem_alloc:
                out.append(Finding(
                    "RL-VMEM", ctx.display_path, fn.lineno,
                    f"{fn.name}() issues DMAs but allocates no "
                    "SemaphoreType.DMA scoped storage",
                    col=fn.col_offset, symbol=fn.name))
