"""Unified fit-engine dispatch: one place decides how a fit executes.

``plan_fit`` inspects the problem (shape, dtype, degree, basis, mesh,
backend) and returns a ``FitPlan`` — execution path + numerics policy —
which ``compute_moments`` / ``compute_report_sums`` execute.  Every public
fitting entry point (``core.polyfit``, ``core.fit_report_streamed``,
``streaming.update``, ``distributed``) routes through here.
"""
from repro.engine.plan import (FitPlan, NumericsPolicy, plan_fit,
                               compute_moments, compute_report_sums,
                               resolve_engine, resolve_numerics,
                               reset_moment_counter, moment_counter,
                               REFERENCE, KERNEL_PLAIN, KERNEL_PACKED,
                               PATHS, ENGINES, SOLVERS,
                               PACKED_MIN_BATCH, KERNEL_MIN_POINTS,
                               AUTO_NORMALIZE_DEGREE_F32,
                               AUTO_NORMALIZE_DEGREE_F64)

__all__ = [
    "FitPlan", "NumericsPolicy", "plan_fit",
    "compute_moments", "compute_report_sums", "resolve_engine",
    "resolve_numerics", "reset_moment_counter", "moment_counter",
    "REFERENCE", "KERNEL_PLAIN", "KERNEL_PACKED", "PATHS", "ENGINES",
    "SOLVERS", "PACKED_MIN_BATCH", "KERNEL_MIN_POINTS",
    "AUTO_NORMALIZE_DEGREE_F32", "AUTO_NORMALIZE_DEGREE_F64",
]
