"""FitPlan — the one place that decides HOW a matricized LSE fit executes.

The paper's algorithm has exactly one heavy step (moment/Gram accumulation,
O(n·m²) additive work) and the framework grew four ways to run it:

* ``reference``      pure-jnp ``core.moments.gram_moments`` (XLA fuses it);
* ``kernel_plain``   one-series-per-tile Pallas kernel;
* ``kernel_packed``  P = ⌊128/(degree+2)⌋ series per MXU tile (PR-1);
* distributed       any of the above per shard inside ``shard_map`` + psum.

Previously each callsite (``polyfit``, ``streaming.update``,
``distributed.local_moments``, ``fit_report_streamed``) hand-threaded a
``use_kernel`` boolean and re-implemented its own validation.  ``plan_fit``
centralizes the choice: it inspects the static facts of the problem — batch
shape, series length, degree, basis, dtype, the active mesh and backend —
and returns a ``FitPlan`` naming the execution path plus the numerics
policy (accumulation dtype, Kahan compensation, domain normalization).
``compute_moments`` then executes any plan.  Callers keep ``use_kernel`` as
a deprecated alias that maps onto ``engine=``.

Selection heuristics (measured table in EXPERIMENTS.md §Plan selection):

* non-monomial bases and degree+2 > 128 always take ``reference`` (the
  kernels build monomial power rows in a 128-sublane tile);
* off-TPU, ``auto`` always takes ``reference`` — interpret-mode Pallas is a
  correctness tool, ~100-1000× slower than XLA on CPU;
* on TPU, a batch of ≥ PACKED_MIN_BATCH series with packing room takes
  ``kernel_packed`` (the P× FLOPs-per-fit win applies at any n);
* on TPU, a single series takes ``kernel_plain`` only past
  ``KERNEL_MIN_POINTS`` — below it, compile/dispatch overhead beats the
  kernel's bandwidth advantage;
* everything else stays ``reference``.

Forcing is always available: ``engine="kernel"`` (auto packing),
``"kernel_packed"``, ``"kernel_plain"``, ``"reference"`` — with central
validation, so e.g. the distributed path can no longer silently drop a
chebyshev basis on the kernel route.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any

import jax
import jax.numpy as jnp

# path names (FitPlan.path)
REFERENCE = "reference"
KERNEL_PLAIN = "kernel_plain"
KERNEL_PACKED = "kernel_packed"
PATHS = (REFERENCE, KERNEL_PLAIN, KERNEL_PACKED)

# engine= values accepted by plan_fit and every refactored callsite
ENGINES = ("auto", "reference", "kernel", "kernel_plain", "kernel_packed")

# auto heuristics — see module docstring and EXPERIMENTS.md for the numbers
PACKED_MIN_BATCH = 2          # packed needs ≥ 2 series to beat plain
KERNEL_MIN_POINTS = 1 << 15   # single-series TPU crossover (total points)

# solver= values plan_fit accepts: the explicit ladder plus "auto"
# (select_solver from degree/dtype/basis) and "lspia" (the matrix-free
# iterative path — polyfit delegates to core.lspia, which never forms the
# Gram; only meaningful where the raw data is in hand)
SOLVERS = ("auto", "gauss", "cholesky", "qr", "svd", "lspia")

# solver="auto" escalates NumericsPolicy.normalize on raw-monomial fits at
# these degrees: past them a wide-domain Gram is unsalvageable *after*
# accumulation (every factorization of it fails — EXPERIMENTS.md §Solver
# selection), so conditioning must be fixed before the Gram is formed.
AUTO_NORMALIZE_DEGREE_F32 = 6
AUTO_NORMALIZE_DEGREE_F64 = 8


@dataclasses.dataclass(frozen=True)
class NumericsPolicy:
    """Numerical-robustness knobs, decided once per fit (Skala 1802.07591).

    ``accum_dtype=None`` means "accumulate in the input dtype" on the
    reference path and f32 on the kernel paths (their tile dtype).

    ``solver`` is the resolved primary solver for the normal-equation solve
    (never "auto" inside a built plan); ``fallback`` the rank-revealing
    rescue ``core.solve.solve_with_fallback`` swaps in when the runtime
    condition estimate exceeds ``cond_cap`` (None = per-dtype default) or
    the primary output is non-finite.  ``fallback=None`` disables the guard
    (pure planned solver — the paper-literal failure mode).
    """

    accum_dtype: Any = None
    compensated: bool = False      # Kahan two-float Gram accumulator
    normalize: bool = False       # map the sample domain to [-1, 1]
    solver: str = "gauss"          # resolved primary normal-equation solver
    fallback: str | None = "svd"   # condition-triggered rescue (None = off)
    cond_cap: float | None = None  # κ threshold (None = dtype default)


@dataclasses.dataclass(frozen=True)
class FitPlan:
    """A fully-resolved execution plan for one moment-accumulation problem.

    Hashable / static: safe to close over or pass as a jit static arg.
    """

    path: str                      # one of PATHS
    degree: int
    basis: str
    batch: tuple[int, ...]         # leading batch shape of x/y
    n: int                         # series length (last axis)
    weighted: bool
    numerics: NumericsPolicy
    block_n: int | None = None     # kernel tile width override
    interpret: bool | None = None  # None = auto (non-TPU backends interpret)
    distributed: bool = False      # wrapped in shard_map + psum by the caller
    devices: int = 1               # mesh size over the data axes
    reason: str = ""               # human-readable why (logs / tests)

    @property
    def uses_kernel(self) -> bool:
        return self.path in (KERNEL_PLAIN, KERNEL_PACKED)

    @property
    def packing(self) -> str:
        """ops.moments packing= argument for this plan."""
        return {KERNEL_PLAIN: "plain", KERNEL_PACKED: "packed"}.get(
            self.path, "plain")

    def describe(self) -> str:
        shard = (f" x{self.devices}shards" if self.distributed else "")
        return (f"FitPlan[{self.path}{shard}] deg={self.degree} "
                f"basis={self.basis} batch={self.batch} n={self.n} "
                f"accum={self.numerics.accum_dtype} "
                f"kahan={self.numerics.compensated} "
                f"norm={self.numerics.normalize} ({self.reason})")


def resolve_engine(engine: str, use_kernel: bool | None) -> str:
    """Fold the deprecated ``use_kernel`` boolean into ``engine=``."""
    if use_kernel is not None:
        warnings.warn(
            "use_kernel= is deprecated; pass engine='kernel' / "
            "engine='reference' (or leave engine='auto')",
            DeprecationWarning, stacklevel=3)
        mapped = "kernel" if use_kernel else "reference"
        if engine not in ("auto", mapped):
            raise ValueError(
                f"conflicting engine={engine!r} and use_kernel={use_kernel} "
                f"(the deprecated alias means engine={mapped!r}); drop "
                "use_kernel=")
        return mapped
    return engine


def _packing_factor(degree: int) -> int:
    from repro.kernels import moments as kernel
    return kernel.packing_factor(degree)


def _kernel_degree_ok(degree: int) -> bool:
    from repro.kernels import moments as kernel
    return degree + 2 <= kernel.K_PAD


def _autonorm_degree(dtype: Any) -> int:
    try:
        f64 = jnp.finfo(jnp.dtype(dtype)).eps < 1e-9
    except (TypeError, ValueError):
        f64 = False
    return AUTO_NORMALIZE_DEGREE_F64 if f64 else AUTO_NORMALIZE_DEGREE_F32


def resolve_numerics(degree: int, *, basis: str = "monomial",
                     dtype: Any = jnp.float32,
                     accum_dtype: Any = None,
                     normalize: bool = False,
                     compensated: bool = False,
                     solver: str = "auto",
                     fallback: str | None = "svd",
                     cond_cap: float | None = None) -> NumericsPolicy:
    """Resolve solver="auto" + auto-normalization into a concrete policy.

    The condition-aware chain (EXPERIMENTS.md §Solver selection):

    1. **before the Gram** — raw-monomial fits at degree ≥ 6 (f32) / 8
       (f64) flip ``normalize`` on: a wide-domain Gram at those degrees is
       beyond every factorization *after* accumulation, so the domain map
       must happen first;
    2. **static solver** — ``core.solve.select_solver`` picks the cheapest
       rung of GE → Cholesky → QR → SVD whose expected error survives the
       degree/dtype/basis;
    3. **runtime guard** — the solve itself estimates κ(Gram) from the
       O(m²) state and swaps in ``fallback`` (default rank-revealing SVD)
       past ``cond_cap`` or on non-finite output.
    """
    from repro.core import solve as solve_lib
    if solver == "qr_vandermonde":
        # same boundary as lspia below: QR on the raw Vandermonde rows
        # never forms the Gram, so no moment-based surface can run it —
        # the eager executor (api.fit / polyfit) dispatches it before
        # planning
        raise ValueError(
            "solver='qr_vandermonde' factors the raw Vandermonde rows and "
            "cannot run from moments; use core.polyfit(..., "
            "solver='qr_vandermonde') or api.FitSpec(numerics="
            "NumericsPolicy(solver='qr_vandermonde')) with api.fit")
    if solver not in SOLVERS:
        raise ValueError(f"solver={solver!r}; expected one of {SOLVERS}")
    if solver == "lspia":
        # only polyfit (which holds the raw data) can delegate to the
        # matrix-free iteration; a moment-based solve cannot run it
        raise ValueError(
            "solver='lspia' needs the raw data (matrix-free V/Vᵀ sweeps); "
            "use core.polyfit(..., solver='lspia') or core.lspia.lspia_fit "
            "— moment-based solves (streaming, distributed, robust, serve) "
            "only take the explicit ladder "
            f"{solve_lib.SOLVERS} or 'auto'")
    if fallback is not None and fallback not in solve_lib.SOLVERS:
        raise ValueError(f"fallback={fallback!r}; expected one of "
                         f"{solve_lib.SOLVERS} or None")
    if solver == "auto":
        if (basis == "monomial" and not normalize
                and degree >= _autonorm_degree(dtype)):
            normalize = True
        solver = solve_lib.select_solver(degree, dtype, basis=basis,
                                         normalized=normalize)
    return NumericsPolicy(accum_dtype=accum_dtype, compensated=compensated,
                          normalize=normalize, solver=solver,
                          fallback=fallback, cond_cap=cond_cap)


def plan_fit(shape: tuple[int, ...], degree: int, *,
             basis: str = "monomial",
             dtype: Any = jnp.float32,
             weighted: bool = False,
             engine: str = "auto",
             accum_dtype: Any = None,
             normalize: bool = False,
             compensated: bool = False,
             solver: str = "auto",
             fallback: str | None = "svd",
             cond_cap: float | None = None,
             block_n: int | None = None,
             interpret: bool | None = None,
             mesh: jax.sharding.Mesh | None = None,
             data_axes: tuple[str, ...] = (),
             backend: str | None = None,
             workload: str = "moments") -> FitPlan:
    """Resolve an execution path + numerics policy from static problem facts.

    ``shape``: full x/y shape (leading batch axes + series length).
    ``engine``: "auto" or a forced path; forcing a kernel path validates
    centrally (non-monomial basis / oversized degree raise here, for every
    caller).  ``mesh``/``data_axes``: the active mesh — ``shape`` is then the
    per-shard shape and the plan is marked distributed.  ``backend``
    overrides ``jax.default_backend()`` (tests / what-if planning).
    ``workload``: "moments" (Gram accumulation), "select" (the degree-sweep
    accumulation of ``repro.select`` — identical path logic to "moments":
    the fold/candidate axis is an ordinary series batch, so the packed
    Pallas kernel picks it up on TPU; the numerics policy is resolved at
    the MAX candidate degree, where conditioning is worst), "report"
    (fused evaluate/residual pass — no packed variant, and it is the only
    one-pass option so monomial fits take it on every backend), or "lspia"
    (the matrix-free iterative fit: no Gram at all, always the reference
    basis ops).  ``solver``/``fallback``/``cond_cap`` resolve the
    normal-equation solve policy (see ``resolve_numerics``) and ride in
    ``plan.numerics``.
    """
    if engine not in ENGINES:
        raise ValueError(f"engine={engine!r}; expected one of {ENGINES}")
    if workload not in ("moments", "select", "report", "lspia"):
        raise ValueError(f"workload={workload!r}")
    if not shape:
        raise ValueError("x/y must have at least one (series) axis")
    batch = tuple(int(s) for s in shape[:-1])
    n = int(shape[-1])
    b = 1
    for s in batch:
        b *= s
    backend = backend or jax.default_backend()
    if workload == "lspia":
        # the matrix-free workload has no normal-equation solve to plan
        numerics = NumericsPolicy(accum_dtype=accum_dtype,
                                  compensated=compensated,
                                  normalize=normalize, solver="lspia",
                                  fallback=None, cond_cap=cond_cap)
    else:
        numerics = resolve_numerics(degree, basis=basis, dtype=dtype,
                                    accum_dtype=accum_dtype,
                                    normalize=normalize,
                                    compensated=compensated, solver=solver,
                                    fallback=fallback, cond_cap=cond_cap)
    devices = 1
    if mesh is not None and data_axes:
        for ax in data_axes:
            devices *= mesh.shape[ax]
    common = dict(degree=degree, basis=basis, batch=batch, n=n,
                  weighted=weighted, numerics=numerics, block_n=block_n,
                  interpret=interpret, distributed=devices > 1,
                  devices=devices)

    kernel_forced = engine in ("kernel", "kernel_plain", "kernel_packed")
    monomial = basis == "monomial"
    if kernel_forced:
        # central validation — every callsite gets the same errors
        if not monomial:
            raise ValueError(
                f"engine={engine!r} supports the monomial basis only (the "
                f"Pallas kernels build monomial power rows); use "
                f"engine='reference' or 'auto' for basis={basis!r}")
        if not _kernel_degree_ok(degree):
            raise ValueError(f"degree {degree} exceeds the kernel tile "
                             "(degree + 2 must be <= 128)")

    if workload == "lspia":
        # matrix-free: basis matvecs only, no Gram to accumulate — the
        # kernel paths have nothing to offer (central basis validation for
        # a forced kernel engine already ran above)
        return FitPlan(path=REFERENCE, reason="lspia: matrix-free basis "
                       "matvecs (never forms the Gram)", **common)

    if workload == "report":
        if engine == "reference" or not monomial:
            return FitPlan(path=REFERENCE, reason="report: materializing "
                           "jnp pass (forced or non-monomial)", **common)
        return FitPlan(path=KERNEL_PLAIN, reason="report: fused one-pass "
                       "kernel (only one-pass option)", **common)

    if engine == "reference":
        return FitPlan(path=REFERENCE, reason="forced", **common)
    if engine == "kernel_plain":
        return FitPlan(path=KERNEL_PLAIN, reason="forced", **common)
    if engine == "kernel_packed":
        if _packing_factor(degree) < 2:
            raise ValueError(f"degree {degree} leaves no room to pack "
                             f"(packing_factor="
                             f"{_packing_factor(degree)})")
        return FitPlan(path=KERNEL_PACKED, reason="forced", **common)
    if engine == "kernel":
        if b >= PACKED_MIN_BATCH and _packing_factor(degree) >= 2:
            return FitPlan(path=KERNEL_PACKED,
                           reason=f"forced kernel; batch {b} packs "
                           f"{_packing_factor(degree)}/tile", **common)
        return FitPlan(path=KERNEL_PLAIN,
                       reason="forced kernel; no packing room", **common)

    # ---- auto -----------------------------------------------------------
    if not monomial:
        return FitPlan(path=REFERENCE, reason=f"auto: basis={basis} has no "
                       "kernel", **common)
    if not _kernel_degree_ok(degree):
        return FitPlan(path=REFERENCE,
                       reason=f"auto: degree {degree} > kernel tile",
                       **common)
    if backend != "tpu":
        return FitPlan(path=REFERENCE, reason=f"auto: backend={backend} "
                       "(interpret-mode Pallas loses to XLA)", **common)
    if b >= PACKED_MIN_BATCH and _packing_factor(degree) >= 2:
        return FitPlan(path=KERNEL_PACKED,
                       reason=f"auto: batch {b} packs "
                       f"{_packing_factor(degree)} series/tile", **common)
    if b * n >= KERNEL_MIN_POINTS:
        return FitPlan(path=KERNEL_PLAIN,
                       reason=f"auto: {b * n} pts >= crossover "
                       f"{KERNEL_MIN_POINTS}", **common)
    return FitPlan(path=REFERENCE,
                   reason=f"auto: {b * n} pts below kernel crossover",
                   **common)


# instrumented counter on moment-producing calls — the "exactly one data
# pass" contract of repro.select is asserted against it.  Counts every
# compute_moments invocation and the points it touches; under jit the
# increment happens at trace time, i.e. it counts moment-producing
# *computations in the traced program* — one accumulation in the compiled
# graph is one tick, which is precisely the pass count that matters.
_MOMENT_COUNTER = {"calls": 0, "points": 0}


def reset_moment_counter() -> None:
    _MOMENT_COUNTER["calls"] = 0
    _MOMENT_COUNTER["points"] = 0


def moment_counter() -> dict:
    """Snapshot of the moment-pass counter: {"calls": int, "points": int}."""
    return dict(_MOMENT_COUNTER)


def compute_moments(plan: FitPlan, x: jax.Array, y: jax.Array,
                    weights: jax.Array | None = None):
    """Execute a plan's moment accumulation.  Returns ``core.Moments``.

    ``x``/``y`` must already be domain-mapped if ``plan.numerics.normalize``
    (the Domain lives with the caller, next to the solve)."""
    _MOMENT_COUNTER["calls"] += 1
    _MOMENT_COUNTER["points"] += math.prod(x.shape)
    if plan.uses_kernel:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.moments(
            x, y, plan.degree, weights=weights,
            block_n=plan.block_n,
            accum_dtype=plan.numerics.accum_dtype,
            packing=plan.packing,
            compensated=plan.numerics.compensated,
            interpret=plan.interpret)
    from repro.core import moments as moments_lib
    return moments_lib.gram_moments(
        x, y, plan.degree, basis=plan.basis, weights=weights,
        accum_dtype=plan.numerics.accum_dtype)


def compute_report_sums(plan: FitPlan, x: jax.Array, y: jax.Array,
                        coeffs: jax.Array,
                        weights: jax.Array | None = None) -> dict:
    """Execute a ``workload="report"`` plan: the seven evaluate/residual
    sums (Σw, Σwy, Σwy², Σwf, Σwf², Σwyf, Σwe²) every fit-report quantity
    derives from.  ``x`` must already be domain-mapped (monomial Horner)."""
    if plan.uses_kernel:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.fused_report_sums(
            x, y, coeffs, weights=weights, block_n=plan.block_n,
            interpret=plan.interpret)
    from repro.core import basis as basis_lib
    fitted = basis_lib.evaluate(coeffs, x, basis=plan.basis)
    w = jnp.ones_like(y) if weights is None else weights
    e = y - fitted
    return {"sw": jnp.sum(w, axis=-1),
            "sy": jnp.sum(w * y, axis=-1),
            "syy": jnp.sum(w * y * y, axis=-1),
            "sf": jnp.sum(w * fitted, axis=-1),
            "sff": jnp.sum(w * fitted * fitted, axis=-1),
            "syf": jnp.sum(w * y * fitted, axis=-1),
            "sse": jnp.sum(w * e * e, axis=-1)}
