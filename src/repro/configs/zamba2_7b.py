"""Zamba2-7B hybrid: Mamba2 backbone + 2 alternating shared attention blocks
[arXiv:2411.15242]. 81 mamba blocks; shared block every 6."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab_size=32000, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    attn_every=6, n_shared_blocks=2)

SMOKE = dataclasses.replace(
    CONFIG, arch="zamba2-smoke", n_layers=5, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=256, ssm_state=16, ssm_head_dim=16,
    attn_every=2)
