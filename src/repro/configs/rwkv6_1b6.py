"""RWKV6 'Finch' 1.6B: attention-free, data-dependent decay [arXiv:2404.05892]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168,
    vocab_size=65536, head_dim=64, decay_lora=64)

SMOKE = dataclasses.replace(
    CONFIG, arch="rwkv6-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256, decay_lora=8)
