"""LLaVA-NeXT (mistral-7b backbone); anyres tiling frontend stubbed to
precomputed patch embeddings [hf:llava-hf/llava-v1.6-mistral-7b-hf].
2880 image tokens = anyres 4+1 tiles x 576 patches."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, rope_theta=1000000.0, n_image_tokens=2880)

SMOKE = dataclasses.replace(
    CONFIG, arch="llava-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256, n_image_tokens=8)
