"""Yi-6B: llama-arch GQA kv=4 [arXiv:2403.04652]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11008,
    vocab_size=64000, rope_theta=5000000.0)

SMOKE = dataclasses.replace(
    CONFIG, arch="yi-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256)
