"""Assigned-architecture configs (exact published dims) + reduced smoke
variants. ``get_config(arch)`` / ``get_smoke_config(arch)`` / ``ARCHS``."""
from __future__ import annotations

import dataclasses

from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES, shapes_for,
                                SUBQUADRATIC_FAMILIES)

from repro.configs import (dbrx_132b, phi35_moe_42b, zamba2_7b, rwkv6_1b6,
                           internlm2_1b8, yi_6b, qwen15_4b, gemma2_27b,
                           whisper_base, llava_next_mistral_7b)

_MODULES = {
    "dbrx-132b": dbrx_132b,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b,
    "zamba2-7b": zamba2_7b,
    "rwkv6-1.6b": rwkv6_1b6,
    "internlm2-1.8b": internlm2_1b8,
    "yi-6b": yi_6b,
    "qwen1.5-4b": qwen15_4b,
    "gemma2-27b": gemma2_27b,
    "whisper-base": whisper_base,
    "llava-next-mistral-7b": llava_next_mistral_7b,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].SMOKE


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shapes_for", "ARCHS",
           "get_config", "get_smoke_config", "SUBQUADRATIC_FAMILIES"]
