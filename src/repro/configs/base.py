"""Config schema for the model zoo + the assigned input-shape grid."""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None            # default d_model // n_heads
    norm: str = "rmsnorm"                  # rmsnorm | layernorm
    activation: str = "silu"               # silu | gelu
    use_qkv_bias: bool = False
    rope_theta: float = 10000.0
    attn_softcap: float | None = None      # gemma2: 50.0
    final_softcap: float | None = None     # gemma2: 30.0
    query_scale: float | None = None
    sliding_window: int | None = None
    layer_pattern: str = "full"            # full | local_global (gemma2)
    embed_scale: bool = False              # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = True
    post_norms: bool = False               # gemma2 sandwich norms
    attn_seq_shard: bool = False           # context-parallel attention
                                           # (for n_heads % TP != 0)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 64
    decay_lora: int = 64                   # rwkv6
    attn_every: int = 0                    # zamba2: shared attn every k blocks
    n_shared_blocks: int = 2
    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # vlm (llava)
    n_image_tokens: int = 0
    # execution policy
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"                    # none | full | dots
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND roofline."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d
        if self.family in ("dense", "vlm"):
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            mlp = 3 * d * self.d_ff
            return emb + self.n_layers * (attn + mlp)
        if self.family == "moe":
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            moe = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            return emb + self.n_layers * (attn + moe)
        if self.family == "ssm":  # rwkv6
            att = 6 * d * d + 2 * d * self.decay_lora
            ffn = 2 * d * self.d_ff + d * d
            return emb + self.n_layers * (att + ffn)
        if self.family == "hybrid":  # zamba2
            di = self.ssm_expand * d
            proj = d * (2 * di + 2 * self.ssm_state + di // self.ssm_head_dim)
            mamba = proj + di * d
            shared = (2 * d) * self.n_heads * hd * 3 + self.n_heads * hd * d \
                + 2 * (2 * d) * self.d_ff + self.d_ff * d
            return emb + self.n_layers * mamba + self.n_shared_blocks * shared
        if self.family == "audio":  # whisper enc-dec
            attn = 4 * d * d
            mlp = 2 * d * self.d_ff
            per = attn + mlp
            return emb + self.n_enc_layers * per + self.n_dec_layers * (per + attn)
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        hd = self.resolved_head_dim
        emb = self.vocab_size * d
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        moe_active = self.top_k * 3 * d * self.d_ff + d * self.n_experts
        return emb + self.n_layers * (attn + moe_active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# The assigned LM shape grid (applies to every arch; long_500k only where
# sub-quadratic — see DESIGN.md §Arch-applicability).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.family in SUBQUADRATIC_FAMILIES:
        out.append(SHAPES["long_500k"])
    return out
