"""DBRX-132B: 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab_size=100352, n_experts=16, top_k=4, norm="layernorm",
    rope_theta=500000.0)

# capacity_factor 2.5: smoke runs are effectively dropless, so the
# prefill/decode consistency test validates cache+routing determinism rather
# than capacity-drop edge semantics (a train-side drop at the decoded
# position is an inherent train/serve divergence of capacity-based MoE —
# decode groups are single tokens and never overflow).
SMOKE = dataclasses.replace(
    CONFIG, arch="dbrx-132b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256, n_experts=4, top_k=2,
    capacity_factor=2.5)
