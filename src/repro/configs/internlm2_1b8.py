"""InternLM2-1.8B: llama-arch GQA [arXiv:2403.17297]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab_size=92544, rope_theta=1000000.0)

SMOKE = dataclasses.replace(
    CONFIG, arch="internlm2-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256)
