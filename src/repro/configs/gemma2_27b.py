"""Gemma2-27B: local/global alternating attention, logit softcaps, sandwich
norms, GeGLU [arXiv:2408.00118]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864,
    vocab_size=256000, head_dim=128, activation="gelu",
    attn_softcap=50.0, final_softcap=30.0,
    query_scale=(4608 / 32) ** -0.5,        # 1/sqrt(d_model/n_heads)
    sliding_window=4096, layer_pattern="local_global",
    embed_scale=True, post_norms=True)

SMOKE = dataclasses.replace(
    CONFIG, arch="gemma2-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=256, vocab_size=256, sliding_window=32,
    query_scale=(64 / 4) ** -0.5)
