"""Whisper-base enc-dec backbone; conv frontend stubbed [arXiv:2212.04356]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-base", family="audio",
    n_layers=12, n_enc_layers=6, n_dec_layers=6,
    d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab_size=51865, norm="layernorm", activation="gelu")

SMOKE = dataclasses.replace(
    CONFIG, arch="whisper-smoke", n_layers=4, n_enc_layers=2, n_dec_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256)
