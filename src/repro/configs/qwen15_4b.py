"""Qwen1.5-4B: QKV bias, MHA-equivalent GQA (kv=20) [hf:Qwen/Qwen1.5-4B]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
    vocab_size=151936, use_qkv_bias=True, rope_theta=5000000.0,
    # 20 heads do not divide the 16-way TP axis: context-parallel
    # attention (EXPERIMENTS.md Perf cell 1: 3.6x step-time win)
    attn_seq_shard=True)

SMOKE = dataclasses.replace(
    CONFIG, arch="qwen1.5-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=256)
