"""Phi-3.5-MoE 42B (6.6B active): 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab_size=32064, n_experts=16, top_k=2, norm="layernorm",
    rope_theta=10000.0)

# capacity_factor 2.5: see dbrx_132b.py — smoke is effectively dropless so
# the consistency test checks routing determinism, not capacity-drop edges.
SMOKE = dataclasses.replace(
    CONFIG, arch="phi3.5-moe-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=96, vocab_size=256, n_experts=4, top_k=2,
    capacity_factor=2.5)
