"""RWKV6-1.6B language model wrapper (attention-free; O(1) decode state)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import rwkv6


def _cfg(cfg: ModelConfig) -> rwkv6.RWKV6Config:
    return rwkv6.RWKV6Config(
        d_model=cfg.d_model, head_dim=cfg.resolved_head_dim, d_ff=cfg.d_ff,
        decay_lora=cfg.decay_lora, chunk=cfg.ssm_chunk)


def init_params(rng, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    re, rl = cm.split(rng, 2)
    rcfg = _cfg(cfg)
    return {
        "embed": cm.embed_init(re, cfg.vocab_size, cfg.d_model, dtype),
        "ln0": cm.layernorm_init(cfg.d_model, dtype),   # rwkv's post-embed LN
        "layers": cm.stack_layer_trees(
            [rwkv6.init(r, rcfg, dtype) for r in cm.split(rl, cfg.n_layers)]),
        "final_norm": cm.layernorm_init(cfg.d_model, dtype),
    }


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def param_specs(cfg: ModelConfig):
    return {
        "embed": cm.embed_specs(),
        "ln0": cm.layernorm_specs(),
        "layers": cm.add_layer_axis_to_specs(rwkv6.specs(_cfg(cfg))),
        "final_norm": cm.layernorm_specs(),
    }


def forward_train(params, cfg: ModelConfig, tokens, extra_embeds=None):
    dt = jnp.dtype(cfg.compute_dtype)
    rcfg = _cfg(cfg)
    h = cm.embed_lookup(params["embed"], tokens).astype(dt)
    h = cm.layernorm(params["ln0"], h)
    remat = cfg.remat != "none"

    def one(h, p):
        return rwkv6.block_train(p, rcfg, h), None

    fn = jax.checkpoint(one) if remat else one
    h, _ = cm.scan(fn, h, params["layers"])
    h = cm.layernorm(params["final_norm"], h)
    return cm.embed_logits(params["embed"], h), jnp.zeros((), jnp.float32)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int = 0,
                      dtype=jnp.bfloat16):
    """max_len unused: RWKV state is O(1) in sequence length — that's the
    whole point of running the long_500k cell on this arch."""
    rcfg = _cfg(cfg)
    one = rwkv6.init_state(rcfg, batch, jnp.dtype(cfg.compute_dtype))
    return {
        "layers": jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one),
        "len": jnp.zeros((), jnp.int32),
    }


def decode_state_specs(cfg: ModelConfig):
    return {"layers": cm.add_layer_axis_to_specs(rwkv6.state_specs()),
            "len": ()}


def decode_step(params, cfg: ModelConfig, token, state):
    dt = jnp.dtype(cfg.compute_dtype)
    rcfg = _cfg(cfg)
    h = cm.embed_lookup(params["embed"], token).astype(dt)
    h = cm.layernorm(params["ln0"], h)

    def one(h, xs):
        p, st = xs
        return rwkv6.block_decode(p, rcfg, h, st)

    h, new_states = cm.scan(one, h, (params["layers"], state["layers"]))
    h = cm.layernorm(params["final_norm"], h)
    logits = cm.embed_logits(params["embed"], h)
    return logits, {"layers": new_states, "len": state["len"] + 1}


def prefill(params, cfg: ModelConfig, tokens, max_len: int = 0,
            extra_embeds=None, cache_dtype=jnp.bfloat16):
    dt = jnp.dtype(cfg.compute_dtype)
    rcfg = _cfg(cfg)
    h = cm.embed_lookup(params["embed"], tokens).astype(dt)
    h = cm.layernorm(params["ln0"], h)
    init = init_decode_state(cfg, tokens.shape[0])
    remat = cfg.remat != "none"

    def one(h, xs):
        p, st = xs
        return rwkv6.block_prefill(p, rcfg, h, st)

    fn = jax.checkpoint(one) if remat else one
    h, new_states = cm.scan(fn, h, (params["layers"], init["layers"]))
    h = cm.layernorm(params["final_norm"], h)
    logits = cm.embed_logits(params["embed"], h[:, -1:])
    return logits, {"layers": new_states,
                    "len": jnp.asarray(tokens.shape[1], jnp.int32)}
