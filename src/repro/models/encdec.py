"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, n_frames, d_model) directly to the encoder.
Encoder: bidirectional pre-LN transformer + sinusoidal positions.
Decoder: causal self-attn (KV cache) + cross-attn over encoder output
(cross-KV computed once at prefill), learned positions, GELU MLPs,
LayerNorms with bias, logits tied to the decoder token embedding.

Shape mapping for the LM grid (DESIGN.md): train_4k → enc S frames + dec S/4
tokens; prefill_32k → enc S frames + dec prompt S/32; decode_32k → 1 new dec
token against enc 32768; long_500k skipped (full attention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import mlp as mlp_lib

DEC_RATIO_TRAIN = 4     # dec tokens = seq_len // 4 for train cells
DEC_RATIO_PREFILL = 32


def _attn_cfg(cfg: ModelConfig, causal: bool) -> attn.AttnConfig:
    return attn.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, use_bias=True, use_rope=False)


def dec_len(cfg: ModelConfig, seq_len: int, kind: str) -> int:
    if kind == "train":
        return max(64, seq_len // DEC_RATIO_TRAIN)
    return max(64, seq_len // DEC_RATIO_PREFILL)


def _enc_layer_init(rng, cfg, dtype):
    ra, rm = cm.split(rng, 2)
    return {"ln1": cm.layernorm_init(cfg.d_model, dtype),
            "attn": attn.init(ra, _attn_cfg(cfg, False), dtype),
            "ln2": cm.layernorm_init(cfg.d_model, dtype),
            "mlp": mlp_lib.plain_init(rm, cfg.d_model, cfg.d_ff, dtype)}


def _dec_layer_init(rng, cfg, dtype):
    ra, rc, rm = cm.split(rng, 3)
    return {"ln1": cm.layernorm_init(cfg.d_model, dtype),
            "self_attn": attn.init(ra, _attn_cfg(cfg, True), dtype),
            "ln_cross": cm.layernorm_init(cfg.d_model, dtype),
            "cross_attn": attn.init(rc, _attn_cfg(cfg, False), dtype),
            "ln2": cm.layernorm_init(cfg.d_model, dtype),
            "mlp": mlp_lib.plain_init(rm, cfg.d_model, cfg.d_ff, dtype)}


def _enc_layer_specs(cfg):
    return {"ln1": cm.layernorm_specs(),
            "attn": attn.specs(_attn_cfg(cfg, False)),
            "ln2": cm.layernorm_specs(), "mlp": mlp_lib.plain_specs()}


def _dec_layer_specs(cfg):
    return {"ln1": cm.layernorm_specs(),
            "self_attn": attn.specs(_attn_cfg(cfg, True)),
            "ln_cross": cm.layernorm_specs(),
            "cross_attn": attn.specs(_attn_cfg(cfg, False)),
            "ln2": cm.layernorm_specs(), "mlp": mlp_lib.plain_specs()}


def init_params(rng, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    re, ren, rde, rp = cm.split(rng, 4)
    return {
        "embed": cm.embed_init(re, cfg.vocab_size, cfg.d_model, dtype),
        "dec_pos": cm.dense_init(rp, (8192, cfg.d_model), (1,), dtype),
        "enc_layers": cm.stack_layer_trees(
            [_enc_layer_init(r, cfg, dtype)
             for r in cm.split(ren, cfg.n_enc_layers)]),
        "enc_final": cm.layernorm_init(cfg.d_model, dtype),
        "dec_layers": cm.stack_layer_trees(
            [_dec_layer_init(r, cfg, dtype)
             for r in cm.split(rde, cfg.n_dec_layers)]),
        "dec_final": cm.layernorm_init(cfg.d_model, dtype),
    }


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def param_specs(cfg: ModelConfig):
    return {
        "embed": cm.embed_specs(),
        "dec_pos": (None, "embed"),
        "enc_layers": cm.add_layer_axis_to_specs(_enc_layer_specs(cfg)),
        "enc_final": cm.layernorm_specs(),
        "dec_layers": cm.add_layer_axis_to_specs(_dec_layer_specs(cfg)),
        "dec_final": cm.layernorm_specs(),
    }


def _sinusoid(n, d, dtype):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, T, d) stub frame embeddings -> (B, T, d)."""
    dt = jnp.dtype(cfg.compute_dtype)
    h = frames.astype(dt) + _sinusoid(frames.shape[1], cfg.d_model, dt)
    acfg = _attn_cfg(cfg, False)
    from repro.models.transformer import Q_CHUNK

    from repro.sharding.rules import constrain

    def one(h, p):
        x = cm.layernorm(p["ln1"], h)
        # bidirectional attention; q-chunked above Q_CHUNK (a 32k encoder
        # would otherwise materialize S² probs — measured 141 GB/device)
        q, k, v = attn._qkv(p["attn"], acfg, x, None)
        if x.shape[1] > Q_CHUNK:
            a = attn._sdpa_chunked(acfg, q, k, v, window=None,
                                   q_chunk=Q_CHUNK, causal=False)
        else:
            mask = jnp.ones((1, 1, x.shape[1], x.shape[1]), bool)
            a = attn._sdpa(acfg, q, k, v, mask)
        h = h + jnp.einsum("bshk,hkd->bsd", a,
                           p["attn"]["wo"].astype(x.dtype))
        h = h + mlp_lib.plain_apply(p["mlp"], cm.layernorm(p["ln2"], h))
        return constrain(h, "batch", None, None), None

    fn = jax.checkpoint(one) if cfg.remat != "none" else one
    h, _ = cm.scan(fn, h, params["enc_layers"])
    return cm.layernorm(params["enc_final"], h)


def _dec_block(p, cfg, acfg, h, positions, enc_out, self_mode, cache=None,
               cache_len=None):
    """self_mode: 'train' (causal full-seq) or 'decode' (1 token + cache)."""
    x = cm.layernorm(p["ln1"], h)
    if self_mode == "train":
        a = attn.attend_train(p["self_attn"], acfg, x, positions)
        nkv = None
    elif self_mode == "prefill":
        a, nkv = attn.attend_prefill(p["self_attn"], acfg, x, positions,
                                     cache)
    else:
        a, nkv = attn.attend_decode(p["self_attn"], acfg, x, cache, cache_len)
    h = h + a
    c = attn.attend_cross(p["cross_attn"], acfg,
                          cm.layernorm(p["ln_cross"], h), enc_out)
    h = h + c
    h = h + mlp_lib.plain_apply(p["mlp"], cm.layernorm(p["ln2"], h))
    from repro.sharding.rules import constrain
    return constrain(h, "batch", None, None), nkv


def forward_train(params, cfg: ModelConfig, batch):
    """batch: {'frames': (B,T,d), 'dec_tokens': (B,S) int32}."""
    enc_out = encode(params, cfg, batch["frames"])
    dt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["dec_tokens"]
    b, s = tokens.shape
    h = (cm.embed_lookup(params["embed"], tokens).astype(dt)
         + params["dec_pos"][:s].astype(dt))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    acfg = _attn_cfg(cfg, True)

    def one(h, p):
        h, _ = _dec_block(p, cfg, acfg, h, positions, enc_out, "train")
        return h, None

    fn = jax.checkpoint(one) if cfg.remat != "none" else one
    h, _ = cm.scan(fn, h, params["dec_layers"])
    h = cm.layernorm(params["dec_final"], h)
    return cm.embed_logits(params["embed"], h), jnp.zeros((), jnp.float32)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int, dtype=jnp.bfloat16):
    acfg = _attn_cfg(cfg, True)
    one = attn.init_cache(acfg, batch, max_len, dtype)
    return {
        "self_kv": jax.tree.map(
            lambda a: jnp.zeros((cfg.n_dec_layers,) + a.shape, a.dtype), one),
        "enc_out": jnp.zeros((batch, enc_len, cfg.d_model), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def decode_state_specs(cfg: ModelConfig):
    return {"self_kv": cm.add_layer_axis_to_specs(attn.cache_specs()),
            "enc_out": ("batch", "kv_seq", "embed"),
            "len": ()}


def prefill(params, cfg: ModelConfig, batch, max_len: int,
            cache_dtype=jnp.bfloat16):
    """Encode frames + run the decoder prompt. batch: {'frames', 'dec_tokens'}."""
    enc_out = encode(params, cfg, batch["frames"])
    dt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["dec_tokens"]
    b, s = tokens.shape
    h = (cm.embed_lookup(params["embed"], tokens).astype(dt)
         + params["dec_pos"][:s].astype(dt))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    acfg = _attn_cfg(cfg, True)
    empty = attn.init_cache(acfg, b, max_len, cache_dtype)

    def one(h, p):
        h, kv = _dec_block(p, cfg, acfg, h, positions, enc_out, "prefill",
                           cache=empty)
        return h, kv

    h, kvs = cm.scan(one, h, params["dec_layers"])
    h = cm.layernorm(params["dec_final"], h)
    logits = cm.embed_logits(params["embed"], h[:, -1:])
    return logits, {"self_kv": kvs,
                    "enc_out": enc_out.astype(cache_dtype),
                    "len": jnp.asarray(s, jnp.int32)}


def decode_step(params, cfg: ModelConfig, token, state):
    dt = jnp.dtype(cfg.compute_dtype)
    b = token.shape[0]
    cache_len = state["len"]
    h = (cm.embed_lookup(params["embed"], token).astype(dt)
         + jax.lax.dynamic_slice_in_dim(params["dec_pos"], cache_len, 1,
                                        axis=0).astype(dt))
    acfg = _attn_cfg(cfg, True)
    enc_out = state["enc_out"].astype(dt)

    def one(h, xs):
        p, kv = xs
        h, nkv = _dec_block(p, cfg, acfg, h, None, enc_out, "decode",
                            cache=kv, cache_len=cache_len)
        return h, nkv

    h, nkvs = cm.scan(one, h, (params["dec_layers"], state["self_kv"]))
    h = cm.layernorm(params["dec_final"], h)
    logits = cm.embed_logits(params["embed"], h)
    return logits, {"self_kv": nkvs, "enc_out": state["enc_out"],
                    "len": cache_len + 1}
