"""Feed-forward blocks: gated (SwiGLU/GeGLU, llama/gemma-style) and plain
(GELU, whisper-style)."""
from __future__ import annotations

import jax.numpy as jnp
import jax

from repro.models import common as cm


def gated_init(rng, d_model, d_ff, dtype=jnp.float32):
    rg, ru, rd = cm.split(rng, 3)
    return {
        "w_gate": cm.dense_init(rg, (d_model, d_ff), (0,), dtype),
        "w_up": cm.dense_init(ru, (d_model, d_ff), (0,), dtype),
        "w_down": cm.dense_init(rd, (d_ff, d_model), (0,), dtype),
    }


def gated_specs():
    return {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
            "w_down": ("mlp", "embed")}


def gated_apply(params, x, *, activation="silu"):
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
    act = cm.swiglu(g, u) if activation == "silu" else cm.geglu(g, u)
    return jnp.einsum("bsf,fd->bsd", act, params["w_down"].astype(x.dtype))


def plain_init(rng, d_model, d_ff, dtype=jnp.float32):
    r1, r2 = cm.split(rng, 2)
    return {
        "w_in": cm.dense_init(r1, (d_model, d_ff), (0,), dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": cm.dense_init(r2, (d_ff, d_model), (0,), dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def plain_specs():
    return {"w_in": ("embed", "mlp"), "b_in": ("mlp",),
            "w_out": ("mlp", "embed"), "b_out": ("embed",)}


def plain_apply(params, x):
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(x.dtype))
    h = jax.nn.gelu(h + params["b_in"].astype(x.dtype), approximate=True)
    return (jnp.einsum("bsf,fd->bsd", h, params["w_out"].astype(x.dtype))
            + params["b_out"].astype(x.dtype))
