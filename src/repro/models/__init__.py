"""Model zoo: dense GQA, MoE, SSM (RWKV6), hybrid (Zamba2/Mamba2),
enc-dec (Whisper), VLM (LLaVA) — all pure-functional JAX."""
from repro.models.registry import ModelAPI, get_model

__all__ = ["ModelAPI", "get_model"]
