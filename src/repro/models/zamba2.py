"""Zamba2-7B hybrid: Mamba2 backbone + 2 alternating *shared* attention
blocks (arXiv:2411.15242).

Structure here (simplifications noted in DESIGN.md): `n_layers` Mamba2 blocks;
before every `attn_every`-th block a shared transformer block runs on
concat(hidden, initial_embedding) (2·d_model wide, as in the paper) and its
output (projected back to d_model) is added to the residual stream. The two
shared blocks alternate across applications. Per-application LoRA deltas on
the shared weights are omitted.

Scan layout: groups of `attn_every` mamba blocks; group g applies shared
block g % 2 first. Shared params are stacked (2, ...) and gathered per group
inside the scan (an HBM read, not a copy-compute).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import mamba2
from repro.models import mlp as mlp_lib


def _m2cfg(cfg: ModelConfig) -> mamba2.Mamba2Config:
    return mamba2.Mamba2Config(
        d_model=cfg.d_model, d_state=cfg.ssm_state,
        head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
        conv_width=cfg.ssm_conv_width, chunk=cfg.ssm_chunk)


def _shared_attn_cfg(cfg: ModelConfig) -> attn.AttnConfig:
    d2 = 2 * cfg.d_model
    return attn.AttnConfig(
        d_model=d2, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=d2 // cfg.n_heads, rope_theta=cfg.rope_theta)


def n_groups(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def tail_layers(cfg: ModelConfig) -> int:
    return cfg.n_layers % cfg.attn_every


def _shared_block_init(rng, cfg: ModelConfig, dtype):
    ra, rm, ro = cm.split(rng, 3)
    d, d2 = cfg.d_model, 2 * cfg.d_model
    acfg = _shared_attn_cfg(cfg)
    return {
        "ln_attn": cm.rmsnorm_init(d2, dtype),
        "attn": attn.init(ra, acfg, dtype),
        "attn_out": cm.dense_init(ro, (d2, d), (0,), dtype),
        "ln_mlp": cm.rmsnorm_init(d2, dtype),
        "mlp": {
            "w_gate": cm.dense_init(rm, (d2, cfg.d_ff), (0,), dtype),
            "w_up": cm.dense_init(rm, (d2, cfg.d_ff), (0,), dtype),
            "w_down": cm.dense_init(rm, (cfg.d_ff, d), (0,), dtype),
        },
    }


def _shared_block_specs(cfg: ModelConfig):
    return {
        "ln_attn": {"scale": ("embed",)},
        "attn": attn.specs(_shared_attn_cfg(cfg)),
        "attn_out": ("embed", "embed"),
        "ln_mlp": {"scale": ("embed",)},
        "mlp": {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
                "w_down": ("mlp", "embed")},
    }


def init_params(rng, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    re, rm, rs, rn, rt = cm.split(rng, 5)
    m2 = _m2cfg(cfg)
    ng, tl = n_groups(cfg), tail_layers(cfg)
    body = [{"ln": cm.rmsnorm_init(cfg.d_model, dtype),
             "mamba": mamba2.init(r, m2, dtype)}
            for r in cm.split(rm, cfg.n_layers)]
    grouped = cm.stack_layer_trees(body[:ng * cfg.attn_every])
    # reshape (ng*k, ...) -> (ng, k, ...)
    grouped = jax.tree.map(
        lambda a: a.reshape((ng, cfg.attn_every) + a.shape[1:]), grouped)
    params = {
        "embed": cm.embed_init(re, cfg.vocab_size, cfg.d_model, dtype),
        "blocks": grouped,
        "shared": cm.stack_layer_trees(
            [_shared_block_init(r, cfg, dtype)
             for r in cm.split(rs, cfg.n_shared_blocks)]),
        "final_norm": cm.rmsnorm_init(cfg.d_model, dtype),
    }
    if tl:
        params["tail"] = cm.stack_layer_trees(body[ng * cfg.attn_every:])
    return params


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def param_specs(cfg: ModelConfig):
    m2 = _m2cfg(cfg)
    block = {"ln": cm.rmsnorm_specs(), "mamba": mamba2.specs(m2)}
    grouped = jax.tree.map(lambda ax: ("layers", None) + tuple(ax), block,
                           is_leaf=lambda x: isinstance(x, tuple))
    s = {
        "embed": cm.embed_specs(),
        "blocks": grouped,
        "shared": cm.add_layer_axis_to_specs(_shared_block_specs(cfg)),
        "final_norm": cm.rmsnorm_specs(),
    }
    if tail_layers(cfg):
        s["tail"] = cm.add_layer_axis_to_specs(block)
    return s


# ------------------------------------------------------------------ shared
def _apply_shared_train(sp, cfg: ModelConfig, h, emb0, positions):
    """One shared-block application (training/full-seq)."""
    from repro.sharding.rules import constrain
    acfg = _shared_attn_cfg(cfg)
    h = constrain(h, "batch", None, None)
    xcat = jnp.concatenate([h, emb0], axis=-1)
    from repro.models.transformer import Q_CHUNK
    a = attn.attend_train(sp["attn"], acfg, cm.rmsnorm(sp["ln_attn"], xcat),
                          positions,
                          q_chunk=Q_CHUNK if h.shape[1] > Q_CHUNK else None)
    h = h + jnp.einsum("bsd,de->bse", a, sp["attn_out"].astype(a.dtype))
    xcat = jnp.concatenate([h, emb0], axis=-1)
    x = cm.rmsnorm(sp["ln_mlp"], xcat)
    g = jnp.einsum("bsd,df->bsf", x, sp["mlp"]["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, sp["mlp"]["w_up"].astype(x.dtype))
    m = jnp.einsum("bsf,fd->bsd", cm.swiglu(g, u),
                   sp["mlp"]["w_down"].astype(x.dtype))
    return h + m


def _apply_shared_decode(sp, cfg: ModelConfig, h, emb0, kv, cache_len):
    acfg = _shared_attn_cfg(cfg)
    xcat = jnp.concatenate([h, emb0], axis=-1)
    a, nkv = attn.attend_decode(sp["attn"], acfg,
                                cm.rmsnorm(sp["ln_attn"], xcat), kv, cache_len)
    h = h + jnp.einsum("bsd,de->bse", a, sp["attn_out"].astype(a.dtype))
    xcat = jnp.concatenate([h, emb0], axis=-1)
    x = cm.rmsnorm(sp["ln_mlp"], xcat)
    g = jnp.einsum("bsd,df->bsf", x, sp["mlp"]["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, sp["mlp"]["w_up"].astype(x.dtype))
    m = jnp.einsum("bsf,fd->bsd", cm.swiglu(g, u),
                   sp["mlp"]["w_down"].astype(x.dtype))
    return h + m, nkv


def _mamba_subscan(cfg: ModelConfig, group_params, h, remat: bool):
    m2 = _m2cfg(cfg)

    def one(h, p):
        x = cm.rmsnorm(p["ln"], h)
        return h + mamba2.apply_train(p["mamba"], m2, x), None

    fn = jax.checkpoint(one) if remat else one
    h, _ = cm.scan(fn, h, group_params)
    return h


# ------------------------------------------------------------------- train
def forward_train(params, cfg: ModelConfig, tokens, extra_embeds=None):
    dt = jnp.dtype(cfg.compute_dtype)
    emb0 = cm.embed_lookup(params["embed"], tokens).astype(dt)
    h = emb0
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    remat = cfg.remat != "none"

    def group(h, xs):
        gp, gi = xs
        sp = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(
                a, gi % cfg.n_shared_blocks, 0, keepdims=False),
            params["shared"])
        fn = (jax.checkpoint(lambda sp, h: _apply_shared_train(
            sp, cfg, h, emb0, positions)) if remat
            else (lambda sp, h: _apply_shared_train(sp, cfg, h, emb0,
                                                    positions)))
        h = fn(sp, h)
        h = _mamba_subscan(cfg, gp, h, remat)
        return h, None

    h, _ = cm.scan(group, h,
                        (params["blocks"], jnp.arange(n_groups(cfg))))
    if tail_layers(cfg):
        h = _mamba_subscan(cfg, params["tail"], h, remat)
    h = cm.rmsnorm(params["final_norm"], h)
    logits = cm.embed_logits(params["embed"], h)
    return logits, jnp.zeros((), jnp.float32)


# ----------------------------------------------------------------- serving
def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    m2 = _m2cfg(cfg)
    acfg = _shared_attn_cfg(cfg)
    ng, tl = n_groups(cfg), tail_layers(cfg)
    one_m = mamba2.init_state(m2, batch)
    state = {
        "blocks": jax.tree.map(
            lambda a: jnp.zeros((ng, cfg.attn_every) + a.shape, a.dtype),
            one_m),
        "shared_kv": jax.tree.map(
            lambda a: jnp.zeros((ng,) + a.shape, a.dtype),
            attn.init_cache(acfg, batch, max_len, dtype)),
        "len": jnp.zeros((), jnp.int32),
    }
    if tl:
        state["tail"] = jax.tree.map(
            lambda a: jnp.zeros((tl,) + a.shape, a.dtype), one_m)
    return state


def decode_state_specs(cfg: ModelConfig):
    m2spec = mamba2.state_specs()
    s = {
        "blocks": jax.tree.map(lambda ax: ("layers", None) + tuple(ax),
                               m2spec, is_leaf=lambda x: isinstance(x, tuple)),
        "shared_kv": cm.add_layer_axis_to_specs(attn.cache_specs()),
        "len": (),
    }
    if tail_layers(cfg):
        s["tail"] = cm.add_layer_axis_to_specs(m2spec)
    return s


def decode_step(params, cfg: ModelConfig, token, state):
    dt = jnp.dtype(cfg.compute_dtype)
    emb0 = cm.embed_lookup(params["embed"], token).astype(dt)
    h = emb0
    m2 = _m2cfg(cfg)
    cache_len = state["len"]

    def mamba_scan(h, gp, gs):
        def one(h, xs):
            p, st = xs
            x = cm.rmsnorm(p["ln"], h)
            o, nst = mamba2.apply_decode(p["mamba"], m2, x, st)
            return h + o, nst
        return cm.scan(one, h, (gp, gs))

    def group(h, xs):
        gp, gs, kv, gi = xs
        sp = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(
                a, gi % cfg.n_shared_blocks, 0, keepdims=False),
            params["shared"])
        h, nkv = _apply_shared_decode(sp, cfg, h, emb0, kv, cache_len)
        h, ns = mamba_scan(h, gp, gs)
        return h, (ns, nkv)

    h, (nblocks, nkv) = cm.scan(
        group, h, (params["blocks"], state["blocks"], state["shared_kv"],
                   jnp.arange(n_groups(cfg))))
    new_state = {"blocks": nblocks, "shared_kv": nkv, "len": cache_len + 1}
    if tail_layers(cfg):
        h, ntail = mamba_scan(h, params["tail"], state["tail"])
        new_state["tail"] = ntail
    h = cm.rmsnorm(params["final_norm"], h)
    logits = cm.embed_logits(params["embed"], h)
    return logits, new_state


def prefill(params, cfg: ModelConfig, tokens, max_len: int,
            extra_embeds=None, cache_dtype=jnp.bfloat16):
    """Full-sequence forward that seeds every decode state: SSD final states
    (via chunked_gla), conv tails, and the shared blocks' KV caches."""
    dt = jnp.dtype(cfg.compute_dtype)
    emb0 = cm.embed_lookup(params["embed"], tokens).astype(dt)
    h = emb0
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    m2 = _m2cfg(cfg)
    acfg = _shared_attn_cfg(cfg)
    remat = cfg.remat != "none"

    def mamba_prefill_scan(h, gp, gs):
        def one(h, xs):
            p, st = xs
            x = cm.rmsnorm(p["ln"], h)
            o, nst = mamba2.apply_prefill(p["mamba"], m2, x, st)
            return h + o, nst
        fn = jax.checkpoint(one) if remat else one
        return cm.scan(fn, h, (gp, gs))

    def shared_prefill(sp, h):
        xcat = jnp.concatenate([h, emb0], axis=-1)
        from repro.models.transformer import Q_CHUNK
        empty = attn.init_cache(acfg, b, max_len, cache_dtype)
        a, kv = attn.attend_prefill(
            sp["attn"], acfg, cm.rmsnorm(sp["ln_attn"], xcat), positions,
            empty, q_chunk=Q_CHUNK if s > Q_CHUNK else None)
        h = h + jnp.einsum("bsd,de->bse", a, sp["attn_out"].astype(a.dtype))
        xcat = jnp.concatenate([h, emb0], axis=-1)
        x = cm.rmsnorm(sp["ln_mlp"], xcat)
        g = jnp.einsum("bsd,df->bsf", x, sp["mlp"]["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, sp["mlp"]["w_up"].astype(x.dtype))
        m = jnp.einsum("bsf,fd->bsd", cm.swiglu(g, u),
                       sp["mlp"]["w_down"].astype(x.dtype))
        return h + m, kv

    init = init_decode_state(cfg, b, max_len, cache_dtype)

    def group(h, xs):
        gp, gs, gi = xs
        sp = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(
                a, gi % cfg.n_shared_blocks, 0, keepdims=False),
            params["shared"])
        h, kv = shared_prefill(sp, h)
        h, ns = mamba_prefill_scan(h, gp, gs)
        return h, (ns, kv)

    h, (nblocks, nkv) = cm.scan(
        group, h, (params["blocks"], init["blocks"],
                   jnp.arange(n_groups(cfg))))
    state = {"blocks": nblocks, "shared_kv": nkv,
             "len": jnp.asarray(s, jnp.int32)}
    if tail_layers(cfg):
        h, ntail = mamba_prefill_scan(h, params["tail"], init["tail"])
        state["tail"] = ntail
    h = cm.rmsnorm(params["final_norm"], h)
    logits = cm.embed_logits(params["embed"], h[:, -1:])
    return logits, state
