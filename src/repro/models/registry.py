"""Model registry: one uniform interface over all zoo families.

  model = get_model(cfg)
  model.init_params / abstract_params / param_specs
  model.forward_train(params, batch)        batch dict (family-specific keys)
  model.prefill(params, batch, max_len)
  model.decode_step(params, token, state)
  model.init_decode_state / decode_state_specs
  model.input_specs(shape)                  ShapeDtypeStruct stand-ins
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, rwkv6_model, transformer, zamba2


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init_params: Callable
    abstract_params: Callable
    param_specs: Callable
    forward_train: Callable       # (params, batch) -> (logits, aux)
    prefill: Callable             # (params, batch, max_len) -> (logits, state)
    decode_step: Callable         # (params, token, state) -> (logits, state)
    init_decode_state: Callable   # (batch, max_len) -> state
    decode_state_specs: Callable
    input_specs: Callable         # (ShapeConfig) -> dict of SDS

    def batch_tokens(self, shape: ShapeConfig) -> int:
        """Tokens processed per step for this (cfg, shape) — roofline unit."""
        if shape.kind == "train":
            if self.cfg.family == "audio":
                return shape.global_batch * encdec.dec_len(
                    self.cfg, shape.seq_len, "train")
            return shape.global_batch * shape.seq_len
        if shape.kind == "prefill":
            n = shape.global_batch * shape.seq_len
            if self.cfg.family == "audio":
                n += shape.global_batch * encdec.dec_len(
                    self.cfg, shape.seq_len, "prefill")
            return n
        return shape.global_batch  # decode: 1 token per sequence


def _tok_specs(shape: ShapeConfig, seq):
    return jax.ShapeDtypeStruct((shape.global_batch, seq), jnp.int32)


def _decoder_like(cfg: ModelConfig, mod) -> ModelAPI:
    n_img = cfg.n_image_tokens

    def forward_train(params, batch):
        return mod.forward_train(params, cfg, batch["tokens"],
                                 batch.get("extra_embeds"))

    def prefill(params, batch, max_len):
        return mod.prefill(params, cfg, batch["tokens"], max_len,
                           extra_embeds=batch.get("extra_embeds"))

    def decode_step(params, token, state):
        return mod.decode_step(params, cfg, token, state)

    def init_decode_state(batch, max_len):
        return mod.init_decode_state(cfg, batch, max_len)

    def input_specs(shape: ShapeConfig):
        dt = jnp.dtype(cfg.compute_dtype)
        if shape.kind in ("train", "prefill"):
            text = shape.seq_len - n_img
            specs = {"tokens": _tok_specs(shape, text)}
            if n_img:
                specs["extra_embeds"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, n_img, cfg.d_model), dt)
            if shape.kind == "train":
                specs["labels"] = _tok_specs(shape, text if not n_img
                                             else shape.seq_len)
                specs["loss_mask"] = jax.ShapeDtypeStruct(
                    (shape.global_batch,
                     shape.seq_len if n_img else text), dt)
            return specs
        # decode: one token + cache of seq_len
        state = jax.eval_shape(
            lambda: mod.init_decode_state(cfg, shape.global_batch,
                                          shape.seq_len))
        return {"token": _tok_specs(shape, 1), "state": state}

    return ModelAPI(
        cfg=cfg,
        init_params=lambda rng, dtype=None: mod.init_params(rng, cfg, dtype),
        abstract_params=lambda: mod.abstract_params(cfg),
        param_specs=lambda: mod.param_specs(cfg),
        forward_train=forward_train, prefill=prefill, decode_step=decode_step,
        init_decode_state=init_decode_state,
        decode_state_specs=lambda: mod.decode_state_specs(cfg),
        input_specs=input_specs)


def _encdec_api(cfg: ModelConfig) -> ModelAPI:
    def forward_train(params, batch):
        return encdec.forward_train(params, cfg, batch)

    def prefill(params, batch, max_len):
        return encdec.prefill(params, cfg, batch, max_len)

    def decode_step(params, token, state):
        return encdec.decode_step(params, cfg, token, state)

    def init_decode_state(batch, max_len, enc_len=None):
        return encdec.init_decode_state(cfg, batch, max_len,
                                        enc_len or max_len)

    def input_specs(shape: ShapeConfig):
        dt = jnp.dtype(cfg.compute_dtype)
        frames = jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len, cfg.d_model), dt)
        if shape.kind in ("train", "prefill"):
            dl = encdec.dec_len(cfg, shape.seq_len, shape.kind)
            specs = {"frames": frames, "dec_tokens": _tok_specs(shape, dl)}
            if shape.kind == "train":
                specs["labels"] = _tok_specs(shape, dl)
                specs["loss_mask"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, dl), dt)
            return specs
        dl = encdec.dec_len(cfg, shape.seq_len, "prefill")
        state = jax.eval_shape(
            lambda: encdec.init_decode_state(cfg, shape.global_batch,
                                             dl + 256, shape.seq_len))
        return {"token": _tok_specs(shape, 1), "state": state}

    return ModelAPI(
        cfg=cfg,
        init_params=lambda rng, dtype=None: encdec.init_params(rng, cfg, dtype),
        abstract_params=lambda: encdec.abstract_params(cfg),
        param_specs=lambda: encdec.param_specs(cfg),
        forward_train=forward_train, prefill=prefill, decode_step=decode_step,
        init_decode_state=init_decode_state,
        decode_state_specs=lambda: encdec.decode_state_specs(cfg),
        input_specs=input_specs)


def get_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.family in ("dense", "moe", "vlm"):
        return _decoder_like(cfg, transformer)
    if cfg.family == "ssm":
        return _decoder_like(cfg, rwkv6_model)
    if cfg.family == "hybrid":
        return _decoder_like(cfg, zamba2)
    if cfg.family == "audio":
        return _encdec_api(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
