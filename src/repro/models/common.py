"""Functional building blocks for the model zoo.

No flax: every module is a pair of pure functions
  ``init(rng, cfg) -> params``   (nested dict of jnp arrays)
  ``apply(params, ...) -> out``
plus a parallel ``specs(cfg)`` tree of *logical axis names* per leaf, which
``repro.sharding.rules`` maps to mesh ``PartitionSpec``s. init/specs trees are
structurally identical by construction (tests assert it).

Logical axes used across the zoo:
  layers, embed (d_model), q_heads, kv_heads, head_dim, mlp (d_ff), vocab,
  experts, conv, state (SSM), lora, batch, seq, kv_seq
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = Any      # nested dict of arrays
Specs = Any       # same structure, leaves = tuple[str | None, ...]


# ---------------------------------------------------------------- init utils
def dense_init(rng, shape, in_axes=(0,), dtype=jnp.float32, scale=1.0):
    """Truncated-normal fan-in init (LeCun-style), matching common LM inits."""
    fan_in = 1
    for a in in_axes:
        fan_in *= shape[a]
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, dtype) * std)


def split(rng, n):
    return list(jax.random.split(rng, n))


# ---------------------------------------------------------------- norms
def rmsnorm_init(cfg_dim, dtype=jnp.float32):
    return {"scale": jnp.zeros((cfg_dim,), dtype)}  # stored as (1+scale) factor


def rmsnorm_specs():
    return {"scale": ("embed",)}


def rmsnorm(params, x, *, eps=1e-6, upcast=True):
    dt = x.dtype
    if upcast:
        x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(x.dtype))).astype(dt)


def layernorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_specs():
    return {"scale": ("embed",), "bias": ("embed",)}


def layernorm(params, x, *, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(x.dtype)
            + params["bias"].astype(x.dtype)).astype(dt)


# ---------------------------------------------------------------- embedding
def embed_init(rng, vocab, dim, dtype=jnp.float32):
    return {"table": jax.random.normal(rng, (vocab, dim), dtype)}


def embed_specs():
    # "table_embed" (not "embed"): the table's d_model axis must stay
    # replicated — see repro.sharding.rules.BASE_RULES
    return {"table": ("vocab", "table_embed")}


def embed_lookup(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def embed_logits(params, x, *, softcap: float | None = None):
    logits = jnp.einsum("...d,vd->...v", x,
                        params["table"].astype(x.dtype))
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


# ---------------------------------------------------------------- activations
def swiglu(gate, up):
    return jax.nn.silu(gate) * up


def geglu(gate, up):
    return jax.nn.gelu(gate, approximate=True) * up


def softcap(x, cap):
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------- rope
def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- scan
# Global switch: when True, every model scan fully unrolls. Used by the
# dry-run's cost pass — XLA's cost_analysis counts while-loop bodies ONCE
# (not × trip count), so exact FLOP/byte/collective counting compiles small
# reduced-layer configs with straight-line code and extrapolates linearly in
# the layer count (launch/dryrun.py::extrapolated_costs).
UNROLL_ALL = False


def scan(f, init, xs, length=None):
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if UNROLL_ALL else 1)


# ---------------------------------------------------------------- tree helpers
def tree_cast(params, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        params)


def stack_layer_trees(trees):
    """Stack a list of identical-structure param trees along a new leading
    'layers' axis (for lax.scan over layers)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def add_layer_axis_to_specs(specs):
    return jax.tree.map(lambda ax: ("layers",) + tuple(ax), specs,
                        is_leaf=lambda x: isinstance(x, tuple))
