"""Chunked gated linear recurrence — shared engine for Mamba2 (SSD, scalar
per-head decay) and RWKV6 (vector per-channel decay + bonus).

Recurrence (per head, state S ∈ R^{dk×dv}):
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    mamba/"inclusive":  y_t = q_tᵀ S_t
    rwkv/"bonus":       y_t = q_tᵀ (S_{t-1} + diag(u ⊙ k_t)·v_t-outer)

Training uses the chunked parallel form. Numerical design: the naive GLA
factorization (q·e^{cum}) @ (k·e^{-cum})ᵀ overflows for strong decays
(Mamba2 log-decays reach -10/step). Here every exponential has a
NON-POSITIVE exponent, so the math is stable for arbitrary decay strength:
  * cross-chunk state: q·e^{cum} (≤0), k·e^{total-cum} (≤0), state×e^{total}
  * intra-chunk scores use a sub-block decomposition (secondary chunking à la
    GLA): diagonal c×c sub-blocks compute exact per-channel log-space
    differences (small (c,c,dk) tensors); off-diagonal sub-block pairs (i>j)
    factor through the block-j end reference:
        cum_t - cum_s = (cum_t - end_j) + (end_j - cum_s),  both terms ≤ 0
    giving bounded matmuls on the MXU.
All math in f32. Shapes: q,k,logw: (B,H,T,dk); v: (B,H,T,dv); u: (H,dk)|None.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import common as cm

SUB = 16  # sub-block (secondary chunk) size


def _intra_scores(qc, kc, qcum, kcum, *, mode: str, sub: int = SUB):
    """Stable intra-chunk score matrix.

    qc, kc: (..., C, dk). kcum: inclusive cumulative log-decay; qcum is the
    q-side reference (== kcum for inclusive mode, kcum - logw for bonus mode,
    i.e. decay only through t-1). Returns scores: (..., C, C) with
    scores[t,s] = Σ_d q[t,d] k[s,d] e^{qcum[t,d]-kcum[s,d]}, causally masked
    (s<=t inclusive, s<t bonus).
    """
    c_total = qc.shape[-2]
    dk = qc.shape[-1]
    sub = min(sub, c_total)
    nb = c_total // sub
    lead = qc.shape[:-2]
    qs = qc.reshape(lead + (nb, sub, dk))
    ks = kc.reshape(lead + (nb, sub, dk))
    qcs = qcum.reshape(lead + (nb, sub, dk))
    kcs = kcum.reshape(lead + (nb, sub, dk))
    ends = kcs[..., -1:, :]                     # (..., nb, 1, dk)

    # --- diagonal blocks: exact per-channel log-space differences
    diff = qcs[..., :, None, :] - kcs[..., None, :, :]    # (...,nb,c,c,dk)
    tri = jnp.tril(jnp.ones((sub, sub), bool),
                   k=0 if mode == "inclusive" else -1)
    # mask exponent before exp to avoid inf from upper triangle
    diff = jnp.where(tri[..., None], diff, -jnp.inf)
    diag_scores = jnp.einsum("...tsd,...td,...sd->...ts",
                             jnp.exp(diff), qs, ks)       # (...,nb,c,c)

    if nb == 1:
        return diag_scores[..., 0, :, :]

    # --- off-diagonal pairs (i > j): all exponents <= 0
    rows = []
    for i in range(nb):
        row = []
        for j in range(nb):
            if j == i:
                row.append(diag_scores[..., i, :, :])
            elif j < i:
                qd = qs[..., i, :, :] * jnp.exp(
                    qcs[..., i, :, :] - ends[..., j, :, :])
                kd = ks[..., j, :, :] * jnp.exp(
                    ends[..., j, :, :] - kcs[..., j, :, :])
                row.append(jnp.einsum("...td,...sd->...ts", qd, kd))
            else:
                row.append(jnp.zeros(lead + (sub, sub), qc.dtype))
        rows.append(jnp.concatenate(row, axis=-1))
    return jnp.concatenate(rows, axis=-2)                 # (..., C, C)


@partial(jax.jit, static_argnames=("chunk", "mode"))
def chunked_gla(q, k, v, logw, *, u=None, initial_state=None,
                chunk: int = 64, mode: str = "inclusive"):
    """Returns (y: (B,H,T,dv), final_state: (B,H,dk,dv))."""
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    if mode not in ("inclusive", "bonus"):
        raise ValueError(mode)
    t_orig = t
    pad = (-t) % chunk
    if pad:
        # inert tail: q=k=v=0 (no output/state contribution), logw=0
        # (decay 1 ⇒ state passes through unchanged)
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q, k, v = zpad(q), zpad(k), zpad(v)
        logw = jnp.pad(jnp.broadcast_to(
            logw, (b, h, t, logw.shape[-1])),
            ((0, 0), (0, 0), (0, pad), (0, 0)))
        t = t + pad
    nc = t // chunk
    f32 = jnp.float32
    from repro.sharding.rules import constrain
    con = lambda a: constrain(a, "batch", "heads", None, None)
    qf, kf, vf = con(q.astype(f32)), con(k.astype(f32)), con(v.astype(f32))
    lw = jnp.broadcast_to(logw.astype(f32), (b, h, t, dk))

    resh = lambda a, d: a.reshape(b, h, nc, chunk, d)
    qc, kc, vc, lwc = resh(qf, dk), resh(kf, dk), resh(vf, dv), resh(lw, dk)
    cum = jnp.cumsum(lwc, axis=-2)                     # inclusive cumsum
    total = cum[..., -1:, :]                           # (B,H,nc,1,dk)

    # decay applied to the incoming state when it contributes to y_t
    q_decay = cum if mode == "inclusive" else cum - lwc
    qd_state = qc * jnp.exp(q_decay)                   # exponent <= 0
    k_tail = kc * jnp.exp(total - cum)                 # exponent <= 0

    scores = _intra_scores(qc, kc, q_decay, cum, mode=mode)
    y_intra = jnp.einsum("...ts,...sv->...tv", scores, vc)
    if mode == "bonus":
        uu = (u if u is not None else jnp.ones((h, dk), f32)).astype(f32)
        diag = jnp.einsum("bhntk,hk,bhntk->bhnt", qc, uu, kc)
        y_intra = y_intra + diag[..., None] * vc

    s0 = (jnp.zeros((b, h, dk, dv), f32) if initial_state is None
          else initial_state.astype(f32))

    def body(s, inp):
        qd_c, ktail_c, v_c, tot_c = inp
        y_inter = jnp.einsum("bhtk,bhkv->bhtv", qd_c, s)
        s_new = jnp.exp(tot_c)[:, :, 0, :, None] * s + jnp.einsum(
            "bhtk,bhtv->bhkv", ktail_c, v_c)
        return s_new, y_inter

    move = lambda a: jnp.moveaxis(a, 2, 0)             # nc to scan axis
    final, y_inter = cm.scan(
        body, s0, (move(qd_state), move(k_tail), move(vc), move(total)))
    y_inter = jnp.moveaxis(y_inter, 0, 2)
    y = (y_intra + y_inter).reshape(b, h, t, dv)[:, :, :t_orig]
    return y.astype(q.dtype), final


@partial(jax.jit, static_argnames=("mode",))
def gla_decode_step(q, k, v, logw, state, *, u=None, mode: str = "inclusive"):
    """One-token recurrence. q,k,logw: (B,H,dk); v: (B,H,dv);
    state: (B,H,dk,dv). Returns (y: (B,H,dv), new_state)."""
    f32 = jnp.float32
    qf, kf, vf = q.astype(f32), k.astype(f32), v.astype(f32)
    w = jnp.exp(jnp.broadcast_to(logw.astype(f32), qf.shape))
    kv = kf[..., :, None] * vf[..., None, :]           # (B,H,dk,dv)
    s = state.astype(f32)
    if mode == "inclusive":
        s_new = w[..., None] * s + kv
        y = jnp.einsum("bhk,bhkv->bhv", qf, s_new)
    else:
        bonus = (u.astype(f32) if u is not None
                 else jnp.ones(qf.shape[1:], f32))
        y = jnp.einsum("bhk,bhkv->bhv", qf, s + bonus[..., None] * kv)
        s_new = w[..., None] * s + kv
    return y.astype(q.dtype), s_new


def reference_recurrence(q, k, v, logw, *, u=None, initial_state=None,
                         mode: str = "inclusive"):
    """O(T) scan oracle for tests (matches chunked_gla in f32)."""
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    s0 = (jnp.zeros((b, h, dk, dv), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    lw = jnp.broadcast_to(logw, (b, h, t, dk))

    def body(s, inp):
        qt, kt, vt, lwt = inp
        y, s = gla_decode_step(qt, kt, vt, lwt, s, u=u, mode=mode)
        return s, y

    mv = lambda a: jnp.moveaxis(a, 2, 0)
    final, ys = cm.scan(body, s0, (mv(q), mv(k), mv(v), mv(lw)))
    return jnp.moveaxis(ys, 0, 2), final
