"""Mamba2 (SSD) block — zamba2's backbone mixer.

Structure (Mamba2 paper, arXiv:2405.21060, simplified to ngroups=1):
  in_proj -> [z (gate), x, B, C, dt]; depthwise causal conv (window 4) over
  (x,B,C); SSD recurrence with per-head scalar decay exp(-exp(A_log)·dt);
  +D·x skip; gated RMSNorm; out_proj.

Training uses the chunked GLA engine; decode keeps (conv_state, ssm_state).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import gla


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 64

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init(rng, cfg: Mamba2Config, dtype=jnp.float32):
    r_in, r_conv, r_out, r_dt = cm.split(rng, 4)
    di, ds, nh = cfg.d_inner, cfg.d_state, cfg.n_heads
    proj_out = 2 * di + 2 * ds + nh   # z, x, B, C, dt
    return {
        "w_in": cm.dense_init(r_in, (cfg.d_model, proj_out), (0,), dtype),
        "conv_w": cm.dense_init(r_conv, (cfg.conv_width, di + 2 * ds), (0,),
                                dtype, scale=1.0),
        "conv_b": jnp.zeros((di + 2 * ds,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=dtype)),
        "dt_bias": jnp.zeros((nh,), dtype),
        "d_skip": jnp.ones((nh,), dtype),
        "norm": cm.rmsnorm_init(di, dtype),
        "w_out": cm.dense_init(r_out, (di, cfg.d_model), (0,), dtype),
    }


def specs(cfg: Mamba2Config):
    return {
        "w_in": ("embed", "mlp"),
        "conv_w": ("conv", "mlp"),
        "conv_b": ("mlp",),
        "a_log": ("heads",),
        "dt_bias": ("heads",),
        "d_skip": ("heads",),
        "norm": cm.rmsnorm_specs(),
        "w_out": ("mlp", "embed"),
    }


def _split_proj(cfg: Mamba2Config, proj):
    di, ds, nh = cfg.d_inner, cfg.d_state, cfg.n_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * ds]
    dt = proj[..., di + di + 2 * ds:]
    return z, xbc, dt


def _causal_conv(cfg: Mamba2Config, xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv, width W. xbc: (b, s, c). conv_state: (b, W-1, c)
    carries the last W-1 inputs for decode continuity."""
    w = cfg.conv_width
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)
    out = sum(full[:, i:i + xbc.shape[1], :] * conv_w[i].astype(xbc.dtype)
              for i in range(w))
    out = jax.nn.silu(out + conv_b.astype(xbc.dtype))
    new_state = full[:, -(w - 1):, :]
    return out, new_state


def _ssd_inputs(cfg: Mamba2Config, params, xbc, dt):
    from repro.sharding.rules import constrain
    di, ds, nh, hd = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    x = constrain(xbc[..., :di], "batch", None, "mlp")
    bmat = xbc[..., di:di + ds]
    cmat = xbc[..., di + ds:]
    b, s, _ = x.shape
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (b,s,nh)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))              # (nh,)
    logw = (dt * a).transpose(0, 2, 1)[..., None]                  # (b,nh,s,1)
    xh = constrain(x.reshape(b, s, nh, hd).transpose(0, 2, 1, 3),
                   "batch", "heads", None, None)                   # (b,nh,s,hd)
    # dt scales the input (ZOH discretization): k = B, v = dt*x
    v = xh * dt.transpose(0, 2, 1)[..., None].astype(xh.dtype)
    k = jnp.broadcast_to(bmat[:, None], (b, nh, s, ds)).astype(xh.dtype)
    q = jnp.broadcast_to(cmat[:, None], (b, nh, s, ds)).astype(xh.dtype)
    return q, k, v, logw, xh


def _finish(cfg: Mamba2Config, params, y, xh, z):
    b, nh, s, hd = y.shape
    y = y + params["d_skip"].astype(y.dtype)[None, :, None, None] * xh
    y = y.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    y = cm.rmsnorm(params["norm"], y * jax.nn.silu(z))
    return jnp.einsum("bsd,de->bse", y, params["w_out"].astype(y.dtype))


def apply_train(params, cfg: Mamba2Config, x):
    proj = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(x.dtype))
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, _ = _causal_conv(cfg, xbc, params["conv_w"], params["conv_b"])
    q, k, v, logw, xh = _ssd_inputs(cfg, params, xbc, dt)
    y, _ = gla.chunked_gla(q, k, v, logw, chunk=cfg.chunk, mode="inclusive")
    return _finish(cfg, params, y, xh, z)


def init_state(cfg: Mamba2Config, batch, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1,
                           cfg.d_inner + 2 * cfg.d_state), dtype),
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim),
                         jnp.float32),
    }


def state_specs():
    return {"conv": ("batch", None, "mlp"),
            "ssm": ("batch", "heads", None, None)}


def apply_prefill(params, cfg: Mamba2Config, x, state):
    """Full-sequence forward that also returns the post-sequence state
    (conv tail + SSD final state) for subsequent decode."""
    proj = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(x.dtype))
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(cfg, xbc, params["conv_w"],
                                   params["conv_b"], state["conv"])
    q, k, v, logw, xh = _ssd_inputs(cfg, params, xbc, dt)
    y, ssm = gla.chunked_gla(q, k, v, logw, initial_state=state["ssm"],
                             chunk=cfg.chunk, mode="inclusive")
    return _finish(cfg, params, y, xh, z), {"conv": conv_state, "ssm": ssm}


def apply_decode(params, cfg: Mamba2Config, x, state):
    """x: (b, 1, d). Returns (out, new_state)."""
    proj = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(x.dtype))
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(cfg, xbc, params["conv_w"],
                                   params["conv_b"], state["conv"])
    q, k, v, logw, xh = _ssd_inputs(cfg, params, xbc, dt)
    y, ssm = gla.gla_decode_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                 logw[:, :, 0], state["ssm"],
                                 mode="inclusive")
    out = _finish(cfg, params, y[:, :, None, :], xh, z)
    return out, {"conv": conv_state, "ssm": ssm}
