"""Decoder-only transformer LM: dense (internlm2/yi/qwen1.5/mistral-llava),
gemma2 (local/global + softcaps + sandwich norms), and MoE (dbrx/phi3.5-moe).

Layers are scanned (stacked params along a leading 'layers' axis) with
configurable remat. Alternating layer patterns (gemma2 local/global) scan
over *groups* of layers so each position in the group gets a STATIC window —
no masked double-compute, roofline-honest.

Three execution paths share one layer body:
  forward_train : tokens -> logits (full causal)
  prefill       : tokens -> logits, KV cache
  decode_step   : 1 token + cache -> logits, cache
VLM (llava) is this model with stub patch embeddings prepended to the token
embeddings (anyres frontend is out-of-scope per assignment).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib

Q_CHUNK = 2048  # flash-style query chunking kicks in above this seq len


def _attn_cfg(cfg: ModelConfig) -> attn.AttnConfig:
    return attn.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        use_bias=cfg.use_qkv_bias, logit_softcap=cfg.attn_softcap,
        query_scale=cfg.query_scale, seq_shard=cfg.attn_seq_shard)


def _moe_cfg(cfg: ModelConfig) -> moe_lib.MoEConfig:
    return moe_lib.MoEConfig(
        d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
        activation=cfg.activation)


def _norm_init(cfg, dtype):
    return (cm.rmsnorm_init(cfg.d_model, dtype) if cfg.norm == "rmsnorm"
            else cm.layernorm_init(cfg.d_model, dtype))


def _norm_specs(cfg):
    return (cm.rmsnorm_specs() if cfg.norm == "rmsnorm"
            else cm.layernorm_specs())


def _norm(cfg, p, x):
    return cm.rmsnorm(p, x) if cfg.norm == "rmsnorm" else cm.layernorm(p, x)


def group_size(cfg: ModelConfig) -> int:
    """Layers per scan step: 2 for alternating local/global, else 1."""
    if cfg.layer_pattern == "local_global":
        assert cfg.n_layers % 2 == 0
        return 2
    return 1


def _group_windows(cfg: ModelConfig) -> tuple[int | None, ...]:
    if cfg.layer_pattern == "local_global":
        return (cfg.sliding_window, None)      # gemma2: local layer first
    return (None,)


# ----------------------------------------------------------------- params
def _layer_init(rng, cfg: ModelConfig, dtype):
    ra, rm = cm.split(rng, 2)
    p = {"ln1": _norm_init(cfg, dtype), "ln2": _norm_init(cfg, dtype),
         "attn": attn.init(ra, _attn_cfg(cfg), dtype)}
    if cfg.n_experts:
        p["moe"] = moe_lib.init(rm, _moe_cfg(cfg), dtype)
    else:
        p["mlp"] = mlp_lib.gated_init(rm, cfg.d_model, cfg.d_ff, dtype)
    if cfg.post_norms:
        p["ln1_post"] = _norm_init(cfg, dtype)
        p["ln2_post"] = _norm_init(cfg, dtype)
    return p


def _layer_specs(cfg: ModelConfig):
    s = {"ln1": _norm_specs(cfg), "ln2": _norm_specs(cfg),
         "attn": attn.specs(_attn_cfg(cfg))}
    if cfg.n_experts:
        s["moe"] = moe_lib.specs(_moe_cfg(cfg))
    else:
        s["mlp"] = mlp_lib.gated_specs()
    if cfg.post_norms:
        s["ln1_post"] = _norm_specs(cfg)
        s["ln2_post"] = _norm_specs(cfg)
    return s


def init_params(rng, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    re, rl, _ = cm.split(rng, 3)
    g = group_size(cfg)
    layer_trees = [_layer_init(r, cfg, dtype)
                   for r in cm.split(rl, cfg.n_layers)]
    params = {
        "embed": cm.embed_init(re, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": _norm_init(cfg, dtype),
        # grouped stack: tree leaves (n_groups, g, ...); g=1 when no pattern
        "layers": tuple(
            cm.stack_layer_trees(layer_trees[j::g]) for j in range(g)),
    }
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct tree (no allocation) for the dry-run."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def param_specs(cfg: ModelConfig):
    g = group_size(cfg)
    layer = cm.add_layer_axis_to_specs(_layer_specs(cfg))
    return {
        "embed": cm.embed_specs(),
        "final_norm": _norm_specs(cfg),
        "layers": tuple(layer for _ in range(g)),
    }


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    policy = (None if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


# ----------------------------------------------------------------- bodies
def _ffn(cfg: ModelConfig, p, h):
    """Post-attention half of a block. Returns (h, aux)."""
    from repro.sharding.rules import constrain
    aux = jnp.zeros((), jnp.float32)
    x = _norm(cfg, p["ln2"], h)
    if cfg.n_experts:
        m, aux = moe_lib.apply(p["moe"], _moe_cfg(cfg), x)
    else:
        m = mlp_lib.gated_apply(p["mlp"], x, activation=cfg.activation)
    if cfg.post_norms:
        m = _norm(cfg, p["ln2_post"], m)
    return constrain(h + m, "batch", None, None), aux


def _attn_train(cfg: ModelConfig, p, h, positions, window):
    from repro.sharding.rules import constrain
    acfg = _attn_cfg(cfg)
    a = attn.attend_train(p["attn"], acfg, _norm(cfg, p["ln1"], h), positions,
                          window=window,
                          q_chunk=Q_CHUNK if h.shape[1] > Q_CHUNK else None)
    if cfg.post_norms:
        a = _norm(cfg, p["ln1_post"], a)
    return constrain(h + a, "batch", None, None)


def _embed_in(params, cfg: ModelConfig, tokens, extra_embeds):
    from repro.sharding.rules import constrain
    dt = jnp.dtype(cfg.compute_dtype)
    h = cm.embed_lookup(params["embed"], tokens).astype(dt)
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), dt)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(dt), h], axis=1)
    return constrain(h, "batch", None, None)


# ------------------------------------------------------------------- train
def forward_train(params, cfg: ModelConfig, tokens, extra_embeds=None):
    """tokens: (B, S_text) int32; extra_embeds: (B, N, d) prepended (llava).
    Returns (logits: (B, S_total, vocab), aux_loss: scalar)."""
    h = _embed_in(params, cfg, tokens, extra_embeds)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    windows = _group_windows(cfg)

    def group_body(group_params, h):
        aux = jnp.zeros((), jnp.float32)
        for j, w in enumerate(windows):
            p = group_params[j]
            h = _attn_train(cfg, p, h, positions, w)
            h, a = _ffn(cfg, p, h)
            aux = aux + a
        return h, aux

    body = _maybe_remat(cfg, group_body)
    if cfg.scan_layers:
        def scan_fn(h, xs):
            h, aux = body(xs, h)
            return h, aux
        h, auxs = cm.scan(scan_fn, h, params["layers"])
        aux = jnp.sum(auxs)
    else:
        n_groups = jax.tree.leaves(params["layers"])[0].shape[0]
        aux = jnp.zeros((), jnp.float32)
        for i in range(n_groups):
            gp = jax.tree.map(lambda a: a[i], params["layers"])
            h, a = body(gp, h)
            aux = aux + a
    h = _norm(cfg, params["final_norm"], h)
    logits = cm.embed_logits(params["embed"], h, softcap=cfg.final_softcap)
    return logits, aux


# ------------------------------------------------------------------ serving
def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    acfg = _attn_cfg(cfg)
    one = attn.init_cache(acfg, batch, max_len, dtype)
    g = group_size(cfg)
    n_groups = cfg.n_layers // g
    layers = tuple(
        jax.tree.map(lambda a: jnp.zeros((n_groups,) + a.shape, a.dtype), one)
        for _ in range(g))
    return {"layers": layers, "len": jnp.zeros((), jnp.int32)}


def decode_state_specs(cfg: ModelConfig):
    g = group_size(cfg)
    layer = cm.add_layer_axis_to_specs(attn.cache_specs())
    return {"layers": tuple(layer for _ in range(g)), "len": ()}


def prefill(params, cfg: ModelConfig, tokens, max_len: int,
            extra_embeds=None, cache_dtype=jnp.bfloat16):
    """Run the prompt, build the cache. Returns (logits, state)."""
    h = _embed_in(params, cfg, tokens, extra_embeds)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    windows = _group_windows(cfg)
    acfg = _attn_cfg(cfg)

    def group_body(group_params, h):
        kvs = []
        for j, w in enumerate(windows):
            p = group_params[j]
            empty = attn.init_cache(acfg, b, max_len, cache_dtype)
            a, kv = attn.attend_prefill(
                p["attn"], acfg, _norm(cfg, p["ln1"], h), positions, empty,
                window=w, q_chunk=Q_CHUNK if s > Q_CHUNK else None)
            if cfg.post_norms:
                a = _norm(cfg, p["ln1_post"], a)
            h = h + a
            h, _ = _ffn(cfg, p, h)
            kvs.append(kv)
        return h, tuple(kvs)

    body = _maybe_remat(cfg, group_body)
    if cfg.scan_layers:
        h, layer_caches = cm.scan(lambda h, xs: body(xs, h), h,
                                       params["layers"])
    else:
        caches = []
        n_groups = jax.tree.leaves(params["layers"])[0].shape[0]
        for i in range(n_groups):
            gp = jax.tree.map(lambda a: a[i], params["layers"])
            h, kv = body(gp, h)
            caches.append(kv)
        layer_caches = cm.stack_layer_trees(caches)
    h = _norm(cfg, params["final_norm"], h)
    logits = cm.embed_logits(params["embed"], h[:, -1:],
                             softcap=cfg.final_softcap)
    return logits, {"layers": layer_caches,
                    "len": jnp.asarray(s, jnp.int32)}


def decode_step(params, cfg: ModelConfig, token, state):
    """token: (B, 1) int32. Returns (logits (B,1,V), new state)."""
    h = _embed_in(params, cfg, token, None)
    cache_len = state["len"]
    windows = _group_windows(cfg)
    acfg = _attn_cfg(cfg)

    def group_body(h, group_params, group_caches):
        new_kvs = []
        for j, w in enumerate(windows):
            p, kv = group_params[j], group_caches[j]
            x = _norm(cfg, p["ln1"], h)
            a, nkv = attn.attend_decode(p["attn"], acfg, x, kv, cache_len,
                                        window=w)
            if cfg.post_norms:
                a = _norm(cfg, p["ln1_post"], a)
            h = h + a
            h, _ = _ffn(cfg, p, h)
            new_kvs.append(nkv)
        return h, tuple(new_kvs)

    if cfg.scan_layers:
        def scan_fn(h, xs):
            gp, gc = xs
            return group_body(h, gp, gc)
        h, new_caches = cm.scan(
            scan_fn, h, (params["layers"], state["layers"]))
    else:
        outs = []
        n_groups = jax.tree.leaves(params["layers"])[0].shape[0]
        for i in range(n_groups):
            gp = jax.tree.map(lambda a: a[i], params["layers"])
            gc = jax.tree.map(lambda a: a[i], state["layers"])
            h, nkv = group_body(h, gp, gc)
            outs.append(nkv)
        new_caches = cm.stack_layer_trees(outs)
    h = _norm(cfg, params["final_norm"], h)
    logits = cm.embed_logits(params["embed"], h, softcap=cfg.final_softcap)
    return logits, {"layers": new_caches, "len": cache_len + 1}
