"""RWKV6 "Finch" block (arXiv:2404.05892): token-shift time-mix with
data-dependent decay (the paper's headline feature), WKV6 recurrence with
per-channel decay + bonus, grouped output norm, and the squared-ReLU
channel-mix FFN.

Simplifications vs. the reference implementation (noted in DESIGN.md):
static token-shift mix coefficients per projection (r/k/v/g), LoRA only on
the decay path (the data-dependent part that defines RWKV6).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import gla


@dataclasses.dataclass(frozen=True)
class RWKV6Config:
    d_model: int
    head_dim: int = 64
    d_ff: int = 7168
    decay_lora: int = 64
    chunk: int = 64

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


def init(rng, cfg: RWKV6Config, dtype=jnp.float32):
    rr, rk, rv, rg, ro, rw1, rw2, rfk, rfv = cm.split(rng, 9)
    d, nh, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "ln1": cm.layernorm_init(d, dtype),
        "ln2": cm.layernorm_init(d, dtype),
        "att": {
            "mix": 0.5 * jnp.ones((4, d), dtype),        # r,k,v,g shift mixes
            "mix_w": 0.5 * jnp.ones((d,), dtype),        # decay shift mix
            "w_r": cm.dense_init(rr, (d, d), (0,), dtype),
            "w_k": cm.dense_init(rk, (d, d), (0,), dtype),
            "w_v": cm.dense_init(rv, (d, d), (0,), dtype),
            "w_g": cm.dense_init(rg, (d, d), (0,), dtype),
            "w_o": cm.dense_init(ro, (d, d), (0,), dtype),
            # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x A) B))
            "decay_w0": jnp.full((d,), -6.0, dtype),
            "decay_a": cm.dense_init(rw1, (d, cfg.decay_lora), (0,), dtype),
            "decay_b": cm.dense_init(rw2, (cfg.decay_lora, d), (0,), dtype),
            "bonus": jnp.zeros((nh, hd), dtype),          # u
            "ln_out": cm.layernorm_init(d, dtype),        # group-norm per head
        },
        "ffn": {
            "mix": 0.5 * jnp.ones((2, d), dtype),         # k, r mixes
            "w_k": cm.dense_init(rfk, (d, cfg.d_ff), (0,), dtype),
            "w_v": cm.dense_init(rfv, (cfg.d_ff, d), (0,), dtype),
            "w_r": cm.dense_init(rr, (d, d), (0,), dtype),
        },
    }


def specs(cfg: RWKV6Config):
    return {
        "ln1": cm.layernorm_specs(),
        "ln2": cm.layernorm_specs(),
        "att": {
            # Megatron layout: column-parallel r/k/v/g (output dim on the TP
            # axis), row-parallel w_o (one fwd psum per block); input dims
            # replicated ("act_in") — see rules.BASE_RULES
            "mix": (None, "act_in"), "mix_w": ("act_in",),
            "w_r": ("act_in", "heads_embed"),
            "w_k": ("act_in", "heads_embed"),
            "w_v": ("act_in", "heads_embed"),
            "w_g": ("act_in", "heads_embed"),
            "w_o": ("heads_embed", "act_in"),
            "decay_w0": ("act_in",), "decay_a": ("act_in", "lora"),
            "decay_b": ("lora", "act_in"),
            "bonus": ("heads", "head_dim"),
            "ln_out": {"scale": ("heads_embed",), "bias": ("heads_embed",)},
        },
        "ffn": {
            "mix": (None, "act_in"),
            "w_k": ("act_in", "mlp"), "w_v": ("mlp", "act_in"),
            # gate output multiplies the (replicated) psummed kv: replicate
            "w_r": (None, None),
        },
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / `last` for t=0). x: (b, s, d)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _time_mix_inputs(p, cfg: RWKV6Config, x, last=None):
    b, s, d = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    xs = _shift(x, last)
    xr = _mix(x, xs, p["mix"][0])
    xk = _mix(x, xs, p["mix"][1])
    xv = _mix(x, xs, p["mix"][2])
    xg = _mix(x, xs, p["mix"][3])
    xw = _mix(x, xs, p["mix_w"])
    r = jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"].astype(x.dtype))
    g = jnp.einsum("bsd,de->bse", xg, p["w_g"].astype(x.dtype))
    # data-dependent decay (f32): logw in (-inf, 0)
    lora = jnp.einsum("bsl,ld->bsd",
                      jnp.tanh(jnp.einsum("bsd,dl->bsl",
                                          xw.astype(jnp.float32),
                                          p["decay_a"].astype(jnp.float32))),
                      p["decay_b"].astype(jnp.float32))
    logw = -jnp.exp(p["decay_w0"].astype(jnp.float32) + lora)
    from repro.sharding.rules import constrain
    heads = lambda a: constrain(
        a.reshape(b, s, nh, hd).transpose(0, 2, 1, 3),
        "batch", "heads", None, None)
    return heads(r), heads(k), heads(v), g, heads(logw)


def _time_mix_out(p, cfg: RWKV6Config, y, g, x_dtype):
    """Per-head GroupNorm (RWKV's faithful choice) — normalizing within each
    head keeps the op local to the head-sharded TP layout; the earlier
    full-d LayerNorm stand-in forced a cross-shard gather every block (the
    dominant collective in the train_4k baseline, see EXPERIMENTS.md §Perf).
    """
    b, nh, s, hd = y.shape
    yf = y.astype(jnp.float32)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.mean((yf - mu) ** 2, axis=-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + 1e-5)
    scale = p["ln_out"]["scale"].astype(jnp.float32).reshape(nh, 1, hd)
    bias = p["ln_out"]["bias"].astype(jnp.float32).reshape(nh, 1, hd)
    y = (yf * scale + bias).astype(x_dtype)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    y = y * jax.nn.silu(g).astype(x_dtype)
    return jnp.einsum("bsd,de->bse", y, p["w_o"].astype(x_dtype))


def time_mix_train(p, cfg: RWKV6Config, x):
    r, k, v, g, logw = _time_mix_inputs(p, cfg, x)
    y, _ = gla.chunked_gla(r, k, v, logw, u=p["bonus"].astype(jnp.float32),
                           chunk=cfg.chunk, mode="bonus")
    return _time_mix_out(p, cfg, y, g, x.dtype)


def channel_mix_train(p, x, last=None):
    xs = _shift(x, last)
    xk = _mix(x, xs, p["mix"][0])
    xr = _mix(x, xs, p["mix"][1])
    k = jnp.einsum("bsd,df->bsf", xk, p["w_k"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_v"].astype(x.dtype))
    r = jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(x.dtype))
    return jax.nn.sigmoid(r) * kv


def init_state(cfg: RWKV6Config, batch, dtype=jnp.float32):
    d, nh, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "att_x": jnp.zeros((batch, 1, d), dtype),
        "ffn_x": jnp.zeros((batch, 1, d), dtype),
        "wkv": jnp.zeros((batch, nh, hd, hd), jnp.float32),
    }


def state_specs():
    return {"att_x": ("batch", None, "embed"),
            "ffn_x": ("batch", None, "embed"),
            "wkv": ("batch", "heads", None, None)}


def block_decode(p, cfg: RWKV6Config, x, state):
    """One token through time-mix + channel-mix (pre-LN). x: (b, 1, d)."""
    xa = cm.layernorm(p["ln1"], x)
    r, k, v, g, logw = _time_mix_inputs(p["att"], cfg, xa, state["att_x"])
    y, wkv = gla.gla_decode_step(r[:, :, 0], k[:, :, 0], v[:, :, 0],
                                 logw[:, :, 0], state["wkv"],
                                 u=p["att"]["bonus"].astype(jnp.float32),
                                 mode="bonus")
    att = _time_mix_out(p["att"], cfg, y[:, :, None, :], g, x.dtype)
    h = x + att
    hf = cm.layernorm(p["ln2"], h)
    ffn = channel_mix_train(p["ffn"], hf, state["ffn_x"])
    out = h + ffn
    return out, {"att_x": xa, "ffn_x": hf, "wkv": wkv}


def block_train(p, cfg: RWKV6Config, x):
    h = x + time_mix_train(p["att"], cfg, cm.layernorm(p["ln1"], x))
    return h + channel_mix_train(p["ffn"], cm.layernorm(p["ln2"], h))


def block_prefill(p, cfg: RWKV6Config, x, state):
    """Full-sequence forward returning the carried decode state (wkv final
    state via chunked_gla + last-token shift inputs)."""
    xa = cm.layernorm(p["ln1"], x)
    r, k, v, g, logw = _time_mix_inputs(p["att"], cfg, xa, state["att_x"])
    y, wkv = gla.chunked_gla(r, k, v, logw,
                             u=p["att"]["bonus"].astype(jnp.float32),
                             initial_state=state["wkv"],
                             chunk=cfg.chunk, mode="bonus")
    h = x + _time_mix_out(p["att"], cfg, y, g, x.dtype)
    hf = cm.layernorm(p["ln2"], h)
    out = h + channel_mix_train(p["ffn"], hf, state["ffn_x"])
    return out, {"att_x": xa[:, -1:], "ffn_x": hf[:, -1:], "wkv": wkv}
