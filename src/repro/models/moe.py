"""Mixture-of-Experts FFN: top-k softmax router + GROUPED capacity-bounded
dispatch (GShard, arXiv:2006.16668).

Tokens are reshaped into groups of ``group_size``; each group scatters into
per-expert capacity buffers via one-hot einsums (MXU-friendly — the TPU
idiom for MoE dispatch) and expert FFNs run vmapped over the expert axis,
sharded over the mesh "model"/EP axis (16 experts ↔ the 16-way model axis of
the production mesh). Results are combined with router weights; capacity-
dropped tokens fall through via the residual stream.

Grouping bounds both the dispatch-tensor footprint (G·S·E·C) and its einsum
FLOPs (2·T·S·k·cf·d — linear in group size), unlike a single global group
whose capacity makes dispatch quadratic in batch (measured: 3.5 TB/device
peak on dbrx-132b before grouping; 84 MB/device after).

An aux load-balancing loss (Switch §2.2, computed per group then averaged)
is returned alongside.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common as cm


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                 # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    activation: str = "silu"
    group_size: int = 256     # tokens per dispatch group


def init(rng, cfg: MoEConfig, dtype=jnp.float32):
    rr, rg, ru, rd = cm.split(rng, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": cm.dense_init(rr, (d, e), (0,), dtype),
        "w_gate": cm.dense_init(rg, (e, d, f), (1,), dtype),
        "w_up": cm.dense_init(ru, (e, d, f), (1,), dtype),
        "w_down": cm.dense_init(rd, (e, f, d), (1,), dtype),
    }


def specs(cfg: MoEConfig):
    return {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "mlp"),
        "w_up": ("experts", "embed", "mlp"),
        "w_down": ("experts", "mlp", "embed"),
    }


def group_capacity(cfg: MoEConfig, group: int) -> int:
    cap = int(cfg.capacity_factor * group * cfg.top_k / cfg.n_experts)
    return max(8, -(-cap // 8) * 8)  # pad to sublane multiple


def apply(params, cfg: MoEConfig, x):
    """x: (b, s, d) -> (out, aux_loss). Routing in f32 for stability."""
    b, s, d = x.shape
    # groups tile each row IN ORDER and never straddle batch rows.  Buffer
    # slots come from a positional cumsum, so a token's slot depends only on
    # tokens BEFORE it in its own group: row-local groups make capacity
    # dropping a per-row prefix property — prefill over s-1 tokens drops
    # exactly the tokens train drops in its first s-1 positions, instead of
    # batch-row i's drops shifting with row i-1's length (the old flat
    # (b·s) grouping broke prefill/train consistency whenever an expert ran
    # near capacity).
    sg = min(cfg.group_size, s)
    assert s % sg == 0, (s, sg)
    g = b * (s // sg)
    cap = group_capacity(cfg, sg)
    from repro.sharding.rules import constrain
    xt = constrain(x.reshape(g, sg, d), "batch", None, None)

    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (g,s,e)
    # deterministic near-tie break: SELECT on a coarse quantization of the
    # probabilities, then gather the EXACT probabilities for the gates.
    # Routing is a discrete decision riding on continuous inputs: prefill
    # and decode reach this point through different kernel schedules whose
    # bf16 rounding can differ by ~1e-2 under global x64 — enough to swap
    # two near-tied experts between the paths.  Quantizing to 1/16
    # collapses near-ties into exact ties, and ``lax.top_k`` breaks exact
    # ties to the lower expert index identically on every path.
    qsel = jnp.floor(probs * 16.0)
    _, gate_idx = jax.lax.top_k(qsel, cfg.top_k)              # (g,s,k)
    gate_vals = jnp.take_along_axis(probs, gate_idx, axis=-1)
    # renormalize the selected gates (dbrx/mixtral convention)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # position of each (token, k) inside its expert's per-group buffer
    onehot = jax.nn.one_hot(gate_idx, cfg.n_experts,
                            dtype=jnp.int32)                  # (g,s,k,e)
    flat = onehot.reshape(g, sg * cfg.top_k, cfg.n_experts)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(
        g, sg, cfg.top_k, cfg.n_experts)
    pos = jnp.sum(pos * onehot, axis=-1)                      # (g,s,k)
    keep = pos < cap
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # (g,s,e,c): a token occupies at most one (e,c) slot per k; sum over k
    disp = jnp.einsum(
        "gske,gskc->gsec",
        (onehot * keep[..., None]).astype(x.dtype),
        jax.nn.one_hot(pos, cap, dtype=x.dtype))
    expert_in = constrain(
        jnp.einsum("gsec,gsd->egcd", disp, xt),               # (e,g,c,d)
        "experts", "batch", None, None)

    # expert FFN, vmapped over the (sharded) expert axis
    def ffn(wg, wu, wd, h):
        gate = jnp.einsum("gcd,df->gcf", h, wg.astype(h.dtype))
        up = jnp.einsum("gcd,df->gcf", h, wu.astype(h.dtype))
        a = cm.swiglu(gate, up) if cfg.activation == "silu" \
            else cm.geglu(gate, up)
        return jnp.einsum("gcf,fd->gcd", a, wd.astype(h.dtype))

    expert_out = jax.vmap(ffn)(params["w_gate"], params["w_up"],
                               params["w_down"], expert_in)   # (e,g,c,d)

    combine = jnp.einsum("gsec,gse->gsec", disp,
                         jnp.einsum("gske,gsk->gse",
                                    onehot.astype(gate_vals.dtype),
                                    gate_vals).astype(x.dtype))
    out = jnp.einsum("gsec,egcd->gsd", combine, expert_out)
    out = out.reshape(b, s, d)

    # Switch aux loss: e * Σ_e (frac tokens to e) * (mean router prob e)
    frac = jnp.mean(jnp.sum(onehot.astype(jnp.float32), axis=2),
                    axis=(0, 1))
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = cfg.n_experts * jnp.sum(frac / cfg.top_k * pmean)
    return out, aux
