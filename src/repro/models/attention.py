"""Grouped-query attention with RoPE, optional QKV bias, logit softcap,
sliding-window masking, and a KV cache for decode.

Covers: llama-family (internlm2/yi/mistral-llava), qwen1.5 (QKV bias),
gemma2 (softcap + local/global alternation), dbrx/phi3.5 (GQA MoE backbones),
zamba2's shared attention and whisper's self/cross attention (is_causal &
cross-KV options).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import common as cm

NEG_INF = -2.3819763e38  # large negative, bf16-safe (matches gemma impls)


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    use_bias: bool = False          # qwen1.5-style QKV bias
    logit_softcap: float | None = None   # gemma2: 50.0
    query_scale: float | None = None     # default 1/sqrt(head_dim)
    use_rope: bool = True                # whisper uses absolute pos instead
    # context parallelism: shard the QUERY sequence over the TP axis inside
    # attention (K/V replicated). The right call when n_heads doesn't divide
    # the TP axis (qwen1.5's 20 heads on TP=16): heads can't shard, so
    # without this every device computes all heads' S×S probs.
    seq_shard: bool = False


def init(rng, cfg: AttnConfig, dtype=jnp.float32):
    rq, rk, rv, ro = cm.split(rng, 4)
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": cm.dense_init(rq, (d, h, hd), (0,), dtype),
        "wk": cm.dense_init(rk, (d, kh, hd), (0,), dtype),
        "wv": cm.dense_init(rv, (d, kh, hd), (0,), dtype),
        "wo": cm.dense_init(ro, (h, hd, d), (0, 1), dtype),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kh, hd), dtype)
        p["bv"] = jnp.zeros((kh, hd), dtype)
    return p


def specs(cfg: AttnConfig):
    s = {
        "wq": ("embed", "q_heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("q_heads", "head_dim", "embed"),
    }
    if cfg.use_bias:
        s["bq"] = ("q_heads", "head_dim")
        s["bk"] = ("kv_heads", "head_dim")
        s["bv"] = ("kv_heads", "head_dim")
    return s


def _qkv(params, cfg: AttnConfig, x, positions):
    from repro.sharding.rules import constrain
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.use_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if cfg.use_rope:
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
    # pin the layout: batch over DP axes, heads over TP where divisible;
    # seq_shard puts the query SEQUENCE on the TP axis instead
    if cfg.seq_shard:
        q = constrain(q, "batch", "q_seq", None, None,
                      overrides={"q_seq": "model"})
    else:
        q = constrain(q, "batch", None, "q_heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


def _sdpa(cfg: AttnConfig, q, k, v, mask):
    """q: (b, sq, h, hd); k/v: (b, skv, kh, hd); mask: (b, 1, sq, skv) bool."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    group = h // kh
    scale = cfg.query_scale or (1.0 / math.sqrt(cfg.head_dim))
    qg = q.reshape(b, sq, kh, group, hd)
    # f32 accumulation INSIDE the dot: converting afterwards makes XLA
    # materialize f32 copies of K (measured: a full f32 KV cache temp on
    # decode cells)
    logits = jnp.einsum("bqhgk,bshk->bhgqs", qg * scale, k,
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = cm.softcap(logits, cfg.logit_softcap)
    # mask: (b|1, 1, sq, skv) -> broadcast over (kh, group)
    logits = jnp.where(mask[:, :, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs, v)
    return out.reshape(b, sq, h, hd)


def _sdpa_chunked(cfg: AttnConfig, q, k, v, *, window: int | None,
                  q_chunk: int, offset: int = 0, causal: bool = True):
    """Query-chunked attention (flash-style memory profile in pure jnp):
    peak logits buffer is (b, kh, g, q_chunk, skv) instead of O(sq·skv).
    Each chunk sees the full K/V with its own causal/window mask slice.
    The chunk body is rematerialized — otherwise the scan stashes every
    chunk's probs for backward (measured 343 GB on qwen prefill_32k)."""
    b, sq, h, hd = q.shape
    assert sq % q_chunk == 0, (sq, q_chunk)
    nq = sq // q_chunk

    @jax.checkpoint
    def chunk(qi, i):
        if causal:
            off = offset + i * q_chunk
            mask = causal_mask(q_chunk, k.shape[1], window=window, offset=off)
        else:
            mask = jnp.ones((1, 1, q_chunk, k.shape[1]), bool)
        return _sdpa(cfg, qi, k, v, mask)

    qs = q.reshape(b, nq, q_chunk, h, hd)

    def body(carry, inp):
        qi, i = inp
        return carry, chunk(qi, i)

    _, out = cm.scan(
        body, None, (jnp.moveaxis(qs, 1, 0), jnp.arange(nq)))
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, hd)


def causal_mask(sq, skv, *, window: int | None = None, offset: int = 0):
    """(1, 1, sq, skv) bool. offset = absolute position of query 0 minus key 0
    (for decode: offset = cache_len). window = sliding-window size (gemma2
    local layers): key position must be within [qpos - window + 1, qpos]."""
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m[None, None]


def attend_train(params, cfg: AttnConfig, x, positions, *,
                 window: int | None = None, q_chunk: int | None = None):
    q, k, v = _qkv(params, cfg, x, positions)
    sq = x.shape[1]
    if q_chunk and sq > q_chunk:
        out = _sdpa_chunked(cfg, q, k, v, window=window, q_chunk=q_chunk)
    else:
        mask = causal_mask(sq, sq, window=window)
        out = _sdpa(cfg, q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


# ------------------------------------------------------------------ KV cache
def init_cache(cfg: AttnConfig, batch, max_len, dtype=jnp.bfloat16):
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_specs():
    return {"k": ("batch", "kv_seq", "kv_heads", "head_dim"),
            "v": ("batch", "kv_seq", "kv_heads", "head_dim")}


def attend_prefill(params, cfg: AttnConfig, x, positions, cache, *,
                   window: int | None = None, q_chunk: int | None = None):
    """Prefill seq into an (empty) cache; returns (out, cache)."""
    q, k, v = _qkv(params, cfg, x, positions)
    sq = x.shape[1]
    cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=1),
    }
    if q_chunk and sq > q_chunk:
        out = _sdpa_chunked(cfg, q, k, v, window=window, q_chunk=q_chunk)
    else:
        mask = causal_mask(sq, sq, window=window)
        out = _sdpa(cfg, q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out,
                      params["wo"].astype(x.dtype)), cache


def attend_decode(params, cfg: AttnConfig, x, cache, cache_len, *,
                  window: int | None = None):
    """One-token decode. x: (b, 1, d); cache_len: scalar int32 (tokens already
    in cache). Returns (out, cache). Attention runs over the whole cache
    buffer with positions >= cache_len masked out — this keeps shapes static
    (XLA/pjit-friendly) and lets the kv_seq axis shard over the mesh for
    long-context decode (partial-softmax combine emerges as psum)."""
    positions = jnp.full((x.shape[0], 1), cache_len, jnp.int32)
    q, k, v = _qkv(params, cfg, x, positions)
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), cache_len, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), cache_len, axis=1)
    skv = ck.shape[1]
    kpos = jnp.arange(skv)[None, :]
    valid = kpos <= cache_len
    if window is not None:
        valid &= kpos > cache_len - window
    mask = valid[:, None, None, :][:, :, :, :]       # (1,1,1,skv)
    mask = jnp.broadcast_to(mask, (x.shape[0], 1, 1, skv))
    out = _sdpa(cfg, q, ck.astype(q.dtype), cv.astype(q.dtype), mask)
    return (jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype)),
            {"k": ck, "v": cv})


# -------------------------------------------------------- cross attention
def cross_init(rng, cfg: AttnConfig, dtype=jnp.float32):
    return init(rng, cfg, dtype)


def attend_cross(params, cfg: AttnConfig, x, kv_feats, kv_mask=None):
    """Whisper decoder cross-attention. kv_feats: (b, s_enc, d)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_feats, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_feats, params["wv"].astype(x.dtype))
    if cfg.use_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    sq, skv = x.shape[1], kv_feats.shape[1]
    if kv_mask is None:
        mask = jnp.ones((x.shape[0], 1, sq, skv), bool)
    else:
        mask = jnp.broadcast_to(kv_mask[:, None, None, :],
                                (x.shape[0], 1, sq, skv))
    out = _sdpa(cfg, q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
