"""Packing-aware ``block_n`` autotune for the packed moments kernel.

The packed kernel's only free parameter is the tile width ``block_n``: too
small and the per-block overhead (DMA issue, accumulator add) dominates;
too large and the multi-buffered ring blows the ~16 MB VMEM budget or
starves the pipeline of overlap. The best value depends on the packing
factor P = ⌊128/(degree+2)⌋ (the ring holds 3·nbuf·P·block_n elements), the
input dtype, and the backend — so ``autotune_block_n`` runs a ONE-SHOT
timed sweep over the VMEM-feasible candidates and caches the winner per
``(degree, dtype, backend)`` for the life of the process.

The sweep costs a few kernel launches once per key; every later call is a
dict hit. ``clear_cache()`` resets it (tests).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import moments as kernel

# candidate tile widths, lane-aligned; clamped by the VMEM model below
CANDIDATE_BLOCKS = (1024, 2048, 4096, 8192)
VMEM_BUDGET = 8 << 20          # stay in half the ~16 MB/core VMEM

_CACHE: dict[tuple, int] = {}


def ring_vmem_bytes(degree: int, block_n: int, *, nbuf: int = 2,
                    itemsize: int = 4, compensated: bool = False) -> int:
    """VMEM the multi-buffered packed kernel needs at this tile width:
    the 3-array input ring, the in-register W / weighted-W tiles, and the
    (1|2) accumulator tiles."""
    p = kernel.packing_factor(degree)
    ring = 3 * nbuf * p * block_n * itemsize
    wmat = 2 * kernel.K_PAD * block_n * 4          # accum dtype f32
    acc = (2 if compensated else 1) * kernel.K_PAD * kernel.K_PAD * 4
    return ring + wmat + acc


def feasible_blocks(degree: int, *, nbuf: int = 2, itemsize: int = 4,
                    budget: int = VMEM_BUDGET) -> tuple[int, ...]:
    out = tuple(b for b in CANDIDATE_BLOCKS
                if ring_vmem_bytes(degree, b, nbuf=nbuf,
                                   itemsize=itemsize) <= budget)
    return out or CANDIDATE_BLOCKS[:1]


def autotune_block_n(degree: int, n: int | None = None, *,
                     dtype=jnp.float32, nbuf: int = 2,
                     backend: str | None = None, reps: int = 2,
                     timer=time.perf_counter,
                     force: bool = False) -> int:
    """Pick ``block_n`` for the packed kernel from a one-shot timed sweep.

    ``n`` only bounds the sweep's synthetic series length (defaults to
    4 blocks of the largest candidate); the winner is cached per
    ``(degree, dtype.name, backend)`` — NOT per n, since any block width
    serves any length (ops.py pads the tail with weight 0).
    """
    bk = backend or jax.default_backend()
    key = (degree, jnp.dtype(dtype).name, bk)
    if not force and key in _CACHE:
        return _CACHE[key]

    cands = feasible_blocks(degree, nbuf=nbuf,
                            itemsize=jnp.dtype(dtype).itemsize)
    p = kernel.packing_factor(degree)
    interpret = bk != "tpu"
    n_sweep = max(c * 2 for c in cands) if n is None else n
    best_b, best_t = cands[0], float("inf")
    for bn in cands:
        n_pad = -(-n_sweep // bn) * bn
        x = jnp.linspace(-1.0, 1.0, n_pad, dtype=dtype)
        x = jnp.broadcast_to(x, (1, p, n_pad))
        try:
            fn = lambda: kernel.moments_packed_extended(   # noqa: E731
                x, x, jnp.ones_like(x), degree=degree, block_n=bn,
                nbuf=nbuf, interpret=interpret)
            jax.block_until_ready(fn())                    # compile + warm
            t = float("inf")
            for _ in range(reps):
                t0 = timer()
                out = fn()
                jax.block_until_ready(out)
                t = min(t, timer() - t0)
        except Exception:  # noqa: BLE001 — infeasible candidate on this host
            continue
        if t < best_t:
            best_b, best_t = bn, t
    _CACHE[key] = best_b
    return best_b


def clear_cache() -> None:
    _CACHE.clear()
