"""Pallas TPU kernels: blocked Vandermonde-Gram moments + fused fit report.

TPU-native adaptation of the paper's CUDA moment kernel (DESIGN.md §2):

* The paper's per-thread partial power sums become a *single MXU matmul* per
  data tile. With W = [V | y] (rows = powers of x, then y), the product
  G = (W ⊙ w) Wᵀ simultaneously yields the Hankel/Gram matrix, the moment
  vector Vᵀy, Σwy² and Σw — every sufficient statistic of the fit.
* Grid streams (batch, n-block) tiles HBM→VMEM; the (128, 128) accumulator
  tile stays VMEM-resident across the n-block grid dimension (constant
  index_map), mirroring the shared-memory block reduction on GPU.
* Power rows are built by iterated multiply (no transcendental `pow`),
  matching the paper's "matricized" construction.

Three kernels live here:

``moments_extended``          one series per (128, block_n) tile (the
                              original layout; rows degree+2..127 are zero).
``moments_packed_extended``   P = 128 // (degree+2) series per tile — the
                              packed layout below.
``fused_report_sums``         one streamed pass computing everything
                              ``core.fit.fit_report`` needs (SSE, R) without
                              materializing fitted/residual arrays in HBM.

Packed layout (the perf-critical path for batched fits)
-------------------------------------------------------
The MXU always multiplies full (128, block_n) × (block_n, 128) tiles, so
with one series per tile a degree-3 fit (K = degree+2 = 5 live rows) wastes
123/128 ≈ 96% of every matmul on zeros. Packing P = 128 // K independent
series into the sublane dimension turns that padding into useful work:

      sublane 0   ┌ 1  1  1 … ┐   series 0, power 0
              1   │ x₀ row    │   series 0, power 1..m
              …   │ …         │
              K-1 │ y₀ row    │   series 0, response
              K   │ 1  1  1 … │   series 1, power 0
              …   │ …         │   …
          P·K-1   │ y_{P-1}   │   series P-1, response
          P·K..127└ 0 zeros   ┘   remainder rows (128 mod K)

G = (W ⊙ w) Wᵀ then contains each series' (K × K) extended Gram as the
p-th diagonal block G[pK:(p+1)K, pK:(p+1)K]; off-diagonal blocks are
cross-series products we simply never read. Per *fit* the MXU work drops
from 2·128²·n to 2·128²·n/P FLOPs — 25× at degree 3, 14× at degree 7,
9× at degree 12. Tail series (batch not divisible by P) ride in with
weight 0, so they contribute exact zeros and are sliced away by ops.py.

VMEM footprint of the packed tile (f32 accumulate, block_n = 4096):
  x/y/w input tiles   3 · P·block_n · 4 B   ≈ 1.2 MB  (P = 25)
  W and (W ⊙ w)       2 · 128·block_n · 4 B ≈ 4.2 MB
  G accumulator       128² · 4 B            ≈ 65 KB   (×2 if compensated)
  total ≈ 5.5 MB — comfortably inside the ~16 MB/core budget; halve
  block_n for the compensated path if other buffers share the core.

Path selection (see ``ops.moments``): packed when the batch has ≥ 2 series
and P ≥ 2 (i.e. degree ≤ 62); plain for single series or huge degrees; the
pure-jnp ``core.gram_moments`` remains the non-kernel reference path.

Compensated accumulation
------------------------
Skala (arXiv:1802.07591) shows naive monomial power sums lose precision at
exactly the large-n scale the paper targets. ``compensated=True`` keeps a
second VMEM-resident tile carrying a Kahan running-error term: each block's
contribution is corrected by the error of the previous addition, making the
cross-block reduction error O(1) in the number of blocks instead of O(nblk).
Costs one extra (128, 128) tile and 3 extra VPU adds per block — invisible
next to the MXU matmul.

Double buffering (``nbuf >= 2``)
--------------------------------
The grid-streamed form above leaves the HBM→VMEM pipelining entirely to the
Mosaic pipeliner. ``moments_packed_extended(..., nbuf=2)`` instead runs ONE
grid step per group and drives the n-block loop in-kernel over an explicit
``nbuf``-slot VMEM scratch ring: the DMA for block k+1 is started *before*
the matmul on block k, so the MXU never waits on HBM as long as one block's
compute covers one block's transfer (true for every block_n ≥ 1024 at the
moment pass's arithmetic intensity). Inputs stay in ``ANY`` (HBM) memory
space; per-slot DMA semaphores sequence the ring. The per-block update and
accumulation order are IDENTICAL to the grid-streamed kernel (shared
``_packed_tile_update``), so the two paths are bit-equal by construction —
asserted in tests. Pick ``block_n`` with ``repro.kernels.tune``
(one-shot sweep cached per (degree, dtype, backend)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

K_PAD = 128          # fixed row count: degree + 2 <= 128
DEFAULT_BLOCK_N = 4096

# index layout of the fused-report sums vector (lane j of the (B, 128) out)
SUM_W, SUM_Y, SUM_YY, SUM_F, SUM_FF, SUM_YF, SUM_SSE, N_SUMS = range(8)


def packing_factor(degree: int) -> int:
    """How many independent series fit in one 128-sublane tile."""
    return K_PAD // (degree + 2)


def _accum_init(i, out_refs):
    """Zero all VMEM accumulator tiles on the first n-block."""
    @pl.when(i == 0)
    def _init():
        for ref in out_refs:
            ref[...] = jnp.zeros_like(ref)


def _accum_add(update, g_ref, c_ref):
    """g += update, optionally Kahan-compensated via the c_ref error tile."""
    if c_ref is None:
        g_ref[...] += update
    else:
        y = update - c_ref[...]
        t = g_ref[...] + y
        c_ref[...] = (t - g_ref[...]) - y
        g_ref[...] = t


def _power_rows(x, y, degree):
    """[x^0, ..., x^degree, y] stacked on a new leading axis."""
    rows = [jnp.ones_like(x)]
    for _ in range(degree):
        rows.append(rows[-1] * x)
    rows.append(y)
    return jnp.stack(rows, axis=0)


def _moments_kernel(x_ref, y_ref, w_ref, g_ref, *maybe_c, degree: int,
                    accum_dtype):
    """One (batch, block) grid step: G[b] += (W·w) Wᵀ for this tile."""
    c_ref = maybe_c[0] if maybe_c else None
    i = pl.program_id(1)
    _accum_init(i, (g_ref,) + ((c_ref,) if c_ref is not None else ()))

    x = x_ref[...].astype(accum_dtype)   # (1, block_n)
    y = y_ref[...].astype(accum_dtype)   # (1, block_n)
    w = w_ref[...].astype(accum_dtype)   # (1, block_n)

    # Build W rows by the iterated-multiply power ladder (paper's trick).
    wmat = _power_rows(x[0], y[0], degree)                   # (deg+2, bn)
    pad = K_PAD - (degree + 2)
    if pad:
        wmat = jnp.concatenate(
            [wmat, jnp.zeros((pad, wmat.shape[1]), accum_dtype)], axis=0)

    lhs = wmat * w                                           # weight one side
    # MXU: (128, bn) @ (bn, 128), f32 accumulation.
    update = jax.lax.dot_general(
        lhs, wmat, (((1,), (1,)), ((), ())),
        preferred_element_type=accum_dtype)[None]
    _accum_add(update, g_ref, c_ref)


def _packed_tile_update(x, y, w, degree: int, accum_dtype):
    """The packed layout's (1, 128, 128) Gram contribution of one
    (P, block_n) tile — the ONE definition both the grid-streamed and the
    double-buffered kernels accumulate, so their results agree bitwise."""
    x = x.astype(accum_dtype)
    y = y.astype(accum_dtype)
    w = w.astype(accum_dtype)
    p, bn = x.shape
    k = degree + 2

    # (K, P, bn) power rows -> interleave to series-major (P*K, bn) so each
    # series owns a contiguous sublane block (diagonal extraction below).
    rows = _power_rows(x, y, degree)
    wmat = jnp.swapaxes(rows, 0, 1).reshape(p * k, bn)
    wfull = jnp.repeat(w, k, axis=0)                         # row p*K+j <- w[p]
    pad = K_PAD - p * k
    if pad:
        zpad = jnp.zeros((pad, bn), accum_dtype)
        wmat = jnp.concatenate([wmat, zpad], axis=0)
        wfull = jnp.concatenate([wfull, zpad], axis=0)

    return jax.lax.dot_general(
        wmat * wfull, wmat, (((1,), (1,)), ((), ())),
        preferred_element_type=accum_dtype)[None]


def _packed_moments_kernel(x_ref, y_ref, w_ref, g_ref, *maybe_c, degree: int,
                           accum_dtype):
    """One (group, block) grid step with P series packed into the sublanes."""
    c_ref = maybe_c[0] if maybe_c else None
    i = pl.program_id(1)
    _accum_init(i, (g_ref,) + ((c_ref,) if c_ref is not None else ()))

    update = _packed_tile_update(x_ref[0], y_ref[0], w_ref[0], degree,
                                 accum_dtype)
    _accum_add(update, g_ref, c_ref)


def _packed_moments_db_kernel(x_hbm, y_hbm, w_hbm, g_ref, *maybe_c,
                              degree: int, accum_dtype, block_n: int,
                              n_blocks: int, nbuf: int, p: int):
    """One grid step per GROUP; the n-block loop runs in-kernel over an
    ``nbuf``-slot VMEM ring with explicit async copies: block k+1's three
    DMAs are in flight while block k's matmul runs on the MXU."""
    c_ref = maybe_c[0] if maybe_c else None
    gi = pl.program_id(0)
    in_dtype = x_hbm.dtype

    def body(xs, ys, ws, sem):
        g_ref[...] = jnp.zeros_like(g_ref)
        if c_ref is not None:
            c_ref[...] = jnp.zeros_like(c_ref)

        def dmas(slot, i):
            sl = pl.ds(i * block_n, block_n)
            return (pltpu.make_async_copy(x_hbm.at[gi, :, sl], xs.at[slot],
                                          sem.at[slot, 0]),
                    pltpu.make_async_copy(y_hbm.at[gi, :, sl], ys.at[slot],
                                          sem.at[slot, 1]),
                    pltpu.make_async_copy(w_hbm.at[gi, :, sl], ws.at[slot],
                                          sem.at[slot, 2]))

        for d in dmas(0, 0):                       # warm the pipeline
            d.start()

        def step(i, _):
            slot = jax.lax.rem(i, nbuf)
            nxt = jax.lax.rem(i + 1, nbuf)

            @pl.when(i + 1 < n_blocks)
            def _prefetch():                       # block k+1 in flight...
                for d in dmas(nxt, i + 1):
                    d.start()

            for d in dmas(slot, i):                # ...while block k lands
                d.wait()
            update = _packed_tile_update(xs[slot], ys[slot], ws[slot],
                                         degree, accum_dtype)
            _accum_add(update, g_ref, c_ref)
            return 0

        jax.lax.fori_loop(0, n_blocks, step, 0)

    pl.run_scoped(
        body,
        xs=pltpu.VMEM((nbuf, p, block_n), in_dtype),
        ys=pltpu.VMEM((nbuf, p, block_n), in_dtype),
        ws=pltpu.VMEM((nbuf, p, block_n), in_dtype),
        sem=pltpu.SemaphoreType.DMA((nbuf, 3)),
    )


def _fused_report_kernel(x_ref, y_ref, w_ref, coef_ref, o_ref, *, degree: int,
                         accum_dtype):
    """Evaluate + residual + SSE/R sums in one pass; no HBM intermediates."""
    i = pl.program_id(1)
    _accum_init(i, (o_ref,))

    x = x_ref[...].astype(accum_dtype)       # (1, block_n)
    y = y_ref[...].astype(accum_dtype)
    w = w_ref[...].astype(accum_dtype)
    c = coef_ref[...].astype(accum_dtype)    # (1, 128): coeffs then zero pad

    # Horner evaluation — same O(m) ladder as basis.evaluate, in-register.
    f = jnp.full_like(x, c[0, degree])
    for k in range(degree - 1, -1, -1):
        f = f * x + c[0, k]
    e = y - f

    sums = (jnp.sum(w), jnp.sum(w * y), jnp.sum(w * y * y),
            jnp.sum(w * f), jnp.sum(w * f * f), jnp.sum(w * y * f),
            jnp.sum(w * e * e))
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, K_PAD), 1)
    update = jnp.zeros((1, K_PAD), accum_dtype)
    for j, s in enumerate(sums):
        update = update + jnp.where(lane == j, s, jnp.zeros((), accum_dtype))
    o_ref[...] += update


def _moments_call(kernel_fn, grid, in_specs, out_spec, b_out, *,
                  compensated, accum_dtype, interpret, args):
    """Shared pallas_call plumbing for the plain/packed moment kernels."""
    struct = jax.ShapeDtypeStruct((b_out, K_PAD, K_PAD), accum_dtype)
    if compensated:
        out = pl.pallas_call(
            kernel_fn, grid=grid, in_specs=in_specs,
            out_specs=[out_spec, out_spec], out_shape=[struct, struct],
            interpret=interpret)(*args)
        return out[0]   # Kahan: the corrected sum is the primary tile
    return pl.pallas_call(
        kernel_fn, grid=grid, in_specs=in_specs,
        out_specs=out_spec, out_shape=struct, interpret=interpret)(*args)


@functools.partial(jax.jit,
                   static_argnames=("degree", "block_n", "interpret",
                                    "accum_dtype", "compensated"))
def moments_extended(x: jax.Array, y: jax.Array, weights: jax.Array, *,
                     degree: int, block_n: int = DEFAULT_BLOCK_N,
                     accum_dtype=jnp.float32,
                     compensated: bool = False,
                     interpret: bool = False) -> jax.Array:
    """Raw kernel output: (B, K_PAD, K_PAD) extended Gram per batch row.

    x, y, weights: (B, n) with n % block_n == 0 (ops.py handles padding —
    padded tail carries weight 0 so it contributes nothing).
    """
    if x.ndim != 2:
        raise ValueError("moments_extended expects (B, n) inputs")
    b, n = x.shape
    if n % block_n:
        raise ValueError(f"n={n} must be a multiple of block_n={block_n}")
    if degree + 2 > K_PAD:
        raise ValueError(f"degree {degree} too large for K_PAD={K_PAD}")

    kernel_fn = functools.partial(_moments_kernel, degree=degree,
                                  accum_dtype=accum_dtype)
    in_spec = pl.BlockSpec((1, block_n), lambda bi, ni: (bi, ni))
    out_spec = pl.BlockSpec((1, K_PAD, K_PAD), lambda bi, ni: (bi, 0, 0))
    return _moments_call(kernel_fn, (b, n // block_n), [in_spec] * 3,
                         out_spec, b, compensated=compensated,
                         accum_dtype=accum_dtype, interpret=interpret,
                         args=(x, y, weights))


@functools.partial(jax.jit,
                   static_argnames=("degree", "block_n", "interpret",
                                    "accum_dtype", "compensated", "nbuf"))
def moments_packed_extended(x: jax.Array, y: jax.Array, weights: jax.Array, *,
                            degree: int, block_n: int = DEFAULT_BLOCK_N,
                            accum_dtype=jnp.float32,
                            compensated: bool = False,
                            nbuf: int = 0,
                            interpret: bool = False) -> jax.Array:
    """Packed kernel output: (G, K_PAD, K_PAD); series p of group g lives in
    the diagonal block ``out[g, p*K:(p+1)*K, p*K:(p+1)*K]`` (K = degree+2).

    x, y, weights: (G, P, n) with P == packing_factor(degree) and
    n % block_n == 0. Use ``extract_packed`` to pull per-series blocks.

    ``nbuf >= 2`` selects the explicit multi-buffered DMA pipeline (see
    module docstring §Double buffering): same per-block math and
    accumulation order, prefetch of block k+1 overlapped with block k's
    matmul. ``nbuf=0`` (default) is the grid-streamed form.
    """
    if x.ndim != 3:
        raise ValueError("moments_packed_extended expects (G, P, n) inputs")
    g, p, n = x.shape
    if p != packing_factor(degree):
        raise ValueError(f"P={p} != packing_factor({degree})="
                         f"{packing_factor(degree)}")
    if n % block_n:
        raise ValueError(f"n={n} must be a multiple of block_n={block_n}")
    if nbuf == 1 or nbuf < 0:
        raise ValueError(f"nbuf={nbuf}: 0 (grid-streamed) or >= 2 "
                         "(multi-buffered ring)")

    if nbuf >= 2:
        n_blocks = n // block_n
        kernel_fn = functools.partial(
            _packed_moments_db_kernel, degree=degree,
            accum_dtype=accum_dtype, block_n=block_n,
            n_blocks=n_blocks, nbuf=min(nbuf, n_blocks) if n_blocks > 1
            else 2, p=p)
        in_specs = [pl.BlockSpec(memory_space=pltpu.ANY)] * 3
        out_spec = pl.BlockSpec((1, K_PAD, K_PAD), lambda gi: (gi, 0, 0))
        return _moments_call(kernel_fn, (g,), in_specs, out_spec, g,
                             compensated=compensated,
                             accum_dtype=accum_dtype, interpret=interpret,
                             args=(x, y, weights))

    kernel_fn = functools.partial(_packed_moments_kernel, degree=degree,
                                  accum_dtype=accum_dtype)
    in_spec = pl.BlockSpec((1, p, block_n), lambda gi, ni: (gi, 0, ni))
    out_spec = pl.BlockSpec((1, K_PAD, K_PAD), lambda gi, ni: (gi, 0, 0))
    return _moments_call(kernel_fn, (g, n // block_n), [in_spec] * 3,
                         out_spec, g, compensated=compensated,
                         accum_dtype=accum_dtype, interpret=interpret,
                         args=(x, y, weights))


def extract_packed(g: jax.Array, degree: int) -> jax.Array:
    """(G, K_PAD, K_PAD) packed Gram -> (G*P, K, K) per-series blocks."""
    k = degree + 2
    p = packing_factor(degree)
    blocks = jnp.stack([g[:, i * k:(i + 1) * k, i * k:(i + 1) * k]
                        for i in range(p)], axis=1)       # (G, P, K, K)
    return blocks.reshape(g.shape[0] * p, k, k)


@functools.partial(jax.jit,
                   static_argnames=("degree", "block_n", "interpret",
                                    "accum_dtype"))
def fused_report_sums(x: jax.Array, y: jax.Array, weights: jax.Array,
                      coeffs: jax.Array, *, degree: int,
                      block_n: int = DEFAULT_BLOCK_N,
                      accum_dtype=jnp.float32,
                      interpret: bool = False) -> jax.Array:
    """One streamed pass over (B, n) data: per-series report sums.

    Returns (B, K_PAD) where lanes SUM_W..SUM_SSE hold
    [Σw, Σwy, Σwy², Σwf, Σwf², Σwyf, Σw(y-f)²] and the rest are zero.
    ``coeffs``: (B, K_PAD) monomial coefficients, zero-padded past degree.
    Everything ``fit_report`` derives (SSE, R) follows from these sums with
    O(B) work — no (B, n) fitted/residual arrays ever touch HBM.
    """
    if x.ndim != 2 or coeffs.shape != (x.shape[0], K_PAD):
        raise ValueError("fused_report_sums expects x:(B,n), coeffs:(B,128)")
    b, n = x.shape
    if n % block_n:
        raise ValueError(f"n={n} must be a multiple of block_n={block_n}")

    kernel_fn = functools.partial(_fused_report_kernel, degree=degree,
                                  accum_dtype=accum_dtype)
    data_spec = pl.BlockSpec((1, block_n), lambda bi, ni: (bi, ni))
    coef_spec = pl.BlockSpec((1, K_PAD), lambda bi, ni: (bi, 0))
    out_spec = pl.BlockSpec((1, K_PAD), lambda bi, ni: (bi, 0))
    return pl.pallas_call(
        kernel_fn,
        grid=(b, n // block_n),
        in_specs=[data_spec, data_spec, data_spec, coef_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, K_PAD), accum_dtype),
        interpret=interpret,
    )(x, y, weights, coeffs)
