"""Pallas TPU kernel: blocked Vandermonde-Gram moment accumulation.

TPU-native adaptation of the paper's CUDA moment kernel (DESIGN.md §2):

* The paper's per-thread partial power sums become a *single MXU matmul* per
  data tile. With W = [V | y] (rows = powers of x, then y), the product
  G = (W ⊙ w) Wᵀ simultaneously yields the Hankel/Gram matrix, the moment
  vector Vᵀy, Σwy² and Σw (= count) — every sufficient statistic of the fit.
* Grid streams (batch, n-block) tiles HBM→VMEM; the (128, 128) accumulator
  tile stays VMEM-resident across the n-block grid dimension (constant
  index_map), mirroring the shared-memory block reduction on GPU.
* Power rows are built by iterated multiply (no transcendental `pow`),
  matching the paper's "matricized" construction.

Layout choices (TPU):
  W tile: (K_PAD=128, block_n) — sublane dim 128 rows of powers, lane dim the
  data block (multiple of 128). G += W_w @ Wᵀ contracts over lanes on the MXU
  with f32 accumulation (preferred_element_type), independent of input dtype.
  VMEM footprint ≈ (2·K_PAD·block_n + K_PAD²)·4B ≈ 4.3 MB at block_n=4096.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

K_PAD = 128          # fixed row count: degree + 2 <= 128
DEFAULT_BLOCK_N = 4096


def _moments_kernel(x_ref, y_ref, w_ref, g_ref, *, degree: int,
                    accum_dtype):
    """One (batch, block) grid step: G[b] += (W·w) Wᵀ for this tile."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    x = x_ref[...].astype(accum_dtype)   # (1, block_n)
    y = y_ref[...].astype(accum_dtype)   # (1, block_n)
    w = w_ref[...].astype(accum_dtype)   # (1, block_n)

    # Build W rows by the iterated-multiply power ladder (paper's trick).
    rows = [jnp.ones_like(x)]
    for _ in range(degree):
        rows.append(rows[-1] * x)
    rows.append(y)
    wmat = jnp.concatenate(rows, axis=0)                     # (deg+2, bn)
    pad = K_PAD - (degree + 2)
    if pad:
        wmat = jnp.concatenate(
            [wmat, jnp.zeros((pad, wmat.shape[1]), accum_dtype)], axis=0)

    lhs = wmat * w                                           # weight one side
    # MXU: (128, bn) @ (bn, 128), f32 accumulation.
    g_ref[...] += jax.lax.dot_general(
        lhs, wmat, (((1,), (1,)), ((), ())),
        preferred_element_type=accum_dtype)[None]


@functools.partial(jax.jit,
                   static_argnames=("degree", "block_n", "interpret",
                                    "accum_dtype"))
def moments_extended(x: jax.Array, y: jax.Array, weights: jax.Array, *,
                     degree: int, block_n: int = DEFAULT_BLOCK_N,
                     accum_dtype=jnp.float32,
                     interpret: bool = False) -> jax.Array:
    """Raw kernel output: (B, K_PAD, K_PAD) extended Gram per batch row.

    x, y, weights: (B, n) with n % block_n == 0 (ops.py handles padding —
    padded tail carries weight 0 so it contributes nothing).
    """
    if x.ndim != 2:
        raise ValueError("moments_extended expects (B, n) inputs")
    b, n = x.shape
    if n % block_n:
        raise ValueError(f"n={n} must be a multiple of block_n={block_n}")
    if degree + 2 > K_PAD:
        raise ValueError(f"degree {degree} too large for K_PAD={K_PAD}")
    nblk = n // block_n

    kernel = functools.partial(_moments_kernel, degree=degree,
                               accum_dtype=accum_dtype)
    in_spec = pl.BlockSpec((1, block_n), lambda bi, ni: (bi, ni))
    out_spec = pl.BlockSpec((1, K_PAD, K_PAD), lambda bi, ni: (bi, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, nblk),
        in_specs=[in_spec, in_spec, in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, K_PAD, K_PAD), accum_dtype),
        interpret=interpret,
    )(x, y, weights)
