"""Pallas TPU kernels for the paper's compute hot-spot.

The paper's CUDA contribution is the parallel moment/power-sum accumulation;
``moments.py`` is its TPU-native re-derivation (blocked Vandermonde-Gram on
the MXU). ``ops.py`` is the jitted wrapper, ``ref.py`` the pure-jnp oracle.
"""
from repro.kernels.ops import moments as compute_moments  # noqa: F401
# (exported under a distinct name so the `repro.kernels.moments` submodule
# stays importable — same shadowing hazard as core.solve)

__all__ = ["compute_moments"]
