"""Jitted public wrappers around the Pallas moment/report kernels.

Handles: batch/flat shapes, tail padding (weight-masked so padding is inert),
block size choice, CPU fallback (interpret mode), packed-vs-plain path
selection, and extraction of the ``Moments`` sufficient statistics from the
kernels' extended Gram output.

Path selection (``moments(..., packing="auto")``):
  * **packed** — batch of ≥ 2 series and packing_factor(degree) ≥ 2: pack
    P = 128 // (degree+2) series per MXU tile (≈ P× fewer FLOPs per fit; see
    the layout diagram in ``repro.kernels.moments``). Batches not divisible
    by P are padded with zero-weight tail series whose exact-zero Gram
    blocks are sliced away.
  * **plain** — single series, or degree > 62 (P < 2): one series per tile.
  * the pure-jnp path stays in ``repro.core.gram_moments`` (the
    ``repro.engine`` plan layer picks between them; ``engine="reference"``
    forces it).

Count semantics: ``Moments.count`` from this module is the TRUE number of
contributing data points — points with nonzero weight, excluding padding —
and ``Moments.weight_sum`` is Σw (== the kernel's raw G[0,0] entry).  The
jnp path records the same split, so kernel- and jnp-produced states mix
freely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.moments import Moments
from repro.kernels import moments as kernel


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _auto_block(n: int) -> int:
    # smallest lane-aligned block that covers short series in one step;
    # large series stream in DEFAULT_BLOCK_N tiles.
    return min(kernel.DEFAULT_BLOCK_N, max(128, -(-n // 128) * 128))


def _pad_tail(arrs, pad):
    if not pad:
        return arrs
    zpad = [(0, 0)] * (arrs[0].ndim - 1) + [(0, pad)]
    return [jnp.pad(a, zpad) for a in arrs]


def _true_count(weights, b, n, dtype):
    """Number of contributing points per series (not Σw — see module doc)."""
    if weights is None:
        return jnp.full((b,), n, dtype)
    return jnp.sum((weights != 0).astype(dtype), axis=-1)


@functools.partial(jax.jit, static_argnames=("degree", "block_n", "interpret",
                                             "accum_dtype", "packing",
                                             "compensated", "nbuf"))
def moments(x: jax.Array, y: jax.Array, degree: int, *,
            weights: jax.Array | None = None,
            block_n: int | None = None,
            accum_dtype=jnp.float32,
            packing: str = "auto",
            compensated: bool = False,
            nbuf: int = 0,
            interpret: bool | None = None) -> Moments:
    """Drop-in kernel-backed equivalent of ``repro.core.gram_moments``.

    Accepts (n,) or (B, n) inputs of any float dtype; returns f32-accumulated
    Moments with matching batch shape. ``packing`` ∈ {"auto", "packed",
    "plain"} picks the tile layout; ``compensated=True`` enables the Kahan
    two-float Gram accumulator (large-n precision, Skala arXiv:1802.07591);
    ``nbuf >= 2`` selects the packed kernel's explicit multi-buffered DMA
    pipeline (prefetch block k+1 while block k's matmul runs — pick the
    tile width with ``repro.kernels.tune.autotune_block_n``).
    """
    if packing not in ("auto", "packed", "plain"):
        raise ValueError(f"packing={packing!r}; expected 'auto', 'packed' "
                         "or 'plain'")
    if interpret is None:
        interpret = _should_interpret()
    if accum_dtype is None:
        accum_dtype = jnp.float32
    flat = x.ndim == 1
    if flat:
        x, y = x[None], y[None]
        if weights is not None:
            weights = weights[None]
    b, n = x.shape
    count = _true_count(weights, b, n, accum_dtype)
    weight_sum = (jnp.full((b,), n, accum_dtype) if weights is None
                  else jnp.sum(weights, axis=-1).astype(accum_dtype))

    pfac = kernel.packing_factor(degree)
    use_packed = (packing == "packed"
                  or (packing == "auto" and b > 1 and pfac > 1))
    if use_packed and pfac < 2:
        raise ValueError(f"degree {degree} leaves no room to pack "
                         f"(packing_factor={pfac}); use packing='plain'")
    if nbuf >= 2 and not use_packed:
        raise ValueError("nbuf (multi-buffered DMA pipeline) is a packed-"
                         "kernel knob; this call resolved to the plain "
                         "layout")

    if block_n is None:
        block_n = _auto_block(n)
    w = jnp.ones_like(x) if weights is None else weights
    x, y, w = _pad_tail([x, y, w], (-n) % block_n)
    # zero weight ⇒ padded tail contributes nothing

    if use_packed:
        bpad = (-b) % pfac
        if bpad:
            zrow = [(0, bpad), (0, 0)]
            x = jnp.pad(x, zrow)
            y = jnp.pad(y, zrow)
            w = jnp.pad(w, zrow)   # zero-weight tail series: exact-zero blocks
        groups = (b + bpad) // pfac
        shape = (groups, pfac, x.shape[-1])
        gp = kernel.moments_packed_extended(
            x.reshape(shape), y.reshape(shape), w.reshape(shape),
            degree=degree, block_n=block_n, accum_dtype=accum_dtype,
            compensated=compensated, nbuf=nbuf, interpret=interpret)
        g = kernel.extract_packed(gp, degree)[:b]         # (b, m+2, m+2)
    else:
        g = kernel.moments_extended(x, y, w, degree=degree, block_n=block_n,
                                    accum_dtype=accum_dtype,
                                    compensated=compensated,
                                    interpret=interpret)
    m1 = degree + 1
    out = Moments(gram=g[:, :m1, :m1], vty=g[:, :m1, m1],
                  yty=g[:, m1, m1], count=count, weight_sum=weight_sum)
    if flat:
        out = jax.tree.map(lambda a: a[0], out)
    return out


@functools.partial(jax.jit, static_argnames=("block_n", "interpret",
                                             "accum_dtype"))
def fused_report_sums(x: jax.Array, y: jax.Array, coeffs: jax.Array, *,
                      weights: jax.Array | None = None,
                      block_n: int | None = None,
                      accum_dtype=jnp.float32,
                      interpret: bool | None = None) -> dict[str, jax.Array]:
    """One-pass evaluation/residual sums for ``core.fit.fit_report_streamed``.

    x, y: (..., n); coeffs: (..., m+1) monomial coefficients in the same
    (already domain-mapped) x. Returns a dict of (...,)-shaped sums:
    ``sw, sy, syy, sf, sff, syf, sse`` — Σw, Σwy, Σwy², Σwf, Σwf², Σwyf,
    Σw(y-f)². Padding rides in with weight 0 and contributes nothing.
    """
    if interpret is None:
        interpret = _should_interpret()
    if accum_dtype is None:
        accum_dtype = jnp.float32
    degree = coeffs.shape[-1] - 1
    if degree + 1 > kernel.K_PAD:
        raise ValueError(f"degree {degree} too large for K_PAD={kernel.K_PAD}")
    batch = x.shape[:-1]
    n = x.shape[-1]
    xb = x.reshape(-1, n)
    yb = y.reshape(-1, n)
    b = xb.shape[0]
    wb = (jnp.ones_like(xb) if weights is None
          else jnp.broadcast_to(weights, x.shape).reshape(-1, n))
    cb = jnp.broadcast_to(coeffs, batch + coeffs.shape[-1:]).reshape(b, -1)
    cb = jnp.pad(cb, [(0, 0), (0, kernel.K_PAD - cb.shape[-1])])

    if block_n is None:
        block_n = _auto_block(n)
    xb, yb, wb = _pad_tail([xb, yb, wb], (-n) % block_n)

    sums = kernel.fused_report_sums(
        xb, yb, wb, cb.astype(accum_dtype), degree=degree, block_n=block_n,
        accum_dtype=accum_dtype, interpret=interpret)
    names = ("sw", "sy", "syy", "sf", "sff", "syf", "sse")
    return {name: sums[:, j].reshape(batch)
            for j, name in enumerate(names)}
