"""Jitted public wrappers around the Pallas moments kernel.

Handles: batch/flat shapes, tail padding (weight-masked so padding is inert),
block size choice, CPU fallback (interpret mode), and extraction of the
``Moments`` sufficient statistics from the kernel's extended Gram output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.moments import Moments
from repro.kernels import moments as kernel


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("degree", "block_n", "interpret",
                                             "accum_dtype"))
def moments(x: jax.Array, y: jax.Array, degree: int, *,
            weights: jax.Array | None = None,
            block_n: int | None = None,
            accum_dtype=jnp.float32,
            interpret: bool | None = None) -> Moments:
    """Drop-in kernel-backed equivalent of ``repro.core.gram_moments``.

    Accepts (n,) or (B, n) inputs of any float dtype; returns f32-accumulated
    Moments with matching batch shape.
    """
    if interpret is None:
        interpret = _should_interpret()
    if accum_dtype is None:
        accum_dtype = jnp.float32
    flat = x.ndim == 1
    if flat:
        x, y = x[None], y[None]
        if weights is not None:
            weights = weights[None]
    b, n = x.shape

    if block_n is None:
        # smallest lane-aligned block that covers short series in one step;
        # large series stream in DEFAULT_BLOCK_N tiles.
        block_n = min(kernel.DEFAULT_BLOCK_N, max(128, -(-n // 128) * 128))
    pad = (-n) % block_n
    w = jnp.ones_like(x) if weights is None else weights
    if pad:
        zpad = [(0, 0), (0, pad)]
        x = jnp.pad(x, zpad)
        y = jnp.pad(y, zpad)
        w = jnp.pad(w, zpad)   # zero weight ⇒ padded tail contributes nothing

    g = kernel.moments_extended(x, y, w, degree=degree, block_n=block_n,
                                accum_dtype=accum_dtype, interpret=interpret)
    m1 = degree + 1
    out = Moments(gram=g[:, :m1, :m1], vty=g[:, :m1, m1],
                  yty=g[:, m1, m1], count=g[:, 0, 0])
    if flat:
        out = jax.tree.map(lambda a: a[0], out)
    return out
