"""Pure-jnp oracle for the moments kernel.

Computes the extended Gram matrix G = (W·w) Wᵀ with W = [V | y | 0-pad],
W: (K, n) row-major powers — exactly what the Pallas kernel accumulates,
including the K=128 zero-padding, so tests can compare the *full* padded
output as well as the extracted Moments."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import basis as basis_lib
from repro.core.moments import Moments

K_PAD = 128  # kernel's fixed row count (degree+2 <= K_PAD)


def extended_matrix(x: jnp.ndarray, y: jnp.ndarray, degree: int,
                    accum_dtype=jnp.float32) -> jnp.ndarray:
    """W rows: [x^0, x^1, ..., x^degree, y, zeros...]; shape (..., K_PAD, n).

    Inputs are cast to ``accum_dtype`` BEFORE the power ladder — matching the
    kernel, which builds powers in the accumulation dtype."""
    x = x.astype(accum_dtype)
    y = y.astype(accum_dtype)
    v = basis_lib.vandermonde(x, degree)            # (..., n, m+1)
    w = jnp.concatenate([v, y[..., :, None]], axis=-1)  # (..., n, m+2)
    pad = K_PAD - (degree + 2)
    w = jnp.pad(w, [(0, 0)] * (w.ndim - 1) + [(0, pad)])
    return jnp.swapaxes(w, -1, -2)                  # (..., K_PAD, n)


def extended_gram(x: jnp.ndarray, y: jnp.ndarray, degree: int,
                  weights: jnp.ndarray | None = None,
                  accum_dtype=jnp.float32) -> jnp.ndarray:
    """(..., K_PAD, K_PAD) reference for the kernel's raw output."""
    w_mat = extended_matrix(x, y, degree, accum_dtype)
    lhs = w_mat if weights is None else w_mat * weights[..., None, :].astype(accum_dtype)
    return jnp.einsum("...kn,...jn->...kj", lhs, w_mat)


def moments_from_extended(g: jnp.ndarray, degree: int,
                          count: jnp.ndarray | None = None) -> Moments:
    """Slice the paper's statistics out of the extended Gram matrix.

    G[0,0] is Σw (``weight_sum``); the true contributing-point count is not
    recoverable from G alone, so pass it when weights are in play (defaults
    to Σw, which is exact for 0/1 weights)."""
    m1 = degree + 1
    return Moments(gram=g[..., :m1, :m1],
                   vty=g[..., :m1, m1],
                   yty=g[..., m1, m1],
                   count=g[..., 0, 0] if count is None else count,
                   weight_sum=g[..., 0, 0])


def moments_reference(x: jnp.ndarray, y: jnp.ndarray, degree: int,
                      weights: jnp.ndarray | None = None,
                      accum_dtype=jnp.float32) -> Moments:
    count = None
    if weights is not None:
        count = jnp.sum((weights != 0), axis=-1).astype(accum_dtype)
    return moments_from_extended(
        extended_gram(x, y, degree, weights, accum_dtype), degree,
        count=count)


def packed_extended_gram(x: jnp.ndarray, y: jnp.ndarray, degree: int,
                         weights: jnp.ndarray | None = None,
                         accum_dtype=jnp.float32) -> jnp.ndarray:
    """Oracle for the packed kernel's raw (G, K_PAD, K_PAD) output.

    x, y (and weights): (G, P, n) with P = K_PAD // (degree+2). Builds the
    series-major packed W = [V₀|y₀|V₁|y₁|...|0-pad] rows explicitly and forms
    (W·w) Wᵀ — including the cross-series off-diagonal blocks, so tests can
    compare the kernel's full tile, not just the extracted diagonals."""
    g, p, n = x.shape
    k = degree + 2
    x = x.astype(accum_dtype)
    y = y.astype(accum_dtype)
    v = basis_lib.vandermonde(x, degree)                 # (G, P, n, m+1)
    w = jnp.concatenate([v, y[..., :, None]], axis=-1)   # (G, P, n, K)
    w = jnp.swapaxes(w, -1, -2).reshape(g, p * k, n)     # (G, P*K, n)
    w = jnp.pad(w, [(0, 0), (0, K_PAD - p * k), (0, 0)])
    if weights is None:
        lhs = w
    else:
        wexp = jnp.repeat(weights.astype(accum_dtype), k, axis=1)
        wexp = jnp.pad(wexp, [(0, 0), (0, K_PAD - p * k), (0, 0)])
        lhs = w * wexp
    return jnp.einsum("gkn,gjn->gkj", lhs, w)
