"""Linear solvers for the (m+1)x(m+1) normal-equation system.

``gaussian_elimination`` is the paper's method (Sec. II: "the matrix X has been
solved for using the method of Gaussian Elimination"), implemented with partial
pivoting in pure ``jax.lax`` control flow so it jits, vmaps and shards.

``qr_solve`` is the paper's *comparison baseline* (MATLAB polyfit's method:
QR-factorize the Vandermonde, never form the Gram matrix).

``cholesky_solve`` is a beyond-paper option exploiting SPD-ness of VᵀV.
All solvers are batched over leading axes via vmap-compatible code.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@jax.jit
def gaussian_elimination(a: jax.Array, b: jax.Array) -> jax.Array:
    """Solve a @ x = b by Gaussian elimination with partial pivoting.

    a: (..., m, m), b: (..., m). Returns x: (..., m).
    Written as row-parallel rank-1 updates inside a fori_loop, which is the
    TPU-friendly shape (VPU row ops) of the paper's sequential elimination.
    """
    if a.ndim > 2:
        return jax.vmap(gaussian_elimination)(a, b)
    m = a.shape[-1]
    aug = jnp.concatenate([a, b[..., None]], axis=-1)  # (m, m+1)

    def step(k, aug):
        # partial pivot: swap row k with argmax |aug[k:, k]|
        col = jnp.abs(aug[:, k])
        col = jnp.where(jnp.arange(m) < k, -jnp.inf, col)
        p = jnp.argmax(col)
        rk, rp = aug[k], aug[p]
        aug = aug.at[k].set(rp).at[p].set(rk)
        # eliminate below AND above (Gauss-Jordan: avoids a back-subst loop,
        # same O(m^3), better for tiny m on vector units)
        pivot = aug[k, k]
        factors = aug[:, k] / pivot
        factors = factors.at[k].set(0.0)
        aug = aug - factors[:, None] * aug[k][None, :]
        return aug

    aug = jax.lax.fori_loop(0, m, step, aug)
    return aug[:, m] / jnp.diagonal(aug[:, :m])


@jax.jit
def cholesky_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    """SPD solve via Cholesky (beyond-paper; Gram matrices are SPD)."""
    chol = jnp.linalg.cholesky(a)
    y = jax.scipy.linalg.solve_triangular(chol, b[..., None], lower=True)
    x = jax.scipy.linalg.solve_triangular(
        jnp.swapaxes(chol, -1, -2), y, lower=False)
    return x[..., 0]


@partial(jax.jit, static_argnames=())
def qr_solve_vandermonde(v: jax.Array, y: jax.Array) -> jax.Array:
    """polyfit()-style solve: V = QR, coeffs = R⁻¹ Qᵀ y (Householder QR).

    This is the paper's accuracy baseline — it acts on the full n×(m+1) design
    matrix, so it is NOT matricizable into O(m²) sufficient statistics; its
    communication cost scales with n. That contrast is the paper's point.
    """
    q, r = jnp.linalg.qr(v)
    return jax.scipy.linalg.solve_triangular(
        r, jnp.einsum("...nk,...n->...k", q, y)[..., None], lower=False)[..., 0]


def solve(a: jax.Array, b: jax.Array, method: str = "gauss") -> jax.Array:
    if method == "gauss":
        return gaussian_elimination(a, b)
    if method == "cholesky":
        return cholesky_solve(a, b)
    raise ValueError(f"unknown solve method {method!r}")
