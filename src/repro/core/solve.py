"""Linear solvers for the (m+1)x(m+1) normal-equation system.

``gaussian_elimination`` is the paper's method (Sec. II: "the matrix X has been
solved for using the method of Gaussian Elimination"), implemented with partial
pivoting in pure ``jax.lax`` control flow so it jits, vmaps and shards.

``qr_solve_vandermonde`` is the paper's *comparison baseline* (MATLAB
polyfit's method: QR-factorize the Vandermonde, never form the Gram matrix).

Beyond the paper, this module holds the condition-aware solver stack
(Skala, arXiv:1802.07591: the normal equations square the Vandermonde's
condition number, so plain elimination silently degrades or NaNs at higher
degrees / wider domains):

* ``cholesky_solve``       SPD fast path (VᵀV is SPD when full rank);
* ``qr_solve_gram``        Householder QR of the Gram matrix — no SPD
                           assumption, stable pivot-free triangular solve;
* ``svd_solve``            rank-revealing minimum-norm solve: symmetric
                           Jacobi-equilibrated SVD pseudo-inverse with a
                           relative singular-value cutoff.  Finite output
                           even on exactly singular systems;
* ``condition_estimate``   2-norm condition number of the Gram from its
                           eigenvalues — O(m³) on the O(m²) moment state,
                           negligible next to the O(n·m²) accumulation;
* ``select_solver``        static GE → Cholesky → QR → SVD choice from
                           degree/dtype/basis (the ``plan_fit`` hook);
* ``solve_with_fallback``  runtime guard: run the planned solver, and where
                           the condition estimate exceeds the dtype's cap —
                           or the output is non-finite — swap in the SVD
                           result (``lax.cond``: the fallback branch costs
                           nothing unless taken; under vmap it lowers to
                           select, still O(m³) on a tiny matrix).

All solvers are batched over leading axes via vmap-compatible code.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

# the explicit-solve ladder, in escalation order (LSPIA — the matrix-free
# iterative path that never forms the Gram — lives in repro.core.lspia and
# is selectable one level up, in repro.engine.plan_fit / core.polyfit)
SOLVERS = ("gauss", "cholesky", "qr", "svd")

# runtime condition caps: past these the planned solver's normwise error
# bound (~eps·κ) has lost every digit and the SVD rescue replaces its
# result.  f32: 1/eps ≈ 8e6 rounded up — note this means wide-raw-domain
# f32 fits (the paper's own [0, 40] degree-3 tables sit at κ ≈ 2.6e9,
# already past f32 precision) report fallback_used=True and return the
# equilibrated-SVD result; it reproduces the paper's tables to the same
# digits GE does, but byte-identical paper-literal output needs
# solver="gauss", fallback=None.  The cap stays below the f32 eigvalsh
# noise floor of exactly-singular matrices (≈1e8: wmin rounds to ~eps·wmax)
# so singularity is still caught by κ, not just by non-finite output.
COND_CAP = {jnp.dtype(jnp.float32): 3e7, jnp.dtype(jnp.float64): 1e11}


def cond_cap_for(dtype) -> float:
    """Condition cap above which ``solve_with_fallback`` engages the SVD."""
    return COND_CAP.get(jnp.dtype(dtype), 3e7)


@jax.jit
def gaussian_elimination(a: jax.Array, b: jax.Array) -> jax.Array:
    """Solve a @ x = b by Gaussian elimination with partial pivoting.

    a: (..., m, m), b: (..., m). Returns x: (..., m).
    Written as row-parallel rank-1 updates inside a fori_loop, which is the
    TPU-friendly shape (VPU row ops) of the paper's sequential elimination.
    """
    if a.ndim > 2:
        return jax.vmap(gaussian_elimination)(a, b)
    m = a.shape[-1]
    aug = jnp.concatenate([a, b[..., None]], axis=-1)  # (m, m+1)

    def step(k, aug):
        # partial pivot: swap row k with argmax |aug[k:, k]|
        col = jnp.abs(aug[:, k])
        col = jnp.where(jnp.arange(m) < k, -jnp.inf, col)
        p = jnp.argmax(col)
        rk, rp = aug[k], aug[p]
        aug = aug.at[k].set(rp).at[p].set(rk)
        # eliminate below AND above (Gauss-Jordan: avoids a back-subst loop,
        # same O(m^3), better for tiny m on vector units)
        pivot = aug[k, k]
        factors = aug[:, k] / pivot
        factors = factors.at[k].set(0.0)
        aug = aug - factors[:, None] * aug[k][None, :]
        return aug

    aug = jax.lax.fori_loop(0, m, step, aug)
    return aug[:, m] / jnp.diagonal(aug[:, :m])


@jax.jit
def cholesky_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    """SPD solve via Cholesky (beyond-paper; Gram matrices are SPD)."""
    chol = jnp.linalg.cholesky(a)
    y = jax.scipy.linalg.solve_triangular(chol, b[..., None], lower=True)
    x = jax.scipy.linalg.solve_triangular(
        jnp.swapaxes(chol, -1, -2), y, lower=False)
    return x[..., 0]


@partial(jax.jit, static_argnames=())
def qr_solve_vandermonde(v: jax.Array, y: jax.Array) -> jax.Array:
    """polyfit()-style solve: V = QR, coeffs = R⁻¹ Qᵀ y (Householder QR).

    This is the paper's accuracy baseline — it acts on the full n×(m+1) design
    matrix, so it is NOT matricizable into O(m²) sufficient statistics; its
    communication cost scales with n. That contrast is the paper's point.
    """
    q, r = jnp.linalg.qr(v)
    return jax.scipy.linalg.solve_triangular(
        r, jnp.einsum("...nk,...n->...k", q, y)[..., None], lower=False)[..., 0]


@jax.jit
def qr_solve_gram(a: jax.Array, b: jax.Array) -> jax.Array:
    """Householder-QR solve of the (m+1)×(m+1) Gram system.

    More robust than elimination for moderately ill-conditioned A (no pivot
    growth, orthogonal reduction); still limited by cond(A) = cond(V)²."""
    q, r = jnp.linalg.qr(a)
    qtb = jnp.einsum("...ji,...j->...i", q, b)
    return jax.scipy.linalg.solve_triangular(
        r, qtb[..., None], lower=False)[..., 0]


@jax.jit
def svd_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    """Rank-revealing minimum-norm solve: equilibrate, SVD, truncate, invert.

    Symmetric Jacobi equilibration (A' = DAD, D = diag(A)^-½) first: for
    Gram matrices it is exactly "scale every basis column to unit norm",
    which soaks up the domain-width part of the conditioning (the dominant
    term for raw monomials — see EXPERIMENTS.md §Solver selection) before
    the SVD sees the matrix.  Singular values below ``eps·(m+1)·σmax`` are
    truncated, so exactly-singular systems (constant x, zero-weight slots)
    return the finite minimum-norm solution instead of inf/NaN.
    """
    d = jnp.diagonal(a, axis1=-2, axis2=-1)
    d = jnp.where(d > 0, jax.lax.rsqrt(jnp.where(d > 0, d, 1.0)), 1.0)
    ae = a * d[..., :, None] * d[..., None, :]
    be = b * d
    u, s, vt = jnp.linalg.svd(ae)
    cutoff = (jnp.finfo(a.dtype).eps * a.shape[-1]
              * jnp.max(s, axis=-1, keepdims=True))
    keep = s > cutoff
    s_inv = jnp.where(keep, 1.0 / jnp.where(keep, s, 1.0), 0.0)
    utb = jnp.einsum("...ji,...j->...i", u, be)
    xe = jnp.einsum("...ji,...j->...i", vt, s_inv * utb)
    return xe * d


@jax.jit
def condition_estimate(a: jax.Array) -> jax.Array:
    """2-norm condition number κ(A) of the symmetric Gram, batched.

    Eigenvalue ratio max|λ|/min|λ| via ``eigvalsh`` — O(m³) on the O(m²)
    sufficient-statistic state, so streaming/serving can afford it per
    solve.  Returns +inf for singular (or all-zero) matrices; near-singular
    matrices whose smallest eigenvalue rounds negative report the honest
    huge-but-finite ratio of magnitudes.

    κ is scale-invariant (κ(sA) = κ(A)), so the matrix is normalized by
    its largest |entry| before the eigensolve: a uniformly tiny Gram — a
    decayed stream whose total weight has underflowed toward 0 but whose
    SHAPE is still perfectly conditioned — must report its true κ, not
    the +inf that eigenvalues under the dtype's tiny would produce (which
    silently pinned such streams to the SVD fallback forever)."""
    amax = jnp.max(jnp.abs(a), axis=(-2, -1), keepdims=True)
    an = a / jnp.where(amax > 0, amax, 1.0)
    w = jnp.abs(jnp.linalg.eigvalsh(an))
    wmax = jnp.max(w, axis=-1)
    wmin = jnp.min(w, axis=-1)
    inf = jnp.asarray(jnp.inf, wmax.dtype)
    # an all-zero state stays +inf (wmax == 0 after normalization guard)
    return jnp.where(wmin > 0, wmax / jnp.where(wmin > 0, wmin, 1.0), inf)


def select_solver(degree: int, dtype, *, basis: str = "monomial",
                  normalized: bool = False) -> str:
    """Static GE → Cholesky → QR → SVD choice from degree/dtype/basis.

    The static pick covers what is knowable before seeing data: the Gram's
    condition grows roughly geometrically with degree, slowly for bases
    confined to [-1, 1] (normalized domain or Chebyshev), explosively for
    raw monomials on arbitrary domains (measured crossovers in
    EXPERIMENTS.md §Solver selection).  The runtime condition estimate in
    ``solve_with_fallback`` then catches what only the data can reveal
    (wide un-normalized domains at low degree, degenerate inputs).
    """
    f64 = jnp.finfo(jnp.dtype(dtype)).eps < 1e-9
    well = normalized or basis == "chebyshev"
    if well:
        # [-1,1]-confined bases: cond(Gram) ≈ 10^(0.55·deg) monomial-normalized,
        # far less for Chebyshev — elimination is fine deep into the degrees.
        if degree <= 5:
            return "gauss"
        if degree <= 8:
            return "cholesky"      # SPD fast path, still comfortably ranked
        return "qr" if f64 else "svd"
    # raw monomial on an arbitrary domain: cond(Gram) ≈ (width/2)^(2·deg) ·
    # normalized-cond — already ~2.6e9 at degree 3 on the paper's [0, 40]
    # data, so on wide domains the runtime guard may still swap in the SVD
    # over the GE picked here (see COND_CAP).
    if degree <= 3:
        return "gauss"             # the paper's regime; fallback guards it
    if degree <= 5:
        return "cholesky" if f64 else "qr"
    return "qr" if f64 else "svd"


def solve(a: jax.Array, b: jax.Array, method: str = "gauss") -> jax.Array:
    if method == "gauss":
        return gaussian_elimination(a, b)
    if method == "cholesky":
        return cholesky_solve(a, b)
    if method == "qr":
        return qr_solve_gram(a, b)
    if method == "svd":
        return svd_solve(a, b)
    raise ValueError(f"unknown solve method {method!r}; "
                     f"expected one of {SOLVERS}")


@partial(jax.jit, static_argnames=("method", "fallback", "cond_cap"))
def solve_with_fallback(a: jax.Array, b: jax.Array, *,
                        method: str = "gauss",
                        fallback: str | None = "svd",
                        cond_cap: float | None = None):
    """Condition-guarded solve: planned solver, SVD rescue when it degrades.

    Returns ``(x, cond, fallback_used)``.  The fallback engages when the
    estimated κ(A) exceeds ``cond_cap`` (default per-dtype ``COND_CAP``) or
    the primary produced non-finite output — the silent-NaN regime of plain
    elimination on singular Grams (constant x, zero-range domains).  With
    ``fallback=None`` the guard is off (pure planned solver; cond is still
    reported, fallback_used is always False).

    Unbatched: ``lax.cond`` skips the fallback entirely on the hot path.
    Batched (leading axes on a/b): vmapped, where cond lowers to select —
    both branches run, still O(m³) on tiny matrices.
    """
    if a.ndim > 2:
        part = partial(solve_with_fallback, method=method, fallback=fallback,
                       cond_cap=cond_cap)
        return jax.vmap(part)(a, b)
    cap = float(cond_cap) if cond_cap is not None else cond_cap_for(a.dtype)
    cond = condition_estimate(a)
    x = solve(a, b, method)
    if fallback is None:
        return x, cond, jnp.zeros((), bool)
    bad = (~jnp.all(jnp.isfinite(x))) | ~(cond <= cap)   # NaN cond counts
    if fallback == method:
        # nothing different to re-solve with, but the condition breach must
        # still be reported — flagging is the guard's contract, the second
        # solve just its remedy
        return x, cond, bad
    x = jax.lax.cond(bad,
                     lambda ab: solve(ab[0], ab[1], fallback),
                     lambda ab: x, (a, b))
    return x, cond, bad
