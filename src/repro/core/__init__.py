"""Core matricized LSE curve fitting (the paper's contribution).

Public API re-exports."""
from repro.core.basis import Domain, vandermonde, evaluate, MONOMIAL, CHEBYSHEV
from repro.core.moments import (Moments, gram_moments, gram_moments_blocked,
                                power_sums, hankel_from_power_sums,
                                moment_vector)
from repro.core.solve import (gaussian_elimination, cholesky_solve,
                              qr_solve_vandermonde, qr_solve_gram,
                              svd_solve, condition_estimate, select_solver,
                              solve_with_fallback, cond_cap_for, SOLVERS)
from repro.core.solve import solve as solve_linear
from repro.core.fit import (Polynomial, FitReport, StreamedFitReport,
                            FitDiagnostics,
                            polyfit, polyfit_qr, fit_from_moments,
                            fit_report, fit_report_streamed,
                            sse_from_moments, report_from_moments)
from repro.core.robust import (robust_polyfit, RobustFit, HUBER, TUKEY)
from repro.core.lspia import (lspia_fit, LSPIAFit)
from repro.core.distributed import (make_distributed_fit,
                                    make_distributed_select,
                                    local_moments, psum_moments)
from repro.core.streaming import StreamState, update, current_fit, current_sse
from repro.core.scaling_laws import PowerLaw, fit_power_law

# single-pass automatic model selection: repro.select builds ON these core
# modules, so its names are re-exported lazily (PEP 562) — an eager import
# here would be circular whenever repro.select (or repro.serve, which uses
# it) is imported before repro.core finishes initializing
_SELECT_EXPORTS = ("select_degree", "DegreeSearch", "Selection",
                   "SweepResult", "sweep_from_moments")


def __getattr__(name):
    if name in _SELECT_EXPORTS:
        import repro.select as _select
        return getattr(_select, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Domain", "vandermonde", "evaluate", "MONOMIAL", "CHEBYSHEV",
    "Moments", "gram_moments", "gram_moments_blocked", "power_sums",
    "hankel_from_power_sums", "moment_vector",
    "gaussian_elimination", "cholesky_solve", "qr_solve_vandermonde",
    "qr_solve_gram", "svd_solve", "condition_estimate", "select_solver",
    "solve_with_fallback", "cond_cap_for", "SOLVERS",
    "solve_linear",
    "Polynomial", "FitReport", "StreamedFitReport", "FitDiagnostics",
    "polyfit", "polyfit_qr",
    "fit_from_moments", "fit_report", "fit_report_streamed",
    "sse_from_moments", "report_from_moments",
    "robust_polyfit", "RobustFit", "HUBER", "TUKEY",
    "lspia_fit", "LSPIAFit",
    "make_distributed_fit", "make_distributed_select",
    "local_moments", "psum_moments",
    "StreamState", "update", "current_fit", "current_sse",
    "PowerLaw", "fit_power_law",
    "select_degree", "DegreeSearch", "Selection", "SweepResult",
    "sweep_from_moments",
]
