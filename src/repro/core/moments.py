"""Matricized moment / Gram accumulation — the paper's core primitive.

The paper's normal-equation matrix is the Hankel matrix of power sums
``A[j,k] = S_{j+k} = Σ_i x_i^{j+k}`` and the RHS is ``B[j] = T_j = Σ_i x_i^j y_i``.
With the Vandermonde matrix ``V[i,k] = x_i^k`` these are exactly

    A = Vᵀ V          (Gram)
    B = Vᵀ y

which is the TPU-native (MXU) formulation used throughout this framework and by
the Pallas kernel in ``repro.kernels.moments``. Both formulations are provided;
``power_sums`` is the paper-literal one, ``gram_moments`` the matricized one —
they agree to fp tolerance and the tests assert it.

Moments are *additive* across data shards and across time. That property is
what makes the fit (a) embarrassingly data-parallel (one tiny psum) and (b)
streamable with O(1) state (see ``repro.core.streaming``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import basis as basis_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Moments:
    """Sufficient statistics of an LSE fit. Additive: m1 + m2 fits the union.

    ``count`` is the TRUE number of contributing points (nonzero combined
    weight, padding excluded) on every producing path — jnp and kernel alike
    — so states from different paths mix freely.  The weighted mass Σw lives
    in ``weight_sum`` (== gram[..., 0, 0] for weight-1 bases); it is what
    decays under exponential forgetting, while ``count`` keeps counting raw
    points seen.
    """

    gram: jax.Array        # (..., m+1, m+1)  == Vᵀ V
    vty: jax.Array         # (..., m+1)       == Vᵀ y
    yty: jax.Array         # (...,)           == Σ w y²  (residual/R without refit)
    count: jax.Array       # (...,)           == # points with nonzero weight
    weight_sum: jax.Array  # (...,)           == Σ w

    def __add__(self, other: "Moments") -> "Moments":
        return Moments(self.gram + other.gram, self.vty + other.vty,
                       self.yty + other.yty, self.count + other.count,
                       self.weight_sum + other.weight_sum)

    @property
    def degree(self) -> int:
        return self.gram.shape[-1] - 1

    def condition(self) -> jax.Array:
        """Estimated κ₂ of the normal-equation matrix, from the O(m²) state.

        This is the quantity the condition-aware solver stack keys on
        (``core.solve.solve_with_fallback``): it costs O(m³) on the tiny
        sufficient statistics — nothing next to the O(n·m²) accumulation —
        so streaming/serving paths can re-check it every solve.  +inf means
        singular (fewer distinct x than coefficients, zero-weight state).
        The estimate is scale-invariant: a decayed stream whose weighted
        mass has shrunk toward underflow reports the κ of its SHAPE, so
        refilled streams return to the fast solver rungs instead of being
        pinned to the SVD fallback by spurious +inf."""
        from repro.core import solve as solve_lib
        return solve_lib.condition_estimate(self.gram)

    def regularized(self, ridge: float) -> "Moments":
        """Moments with λI added to the Gram (Tikhonov / early-stream
        stabilizer).  Shared by streaming and the fit server's pooled
        solve, which must tolerate all-zero idle slots."""
        eye = jnp.eye(self.degree + 1, dtype=self.gram.dtype)
        return dataclasses.replace(self, gram=self.gram + ridge * eye)

    def truncate(self, degree: int) -> "Moments":
        """The degree-``degree`` sufficient statistics nested inside this
        state: leading (degree+1)×(degree+1) Gram submatrix, leading
        (degree+1) slice of Vᵀy; yty/count/weight_sum are degree-free and
        shared.  Exact for the monomial and Chebyshev bases (column k of V
        depends only on k), which is what makes a single degree-M
        accumulation carry the *whole* ladder d = 0..M — the basis of
        ``repro.select``'s one-pass model selection."""
        if not 0 <= degree <= self.degree:
            raise ValueError(f"cannot truncate degree-{self.degree} moments "
                             f"to degree {degree}")
        m1 = degree + 1
        return dataclasses.replace(self, gram=self.gram[..., :m1, :m1],
                                   vty=self.vty[..., :m1])

    @staticmethod
    def zeros(degree: int, batch: tuple[int, ...] = (), dtype=jnp.float32) -> "Moments":
        m1 = degree + 1
        return Moments(
            gram=jnp.zeros(batch + (m1, m1), dtype),
            vty=jnp.zeros(batch + (m1,), dtype),
            yty=jnp.zeros(batch, dtype),
            count=jnp.zeros(batch, dtype),
            weight_sum=jnp.zeros(batch, dtype),
        )


def decay_ladder(n: int, decay, dtype) -> jax.Array:
    """The exponential-forgetting age ladder for one n-point chunk:
    ``decay ** [n-1, ..., 1, 0]`` — newest point gets γ⁰.  The ONE home of
    that convention: every surface (eager fit, streaming update, serve
    ingest, IRLS base weights, distributed shards) multiplies this in, so
    a γ-weighted fit means the same thing everywhere."""
    return jnp.asarray(decay, dtype) ** jnp.arange(n - 1, -1, -1,
                                                   dtype=dtype)


@partial(jax.jit, static_argnames=("degree",))
def power_sums(x: jax.Array, degree: int, *, weights: jax.Array | None = None) -> jax.Array:
    """Paper-literal power sums S_0..S_{2m} (shape (2*degree+1,)).

    Iterated-multiply power ladder, summed per power — exactly the quantity the
    paper's CUDA threads accumulate."""
    w = jnp.ones_like(x) if weights is None else weights
    sums = []
    p = jnp.ones_like(x)
    for _ in range(2 * degree + 1):
        sums.append(jnp.sum(p * w))
        p = p * x
    return jnp.stack(sums)


def hankel_from_power_sums(s: jax.Array, degree: int) -> jax.Array:
    """Assemble the paper's A matrix from power sums: A[j,k] = S[j+k]."""
    idx = jnp.arange(degree + 1)
    return s[idx[:, None] + idx[None, :]]


@partial(jax.jit, static_argnames=("degree", "basis"))
def moment_vector(x: jax.Array, y: jax.Array, degree: int,
                  basis: str = basis_lib.MONOMIAL) -> jax.Array:
    """Paper-literal B[j] = Σ x^j y, j = 0..m."""
    v = basis_lib.vandermonde(x, degree, basis)
    return jnp.einsum("...nk,...n->...k", v, y)


@partial(jax.jit, static_argnames=("degree", "basis", "accum_dtype"))
def gram_moments(x: jax.Array, y: jax.Array, degree: int, *,
                 basis: str = basis_lib.MONOMIAL,
                 weights: jax.Array | None = None,
                 accum_dtype=None) -> Moments:
    """Matricized moments A = VᵀV, B = Vᵀy over the last axis of x/y.

    Supports arbitrary leading batch axes (batched curve fitting): x, y of
    shape (..., n) produce Moments with batch shape (...,).

    ``accum_dtype`` lets callers accumulate in a wider dtype than the inputs
    (e.g. bf16 data, f32 sums) — the numerical-hardening path beyond the paper.
    """
    v = basis_lib.vandermonde(x, degree, basis)  # (..., n, m+1)
    if accum_dtype is not None:
        v = v.astype(accum_dtype)
        y = y.astype(accum_dtype)
    if weights is not None:
        wv = v * weights[..., :, None]
    else:
        wv = v
    gram = jnp.einsum("...nj,...nk->...jk", wv, v)
    vty = jnp.einsum("...nj,...n->...j", wv, y)
    yty = jnp.sum((weights * y if weights is not None else y) * y, axis=-1)
    if weights is None:
        count = jnp.full(x.shape[:-1], x.shape[-1], (accum_dtype or x.dtype))
        weight_sum = count
    else:
        # true contributing-point count (kernel-path semantics); Σw separately
        count = jnp.sum((weights != 0).astype(gram.dtype), axis=-1)
        weight_sum = jnp.sum(weights, axis=-1)
    return Moments(gram=gram, vty=vty, yty=yty,
                   count=count.astype(gram.dtype),
                   weight_sum=weight_sum.astype(gram.dtype))


@partial(jax.jit, static_argnames=("degree", "basis", "block", "accum_dtype"))
def gram_moments_blocked(x: jax.Array, y: jax.Array, degree: int, *,
                         basis: str = basis_lib.MONOMIAL,
                         block: int = 1 << 16,
                         accum_dtype=None) -> Moments:
    """Chunked accumulation for datasets too large to materialize V at once.

    Mirrors the Pallas kernel's grid structure (one Gram update per block) in
    pure JAX; used as the large-n host path and as the kernel's shape oracle.
    Tail is zero-padded; padding contributes nothing because both V-rows and y
    are zeroed there (weights mask).
    """
    n = x.shape[-1]
    nblk = -(-n // block)
    pad = nblk * block - n
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    yp = jnp.pad(y, [(0, 0)] * (y.ndim - 1) + [(0, pad)])
    mask = jnp.pad(jnp.ones_like(x), [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = xp.reshape(x.shape[:-1] + (nblk, block))
    yb = yp.reshape(y.shape[:-1] + (nblk, block))
    mb = mask.reshape(x.shape[:-1] + (nblk, block))

    def body(carry: Moments, inp):
        xi, yi, mi = inp
        m = gram_moments(xi, yi, degree, basis=basis, weights=mi,
                         accum_dtype=accum_dtype)
        return carry + m, None

    # scan over the block axis (moved to front)
    move = lambda a: jnp.moveaxis(a, -2, 0)
    init = Moments.zeros(degree, x.shape[:-1],
                         dtype=(accum_dtype or x.dtype))
    out, _ = jax.lax.scan(body, init, (move(xb), move(yb), move(mb)))
    return out
