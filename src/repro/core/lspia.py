"""LSPIA: least-squares progressive-iterative approximation, matrix-free.

The paper's matricization — and this repo's entire fast path — still ends
in an explicit (m+1)×(m+1) normal-equation solve, and the Gram matrix it
solves squares the Vandermonde's condition number the moment it is formed.
LSPIA (Deng & Lin 2014; asynchronous variant Wu & Liu, arXiv:2211.06556)
sidesteps the Gram entirely: iterate

    c ← c + μ · Vᵀ W (y − V c)

where both operators are applied *matrix-free* — ``V c`` is Horner/Clenshaw
evaluation and ``Vᵀ r`` an iterated-multiply reduction — so the working
state is O(m) coefficients plus one O(n) residual stream, never an O(m²)
matrix.  Fixed point: the weighted LSE solution (the update is Richardson
iteration on the normal equations; it converges for 0 < μ < 2/λmax(VᵀWV)).

The step size is set from a matrix-free power-iteration estimate of λmax
(a handful of V/Vᵀ passes).  Convergence rate degrades with κ(VᵀV) like
any first-order method, so the practical regime is normalized domains and
the Chebyshev basis — where κ is small and the iteration converges in tens
of steps — and colossal/streamed datasets where forming the Gram in low
precision loses more than the iteration does (measured crossovers:
EXPERIMENTS.md §Solver selection).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import basis as basis_lib
from repro.core import fit as fit_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LSPIAFit:
    """An LSPIA fit: polynomial + the iteration's convergence record."""

    poly: fit_lib.Polynomial
    iterations: jax.Array      # ()     iterations actually run
    converged: jax.Array       # (...,) ‖∇‖ fell below tol·‖Vᵀwy‖
    grad_norm: jax.Array       # (...,) final ‖Vᵀ W (y - Vc)‖₂
    step: jax.Array            # (...,) μ used (1/λ̂max)


def vt_apply(x: jax.Array, r: jax.Array, degree: int, *,
             basis: str = basis_lib.MONOMIAL) -> jax.Array:
    """Matrix-free Vᵀ r over the last axis: out[k] = Σ_i basis_k(x_i)·r_i.

    Iterated multiply for monomials (the paper's CUDA trick), the
    three-term recurrence for Chebyshev — O(n·m) work, O(n) live memory,
    no (n, m+1) Vandermonde materialized."""
    if basis not in (basis_lib.MONOMIAL, basis_lib.CHEBYSHEV):
        raise ValueError(f"unknown basis {basis!r}")
    outs = [jnp.sum(r, axis=-1)]
    if degree >= 1:
        prev, cur = r, x * r
        outs.append(jnp.sum(cur, axis=-1))
        for _ in range(2, degree + 1):
            if basis == basis_lib.MONOMIAL:
                prev, cur = cur, x * cur
            else:
                prev, cur = cur, 2.0 * x * cur - prev
            outs.append(jnp.sum(cur, axis=-1))
    return jnp.stack(outs, axis=-1)


def _normal_op(x: jax.Array, w: jax.Array, c: jax.Array, degree: int,
               basis: str) -> jax.Array:
    """Matrix-free (VᵀWV)·c — evaluate then reduce, never the Gram."""
    f = basis_lib.evaluate(c, x, basis=basis)
    return vt_apply(x, w * f, degree, basis=basis)


def _power_iter(op, shape, dtype, iters: int,
                with_prev: bool = False):
    """Largest eigenvalue of the SPD operator ``op`` by power iteration.

    ``with_prev=True`` additionally returns the previous sweep's estimate
    — the caller's cheap settledness signal: a large relative gap between
    the last two iterates means the estimate is still climbing (clustered
    spectrum, or a start vector nearly orthogonal to the top eigenvector)
    and must not be trusted as λmax."""
    m1 = shape[-1]
    v0 = jnp.broadcast_to(jnp.ones(m1, dtype) / jnp.sqrt(jnp.asarray(
        m1, dtype)), shape)

    def body(_, carry):
        v, lam_prev, _ = carry
        av = op(v)
        lam = jnp.linalg.norm(av, axis=-1)
        safe = jnp.maximum(lam[..., None], jnp.finfo(dtype).tiny)
        return av / safe, lam, lam_prev

    _, lam, prev = jax.lax.fori_loop(
        0, iters, body, (v0, jnp.ones(shape[:-1], dtype),
                         jnp.ones(shape[:-1], dtype)))
    return (lam, prev) if with_prev else lam


def _lambda_max(x: jax.Array, w: jax.Array, degree: int, basis: str,
                iters: int, with_prev: bool = False):
    """Power-iteration λmax(VᵀWV) from V/Vᵀ passes only (batched)."""
    return _power_iter(lambda v: _normal_op(x, w, v, degree, basis),
                       x.shape[:-1] + (degree + 1,), x.dtype, iters,
                       with_prev)


def _trace_normal(x: jax.Array, w: jax.Array, degree: int,
                  basis: str) -> jax.Array:
    """Matrix-free trace(VᵀWV) = Σᵢ wᵢ Σₖ basisₖ(xᵢ)² — one O(n·m) pass
    with the same recurrences as ``vt_apply``, never forming the Gram.
    trace(A) ≥ λmax(A) for SPD A, so 1/trace is an always-convergent
    (if slow) Richardson step."""
    tr = jnp.sum(w, axis=-1)
    if degree >= 1:
        prev, cur = jnp.ones_like(x), x
        tr = tr + jnp.sum(w * cur * cur, axis=-1)
        for _ in range(2, degree + 1):
            if basis == basis_lib.MONOMIAL:
                prev, cur = cur, x * cur
            else:
                prev, cur = cur, 2.0 * x * cur - prev
            tr = tr + jnp.sum(w * cur * cur, axis=-1)
    return tr


def _gram_lambda_ub(gram: jax.Array) -> jax.Array:
    """Cheap guaranteed upper bound on λmax of the (batched) SPD Gram:
    min(trace, Gershgorin max-row-sum).  Both dominate λmax, so clamping
    the power-iteration estimate from below by half this bound keeps the
    Richardson step μ = 1/λ̂ strictly inside the convergent region
    μ·λmax < 2 even when 12 power sweeps under-estimated λmax on a
    clustered spectrum (the silent-divergence bug)."""
    tr = jnp.trace(gram, axis1=-2, axis2=-1)
    gersh = jnp.max(jnp.sum(jnp.abs(gram), axis=-1), axis=-1)
    return jnp.minimum(tr, gersh)


# relative gradient-norm growth beyond this is divergence, not a heavy-ball
# transient: the lane freezes at its last finite iterate and reports
# converged=False (finite coefficients are guaranteed — the fleet's
# non-finite quarantine must never fire from a mis-stepped LSPIA)
_DIVERGE_FACTOR = 1e6


def _condition_from_rate(rho: jax.Array, lam_mu: jax.Array) -> jax.Array:
    """Matrix-free κ̂(VᵀWV) from the iteration's own contraction rate.

    Richardson with step μ contracts the gradient by ρ = 1 − μ·λmin per
    sweep asymptotically, so κ = λmax/λmin = λmax·μ/(1 − ρ) — observed for
    free from the last two gradient norms, with no extra operator passes
    (a *shifted* power iteration for λmin is useless here: its top-two
    eigenvalue gap is λ2−λmin ≪ λmax, so it would need thousands of
    sweeps).  A LOWER bound when the run stopped before its asymptotic
    regime — early sweeps contract at mid-spectrum rates — so read it as
    "at least this ill-conditioned".  ρ ≥ 1 (no contraction: singular or
    mis-stepped) reports +inf, matching
    ``core.solve.condition_estimate``'s convention."""
    inf = jnp.asarray(jnp.inf, rho.dtype)
    denom = 1.0 - rho
    return jnp.where(denom > 0,
                     jnp.maximum(lam_mu / jnp.where(denom > 0, denom, 1.0),
                                 1.0),
                     inf)


@partial(jax.jit, static_argnames=("tol", "max_iter", "power_iters", "step",
                                   "momentum"))
def lspia_solve_moments(gram: jax.Array, vty: jax.Array, *,
                        tol: float = 1e-8,
                        max_iter: int = 5000,
                        power_iters: int = 12,
                        step: float | None = None,
                        momentum: float = 0.0):
    """LSPIA's fixed point computed from the O(m²) moment state alone.

    The matrix-free iteration ``c ← c + μ Vᵀ W (y − V c)`` is Richardson
    iteration on the normal equations, so on a surface that already HOLDS
    the accumulated Gram (streams, slot pools, psum'd shards — where the
    data is gone but A = VᵀWV and B = VᵀWy remain) the same fixed point is
    reachable without the data: ``c ← c + μ (B − A c)``.  This is what
    lets ``FitSpec(method="lspia")`` run on every execution surface —
    method choice orthogonal to execution strategy (arXiv:2211.06556) —
    at the cost of the property the eager path keeps (never forming A).

    Batched over leading axes of ``gram``/``vty``.  Returns
    ``(coeffs, condition, converged, iterations)``: ``condition`` is the
    contraction-rate κ̂ estimate (same convention as ``lspia_fit``),
    ``converged`` whether ‖B − Ac‖ ≤ tol·‖B‖ before ``max_iter``.  An
    all-zero state (idle serve slot) converges immediately to c = 0.

    ``momentum`` > 0 adds the PIA-with-memory heavy-ball term
    β·(cₖ − cₖ₋₁) (arXiv:1908.06417) — same fixed point, multiples fewer
    sweeps on moderately conditioned states.

    The step μ = 1/λ̂max is clamped from below by half the
    Gershgorin/trace upper bound on λmax (``_gram_lambda_ub``): a
    12-sweep power iteration under-estimates λmax on clustered spectra,
    and an unclamped 1/λ̂ then exceeds the Richardson stability bound
    2/λmax — the iteration diverged *silently*.  Post-clamp μ·λmax < 2
    always; should any lane still fail to contract (explicit user
    ``step``, marginal rank-1 states), it freezes at its last finite
    iterate and reports ``converged=False`` with finite coefficients."""
    dtype = gram.dtype
    mv = lambda c: jnp.einsum("...jk,...k->...j", gram, c)
    lam = _power_iter(mv, vty.shape, dtype, power_iters)
    lam_safe = jnp.maximum(lam, 0.5 * _gram_lambda_ub(gram))
    if step is None:
        mu = 1.0 / jnp.maximum(lam_safe, jnp.finfo(dtype).tiny)
    else:
        mu = jnp.full(vty.shape[:-1], step, dtype)
    beta = jnp.asarray(momentum, dtype)
    gref = jnp.maximum(jnp.linalg.norm(vty, axis=-1), jnp.finfo(dtype).tiny)
    tol = max(float(tol), 25.0 * float(jnp.finfo(dtype).eps))
    cap = _DIVERGE_FACTOR * gref
    c0 = jnp.zeros_like(vty)
    g0 = jnp.linalg.norm(vty - mv(c0), axis=-1)

    def cond_fn(carry):
        _, _, gnorm, _, it = carry
        live = (gnorm > tol * gref) & (gnorm <= cap) & jnp.isfinite(gnorm)
        return (it < max_iter) & jnp.any(live)

    def body_fn(carry):
        c, cp, gprev, _, it = carry
        g = vty - mv(c)
        gn = jnp.linalg.norm(g, axis=-1)
        ok = (jnp.isfinite(gn) & (gn <= cap))[..., None]
        upd = c + mu[..., None] * g + beta * (c - cp)
        return (jnp.where(ok, upd, c), jnp.where(ok, c, cp),
                gn, gprev, it + 1)

    init = (c0, c0, g0, jnp.full(vty.shape[:-1], jnp.inf, dtype),
            jnp.zeros((), jnp.int32))
    c, _, gnorm, gprev, it = jax.lax.while_loop(cond_fn, body_fn, init)
    converged = gnorm <= tol * gref
    # the freeze guard keeps iterates finite unless the INPUT state was
    # already non-finite; scrub that too — downstream quarantine logic
    # must be able to trust these coefficients
    finite = jnp.all(jnp.isfinite(c), axis=-1)
    c = jnp.where(finite[..., None], c, 0.0)
    converged = converged & finite
    rho = jnp.where(jnp.isfinite(gprev) & (gprev > 0),
                    gnorm / jnp.where(gprev > 0, gprev, 1.0), 0.0)
    cond = _condition_from_rate(rho, lam_safe * mu)
    return c, cond, converged, it


@partial(jax.jit, static_argnames=("spec",))
def lspia_fit_spec(x: jax.Array, y: jax.Array,
                   weights: jax.Array | None, init: jax.Array | None,
                   spec) -> LSPIAFit:
    """The matrix-free LSPIA engine, keyed on a ``FitSpec`` (method=
    "lspia").  ``lspia_fit`` is the legacy-signature shim over this; the
    eager ``api.fit`` executor calls it directly.

    Converges to the (weighted) least-squares polynomial without ever
    forming VᵀV — the path for degrees/precisions where the explicit
    normal equations are hopeless, and for data too large to want an
    O(m²)-state accumulation pass per solve.

    Stops when ‖Vᵀ W (y − Vc)‖ ≤ tol·‖Vᵀ W y‖ (relative normal-equation
    residual — exactly the LSE optimality condition) or at ``max_iter``.
    ``step=None`` estimates μ = 1/λmax by matrix-free power iteration;
    pass an explicit μ to skip those passes.  Batched over leading axes;
    the loop runs until every series converges.
    """
    degree = int(spec.degree)
    basis = spec.basis
    opts = spec.lspia
    tol, max_iter, power_iters = opts.tol, opts.max_iter, opts.power_iters
    step = opts.step
    plan = spec.plan(x.shape, x.dtype, weighted=weights is not None,
                     workload="lspia")
    dom = spec.domain_or(
        basis_lib.Domain.from_data(x) if plan.numerics.normalize
        else basis_lib.Domain.identity(x.dtype), dtype=x.dtype)
    xt = dom.apply(x)
    w = jnp.ones_like(x) if weights is None else weights
    if spec.decay < 1.0:
        from repro.core import moments as moments_lib
        w = w * moments_lib.decay_ladder(x.shape[-1], spec.decay, x.dtype)
    # spec.ridge shifts the fixed point to the Tikhonov solution, exactly
    # as the moment-space surfaces regularize the Gram: the iteration runs
    # on A + λI matrix-free (an extra −λc term), so one spec converges to
    # the same answer eagerly and from accumulated moments
    ridge = jnp.asarray(spec.ridge, x.dtype)

    lam, lam_prev = _lambda_max(xt, w, degree, basis, power_iters,
                                with_prev=True)
    lam = lam + ridge
    # matrix-free step safety: the power estimate is trusted only when its
    # last two sweeps agree (settled); otherwise — clustered spectrum, or a
    # start vector nearly orthogonal to the top eigenvector, the cases
    # where λ̂ under-estimates λmax and μ = 1/λ̂ silently diverges — fall
    # back to μ = 1/trace, which trace(A) ≥ λmax makes unconditionally
    # convergent (one extra O(n·m) pass, no Gram formed)
    tr_ub = (_trace_normal(xt, w, degree, basis)
             + ridge * jnp.asarray(degree + 1, x.dtype))
    settled = jnp.abs(lam - (lam_prev + ridge)) <= 0.05 * lam
    lam_safe = jnp.where(settled, lam, jnp.maximum(lam, tr_ub))
    if step is None:
        mu = 1.0 / jnp.maximum(lam_safe, jnp.finfo(x.dtype).tiny)
    else:
        mu = jnp.full(x.shape[:-1], step, x.dtype)
    beta = jnp.asarray(opts.momentum, x.dtype)

    gref = jnp.linalg.norm(vt_apply(xt, w * y, degree, basis=basis), axis=-1)
    gref = jnp.maximum(gref, jnp.finfo(x.dtype).tiny)
    # the gradient is recomputed from O(n) sums each step, so its relative
    # floor is ~eps·√n of gref — clamp tol there or f32 fits spin to
    # max_iter chasing an unreachable residual
    tol = max(float(tol), 25.0 * float(jnp.finfo(x.dtype).eps))
    cap = _DIVERGE_FACTOR * gref
    c0 = (jnp.zeros(x.shape[:-1] + (degree + 1,), x.dtype)
          if init is None else init)

    def cond_fn(carry):
        _, _, gnorm, _, it = carry
        live = (gnorm > tol * gref) & (gnorm <= cap) & jnp.isfinite(gnorm)
        return (it < max_iter) & jnp.any(live)

    def body_fn(carry):
        c, cp, gprev, _, it = carry
        f = basis_lib.evaluate(c, xt, basis=basis)
        g = vt_apply(xt, w * (y - f), degree, basis=basis) - ridge * c
        gn = jnp.linalg.norm(g, axis=-1)
        # divergence freeze: a lane whose gradient blew past the cap keeps
        # its last finite iterate and will report converged=False — never
        # non-finite coefficients
        ok = (jnp.isfinite(gn) & (gn <= cap))[..., None]
        upd = c + mu[..., None] * g + beta * (c - cp)
        return (jnp.where(ok, upd, c), jnp.where(ok, c, cp),
                gn, gprev, it + 1)

    init_carry = (c0, c0,
                  cap,  # finite "not yet measured" > tol·gref: lane is live
                  jnp.full(x.shape[:-1], jnp.inf, x.dtype),
                  jnp.zeros((), jnp.int32))
    c, _, gnorm, gprev, it = jax.lax.while_loop(cond_fn, body_fn, init_carry)
    converged = gnorm <= tol * gref
    finite = jnp.all(jnp.isfinite(c), axis=-1)
    c = jnp.where(finite[..., None], c, 0.0)
    converged = converged & finite
    # observed per-sweep contraction (last two gradient norms) → κ̂; a
    # single-sweep run has no ratio yet and reports the κ ≈ 1 it implies
    rho = jnp.where(jnp.isfinite(gprev) & (gprev > 0),
                    gnorm / jnp.where(gprev > 0, gprev, 1.0), 0.0)
    cond = _condition_from_rate(rho, lam_safe * mu)
    # diagnostics keep the no-silent-failure contract of the explicit
    # solvers: condition is the matrix-free κ̂ estimate, and fallback_used
    # doubles as the "iteration did NOT meet tol within max_iter" flag —
    # LSPIA has no rescue solver, so an unconverged result is exactly the
    # state a caller must not consume unexamined
    diag = fit_lib.FitDiagnostics(condition=cond,
                                  fallback_used=~converged,
                                  solver="lspia", fallback="none")
    poly = fit_lib.Polynomial(coeffs=c, domain_shift=dom.shift,
                              domain_scale=dom.scale, basis=basis,
                              diagnostics=diag)
    return LSPIAFit(poly=poly, iterations=it, converged=converged,
                    grad_norm=gnorm, step=mu)


def lspia_fit(x: jax.Array, y: jax.Array, degree: int, *,
              weights: jax.Array | None = None,
              basis: str = basis_lib.MONOMIAL,
              normalize: bool = True,
              tol: float = 1e-8,
              max_iter: int = 5000,
              power_iters: int = 12,
              step: float | None = None,
              momentum: float = 0.0,
              init: jax.Array | None = None,
              engine: str = "auto") -> LSPIAFit:
    """Gram-free iterative LSE fit with tolerance/max-iter control.

    Thin shim over the spec path: constructs ``FitSpec(method="lspia",
    lspia=LSPIAOptions(...))`` and runs ``lspia_fit_spec``.
    ``normalize=True`` (default: unlike ``polyfit``, LSPIA *needs* a
    bounded domain for its first-order convergence rate) maps the sample
    range to [-1, 1]."""
    from repro.api import spec as spec_lib
    from repro.engine import plan as plan_lib
    spec = spec_lib.FitSpec(
        degree=int(degree), basis=basis, method="lspia",
        lspia=spec_lib.LSPIAOptions(tol=float(tol), max_iter=int(max_iter),
                                    power_iters=int(power_iters),
                                    step=None if step is None
                                    else float(step),
                                    momentum=float(momentum)),
        numerics=plan_lib.NumericsPolicy(normalize=normalize,
                                         solver="auto"),
        engine=engine)
    return lspia_fit_spec(x, y, weights, init, spec)
