"""Polynomial bases and domain normalization for matricized LSE fitting.

The paper (Dasgupta 2015) works in the raw monomial basis ``1, x, x^2, ...``.
That is the *paper-faithful* path. Beyond the paper we add an affine domain
normalization (maps the sample range to [-1, 1]) and a Chebyshev basis option;
both dramatically improve the conditioning of the normal-equation Gram matrix
``A = V^T V`` for higher orders / wider domains while leaving the fitted
function mathematically unchanged.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

MONOMIAL = "monomial"
CHEBYSHEV = "chebyshev"
_BASES = (MONOMIAL, CHEBYSHEV)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Domain:
    """Affine map t = scale * (x - shift) applied before basis evaluation.

    ``identity()`` is the paper-faithful no-op domain.
    """

    shift: jax.Array  # scalar
    scale: jax.Array  # scalar

    @staticmethod
    def identity(dtype=jnp.float32) -> "Domain":
        return Domain(jnp.zeros((), dtype), jnp.ones((), dtype))

    @staticmethod
    def from_data(x: jax.Array) -> "Domain":
        """Map [min(x), max(x)] -> [-1, 1] (degenerate range -> identity scale)."""
        lo = jnp.min(x)
        hi = jnp.max(x)
        shift = (hi + lo) / 2.0
        half = (hi - lo) / 2.0
        scale = jnp.where(half > 0, 1.0 / jnp.where(half > 0, half, 1.0), 1.0)
        return Domain(shift.astype(x.dtype), scale.astype(x.dtype))

    def apply(self, x: jax.Array) -> jax.Array:
        return (x - self.shift) * self.scale


def vandermonde(x: jax.Array, degree: int, basis: str = MONOMIAL) -> jax.Array:
    """Design matrix V with shape ``x.shape + (degree + 1,)``.

    monomial:  V[..., k] = x^k           (paper's construction)
    chebyshev: V[..., k] = T_k(x)        (recurrence T_k = 2x T_{k-1} - T_{k-2})

    Powers are built by iterated multiplication, never ``pow`` — this is the
    same trick the paper's CUDA kernel uses and what the Pallas kernel mirrors.
    """
    if basis not in _BASES:
        raise ValueError(f"unknown basis {basis!r}; expected one of {_BASES}")
    if degree < 0:
        raise ValueError("degree must be >= 0")
    cols = [jnp.ones_like(x)]
    if degree >= 1:
        cols.append(x)
    if basis == MONOMIAL:
        for _ in range(2, degree + 1):
            cols.append(cols[-1] * x)
    else:
        for _ in range(2, degree + 1):
            cols.append(2.0 * x * cols[-1] - cols[-2])
    return jnp.stack(cols, axis=-1)


@partial(jax.jit, static_argnames=("degree", "basis"))
def evaluate(coeffs: jax.Array, x: jax.Array, *, degree: int | None = None,
             basis: str = MONOMIAL, domain: Domain | None = None) -> jax.Array:
    """Evaluate a fitted polynomial at x. coeffs[..., k] multiplies basis k.

    Horner's rule for monomials, Clenshaw's for Chebyshev — both O(m) with no
    explicit Vandermonde materialization (decode-path friendly).
    """
    deg = (coeffs.shape[-1] - 1) if degree is None else degree
    if domain is not None:
        x = domain.apply(x)
    # batched coeffs (..., m+1) broadcast against x (..., n) on a new axis
    c = ((lambda k: coeffs[..., k, None]) if coeffs.ndim > 1
         else (lambda k: coeffs[..., k]))
    if basis == MONOMIAL:
        acc = jnp.zeros_like(x) + c(deg)
        for k in range(deg - 1, -1, -1):
            acc = acc * x + c(k)
        return acc
    # Clenshaw for Chebyshev
    b1 = jnp.zeros_like(x)
    b2 = jnp.zeros_like(x)
    for k in range(deg, 0, -1):
        b1, b2 = 2.0 * x * b1 - b2 + c(k), b1
    return x * b1 - b2 + c(0)


def monomial_coeffs_from_domain(coeffs: jax.Array, domain: Domain,
                                degree: int) -> jax.Array:
    """Convert coefficients fitted on t = scale*(x-shift) (monomial basis) back
    to raw-x monomial coefficients, so normalized fits report paper-comparable
    coefficients. Pure host-side (small m), uses binomial expansion."""
    import numpy as np

    c = np.asarray(coeffs, dtype=np.float64)
    s = float(domain.scale)
    h = float(domain.shift)
    out = np.zeros(degree + 1, dtype=np.float64)
    # t^k = s^k (x - h)^k = s^k Σ_j C(k,j) x^j (-h)^{k-j}
    from math import comb

    for k in range(degree + 1):
        for j in range(k + 1):
            out[j] += c[k] * (s ** k) * comb(k, j) * ((-h) ** (k - j))
    return jnp.asarray(out, dtype=coeffs.dtype)
