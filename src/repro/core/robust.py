"""Robust polynomial fitting: IRLS with Huber/Tukey weights.

Least squares is maximally efficient on clean Gaussian noise and maximally
gullible on outliers — a single wild point at distance d pulls Σe² by d²,
so 20% contamination routinely moves low-order coefficients by orders of
magnitude.  ``robust_polyfit`` replaces the square loss with a bounded-
influence M-estimator and solves it by IRLS (iteratively reweighted least
squares): each iteration is *exactly* the paper's matricized weighted fit —
moments with per-point weights through ``repro.engine`` (packed Pallas
kernel on TPU, reference jnp elsewhere), condition-aware solve from
``core.solve`` — with weights recomputed from the standardized residuals.
The heavy O(n·m²) accumulation is therefore reused verbatim; robustness
costs ``iterations`` passes over the data and nothing else.

Weight functions (ψ(u)/u form, u = r/σ̂, σ̂ = 1.4826·MAD):

* ``huber``:  w = 1 for |u| ≤ c, c/|u| beyond — bounded influence,
  convex, always converges; c = 1.345 is the classic 95%-Gaussian-
  efficiency tuning.
* ``tukey`` (bisquare):  w = (1 - (u/c)²)² inside |u| < c, 0 beyond —
  redescending: gross outliers get *zero* weight; c = 4.685.

With zero contamination the weights converge to ~1 and IRLS reproduces the
plain LSE fit (a property the conformance suite pins down).

The IRLS engine itself (``irls_fit``) is keyed on a ``repro.api.FitSpec``
— the one description every execution surface consumes; ``robust_polyfit``
is the legacy-signature shim that constructs the spec.  The chunk-level
pieces (``robust_weights``, ``chunk_scale``) are shared with the streaming
and serving surfaces, whose single-pass IRLS reweights each incoming chunk
against the running fit.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import basis as basis_lib
from repro.core import fit as fit_lib
from repro.core import solve as solve_lib

HUBER = "huber"
TUKEY = "tukey"
# 95% asymptotic Gaussian efficiency tunings (Huber 1981; Beaton-Tukey)
DEFAULT_TUNING = {HUBER: 1.345, TUKEY: 4.685}
# runtime dispatch ids for surfaces that select the loss per slot/request
# from a traced array (the fit server's single compiled ingest step)
LOSS_IDS = {HUBER: 0, TUKEY: 1}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RobustFit:
    """An IRLS fit: the polynomial plus the iteration's own diagnostics."""

    poly: fit_lib.Polynomial
    iterations: jax.Array      # ()     IRLS iterations actually run
    converged: jax.Array       # (...,) coefficient change fell below tol
    scale: jax.Array           # (...,) final robust σ̂ (1.4826·MAD)


def resolve_tuning(loss: str, c: float | None) -> float:
    """The ψ tuning constant: the 95%-efficiency default unless forced."""
    if loss not in DEFAULT_TUNING:
        raise ValueError(f"unknown loss {loss!r}; expected {HUBER!r} or "
                         f"{TUKEY!r}")
    return float(DEFAULT_TUNING[loss] if c is None else c)


def robust_weights(u: jax.Array, loss: str, c: float) -> jax.Array:
    """ψ(u)/u weights of standardized residuals u for a static loss name."""
    if loss == HUBER:
        au = jnp.abs(u)
        return jnp.where(au <= c, 1.0, c / jnp.maximum(au, c))
    if loss == TUKEY:
        t = (u / c) ** 2
        return jnp.where(t < 1.0, (1.0 - t) ** 2, 0.0)
    raise ValueError(f"unknown loss {loss!r}; expected {HUBER!r} or {TUKEY!r}")


_robust_weights = robust_weights   # back-compat private alias


def robust_weights_by_id(u: jax.Array, loss_id: jax.Array,
                         c: jax.Array) -> jax.Array:
    """``robust_weights`` with the loss selected by a TRACED per-series id
    (``LOSS_IDS``) and per-series tuning ``c`` — both forms are computed
    and selected, so one compiled program serves any loss mix (the fit
    server's per-request robustness without recompiles)."""
    au = jnp.abs(u)
    huber = jnp.where(au <= c, 1.0, c / jnp.maximum(au, c))
    t = (u / jnp.maximum(c, jnp.finfo(u.dtype).tiny)) ** 2
    tukey = jnp.where(t < 1.0, (1.0 - t) ** 2, 0.0)
    return jnp.where(loss_id == LOSS_IDS[TUKEY], tukey, huber)


def chunk_scale(r: jax.Array, base_w: jax.Array,
                y: jax.Array) -> jax.Array:
    """Robust σ̂ (1.4826·MAD, floored) of one chunk of residuals.

    Shared by the streaming/serving single-pass IRLS surfaces: zero-weight
    points are excluded, all-masked series pin σ̂ to the floor (their
    moments are all-zero anyway), and the floor keeps u = r/σ̂ finite on
    near-exact fits — the same guards the eager IRLS loop applies."""
    eps = jnp.finfo(r.dtype).eps
    has_pts = jnp.any(base_w > 0, axis=-1, keepdims=True)
    y_mask = jnp.where(base_w > 0, jnp.abs(y), jnp.nan)
    y_med = jnp.nanmedian(y_mask, axis=-1, keepdims=True)
    floor = eps * (1.0 + jnp.where(has_pts, y_med, 0.0))
    ar = jnp.where(base_w > 0, jnp.abs(r), jnp.nan)
    mad = jnp.nanmedian(ar, axis=-1, keepdims=True)
    mad = jnp.where(has_pts, mad, 0.0)
    return jnp.maximum(1.4826 * mad, floor)


@partial(jax.jit, static_argnames=("spec",))
def irls_fit(x: jax.Array, y: jax.Array, weights: jax.Array | None,
             spec) -> tuple[RobustFit, jax.Array]:
    """The IRLS engine, keyed on a ``FitSpec`` (method="irls").

    Returns ``(RobustFit, final_weights)`` — the converged per-point
    robustness weights (robust ψ-weights × base weights) are what a
    DegreeSearch under robust loss feeds back into the weighted moment
    ladder.  Every sweep reuses the weighted moment path (same engine
    plan as any weighted LSE fit) and the condition-aware solver stack.
    """
    from repro import engine as engine_lib
    opts = spec.irls
    loss = opts.loss
    cval = resolve_tuning(loss, opts.c)
    degree = int(spec.degree)
    plan = spec.plan(x.shape, x.dtype, weighted=True)
    pol = plan.numerics
    dom = spec.domain_or(
        basis_lib.Domain.from_data(x) if pol.normalize
        else basis_lib.Domain.identity(x.dtype), dtype=x.dtype)
    xt = dom.apply(x)
    base_w = jnp.ones_like(x) if weights is None else weights
    if spec.decay < 1.0:
        from repro.core import moments as moments_lib
        base_w = base_w * moments_lib.decay_ladder(x.shape[-1], spec.decay,
                                                   x.dtype)

    def fit_with(w):
        m = engine_lib.compute_moments(plan, xt, y, w)
        if spec.ridge:
            m = m.regularized(spec.ridge)
        return solve_lib.solve_with_fallback(
            m.gram, m.vty, method=pol.solver, fallback=pol.fallback,
            cond_cap=pol.cond_cap)

    coeffs0, cond0, used0 = fit_with(base_w)
    eps = jnp.finfo(x.dtype).eps
    # near-exact fits leave residuals at roundoff scale, where the weights
    # flip between iterations on noise alone and the coefficients jitter at
    # ~100s of ulps forever — clamp tol above that floor or clean data
    # spins to max_iter
    tol = max(float(opts.tol), 500.0 * float(eps))

    def sigma_of(coeffs):
        r = y - basis_lib.evaluate(coeffs, xt, basis=spec.basis)
        return r, chunk_scale(r, base_w, y)

    big = jnp.asarray(jnp.inf, x.dtype)

    def cond_fn(carry):
        _, _, _, delta, it = carry
        return (it < opts.max_iter) & jnp.any(delta > tol)

    def body_fn(carry):
        coeffs, _, _, _, it = carry
        r, sigma = sigma_of(coeffs)
        w = robust_weights(r / sigma, loss, cval) * base_w
        new, cond, used = fit_with(w)
        scale = jnp.maximum(jnp.max(jnp.abs(new), axis=-1), 1.0)
        delta = jnp.max(jnp.abs(new - coeffs), axis=-1) / scale
        return new, cond, used, delta, it + 1

    init = (coeffs0, cond0, used0,
            jnp.full(x.shape[:-1], big), jnp.zeros((), jnp.int32))
    coeffs, cond, used, delta, it = jax.lax.while_loop(cond_fn, body_fn, init)
    r, sigma = sigma_of(coeffs)
    final_w = robust_weights(r / sigma, loss, cval) * base_w
    diag = fit_lib.FitDiagnostics(condition=cond, fallback_used=used,
                                  solver=pol.solver,
                                  fallback=pol.fallback or "none")
    poly = fit_lib.Polynomial(coeffs=coeffs, domain_shift=dom.shift,
                              domain_scale=dom.scale, basis=spec.basis,
                              diagnostics=diag)
    rfit = RobustFit(poly=poly, iterations=it, converged=delta <= tol,
                     scale=sigma[..., 0])
    return rfit, final_w


def robust_polyfit(x: jax.Array, y: jax.Array, degree: int, *,
                   weights: jax.Array | None = None,
                   loss: str = HUBER,
                   c: float | None = None,
                   max_iter: int = 30,
                   tol: float = 1e-6,
                   basis: str = basis_lib.MONOMIAL,
                   normalize: bool = False,
                   accum_dtype=None,
                   engine: str = "auto",
                   solver: str = "auto",
                   fallback: str | None = "svd") -> RobustFit:
    """IRLS M-estimator fit; drop-in robust sibling of ``core.polyfit``.

    Thin shim over the spec path: constructs
    ``FitSpec(method="irls", irls=IRLSOptions(...))`` and runs the same
    ``irls_fit`` engine every other surface uses.  Batched: x, y may carry
    leading batch axes; the loop runs until every series in the batch
    converges (or ``max_iter``).

    ``weights`` are *base* weights (padding masks, confidence): they
    multiply the robustness weights each iteration and zero-weight points
    are excluded from the MAD scale estimate.
    """
    from repro.api import spec as spec_lib
    from repro.engine import plan as plan_lib
    resolve_tuning(loss, c)        # validate loss/c eagerly
    spec = spec_lib.FitSpec(
        degree=int(degree), basis=basis, method="irls",
        irls=spec_lib.IRLSOptions(loss=loss, c=c, max_iter=int(max_iter),
                                  tol=float(tol)),
        numerics=plan_lib.NumericsPolicy(accum_dtype=accum_dtype,
                                         normalize=normalize, solver=solver,
                                         fallback=fallback),
        engine=engine)
    rfit, _ = irls_fit(x, y, weights, spec)
    return rfit
