"""Robust polynomial fitting: IRLS with Huber/Tukey weights.

Least squares is maximally efficient on clean Gaussian noise and maximally
gullible on outliers — a single wild point at distance d pulls Σe² by d²,
so 20% contamination routinely moves low-order coefficients by orders of
magnitude.  ``robust_polyfit`` replaces the square loss with a bounded-
influence M-estimator and solves it by IRLS (iteratively reweighted least
squares): each iteration is *exactly* the paper's matricized weighted fit —
moments with per-point weights through ``repro.engine`` (packed Pallas
kernel on TPU, reference jnp elsewhere), condition-aware solve from
``core.solve`` — with weights recomputed from the standardized residuals.
The heavy O(n·m²) accumulation is therefore reused verbatim; robustness
costs ``iterations`` passes over the data and nothing else.

Weight functions (ψ(u)/u form, u = r/σ̂, σ̂ = 1.4826·MAD):

* ``huber``:  w = 1 for |u| ≤ c, c/|u| beyond — bounded influence,
  convex, always converges; c = 1.345 is the classic 95%-Gaussian-
  efficiency tuning.
* ``tukey`` (bisquare):  w = (1 - (u/c)²)² inside |u| < c, 0 beyond —
  redescending: gross outliers get *zero* weight; c = 4.685.

With zero contamination the weights converge to ~1 and IRLS reproduces the
plain LSE fit (a property the conformance suite pins down).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import basis as basis_lib
from repro.core import fit as fit_lib
from repro.core import solve as solve_lib

HUBER = "huber"
TUKEY = "tukey"
# 95% asymptotic Gaussian efficiency tunings (Huber 1981; Beaton-Tukey)
DEFAULT_TUNING = {HUBER: 1.345, TUKEY: 4.685}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RobustFit:
    """An IRLS fit: the polynomial plus the iteration's own diagnostics."""

    poly: fit_lib.Polynomial
    iterations: jax.Array      # ()     IRLS iterations actually run
    converged: jax.Array       # (...,) coefficient change fell below tol
    scale: jax.Array           # (...,) final robust σ̂ (1.4826·MAD)


def _robust_weights(u: jax.Array, loss: str, c: float) -> jax.Array:
    if loss == HUBER:
        au = jnp.abs(u)
        return jnp.where(au <= c, 1.0, c / jnp.maximum(au, c))
    if loss == TUKEY:
        t = (u / c) ** 2
        return jnp.where(t < 1.0, (1.0 - t) ** 2, 0.0)
    raise ValueError(f"unknown loss {loss!r}; expected {HUBER!r} or {TUKEY!r}")


@partial(jax.jit, static_argnames=("degree", "loss", "c", "max_iter", "tol",
                                   "basis", "normalize", "accum_dtype",
                                   "engine", "solver", "fallback"))
def robust_polyfit(x: jax.Array, y: jax.Array, degree: int, *,
                   weights: jax.Array | None = None,
                   loss: str = HUBER,
                   c: float | None = None,
                   max_iter: int = 30,
                   tol: float = 1e-6,
                   basis: str = basis_lib.MONOMIAL,
                   normalize: bool = False,
                   accum_dtype=None,
                   engine: str = "auto",
                   solver: str = "auto",
                   fallback: str | None = "svd") -> RobustFit:
    """IRLS M-estimator fit; drop-in robust sibling of ``core.polyfit``.

    Every IRLS step reuses the weighted moment path (``weights=`` ride the
    same engine plan — kernel or reference — as any weighted LSE fit) and
    the condition-aware solver stack, so the robustness loop inherits both
    the performance and the numerical guards of the plain fit.  Batched:
    x, y may carry leading batch axes; the loop runs until every series in
    the batch converges (or ``max_iter``).

    ``weights`` are *base* weights (padding masks, confidence): they
    multiply the robustness weights each iteration and zero-weight points
    are excluded from the MAD scale estimate.
    """
    from repro import engine as engine_lib
    cval = float(DEFAULT_TUNING[loss] if c is None else c)
    _robust_weights(jnp.zeros(()), loss, cval)   # validate loss eagerly
    plan = engine_lib.plan_fit(
        x.shape, degree, basis=basis, dtype=x.dtype, weighted=True,
        engine=engine, accum_dtype=accum_dtype, normalize=normalize,
        solver=solver, fallback=fallback)
    pol = plan.numerics
    dom = (basis_lib.Domain.from_data(x) if pol.normalize
           else basis_lib.Domain.identity(x.dtype))
    xt = dom.apply(x)
    base_w = jnp.ones_like(x) if weights is None else weights

    def fit_with(w):
        m = engine_lib.compute_moments(plan, xt, y, w)
        return solve_lib.solve_with_fallback(
            m.gram, m.vty, method=pol.solver, fallback=pol.fallback,
            cond_cap=pol.cond_cap)

    coeffs0, cond0, used0 = fit_with(base_w)
    eps = jnp.finfo(x.dtype).eps
    # near-exact fits leave residuals at roundoff scale, where the weights
    # flip between iterations on noise alone and the coefficients jitter at
    # ~100s of ulps forever — clamp tol above that floor or clean data
    # spins to max_iter
    tol = max(float(tol), 500.0 * float(eps))
    # scale floor: exact fits drive MAD → 0; keep σ̂ away from 0 so u = r/σ̂
    # stays finite (the weights then go ≈ indicator, which is harmless on
    # residuals at roundoff level).  Series whose base weights are ALL zero
    # (fully padded slots) have no residuals to take a median of — nanmedian
    # would return NaN and poison every later sweep, so pin their σ̂ to the
    # floor instead; their moments are all-zero anyway and the solve's
    # rescue returns the flagged finite minimum-norm fit.
    has_pts = jnp.any(base_w > 0, axis=-1, keepdims=True)
    y_mask = jnp.where(base_w > 0, jnp.abs(y), jnp.nan)
    y_med = jnp.nanmedian(y_mask, axis=-1, keepdims=True)
    floor = eps * (1.0 + jnp.where(has_pts, y_med, 0.0))

    def sigma_of(coeffs):
        r = y - basis_lib.evaluate(coeffs, xt, basis=basis)
        ar = jnp.where(base_w > 0, jnp.abs(r), jnp.nan)
        mad = jnp.nanmedian(ar, axis=-1, keepdims=True)
        mad = jnp.where(has_pts, mad, 0.0)
        return r, jnp.maximum(1.4826 * mad, floor)

    big = jnp.asarray(jnp.inf, x.dtype)

    def cond_fn(carry):
        _, _, _, delta, it = carry
        return (it < max_iter) & jnp.any(delta > tol)

    def body_fn(carry):
        coeffs, _, _, _, it = carry
        r, sigma = sigma_of(coeffs)
        w = _robust_weights(r / sigma, loss, cval) * base_w
        new, cond, used = fit_with(w)
        scale = jnp.maximum(jnp.max(jnp.abs(new), axis=-1), 1.0)
        delta = jnp.max(jnp.abs(new - coeffs), axis=-1) / scale
        return new, cond, used, delta, it + 1

    init = (coeffs0, cond0, used0,
            jnp.full(x.shape[:-1], big), jnp.zeros((), jnp.int32))
    coeffs, cond, used, delta, it = jax.lax.while_loop(cond_fn, body_fn, init)
    _, sigma = sigma_of(coeffs)
    diag = fit_lib.FitDiagnostics(condition=cond, fallback_used=used,
                                  solver=pol.solver,
                                  fallback=pol.fallback or "none")
    poly = fit_lib.Polynomial(coeffs=coeffs, domain_shift=dom.shift,
                              domain_scale=dom.scale, basis=basis,
                              diagnostics=diag)
    return RobustFit(poly=poly, iterations=it, converged=delta <= tol,
                     scale=sigma[..., 0])
