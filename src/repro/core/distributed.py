"""Distributed matricized LSE fitting — the paper's parallelization, pod-scale.

The paper parallelizes moment accumulation across CUDA threads on one GPU.
Here the same additive structure is mapped onto a TPU pod mesh with
``jax.shard_map``: every device accumulates the Gram/moment partials of its
local data shard, a single ``psum`` of O(m²) floats combines them across all
data axes (including the cross-pod ``"pod"`` axis — DCN traffic is ~(m+1)²
floats TOTAL, independent of n), and the tiny (m+1) solve runs replicated.

This module is mesh-agnostic: pass the axis names that partition the data.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import basis as basis_lib
from repro.core import fit as fit_lib
from repro.core import moments as moments_lib

try:  # jax >= 0.4.38 top-level export with the renamed replication check
    _shard_map = jax.shard_map
    _CHECK_KW = {"check_vma": False}
except AttributeError:  # 0.4.37: experimental home, check_rep spelling
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = {"check_rep": False}


def local_moments(x: jax.Array, y: jax.Array, degree: int, *,
                  basis: str = basis_lib.MONOMIAL,
                  weights: jax.Array | None = None,
                  accum_dtype=None,
                  engine: str = "auto",
                  use_kernel: bool | None = None) -> moments_lib.Moments:
    """Per-shard moment accumulation (runs inside shard_map).

    Routes through ``repro.engine.plan_fit``, which validates the basis on
    kernel paths — forcing the kernel with a non-monomial basis raises here
    instead of silently fitting the wrong rows (the Pallas kernel only
    builds monomial powers)."""
    from repro import engine as engine_lib
    plan = engine_lib.plan_fit(
        x.shape, degree, basis=basis, dtype=x.dtype,
        weighted=weights is not None,
        engine=engine_lib.resolve_engine(engine, use_kernel),
        accum_dtype=accum_dtype)
    return engine_lib.compute_moments(plan, x, y, weights)


def psum_moments(m: moments_lib.Moments, axis_names) -> moments_lib.Moments:
    """The one collective of the whole algorithm: O(m²) bytes."""
    return jax.tree.map(lambda a: jax.lax.psum(a, axis_names), m)


def _global_domain(x: jax.Array, w: jax.Array,
                   data_axes) -> basis_lib.Domain:
    """Global [-1, 1] domain over all shards (weighted min/max + pmin/pmax
    — the second tiny collective of a normalized distributed fit).
    Zero-weight entries are excluded; a degenerate zero range keeps the
    identity scale."""
    big = jnp.asarray(jnp.finfo(x.dtype).max, x.dtype)
    lo = jax.lax.pmin(jnp.min(jnp.where(w > 0, x, big)), data_axes)
    hi = jax.lax.pmax(jnp.max(jnp.where(w > 0, x, -big)), data_axes)
    shift = (hi + lo) / 2.0
    half = (hi - lo) / 2.0
    scale = jnp.where(half > 0, 1.0 / jnp.where(half > 0, half, 1.0), 1.0)
    return basis_lib.Domain(shift, scale)


def make_distributed_fit(mesh: jax.sharding.Mesh, degree: int, *,
                         data_axes: tuple[str, ...] = ("data",),
                         method: str | None = None,
                         solver: str = "auto",
                         fallback: str | None = "svd",
                         basis: str = basis_lib.MONOMIAL,
                         normalize: bool = False,
                         accum_dtype=jnp.float32,
                         engine: str = "auto",
                         use_kernel: bool | None = None):
    """Build a jitted distributed fit: (x, y, weights) -> Polynomial.

    x, y, weights are globally sharded over ``data_axes``; weights masks
    padding (ragged global datasets). Polynomial comes out fully replicated.

    normalize=True computes the global min/max first (second tiny collective)
    and fits in the normalized domain — the hardened beyond-paper mode.

    ``engine`` selects each shard's local accumulation path through
    ``repro.engine.plan_fit`` (validated up front, before any tracing);
    ``use_kernel`` is a deprecated alias.  ``solver``/``fallback`` pick the
    replicated normal-equation solve the same way ``core.polyfit`` does
    (condition-aware GE → Cholesky → QR → SVD; the psum'd Gram feeds the
    runtime κ estimate, so the fallback decision is identical on every
    device — no divergence).  ``method=`` is the legacy spelling of
    ``solver=``.
    """
    from repro import engine as engine_lib
    engine = engine_lib.resolve_engine(engine, use_kernel)
    if method is not None:
        solver = method
    # eager validation + a describable plan for logs: per-shard n is not
    # known yet, so plan with a placeholder length (path choice is re-made
    # per shard inside local_moments with the real shard shape).  The
    # numerics policy (solver rung, auto-normalization escalation) IS
    # resolved here, once, from the static facts.
    plan = engine_lib.plan_fit((1,), degree, basis=basis, engine=engine,
                               dtype=accum_dtype or jnp.float32,
                               accum_dtype=accum_dtype, normalize=normalize,
                               solver=solver, fallback=fallback,
                               mesh=mesh, data_axes=data_axes)
    pol = plan.numerics
    normalize = pol.normalize
    spec_in = P(data_axes)
    spec_rep = P()

    # check_vma/check_rep=False: pallas_call out_shapes don't carry
    # replication annotations
    @partial(_shard_map, mesh=mesh,
             in_specs=(spec_in, spec_in, spec_in),
             out_specs=(spec_rep, spec_rep), **_CHECK_KW)
    def _fit_shard(x, y, w):
        dom = (_global_domain(x, w, data_axes) if normalize
               else basis_lib.Domain.identity(x.dtype))
        xt = dom.apply(x)
        m = local_moments(xt, y, degree, basis=basis, weights=w,
                          accum_dtype=accum_dtype, engine=engine)
        m = psum_moments(m, data_axes)
        poly = fit_lib.fit_from_moments(m, solver=pol.solver,
                                        fallback=pol.fallback,
                                        cond_cap=pol.cond_cap, domain=dom,
                                        basis=basis,
                                        normalized=pol.normalize)
        return poly, m

    def fit(x: jax.Array, y: jax.Array, weights: jax.Array | None = None):
        if weights is None:
            weights = jnp.ones_like(x)
        return _fit_shard(x, y, weights)

    return jax.jit(fit)


def make_distributed_select(mesh: jax.sharding.Mesh, max_degree: int, *,
                            folds: int = 5,
                            data_axes: tuple[str, ...] = ("data",),
                            criterion: str | None = None,
                            solver: str = "auto",
                            fallback: str | None = "svd",
                            cond_cap: float | None = None,
                            basis: str = basis_lib.MONOMIAL,
                            normalize: bool = False,
                            accum_dtype=jnp.float32,
                            engine: str = "auto"):
    """Mesh-parallel single-pass degree selection: (x, y, weights) ->
    (poly, sweep, best_degree), all fully replicated.

    Each shard accumulates its local k-fold moment partials (round-robin
    within the shard — fold membership is an arbitrary partition, so local
    assignment is a valid global one) and ONE psum of the (k, m+1, m+1)
    fold stack makes the folds global: selection's collective cost is
    O(k·m²) floats, independent of n, the same additivity argument as the
    distributed fit.  The ladder solve + scoring then run replicated on
    every device, so the chosen degree is identical mesh-wide with no
    extra synchronization.  ``folds < 2`` drops CV (one plain psum'd
    state; AICc/BIC/GCV still select).

    ``poly`` is the winning fit in the zero-padded (max_degree+1) layout
    (the chosen degree is data-dependent, hence not a static shape) and —
    like ``make_distributed_fit`` — carries its Domain, so evaluating it
    on raw x is correct even when normalization (explicit or the plan's
    auto-escalation at high max degrees) mapped the fit to [-1, 1];
    ``sweep.coeffs`` live in that same fitted domain/basis.
    """
    from repro import engine as engine_lib
    from repro import select as select_lib
    from repro.select import crossval
    if criterion is None:
        criterion = "cv" if folds >= 2 else "aicc"
    if criterion == "cv" and folds < 2:
        raise ValueError("criterion='cv' needs folds >= 2")
    # eager validation at the max candidate degree (per-shard n unknown;
    # path choice re-made per shard, numerics resolved once — same pattern
    # as make_distributed_fit)
    plan = engine_lib.plan_fit(
        (max(folds, 1), 1), max_degree, basis=basis, engine=engine,
        dtype=accum_dtype or jnp.float32, accum_dtype=accum_dtype,
        normalize=normalize, solver=solver, fallback=fallback,
        cond_cap=cond_cap, mesh=mesh, data_axes=data_axes,
        workload="select")
    pol = plan.numerics
    spec_in = P(data_axes)
    spec_rep = P()

    @partial(_shard_map, mesh=mesh,
             in_specs=(spec_in, spec_in, spec_in),
             out_specs=(spec_rep, spec_rep, spec_rep), **_CHECK_KW)
    def _select_shard(x, y, w):
        dom = (_global_domain(x, w, data_axes) if pol.normalize
               else basis_lib.Domain.identity(x.dtype))
        xt = dom.apply(x)
        if folds >= 2:
            fm = crossval.fold_moments(xt, y, folds, max_degree, weights=w,
                                       basis=basis, engine=engine,
                                       accum_dtype=accum_dtype)
            fm = psum_moments(fm, data_axes)   # folds made global: O(k·m²)
            total = crossval.sum_folds(fm)
        else:
            fm = None
            total = psum_moments(
                local_moments(xt, y, max_degree, basis=basis, weights=w,
                              accum_dtype=accum_dtype, engine=engine),
                data_axes)
        sweep = select_lib.sweep_from_moments(
            total, fold_moments=fm, solver=solver, fallback=fallback,
            cond_cap=cond_cap, basis=basis, normalized=pol.normalize)
        best = sweep.best(criterion)
        # winning fit in the padded ladder layout (best is traced, so the
        # static-shape slice of selection_from_sweep is unavailable) —
        # crucially WITH its Domain, so raw-x evaluation is correct
        diag = fit_lib.FitDiagnostics(
            condition=jnp.take(sweep.condition, best, axis=-1),
            fallback_used=jnp.take(sweep.fallback_used, best, axis=-1),
            solver=solver, fallback=fallback or "none")
        poly = fit_lib.Polynomial(
            coeffs=jnp.take(sweep.coeffs, best, axis=-2),
            domain_shift=dom.shift, domain_scale=dom.scale, basis=basis,
            diagnostics=diag)
        return poly, sweep, best

    def sel(x: jax.Array, y: jax.Array, weights: jax.Array | None = None):
        if weights is None:
            weights = jnp.ones_like(x)
        return _select_shard(x, y, weights)

    return jax.jit(sel)


def distributed_fit_input_specs(n_global: int, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins for the dry-run of the fit itself."""
    s = jax.ShapeDtypeStruct((n_global,), dtype)
    return dict(x=s, y=s, weights=s)
