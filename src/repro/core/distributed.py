"""Distributed matricized LSE fitting — the paper's parallelization, pod-scale.

The paper parallelizes moment accumulation across CUDA threads on one GPU.
Here the same additive structure is mapped onto a TPU pod mesh with
``jax.shard_map``: every device accumulates the Gram/moment partials of its
local data shard, a single ``psum`` of O(m²) floats combines them across all
data axes (including the cross-pod ``"pod"`` axis — DCN traffic is ~(m+1)²
floats TOTAL, independent of n), and the tiny (m+1) solve runs replicated.

This module is mesh-agnostic: pass the axis names that partition the data.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import basis as basis_lib
from repro.core import fit as fit_lib
from repro.core import moments as moments_lib


def local_moments(x: jax.Array, y: jax.Array, degree: int, *,
                  basis: str = basis_lib.MONOMIAL,
                  weights: jax.Array | None = None,
                  accum_dtype=None,
                  use_kernel: bool = False) -> moments_lib.Moments:
    """Per-shard moment accumulation (runs inside shard_map)."""
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.moments(x, y, degree, weights=weights,
                                  accum_dtype=accum_dtype)
    return moments_lib.gram_moments(x, y, degree, basis=basis,
                                    weights=weights, accum_dtype=accum_dtype)


def psum_moments(m: moments_lib.Moments, axis_names) -> moments_lib.Moments:
    """The one collective of the whole algorithm: O(m²) bytes."""
    return jax.tree.map(lambda a: jax.lax.psum(a, axis_names), m)


def make_distributed_fit(mesh: jax.sharding.Mesh, degree: int, *,
                         data_axes: tuple[str, ...] = ("data",),
                         method: str = "gauss",
                         basis: str = basis_lib.MONOMIAL,
                         normalize: bool = False,
                         accum_dtype=jnp.float32,
                         use_kernel: bool = False):
    """Build a jitted distributed fit: (x, y, weights) -> Polynomial.

    x, y, weights are globally sharded over ``data_axes``; weights masks
    padding (ragged global datasets). Polynomial comes out fully replicated.

    normalize=True computes the global min/max first (second tiny collective)
    and fits in the normalized domain — the hardened beyond-paper mode.
    """
    spec_in = P(data_axes)
    spec_rep = P()

    # check_vma=False: pallas_call out_shapes don't carry vma annotations
    @partial(jax.shard_map, mesh=mesh,
             in_specs=(spec_in, spec_in, spec_in),
             out_specs=(spec_rep, spec_rep), check_vma=False)
    def _fit_shard(x, y, w):
        if normalize:
            big = jnp.asarray(jnp.finfo(x.dtype).max, x.dtype)
            lo = jax.lax.pmin(jnp.min(jnp.where(w > 0, x, big)), data_axes)
            hi = jax.lax.pmax(jnp.max(jnp.where(w > 0, x, -big)), data_axes)
            shift = (hi + lo) / 2.0
            half = (hi - lo) / 2.0
            scale = jnp.where(half > 0, 1.0 / jnp.where(half > 0, half, 1.0), 1.0)
            dom = basis_lib.Domain(shift, scale)
        else:
            dom = basis_lib.Domain.identity(x.dtype)
        xt = dom.apply(x)
        m = local_moments(xt, y, degree, basis=basis, weights=w,
                          accum_dtype=accum_dtype, use_kernel=use_kernel)
        m = psum_moments(m, data_axes)
        poly = fit_lib.fit_from_moments(m, method=method, domain=dom,
                                        basis=basis)
        return poly, m

    def fit(x: jax.Array, y: jax.Array, weights: jax.Array | None = None):
        if weights is None:
            weights = jnp.ones_like(x)
        return _fit_shard(x, y, weights)

    return jax.jit(fit)


def distributed_fit_input_specs(n_global: int, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins for the dry-run of the fit itself."""
    s = jax.ShapeDtypeStruct((n_global,), dtype)
    return dict(x=s, y=s, weights=s)
