"""Distributed matricized LSE fitting — the paper's parallelization, pod-scale.

The paper parallelizes moment accumulation across CUDA threads on one GPU.
Here the same additive structure is mapped onto a TPU pod mesh with
``jax.shard_map``: every device accumulates the Gram/moment partials of its
local data shard, a single ``psum`` of O(m²) floats combines them across all
data axes (including the cross-pod ``"pod"`` axis — DCN traffic is ~(m+1)²
floats TOTAL, independent of n), and the tiny (m+1) solve runs replicated.

``make_spec_executor`` is the one factory: it consumes a ``repro.api``
``FitSpec`` and builds the jitted shard_map program for ANY method ×
degree question — plain LSE, IRLS (the reweighting loop runs the psum
inside ``while_loop``; every sweep is one O(m²) collective), moment-space
LSPIA (Richardson on the psum'd normal equations), and single-pass degree
search (one O(k·m²) fold-stack psum) — with weights/decay/NumericsPolicy
riding in from the spec.  ``make_distributed_fit`` / ``make_distributed_-
select`` are the legacy-signature shims that construct the spec.

This module is mesh-agnostic: pass the axis names that partition the data.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import basis as basis_lib
from repro.core import fit as fit_lib
from repro.core import moments as moments_lib
from repro.core import solve as solve_lib

try:  # jax >= 0.4.38 top-level export with the renamed replication check
    _shard_map = jax.shard_map
    _CHECK_KW = {"check_vma": False}
except AttributeError:  # 0.4.37: experimental home, check_rep spelling
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = {"check_rep": False}


def local_moments(x: jax.Array, y: jax.Array, degree: int, *,
                  basis: str = basis_lib.MONOMIAL,
                  weights: jax.Array | None = None,
                  accum_dtype=None,
                  engine: str = "auto",
                  use_kernel: bool | None = None) -> moments_lib.Moments:
    """Per-shard moment accumulation (runs inside shard_map).

    Routes through ``repro.engine.plan_fit``, which validates the basis on
    kernel paths — forcing the kernel with a non-monomial basis raises here
    instead of silently fitting the wrong rows (the Pallas kernel only
    builds monomial powers)."""
    from repro import engine as engine_lib
    plan = engine_lib.plan_fit(
        x.shape, degree, basis=basis, dtype=x.dtype,
        weighted=weights is not None,
        engine=engine_lib.resolve_engine(engine, use_kernel),
        accum_dtype=accum_dtype)
    return engine_lib.compute_moments(plan, x, y, weights)


def psum_moments(m: moments_lib.Moments, axis_names) -> moments_lib.Moments:
    """The one collective of the whole algorithm: O(m²) bytes."""
    return jax.tree.map(lambda a: jax.lax.psum(a, axis_names), m)


def _global_domain(x: jax.Array, w: jax.Array,
                   data_axes) -> basis_lib.Domain:
    """Global [-1, 1] domain over all shards (weighted min/max + pmin/pmax
    — the second tiny collective of a normalized distributed fit).
    Zero-weight entries are excluded; a degenerate zero range keeps the
    identity scale."""
    big = jnp.asarray(jnp.finfo(x.dtype).max, x.dtype)
    lo = jax.lax.pmin(jnp.min(jnp.where(w > 0, x, big)), data_axes)
    hi = jax.lax.pmax(jnp.max(jnp.where(w > 0, x, -big)), data_axes)
    shift = (hi + lo) / 2.0
    half = (hi - lo) / 2.0
    scale = jnp.where(half > 0, 1.0 / jnp.where(half > 0, half, 1.0), 1.0)
    return basis_lib.Domain(shift, scale)


# --------------------------------------------------------------------------
# the spec executor: every method × degree question, one shard_map factory
# --------------------------------------------------------------------------
def make_spec_executor(spec, mesh: jax.sharding.Mesh, *,
                       data_axes: tuple[str, ...] = ("data",)):
    """Build the jitted mesh program for a ``FitSpec``.

    Returns ``(runner, kind)``: ``runner(x, y, weights)`` takes globally
    sharded inputs and returns fully replicated outputs whose shape
    ``kind`` names —

    * ``"fixed"``:  ``(poly, moments)``                (method="lse")
    * ``"iter"``:   ``(poly, moments, iters, conv)``   (irls / lspia)
    * ``"search"``: ``(poly, sweep, best_degree)``     (DegreeSearch)

    ``repro.api.make_distributed`` wraps the tuple into a ``FitResult``;
    the legacy ``make_distributed_fit``/``_select`` shims return it raw.
    """
    from repro import select as select_lib
    from repro.core import robust as robust_lib
    from repro.select import crossval

    from repro.api import spec as spec_lib
    if spec.numerics.solver in spec_lib.RAW_DATA_SOLVERS:
        raise ValueError(
            f"solver={spec.numerics.solver!r} needs the raw Vandermonde "
            "rows and cannot run on the distributed moment surface; use "
            "the eager api.fit executor")
    search = spec.is_search
    md = spec.max_degree
    folds = spec.folds if search else 0
    accum = spec.numerics.accum_dtype
    # eager validation + numerics resolution (per-shard n is unknown, so
    # plan with a placeholder length: the path choice is re-made per shard
    # inside local_moments; the numerics policy IS resolved here, once)
    plan = spec.plan((max(folds, 1), 1) if search else (1,),
                     accum or jnp.float32, weighted=True,
                     workload="select" if search else "moments",
                     mesh=mesh, data_axes=data_axes)
    pol = plan.numerics
    normalized = pol.normalize or spec.domain is not None
    if search:
        ds = spec.degree
        criterion = ds.criterion
        if criterion is None:
            criterion = "cv" if folds >= 2 else "aicc"
        if criterion == "cv" and folds < 2:
            raise ValueError("criterion='cv' needs folds >= 2")
        ladder_solver = (spec.numerics.solver
                         if spec.numerics.solver != "auto" else ds.solver)
        ladder_fb, ladder_cap = ds.fallback, ds.cond_cap
    spec_in = P(data_axes)
    spec_rep = P()

    def shard_domain(x, w):
        pinned = spec.domain_or(None, dtype=x.dtype)
        if pinned is not None:
            return pinned
        if pol.normalize:
            return _global_domain(x, w, data_axes)
        return basis_lib.Domain.identity(x.dtype)

    devices_total = 1
    for ax in data_axes:
        devices_total *= mesh.shape[ax]

    def apply_decay(x, w):
        """spec.decay as the GLOBAL age ladder: each shard reconstructs
        its points' global positions from its mesh coordinates (shards of
        a P(data_axes)-sharded array are laid out row-major over the data
        axes), so the γ-weighting is identical to the eager surface's
        ``decay_ladder`` over the unsharded series."""
        if spec.decay == 1.0:
            return w
        pos = 0
        for ax in data_axes:
            pos = pos * mesh.shape[ax] + jax.lax.axis_index(ax)
        n_local = x.shape[-1]
        n_global = n_local * devices_total
        age = (n_global - 1
               - (pos * n_local + jnp.arange(n_local)).astype(x.dtype))
        return w * jnp.asarray(spec.decay, x.dtype) ** age

    def gmoments(xt, y, w):
        """One global accumulation: local shard moments + the psum."""
        return psum_moments(
            local_moments(xt, y, md, basis=spec.basis, weights=w,
                          accum_dtype=accum, engine=spec.engine),
            data_axes)

    def solve(m):
        ms = m.regularized(spec.ridge) if spec.ridge else m
        return solve_lib.solve_with_fallback(
            ms.gram, ms.vty, method=pol.solver, fallback=pol.fallback,
            cond_cap=pol.cond_cap)

    def mk_poly(coeffs, dom, diag):
        return fit_lib.Polynomial(coeffs=coeffs, domain_shift=dom.shift,
                                  domain_scale=dom.scale, basis=spec.basis,
                                  diagnostics=diag)

    def irls_weights_loop(xt, y, w):
        """The IRLS loop, mesh-wide: every sweep is one O(m²) psum; the
        convergence test runs on the replicated coefficients, so every
        device takes the same trip count.  The robust scale is the
        contributing-shard mean of per-shard MADs (an exact global median
        would need its own iterative collective; on shuffled shards the
        shard MADs agree to O(1/√n_shard))."""
        opts = spec.irls
        cval = robust_lib.resolve_tuning(opts.loss, opts.c)
        tol = max(float(opts.tol),
                  500.0 * float(jnp.finfo(xt.dtype).eps))

        def sigma_of(coeffs):
            r = y - basis_lib.evaluate(coeffs, xt, basis=spec.basis)
            sig = robust_lib.chunk_scale(r, w, y)[..., 0]
            has = jnp.any(w > 0).astype(xt.dtype)
            num = jax.lax.psum(sig * has, data_axes)
            den = jnp.maximum(jax.lax.psum(has, data_axes), 1.0)
            return r, (num / den)[..., None]

        def reweight(coeffs):
            r, sigma = sigma_of(coeffs)
            return robust_lib.robust_weights(r / sigma, opts.loss, cval) * w

        m0 = gmoments(xt, y, w)
        coeffs0, cond0, used0 = solve(m0)
        big = jnp.asarray(jnp.inf, xt.dtype)

        def cond_fn(carry):
            _, _, _, _, delta, it = carry
            return (it < opts.max_iter) & jnp.any(delta > tol)

        def body_fn(carry):
            coeffs, _, _, _, _, it = carry
            m = gmoments(xt, y, reweight(coeffs))
            new, cond, used = solve(m)
            scale = jnp.maximum(jnp.max(jnp.abs(new), axis=-1), 1.0)
            delta = jnp.max(jnp.abs(new - coeffs), axis=-1) / scale
            return new, cond, used, m, delta, it + 1

        init = (coeffs0, cond0, used0, m0,
                jnp.full(xt.shape[:-1], big), jnp.zeros((), jnp.int32))
        coeffs, cond, used, m, delta, it = jax.lax.while_loop(
            cond_fn, body_fn, init)
        return coeffs, cond, used, m, reweight(coeffs), delta <= tol, it

    # ------------------------------------------------------------ programs
    if search:
        @partial(_shard_map, mesh=mesh,
                 in_specs=(spec_in, spec_in, spec_in),
                 out_specs=(spec_rep, spec_rep, spec_rep), **_CHECK_KW)
        def _run(x, y, w):
            w = apply_decay(x, w)
            dom = shard_domain(x, w)
            xt = dom.apply(x)
            if spec.method == "irls":
                # robust weights established mesh-wide at max_degree, then
                # the usual single-pass weighted ladder on top of them
                _, _, _, _, w_eff, _, _ = irls_weights_loop(xt, y, w)
            else:
                w_eff = w
            if folds >= 2:
                fm = crossval.fold_moments(xt, y, folds, md, weights=w_eff,
                                           basis=spec.basis,
                                           engine=spec.engine,
                                           accum_dtype=accum)
                fm = psum_moments(fm, data_axes)  # folds global: O(k·m²)
                total = crossval.sum_folds(fm)
            else:
                fm = None
                total = gmoments(xt, y, w_eff)
            mr = total.regularized(spec.ridge) if spec.ridge else total
            sweep = select_lib.sweep_from_moments(
                mr, fold_moments=fm,
                score_moments=total if spec.ridge else None,
                solver=ladder_solver,
                fallback=ladder_fb, cond_cap=ladder_cap, basis=spec.basis,
                normalized=normalized)
            best = sweep.best(criterion)
            # winning fit in the padded ladder layout (best is traced, so
            # the static-shape slice of selection_from_sweep is
            # unavailable) — crucially WITH its Domain, so raw-x
            # evaluation is correct
            diag = fit_lib.FitDiagnostics(
                condition=jnp.take(sweep.condition, best, axis=-1),
                fallback_used=jnp.take(sweep.fallback_used, best, axis=-1),
                solver=ladder_solver, fallback=ladder_fb or "none")
            poly = mk_poly(jnp.take(sweep.coeffs, best, axis=-2), dom, diag)
            return poly, sweep, best

    elif spec.method == "irls":
        @partial(_shard_map, mesh=mesh,
                 in_specs=(spec_in, spec_in, spec_in),
                 out_specs=(spec_rep, spec_rep, spec_rep, spec_rep),
                 **_CHECK_KW)
        def _run(x, y, w):
            w = apply_decay(x, w)
            dom = shard_domain(x, w)
            xt = dom.apply(x)
            coeffs, cond, used, m, _, conv, it = irls_weights_loop(xt, y, w)
            diag = fit_lib.FitDiagnostics(
                condition=cond, fallback_used=used, solver=pol.solver,
                fallback=pol.fallback or "none")
            return mk_poly(coeffs, dom, diag), m, it, conv

    elif spec.method == "lspia":
        from repro.core import lspia as lspia_lib

        @partial(_shard_map, mesh=mesh,
                 in_specs=(spec_in, spec_in, spec_in),
                 out_specs=(spec_rep, spec_rep, spec_rep, spec_rep),
                 **_CHECK_KW)
        def _run(x, y, w):
            # the distributed surface already pays the O(m²) psum, so the
            # fixed point is reached by Richardson on the psum'd normal
            # equations (the moment-space LSPIA) — matrix-free sweeps
            # would cost one collective per iteration instead of one total
            w = apply_decay(x, w)
            dom = shard_domain(x, w)
            xt = dom.apply(x)
            m = gmoments(xt, y, w)
            ms = m.regularized(spec.ridge) if spec.ridge else m
            opts = spec.lspia
            coeffs, cond, conv, it = lspia_lib.lspia_solve_moments(
                ms.gram, ms.vty, tol=opts.tol, max_iter=opts.max_iter,
                power_iters=opts.power_iters, step=opts.step,
                momentum=opts.momentum)
            diag = fit_lib.FitDiagnostics(condition=cond,
                                          fallback_used=~conv,
                                          solver="lspia", fallback="none")
            return mk_poly(coeffs, dom, diag), m, it, conv

    else:
        # plain matricized LSE — the paper's algorithm, pod-scale
        @partial(_shard_map, mesh=mesh,
                 in_specs=(spec_in, spec_in, spec_in),
                 out_specs=(spec_rep, spec_rep), **_CHECK_KW)
        def _run(x, y, w):
            w = apply_decay(x, w)
            dom = shard_domain(x, w)
            xt = dom.apply(x)
            m = gmoments(xt, y, w)
            ms = m.regularized(spec.ridge) if spec.ridge else m
            poly = fit_lib.fit_from_moments(ms, solver=pol.solver,
                                            fallback=pol.fallback,
                                            cond_cap=pol.cond_cap,
                                            domain=dom, basis=spec.basis,
                                            normalized=normalized)
            return poly, m

    def entry(x: jax.Array, y: jax.Array, weights: jax.Array | None = None):
        if weights is None:
            weights = jnp.ones_like(x)
        return _run(x, y, weights)

    kind = ("search" if search
            else "iter" if spec.method in ("irls", "lspia") else "fixed")
    return jax.jit(entry), kind


# --------------------------------------------------------------------------
# legacy-signature shims — construct a FitSpec, run the spec executor
# --------------------------------------------------------------------------
def make_distributed_fit(mesh: jax.sharding.Mesh, degree: int, *,
                         data_axes: tuple[str, ...] = ("data",),
                         method: str | None = None,
                         solver: str = "auto",
                         fallback: str | None = "svd",
                         basis: str = basis_lib.MONOMIAL,
                         normalize: bool = False,
                         accum_dtype=jnp.float32,
                         engine: str = "auto",
                         use_kernel: bool | None = None):
    """Build a jitted distributed fit: (x, y, weights) -> (Polynomial,
    Moments).  Thin shim over ``make_spec_executor`` — the kwargs
    assemble a ``FitSpec(method="lse")``.

    x, y, weights are globally sharded over ``data_axes``; weights masks
    padding (ragged global datasets). Polynomial comes out fully replicated.
    normalize=True computes the global min/max first (second tiny collective)
    and fits in the normalized domain.  ``use_kernel`` is a deprecated
    alias of ``engine=``; ``method=`` the legacy spelling of ``solver=``.
    """
    from repro import engine as engine_lib
    from repro.api import spec as spec_lib
    from repro.engine import plan as plan_lib
    engine = engine_lib.resolve_engine(engine, use_kernel)
    if method is not None:
        solver = method
    spec = spec_lib.FitSpec(
        degree=int(degree), basis=basis, method="lse",
        numerics=plan_lib.NumericsPolicy(accum_dtype=accum_dtype,
                                         normalize=normalize, solver=solver,
                                         fallback=fallback),
        engine=engine)
    runner, _ = make_spec_executor(spec, mesh, data_axes=data_axes)
    return runner


def make_distributed_select(mesh: jax.sharding.Mesh, max_degree: int, *,
                            folds: int = 5,
                            data_axes: tuple[str, ...] = ("data",),
                            criterion: str | None = None,
                            solver: str = "auto",
                            fallback: str | None = "svd",
                            cond_cap: float | None = None,
                            basis: str = basis_lib.MONOMIAL,
                            normalize: bool = False,
                            accum_dtype=jnp.float32,
                            engine: str = "auto"):
    """Mesh-parallel single-pass degree selection: (x, y, weights) ->
    (poly, sweep, best_degree), all fully replicated.  Thin shim over
    ``make_spec_executor`` — the kwargs assemble a
    ``FitSpec(degree=DegreeSearch(...))``.

    Each shard accumulates its local k-fold moment partials (round-robin
    within the shard — fold membership is an arbitrary partition, so local
    assignment is a valid global one) and ONE psum of the (k, m+1, m+1)
    fold stack makes the folds global: selection's collective cost is
    O(k·m²) floats, independent of n.  The ladder solve + scoring then run
    replicated on every device, so the chosen degree is identical
    mesh-wide with no extra synchronization.  ``folds < 2`` drops CV (one
    plain psum'd state; AICc/BIC/GCV still select).

    ``poly`` is the winning fit in the zero-padded (max_degree+1) layout
    (the chosen degree is data-dependent, hence not a static shape) and
    carries its Domain, so evaluating it on raw x is correct even when
    normalization mapped the fit to [-1, 1]; ``sweep.coeffs`` live in that
    same fitted domain/basis.
    """
    from repro import select as select_lib
    from repro.api import spec as spec_lib
    from repro.engine import plan as plan_lib
    spec = spec_lib.FitSpec(
        degree=select_lib.DegreeSearch(max_degree=int(max_degree),
                                       folds=int(folds),
                                       criterion=criterion, solver=solver,
                                       fallback=fallback,
                                       cond_cap=cond_cap),
        basis=basis, method="lse",
        numerics=plan_lib.NumericsPolicy(accum_dtype=accum_dtype,
                                         normalize=normalize,
                                         solver="auto", fallback=fallback,
                                         cond_cap=cond_cap),
        engine=engine)
    runner, _ = make_spec_executor(spec, mesh, data_axes=data_axes)
    return runner


def distributed_fit_input_specs(n_global: int, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins for the dry-run of the fit itself."""
    s = jax.ShapeDtypeStruct((n_global,), dtype)
    return dict(x=s, y=s, weights=s)


# --------------------------------------------------------------------------
# asynchronous LSPIA: barrier-free shard contributions (arXiv:2211.06556)
# --------------------------------------------------------------------------
#
# The shard_map executor above is a BARRIER program: every Richardson sweep
# waits for the slowest shard's psum.  The asynchronous-LSPIA result says it
# does not have to — gradient contributions computed against *stale*
# coefficient versions still drive the iteration to the same least-squares
# fixed point as long as the staleness is bounded.  This section realizes
# that on the fleet's virtual-tick mailbox substrate: one coordinator, N
# ``AsyncLSPIAShard`` workers (each wrappable by ``runtime.chaos``'s
# ``ChaosWorker`` — same protocol as ``serve.fleet``'s workers), per-shard
# sequence numbers for idempotent delivery, and a staleness window outside
# which a shard's delta is rejected and recomputed.  A chaos-stalled shard
# therefore delays CONVERGENCE (its contribution is missing until it
# catches up) but never blocks the coordinator's updates — the property
# the synchronous psum program cannot have.


@dataclasses.dataclass
class ShardSweep:
    """Coordinator → shard: "compute your normal-equation gradient against
    these version-``version`` coefficients".  ``seq`` is the per-shard
    sequence number (idempotent delivery: the coordinator accepts exactly
    one reply per outstanding seq).  ``kind="ingest"`` so the chaos
    injector's drop fault hits sweeps exactly as it hits fleet ingests."""

    shard: int
    seq: int
    version: int
    coeffs: np.ndarray
    kind: str = "ingest"


@dataclasses.dataclass
class ShardDelta:
    """Shard → coordinator: gᵢ = VᵢᵀWᵢ(yᵢ − Vᵢ c_version), stamped with
    the coefficient version it was computed against.  ``kind="result"``
    so the chaos poison fault can corrupt it (and the coordinator's
    finite-validation must catch that)."""

    shard: int
    seq: int
    version: int
    delta: np.ndarray
    worker: int = 0
    kind: str = "result"

    def poisoned(self) -> "ShardDelta":
        return dataclasses.replace(
            self, delta=np.full_like(self.delta, np.nan))


@partial(jax.jit, static_argnames=("degree", "basis"))
def _shard_gradient(xt, y, w, c, degree, basis):
    from repro.core import lspia as lspia_lib
    f = basis_lib.evaluate(c, xt, basis=basis)
    return lspia_lib.vt_apply(xt, w * (y - f), degree, basis=basis)


class AsyncLSPIAShard:
    """One data shard speaking the fleet mailbox protocol (``process(msg,
    tick) -> [reply]`` / ``reset()``), so ``runtime.chaos.ChaosWorker``
    wraps it unchanged.  Stateless between sweeps — the shard's partition
    IS its identity — so a chaos crash + revive loses nothing but the
    in-flight sweep (which the coordinator's retry resends)."""

    def __init__(self, shard_id: int, xt, y, w, degree: int, basis: str):
        self.shard_id = shard_id
        self._xt, self._y, self._w = xt, y, w
        self._degree, self._basis = degree, basis
        self.sweeps_done = 0

    def reset(self) -> None:
        self.sweeps_done = 0

    def process(self, msg, tick: int) -> list:
        if getattr(msg, "kind", None) != "ingest":
            return []
        c = jnp.asarray(msg.coeffs, self._xt.dtype)
        g = _shard_gradient(self._xt, self._y, self._w, c,
                            self._degree, self._basis)
        self.sweeps_done += 1
        return [ShardDelta(shard=self.shard_id, seq=msg.seq,
                           version=msg.version, delta=np.asarray(g),
                           worker=self.shard_id)]


@dataclasses.dataclass
class AsyncLSPIAFit:
    """An asynchronous LSPIA fit: polynomial + the coordinator's record.

    ``iterations`` counts coefficient versions applied (the async analogue
    of sweeps); ``stats`` surfaces every fault-path event — stale
    rejections, poisoned deltas, resends, straggler verdicts and the
    ``runtime.straggler`` reslice plan they imply, and crucially
    ``updates_during_stall``: coordinator updates applied while at least
    one shard was chaos-stalled (the no-global-barrier property, > 0 in
    any stalled run that converged)."""

    poly: fit_lib.Polynomial
    iterations: int
    ticks: int
    converged: bool
    grad_norm: float
    step: float
    stats: dict
    metrics: object | None = None   # the run's obs.MetricsRegistry


def async_lspia_fit(x, y, spec, *, n_shards: int = 4,
                    weights=None, chaos=None,
                    work_per_tick: int = 1,
                    max_ticks: int = 200_000,
                    retry_ticks: int = 8,
                    restart_ticks: int = 8,
                    straggler_every: int = 4,
                    straggler_threshold: float = 3.0,
                    registry=None) -> AsyncLSPIAFit:
    """Barrier-free distributed LSPIA on the virtual-tick mailbox substrate.

    ``spec`` must be ``FitSpec(method="lspia")``; its ``LSPIAOptions``
    supply tol / max-iteration budget / ``momentum`` (heavy-ball on the
    coordinator's updates) and ``staleness`` — the bounded-delay window of
    the asynchronous convergence result: a delta computed more than
    ``staleness`` coefficient versions ago is rejected (and excluded from
    the accumulated gradient until its shard refreshes), and convergence
    is only declared when the combined gradient is small AND every shard's
    contribution is within the window.  The coordinator's step is the
    synchronous safe step damped by the staleness bound
    (μ = μ_sync / (1 + s/2), the classic delayed-gradient stability
    margin), with the same divergence freeze guard as the eager path.

    ``chaos`` takes a ``runtime.chaos.ChaosSchedule``; every fault kind
    applies (sweeps are droppable "ingest"s, deltas poisonable "result"s,
    shards stall/crash/delay like fleet workers).  Straggler verdicts come
    from ``runtime.fault_tolerance.FailureDetector`` — the paper's own LSE
    fitting per-shard reply gaps — and each verdict is answered with a
    ``runtime.straggler.plan_reslice`` share plan in ``stats["reslice"]``.

    Requires ``spec.decay == 1.0``: asynchronous delivery has no global
    age order, so exponential forgetting is not defined on this surface.
    """
    from repro.core import lspia as lspia_lib
    from repro.runtime import chaos as chaos_lib
    from repro.runtime import straggler as straggler_lib
    from repro.runtime.fault_tolerance import FailureDetector

    if spec.method != "lspia":
        raise ValueError(f"async_lspia_fit needs method='lspia', got "
                         f"{spec.method!r}")
    if spec.is_search:
        raise ValueError("async_lspia_fit serves fixed degrees; run "
                         "DegreeSearch on the moment surfaces")
    if spec.decay != 1.0:
        raise ValueError(
            "async delivery has no global age order: decay must be 1.0 "
            f"(got {spec.decay})")
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    if x.ndim != 1 or x.shape != y.shape:
        raise ValueError(f"expected equal 1-D x/y, got {x.shape} vs "
                         f"{y.shape}")
    if x.shape[0] < n_shards:
        raise ValueError(f"{x.shape[0]} points cannot fill {n_shards} "
                         "shards")
    degree = int(spec.degree)
    basis = spec.basis
    opts = spec.lspia
    staleness = int(opts.staleness)
    beta = float(opts.momentum)
    ridge = float(spec.ridge)
    w = (jnp.ones_like(x) if weights is None
         else jnp.asarray(weights, x.dtype))
    plan = spec.plan(x.shape, x.dtype, weighted=weights is not None,
                     workload="lspia")
    dom = spec.domain_or(
        basis_lib.Domain.from_data(x) if plan.numerics.normalize
        else basis_lib.Domain.identity(x.dtype), dtype=x.dtype)
    xt = dom.apply(x)

    # safe synchronous step (same settledness-gated trace clamp as the
    # eager path), then the bounded-delay damping
    tiny = float(jnp.finfo(x.dtype).tiny)
    lam, lam_prev = lspia_lib._lambda_max(xt, w, degree, basis,
                                          opts.power_iters, with_prev=True)
    lam = float(lam) + ridge
    tr_ub = float(lspia_lib._trace_normal(xt, w, degree, basis)) \
        + ridge * (degree + 1)
    settled = abs(lam - (float(lam_prev) + ridge)) <= 0.05 * lam
    lam_safe = lam if settled else max(lam, tr_ub)
    mu_sync = (1.0 / max(lam_safe, tiny) if opts.step is None
               else float(opts.step))
    mu = mu_sync / (1.0 + 0.5 * staleness)

    bvec = np.asarray(lspia_lib.vt_apply(xt, w * y, degree, basis=basis),
                      # reprolint: disable=RL-DTYPE — f64 LSPIA iterate
                      np.float64)
    gref = max(float(np.linalg.norm(bvec)), tiny)
    tol = max(float(opts.tol), 25.0 * float(jnp.finfo(x.dtype).eps))
    cap = lspia_lib._DIVERGE_FACTOR * gref

    bounds = np.linspace(0, x.shape[0], n_shards + 1).astype(int)
    schedule = chaos or chaos_lib.ChaosSchedule()
    workers = [
        chaos_lib.ChaosWorker(
            AsyncLSPIAShard(i, xt[bounds[i]:bounds[i + 1]],
                            y[bounds[i]:bounds[i + 1]],
                            w[bounds[i]:bounds[i + 1]], degree, basis),
            i, schedule.for_worker(i))
        for i in range(n_shards)]
    detector = FailureDetector(n_shards, timeout_s=float(max_ticks),
                               straggler_threshold=straggler_threshold)

    m1 = degree + 1
    c = np.zeros(m1, np.float64)  # reprolint: disable=RL-DTYPE — f64 iterate
    c_prev = c.copy()
    version = 0
    latest: list[np.ndarray | None] = [None] * n_shards
    latest_version = [-1] * n_shards
    next_seq = [0] * n_shards
    # outstanding[i] = (seq, sent_tick) of the sweep awaiting a reply
    outstanding: list[tuple[int, int] | None] = [None] * n_shards
    inbox: list[list] = [[] for _ in range(n_shards)]
    due: list[tuple[int, int, ShardDelta]] = []
    due_n = 0
    last_reply = [0] * n_shards
    died_at: dict[int, int] = {}
    gnorm = gref
    gprev = float("inf")
    # counters live in an obs registry (caller-supplied to share one
    # scrape surface, else private); the returned ``stats`` dict is a
    # view over it plus the non-counter records below
    from repro.obs import metrics as obs_metrics
    reg = registry if registry is not None else obs_metrics.MetricsRegistry()
    ctr = {k: reg.counter(k) for k in
           ("updates", "updates_during_stall", "stale_rejected",
            "poisoned", "resends", "duplicates", "crashes", "freezes")}
    lag_gauge = reg.gauge("staleness_lag")   # hwm = worst in-window lag
    straggler_verdicts: list = []
    reslice = None
    converged = False
    tick = 0

    def send_sweep(i: int) -> None:
        if len(inbox[i]) >= 4:      # bounded mailbox: a stalled shard's
            return                  # queue must not grow without limit
        next_seq[i] += 1
        outstanding[i] = (next_seq[i], tick)
        inbox[i].append(ShardSweep(shard=i, seq=next_seq[i],
                                   version=version, coeffs=c.copy()))

    while tick < max_ticks and not converged:
        tick += 1
        for i, wk in enumerate(workers):
            wk.begin_tick(tick)
            if not wk.alive and i not in died_at:
                died_at[i] = tick
                ctr["crashes"].inc()
            if not wk.alive and tick - died_at.get(i, tick) >= \
                    restart_ticks:
                wk.revive()
                inbox[i].clear()
                outstanding[i] = None
                del died_at[i]
        stalled_now = any(wk.stalled(tick) for wk in workers)
        # pump shard mailboxes (a stalled shard heartbeats but computes
        # nothing — its inbox just waits)
        for i, wk in enumerate(workers):
            if not wk.alive or wk.stalled(tick):
                continue
            for _ in range(work_per_tick):
                if not inbox[i]:
                    break
                msg = inbox[i].pop(0)
                for delay, rep in wk.process(msg, tick):
                    due.append((tick + delay, due_n, rep))
                    due_n += 1
        # deliver due replies
        due.sort()
        fresh = False
        while due and due[0][0] <= tick:
            _, _, rep = due.pop(0)
            i = rep.shard
            out = outstanding[i]
            if out is None or rep.seq != out[0]:
                ctr["duplicates"].inc()
                continue
            outstanding[i] = None
            last_reply[i] = tick
            if not np.all(np.isfinite(rep.delta)):
                ctr["poisoned"].inc()       # chaos poison: recompute
                continue
            if version - rep.version > staleness:
                ctr["stale_rejected"].inc()     # outside the bounded-
                continue                        # delay window: recompute
            # reprolint: disable=RL-DTYPE — deltas join the f64 iterate
            latest[i] = np.asarray(rep.delta, np.float64)
            latest_version[i] = rep.version
            fresh = True
        # staleness-bounded accumulation: only in-window contributions
        # enter the combined gradient (a stalled shard's ancient delta
        # must not keep steering the iterate)
        in_window = [i for i in range(n_shards)
                     if latest[i] is not None
                     and version - latest_version[i] <= staleness]
        # worst version lag among contributing shards (hwm = worst seen):
        # the live "how stale is the slowest voice in the gradient" gauge
        if in_window:
            lag_gauge.set(max(version - latest_version[i]
                              for i in in_window))
        if fresh and in_window:
            gsum = sum(latest[i] for i in in_window) - ridge * c
            gn = float(np.linalg.norm(gsum))
            if not np.isfinite(gn) or gn > cap:
                ctr["freezes"].inc()    # divergence freeze, as eager
            else:
                upd = c + mu * gsum + beta * (c - c_prev)
                c_prev, c = c, upd
                version += 1
                gprev, gnorm = gnorm, gn
                ctr["updates"].inc()
                if stalled_now:
                    ctr["updates_during_stall"].inc()
        # convergence: small combined gradient AND every shard current
        if (len(in_window) == n_shards and gnorm <= tol * gref
                and ctr["updates"].value > 0):
            converged = True
            break
        # refill / retry sweeps
        for i in range(n_shards):
            out = outstanding[i]
            if out is None:
                send_sweep(i)
            elif tick - out[1] > retry_ticks:
                ctr["resends"].inc()    # dropped/lost sweep: resend with
                send_sweep(i)           # a fresh seq (old reply ignored)
        # straggler verdicts from the paper's own LSE on reply gaps
        if tick % straggler_every == 0:
            gaps = [float(max(1, tick - last_reply[i]))
                    for i in range(n_shards)]
            detector.observe_step(tick // straggler_every, gaps,
                                  now=float(tick))
            v = detector.verdict(tick // straggler_every, now=float(tick))
            if v["stragglers"]:
                straggler_verdicts.append(
                    (tick, tuple(v["stragglers"])))
                try:
                    reslice = straggler_lib.plan_reslice(
                        detector.steptime, tick // straggler_every,
                        int(x.shape[0]), min_share=1).shares
                except ValueError:
                    pass

    if ctr["updates"].value >= 2 and gprev > 0 and np.isfinite(gprev):
        rho = gnorm / gprev
    else:
        rho = 0.0
    lam_mu = lam_safe * mu
    cond = (float("inf") if rho >= 1.0
            else max(lam_mu / (1.0 - rho), 1.0))
    stats = {"n_shards": n_shards, "staleness": staleness,
             **{k: c.value for k, c in ctr.items()},
             "straggler_verdicts": straggler_verdicts, "reslice": reslice,
             "sweeps_per_shard": [wk.inner.sweeps_done for wk in workers]}
    dtype = x.dtype
    diag = fit_lib.FitDiagnostics(
        condition=jnp.asarray(cond, dtype),
        fallback_used=jnp.asarray(not converged),
        solver="lspia", fallback="none")
    poly = fit_lib.Polynomial(coeffs=jnp.asarray(c, dtype),
                              domain_shift=dom.shift,
                              domain_scale=dom.scale, basis=basis,
                              diagnostics=diag)
    return AsyncLSPIAFit(poly=poly, iterations=version, ticks=tick,
                         converged=converged, grad_norm=gnorm, step=mu,
                         stats=stats, metrics=reg)
