"""Streaming LSE fitting with O(1) state — additive moments over time.

Because the paper's sufficient statistics (power sums / Gram) are additive,
a fit over an unbounded stream needs only the running ``Moments`` — no history
buffer. This is what lets the training loop fit its own loss curve every step
for free (``repro.train.monitors``) and what an online-serving statistics
service would keep per series.

Includes an exponential-forgetting variant (decay γ) so monitors track the
*recent* trend — the fit solves the γ-weighted least-squares problem exactly.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import basis as basis_lib
from repro.core import fit as fit_lib
from repro.core import moments as moments_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StreamState:
    """Running moments (+ optional k-fold partials for online selection).

    ``cv_folds > 0`` at creation adds per-fold partial moments (leading
    fold axis) maintained for free: each incoming chunk's moments are
    computed once and folded into BOTH the total and one fold, assigned
    round-robin per chunk (``fold_index``).  That is what lets
    ``current_selection()`` run moment-space k-fold CV over the whole
    degree ladder at any time with zero re-reads of the stream."""

    moments: moments_lib.Moments
    decay: jax.Array  # scalar in (0, 1]; 1.0 = plain accumulation
    fold_moments: moments_lib.Moments | None = None  # (k, ...batch) partials
    fold_index: jax.Array | None = None              # next fold, round-robin

    @staticmethod
    def create(degree: int, batch: tuple[int, ...] = (), *, decay: float = 1.0,
               dtype=jnp.float32, cv_folds: int = 0) -> "StreamState":
        folds = (moments_lib.Moments.zeros(degree, (cv_folds,) + batch, dtype)
                 if cv_folds >= 2 else None)
        idx = jnp.zeros((), jnp.int32) if cv_folds >= 2 else None
        return StreamState(moments_lib.Moments.zeros(degree, batch, dtype),
                           jnp.asarray(decay, dtype), folds, idx)

    def current_selection(self, *, criterion: str | None = None,
                          ridge: float = 0.0, solver: str = "auto",
                          fallback: str | None = "svd",
                          basis: str = basis_lib.MONOMIAL):
        """The running best degree (and the whole scored ladder) so far.

        Solves the degree ladder 0..degree on the accumulated O(m²) state
        — AIC/AICc/BIC/GCV always, k-fold CV when the state was created
        with ``cv_folds`` — and returns a ``repro.select.Selection``.
        ``criterion`` defaults to "cv" when folds exist, else "aicc".
        O(m²)-state work only: cost independent of how much data has
        streamed past."""
        from repro import select as select_lib
        m = self.moments.regularized(ridge) if ridge else self.moments
        if criterion is None:
            criterion = "cv" if self.fold_moments is not None else "aicc"
        if criterion == "cv" and self.fold_moments is None:
            raise ValueError("criterion='cv' needs StreamState.create(..., "
                             "cv_folds=k)")
        sweep = select_lib.sweep_from_moments(
            m, fold_moments=self.fold_moments,
            score_moments=self.moments if ridge else None, solver=solver,
            fallback=fallback, basis=basis)
        return select_lib.selection_from_sweep(sweep, criterion, basis=basis,
                                               solver=solver,
                                               fallback=fallback)


@partial(jax.jit, static_argnames=("basis", "engine", "use_kernel"))
def update(state: StreamState, x: jax.Array, y: jax.Array, *,
           weights: jax.Array | None = None,
           basis: str = basis_lib.MONOMIAL,
           engine: str = "auto",
           use_kernel: bool | None = None) -> StreamState:
    """Fold a new chunk (..., n) into the running moments.

    With decay γ, previous weighted mass is multiplied by γ**n_new, giving
    exact exponentially-weighted least squares (newest point has weight 1).
    ``count`` is exempt from decay: it keeps the true number of contributing
    points ever folded in, identically on every engine path, so kernel- and
    jnp-produced states mix freely (the solve itself never reads count).

    ``engine`` picks the accumulation path via ``repro.engine.plan_fit``
    ("auto" = reference off-TPU, packed Pallas kernel for batched streams on
    TPU); ``use_kernel`` is a deprecated alias."""
    from repro import engine as engine_lib
    degree = state.moments.degree
    w = _decay_weights(state, x, weights)
    plan = engine_lib.plan_fit(
        x.shape, degree, basis=basis, dtype=x.dtype, weighted=True,
        engine=engine_lib.resolve_engine(engine, use_kernel),
        accum_dtype=state.moments.gram.dtype)
    new = engine_lib.compute_moments(plan, x, y, w)
    new = jax.tree.map(lambda a, ref: a.astype(ref.dtype),
                       new, state.moments)
    # count from the USER weights only: γ^age underflows to exactly 0 in
    # f32 past age ~700, and compute_moments counts nonzero combined
    # weights — decay must never make a point "not contribute" to count
    cdt = new.count.dtype
    true_count = (jnp.full(x.shape[:-1], x.shape[-1], cdt) if weights is None
                  else jnp.sum((weights != 0), axis=-1).astype(cdt))
    new = dataclasses.replace(
        new, count=jnp.broadcast_to(true_count, new.count.shape))
    n_new = jnp.asarray(x.shape[-1], state.decay.dtype)
    g = state.decay ** n_new
    m = state.moments
    old = dataclasses.replace(
        jax.tree.map(lambda a: a * g, m), count=m.count)
    if state.fold_moments is None:
        return StreamState(old + new, state.decay)
    # the chunk's moments are already in hand — fold them into one fold
    # partial as well (round-robin per chunk): the k-fold CV state costs
    # zero extra passes.  Decay applies to fold partials exactly as to the
    # total (count exempt, as above).
    k = state.fold_moments.gram.shape[0]
    folds_old = dataclasses.replace(
        jax.tree.map(lambda a: a * g, state.fold_moments),
        count=state.fold_moments.count)
    idx = state.fold_index % k
    folds = jax.tree.map(lambda f, a: f.at[idx].add(a), folds_old, new)
    return StreamState(old + new, state.decay, folds, state.fold_index + 1)


def _decay_weights(state: StreamState, x: jax.Array,
                   weights: jax.Array | None) -> jax.Array | None:
    n = x.shape[-1]
    # newest point gets γ⁰, oldest in chunk γ^{n-1} (γ=1 → all ones)
    w = state.decay ** jnp.arange(n - 1, -1, -1, dtype=x.dtype)
    w = jnp.broadcast_to(w, x.shape)
    return w if weights is None else w * weights


@partial(jax.jit, static_argnames=("method", "ridge", "solver", "fallback"))
def current_fit(state: StreamState, *, method: str | None = None,
                solver: str = "auto", fallback: str | None = "svd",
                ridge: float = 0.0) -> fit_lib.Polynomial:
    """Solve the running normal equations. ridge>0 adds λI (stabilizes early,
    nearly-singular states — e.g. fewer points seen than coefficients).

    ``solver``/``fallback`` select the condition-aware solve
    (``core.fit.fit_from_moments``): the returned ``Polynomial.diagnostics``
    carries the running state's κ(Gram) and whether the rank-revealing
    rescue fired — the monitor-friendly health signal for a stream going
    degenerate.  ``method=`` is the legacy spelling of ``solver=``."""
    m = state.moments
    if ridge:
        m = m.regularized(ridge)
    return fit_lib.fit_from_moments(m, method=method, solver=solver,
                                    fallback=fallback)


def current_sse(state: StreamState, poly: fit_lib.Polynomial) -> jax.Array:
    return fit_lib.sse_from_moments(state.moments, poly.coeffs)
