"""Streaming LSE fitting with O(1) state — additive moments over time.

Because the paper's sufficient statistics (power sums / Gram) are additive,
a fit over an unbounded stream needs only the running ``Moments`` — no history
buffer. This is what lets the training loop fit its own loss curve every step
for free (``repro.train.monitors``) and what an online-serving statistics
service would keep per series.

Includes an exponential-forgetting variant (decay γ) so monitors track the
*recent* trend — the fit solves the γ-weighted least-squares problem exactly.

A ``StreamState`` may carry a ``repro.api.FitSpec`` (create it with
``spec.streaming()``): ``update`` then applies the spec's engine, basis,
pinned domain and — for ``method="irls"`` — per-chunk robust reweighting
against the running fit, and ``api.stream_result`` reads the spec's answer
(fixed fit, degree search, or moment-space LSPIA) back out of the state.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import basis as basis_lib
from repro.core import fit as fit_lib
from repro.core import moments as moments_lib
from repro.core import solve as solve_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StreamState:
    """Running moments (+ optional k-fold partials for online selection).

    ``cv_folds > 0`` at creation adds per-fold partial moments (leading
    fold axis) maintained for free: each incoming chunk's moments are
    computed once and folded into BOTH the total and one fold, assigned
    round-robin per chunk (``fold_index``).  That is what lets
    ``current_selection()`` run moment-space k-fold CV over the whole
    degree ladder at any time with zero re-reads of the stream.

    ``spec`` (static, hashable) is the optional ``FitSpec`` the state was
    created for — it rides along so every ``update`` and result readout
    agrees on engine/basis/domain/method without re-threading kwargs."""

    moments: moments_lib.Moments
    decay: jax.Array  # scalar in (0, 1]; 1.0 = plain accumulation
    fold_moments: moments_lib.Moments | None = None  # (k, ...batch) partials
    fold_index: jax.Array | None = None              # next fold, round-robin
    spec: object = dataclasses.field(metadata=dict(static=True),
                                     default=None)

    @staticmethod
    def create(degree: int, batch: tuple[int, ...] = (), *, decay: float = 1.0,
               dtype=jnp.float32, cv_folds: int = 0,
               spec=None) -> "StreamState":
        folds = (moments_lib.Moments.zeros(degree, (cv_folds,) + batch, dtype)
                 if cv_folds >= 2 else None)
        idx = jnp.zeros((), jnp.int32) if cv_folds >= 2 else None
        return StreamState(moments_lib.Moments.zeros(degree, batch, dtype),
                           jnp.asarray(decay, dtype), folds, idx, spec)

    def snapshot(self) -> dict:
        """Host-side O(m²) copy of the running state — the fleet journal's
        unit of replay (``repro.serve.fleet``).

        Everything dynamic (moments, decay, fold partials, fold index)
        lands as plain numpy, so the snapshot is picklable across a
        process mailbox and costs a few hundred bytes at serving degrees.
        The static ``spec`` is intentionally NOT captured: the restoring
        side supplies it (it already knows what it accumulates), keeping
        snapshots transport-plain.  ``restore(snapshot())`` round-trips
        bit-exactly: a state restored mid-stream and fed the remaining
        chunks produces the same bits as the uninterrupted run."""
        import numpy as np
        m = self.moments
        snap = {"gram": np.asarray(m.gram), "vty": np.asarray(m.vty),
                "yty": np.asarray(m.yty), "count": np.asarray(m.count),
                "weight_sum": np.asarray(m.weight_sum),
                "decay": np.asarray(self.decay)}
        if self.fold_moments is not None:
            f = self.fold_moments
            snap["folds"] = {"gram": np.asarray(f.gram),
                             "vty": np.asarray(f.vty),
                             "yty": np.asarray(f.yty),
                             "count": np.asarray(f.count),
                             "weight_sum": np.asarray(f.weight_sum)}
            snap["fold_index"] = np.asarray(self.fold_index)
        return snap

    @staticmethod
    def restore(snap: dict, *, spec=None) -> "StreamState":
        """Rebuild a ``StreamState`` from a ``snapshot()`` dict.

        ``spec`` re-attaches the (static, non-serialized) ``FitSpec`` the
        state accumulates under — pass the same spec the snapshotted
        state carried or updates will apply different semantics."""
        mk = lambda d: moments_lib.Moments(  # noqa: E731
            gram=jnp.asarray(d["gram"]), vty=jnp.asarray(d["vty"]),
            yty=jnp.asarray(d["yty"]), count=jnp.asarray(d["count"]),
            weight_sum=jnp.asarray(d["weight_sum"]))
        folds = mk(snap["folds"]) if "folds" in snap else None
        idx = (jnp.asarray(snap["fold_index"]) if "fold_index" in snap
               else None)
        return StreamState(mk(snap), jnp.asarray(snap["decay"]),
                           folds, idx, spec)

    def current_selection(self, *, criterion: str | None = None,
                          ridge: float = 0.0, solver: str = "auto",
                          fallback: str | None = "svd",
                          basis: str = basis_lib.MONOMIAL):
        """The running best degree (and the whole scored ladder) so far.

        Solves the degree ladder 0..degree on the accumulated O(m²) state
        — AIC/AICc/BIC/GCV always, k-fold CV when the state was created
        with ``cv_folds`` — and returns a ``repro.select.Selection``.
        ``criterion`` defaults to "cv" when folds exist, else "aicc".
        O(m²)-state work only: cost independent of how much data has
        streamed past."""
        from repro import select as select_lib
        m = self.moments.regularized(ridge) if ridge else self.moments
        if criterion is None:
            criterion = "cv" if self.fold_moments is not None else "aicc"
        if criterion == "cv" and self.fold_moments is None:
            raise ValueError("criterion='cv' needs StreamState.create(..., "
                             "cv_folds=k)")
        sweep = select_lib.sweep_from_moments(
            m, fold_moments=self.fold_moments,
            score_moments=self.moments if ridge else None, solver=solver,
            fallback=fallback, basis=basis)
        return select_lib.selection_from_sweep(sweep, criterion, basis=basis,
                                               solver=solver,
                                               fallback=fallback)


def _spec_solver(spec, degree: int, dtype) -> tuple[str, str | None]:
    """Statically resolve the spec's (solver, fallback) for a moment solve."""
    pol = spec.numerics
    solver = pol.solver
    if solver == "auto":
        solver = solve_lib.select_solver(degree, dtype, basis=spec.basis,
                                         normalized=spec.domain is not None
                                         or pol.normalize)
    return solver, pol.fallback


def _streaming_irls_weights(state: StreamState, xt: jax.Array,
                            y: jax.Array,
                            base_w: jax.Array | None) -> jax.Array:
    """Single-pass streaming IRLS: robust ψ-weights for the incoming chunk.

    Sweep 0 weights the chunk's residuals against the RUNNING fit (where
    determined — count > degree); the remaining ``stream_sweeps − 1``
    sweeps re-accumulate the in-hand chunk against (decayed running state
    + chunk) and reweight, so even the very first chunk of a contaminated
    stream gets a genuinely robust fit.  Only the chunk is ever touched —
    the stream is never re-read and the state stays O(m²)."""
    from repro import engine as engine_lib
    from repro.core import robust as robust_lib
    spec = state.spec
    opts = spec.irls
    degree = state.moments.degree
    cval = robust_lib.resolve_tuning(opts.loss, opts.c)
    solver, fallback = _spec_solver(spec, degree, state.moments.gram.dtype)
    w0 = jnp.ones_like(xt) if base_w is None else base_w

    def solve(m):
        if spec.ridge:
            m = m.regularized(spec.ridge)
        c, _, _ = solve_lib.solve_with_fallback(
            m.gram, m.vty, method=solver, fallback=fallback,
            cond_cap=spec.numerics.cond_cap)
        return c

    def reweight(coeffs):
        r = y - basis_lib.evaluate(coeffs, xt, basis=spec.basis)
        sigma = robust_lib.chunk_scale(r, w0, y)
        return robust_lib.robust_weights(r / sigma, opts.loss, cval)

    determined = (state.moments.count > degree)[..., None]
    wr = jnp.where(determined, reweight(solve(state.moments)), 1.0)
    if opts.stream_sweeps > 1:
        g = state.decay ** jnp.asarray(xt.shape[-1], state.decay.dtype)
        old = jax.tree.map(lambda a: a * g, state.moments)
        dec = _decay_weights(state, xt, None)
        plan = engine_lib.plan_fit(
            xt.shape, degree, basis=spec.basis, dtype=xt.dtype,
            weighted=True, engine=spec.engine,
            accum_dtype=state.moments.gram.dtype)
        for _ in range(opts.stream_sweeps - 1):
            new = engine_lib.compute_moments(plan, xt, y, dec * w0 * wr)
            wr = reweight(solve(old + new))
    return wr


@partial(jax.jit, static_argnames=("basis", "engine", "use_kernel"))
def update(state: StreamState, x: jax.Array, y: jax.Array, *,
           weights: jax.Array | None = None,
           basis: str = basis_lib.MONOMIAL,
           engine: str = "auto",
           use_kernel: bool | None = None) -> StreamState:
    """Fold a new chunk (..., n) into the running moments.

    With decay γ, previous weighted mass is multiplied by γ**n_new, giving
    exact exponentially-weighted least squares (newest point has weight 1).
    ``count`` is exempt from decay: it keeps the true number of contributing
    points ever folded in, identically on every engine path, so kernel- and
    jnp-produced states mix freely (the solve itself never reads count).

    ``engine`` picks the accumulation path via ``repro.engine.plan_fit``
    ("auto" = reference off-TPU, packed Pallas kernel for batched streams on
    TPU); ``use_kernel`` is a deprecated alias.  When the state carries a
    ``FitSpec``, the spec's basis/engine/domain win over the defaults and
    ``method="irls"`` reweights the chunk against the running fit before
    accumulating (single-pass streaming IRLS)."""
    from repro import engine as engine_lib
    spec = state.spec
    degree = state.moments.degree
    if spec is not None:
        basis = spec.basis
        if engine == "auto":
            engine = spec.engine
    xt = x
    if spec is not None and spec.domain is not None:
        xt = spec.domain_or(dtype=x.dtype).apply(x)
    user_w = weights
    if spec is not None and spec.method == "irls":
        wr = _streaming_irls_weights(state, xt, y, weights)
        user_w = wr if weights is None else weights * wr
    w = _decay_weights(state, x, user_w)
    plan = engine_lib.plan_fit(
        x.shape, degree, basis=basis, dtype=x.dtype, weighted=True,
        engine=engine_lib.resolve_engine(engine, use_kernel),
        accum_dtype=state.moments.gram.dtype)
    new = engine_lib.compute_moments(plan, xt, y, w)
    new = jax.tree.map(lambda a, ref: a.astype(ref.dtype),
                       new, state.moments)
    # count from the USER weights only: γ^age underflows to exactly 0 in
    # f32 past age ~700, and compute_moments counts nonzero combined
    # weights — decay must never make a point "not contribute" to count
    cdt = new.count.dtype
    true_count = (jnp.full(x.shape[:-1], x.shape[-1], cdt) if weights is None
                  else jnp.sum((weights != 0), axis=-1).astype(cdt))
    new = dataclasses.replace(
        new, count=jnp.broadcast_to(true_count, new.count.shape))
    n_new = jnp.asarray(x.shape[-1], state.decay.dtype)
    g = state.decay ** n_new
    m = state.moments
    old = dataclasses.replace(
        jax.tree.map(lambda a: a * g, m), count=m.count)
    if state.fold_moments is None:
        return dataclasses.replace(state, moments=old + new)
    # the chunk's moments are already in hand — fold them into one fold
    # partial as well (round-robin per chunk): the k-fold CV state costs
    # zero extra passes.  Decay applies to fold partials exactly as to the
    # total (count exempt, as above).
    k = state.fold_moments.gram.shape[0]
    folds_old = dataclasses.replace(
        jax.tree.map(lambda a: a * g, state.fold_moments),
        count=state.fold_moments.count)
    idx = state.fold_index % k
    folds = jax.tree.map(lambda f, a: f.at[idx].add(a), folds_old, new)
    return dataclasses.replace(state, moments=old + new, fold_moments=folds,
                               fold_index=state.fold_index + 1)


def _decay_weights(state: StreamState, x: jax.Array,
                   weights: jax.Array | None) -> jax.Array | None:
    # newest point gets γ⁰, oldest in chunk γ^{n-1} (γ=1 → all ones)
    w = jnp.broadcast_to(
        moments_lib.decay_ladder(x.shape[-1], state.decay, x.dtype),
        x.shape)
    return w if weights is None else w * weights


@partial(jax.jit, static_argnames=("method", "ridge", "solver", "fallback"))
def current_fit(state: StreamState, *, method: str | None = None,
                solver: str = "auto", fallback: str | None = "svd",
                ridge: float = 0.0) -> fit_lib.Polynomial:
    """Solve the running normal equations. ridge>0 adds λI (stabilizes early,
    nearly-singular states — e.g. fewer points seen than coefficients).

    ``solver``/``fallback`` select the condition-aware solve
    (``core.fit.fit_from_moments``): the returned ``Polynomial.diagnostics``
    carries the running state's κ(Gram) and whether the rank-revealing
    rescue fired — the monitor-friendly health signal for a stream going
    degenerate.  ``method=`` is the legacy spelling of ``solver=``.

    On a spec-carrying state the spec supplies the defaults: its numerics
    policy (when ``solver`` was left "auto"), its ridge (when ``ridge``
    was left 0), and its basis/pinned domain always ride on the returned
    ``Polynomial``."""
    spec = state.spec
    basis = basis_lib.MONOMIAL
    dom = None
    normalized = False
    cond_cap = None
    if spec is not None:
        basis = spec.basis
        dom = spec.domain_or(dtype=state.moments.gram.dtype)
        normalized = spec.domain is not None
        cond_cap = spec.numerics.cond_cap
        if method is None and solver == "auto":
            solver, fallback = _spec_solver(spec, state.moments.degree,
                                            state.moments.gram.dtype)
        if not ridge:
            ridge = spec.ridge
    m = state.moments
    if ridge:
        m = m.regularized(ridge)
    return fit_lib.fit_from_moments(m, method=method, solver=solver,
                                    fallback=fallback, cond_cap=cond_cap,
                                    domain=dom, basis=basis,
                                    normalized=normalized)


def current_sse(state: StreamState, poly: fit_lib.Polynomial) -> jax.Array:
    return fit_lib.sse_from_moments(state.moments, poly.coeffs)


class AsyncChunkIngestor:
    """Barrier-free multi-source chunk ingestion into one ``StreamState``.

    A state fed by several chunk sources (sensor shards, per-host log
    tails) must not wait for the slowest one: because the moments are
    additive and order-independent, any source's next-in-sequence chunk
    folds in the moment it arrives — ``offer`` never blocks on another
    source.  Per-source sequence numbers make delivery idempotent (a
    retried chunk is acknowledged, never re-accumulated — the fleet
    journal's contract) and a small reorder buffer absorbs out-of-order
    arrival within one source.

    The ``staleness`` bound governs *readout*, not ingestion: ``fresh()``
    is True while no source lags the lead source by more than
    ``staleness`` chunks, so a consumer can distinguish "current fit over
    everything" from "one source is a straggler and this fit under-weights
    it" — without ever stalling the updates themselves.  The same bound
    ``repro.core.distributed.async_lspia_fit`` applies to shard gradient
    versions (``LSPIAOptions.staleness``).

    Requires ``decay == 1.0``: order-independence is exactly what
    exponential forgetting gives up, and barrier-free folding would make
    the γ-weighting depend on arrival races."""

    def __init__(self, state: StreamState, n_sources: int,
                 staleness: int = 4, reorder_window: int = 8,
                 metrics=None):
        if n_sources < 1:
            raise ValueError(f"n_sources must be >= 1, got {n_sources}")
        if staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {staleness}")
        if float(state.decay) != 1.0:
            raise ValueError(
                "barrier-free folding is order-independent accumulation; "
                f"decay={float(state.decay)} is order-dependent — use a "
                "non-forgetting state")
        self.state = state
        self.n_sources = n_sources
        self.staleness = staleness
        self.reorder_window = reorder_window
        self.applied = [0] * n_sources          # per-source seq watermark
        self._held: list[dict[int, tuple]] = [{} for _ in range(n_sources)]
        self.duplicates = 0
        self.buffered = 0
        self.overflowed = 0
        # optional obs.MetricsRegistry: mirrors the attribute counters
        # and keeps a per-readout source-lag gauge (hwm = worst lag seen)
        if metrics is None:
            from repro.obs.metrics import NULL_REGISTRY
            metrics = NULL_REGISTRY
        self.metrics = metrics
        self._m_applied = metrics.counter("chunks_applied")
        self._m_duplicates = metrics.counter("chunks_duplicate")
        self._m_buffered = metrics.counter("chunks_buffered")
        self._m_overflowed = metrics.counter("chunks_overflowed")
        self._g_lag = metrics.gauge("source_lag")

    def offer(self, source: int, seq: int, x, y, *,
              weights=None) -> bool:
        """Fold chunk ``seq`` (1-based, contiguous per source) of
        ``source``.  Returns True if the running state advanced (the
        chunk or any held successors were applied); a duplicate is
        acknowledged idempotently and an early chunk is held in the
        reorder buffer."""
        if not 0 <= source < self.n_sources:
            raise ValueError(f"source {source} out of range "
                             f"[0, {self.n_sources})")
        mark = self.applied[source]
        if seq <= mark:
            self.duplicates += 1
            self._m_duplicates.inc()
            return False
        held = self._held[source]
        if seq > mark + 1:
            if seq - mark > self.reorder_window or seq in held:
                self.overflowed += seq not in held
                self.duplicates += seq in held
                (self._m_overflowed if seq not in held
                 else self._m_duplicates).inc()
                return False
            held[seq] = (x, y, weights)
            self.buffered += 1
            self._m_buffered.inc()
            return False
        self._apply(x, y, weights)
        self._m_applied.inc()
        self.applied[source] = seq
        # drain any successors the reorder buffer was holding
        while self.applied[source] + 1 in held:
            nxt = self.applied[source] + 1
            hx, hy, hw = held.pop(nxt)
            self._apply(hx, hy, hw)
            self._m_applied.inc()
            self.applied[source] = nxt
        self._g_lag.set(self.lag())
        return True

    def _apply(self, x, y, weights) -> None:
        self.state = update(self.state, jnp.asarray(x), jnp.asarray(y),
                            weights=None if weights is None
                            else jnp.asarray(weights))

    def lag(self) -> int:
        """Chunks between the lead source and the most lagging one."""
        return max(self.applied) - min(self.applied)

    def stale_sources(self) -> list[int]:
        lead = max(self.applied)
        return [s for s in range(self.n_sources)
                if lead - self.applied[s] > self.staleness]

    def fresh(self) -> bool:
        """True while every source is within the staleness window — the
        running fit weights all sources near-uniformly.  False flags a
        straggling source; the state still updates regardless."""
        return not self.stale_sources()
