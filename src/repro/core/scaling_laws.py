"""Power-law / scaling-law fits built on the paper's LSE core.

loss(tokens) ≈ a · tokens^b + c  is fitted (for fixed c-grid) by log-log
*linear* LSE — i.e. degree-1 matricized fitting on (log t, log (loss - c)).
Used by the training monitors for ETA/loss extrapolation and exposed as a
user-facing utility (the kind of "colossal dataset statistics" workload the
paper motivates)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import fit as fit_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PowerLaw:
    """y ≈ scale * x^exponent + offset."""

    scale: jax.Array
    exponent: jax.Array
    offset: jax.Array
    sse_log: jax.Array  # Σe² in log space (model-selection score)

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.scale * x ** self.exponent + self.offset


def fit_power_law(x: jax.Array, y: jax.Array, *,
                  offsets: jax.Array | None = None) -> PowerLaw:
    """Fit y = a x^b + c. Grid-search c over ``offsets`` (default: 0 plus a
    small grid below min(y)), solving each candidate with the matricized
    degree-1 LSE in log space, and keep the best by log-space Σe²."""
    if offsets is None:
        ymin = jnp.min(y)
        offsets = jnp.concatenate([
            jnp.zeros((1,), y.dtype),
            ymin * jnp.linspace(0.0, 0.999, 32, dtype=y.dtype)])

    lx = jnp.log(x)

    def one(c):
        ly = jnp.log(jnp.maximum(y - c, jnp.finfo(y.dtype).tiny))
        poly = fit_lib.polyfit(lx, ly, 1, normalize=True)
        rep_sse = jnp.sum((poly(lx) - ly) ** 2)
        mono = poly.coeffs  # normalized-domain coeffs; recover raw a, b:
        # ly = m0 + m1 * ((lx - shift) * scale)  =>  b = m1*scale,
        # log a = m0 - m1*scale*shift
        b = mono[1] * poly.domain_scale
        loga = mono[0] - mono[1] * poly.domain_scale * poly.domain_shift
        return jnp.exp(loga), b, rep_sse

    scales, exps, sses = jax.vmap(one)(offsets)
    i = jnp.argmin(sses)
    return PowerLaw(scale=scales[i], exponent=exps[i], offset=offsets[i],
                    sse_log=sses[i])
