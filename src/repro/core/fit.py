"""Public curve-fitting API: the paper's algorithm end to end.

``polyfit(x, y, degree)`` reproduces the paper's pipeline:
    moments (matricized, VᵀV/Vᵀy)  ->  Gaussian-elimination solve  ->  coeffs

``polyfit_qr`` is the MATLAB-polyfit baseline the paper compares against.
``fit_report`` computes the paper's evaluation artifacts (fitted values,
residuals, Σe², correlation coefficient R) for the accuracy tables.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import basis as basis_lib
from repro.core import moments as moments_lib
from repro.core import solve as solve_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Polynomial:
    """A fitted polynomial: coefficients + the basis/domain they live in."""

    coeffs: jax.Array                      # (..., m+1)
    domain_shift: jax.Array                # scalar (0 for paper-faithful)
    domain_scale: jax.Array                # scalar (1 for paper-faithful)
    basis: str = dataclasses.field(metadata=dict(static=True), default=basis_lib.MONOMIAL)

    @property
    def degree(self) -> int:
        return self.coeffs.shape[-1] - 1

    def __call__(self, x: jax.Array) -> jax.Array:
        dom = basis_lib.Domain(self.domain_shift, self.domain_scale)
        return basis_lib.evaluate(self.coeffs, x, basis=self.basis, domain=dom)

    def monomial_coeffs(self) -> jax.Array:
        """Raw-x monomial coefficients (for comparing against the paper)."""
        if self.basis != basis_lib.MONOMIAL:
            raise NotImplementedError("convert chebyshev via numpy.polynomial")
        dom = basis_lib.Domain(self.domain_shift, self.domain_scale)
        return basis_lib.monomial_coeffs_from_domain(
            self.coeffs, dom, self.degree)


def fit_from_moments(m: moments_lib.Moments, *, method: str = "gauss",
                     domain: basis_lib.Domain | None = None,
                     basis: str = basis_lib.MONOMIAL) -> Polynomial:
    """Solve the normal equations held in ``m``. The tiny-solve half of the
    paper's algorithm; separated so distributed/streaming paths reuse it."""
    coeffs = solve_lib.solve(m.gram, m.vty, method=method)
    dom = domain or basis_lib.Domain.identity(coeffs.dtype)
    return Polynomial(coeffs=coeffs, domain_shift=dom.shift,
                      domain_scale=dom.scale, basis=basis)


@partial(jax.jit, static_argnames=("degree", "method", "basis", "normalize",
                                   "accum_dtype", "engine", "use_kernel"))
def polyfit(x: jax.Array, y: jax.Array, degree: int, *,
            weights: jax.Array | None = None,
            method: str = "gauss", basis: str = basis_lib.MONOMIAL,
            normalize: bool = False, accum_dtype=None,
            engine: str = "auto",
            use_kernel: bool | None = None) -> Polynomial:
    """Paper-faithful matricized LSE fit (defaults) with hardening knobs.

    normalize=False, basis=monomial, method=gauss  ==  the paper's algorithm.
    Batched: x, y may carry leading batch axes (..., n).
    weights: optional per-point weights (..., n) — weighted least squares.
    engine: how moments accumulate — "auto" lets ``repro.engine.plan_fit``
    pick (packed Pallas kernel for batched monomial inputs on TPU, reference
    jnp elsewhere); "reference"/"kernel"/"kernel_packed"/"kernel_plain"
    force a path.  ``use_kernel`` is a deprecated alias for
    engine="kernel"/"reference".
    """
    from repro import engine as engine_lib
    plan = engine_lib.plan_fit(
        x.shape, degree, basis=basis, dtype=x.dtype,
        weighted=weights is not None,
        engine=engine_lib.resolve_engine(engine, use_kernel),
        accum_dtype=accum_dtype, normalize=normalize)
    dom = (basis_lib.Domain.from_data(x) if normalize
           else basis_lib.Domain.identity(x.dtype))
    xt = dom.apply(x)
    m = engine_lib.compute_moments(plan, xt, y, weights)
    return fit_from_moments(m, method=method, domain=dom, basis=basis)


@partial(jax.jit, static_argnames=("degree",))
def polyfit_qr(x: jax.Array, y: jax.Array, degree: int) -> Polynomial:
    """The paper's comparison baseline: MATLAB polyfit's QR-on-Vandermonde."""
    v = basis_lib.vandermonde(x, degree)
    coeffs = solve_lib.qr_solve_vandermonde(v, y)
    return Polynomial(coeffs=coeffs,
                      domain_shift=jnp.zeros((), x.dtype),
                      domain_scale=jnp.ones((), x.dtype))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FitReport:
    """Everything the paper's Tables II-V report about one fit."""

    coeffs: jax.Array          # monomial, raw-x coefficients
    fitted: jax.Array          # f(x_i)
    residuals: jax.Array       # y_i - f(x_i)
    sse: jax.Array             # Σ e²   (paper's headline accuracy number)
    r: jax.Array               # correlation coefficient R


def fit_report(poly: Polynomial, x: jax.Array, y: jax.Array) -> FitReport:
    fitted = poly(x)
    resid = y - fitted
    sse = jnp.sum(resid * resid, axis=-1)
    # correlation coefficient between y and fitted values
    ym = y - jnp.mean(y, axis=-1, keepdims=True)
    fm = fitted - jnp.mean(fitted, axis=-1, keepdims=True)
    r = jnp.sum(ym * fm, axis=-1) / jnp.sqrt(
        jnp.sum(ym * ym, axis=-1) * jnp.sum(fm * fm, axis=-1))
    coeffs = poly.coeffs
    if (poly.basis == basis_lib.MONOMIAL
            and (poly.coeffs.ndim == 1)):
        coeffs = poly.monomial_coeffs()
    return FitReport(coeffs=coeffs, fitted=fitted, residuals=resid,
                     sse=sse, r=r)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StreamedFitReport:
    """``fit_report`` accuracy numbers computed in one streamed pass.

    Unlike ``FitReport`` there are no (..., n) ``fitted``/``residuals``
    arrays — the fused Pallas kernel reduces them on the fly, so HBM traffic
    is one read of x/y and O(batch) output."""

    coeffs: jax.Array          # the fit's coefficients (fitted basis/domain)
    sse: jax.Array             # Σ w e²  (paper's headline accuracy number)
    r: jax.Array               # correlation coefficient R
    count: jax.Array           # Σ w (weighted mass used for the means)


def fit_report_streamed(poly: Polynomial, x: jax.Array, y: jax.Array, *,
                        weights: jax.Array | None = None,
                        block_n: int | None = None,
                        interpret: bool | None = None,
                        engine: str = "auto") -> StreamedFitReport:
    """Fused-kernel ``fit_report``: SSE and R without materializing the
    (..., n) fitted/residual arrays (the `fused_report` hot path).

    Matches ``fit_report``'s sse/r to fp tolerance for monomial fits; falls
    back to a materializing jnp pass with identical weighted semantics for
    chebyshev (Clenshaw is not fused).  ``engine="reference"`` forces the
    materializing pass (the plan layer's report workload has no packed
    variant — see ``repro.engine.plan_fit``).
    """
    from repro import engine as engine_lib
    plan = engine_lib.plan_fit(
        x.shape, poly.degree, basis=poly.basis, dtype=x.dtype,
        weighted=weights is not None, engine=engine,
        block_n=block_n, interpret=interpret, workload="report")
    dom = basis_lib.Domain(poly.domain_shift, poly.domain_scale)
    s = engine_lib.compute_report_sums(plan, dom.apply(x), y, poly.coeffs,
                                       weights=weights)
    n = s["sw"]
    cov = s["syf"] - s["sy"] * s["sf"] / n
    var_y = s["syy"] - s["sy"] * s["sy"] / n
    var_f = s["sff"] - s["sf"] * s["sf"] / n
    r = cov / jnp.sqrt(var_y * var_f)
    return StreamedFitReport(coeffs=poly.coeffs, sse=s["sse"], r=r, count=n)


def sse_from_moments(m: moments_lib.Moments, coeffs: jax.Array) -> jax.Array:
    """Σe² without touching the data: yᵀy - 2aᵀB + aᵀA a.

    Enables streaming quality tracking (monitors) with O(1) state."""
    quad = jnp.einsum("...j,...jk,...k->...", coeffs, m.gram, coeffs)
    cross = jnp.einsum("...j,...j->...", coeffs, m.vty)
    return m.yty - 2.0 * cross + quad


def report_from_moments(m: moments_lib.Moments,
                        coeffs: jax.Array) -> StreamedFitReport:
    """The full streamed report (SSE + R) from the O(m²) state alone.

    Every sum ``fit_report`` needs is a linear/quadratic form in the
    moments: Σwf = aᵀ·G[0,:], Σwf² = aᵀG a, Σwyf = aᵀB, Σwy = B[0],
    Σwy² = yᵀy, Σw = weight_sum — so the fit-serving engine reports
    quality without ever re-reading the data."""
    sw = m.weight_sum
    sf = jnp.einsum("...j,...j->...", coeffs, m.gram[..., 0, :])
    sff = jnp.einsum("...j,...jk,...k->...", coeffs, m.gram, coeffs)
    syf = jnp.einsum("...j,...j->...", coeffs, m.vty)
    sy = m.vty[..., 0]
    syy = m.yty
    sse = syy - 2.0 * syf + sff
    cov = syf - sy * sf / sw
    var_y = syy - sy * sy / sw
    var_f = sff - sf * sf / sw
    r = cov / jnp.sqrt(var_y * var_f)
    return StreamedFitReport(coeffs=coeffs, sse=sse, r=r, count=sw)
