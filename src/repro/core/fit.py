"""Public curve-fitting API: the paper's algorithm end to end.

``polyfit(x, y, degree)`` reproduces the paper's pipeline:
    moments (matricized, VᵀV/Vᵀy)  ->  Gaussian-elimination solve  ->  coeffs

``polyfit_qr`` is the MATLAB-polyfit baseline the paper compares against.
``fit_report`` computes the paper's evaluation artifacts (fitted values,
residuals, Σe², correlation coefficient R) for the accuracy tables.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import basis as basis_lib
from repro.core import moments as moments_lib
from repro.core import solve as solve_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FitDiagnostics:
    """Numerical health of one normal-equation solve.

    ``condition`` is the estimated κ₂ of the Gram matrix (from the O(m²)
    moment state; +inf when singular) and ``fallback_used`` whether the
    condition-triggered rescue solver produced the returned coefficients —
    the signal plain Gaussian elimination never gave when it silently
    returned inf/NaN on degenerate inputs."""

    condition: jax.Array       # (...,) estimated κ₂(VᵀV)
    fallback_used: jax.Array   # (...,) bool — rescue solver engaged
    solver: str = dataclasses.field(metadata=dict(static=True),
                                    default="gauss")
    fallback: str = dataclasses.field(metadata=dict(static=True),
                                      default="none")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Polynomial:
    """A fitted polynomial: coefficients + the basis/domain they live in."""

    coeffs: jax.Array                      # (..., m+1)
    domain_shift: jax.Array                # scalar (0 for paper-faithful)
    domain_scale: jax.Array                # scalar (1 for paper-faithful)
    basis: str = dataclasses.field(metadata=dict(static=True), default=basis_lib.MONOMIAL)
    diagnostics: FitDiagnostics | None = None   # solve health (None: not tracked)

    @property
    def degree(self) -> int:
        return self.coeffs.shape[-1] - 1

    def __call__(self, x: jax.Array) -> jax.Array:
        dom = basis_lib.Domain(self.domain_shift, self.domain_scale)
        return basis_lib.evaluate(self.coeffs, x, basis=self.basis, domain=dom)

    def monomial_coeffs(self) -> jax.Array:
        """Raw-x monomial coefficients (for comparing against the paper)."""
        if self.basis != basis_lib.MONOMIAL:
            raise NotImplementedError("convert chebyshev via numpy.polynomial")
        dom = basis_lib.Domain(self.domain_shift, self.domain_scale)
        return basis_lib.monomial_coeffs_from_domain(
            self.coeffs, dom, self.degree)


def fit_from_moments(m: moments_lib.Moments, *, method: str | None = None,
                     solver: str = "auto",
                     fallback: str | None = "svd",
                     cond_cap: float | None = None,
                     domain: basis_lib.Domain | None = None,
                     basis: str = basis_lib.MONOMIAL,
                     normalized: bool = False) -> Polynomial:
    """Solve the normal equations held in ``m``. The tiny-solve half of the
    paper's algorithm; separated so distributed/streaming paths reuse it.

    ``solver="auto"`` picks the GE → Cholesky → QR → SVD rung statically
    from degree/dtype/basis (``core.solve.select_solver``; ``normalized``
    tells the heuristic the moments were accumulated on a [-1,1] domain);
    any explicit name forces that primary.  Unless ``fallback=None``, the
    runtime condition estimate swaps in the rank-revealing rescue past
    ``cond_cap`` (per-dtype default) or on non-finite output, and the
    returned ``Polynomial.diagnostics`` records κ(Gram) + whether the
    rescue fired.  ``method=`` is the legacy spelling of ``solver=``.
    """
    if method is not None:
        solver = method
    if solver == "lspia":
        raise ValueError(
            "solver='lspia' needs the raw data (matrix-free V/Vᵀ sweeps) "
            "and cannot run from moments; use core.polyfit(..., "
            "solver='lspia') or core.lspia.lspia_fit")
    if solver == "qr_vandermonde":
        raise ValueError(
            "solver='qr_vandermonde' factors the raw Vandermonde rows and "
            "cannot run from moments; use core.polyfit(..., "
            "solver='qr_vandermonde') (the eager surface holds the data)")
    if solver == "auto":
        solver = solve_lib.select_solver(m.degree, m.gram.dtype, basis=basis,
                                         normalized=normalized)
    coeffs, cond, used = solve_lib.solve_with_fallback(
        m.gram, m.vty, method=solver, fallback=fallback, cond_cap=cond_cap)
    diag = FitDiagnostics(condition=cond, fallback_used=used, solver=solver,
                          fallback=fallback or "none")
    dom = domain or basis_lib.Domain.identity(coeffs.dtype)
    return Polynomial(coeffs=coeffs, domain_shift=dom.shift,
                      domain_scale=dom.scale, basis=basis, diagnostics=diag)


@partial(jax.jit, static_argnames=("degree", "method", "basis", "normalize",
                                   "accum_dtype", "engine", "use_kernel",
                                   "solver", "fallback", "cond_cap"))
def _polyfit_fixed(x: jax.Array, y: jax.Array, degree: int, *,
            weights: jax.Array | None = None,
            method: str | None = None, basis: str = basis_lib.MONOMIAL,
            normalize: bool = False, accum_dtype=None,
            engine: str = "auto",
            solver: str = "auto",
            fallback: str | None = "svd",
            cond_cap: float | None = None,
            use_kernel: bool | None = None) -> Polynomial:
    """Paper-faithful matricized LSE fit (defaults) with hardening knobs.

    normalize=False, basis=monomial, solver="gauss", fallback=None  ==  the
    paper's algorithm, silent failures included.  The defaults are
    condition-aware instead (EXPERIMENTS.md §Solver selection): ``plan_fit``
    resolves solver="auto" into the GE → Cholesky → QR → SVD rung that
    matches degree/dtype/basis, flips domain normalization on for
    raw-monomial fits at degrees where the un-normalized Gram is beyond
    every solver (the returned Polynomial carries its Domain, so evaluation
    is unchanged — but ``.coeffs`` are then normalized-basis coefficients;
    use ``.monomial_coeffs()`` for raw ones), and the solve itself swaps in
    the rank-revealing ``fallback`` when the runtime condition estimate
    demands it.  ``Polynomial.diagnostics`` records κ(Gram) and whether the
    fallback fired.  ``solver="lspia"`` skips the normal equations entirely
    and delegates to ``core.lspia.lspia_fit`` (matrix-free, iterative).

    Batched: x, y may carry leading batch axes (..., n).
    weights: optional per-point weights (..., n) — weighted least squares.
    engine: how moments accumulate — "auto" lets ``repro.engine.plan_fit``
    pick (packed Pallas kernel for batched monomial inputs on TPU, reference
    jnp elsewhere); "reference"/"kernel"/"kernel_packed"/"kernel_plain"
    force a path.  ``use_kernel`` is a deprecated alias for
    engine="kernel"/"reference"; ``method=`` the legacy spelling of
    ``solver=``.
    """
    from repro import engine as engine_lib
    if method is not None:
        solver = method
    if solver == "lspia":
        # matrix-free delegation; always on the normalized domain (LSPIA's
        # first-order convergence rate needs the bounded-domain κ — call
        # core.lspia.lspia_fit directly for raw-domain control)
        from repro.core import lspia as lspia_lib
        return lspia_lib.lspia_fit(
            x, y, degree, basis=basis, normalize=True,
            weights=weights, engine=engine).poly
    plan = engine_lib.plan_fit(
        x.shape, degree, basis=basis, dtype=x.dtype,
        weighted=weights is not None,
        engine=engine_lib.resolve_engine(engine, use_kernel),
        accum_dtype=accum_dtype, normalize=normalize,
        solver=solver, fallback=fallback, cond_cap=cond_cap)
    pol = plan.numerics
    dom = (basis_lib.Domain.from_data(x) if pol.normalize
           else basis_lib.Domain.identity(x.dtype))
    xt = dom.apply(x)
    m = engine_lib.compute_moments(plan, xt, y, weights)
    return fit_from_moments(m, solver=pol.solver, fallback=pol.fallback,
                            cond_cap=pol.cond_cap, domain=dom, basis=basis,
                            normalized=pol.normalize)


def polyfit(x: jax.Array, y: jax.Array, degree, *,
            weights: jax.Array | None = None,
            method: str | None = None, basis: str = basis_lib.MONOMIAL,
            normalize: bool = False, accum_dtype=None,
            engine: str = "auto",
            solver: str = "auto",
            fallback: str | None = "svd",
            cond_cap: float | None = None,
            use_kernel: bool | None = None) -> Polynomial:
    """The paper's pipeline (jitted) plus automatic model selection:
    ``degree="auto"`` or ``degree=DegreeSearch(...)`` picks the degree
    analytically from the SAME single moment pass (``repro.select`` —
    degree ladder + moment-space CV; see its docs).

    Thin shim over the declarative API: the kwargs assemble a
    ``repro.api.FitSpec`` and ``api.fit`` executes it (the compile cache
    keys on the spec, so this is the same jitted fast path).  The auto
    path is eager at the top (the winning degree is read back to slice
    the coefficients).  ``normalize=False`` under ``degree="auto"`` still
    lets the numerics policy escalate domain normalization at high max
    degrees, exactly as the fixed-degree plan does.  ``use_kernel`` is a
    deprecated alias of ``engine=``; ``method=`` the legacy spelling of
    ``solver=``."""
    from repro import api
    from repro import engine as engine_lib
    spec = api.spec_from_legacy(
        degree, method=method, basis=basis,
        normalize=normalize, accum_dtype=accum_dtype,
        engine=engine_lib.resolve_engine(engine, use_kernel),
        solver=solver, fallback=fallback, cond_cap=cond_cap)
    return api.fit(x, y, spec, weights=weights).poly


def polyfit_qr(x: jax.Array, y: jax.Array, degree: int) -> Polynomial:
    """Deprecated: the paper's comparison baseline (MATLAB polyfit's
    QR-on-Vandermonde) as a standalone function.  The spec spelling is
    ``FitSpec(method="lse", numerics=NumericsPolicy(solver=
    "qr_vandermonde"))`` — or ``polyfit(x, y, degree,
    solver="qr_vandermonde")`` — which this shim now constructs."""
    import warnings
    warnings.warn(
        "polyfit_qr is deprecated; pass solver='qr_vandermonde' to polyfit "
        "(or FitSpec(numerics=NumericsPolicy(solver='qr_vandermonde')))",
        DeprecationWarning, stacklevel=2)
    return polyfit(x, y, int(degree), solver="qr_vandermonde",
                   fallback=None)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FitReport:
    """Everything the paper's Tables II-V report about one fit."""

    coeffs: jax.Array          # monomial, raw-x coefficients
    fitted: jax.Array          # f(x_i)
    residuals: jax.Array       # y_i - f(x_i)
    sse: jax.Array             # Σ e²   (paper's headline accuracy number)
    r: jax.Array               # correlation coefficient R


def fit_report(poly: Polynomial, x: jax.Array, y: jax.Array) -> FitReport:
    fitted = poly(x)
    resid = y - fitted
    sse = jnp.sum(resid * resid, axis=-1)
    # correlation coefficient between y and fitted values
    ym = y - jnp.mean(y, axis=-1, keepdims=True)
    fm = fitted - jnp.mean(fitted, axis=-1, keepdims=True)
    r = jnp.sum(ym * fm, axis=-1) / jnp.sqrt(
        jnp.sum(ym * ym, axis=-1) * jnp.sum(fm * fm, axis=-1))
    coeffs = poly.coeffs
    if (poly.basis == basis_lib.MONOMIAL
            and (poly.coeffs.ndim == 1)):
        coeffs = poly.monomial_coeffs()
    return FitReport(coeffs=coeffs, fitted=fitted, residuals=resid,
                     sse=sse, r=r)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StreamedFitReport:
    """``fit_report`` accuracy numbers computed in one streamed pass.

    Unlike ``FitReport`` there are no (..., n) ``fitted``/``residuals``
    arrays — the fused Pallas kernel reduces them on the fly, so HBM traffic
    is one read of x/y and O(batch) output."""

    coeffs: jax.Array          # the fit's coefficients (fitted basis/domain)
    sse: jax.Array             # Σ w e²  (paper's headline accuracy number)
    r: jax.Array               # correlation coefficient R
    count: jax.Array           # Σ w (weighted mass used for the means)


def fit_report_streamed(poly: Polynomial, x: jax.Array, y: jax.Array, *,
                        weights: jax.Array | None = None,
                        block_n: int | None = None,
                        interpret: bool | None = None,
                        engine: str = "auto") -> StreamedFitReport:
    """Fused-kernel ``fit_report``: SSE and R without materializing the
    (..., n) fitted/residual arrays (the `fused_report` hot path).

    Matches ``fit_report``'s sse/r to fp tolerance for monomial fits; falls
    back to a materializing jnp pass with identical weighted semantics for
    chebyshev (Clenshaw is not fused).  ``engine="reference"`` forces the
    materializing pass (the plan layer's report workload has no packed
    variant — see ``repro.engine.plan_fit``).
    """
    from repro import engine as engine_lib
    plan = engine_lib.plan_fit(
        x.shape, poly.degree, basis=poly.basis, dtype=x.dtype,
        weighted=weights is not None, engine=engine,
        block_n=block_n, interpret=interpret, workload="report")
    dom = basis_lib.Domain(poly.domain_shift, poly.domain_scale)
    s = engine_lib.compute_report_sums(plan, dom.apply(x), y, poly.coeffs,
                                       weights=weights)
    n = s["sw"]
    cov = s["syf"] - s["sy"] * s["sf"] / n
    var_y = s["syy"] - s["sy"] * s["sy"] / n
    var_f = s["sff"] - s["sf"] * s["sf"] / n
    r = cov / jnp.sqrt(var_y * var_f)
    return StreamedFitReport(coeffs=poly.coeffs, sse=s["sse"], r=r, count=n)


def _broadcast_moments(m: moments_lib.Moments, coeffs: jax.Array):
    """Expand moment leaves so ``coeffs`` may carry extra trailing batch
    axes beyond the moments' batch shape — e.g. a whole degree *ladder*
    (..., M+1, m+1) of zero-padded coefficient rows scored against one
    (...,)-batched state (``repro.select``).  Lower-rank coeffs (one
    shared polynomial scored against many states, the streaming-monitor
    shape) need no expansion: einsum ellipsis broadcasting handles them."""
    extra = coeffs.ndim - m.vty.ndim
    gram, vty, yty, sw = m.gram, m.vty, m.yty, m.weight_sum
    for _ in range(max(extra, 0)):
        gram = gram[..., None, :, :]
        vty = vty[..., None, :]
        yty = yty[..., None]
        sw = sw[..., None]
    return gram, vty, yty, sw


def sse_from_moments(m: moments_lib.Moments, coeffs: jax.Array) -> jax.Array:
    """Σe² without touching the data: yᵀy - 2aᵀB + aᵀA a.

    Enables streaming quality tracking (monitors) with O(1) state.
    ``coeffs`` may carry extra trailing batch axes over the moments' batch
    (a zero-padded degree ladder (..., M+1, m+1) scores every degree at
    once: padded coefficients contribute nothing to either form)."""
    gram, vty, yty, _ = _broadcast_moments(m, coeffs)
    quad = jnp.einsum("...j,...jk,...k->...", coeffs, gram, coeffs)
    cross = jnp.einsum("...j,...j->...", coeffs, vty)
    return yty - 2.0 * cross + quad


def report_from_moments(m: moments_lib.Moments,
                        coeffs: jax.Array) -> StreamedFitReport:
    """The full streamed report (SSE + R) from the O(m²) state alone.

    Every sum ``fit_report`` needs is a linear/quadratic form in the
    moments: Σwf = aᵀ·G[0,:], Σwf² = aᵀG a, Σwyf = aᵀB, Σwy = B[0],
    Σwy² = yᵀy, Σw = weight_sum — so the fit-serving engine reports
    quality without ever re-reading the data.  Like ``sse_from_moments``,
    ``coeffs`` may carry a trailing degree-ladder axis."""
    gram, vty, syy, sw = _broadcast_moments(m, coeffs)
    sf = jnp.einsum("...j,...j->...", coeffs, gram[..., 0, :])
    sff = jnp.einsum("...j,...jk,...k->...", coeffs, gram, coeffs)
    syf = jnp.einsum("...j,...j->...", coeffs, vty)
    sy = vty[..., 0]
    sse = syy - 2.0 * syf + sff
    cov = syf - sy * sf / sw
    var_y = syy - sy * sy / sw
    var_f = sff - sf * sf / sw
    r = cov / jnp.sqrt(var_y * var_f)
    return StreamedFitReport(coeffs=coeffs, sse=sse, r=r, count=sw)
