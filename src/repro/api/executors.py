"""The four executors consuming one ``FitSpec``.

* ``fit(x, y, spec)``                eager/jit — the spec is the jit static
                                     arg, so the compile cache keys on spec
                                     identity;
* ``stream_state(spec)``             (= ``spec.streaming()``) an O(1)-state
                                     ``StreamState`` + ``stream_result``;
* ``make_distributed(spec, mesh)``   (= ``spec.distributed(mesh)``) a
                                     jitted shard_map program;
* the fit server's ``submit(x, y, spec=...)`` (``repro.serve.fit_engine``).

Each lowers through ``repro.engine.plan_fit`` (via ``FitSpec.plan``), so
execution-path and numerics-policy selection stay in one place no matter
which surface runs the spec.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro import engine as engine_lib
from repro import select as select_lib
from repro.api.spec import (FitResult, FitSpec, RAW_DATA_SOLVERS)
from repro.core import basis as basis_lib
from repro.core import distributed as distributed_lib
from repro.core import fit as fit_lib
from repro.core import lspia as lspia_lib
from repro.core import moments as moments_lib
from repro.core import robust as robust_lib
from repro.core import solve as solve_lib
from repro.core import streaming as streaming_lib
from repro.engine import plan as plan_lib


def spec_from_legacy(degree, *, method: str | None = None,
                     basis: str = basis_lib.MONOMIAL,
                     normalize: bool = False, accum_dtype=None,
                     engine: str = "auto", solver: str = "auto",
                     fallback: str | None = "svd",
                     cond_cap: float | None = None,
                     decay: float = 1.0, ridge: float = 0.0) -> FitSpec:
    """Map the legacy ``polyfit``-style kwargs onto a ``FitSpec``.

    ``method=`` is the legacy spelling of ``solver=``; ``solver="lspia"``
    delegates to the iterative method on the normalized domain, exactly as
    ``polyfit`` always has."""
    if isinstance(degree, str):
        if degree != "auto":
            raise ValueError(f"degree={degree!r}; expected an int, 'auto', "
                             "or a repro.select.DegreeSearch")
        degree = select_lib.DegreeSearch()
    if method is not None:
        solver = method
    meth = "lse"
    if solver == "lspia":
        # matrix-free delegation; always on the normalized domain (LSPIA's
        # first-order convergence rate needs the bounded-domain κ)
        meth, solver, normalize = "lspia", "auto", True
    return FitSpec(
        degree=degree, basis=basis, method=meth,
        numerics=plan_lib.NumericsPolicy(accum_dtype=accum_dtype,
                                         normalize=normalize, solver=solver,
                                         fallback=fallback,
                                         cond_cap=cond_cap),
        decay=decay, ridge=ridge, engine=engine)


def _decay_ladder(x: jax.Array, decay: float) -> jax.Array:
    return moments_lib.decay_ladder(x.shape[-1], decay, x.dtype)


def _spec_domain(spec: FitSpec, x: jax.Array,
                 normalize: bool) -> basis_lib.Domain:
    return spec.domain_or(
        basis_lib.Domain.from_data(x) if normalize
        else basis_lib.Domain.identity(x.dtype), dtype=x.dtype)


@partial(jax.jit, static_argnames=("spec",))
def _fit_lse_fixed(x: jax.Array, y: jax.Array,
                   weights: jax.Array | None, spec: FitSpec):
    """The paper's pipeline for one fixed-degree LSE spec: plan → domain →
    moments → condition-aware solve (+ the free moment-space report)."""
    degree = int(spec.degree)
    if spec.numerics.solver in RAW_DATA_SOLVERS:
        # the MATLAB-polyfit baseline: QR directly on the (weighted)
        # Vandermonde rows — no moments, no Gram squaring of κ
        dom = _spec_domain(spec, x, spec.numerics.normalize)
        xt = dom.apply(x)
        v = basis_lib.vandermonde(xt, degree, spec.basis)
        yy = y
        w = weights
        if spec.decay < 1.0:
            lad = _decay_ladder(x, spec.decay)
            w = lad if w is None else w * lad
        if w is not None:
            sw = jnp.sqrt(w)
            v = v * sw[..., :, None]
            yy = y * sw
        coeffs = solve_lib.qr_solve_vandermonde(v, yy)
        poly = fit_lib.Polynomial(coeffs=coeffs, domain_shift=dom.shift,
                                  domain_scale=dom.scale, basis=spec.basis)
        return poly, None
    plan = spec.plan(x.shape, x.dtype, weighted=weights is not None)
    pol = plan.numerics
    dom = _spec_domain(spec, x, pol.normalize)
    xt = dom.apply(x)
    w = weights
    if spec.decay < 1.0:
        lad = _decay_ladder(x, spec.decay)
        w = lad if w is None else w * lad
    m = engine_lib.compute_moments(plan, xt, y, w)
    ms = m.regularized(spec.ridge) if spec.ridge else m
    poly = fit_lib.fit_from_moments(
        ms, solver=pol.solver, fallback=pol.fallback, cond_cap=pol.cond_cap,
        domain=dom, basis=spec.basis,
        normalized=pol.normalize or spec.domain is not None)
    rep = fit_lib.report_from_moments(m, poly.coeffs)
    return poly, rep


def _fit_search(x: jax.Array, y: jax.Array,
                weights: jax.Array | None, spec: FitSpec) -> FitResult:
    """DegreeSearch specs: single-pass selection (eager at the top — the
    winning degree is read back to slice the coefficients).  Under
    ``method="irls"`` the robust weights are established first by IRLS at
    the max candidate degree — where contamination hurts most — and the
    one-pass weighted ladder rides on top of them: degree search under
    robust loss, from spec reuse of the weighted moment path."""
    ds = spec.degree
    iterations = converged = None
    if spec.decay < 1.0:
        lad = _decay_ladder(x, spec.decay)
        weights = lad if weights is None else weights * lad
    if spec.method == "irls":
        fixed = dataclasses.replace(spec, degree=ds.max_degree, decay=1.0)
        rfit, w_final = robust_lib.irls_fit(x, y, weights, fixed)
        weights = w_final
        iterations, converged = rfit.iterations, rfit.converged
    pol = spec.numerics
    solver = pol.solver if pol.solver != "auto" else ds.solver
    dom = spec.domain_or(None, dtype=x.dtype)
    if dom is not None:
        xs = dom.apply(x)
        normalize_arg: bool | None = False
    else:
        xs = x
        normalize_arg = True if pol.normalize else None
    sel = select_lib.select_degree(
        xs, y, ds.max_degree, folds=ds.folds, criterion=ds.criterion,
        weights=weights, basis=spec.basis, normalize=normalize_arg,
        engine=spec.engine, solver=solver, fallback=ds.fallback,
        cond_cap=ds.cond_cap, accum_dtype=pol.accum_dtype,
        ridge=spec.ridge)
    poly = sel.poly
    if dom is not None:
        poly = dataclasses.replace(poly, domain_shift=dom.shift,
                                   domain_scale=dom.scale)
        sel = dataclasses.replace(sel, poly=poly)
    return FitResult(poly=poly, selection=sel, iterations=iterations,
                     converged=converged)


def fit(x: jax.Array, y: jax.Array, spec: FitSpec | None = None, *,
        weights: jax.Array | None = None) -> FitResult:
    """Executor 1: one eager/jit call, any spec.

    The fixed-degree paths are jitted with the spec as the static arg —
    two calls with equal specs share one executable, two different specs
    compile once each and then coexist (the serve no-recompile invariant,
    extended to the whole API).  DegreeSearch specs are eager at the top
    like ``polyfit(..., "auto")`` always was."""
    spec = FitSpec() if spec is None else spec
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    if spec.is_search:
        return _fit_search(x, y, weights, spec)
    if spec.method == "irls":
        rfit, _ = robust_lib.irls_fit(x, y, weights, spec)
        return FitResult(poly=rfit.poly, iterations=rfit.iterations,
                         converged=rfit.converged)
    if spec.method == "lspia":
        lf = lspia_lib.lspia_fit_spec(x, y, weights, None, spec)
        return FitResult(poly=lf.poly, iterations=lf.iterations,
                         converged=lf.converged)
    poly, rep = _fit_lse_fixed(x, y, weights, spec)
    return FitResult(poly=poly, report=rep)


# ------------------------------------------------------------ streaming
def stream_state(spec: FitSpec, batch: tuple[int, ...] = (), *,
                 dtype=None) -> streaming_lib.StreamState:
    """Executor 2 state: an O(1) ``StreamState`` wired to the spec.

    The accumulation degree is the spec's max degree (a DegreeSearch's
    whole ladder nests inside it) and a DegreeSearch's ``folds`` become
    chunk-round-robin CV partials.  A domain-normalizing spec must PIN
    the domain (``FitSpec(domain=(shift, scale))``): a stream cannot
    derive min/max from data it has not seen yet."""
    if spec.numerics.solver in RAW_DATA_SOLVERS:
        raise ValueError(
            f"solver={spec.numerics.solver!r} needs the raw Vandermonde "
            "rows; the streaming surface only holds moments")
    dtype = dtype or spec.numerics.accum_dtype or jnp.float32
    pol = spec.plan((8,), dtype, weighted=True).numerics
    if pol.normalize and spec.domain is None:
        raise ValueError(
            "this spec normalizes the domain (explicitly or by the "
            "numerics policy's high-degree escalation), but a stream "
            "cannot derive min/max from unseen data — pin it with "
            "FitSpec(domain=(shift, scale))")
    return streaming_lib.StreamState.create(
        spec.max_degree, batch, decay=spec.decay, dtype=dtype,
        cv_folds=spec.folds, spec=spec)


def stream_result(state: streaming_lib.StreamState) -> FitResult:
    """Read the spec's answer out of a running stream state: fixed-degree
    solve, moment-space LSPIA, or the scored degree ladder — all O(m²)
    work on the sufficient statistics, zero re-reads of the stream."""
    spec = state.spec
    if spec is None or (not spec.is_search and spec.method != "lspia"):
        poly = streaming_lib.current_fit(state)
        return FitResult(poly=poly, report=fit_lib.report_from_moments(
            state.moments, poly.coeffs))
    if spec.is_search:
        ds = spec.degree
        criterion = ds.criterion
        if criterion is None:
            criterion = "cv" if state.fold_moments is not None else "aicc"
        if criterion == "cv" and state.fold_moments is None:
            raise ValueError("criterion='cv' needs fold partials; create "
                             "the state via spec.streaming() with "
                             "DegreeSearch(folds >= 2)")
        solver = (spec.numerics.solver if spec.numerics.solver != "auto"
                  else ds.solver)
        m = state.moments.regularized(spec.ridge) if spec.ridge \
            else state.moments
        sweep = select_lib.sweep_from_moments(
            m, fold_moments=state.fold_moments,
            score_moments=state.moments if spec.ridge else None,
            solver=solver, fallback=ds.fallback, cond_cap=ds.cond_cap,
            basis=spec.basis, normalized=spec.domain is not None)
        dom = spec.domain_or(None, dtype=state.moments.gram.dtype)
        sel = select_lib.selection_from_sweep(
            sweep, criterion, domain=dom, basis=spec.basis, solver=solver,
            fallback=ds.fallback)
        # score the winner in its zero-padded ladder layout (padding
        # contributes nothing; the sliced poly.coeffs would not broadcast
        # against the full-width moment state)
        best = jnp.asarray(sel.best_degree)
        if best.ndim == 0:
            padded = sweep.coeffs[..., int(best), :]
        else:
            padded = jnp.take_along_axis(
                sweep.coeffs, best[..., None, None], axis=-2)[..., 0, :]
        return FitResult(poly=sel.poly, selection=sel,
                         report=fit_lib.report_from_moments(
                             state.moments, padded))
    # moment-space LSPIA: Richardson on the accumulated normal equations
    m = state.moments.regularized(spec.ridge) if spec.ridge \
        else state.moments
    opts = spec.lspia
    coeffs, cond, conv, it = lspia_lib.lspia_solve_moments(
        m.gram, m.vty, tol=opts.tol, max_iter=opts.max_iter,
        power_iters=opts.power_iters, step=opts.step,
        momentum=opts.momentum)
    diag = fit_lib.FitDiagnostics(condition=cond, fallback_used=~conv,
                                  solver="lspia", fallback="none")
    dom = spec.domain_or(basis_lib.Domain.identity(state.moments.gram.dtype),
                         dtype=state.moments.gram.dtype)
    poly = fit_lib.Polynomial(coeffs=coeffs, domain_shift=dom.shift,
                              domain_scale=dom.scale, basis=spec.basis,
                              diagnostics=diag)
    return FitResult(poly=poly,
                     report=fit_lib.report_from_moments(state.moments,
                                                        coeffs),
                     iterations=it, converged=conv)


# ---------------------------------------------------------- distributed
def make_distributed(spec: FitSpec, mesh: jax.sharding.Mesh, *,
                     data_axes: tuple[str, ...] = ("data",)):
    """Executor 3: ``fn(x, y, weights=None) -> FitResult`` on a mesh.

    Inputs are globally sharded over ``data_axes``; the result is fully
    replicated.  The heavy lifting (method dispatch, the single O(m²)
    collective, IRLS-with-psum, moment-space LSPIA, the fold-stack psum
    of a DegreeSearch) lives in ``core.distributed.make_spec_executor``.
    """
    import numpy as np
    runner, kind = distributed_lib.make_spec_executor(
        spec, mesh, data_axes=data_axes)
    ds = spec.degree if spec.is_search else None
    if ds is not None:
        criterion = ds.criterion or ("cv" if ds.folds >= 2 else "aicc")

    def run(x, y, weights=None) -> FitResult:
        out = runner(x, y, weights)
        if kind == "search":
            poly, sweep, best = out
            best_np = np.asarray(best)
            sel = select_lib.Selection(
                sweep=sweep,
                best_degree=(int(best_np) if best_np.ndim == 0 else best_np),
                criterion=criterion, poly=poly)
            return FitResult(poly=poly, selection=sel)
        if kind == "iter":
            poly, m, it, conv = out
            return FitResult(poly=poly,
                             report=fit_lib.report_from_moments(
                                 m, poly.coeffs),
                             iterations=it, converged=conv)
        poly, m = out
        return FitResult(poly=poly,
                         report=fit_lib.report_from_moments(m, poly.coeffs))

    return run
