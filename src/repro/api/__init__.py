"""``repro.api`` — one declarative FitSpec, four execution surfaces.

>>> from repro import api
>>> spec = api.FitSpec(degree=3, method="irls")
>>> api.fit(x, y, spec).poly                  # eager/jit
>>> st = spec.streaming(); ...                # O(1)-state streaming
>>> run = spec.distributed(mesh); run(x, y)   # shard_map on a mesh
>>> serve_engine.submit(x, y, spec=spec)      # the fit server

See ``repro.api.spec`` for the spec's fields and ``repro.api.executors``
for the execution surfaces.
"""
from repro.api.spec import (FitSpec, FitResult, IRLSOptions, LSPIAOptions,
                            ServicePolicy, METHODS, RAW_DATA_SOLVERS)
from repro.api.executors import (fit, spec_from_legacy, stream_state,
                                 stream_result, make_distributed)
# the spec's composable vocabulary, re-exported so one import serves
from repro.engine.plan import NumericsPolicy
from repro.select.sweep import DegreeSearch

__all__ = [
    "FitSpec", "FitResult", "IRLSOptions", "LSPIAOptions", "ServicePolicy",
    "METHODS", "RAW_DATA_SOLVERS",
    "fit", "spec_from_legacy", "stream_state", "stream_result",
    "make_distributed",
    "NumericsPolicy", "DegreeSearch",
]
