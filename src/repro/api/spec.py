"""FitSpec — one declarative description of a fit, four execution surfaces.

Every capability this framework grew since PR 1 (weights, ``engine=``,
solver/fallback ``NumericsPolicy``, IRLS/LSPIA, ``degree="auto"``, CV
folds, decay) was plumbed kwarg-by-kwarg through ``polyfit``,
``StreamState``, ``make_distributed_*``, and the fit server — and the
surfaces diverged.  ``FitSpec`` is the fix: a frozen, hashable dataclass
holding the WHOLE fitting question (what degree/basis/domain, which
method, which numerics policy, how to weight time), validated once at
construction, consumed unchanged by all four executors:

* ``api.fit(x, y, spec)``          eager/jit (spec is the jit static arg,
                                   so the compile cache keys on spec
                                   identity — the serve no-recompile
                                   invariant extended to the whole API);
* ``spec.streaming()``             an O(1)-state ``StreamState`` wired to
                                   the spec (chunk updates + result);
* ``spec.distributed(mesh)``       a jitted shard_map executor;
* ``serve.submit(x, y, spec=...)`` per-request policy on the fit server.

Method choice is orthogonal to execution strategy (the asynchronous-LSPIA
argument, arXiv:2211.06556) and numerics policy is an explicit first-class
field rather than a buried default (Skala, arXiv:1802.07591).  Internally
every executor lowers the spec through ``repro.engine.plan_fit``, so plan
selection stays in one place.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core import basis as basis_lib
from repro.engine import plan as plan_lib
from repro.select.sweep import DegreeSearch, Selection

METHODS = ("lse", "irls", "lspia")
_LOSSES = ("huber", "tukey")

# solver spellings that need the raw data (no moment-space equivalent):
# valid in a FitSpec consumed by the eager executor only.
RAW_DATA_SOLVERS = ("qr_vandermonde",)


@dataclasses.dataclass(frozen=True)
class IRLSOptions:
    """Per-method options for ``method="irls"`` (bounded-influence IRLS).

    ``loss``/``c`` pick the M-estimator (``core.robust``); ``max_iter`` /
    ``tol`` bound the eager reweighting loop.  Streaming/serve surfaces
    run a single-pass approximation instead: each incoming chunk is
    ψ-weighted against the running fit, then — because the chunk is still
    in hand — re-accumulated ``stream_sweeps``-wise against (running
    state + chunk), so a stream is robust from its very first chunk at
    the cost of ``stream_sweeps`` accumulations of each chunk (the O(1)
    state and the zero-re-read property are untouched)."""

    loss: str = "huber"
    c: float | None = None
    max_iter: int = 30
    tol: float = 1e-6
    stream_sweeps: int = 3

    def __post_init__(self):
        if self.loss not in _LOSSES:
            raise ValueError(f"loss={self.loss!r}; expected one of {_LOSSES}")
        if self.max_iter < 1:
            raise ValueError(f"max_iter must be >= 1, got {self.max_iter}")
        if self.stream_sweeps < 1:
            raise ValueError("stream_sweeps must be >= 1, got "
                             f"{self.stream_sweeps}")


@dataclasses.dataclass(frozen=True)
class LSPIAOptions:
    """Per-method options for ``method="lspia"``.

    The eager executor runs the matrix-free V/Vᵀ iteration
    (``core.lspia.lspia_fit``); moment-only surfaces (streaming,
    distributed, serve) run the same fixed point as Richardson iteration
    directly on the accumulated O(m²) normal equations
    (``core.lspia.lspia_solve_moments``).

    ``momentum`` is the PIA-with-memory acceleration (arXiv:1908.06417):
    a heavy-ball term β·(cₖ − cₖ₋₁) added to every sweep.  β = 0 is the
    plain iteration; β ∈ (0, 1) cuts iterations-to-tol by multiples on
    the moderately conditioned problems LSPIA targets (measured in
    EXPERIMENTS.md §LSPIA acceleration).  Every surface honors it: the
    eager matrix-free loop, moment-space streaming/serve solves, the
    barrier-synchronous distributed executor, and the async shard fleet.

    ``staleness`` bounds how out-of-date a shard's contribution may be in
    the asynchronous executor (``core.distributed.async_lspia_fit``): a
    delta computed against coefficients more than ``staleness`` versions
    behind the coordinator's is rejected and recomputed rather than
    accumulated.  Synchronous surfaces ignore it."""

    tol: float = 1e-8
    max_iter: int = 5000
    power_iters: int = 12
    step: float | None = None
    momentum: float = 0.0
    staleness: int = 4

    def __post_init__(self):
        if self.max_iter < 1:
            raise ValueError(f"max_iter must be >= 1, got {self.max_iter}")
        if self.power_iters < 1:
            raise ValueError("power_iters must be >= 1, got "
                             f"{self.power_iters}")
        if not 0.0 <= self.momentum < 1.0:
            raise ValueError("momentum must lie in [0, 1) (heavy-ball "
                             f"stability), got {self.momentum}")
        if self.staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {self.staleness}")


@dataclasses.dataclass(frozen=True)
class ServicePolicy:
    """Per-request serving policy: how hard the fleet fights for this fit.

    Attached at submission (``fleet.submit(x, y, spec=..., service=...)``)
    rather than inside ``FitSpec``: the *fitting question* is transport-
    free, while retry/deadline/hedging describe how one particular
    submission rides the fault-tolerant fleet (``repro.serve.fleet``).

    ``retry_timeout`` is the no-progress window (virtual ticks) before a
    chunk or solve message is resent to the same worker; ``max_retries``
    bounds resends *and* cross-worker replays per request before it is
    failed; ``hedge`` opts the request into duplicate dispatch when its
    worker is verdicted a straggler; ``deadline`` (ticks from admission,
    ``None`` = never) fails the request outright when serving takes too
    long — the caller prefers an error over a stale answer."""

    max_retries: int = 4
    retry_timeout: int = 8
    hedge: bool = True
    deadline: int | None = None

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got "
                             f"{self.max_retries}")
        if self.retry_timeout < 1:
            raise ValueError(f"retry_timeout must be >= 1, got "
                             f"{self.retry_timeout}")
        if self.deadline is not None and self.deadline < 1:
            raise ValueError(f"deadline must be >= 1 (or None), got "
                             f"{self.deadline}")


def _as_domain_tuple(domain) -> tuple[float, float] | None:
    """Normalize a Domain / (shift, scale) pair to a hashable float tuple."""
    if domain is None:
        return None
    if isinstance(domain, basis_lib.Domain):
        return (float(domain.shift), float(domain.scale))
    shift, scale = domain
    return (float(shift), float(scale))


@dataclasses.dataclass(frozen=True)
class FitSpec:
    """The whole fitting question, validated once, hashable, executor-free.

    Fields
    ------
    degree:   an int (fixed-degree fit) or a ``repro.select.DegreeSearch``
              (single-pass automatic selection over the ladder
              0..max_degree).
    basis:    "monomial" | "chebyshev".
    method:   "lse" (the paper's matricized normal equations), "irls"
              (bounded-influence robust fitting, options in ``irls``) or
              "lspia" (progressive-iterative approximation, options in
              ``lspia``).
    domain:   None (the numerics policy decides: identity, or a
              data-derived [-1, 1] map when ``numerics.normalize`` /
              auto-escalation says so) or an explicit pinned
              ``(shift, scale)`` affine map — required wherever the data
              range is not known up front (streaming/serve with
              normalization).  ``basis_lib.Domain`` instances are
              accepted and stored as the float pair.
    numerics: the explicit numerics policy (Skala 1802.07591): solver
              rung ("auto" resolves per degree/dtype/basis), fallback
              rescue, condition cap, accumulation dtype, Kahan
              compensation, domain normalization.
    decay:    exponential forgetting γ ∈ (0, 1] for time-weighted fits
              (γ = 1: plain accumulation).  Eager ``fit`` applies the
              same γ-ladder weights a chunked stream would.
    ridge:    λI Tikhonov stabilizer added to the Gram at solve time.
    engine:   moment-accumulation path ("auto" | "reference" | "kernel" |
              "kernel_plain" | "kernel_packed"), resolved by
              ``engine.plan_fit``.
    """

    degree: int | DegreeSearch = 3
    basis: str = basis_lib.MONOMIAL
    method: str = "lse"
    irls: IRLSOptions = IRLSOptions()
    lspia: LSPIAOptions = LSPIAOptions()
    domain: tuple[float, float] | None = None
    numerics: plan_lib.NumericsPolicy = plan_lib.NumericsPolicy(solver="auto")
    decay: float = 1.0
    ridge: float = 0.0
    engine: str = "auto"

    # ------------------------------------------------------------ validation
    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"method={self.method!r}; expected one of "
                             f"{METHODS}")
        if self.basis not in (basis_lib.MONOMIAL, basis_lib.CHEBYSHEV):
            raise ValueError(f"basis={self.basis!r}; expected "
                             f"{(basis_lib.MONOMIAL, basis_lib.CHEBYSHEV)}")
        if self.engine not in plan_lib.ENGINES:
            raise ValueError(f"engine={self.engine!r}; expected one of "
                             f"{plan_lib.ENGINES}")
        object.__setattr__(self, "domain", _as_domain_tuple(self.domain))
        if isinstance(self.degree, DegreeSearch):
            if self.degree.max_degree < 0:
                raise ValueError("DegreeSearch.max_degree must be >= 0")
            if self.method == "lspia":
                raise ValueError(
                    "method='lspia' cannot run a DegreeSearch: the degree "
                    "ladder lives in the moment state and LSPIA's selling "
                    "point is not forming it; fit per degree explicitly or "
                    "use method='lse'/'irls'")
            if self.numerics.solver in RAW_DATA_SOLVERS:
                raise ValueError(
                    f"solver={self.numerics.solver!r} has no moment-space "
                    "ladder and cannot drive a DegreeSearch")
        else:
            degree = int(self.degree)
            if degree < 0:
                raise ValueError(f"degree must be >= 0, got {degree}")
            object.__setattr__(self, "degree", degree)
        sol = self.numerics.solver
        if sol == "lspia":
            raise ValueError("spell the iterative method as "
                             "FitSpec(method='lspia'), not as a solver")
        valid = plan_lib.SOLVERS + RAW_DATA_SOLVERS
        if sol not in valid:
            raise ValueError(f"solver={sol!r}; expected one of {valid}")
        if sol in RAW_DATA_SOLVERS and self.method != "lse":
            raise ValueError(f"solver={sol!r} is an LSE direct solve; "
                             f"method={self.method!r} cannot use it")
        if sol in RAW_DATA_SOLVERS and self.ridge:
            raise ValueError(
                f"solver={sol!r} factors the raw rows and has no λI to "
                "add — ridge regularization is a normal-equation concept; "
                "drop ridge= or use a moment-path solver")
        if not 0.0 < self.decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {self.decay}")
        if self.ridge < 0.0:
            raise ValueError(f"ridge must be >= 0, got {self.ridge}")
        # kernel engines only build monomial rows — fail at construction,
        # not at first execution (same message plan_fit would give)
        if (self.engine in ("kernel", "kernel_plain", "kernel_packed")
                and self.basis != basis_lib.MONOMIAL):
            raise ValueError(
                f"engine={self.engine!r} supports the monomial basis only "
                f"(the Pallas kernels build monomial power rows); use "
                f"engine='reference' or 'auto' for basis={self.basis!r}")

    # ------------------------------------------------------------ derived
    @property
    def is_search(self) -> bool:
        return isinstance(self.degree, DegreeSearch)

    @property
    def max_degree(self) -> int:
        """The accumulation degree: the fixed degree, or the search's max."""
        return (self.degree.max_degree if self.is_search
                else int(self.degree))

    @property
    def folds(self) -> int:
        return self.degree.folds if self.is_search else 0

    def domain_or(self, default: basis_lib.Domain | None = None,
                  dtype=None):
        """The pinned Domain as arrays, or ``default`` when unpinned."""
        if self.domain is None:
            return default
        import jax.numpy as jnp
        dtype = dtype or jnp.float32
        shift, scale = self.domain
        return basis_lib.Domain(jnp.asarray(shift, dtype),
                                jnp.asarray(scale, dtype))

    def plan(self, shape: tuple[int, ...], dtype: Any, *,
             weighted: bool = False, workload: str = "moments",
             mesh=None, data_axes: tuple[str, ...] = ()):
        """Lower this spec through ``engine.plan_fit`` — the ONE place plan
        selection happens for every executor."""
        pol = self.numerics
        solver = pol.solver
        if solver in RAW_DATA_SOLVERS:
            # the plan layer only plans moment solves; the raw-data direct
            # solve is dispatched by the eager executor — plan the moment
            # half as if unsolved so path validation still runs centrally
            solver = "auto"
        return plan_lib.plan_fit(
            shape, self.max_degree, basis=self.basis, dtype=dtype,
            weighted=weighted or self.decay < 1.0, engine=self.engine,
            accum_dtype=pol.accum_dtype, normalize=pol.normalize,
            compensated=pol.compensated, solver=solver,
            fallback=pol.fallback, cond_cap=pol.cond_cap,
            mesh=mesh, data_axes=data_axes, workload=workload)

    # ------------------------------------------------------------ executors
    def streaming(self, batch: tuple[int, ...] = (), *, dtype=None):
        """An O(1)-state ``StreamState`` wired to this spec (executor 2).

        Chunk data in with ``streaming.update(state, x, y)`` (the spec's
        engine/basis/domain/decay — and, for ``method="irls"``, per-chunk
        robust reweighting against the running fit — are applied
        automatically); read the spec's answer back with
        ``api.stream_result(state)``."""
        from repro.api import executors
        return executors.stream_state(self, batch, dtype=dtype)

    def distributed(self, mesh, *, data_axes: tuple[str, ...] = ("data",)):
        """A jitted mesh executor for this spec (executor 3):
        ``fn(x, y, weights=None) -> FitResult``, inputs sharded over
        ``data_axes``, result replicated."""
        from repro.api import executors
        return executors.make_distributed(self, mesh, data_axes=data_axes)


@dataclasses.dataclass(frozen=True)
class FitResult:
    """What every executor hands back, whatever the method or surface.

    ``poly`` is always present (ready to evaluate, carrying its basis and
    Domain); ``report`` the moment-space quality report (SSE/R/count) when
    the surface holds the moments to compute it for free; ``selection``
    the full scored ladder for DegreeSearch specs; ``iterations`` /
    ``converged`` the loop record for the iterative methods."""

    poly: Any
    report: Any = None
    selection: Selection | None = None
    iterations: Any = None
    converged: Any = None

    @property
    def coeffs(self):
        return self.poly.coeffs

    @property
    def diagnostics(self):
        return self.poly.diagnostics

    @property
    def best_degree(self):
        return None if self.selection is None else self.selection.best_degree
