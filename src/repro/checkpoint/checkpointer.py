"""Sharded checkpointing without external deps: npz shards + msgpack index.

Layout (one directory per step):
    ckpt_dir/step_000100/
        index.msgpack        # tree structure, leaf shapes/dtypes, shard map
        host_000.npz         # this host's leaf shards (flat key -> array)
        ...
        COMMITTED            # atomic commit marker (written last)

Fault-tolerance properties:
  * atomic: writes go to step_XXX.tmp/, fsync'd, then renamed + COMMITTED
    marker; restore ignores uncommitted directories (crash-consistent)
  * restore-with-resharding: leaves are saved UNSHARDED per host shard with
    their global positions; restore slices whatever the *new* mesh needs, so
    pod counts can change between runs (elastic restart)
  * self-describing: the msgpack index carries the full pytree def

For the CPU container every array is a single host shard; the shard-map
format is exercised by the multiprocess-layout tests.
"""
from __future__ import annotations

import io
import os
import shutil

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

COMMIT_MARKER = "COMMITTED"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, host_id: int = 0,
         extra_metadata: dict | None = None) -> str:
    """Write one checkpoint atomically. Returns the final directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    keys, leaves, treedef = _flatten_with_paths(tree)

    arrays = {}
    index = {"treedef": str(treedef), "keys": [], "step": step,
             "extra": extra_metadata or {}}
    for key, leaf in zip(keys, leaves):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            arrays[key] = arr.view(np.uint16)
            dtype = "bfloat16"
        else:
            arrays[key] = arr
            dtype = str(arr.dtype)
        index["keys"].append({"key": key, "shape": list(arr.shape),
                              "dtype": dtype})
    np.savez(os.path.join(tmp, f"host_{host_id:03d}.npz"), **arrays)
    with open(os.path.join(tmp, "index.msgpack"), "wb") as f:
        f.write(msgpack.packb(index))
    # atomic commit: rename then marker
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    with open(os.path.join(final, COMMIT_MARKER), "w") as f:
        f.write("ok")
    return final


def latest_step(ckpt_dir: str) -> int | None:
    """Newest committed step (ignores torn writes)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, COMMIT_MARKER)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, *, host_id: int = 0,
            shardings=None):
    """Restore into the structure of ``like_tree`` (shapes/dtypes verified).

    shardings: optional matching tree of NamedShardings — leaves are placed
    directly with jax.device_put(leaf, sharding), letting a *different* mesh
    than the saver's slice what it needs (elastic restore)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(final, COMMIT_MARKER)):
        raise FileNotFoundError(f"no committed checkpoint at {final}")
    with open(os.path.join(final, "index.msgpack"), "rb") as f:
        index = msgpack.unpackb(f.read())
    data = np.load(os.path.join(final, f"host_{host_id:03d}.npz"))
    by_key = {meta["key"]: meta for meta in index["keys"]}

    keys, leaves, treedef = _flatten_with_paths(like_tree)
    if shardings is not None:
        _, shard_leaves, _ = _flatten_with_paths(shardings)
    else:
        shard_leaves = [None] * len(leaves)

    out = []
    for key, leaf, shard in zip(keys, leaves, shard_leaves):
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        meta = by_key[key]
        arr = data[key]
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        want_shape = tuple(leaf.shape)
        if tuple(meta["shape"]) != want_shape:
            raise ValueError(
                f"{key}: checkpoint shape {meta['shape']} != {want_shape}")
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like_tree), out)


def gc_old(ckpt_dir: str, keep: int = 3) -> None:
    """Delete all but the newest `keep` committed checkpoints + any tmp."""
    if not os.path.isdir(ckpt_dir):
        return
    committed = []
    for name in sorted(os.listdir(ckpt_dir)):
        path = os.path.join(ckpt_dir, name)
        if name.endswith(".tmp"):
            shutil.rmtree(path, ignore_errors=True)
        elif name.startswith("step_"):
            if os.path.exists(os.path.join(path, COMMIT_MARKER)):
                committed.append(path)
            else:
                shutil.rmtree(path, ignore_errors=True)
    for path in committed[:-keep]:
        shutil.rmtree(path, ignore_errors=True)
