from repro.checkpoint.checkpointer import (save, restore, latest_step,
                                           gc_old, COMMIT_MARKER)

__all__ = ["save", "restore", "latest_step", "gc_old", "COMMIT_MARKER"]
