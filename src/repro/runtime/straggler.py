"""Straggler mitigation driven by the paper's LSE fits.

StepTimeMonitor (repro.train.monitors) fits each host's step-time series
with a streaming degree-1 matricized LSE; this module turns its verdicts
into actions: per-host slowdown diagnosis and data re-slicing plans that
shrink the slow host's shard (work-stealing) without a restart.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.train.monitors import StepTimeMonitor


@dataclasses.dataclass(frozen=True)
class ResliceAction:
    """New per-host example counts for one global batch."""
    shares: tuple[int, ...]

    @property
    def total(self) -> int:
        return sum(self.shares)


def plan_reslice(monitor: StepTimeMonitor, step: int, global_batch: int,
                 min_share: int = 1) -> ResliceAction:
    """Give each host work inversely proportional to its fitted step time
    (projected throughput), keeping the global batch fixed. Integerizes with
    largest-remainder; every host keeps >= min_share.

    Raises ``ValueError`` when ``global_batch < n_hosts * min_share`` —
    there is no assignment giving every host its floor, and the previous
    behavior (silently returning shares summing to MORE than the global
    batch) corrupted the very invariant a reslice exists to keep."""
    levels = monitor.fitted_levels(step)
    n_hosts = levels.shape[0]
    if global_batch < n_hosts * min_share:
        raise ValueError(
            f"global_batch={global_batch} cannot give each of {n_hosts} "
            f"hosts min_share={min_share} (needs >= {n_hosts * min_share}); "
            "shrink min_share or grow the batch")
    levels = np.maximum(levels, 1e-6)
    speed = 1.0 / levels
    raw = speed / speed.sum() * global_batch
    base = np.maximum(np.floor(raw).astype(int), min_share)
    # distribute the remainder to the largest fractional parts
    rem = global_batch - base.sum()
    if rem > 0:
        order = np.argsort(-(raw - np.floor(raw)))
        for i in order[:rem]:
            base[i] += 1
    elif rem < 0:
        # the min_share clamp can overshoot by more than one unit per
        # host, so shrinking may need several passes; the guard above
        # guarantees the loop terminates at exactly the global batch
        order = np.argsort(raw - np.floor(raw))
        while rem < 0:
            for i in order:
                if rem == 0:
                    break
                if base[i] > min_share:
                    base[i] -= 1
                    rem += 1
    out = ResliceAction(tuple(int(b) for b in base))
    assert out.total == global_batch
    return out
