"""Straggler mitigation driven by the paper's LSE fits.

StepTimeMonitor (repro.train.monitors) fits each host's step-time series
with a streaming degree-1 matricized LSE; this module turns its verdicts
into actions: per-host slowdown diagnosis and data re-slicing plans that
shrink the slow host's shard (work-stealing) without a restart.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.train.monitors import StepTimeMonitor


@dataclasses.dataclass(frozen=True)
class ResliceAction:
    """New per-host example counts for one global batch."""
    shares: tuple[int, ...]

    @property
    def total(self) -> int:
        return sum(self.shares)


def plan_reslice(monitor: StepTimeMonitor, step: int, global_batch: int,
                 min_share: int = 1) -> ResliceAction:
    """Give each host work inversely proportional to its fitted step time
    (projected throughput), keeping the global batch fixed. Integerizes with
    largest-remainder; every host keeps >= min_share."""
    levels = monitor.fitted_levels(step)
    levels = np.maximum(levels, 1e-6)
    speed = 1.0 / levels
    raw = speed / speed.sum() * global_batch
    base = np.maximum(np.floor(raw).astype(int), min_share)
    # distribute the remainder to the largest fractional parts
    rem = global_batch - base.sum()
    if rem > 0:
        order = np.argsort(-(raw - np.floor(raw)))
        for i in order[:rem]:
            base[i] += 1
    elif rem < 0:
        order = np.argsort(raw - np.floor(raw))
        for i in order:
            if rem == 0:
                break
            if base[i] > min_share:
                base[i] -= 1
                rem += 1
    return ResliceAction(tuple(int(b) for b in base))
