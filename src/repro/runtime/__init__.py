from repro.runtime.chaos import (FAULT_KINDS, ChaosSchedule, ChaosWorker,
                                 FaultEvent)
from repro.runtime.fault_tolerance import (HeartbeatTracker, RestartPolicy,
                                           ElasticPlan, FailureDetector)
from repro.runtime.straggler import plan_reslice, ResliceAction

__all__ = ["HeartbeatTracker", "RestartPolicy", "ElasticPlan",
           "FailureDetector", "plan_reslice", "ResliceAction",
           "FAULT_KINDS", "ChaosSchedule", "ChaosWorker", "FaultEvent"]
