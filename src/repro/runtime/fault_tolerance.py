"""Fault-tolerant training runtime: heartbeats, failure detection, restart
policy, elastic rescale. The control plane is deliberately dependency-free
(files/host callbacks) so it can sit on any cluster scheduler; the data plane
(checkpoint restore, mesh rebuild) reuses repro.checkpoint and launch.mesh.

What large-scale runs get from this module:
  * HeartbeatTracker  — per-host liveness with configurable timeout
  * FailureDetector   — combines missing heartbeats + straggler fits (the
                        paper's LSE on step-time series, runtime.straggler)
  * RestartPolicy     — bounded exponential backoff, max-restarts budget
  * ElasticPlan       — given surviving hosts, picks the largest valid mesh
                        (full data-parallel replicas only) and the checkpoint
                        step to resume from
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class HeartbeatTracker:
    n_hosts: int
    timeout_s: float = 60.0

    def __post_init__(self):
        now = time.monotonic()
        self.last_seen = {h: now for h in range(self.n_hosts)}

    def beat(self, host: int, t: float | None = None) -> None:
        self.last_seen[host] = time.monotonic() if t is None else t

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout_s]


@dataclasses.dataclass
class RestartPolicy:
    """Bounded restart budget with decorrelated-jitter backoff.

    ``jitter="decorrelated"`` (the default) draws each wait uniformly from
    ``[base, min(3 * previous_wait, max)]`` — the AWS decorrelated-jitter
    schedule — so a fleet of replicas that died together does NOT retry in
    lockstep (the thundering herd the plain exponential creates).  Every
    draw lies in ``[base_backoff_s, max_backoff_s]`` and the expected wait
    still grows geometrically until it saturates at the cap.  ``seed``
    makes the draw sequence reproducible (chaos tests pin it);
    ``jitter=None`` restores the deterministic exponential ladder."""

    max_restarts: int = 100
    base_backoff_s: float = 5.0
    max_backoff_s: float = 300.0
    jitter: str | None = "decorrelated"
    seed: int | None = None

    restarts: int = 0

    def __post_init__(self):
        if self.jitter not in (None, "decorrelated"):
            raise ValueError(f"jitter={self.jitter!r}; expected "
                             "'decorrelated' or None")
        if not 0 < self.base_backoff_s <= self.max_backoff_s:
            raise ValueError(
                f"need 0 < base_backoff_s <= max_backoff_s, got "
                f"{self.base_backoff_s} / {self.max_backoff_s}")
        import numpy as np
        self._rng = np.random.default_rng(self.seed)
        self._prev = self.base_backoff_s

    def next_backoff(self) -> float | None:
        """None = give up."""
        if self.restarts >= self.max_restarts:
            return None
        self.restarts += 1
        if self.jitter is None:
            b = min(self.base_backoff_s * (2 ** min(self.restarts - 1, 10)),
                    self.max_backoff_s)
        else:
            hi = min(3.0 * self._prev, self.max_backoff_s)
            b = float(self._rng.uniform(self.base_backoff_s,
                                        max(self.base_backoff_s, hi)))
        self._prev = b
        return b


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    n_hosts: int          # surviving
    mesh_shape: tuple     # new mesh
    resume_step: int

    @staticmethod
    def plan(surviving_hosts: int, chips_per_host: int,
             model_parallel: int, resume_step: int) -> "ElasticPlan":
        """Largest mesh = (data, model) with model fixed (TP must fit the
        weights' sharding) and data = largest multiple that the surviving
        chips support. Data-parallel size may shrink/grow freely because the
        data pipeline keys examples by batch index, not host count, and the
        checkpoint restores with resharding."""
        chips = surviving_hosts * chips_per_host
        data = max(1, chips // model_parallel)
        return ElasticPlan(surviving_hosts, (data, model_parallel),
                           resume_step)


class FailureDetector:
    """Missing-heartbeat OR persistent-straggler (LSE-fitted) detection."""

    def __init__(self, n_hosts: int, timeout_s: float = 60.0,
                 straggler_threshold: float = 1.5):
        from repro.train.monitors import StepTimeMonitor
        self.hb = HeartbeatTracker(n_hosts, timeout_s)
        self.steptime = StepTimeMonitor(n_hosts,
                                        threshold=straggler_threshold)
        self.n_hosts = n_hosts

    def observe_step(self, step: int, times_s, now: float | None = None):
        self.steptime.observe(step, times_s)
        for h in range(self.n_hosts):
            self.hb.beat(h, now)

    def verdict(self, step: int, now: float | None = None) -> dict:
        dead = self.hb.dead_hosts(now)
        slow = self.steptime.stragglers(step)
        return {"dead": dead, "stragglers": slow,
                "healthy": not dead and not slow}
