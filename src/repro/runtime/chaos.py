"""Deterministic, seedable fault injection for the serving fleet.

Robustness has to be *tested in*, not assumed (Skala, arXiv:1802.07591
catalogs how LSE degrades silently under adverse inputs): this module
turns "what if a worker dies mid-ingest" into a reproducible unit test.
A ``ChaosSchedule`` is a list of ``FaultEvent``s pinned to virtual ticks
— written explicitly by a test, or generated from one integer seed — and
``ChaosWorker`` wraps any fleet worker (anything with ``.process(msg,
tick)``) to realize them:

  * ``crash``  — the worker dies (stops heartbeating, loses all state)
                 until the dispatcher's restart policy revives it;
  * ``stall``  — the worker stays alive (heartbeats) but processes
                 nothing for ``duration`` ticks: a straggler;
  * ``drop``   — the next ingest message delivered to the worker
                 vanishes (network loss; the dispatcher must retry);
  * ``delay``  — the worker's next replies are delivered ``duration``
                 ticks late (retries may race the late ack — the
                 journal's idempotence is what keeps that safe);
  * ``poison`` — the worker's next result reply has its coefficients
                 replaced with NaN (the silent-corruption case the
                 dispatcher's result validation must quarantine).

Everything is keyed on the fleet's injected virtual clock — no
wall-clock sleeps anywhere — so the same seed + schedule reproduces the
same fault interleaving on every run, which is what lets the chaos
parity invariant (faulted run == fault-free run) be a committed test.
"""
from __future__ import annotations

import dataclasses

import numpy as np

FAULT_KINDS = ("crash", "stall", "drop", "delay", "poison")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault, armed at ``tick`` against ``worker``.

    ``duration`` is the stall length / reply delay in ticks (ignored by
    the one-shot kinds)."""

    tick: int
    worker: int
    kind: str
    duration: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"kind={self.kind!r}; expected one of "
                             f"{FAULT_KINDS}")
        if self.tick < 0 or self.duration < 0:
            raise ValueError(f"tick/duration must be >= 0, got "
                             f"{self.tick}/{self.duration}")


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """An immutable, sorted fault schedule over a worker fleet."""

    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events",
                           tuple(sorted(self.events,
                                        key=lambda e: (e.tick, e.worker))))

    def for_worker(self, worker: int) -> tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.worker == worker)

    @staticmethod
    def from_seed(seed: int, n_workers: int, horizon: int, *,
                  crashes: int = 0, stalls: int = 0, drops: int = 0,
                  delays: int = 0, poisons: int = 0,
                  stall_ticks: int = 50,
                  delay_ticks: int = 6) -> "ChaosSchedule":
        """Generate a schedule from one integer seed (deterministic: the
        same arguments always produce the same events, in the same fixed
        draw order).  Counts are per-kind totals over ``horizon`` ticks;
        crash targets are drawn without replacement so a single chaos run
        never kills the whole fleet unless asked to."""
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        crash_workers = rng.choice(n_workers, size=min(crashes, n_workers),
                                   replace=False)
        for w in crash_workers:
            events.append(FaultEvent(int(rng.integers(1, horizon)),
                                     int(w), "crash"))
        for kind, count, dur in (("stall", stalls, stall_ticks),
                                 ("drop", drops, 0),
                                 ("delay", delays, delay_ticks),
                                 ("poison", poisons, 0)):
            for _ in range(count):
                events.append(FaultEvent(int(rng.integers(1, horizon)),
                                         int(rng.integers(n_workers)),
                                         kind, dur))
        return ChaosSchedule(tuple(events))

    @staticmethod
    def parse(spec: str, seed: int, n_workers: int,
              horizon: int = 64) -> "ChaosSchedule":
        """Parse the CLI spelling ``"crash=1,stall=1,poison=2"`` into a
        seeded schedule (``launch.serve --chaos``)."""
        counts = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            kind, _, n = part.partition("=")
            if kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {kind!r} in "
                                 f"--chaos {spec!r}; expected "
                                 f"{FAULT_KINDS}")
            counts[kind] = int(n or 1)
        return ChaosSchedule.from_seed(
            seed, n_workers, horizon,
            crashes=counts.get("crash", 0), stalls=counts.get("stall", 0),
            drops=counts.get("drop", 0), delays=counts.get("delay", 0),
            poisons=counts.get("poison", 0))


class ChaosWorker:
    """Wrap any worker in a fault schedule.

    The wrapped object only needs the fleet worker protocol —
    ``process(msg, tick) -> list[reply]`` and ``reset()`` — and messages /
    replies only need a ``.kind`` attribute ("ingest" / "result" / ...),
    so the injector is reusable against anything mailbox-shaped.  The
    dispatcher drives it with ``begin_tick`` (arm due faults), checks
    ``alive`` / ``stalled`` before pumping, and receives each reply as a
    ``(delay_ticks, reply)`` pair.
    """

    def __init__(self, inner, worker_id: int,
                 events: tuple[FaultEvent, ...] = ()):
        self.inner = inner
        self.worker_id = worker_id
        self._pending = sorted(events, key=lambda e: e.tick)
        self.alive = True
        self.stalled_until = -1
        self._drop_next = 0
        self._delay_next = 0      # ticks to delay the next replies by
        self._poison_next = 0
        self.faults_applied: list[FaultEvent] = []

    # ------------------------------------------------------------- schedule
    def begin_tick(self, tick: int) -> None:
        """Arm every fault whose tick has arrived."""
        while self._pending and self._pending[0].tick <= tick:
            ev = self._pending.pop(0)
            self.faults_applied.append(ev)
            if ev.kind == "crash":
                self.alive = False
                self.inner.reset()     # a dead worker loses its state
            elif ev.kind == "stall":
                self.stalled_until = max(self.stalled_until,
                                         tick + ev.duration)
            elif ev.kind == "drop":
                self._drop_next += 1
            elif ev.kind == "delay":
                self._delay_next = max(self._delay_next, ev.duration)
            elif ev.kind == "poison":
                self._poison_next += 1

    def stalled(self, tick: int) -> bool:
        return tick <= self.stalled_until

    def revive(self) -> None:
        """Restart after a crash: fresh state, future faults still armed."""
        self.inner.reset()
        self.alive = True

    # ------------------------------------------------------------- mailbox
    def process(self, msg, tick: int) -> list[tuple[int, object]]:
        """Run one message through the inner worker, applying drop /
        delay / poison faults on the way; returns (delay, reply) pairs."""
        if not self.alive:
            return []
        if self._drop_next and getattr(msg, "kind", None) == "ingest":
            self._drop_next -= 1
            return []
        replies = self.inner.process(msg, tick)
        out = []
        for rep in replies:
            if self._poison_next and getattr(rep, "kind", None) == "result":
                self._poison_next -= 1
                rep = rep.poisoned()
            delay = self._delay_next
            out.append((delay, rep))
        if replies:
            self._delay_next = 0
        return out
