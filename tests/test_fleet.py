"""Fault-tolerant fit fleet: chaos parity, journal idempotence, recovery
policies, graceful degradation.

The committed invariant (ISSUE 6): a fleet under a seeded fault schedule
— crash mid-ingest, persistent straggler, poisoned reply — completes
every request, never double-counts a chunk, and returns coefficients
bit-identical to a fault-free run.  Everything runs on the injected
virtual tick clock: no wall sleeps, fully deterministic.
"""
import dataclasses

import numpy as np
import pytest

from repro import api
from repro.core import polyfit, streaming
from repro.runtime.chaos import ChaosSchedule, ChaosWorker, FaultEvent
from repro.serve import fit_engine as fe
from repro.serve.fleet import (Ack, FitFleet, FleetConfig, FleetWorker,
                               Ingest, Solve)

CHUNK = 128


def _series(seed, n_lo=300, n_hi=900, k=4):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(k):
        n = int(rng.integers(n_lo, n_hi))
        x = np.sort(rng.uniform(-1, 1, n)).astype(np.float32)
        y = (0.3 - 1.2 * x + 0.5 * x ** 3
             + 0.02 * rng.normal(size=n)).astype(np.float32)
        out.append((x, y))
    return out


def _fleet(chaos=None, **kw):
    kw.setdefault("fit", fe.FitServeConfig(degree=5))
    kw.setdefault("n_workers", 4)
    kw.setdefault("chunk_width", CHUNK)
    return FitFleet(FleetConfig(chaos=chaos, **kw))


def _run(series, chaos=None, **kw):
    fleet = _fleet(chaos, **kw)
    reqs = [fleet.submit(x, y, spec=api.FitSpec(degree=3))
            for x, y in series]
    reqs.append(fleet.submit(*series[0], degree="auto"))
    fleet.run(max_ticks=5000)
    return fleet, reqs


# ------------------------------------------------------------------ parity
def test_fleet_matches_polyfit_without_chaos():
    series = _series(0)
    fleet, reqs = _run(series)
    assert fleet.stats["completed"] == len(reqs)
    assert fleet.stats["failed"] == fleet.stats["shed"] == 0
    for r, (x, y) in zip(reqs, series):
        assert r.done and r.failed is None
        assert r.count == len(x)
        ref = np.asarray(polyfit(x, y, 3).coeffs)
        np.testing.assert_allclose(r.coeffs, ref, rtol=2e-3, atol=2e-3)
    auto = reqs[-1]
    assert auto.done and auto.degree is not None and auto.scores


def test_chaos_parity_crash_straggler_poison():
    """The acceptance invariant: 4 workers, crash mid-ingest + persistent
    straggler + poisoned reply → every request completes, no chunk is
    double-counted (exact counts), and coefficients are BIT-identical to
    the fault-free run (journal replay restores the same f32 state and
    re-runs the same compiled ops on the same chunk boundaries)."""
    series = _series(7, n_lo=600, n_hi=1600, k=8)
    base_fleet, base = _run(series, straggler_threshold=2.0)
    chaos = ChaosSchedule((
        FaultEvent(3, 1, "crash"),        # dies mid-ingest
        FaultEvent(2, 2, "stall", 400),   # persistent straggler
        FaultEvent(1, 3, "poison"),       # NaN-poisoned result
    ))
    fleet, reqs = _run(series, chaos, straggler_threshold=2.0)
    kinds = {e.kind for w in fleet.workers for e in w.faults_applied}
    assert kinds == {"crash", "stall", "poison"}
    assert fleet.stats["worker_deaths"] == 1
    assert fleet.stats["poisoned"] == 1
    assert fleet.stats["completed"] == len(reqs)     # zero lost
    assert fleet.stats["failed"] == 0
    assert fleet.stats["replays"] >= 1 and fleet.stats["hedges"] >= 1
    for b, c in zip(base, reqs):
        assert c.done and c.failed is None
        assert c.count == b.count                    # no double-count
        np.testing.assert_array_equal(np.asarray(c.coeffs),
                                      np.asarray(b.coeffs))
    assert reqs[-1].degree == base[-1].degree


def test_chaos_parity_drop_and_delay():
    """Silently dropped chunks and late acks: retries race the late
    replies, and the worker-side (key, seq) idempotence keeps the
    accumulated moments exact."""
    series = _series(11, k=5)
    _, base = _run(series)
    chaos = ChaosSchedule((
        FaultEvent(2, 0, "drop"),
        FaultEvent(3, 1, "drop"),
        FaultEvent(2, 2, "delay", 10),
    ))
    fleet, reqs = _run(series, chaos)
    assert fleet.stats["completed"] == len(reqs)
    assert fleet.stats["resends"] >= 1
    for b, c in zip(base, reqs):
        assert c.count == b.count
        np.testing.assert_array_equal(np.asarray(c.coeffs),
                                      np.asarray(b.coeffs))


def test_seeded_schedule_reproduces():
    s1 = ChaosSchedule.from_seed(5, 4, 64, crashes=1, stalls=2, poisons=1)
    s2 = ChaosSchedule.from_seed(5, 4, 64, crashes=1, stalls=2, poisons=1)
    assert s1 == s2
    assert ChaosSchedule.parse("crash=1,stall=2,poison=1", 5, 4) == s1
    with pytest.raises(ValueError, match="fault kind"):
        ChaosSchedule.parse("explode=1", 0, 4)


# --------------------------------------------------- journal / idempotence
def test_worker_duplicate_ingest_is_idempotent():
    """A retried chunk must be acked at the watermark and never
    re-accumulated — the property that makes journal replay exact."""
    specs = fe.derive_pool_specs(fe.FitServeConfig(degree=3))
    import jax.numpy as jnp
    solve = fe.make_spec_solve(3)
    sweep = fe.make_spec_sweep(3)
    wk = FleetWorker(0, specs, jnp.float32, solve, sweep)
    x = np.linspace(-1, 1, 64, dtype=np.float32)
    y = (x ** 2).astype(np.float32)
    w = np.ones(64, np.float32)
    msg = Ingest(key=9, seq=1, x=x, y=y, w=w, spec=specs.fixed)
    [ack1] = wk.process(msg, tick=1)
    assert isinstance(ack1, Ack) and ack1.seq == 1
    snap1 = wk.states[9].snapshot()
    [ack_dup] = wk.process(msg, tick=2)          # duplicate delivery
    assert ack_dup.seq == 1                      # re-acked, not re-applied
    snap2 = wk.states[9].snapshot()
    np.testing.assert_array_equal(snap1["gram"], snap2["gram"])
    np.testing.assert_array_equal(snap1["count"], snap2["count"])
    [ack_gap] = wk.process(dataclasses.replace(msg, seq=5), tick=3)
    assert ack_gap.seq == 1                      # out-of-window: resync ack
    [res] = wk.process(Solve(key=9, spec=specs.fixed), tick=4)
    assert float(res.fixed[3]) == 64.0           # count: exactly one copy


def test_stream_state_snapshot_restore_roundtrip():
    import jax.numpy as jnp
    from repro.core.streaming import StreamState
    spec = api.FitSpec(degree=4, method="irls")
    st = StreamState.create(4, (), spec=spec)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(-1, 1, 200).astype(np.float32))
    y = x ** 2 - x
    st = streaming.update(st, x, y)
    snap = st.snapshot()
    back = StreamState.restore(snap, spec=spec)
    np.testing.assert_array_equal(np.asarray(back.moments.gram),
                                  np.asarray(st.moments.gram))
    np.testing.assert_array_equal(np.asarray(back.moments.vty),
                                  np.asarray(st.moments.vty))
    assert back.spec == spec
    # restored state keeps accumulating identically
    a = streaming.update(st, x, y)
    b = streaming.update(back, x, y)
    np.testing.assert_array_equal(np.asarray(a.moments.gram),
                                  np.asarray(b.moments.gram))


# ----------------------------------------------------- degradation / limits
def test_overload_degrades_then_sheds():
    x = np.linspace(-1, 1, 300, dtype=np.float32)
    y = (x ** 2 - x).astype(np.float32)
    fleet = _fleet(fit=fe.FitServeConfig(degree=4), n_workers=2,
                   max_queue=6, degrade_watermark=3, max_inflight=1)
    reqs = [fleet.submit(x, y, degree="auto") for _ in range(10)]
    degraded = [r for r in reqs if r.degraded]
    shed = [r for r in reqs if r.shed]
    assert degraded and shed
    assert all(r.done and r.failed == "shed" for r in shed)
    fleet.run()
    for r in degraded:
        assert r.degraded == "degree_search->fixed"
        assert r.done and r.scores is None       # served as a fixed fit
        assert r.degree == 4
    served = [r for r in reqs if not r.shed]
    assert fleet.stats["completed"] == len(served)
    assert fleet.stats["shed"] == len(shed)
    assert fleet.stats["degraded"] == len(degraded)


def test_deadline_fails_unservable_request():
    x = np.linspace(-1, 1, 500, dtype=np.float32)
    y = x.copy()
    chaos = ChaosSchedule(tuple(
        FaultEvent(1, w, "stall", 500) for w in range(2)))
    fleet = _fleet(chaos, n_workers=2)
    req = fleet.submit(x, y, service=api.ServicePolicy(deadline=10))
    for _ in range(30):
        fleet.step()
    assert req.done and req.failed == "deadline"
    assert fleet.stats["failed"] == 1
    assert fleet.pending == 0


def test_service_policy_validation():
    with pytest.raises(ValueError, match="max_retries"):
        api.ServicePolicy(max_retries=-1)
    with pytest.raises(ValueError, match="deadline"):
        api.ServicePolicy(deadline=0)


# ------------------------------------------------------- recovery policies
def test_crashed_worker_revives_and_serves_again():
    series = _series(13, k=6)
    chaos = ChaosSchedule((FaultEvent(2, 0, "crash"),))
    fleet, reqs = _run(series, chaos, n_workers=2)
    assert fleet.stats["worker_deaths"] == 1
    assert fleet.stats["revivals"] == 1
    assert fleet.stats["completed"] == len(reqs)
    assert fleet.workers[0].alive
    # the revived worker can take fresh work
    r = fleet.submit(*series[0], spec=api.FitSpec(degree=3))
    fleet.run()
    assert r.done and r.failed is None


def test_hedge_rescues_straggler_pinned_request():
    series = _series(17, k=3)
    chaos = ChaosSchedule((FaultEvent(2, 0, "stall", 300),))
    fleet, reqs = _run(series, chaos, straggler_threshold=2.0)
    assert fleet.stats["hedges"] >= 1
    hedged = [r for r in reqs if r.hedged]
    assert hedged
    for r in hedged:
        assert r.done and r.failed is None
        assert len(r.workers) >= 2               # served by the backup


def test_hedging_disabled_by_service_policy():
    x = np.linspace(-1, 1, 700, dtype=np.float32)
    y = (x ** 3).astype(np.float32)
    chaos = ChaosSchedule((FaultEvent(2, 0, "stall", 60),))
    fleet = _fleet(chaos, n_workers=2, straggler_threshold=2.0)
    svc = api.ServicePolicy(hedge=False, retry_timeout=100,
                            max_retries=50)
    req = fleet.submit(x, y, service=svc)
    fleet.run(max_ticks=5000)
    assert req.done and not req.hedged
    assert fleet.stats["hedges"] == 0


def test_poisoned_result_quarantines_worker():
    x = np.linspace(-1, 1, 400, dtype=np.float32)
    y = (1.0 + x).astype(np.float32)
    chaos = ChaosSchedule((FaultEvent(1, 0, "poison"),))
    fleet = _fleet(chaos, n_workers=2)
    req = fleet.submit(x, y)
    fleet.run()
    assert fleet.stats["poisoned"] == 1
    assert req.done and req.failed is None
    assert np.all(np.isfinite(req.coeffs))       # NaN never reached caller
    assert req.retries >= 1
    # producer sat in the penalty box after the bad reply
    assert fleet._quarantined_until[0] > 0


# ----------------------------------------------------------- infrastructure
def test_parallel_pump_matches_serial():
    x = np.linspace(-1, 1, 500, dtype=np.float32)
    y = (x ** 2 - 0.5 * x).astype(np.float32)

    def coeffs(par):
        fleet = _fleet(n_workers=3, parallel_pump=par)
        rs = [fleet.submit(x, y) for _ in range(6)]
        fleet.run()
        return np.stack([np.asarray(r.coeffs) for r in rs])

    np.testing.assert_array_equal(coeffs(False), coeffs(True))


def test_fleet_compiles_once_for_default_specs():
    """Replication adds zero executables: all workers share the pool's
    solve/sweep, and more requests on the warmed default specs never
    recompile."""
    fleet = _fleet()
    n0 = fleet.warmup()
    series = _series(23, k=5)
    for x, y in series:
        fleet.submit(x, y)
        fleet.submit(x, y, degree="auto")
    fleet.run()
    assert fleet.compiled_executables() == n0
    assert fleet.stats["completed"] == 2 * len(series) + 2


def test_fleet_config_validation():
    with pytest.raises(ValueError, match="n_workers"):
        FleetConfig(n_workers=0)
    with pytest.raises(ValueError, match="degrade_watermark"):
        FleetConfig(max_queue=4, degrade_watermark=9)


def test_chaos_worker_passthrough_without_events():
    class _Echo:
        def process(self, msg, tick):
            return [msg]

        def reset(self):
            pass

    wk = ChaosWorker(_Echo(), 0, ())
    wk.begin_tick(1)
    assert wk.alive and not wk.stalled(1)
    msg = Ingest(key=1, seq=1, x=None, y=None, w=None, spec=None)
    assert wk.process(msg, 1) == [(0, msg)]
