"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness; decode-vs-prefill consistency for
the serving path; param/spec tree congruence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import get_model
from repro.train import TrainConfig, init_train_state, make_train_step

ARCHS = list(configs.ARCHS)


def _train_batch(cfg, rng, b=2, s=64):
    r1, r2, r3 = jax.random.split(rng, 3)
    if cfg.family == "audio":
        dl = 16
        return {
            "frames": jax.random.normal(r1, (b, s, cfg.d_model),
                                        jnp.bfloat16),
            "dec_tokens": jax.random.randint(r2, (b, dl), 0,
                                             cfg.vocab_size),
            "labels": jax.random.randint(r3, (b, dl), 0, cfg.vocab_size),
            "loss_mask": jnp.ones((b, dl), jnp.float32),
        }
    if cfg.family == "vlm":
        st = s - cfg.n_image_tokens
        return {
            "tokens": jax.random.randint(r1, (b, st), 0, cfg.vocab_size),
            "extra_embeds": jax.random.normal(
                r2, (b, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16),
            "labels": jax.random.randint(r3, (b, s), 0, cfg.vocab_size),
            "loss_mask": jnp.ones((b, s), jnp.float32),
        }
    return {
        "tokens": jax.random.randint(r1, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(r2, (b, s), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((b, s), jnp.float32),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = configs.get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _train_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(model.forward_train)(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_moves_loss(arch):
    from repro.train import AdamWConfig
    cfg = configs.get_smoke_config(arch)
    model = get_model(cfg)
    tc = TrainConfig(optimizer=AdamWConfig(peak_lr=5e-3, warmup_steps=0))
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tc))
    batch = _train_batch(cfg, jax.random.PRNGKey(1))
    state1, m1 = step(state, batch)
    state2, m2 = step(state1, batch)
    assert np.isfinite(float(m1["loss"]))
    assert float(m2["loss"]) < float(m1["loss"])   # same batch: must improve
    assert int(state2["step"]) == 2


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step after prefill(prompt) ≈ forward_train logits at the same
    position — validates every cache/state layout in the zoo."""
    cfg = configs.get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    b, s = 2, 32
    rng = jax.random.PRNGKey(2)
    max_len = 64

    if cfg.family == "audio":
        frames = jax.random.normal(rng, (b, 24, cfg.d_model), jnp.bfloat16)
        toks = jax.random.randint(rng, (b, s), 3, cfg.vocab_size)
        full, _ = model.forward_train(
            params, {"frames": frames, "dec_tokens": toks})
        logits_p, state = model.prefill(
            params, {"frames": frames, "dec_tokens": toks[:, :s - 1]},
            max_len)
        logits_d, _ = model.decode_step(params, toks[:, s - 1:s], state)
        want = full[:, s - 1]
    elif cfg.family == "vlm":
        toks = jax.random.randint(rng, (b, s), 3, cfg.vocab_size)
        embeds = jax.random.normal(rng, (b, cfg.n_image_tokens, cfg.d_model),
                                   jnp.bfloat16)
        full, _ = model.forward_train(
            params, {"tokens": toks, "extra_embeds": embeds})
        logits_p, state = model.prefill(
            params, {"tokens": toks[:, :s - 1], "extra_embeds": embeds},
            max_len)
        logits_d, _ = model.decode_step(params, toks[:, s - 1:s], state)
        want = full[:, -1]
    else:
        toks = jax.random.randint(rng, (b, s), 3, cfg.vocab_size)
        full, _ = model.forward_train(params, {"tokens": toks})
        logits_p, state = model.prefill(params, {"tokens": toks[:, :s - 1]},
                                        max_len)
        logits_d, _ = model.decode_step(params, toks[:, s - 1:s], state)
        want = full[:, -1]

    got = logits_d[:, 0]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=5e-2, atol=5e-2)
    # and the prefill's own last-position logits match train at s-2
    want_p = full[:, -2] if cfg.family != "audio" else full[:, s - 2]
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0], np.float32),
        np.asarray(want_p, np.float32), rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_tree_congruent(arch):
    """Every param leaf has a logical-axes tuple of matching rank."""
    cfg = configs.get_smoke_config(arch)
    model = get_model(cfg)
    params = model.abstract_params()
    specs = model.param_specs()
    is_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=is_leaf)
    assert len(flat_p) == len(flat_s)

    def check(spec, sds):
        assert len(sds.shape) == len(spec), (sds.shape, spec)
        return True

    jax.tree.map(check, specs, params, is_leaf=is_leaf)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "rwkv6-1.6b",
                                  "zamba2-7b", "gemma2-27b"])
def test_full_config_abstract_params(arch):
    """Full (not smoke) configs materialize abstractly with sane param
    counts vs the analytic formula (±12%)."""
    cfg = configs.get_config(arch)
    model = get_model(cfg)
    abstract = model.abstract_params()
    total = sum(np.prod(l.shape) for l in jax.tree.leaves(abstract))
    analytic = cfg.param_count()
    assert abs(total - analytic) / analytic < 0.12, (total, analytic)
