"""Gradient compression: quantization properties + error feedback
convergence + compressed-allreduce equivalence under shard_map."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.train import compression as comp

settings.register_profile("comp", deadline=None, max_examples=20)
settings.load_profile("comp")


@given(st.integers(0, 10_000))
def test_quantize_dequantize_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 3, (37, 19)), jnp.float32)
    q, scale = comp.quantize(x)
    assert q.dtype == jnp.int8
    back = comp.dequantize(q, scale, x.shape)
    # per-block max error <= scale/2 = max|block| / 254
    err = np.abs(np.asarray(back - x))
    bound = np.abs(np.asarray(x)).max() / 127.0
    assert err.max() <= bound + 1e-6


def test_error_feedback_unbiased_over_time():
    """With error feedback, the ACCUMULATED transmitted signal converges to
    the accumulated true signal (residual stays bounded)."""
    rng = np.random.default_rng(0)
    true = jnp.asarray(rng.normal(0, 1, (256,)), jnp.float32)
    residual = jnp.zeros((256,), jnp.float32)
    sent_total = np.zeros(256)
    for step in range(50):
        (q, s), residual = comp.compress_residual(true, residual)
        sent_total += np.asarray(comp.dequantize(q, s, true.shape))
    # mean transmitted per step ≈ true signal
    np.testing.assert_allclose(sent_total / 50, np.asarray(true),
                               rtol=0.05, atol=0.02)
    assert float(jnp.max(jnp.abs(residual))) < 0.1


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 fake devices")
def test_compressed_allreduce_shard_map():
    from jax.sharding import PartitionSpec as P
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_host_mesh(data=8, model=1)
    rng = np.random.default_rng(1)
    grads = jnp.asarray(rng.normal(0, 1, (8, 512)), jnp.float32)
    residuals = jnp.zeros((8, 512), jnp.float32)

    @jax.jit
    @lambda f: jax.shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                             out_specs=(P("data"), P("data")))
    def sync(g, r):
        out, nr = comp.allreduce_compressed(g[0], r[0], "data")
        return out[None], nr[None]

    mean_c, _ = sync(grads, residuals)
    true_mean = jnp.mean(grads, axis=0)
    got = np.asarray(mean_c[0])
    np.testing.assert_allclose(got, np.asarray(true_mean),
                               rtol=0.05, atol=0.03)


def test_wire_bytes_are_4x_smaller():
    """The int8 payload (what crosses DCN) is 4x smaller than f32 + per-256
    scales overhead."""
    x = jnp.ones((1024,), jnp.float32)
    q, scale = comp.quantize(x)
    wire = q.size + scale.size * 4
    assert wire < x.size * 4 / 3.5
