"""Observability layer: metric registry, trace spans, SLO monitors.

The committed invariants (ISSUE 9):

* deterministic telemetry — the same chaos seed yields a *bit-identical*
  JSONL event log and identical metric snapshots across two fleet runs
  (the observability analogue of the chaos-parity invariant);
* complete span chains — every admitted request reaches exactly one
  terminal annotation, and every replay/hedge the request surfaced is
  annotated in its chain;
* sketch quantiles — the DDSketch-style histogram answers quantiles to
  the configured relative error with NO sample retention, and merge is
  associative/commutative by construction (property-tested);
* self-fitting SLOs — a monitor built from the repo's own streaming
  moment fits forecasts an injected latency ramp's breach BEFORE the
  threshold is crossed;
* zero-cost off path — the null recorders record nothing and leave
  serving results identical.
"""
import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import api
from repro import obs as obs_lib
from repro.core import streaming
from repro.obs.metrics import HistogramSketch, MetricsRegistry, NULL_REGISTRY
from repro.obs.slo import SLOBoard, SLOMonitor, resolve_metric
from repro.obs.trace import Tracer, validate_events
from repro.runtime.chaos import ChaosSchedule, FaultEvent
from repro.serve import fit_engine as fe
from repro.serve.fleet import FitFleet, FleetConfig

CHUNK = 128


def _series(seed, n_lo=300, n_hi=900, k=4):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(k):
        n = int(rng.integers(n_lo, n_hi))
        x = np.sort(rng.uniform(-1, 1, n)).astype(np.float32)
        y = (0.3 - 1.2 * x + 0.5 * x ** 3
             + 0.02 * rng.normal(size=n)).astype(np.float32)
        out.append((x, y))
    return out


CHAOS = ChaosSchedule((FaultEvent(3, 1, "crash"),
                       FaultEvent(2, 2, "stall", 400),
                       FaultEvent(1, 3, "poison")))


def _run(seed=0, chaos=CHAOS, **kw):
    kw.setdefault("fit", fe.FitServeConfig(degree=5))
    kw.setdefault("n_workers", 4)
    kw.setdefault("chunk_width", CHUNK)
    kw.setdefault("trace", True)
    kw.setdefault("straggler_threshold", 2.0)
    fleet = FitFleet(FleetConfig(chaos=chaos, **kw))
    reqs = [fleet.submit(x, y, spec=api.FitSpec(degree=3))
            for x, y in _series(seed)]
    fleet.run(max_ticks=5000)
    return fleet, reqs


# -------------------------------------------------------- histogram sketch
def test_sketch_quantile_relative_error():
    rng = np.random.default_rng(0)
    data = np.exp(rng.normal(3.0, 1.5, 4000))     # heavy-tailed latencies
    h = HistogramSketch("lat", alpha=0.01)
    for v in data:
        h.observe(float(v))
    assert h.count == data.size
    for q in (0.1, 0.5, 0.9, 0.99):
        lo = float(np.quantile(data, q, method="lower"))
        hi = float(np.quantile(data, q, method="higher"))
        est = h.quantile(q)
        assert lo * (1 - 2 * h.alpha) <= est <= hi * (1 + 2 * h.alpha), \
            (q, lo, est, hi)


def test_sketch_no_sample_retention():
    h = HistogramSketch("lat", alpha=0.05)
    for v in np.linspace(1, 10_000, 100_000):
        h.observe(float(v))
    # 100k observations over 4 decades: O(log range / log gamma) buckets
    assert len(h.buckets) < 120
    assert h.count == 100_000


def test_sketch_zero_and_snapshot_roundtrip():
    h = HistogramSketch("lat", alpha=0.02)
    for v in (0.0, -1.0, 3.0, 900.0):
        h.observe(v)
    assert h.zero_count == 2
    assert h.quantile(0.0) == 0.0
    h2 = HistogramSketch.from_snapshot("lat", h.snapshot())
    for q in (0.0, 0.5, 0.99):
        assert h2.quantile(q) == h.quantile(q)
    assert h2.count == h.count and h2.zero_count == h.zero_count


@settings(max_examples=25)
@given(st.integers(0, 2 ** 16), st.integers(1, 60), st.integers(1, 60),
       st.integers(1, 60))
def test_sketch_merge_associative_commutative(seed, na, nb, nc):
    rng = np.random.default_rng(seed)
    parts = []
    for n in (na, nb, nc):
        h = HistogramSketch("m", alpha=0.01)
        for v in rng.exponential(50.0, n):
            h.observe(float(v))
        parts.append(h)
    a, b, c = parts

    def key(h):
        return (h.count, h.zero_count, h.min, h.max,
                tuple(sorted(h.buckets.items())))

    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    swapped = c.merge(a).merge(b)
    assert key(left) == key(right) == key(swapped)
    assert np.isclose(left.total, right.total) \
        and np.isclose(left.total, swapped.total)
    for q in (0.25, 0.5, 0.99):
        assert left.quantile(q) == right.quantile(q) == swapped.quantile(q)


@settings(max_examples=25)
@given(st.integers(0, 2 ** 16), st.floats(0.0, 1.0))
def test_sketch_merge_equals_union_stream(seed, q):
    """Merging two sketches answers quantiles exactly as one sketch fed
    the concatenated stream would (bucket counts are exact)."""
    rng = np.random.default_rng(seed)
    xs = rng.exponential(20.0, 40)
    ys = rng.exponential(200.0, 30)
    ha, hb, hu = (HistogramSketch("m", 0.01) for _ in range(3))
    for v in xs:
        ha.observe(float(v))
        hu.observe(float(v))
    for v in ys:
        hb.observe(float(v))
        hu.observe(float(v))
    assert ha.merge(hb).quantile(q) == hu.quantile(q)


def test_sketch_merge_alpha_mismatch_rejected():
    with pytest.raises(ValueError, match="alpha"):
        HistogramSketch("a", 0.01).merge(HistogramSketch("b", 0.05))


# --------------------------------------------------------------- registry
def test_registry_snapshot_deterministic_and_prometheus():
    reg = MetricsRegistry()
    reg.counter("completed").inc(3)
    reg.gauge("queue_depth").set(7)
    reg.gauge("queue_depth").set(2)
    reg.histogram("latency_ticks").observe(10)
    snap = reg.snapshot()
    assert snap["counters"] == {"completed": 3}
    assert snap["gauges"]["queue_depth"] == {"value": 2.0, "hwm": 7.0}
    assert reg.snapshot_json() == json.dumps(snap, sort_keys=True)
    text = reg.render_prometheus()
    assert "# TYPE completed counter\ncompleted 3" in text
    assert "queue_depth_hwm 7" in text
    assert 'latency_ticks{quantile="0.99"}' in text
    assert "latency_ticks_count 1" in text


def test_null_registry_records_nothing():
    NULL_REGISTRY.counter("x").inc(5)
    NULL_REGISTRY.gauge("g").set(3)
    NULL_REGISTRY.histogram("h").observe(1.0)
    assert NULL_REGISTRY.counter("x").value == 0
    assert NULL_REGISTRY.snapshot() == {"counters": {}, "gauges": {},
                                        "histograms": {}}


# ----------------------------------------------------------------- tracer
def test_tracer_idempotent_spans_and_validation():
    t = Tracer()
    t.instant(0, "submit", 0)
    t.instant(0, "admit", 1)
    t.begin(0, "ingest", 1)
    t.begin(0, "ingest", 2)          # re-begin: ignored, span kept
    t.end(0, "ingest", 3)
    t.end(0, "ingest", 4)            # double-end: dropped
    t.end(0, "solve", 4)             # end without begin: dropped
    t.instant(0, "respond", 5)
    assert [e["ph"] for e in t.events] == ["i", "i", "B", "E", "i"]
    assert validate_events(t.events) == []


def test_tracer_detects_missing_terminal_and_dangling_span():
    t = Tracer()
    t.instant(0, "admit", 1)
    problems = validate_events(t.events)
    assert any("terminal" in p for p in problems)
    t2 = Tracer()
    t2.instant(1, "admit", 1)
    t2.begin(1, "solve", 2)
    t2.instant(1, "respond", 3)
    assert any("open spans" in p for p in validate_events(t2.events))


# ------------------------------------------------------ fleet determinism
def test_chaos_seed_determinism_bit_identical_telemetry():
    """Same chaos schedule, two runs: byte-identical JSONL event log AND
    identical metric snapshots — telemetry is replayable evidence."""
    fleet_a, _ = _run(seed=0)
    fleet_b, _ = _run(seed=0)
    assert fleet_a.tracer.to_jsonl() == fleet_b.tracer.to_jsonl()
    assert fleet_a.metrics.snapshot_json() == fleet_b.metrics.snapshot_json()
    assert len(fleet_a.tracer.events) > 0


def test_fleet_span_chains_complete_under_chaos():
    fleet, reqs = _run(seed=1)
    assert validate_events(fleet.tracer.events) == []
    for r in reqs:
        names = fleet.tracer.names_for(r.uid)
        assert "submit" in names and "admit" in names
        assert sum(n in ("respond", "failed") for n in names) == 1
        # every surfaced replay/hedge is annotated in the chain
        assert names.count("replay") == r.replays
        if r.hedged:
            assert "hedge" in names


def test_fleet_stats_coverage_and_registry():
    """The old ad-hoc dict keys survive, and the coverage gaps are
    closed: hedge wins/losses, per-cause retries, queue-depth hwm."""
    fleet, reqs = _run(seed=0)
    s = fleet.stats
    for k in ("completed", "shed", "degraded", "failed", "replays",
              "hedges", "hedge_wins", "hedge_losses", "resends",
              "retries_timeout", "retries_invalid", "poisoned",
              "worker_deaths", "revivals"):
        assert k in s, k
    assert s["completed"] == len(reqs)
    assert s["hedge_wins"] + s["hedge_losses"] == sum(
        1 for r in reqs if r.hedged and r.done and not r.failed)
    assert s["retries_timeout"] + s["retries_invalid"] \
        == sum(r.retries for r in reqs)
    assert fleet.metrics.gauge("queue_depth").hwm >= 1
    # the registry IS the stats backing store
    assert fleet.metrics.counter("completed").value == s["completed"]


def test_latency_quantiles_from_sketch_mid_run():
    """Quantiles are sketch-backed: identical at both call sites and
    available mid-run, not only at shutdown."""
    fleet, reqs = _run(seed=0, chaos=None)
    q = fleet.latency_quantiles()
    h = fleet.metrics.histogram("latency_ticks")
    assert q["p50"] == h.quantile(0.5) and q["p99"] == h.quantile(0.99)
    assert h.count == len(reqs)
    lats = [r.latency_ticks for r in reqs]
    lo = float(np.quantile(lats, 0.5, method="lower"))
    hi = float(np.quantile(lats, 0.5, method="higher"))
    assert lo * 0.98 <= q["p50"] <= hi * 1.02
    # empty sketch: defined zeros, no retained samples anywhere
    empty = FitFleet(FleetConfig(fit=fe.FitServeConfig(degree=5)))
    assert empty.latency_quantiles() == {"p50": 0.0, "p99": 0.0}
    assert not hasattr(fleet, "latencies")


def test_fleet_snapshot_surfaces_obs():
    fleet, _ = _run(seed=0, chaos=None, slo_p99=500.0)
    snap = fleet.snapshot()
    assert snap["tick"] == fleet.tick
    assert snap["metrics"]["counters"]["completed"] == len(_series(0))
    assert "latency_ticks:p99" in snap["slo"]
    rep = snap["slo"]["latency_ticks:p99"]
    assert rep["threshold"] == 500.0 and not rep["breached"]


def test_trace_off_by_default_zero_events():
    fleet, reqs = _run(seed=0, chaos=None, trace=False)
    assert fleet.tracer.events == [] and not fleet.tracer.enabled
    assert fleet.stats["completed"] == len(reqs)   # metrics still live


# ------------------------------------------------------------ SLO monitor
def test_slo_monitor_forecasts_injected_ramp_before_breach():
    """The acceptance invariant: feed the monitor a latency ramp and it
    must flag the coming p99 breach while the metric is still BELOW the
    threshold, with a sane crossing-time estimate."""
    mon = SLOMonitor(metric="latency_ticks:p99", threshold=100.0,
                     decay=0.995)
    slope = 0.5
    tick = 0
    for tick in range(8, 8 * 16 + 1, 8):          # ramp: 10 + 0.5·tick
        mon.observe(tick, 10.0 + slope * tick)
    assert mon.ready
    assert mon.last_value < mon.threshold          # not yet breached...
    eta = mon.breach_eta(tick)
    assert eta is not None and eta > 0             # ...but forecast fires
    true_eta = (mon.threshold - (10.0 + slope * tick)) / slope
    assert 0.5 * true_eta <= eta <= 1.5 * true_eta, (eta, true_eta)
    assert mon.slope(tick) == pytest.approx(slope, rel=0.35)


def test_slo_monitor_flat_metric_never_breaches():
    mon = SLOMonitor(metric="queue_depth", threshold=50.0, decay=0.99)
    for tick in range(8, 200, 8):
        mon.observe(tick, 5.0 + (tick % 16 == 0))
    assert mon.breach_eta(192) is None
    rep = mon.report(192)
    assert rep["breached"] is False and rep["breach_eta_ticks"] is None


def test_slo_board_resolves_live_registry_refs():
    reg = MetricsRegistry()
    board = SLOBoard(reg)
    board.watch("latency_ticks:p99", threshold=100.0, decay=0.995)
    board.watch("queue_depth", threshold=64.0)
    h = reg.histogram("latency_ticks")
    rng = np.random.default_rng(0)
    tick = 0
    for step in range(24):
        tick = 8 * (step + 1)
        base = 5.0 + 0.4 * tick                    # injected latency ramp
        for v in base + rng.exponential(2.0, 16):
            h.observe(float(v))
        reg.gauge("queue_depth").set(3)
        board.update(tick)
    rep = board.report(tick)
    p99 = rep["latency_ticks:p99"]
    assert p99["value"] < 100.0                    # below threshold now
    assert p99["breach_eta_ticks"] is not None     # breach forecast fires
    assert board.breaching(tick, within=p99["breach_eta_ticks"] + 1) \
        == ["latency_ticks:p99"]
    assert rep["queue_depth"]["breach_eta_ticks"] is None


def test_resolve_metric_forms():
    reg = MetricsRegistry()
    reg.counter("completed").inc(4)
    reg.gauge("queue_depth").set(9)
    assert resolve_metric(reg, "completed") == 4
    assert resolve_metric(reg, "queue_depth") == 9
    assert resolve_metric(reg, "queue_depth:hwm") == 9
    assert resolve_metric(reg, "latency_ticks:p99") is None   # empty sketch
    reg.histogram("latency_ticks").observe(10.0)
    assert resolve_metric(reg, "latency_ticks:p50") \
        == pytest.approx(10.0, rel=0.02)
    with pytest.raises(ValueError, match="stat"):
        resolve_metric(reg, "latency_ticks:median")


def test_fleet_slo_board_live_under_ramp():
    """End-to-end dogfood: a fleet whose SLO board watches the live
    latency sketch keeps a current forecast via step()."""
    fleet, _ = _run(seed=0, chaos=None, slo_p99=1000.0, slo_every=1)
    for _ in range(8):          # idle ticks: the board keeps observing
        fleet.step()
    mon = fleet.slo.monitors["latency_ticks:p99"]
    assert mon.ready and mon.last_value == fleet.latency_quantiles()["p99"]
    assert mon.report(fleet.tick)["breached"] is False


# ------------------------------------------------------------ serve engine
def test_engine_obs_enabled_vs_null_identical_results():
    series = _series(3, k=3)

    def serve(obs):
        eng = fe.FitServeEngine(fe.FitServeConfig(degree=4), obs=obs)
        reqs = [eng.submit(x, y) for x, y in series]
        eng.run()
        return eng, reqs

    on = obs_lib.Observability.on()
    eng_on, reqs_on = serve(on)
    eng_off, reqs_off = serve(None)
    for a, b in zip(reqs_on, reqs_off):
        np.testing.assert_array_equal(a.coeffs, b.coeffs)
        assert a.sse == b.sse
    assert on.metrics.counter("submitted").value == len(series)
    assert on.metrics.counter("completed").value == len(series)
    assert on.metrics.histogram("points_per_fit").count == len(series)
    assert validate_events(on.tracer.events) == []
    for r in reqs_on:
        assert "respond" in on.tracer.names_for(r.uid)
    # the default engine records nothing and keeps no admit bookkeeping
    assert eng_off.obs is obs_lib.NULL_OBS
    assert eng_off.obs.tracer.events == []
    assert eng_off._admit_step == {}


# ------------------------------------------------------- async ingest/LSPIA
def test_ingestor_lag_gauge_and_counter_mirror():
    reg = MetricsRegistry()
    st0 = streaming.StreamState.create(2, dtype=np.float32)
    ing = streaming.AsyncChunkIngestor(st0, n_sources=2, staleness=2,
                                       metrics=reg)
    x = np.linspace(-1, 1, 8, dtype=np.float32)
    y = x ** 2
    for seq in range(1, 5):
        ing.offer(0, seq, x, y)                   # source 0 races ahead
    ing.offer(0, 2, x, y)                         # duplicate
    ing.offer(1, 1, x, y)
    assert reg.counter("chunks_applied").value == 5
    assert reg.counter("chunks_duplicate").value == ing.duplicates == 1
    assert reg.gauge("source_lag").value == ing.lag() == 3
    assert reg.gauge("source_lag").hwm == 4.0     # worst lag seen
    assert ing.stale_sources() == [1]


def test_async_lspia_stats_registry_backed():
    from repro.core.distributed import async_lspia_fit
    rng = np.random.default_rng(0)
    x = np.sort(rng.uniform(-1, 1, 600)).astype(np.float32)
    y = (0.5 + 0.8 * x - 0.4 * x ** 2).astype(np.float32)
    reg = MetricsRegistry()
    spec = api.FitSpec(degree=2, method="lspia")
    out = async_lspia_fit(x, y, spec, n_shards=2, registry=reg)
    assert out.converged
    assert out.metrics is reg
    for k in ("updates", "updates_during_stall", "stale_rejected",
              "poisoned", "resends", "duplicates", "crashes", "freezes"):
        assert out.stats[k] == reg.counter(k).value
    assert out.stats["updates"] > 0
    assert "staleness_lag" in reg.snapshot()["gauges"]
    assert "updates" in reg.render_prometheus()
