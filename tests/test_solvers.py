"""Property-based tests (hypothesis) for the solver layer and the fit's
mathematical invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro import core

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _well_conditioned_system(seed, m):
    rng = np.random.default_rng(seed)
    a = rng.normal(0, 1, (m, m))
    a = a @ a.T + m * np.eye(m)     # SPD, well conditioned
    b = rng.normal(0, 1, (m,))
    return jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)


@given(st.integers(0, 10_000), st.integers(1, 9))
def test_gaussian_elimination_solves(seed, m):
    a, b = _well_conditioned_system(seed, m)
    x = core.gaussian_elimination(a, b)
    np.testing.assert_allclose(np.asarray(a @ x), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


@given(st.integers(0, 10_000), st.integers(1, 9))
def test_gauss_matches_cholesky_on_spd(seed, m):
    a, b = _well_conditioned_system(seed, m)
    xg = core.gaussian_elimination(a, b)
    xc = core.cholesky_solve(a, b)
    np.testing.assert_allclose(np.asarray(xg), np.asarray(xc),
                               rtol=2e-3, atol=2e-3)


def test_gaussian_elimination_pivots():
    """Zero leading pivot requires row exchange — the paper's plain
    elimination would divide by zero; partial pivoting must handle it."""
    a = jnp.asarray([[0.0, 1.0], [1.0, 0.0]])
    b = jnp.asarray([2.0, 3.0])
    x = core.gaussian_elimination(a, b)
    np.testing.assert_allclose(np.asarray(x), [3.0, 2.0], rtol=1e-6)


def test_gaussian_elimination_batched():
    rng = np.random.default_rng(0)
    a = rng.normal(0, 1, (5, 4, 4)) + 4 * np.eye(4)
    b = rng.normal(0, 1, (5, 4))
    x = core.gaussian_elimination(jnp.asarray(a, jnp.float32),
                                  jnp.asarray(b, jnp.float32))
    for i in range(5):
        np.testing.assert_allclose(a[i] @ np.asarray(x[i], np.float64),
                                   b[i], rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------ fit invariants
@given(st.integers(0, 10_000), st.integers(0, 4), st.integers(8, 200))
def test_exact_polynomial_recovery(seed, degree, n):
    """Noise-free data from a degree-m polynomial is recovered exactly
    (interpolation property of least squares)."""
    rng = np.random.default_rng(seed)
    coeffs = rng.normal(0, 1, degree + 1)
    x = np.sort(rng.uniform(-2, 2, n))
    y = np.polyval(coeffs[::-1], x)
    poly = core.polyfit(jnp.asarray(x, jnp.float32),
                        jnp.asarray(y, jnp.float32), degree, normalize=True)
    np.testing.assert_allclose(np.asarray(poly.monomial_coeffs(), np.float64),
                               coeffs, rtol=5e-2, atol=5e-3)


@given(st.integers(0, 10_000), st.integers(1, 3))
def test_residual_orthogonality(seed, degree):
    """LSE optimality: residuals are orthogonal to every basis column —
    Vᵀ(y - Va) = 0. This is the defining property of the minimum."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, 64)
    y = rng.normal(0, 1, 64)
    xj = jnp.asarray(x, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    poly = core.polyfit(xj, yj, degree)
    resid = yj - poly(xj)
    v = core.vandermonde(xj, degree)
    ortho = np.asarray(jnp.einsum("nk,n->k", v, resid), np.float64)
    scale = np.asarray(jnp.einsum("nk,n->k", jnp.abs(v), jnp.abs(yj)))
    np.testing.assert_allclose(ortho / (scale + 1e-9), 0.0, atol=1e-4)


@given(st.integers(0, 10_000))
def test_fit_beats_any_perturbation(seed):
    """Σe² at the LSE solution is <= Σe² at perturbed coefficients (the
    paper's 'best-fit' claim as a property)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-2, 2, 50), jnp.float32)
    y = jnp.asarray(rng.normal(0, 1, 50), jnp.float32)
    poly = core.polyfit(x, y, 2)
    base = float(core.fit_report(poly, x, y).sse)
    for _ in range(5):
        delta = jnp.asarray(rng.normal(0, 0.05, 3), jnp.float32)
        pert = core.Polynomial(poly.coeffs + delta, poly.domain_shift,
                               poly.domain_scale)
        assert float(core.fit_report(pert, x, y).sse) >= base - 1e-3


@given(st.integers(0, 10_000), st.integers(1, 4))
def test_moments_additivity(seed, degree):
    """The core systems property: moments of a union = sum of moments.
    This is what makes the algorithm shard- and stream-able."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-2, 2, 64), jnp.float32)
    y = jnp.asarray(rng.normal(0, 1, 64), jnp.float32)
    whole = core.gram_moments(x, y, degree)
    parts = (core.gram_moments(x[:20], y[:20], degree)
             + core.gram_moments(x[20:], y[20:], degree))
    for f in ("gram", "vty", "yty", "count"):
        np.testing.assert_allclose(np.asarray(getattr(whole, f)),
                                   np.asarray(getattr(parts, f)),
                                   rtol=2e-4, atol=2e-4)


def test_blocked_equals_direct():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(-2, 2, 1000), jnp.float32)
    y = jnp.asarray(rng.normal(0, 1, 1000), jnp.float32)
    direct = core.gram_moments(x, y, 3)
    blocked = core.gram_moments_blocked(x, y, 3, block=128)
    np.testing.assert_allclose(np.asarray(direct.gram),
                               np.asarray(blocked.gram), rtol=2e-4, atol=1e-3)


def test_chebyshev_basis_better_conditioned():
    """Beyond-paper: Chebyshev Gram condition number << monomial Gram
    condition number for higher degrees on [-1, 1]."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-1, 1, 512), jnp.float32)
    y = jnp.asarray(rng.normal(0, 1, 512), jnp.float32)
    gm = core.gram_moments(x, y, 8, basis=core.MONOMIAL).gram
    gc = core.gram_moments(x, y, 8, basis=core.CHEBYSHEV).gram
    cm = np.linalg.cond(np.asarray(gm, np.float64))
    cc = np.linalg.cond(np.asarray(gc, np.float64))
    assert cc < cm / 100


def test_power_law_fit():
    x = jnp.asarray(np.linspace(1e3, 1e6, 200), jnp.float32)
    y = 5.0 * x ** -0.3 + 0.1
    law = core.fit_power_law(x, y)
    assert abs(float(law.exponent) + 0.3) < 0.05
    assert abs(float(law.scale) - 5.0) / 5.0 < 0.3
