"""Known-bad corpus for RL-RECOMPILE: every compile-cache hazard class."""
import dataclasses
import functools

import jax

_CACHE = {}


@dataclasses.dataclass
class SpecLike:
    name: str = "fit"
    knobs: dict = {}            # mutable dataclass default


@functools.partial(jax.jit, static_argnames=("spec",))
def solve(state, spec=[]):      # mutable default on a static parameter
    return state


@functools.partial(jax.jit, static_argnames=("degree",))
def sweep(state):               # static_argnames names a missing parameter
    return state


def lookup(spec):
    return _CACHE[f"{spec}"]    # f-string compile-cache key


def lookup_by_identity(spec):
    return _CACHE.get((id(spec), "x"))   # id() compile-cache key


def call_it(state):
    return solve(state, spec=["a"])      # mutable value at a static position
