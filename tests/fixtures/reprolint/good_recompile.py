"""Known-good corpus for RL-RECOMPILE: the hashable-statics discipline."""
import dataclasses
import functools

import jax

_CACHE = {}


@dataclasses.dataclass(frozen=True)
class SpecLike:
    name: str = "fit"
    knobs: tuple = ()
    tags: tuple = dataclasses.field(default=())


@functools.partial(jax.jit, static_argnames=("spec",))
def solve(state, spec=None):
    return state


def lookup(spec):
    key = (spec.name, spec.knobs)        # tuple of hashable statics
    return _CACHE[key]


def call_it(state, spec):
    return solve(state, spec=spec)
