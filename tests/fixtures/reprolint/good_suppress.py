"""Known-good corpus for RL-SUPPRESS: a well-formed reasoned disable."""


def fine():
    # reprolint: disable=RL-DTYPE — demo: reasoned disables are welcome
    return 1.0
