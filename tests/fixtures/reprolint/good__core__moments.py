"""Known-good corpus for RL-DTYPE: every width named, f32 throughout."""
import jax.numpy as jnp
import numpy as np


def gram_accumulate(gram, update):
    return gram + np.asarray(update, np.float32)


def normalize(vty):
    return vty.astype(np.float32)


def init_weight():
    return jnp.asarray(0.5, dtype=jnp.float32)


def scale(count):
    return np.zeros(8, dtype=np.float32)
