"""Known-good corpus for RL-TRACERLEAK: traced control flow stays traced."""
import jax
import jax.numpy as jnp


@jax.jit
def fit_step(state, x):
    ok = jnp.logical_not(jnp.any(jnp.isnan(x)))
    return jax.lax.cond(ok, lambda s: helper(s, x), lambda s: s, state)


def helper(state, x):
    total = jnp.sum(x)
    return state + jnp.where(total > 0, total, 0.0)


def scan_me(xs):
    def body(carry, x):
        return carry + x, x
    return jax.lax.scan(body, 0.0, xs)
