"""Known-good corpus for RL-PROTOCOL: closed vocabulary, typed raises,
acked ingests, terminal parity with the tracer."""
import dataclasses


class ProtocolError(RuntimeError):
    def __init__(self, where, kind):
        self.kind = kind
        super().__init__(f"{where}: unknown message kind {kind!r}")


@dataclasses.dataclass
class Ingest:
    key: int
    seq: int
    kind: str = "ingest"


@dataclasses.dataclass
class Solve:
    key: int
    kind: str = "solve"


@dataclasses.dataclass
class Ack:
    key: int
    seq: int
    kind: str = "ack"


@dataclasses.dataclass
class Result:
    key: int
    kind: str = "result"


class Worker:
    def __init__(self):
        self.applied = {}

    def process(self, msg, tick):
        if msg.kind == "ingest":
            applied = self.applied.get(msg.key, 0)
            if msg.seq != applied + 1:
                return [Ack(msg.key, applied)]   # duplicates still acked
            self.applied[msg.key] = msg.seq
            return [Ack(msg.key, msg.seq)]
        if msg.kind == "solve":
            return [Result(msg.key)]
        raise ProtocolError("worker", msg.kind)


class Fleet:
    def __init__(self, tracer):
        self.tracer = tracer

    def pump(self, worker, key, tick):
        for rep in worker.process(Ingest(key, 1), tick):
            self.handle(rep, tick)
        for rep in worker.process(Solve(key), tick):
            self.handle(rep, tick)

    def handle(self, rep, tick):
        if rep.kind == "ack":
            return
        if rep.kind == "result":
            self.finish(rep, tick)
            return
        raise ProtocolError("dispatcher", rep.kind)

    def finish(self, rep, tick):
        rep.done_tick = tick
        self.tracer.instant(rep.key, "respond", tick)

    def abandon(self, rep, tick):
        rep.done_tick = tick
        self.tracer.instant(rep.key, "failed", tick)
