"""Known-bad corpus for RL-PROTOCOL (opts into the serve/fleet.py scope
via its name): orphan message, silent-drop dispatch, unacked ingest,
non-terminal trace on a terminated request."""
import dataclasses


@dataclasses.dataclass
class Ingest:
    key: int
    seq: int
    kind: str = "ingest"


@dataclasses.dataclass
class Ack:
    key: int
    seq: int
    kind: str = "ack"


@dataclasses.dataclass
class Probe:
    key: int
    kind: str = "probe"


class Worker:
    def __init__(self):
        self.applied = {}

    def process(self, msg, tick):
        # closed-world violation: no ProtocolError on fallthrough
        if msg.kind == "ingest":
            applied = self.applied.get(msg.key, 0)
            if msg.seq != applied + 1:
                return []          # duplicate delivered but never acked
            self.applied[msg.key] = msg.seq
            return [Ack(msg.key, msg.seq)]
        return []


class Fleet:
    def __init__(self, tracer):
        self.tracer = tracer

    def ping(self, worker, key):
        worker.process(Probe(key), 0)    # "probe" has no handler anywhere

    def _fail(self, req, tick):
        req.done_tick = tick
        self.tracer.instant(req.uid, "gave-up", tick)   # not a terminal
