"""Known-bad corpus for RL-SUPPRESS: the suppression policy itself."""
import numpy as np


def sneaky():
    # reprolint: disable=RL-DTYPE
    return np.float64(1.0)       # reasonless disable does NOT suppress


def bogus():
    # reprolint: disable=RL-BOGUS — naming a code the suite doesn't define
    return 1.0
