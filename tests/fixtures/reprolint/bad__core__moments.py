"""Known-bad corpus for RL-DTYPE (opts into the core/moments.py scope
via its name): silent f32->f64 promotion on the moment path."""
import jax.numpy as jnp
import numpy as np


def gram_accumulate(gram, update):
    return gram + np.asarray(update, np.float64)    # explicit f64


def normalize(vty):
    return vty.astype(float)                        # Python float IS f64


def init_weight():
    return jnp.asarray(0.5)                         # weak-typed literal


def scale(count):
    return np.zeros(8, dtype=float)                 # dtype=float
