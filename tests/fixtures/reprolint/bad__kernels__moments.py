"""Known-bad corpus for RL-VMEM (opts into the kernels/moments.py scope
via its name): a tile width no configuration can fit, and a DMA that is
started but never waited on."""
import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

K_PAD = 128
DEFAULT_BLOCK_N = 16384          # ring needs >17 MB even at packing 1


def leaky_db_kernel(x_hbm, g_ref, *, block_n, n_blocks, nbuf):
    def body(xs, sem):
        def dmas(slot, i):
            sl = pl.ds(i * block_n, block_n)
            return (pltpu.make_async_copy(x_hbm.at[sl], xs.at[slot],
                                          sem.at[slot]),)

        for d in dmas(0, 0):
            d.start()            # started, never waited: races the MXU

        def step(i, _):
            return 0

        jax.lax.fori_loop(0, n_blocks, step, 0)

    pl.run_scoped(body, xs=pltpu.VMEM((nbuf, 1, block_n), x_hbm.dtype),
                  sem=pltpu.SemaphoreType.DMA((nbuf,)))
