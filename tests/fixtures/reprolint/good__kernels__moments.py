"""Known-good corpus for RL-VMEM: the committed double-buffered ring —
feasible tile width, start/wait paired, semaphores scoped."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

K_PAD = 128
DEFAULT_BLOCK_N = 4096


def ring_db_kernel(x_hbm, g_ref, *, block_n, n_blocks, nbuf):
    def body(xs, sem):
        g_ref[...] = jnp.zeros_like(g_ref)

        def dmas(slot, i):
            sl = pl.ds(i * block_n, block_n)
            return (pltpu.make_async_copy(x_hbm.at[sl], xs.at[slot],
                                          sem.at[slot]),)

        for d in dmas(0, 0):
            d.start()

        def step(i, _):
            slot = jax.lax.rem(i, nbuf)
            nxt = jax.lax.rem(i + 1, nbuf)

            @pl.when(i + 1 < n_blocks)
            def _prefetch():
                for d in dmas(nxt, i + 1):
                    d.start()

            for d in dmas(slot, i):
                d.wait()
            return 0

        jax.lax.fori_loop(0, n_blocks, step, 0)

    pl.run_scoped(body, xs=pltpu.VMEM((nbuf, 1, block_n), x_hbm.dtype),
                  sem=pltpu.SemaphoreType.DMA((nbuf,)))
