"""Known-good corpus for RL-DETERMINISM: seeded, tick-driven, sorted."""
import numpy as np


def jitter_backoff(attempt, seed):
    rng = np.random.default_rng(seed)    # explicit seed threads through
    return rng.uniform() * attempt


def now_tick(tick):
    return tick                          # time is the injected tick


def drain(pending):
    for item in sorted(pending):         # deterministic order
        handle(item)


def handle(item):
    return item
