"""Companion terminal vocabulary for the protocol fixtures — the same
shape as ``repro.obs.trace``, resolved by the RL-PROTOCOL checker's
sibling-file fallback."""

TERMINAL = ("respond", "failed")
