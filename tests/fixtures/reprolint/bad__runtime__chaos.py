"""Known-bad corpus for RL-DETERMINISM (opts into the runtime/chaos.py
scope via its name): wall clock, unseeded RNG, set-iteration order."""
import time

import numpy as np


def jitter_backoff(attempt):
    rng = np.random.default_rng()        # unseeded: OS entropy
    return rng.uniform() * attempt


def now_tick():
    return time.time()                   # wall clock in the tick domain


def drain(pending):
    for item in set(pending):            # hash-order iteration
        handle(item)


def handle(item):
    return item


def shuffle_faults(kinds):
    return np.random.permutation(kinds)  # global RNG stream
