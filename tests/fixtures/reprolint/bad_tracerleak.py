"""Known-bad corpus for RL-TRACERLEAK: concretization + host callbacks."""
import jax
import jax.numpy as jnp


@jax.jit
def fit_step(state, x):
    if jnp.any(jnp.isnan(x)):            # Python if on a traced value
        return state
    return helper(state, x)


def helper(state, x):
    while jnp.sum(x) > 0:                # Python while, jit-reachable
        x = x - 1.0
    return state


def scan_me(xs):
    def body(carry, x):
        print("step", x)                 # host callback inside a scan body
        return carry + x, x
    return jax.lax.scan(body, 0.0, xs)
