"""Continuous-batching fit server: parity with direct polyfit on ragged
traces, chunked ingest of long series, and the no-recompile invariant."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.serve import FitRequest, FitServeConfig, FitServeEngine


def _trace(seed, n_reqs, lo, hi, degree=3):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_reqs):
        n = int(rng.integers(lo, hi + 1))
        x = rng.uniform(-2, 2, n).astype(np.float32)
        coef = rng.normal(0, 1, degree + 1)
        y = (np.polyval(coef[::-1], x)
             + rng.normal(0, 0.1, n)).astype(np.float32)
        out.append((x, y))
    return out


def _assert_matches_polyfit(reqs: list[FitRequest], degree, atol=5e-4):
    for r in reqs:
        assert r.done and r.count == r.n
        ref = core.polyfit(jnp.asarray(r.x), jnp.asarray(r.y), degree)
        np.testing.assert_allclose(r.coeffs, np.asarray(ref.coeffs),
                                   rtol=5e-3, atol=atol,
                                   err_msg=f"req {r.uid} n={r.n}")


def test_ragged_trace_matches_direct_polyfit():
    eng = FitServeEngine(FitServeConfig(degree=3, n_slots=4,
                                        buckets=(64, 256), ridge=1e-9))
    reqs = [eng.submit(x, y) for x, y in _trace(0, 25, 5, 700)]
    eng.run()
    assert eng.fits_done == 25
    _assert_matches_polyfit(reqs, 3)


def test_long_series_streams_through_small_bucket():
    """A series much longer than every bucket ingests chunk-by-chunk."""
    eng = FitServeEngine(FitServeConfig(degree=2, n_slots=2,
                                        buckets=(128,), ridge=1e-9))
    (x, y), = _trace(1, 1, 5000, 5000, degree=2)
    req = eng.submit(x, y)
    eng.run()
    assert req.done and req.count == 5000
    _assert_matches_polyfit([req], 2)


def test_zero_recompiles_across_request_churn():
    eng = FitServeEngine(FitServeConfig(degree=3, n_slots=3,
                                        buckets=(64, 256), ridge=1e-9))
    warm = eng.warmup()
    # one fused ingest+fixed-solve per bucket + one auto-degree sweep +
    # one plain mid-series ingest for the widest bucket (the default
    # fixed solve is inlined into the fused executable, so the
    # standalone solve cache stays empty until a NOVEL spec arrives)
    assert warm == len(eng.buckets) + 2
    for x, y in _trace(2, 8, 5, 500):
        eng.submit(x, y)
    eng.run()
    assert eng.compiled_executables() == warm
    reqs = [eng.submit(x, y) for x, y in _trace(3, 30, 5, 500)]
    autos = [eng.submit(x, y, degree="auto")
             for x, y in _trace(4, 6, 5, 500)]
    eng.run()
    assert eng.compiled_executables() == warm
    assert all(r.done and r.degree is not None for r in autos)
    _assert_matches_polyfit(reqs, 3)


def test_slot_reuse_isolates_requests():
    """Back-to-back occupants of the same slot don't contaminate each other:
    serve a constant series after a wild one, slot pool of 1."""
    eng = FitServeEngine(FitServeConfig(degree=1, n_slots=1,
                                        buckets=(32,), ridge=1e-9))
    rng = np.random.default_rng(4)
    wild_x = rng.uniform(-100, 100, 200).astype(np.float32)
    wild_y = rng.normal(0, 1000, 200).astype(np.float32)
    eng.submit(wild_x, wild_y)
    x = np.linspace(-1, 1, 30).astype(np.float32)
    clean = eng.submit(x, (2.0 + 3.0 * x).astype(np.float32))
    eng.run()
    np.testing.assert_allclose(clean.coeffs, [2.0, 3.0], rtol=1e-4,
                               atol=1e-4)


def test_kernel_engine_path():
    """Forced packed-kernel ingest (interpret mode on CPU) serves correctly."""
    eng = FitServeEngine(FitServeConfig(degree=3, n_slots=3, buckets=(128,),
                                        engine="kernel", ridge=1e-9))
    reqs = [eng.submit(x, y) for x, y in _trace(5, 4, 20, 200)]
    eng.run()
    _assert_matches_polyfit(reqs, 3)


def test_report_quality_fields():
    eng = FitServeEngine(FitServeConfig(degree=2, n_slots=2, buckets=(256,),
                                        ridge=1e-9))
    rng = np.random.default_rng(6)
    x = rng.uniform(-2, 2, 400).astype(np.float32)
    y = (x ** 2 + rng.normal(0, 0.05, 400)).astype(np.float32)
    req = eng.submit(x, y)
    eng.run()
    rep = core.fit_report(core.polyfit(jnp.asarray(x), jnp.asarray(y), 2),
                          jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(req.sse, float(rep.sse), rtol=5e-3, atol=1e-3)
    np.testing.assert_allclose(req.r, float(rep.r), rtol=1e-3)


def test_submit_validation():
    eng = FitServeEngine(FitServeConfig(n_slots=1, buckets=(32,)))
    with pytest.raises(ValueError):
        eng.submit(np.ones(3), np.ones(4))
    with pytest.raises(ValueError):
        eng.submit(np.ones(0), np.ones(0))
    with pytest.raises(ValueError, match="determine"):
        # degree-3 default: an underdetermined request is rejected up front
        eng.submit(np.ones(2), np.ones(2))
    with pytest.raises(ValueError):
        FitServeEngine(FitServeConfig(buckets=(256, 64)))


def test_fused_solve_matches_standalone_solve():
    """The fused ingest+solve answers the default spec from the SAME
    ``_spec_solve_from_state`` the standalone per-spec solve traces, so
    re-solving the bucket's post-ingest state standalone reproduces the
    served result."""
    eng = FitServeEngine(FitServeConfig(degree=3, n_slots=2,
                                        buckets=(128,), ridge=1e-9))
    reqs = [eng.submit(x, y) for x, y in _trace(13, 2, 100, 100, degree=3)]
    eng.run()
    assert all(r.done for r in reqs)
    b = eng.buckets[0]
    coeffs, sse, r, count, cond, fb = (np.asarray(a) for a in
                                       eng._solve(b.state, eng.fixed_spec))
    for s, req in enumerate(reqs):
        np.testing.assert_array_equal(req.coeffs, coeffs[s, :4])
        np.testing.assert_array_equal(req.sse, sse[s])
        np.testing.assert_array_equal(req.r, r[s])
