"""Chunked gated-linear-recurrence engine vs the sequential oracle, across
decay regimes (incl. Mamba2-extreme), modes, chunk sizes, and state carry."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import gla

settings.register_profile("gla", deadline=None, max_examples=15)
settings.load_profile("gla")


def _inputs(seed, b, h, t, dk, dv, decay_scale, scalar_decay=False):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (b, h, t, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, h, t, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, h, t, dv)), jnp.float32)
    shape = (b, h, t, 1) if scalar_decay else (b, h, t, dk)
    logw = jnp.asarray(-np.abs(rng.normal(decay_scale, decay_scale / 2,
                                          shape)), jnp.float32)
    u = jnp.asarray(rng.normal(0, 1, (h, dk)), jnp.float32)
    return q, k, v, logw, u


@pytest.mark.parametrize("mode", ["inclusive", "bonus"])
@pytest.mark.parametrize("decay", [0.05, 1.0, 8.0])
@pytest.mark.parametrize("chunk", [16, 64])
def test_chunked_matches_sequential(mode, decay, chunk):
    q, k, v, logw, u = _inputs(0, 2, 2, 128, 16, 8, decay)
    y1, s1 = gla.chunked_gla(q, k, v, logw, u=u, chunk=chunk, mode=mode)
    y2, s2 = gla.reference_recurrence(q, k, v, logw, u=u, mode=mode)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=3e-3, atol=3e-3)
    assert bool(jnp.all(jnp.isfinite(y1)))


@pytest.mark.parametrize("mode", ["inclusive", "bonus"])
def test_scalar_decay_broadcast(mode):
    """Mamba2-style per-head scalar decay (logw last dim == 1)."""
    q, k, v, logw, u = _inputs(1, 2, 3, 64, 16, 16, 6.0, scalar_decay=True)
    y1, s1 = gla.chunked_gla(q, k, v, logw, u=u, chunk=32, mode=mode)
    y2, s2 = gla.reference_recurrence(q, k, v, logw, u=u, mode=mode)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("mode", ["inclusive", "bonus"])
def test_state_carry_across_calls(mode):
    """Running two halves with carried state == one full call (prefill
    correctness)."""
    q, k, v, logw, u = _inputs(2, 1, 2, 128, 8, 8, 0.5)
    y, s = gla.chunked_gla(q, k, v, logw, u=u, chunk=32, mode=mode)
    half = 64
    ya, sa = gla.chunked_gla(q[:, :, :half], k[:, :, :half], v[:, :, :half],
                             logw[:, :, :half], u=u, chunk=32, mode=mode)
    yb, sb = gla.chunked_gla(q[:, :, half:], k[:, :, half:], v[:, :, half:],
                             logw[:, :, half:], u=u, initial_state=sa,
                             chunk=32, mode=mode)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([ya, yb], 2)),
                               np.asarray(y), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(sb), np.asarray(s),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("mode", ["inclusive", "bonus"])
def test_decode_steps_match_chunked(mode):
    """T decode steps == chunked training pass (train/serve parity)."""
    t = 32
    q, k, v, logw, u = _inputs(3, 1, 2, t, 8, 8, 0.3)
    y_train, _ = gla.chunked_gla(q, k, v, logw, u=u, chunk=16, mode=mode)
    state = jnp.zeros((1, 2, 8, 8), jnp.float32)
    outs = []
    for i in range(t):
        yi, state = gla.gla_decode_step(q[:, :, i], k[:, :, i], v[:, :, i],
                                        logw[:, :, i], state, u=u, mode=mode)
        outs.append(yi)
    y_decode = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(y_decode), np.asarray(y_train),
                               rtol=3e-3, atol=3e-3)


@given(st.integers(0, 10_000), st.sampled_from([16, 32]),
       st.floats(0.01, 10.0))
def test_property_sweep(seed, chunk, decay):
    q, k, v, logw, u = _inputs(seed, 1, 1, 64, 8, 4, decay)
    y1, _ = gla.chunked_gla(q, k, v, logw, u=u, chunk=chunk, mode="bonus")
    y2, _ = gla.reference_recurrence(q, k, v, logw, u=u, mode="bonus")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=5e-3, atol=5e-3)
