"""Fault-tolerance runtime: heartbeat detection, restart policy, elastic
planning, straggler reslicing, chaos-injected detector behavior,
serve-engine behavior, data-pipeline determinism/elasticity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.data import DataConfig, TokenPipeline
from repro.models import get_model
from repro.runtime import (ChaosSchedule, ChaosWorker, ElasticPlan,
                           FailureDetector, FaultEvent, HeartbeatTracker,
                           RestartPolicy, ResliceAction, plan_reslice)
from repro.serve import EngineConfig, ServeEngine
from repro.train.monitors import StepTimeMonitor


def test_heartbeat_detects_dead_host():
    hb = HeartbeatTracker(n_hosts=4, timeout_s=10.0)
    now = 1000.0
    for h in range(4):
        hb.beat(h, now)
    hb.beat(2, now + 100)
    assert hb.dead_hosts(now + 105) == [0, 1, 3]
    assert hb.dead_hosts(now + 5) == []


def test_restart_policy_backoff_and_budget():
    rp = RestartPolicy(max_restarts=3, base_backoff_s=1.0,
                       max_backoff_s=10.0, jitter=None)
    bs = [rp.next_backoff() for _ in range(4)]
    assert bs[0] == 1.0 and bs[1] == 2.0 and bs[2] == 4.0
    assert bs[3] is None            # budget exhausted


@given(st.integers(0, 10_000), st.integers(1, 20))
@settings(max_examples=30, deadline=None)
def test_restart_policy_jitter_properties(seed, max_restarts):
    """Decorrelated jitter: every draw lands in [base, max], the budget
    exhausts to None exactly after max_restarts, and two policies with the
    same seed replay identically."""
    base, cap = 1.5, 12.0
    rp = RestartPolicy(max_restarts=max_restarts, base_backoff_s=base,
                       max_backoff_s=cap, seed=seed)
    draws = [rp.next_backoff() for _ in range(max_restarts + 3)]
    good, exhausted = draws[:max_restarts], draws[max_restarts:]
    assert all(b is not None and base <= b <= cap for b in good)
    assert all(b is None for b in exhausted)
    twin = RestartPolicy(max_restarts=max_restarts, base_backoff_s=base,
                         max_backoff_s=cap, seed=seed)
    assert [twin.next_backoff() for _ in range(max_restarts)] == good


def test_restart_policy_rejects_bad_config():
    with pytest.raises(ValueError, match="jitter"):
        RestartPolicy(jitter="bogus")
    with pytest.raises(ValueError, match="backoff"):
        RestartPolicy(base_backoff_s=5.0, max_backoff_s=1.0)


def test_elastic_plan_shrinks_data_axis():
    plan = ElasticPlan.plan(surviving_hosts=6, chips_per_host=4,
                            model_parallel=8, resume_step=120)
    assert plan.mesh_shape == (3, 8)    # 24 chips / tp8
    assert plan.resume_step == 120


def test_failure_detector_combines_signals():
    det = FailureDetector(n_hosts=4, timeout_s=60.0,
                          straggler_threshold=1.4)
    rng = np.random.default_rng(0)
    for step in range(10):
        t = 1.0 + rng.normal(0, 0.02, 4)
        t[1] = 2.5
        det.observe_step(step, t, now=1000.0 + step)
    v = det.verdict(10, now=1010.0)
    assert v["stragglers"] == [1]
    assert v["dead"] == []
    assert not v["healthy"]


# --------------------------------------------------------------- reslicing
def _monitor_with_levels(levels, steps=6):
    mon = StepTimeMonitor(len(levels), decay=0.5)
    for s in range(steps):
        mon.observe(s, np.asarray(levels, float))
    return mon, steps - 1


def test_plan_reslice_shrinks_slow_host_share():
    mon, step = _monitor_with_levels([1.0, 1.0, 4.0, 1.0])
    act = plan_reslice(mon, step, global_batch=64, min_share=2)
    assert isinstance(act, ResliceAction)
    assert act.total == 64
    assert all(s >= 2 for s in act.shares)
    assert act.shares[2] == min(act.shares)    # slow host gets least work


def test_plan_reslice_raises_when_batch_below_floor():
    mon, step = _monitor_with_levels([1.0, 1.0, 1.0, 1.0])
    with pytest.raises(ValueError, match="min_share"):
        plan_reslice(mon, step, global_batch=7, min_share=2)


def test_plan_reslice_min_share_clamp_converges_multipass():
    """One extreme straggler among many hosts: the min_share clamp
    overshoots the batch by more than one unit per host, forcing the
    shrink loop through several passes — the single-pass bug returned
    shares summing past the global batch here."""
    mon, step = _monitor_with_levels([1.0, 1000.0, 1000.0, 1000.0])
    # raw ≈ [8.97, .009, .009, .009] → floor+clamp = [8, 2, 2, 2] = 14,
    # five units over the batch of 9: the fast host must shed 5, one per
    # pass, so the loop runs five times before converging to [3, 2, 2, 2]
    act = plan_reslice(mon, step, global_batch=9, min_share=2)
    assert act.total == 9
    assert all(s >= 2 for s in act.shares)
    assert act.shares[0] == 3
    # exactly at the floor: every host gets min_share, nothing else fits
    act = plan_reslice(mon, step, global_batch=8, min_share=2)
    assert act.shares == (2, 2, 2, 2)


# ------------------------------------------------- chaos-injected detection
def _tick_worker(events):
    """A no-op mailbox worker under a chaos schedule."""
    class _Inner:
        def process(self, msg, tick):
            return []

        def reset(self):
            pass

    return ChaosWorker(_Inner(), 0, events)


def test_failure_detector_flags_chaos_heartbeat_loss():
    """A chaos crash stops the worker's heartbeats; the detector must
    call it dead after the timeout — on the injected virtual clock, no
    wall sleeps anywhere."""
    wk = _tick_worker((FaultEvent(5, 0, "crash"),))
    det = FailureDetector(n_hosts=1, timeout_s=3.0)
    deaths = []
    for tick in range(1, 12):
        wk.begin_tick(tick)
        if wk.alive:
            det.hb.beat(0, float(tick))
        v = det.verdict(tick, now=float(tick))
        if v["dead"]:
            deaths.append(tick)
    # alive through tick 4, beats stop at 5, timeout_s=3 → dead from 8 on
    assert deaths == [8, 9, 10, 11]


def test_failure_detector_flags_chaos_persistent_straggler():
    """A chaos stall shows up as inflated observed step times; the fitted
    verdict must flag that worker and ElasticPlan must replan without a
    restart."""
    wk = _tick_worker((FaultEvent(4, 0, "stall", 100),))
    det = FailureDetector(n_hosts=3, timeout_s=50.0,
                          straggler_threshold=1.5)
    step = 0
    for tick in range(1, 20):
        wk.begin_tick(tick)
        times = np.asarray([5.0 if wk.stalled(tick) else 1.0, 1.0, 1.0])
        det.observe_step(step, times, now=float(tick))
        step += 1
    v = det.verdict(step, now=19.0)
    assert v["stragglers"] == [0]
    assert v["dead"] == []        # stalled, not dead: it still heartbeats
    # evict the straggler and replan the mesh around the survivors
    survivors = [h for h in range(3) if h not in v["stragglers"]]
    plan = ElasticPlan.plan(surviving_hosts=len(survivors),
                            chips_per_host=4, model_parallel=4,
                            resume_step=7)
    assert plan.mesh_shape == (2, 4)
    assert plan.resume_step == 7


# ------------------------------------------------------------ data pipeline
def test_pipeline_deterministic_and_host_sharded():
    d = DataConfig(vocab_size=128, seq_len=16, global_batch=8)
    a0 = TokenPipeline(d, host_id=0, n_hosts=2)
    a1 = TokenPipeline(d, host_id=1, n_hosts=2)
    full = TokenPipeline(d, host_id=0, n_hosts=1)
    b0, b1, bf = a0.next(), a1.next(), full.next()
    assert b0["tokens"].shape == (4, 16)
    assert bf["tokens"].shape == (8, 16)
    # host shards differ
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))
    # restart determinism
    a0b = TokenPipeline(d, host_id=0, n_hosts=2)
    np.testing.assert_array_equal(np.asarray(a0b.next()["tokens"]),
                                  np.asarray(b0["tokens"]))
    # labels are shifted tokens
    np.testing.assert_array_equal(np.asarray(b0["labels"][:, :-1]),
                                  np.asarray(b0["tokens"][:, 1:]))


def test_pipeline_state_roundtrip():
    d = DataConfig(vocab_size=64, seq_len=8, global_batch=2)
    p = TokenPipeline(d)
    p.next(); p.next()
    st = p.state()
    want = p.next()
    q = TokenPipeline(d)
    q.restore(st)
    got = q.next()
    np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                  np.asarray(want["tokens"]))


# ------------------------------------------------------------ serve engine
def test_serve_engine_continuous_batching():
    cfg = configs.get_smoke_config("internlm2-1.8b")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, EngineConfig(n_slots=2, max_len=64))
    reqs = [eng.submit([5, 6, 7], max_new_tokens=5) for _ in range(5)]
    eng.run(max_steps=100)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 5 or
               (r.out_tokens and r.out_tokens[-1] == eng.ecfg.eos_id)
               for r in reqs)


def test_serve_greedy_matches_decode_loop():
    """Engine greedy output == hand-rolled prefill+decode loop."""
    cfg = configs.get_smoke_config("yi-6b")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompt = [5, 9, 13, 21]
    n_new = 6

    logits, state = model.prefill(params, {"tokens": jnp.asarray([prompt])},
                                  64)
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    want = [int(tok[0, 0])]
    for _ in range(n_new - 1):
        logits, state = model.decode_step(params, tok, state)
        tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
        want.append(int(tok[0, 0]))

    eng = ServeEngine(model, params, EngineConfig(n_slots=1, max_len=64,
                                                  eos_id=-1))
    req = eng.submit(prompt, max_new_tokens=n_new, temperature=0.0)
    eng.run(max_steps=50)
    assert req.out_tokens == want
