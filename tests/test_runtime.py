"""Fault-tolerance runtime: heartbeat detection, restart policy, elastic
planning, serve-engine behavior, data-pipeline determinism/elasticity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import DataConfig, TokenPipeline
from repro.models import get_model
from repro.runtime import (ElasticPlan, FailureDetector, HeartbeatTracker,
                           RestartPolicy)
from repro.serve import EngineConfig, ServeEngine


def test_heartbeat_detects_dead_host():
    hb = HeartbeatTracker(n_hosts=4, timeout_s=10.0)
    now = 1000.0
    for h in range(4):
        hb.beat(h, now)
    hb.beat(2, now + 100)
    assert hb.dead_hosts(now + 105) == [0, 1, 3]
    assert hb.dead_hosts(now + 5) == []


def test_restart_policy_backoff_and_budget():
    rp = RestartPolicy(max_restarts=3, base_backoff_s=1.0, max_backoff_s=10.0)
    bs = [rp.next_backoff() for _ in range(4)]
    assert bs[0] == 1.0 and bs[1] == 2.0 and bs[2] == 4.0
    assert bs[3] is None            # budget exhausted


def test_elastic_plan_shrinks_data_axis():
    plan = ElasticPlan.plan(surviving_hosts=6, chips_per_host=4,
                            model_parallel=8, resume_step=120)
    assert plan.mesh_shape == (3, 8)    # 24 chips / tp8
    assert plan.resume_step == 120


def test_failure_detector_combines_signals():
    det = FailureDetector(n_hosts=4, timeout_s=60.0,
                          straggler_threshold=1.4)
    rng = np.random.default_rng(0)
    for step in range(10):
        t = 1.0 + rng.normal(0, 0.02, 4)
        t[1] = 2.5
        det.observe_step(step, t, now=1000.0 + step)
    v = det.verdict(10, now=1010.0)
    assert v["stragglers"] == [1]
    assert v["dead"] == []
    assert not v["healthy"]


# ------------------------------------------------------------ data pipeline
def test_pipeline_deterministic_and_host_sharded():
    d = DataConfig(vocab_size=128, seq_len=16, global_batch=8)
    a0 = TokenPipeline(d, host_id=0, n_hosts=2)
    a1 = TokenPipeline(d, host_id=1, n_hosts=2)
    full = TokenPipeline(d, host_id=0, n_hosts=1)
    b0, b1, bf = a0.next(), a1.next(), full.next()
    assert b0["tokens"].shape == (4, 16)
    assert bf["tokens"].shape == (8, 16)
    # host shards differ
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))
    # restart determinism
    a0b = TokenPipeline(d, host_id=0, n_hosts=2)
    np.testing.assert_array_equal(np.asarray(a0b.next()["tokens"]),
                                  np.asarray(b0["tokens"]))
    # labels are shifted tokens
    np.testing.assert_array_equal(np.asarray(b0["labels"][:, :-1]),
                                  np.asarray(b0["tokens"][:, 1:]))


def test_pipeline_state_roundtrip():
    d = DataConfig(vocab_size=64, seq_len=8, global_batch=2)
    p = TokenPipeline(d)
    p.next(); p.next()
    st = p.state()
    want = p.next()
    q = TokenPipeline(d)
    q.restore(st)
    got = q.next()
    np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                  np.asarray(want["tokens"]))


# ------------------------------------------------------------ serve engine
def test_serve_engine_continuous_batching():
    cfg = configs.get_smoke_config("internlm2-1.8b")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, EngineConfig(n_slots=2, max_len=64))
    reqs = [eng.submit([5, 6, 7], max_new_tokens=5) for _ in range(5)]
    eng.run(max_steps=100)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 5 or
               (r.out_tokens and r.out_tokens[-1] == eng.ecfg.eos_id)
               for r in reqs)


def test_serve_greedy_matches_decode_loop():
    """Engine greedy output == hand-rolled prefill+decode loop."""
    cfg = configs.get_smoke_config("yi-6b")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompt = [5, 9, 13, 21]
    n_new = 6

    logits, state = model.prefill(params, {"tokens": jnp.asarray([prompt])},
                                  64)
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    want = [int(tok[0, 0])]
    for _ in range(n_new - 1):
        logits, state = model.decode_step(params, tok, state)
        tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
        want.append(int(tok[0, 0]))

    eng = ServeEngine(model, params, EngineConfig(n_slots=1, max_len=64,
                                                  eos_id=-1))
    req = eng.submit(prompt, max_new_tokens=n_new, temperature=0.0)
    eng.run(max_steps=50)
    assert req.out_tokens == want
