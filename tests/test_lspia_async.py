"""Async-LSPIA (ISSUE 8): barrier-free distributed fitting with momentum.

The committed invariants:

* the asynchronous staleness-bounded iteration reaches the SAME fixed
  point as the synchronous sweep (arXiv:2211.06556) — including under a
  chaos-stalled shard, where it must keep making progress instead of
  waiting at a barrier;
* heavy-ball momentum (PIA-with-memory, arXiv:1908.06417) cuts
  iterations-to-tol by >= 2x on the committed workload;
* the step-size clamp keeps the iteration finite on adversarial spectra
  where the power-iteration estimate has not settled;
* the same staleness vocabulary governs streaming chunk ingestion
  (``AsyncChunkIngestor``): a slow source never stalls state updates;
* the fleet's sharded async ingest (``submit_async_lspia``) serves
  partial answers while a shard straggles and lands the exact merged
  answer when it arrives.

Everything runs on virtual ticks — no wall-clock sleeps, deterministic.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api.spec import FitSpec, LSPIAOptions
from repro.core import distributed, lspia, polyfit, streaming
from repro.core.fit import fit_from_moments
from repro.engine.plan import NumericsPolicy
from repro.runtime.chaos import ChaosSchedule, FaultEvent
from repro.serve import fit_engine as fe
from repro.serve.fleet import FitFleet, FleetConfig


def _workload(n=4096, seed=5):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(np.sort(rng.uniform(-3.0, 3.0, n)), jnp.float32)
    y = jnp.asarray(np.sin(np.asarray(x)) + 0.02 * rng.normal(0, 1, n),
                    jnp.float32)
    return x, y


def _spec(**lspia_kw):
    # normalize=True: LSPIA needs the [-1, 1] domain map for a contractive
    # Chebyshev iteration (the lspia_fit shim defaults it on; FitSpec's
    # NumericsPolicy defaults it off)
    return FitSpec(degree=5, basis="chebyshev", method="lspia",
                   numerics=NumericsPolicy(solver="auto", normalize=True),
                   lspia=LSPIAOptions(**lspia_kw))


# ------------------------------------------------------- async fixed point
def test_async_matches_sync_fixed_point():
    x, y = _workload()
    sync = lspia.lspia_fit(x, y, 5, basis="chebyshev")
    assert bool(sync.converged)
    af = distributed.async_lspia_fit(x, y, _spec(), n_shards=4)
    assert bool(af.converged)
    # same fixed point: compare predictions (domain-free), kappa-scaled tol
    cond = float(af.poly.diagnostics.condition)
    tol = 50 * np.finfo(np.float32).eps * max(cond, 1.0)
    gap = float(jnp.max(jnp.abs(af.poly(x) - sync.poly(x))))
    assert gap <= max(tol, 1e-4), (gap, tol)
    assert af.stats["updates"] == af.iterations


def test_async_converges_past_stalled_shard():
    """One shard stalls for a long window mid-fit.  The coordinator must
    keep updating from the live shards (no global barrier), reject the
    stalled shard's out-of-window contribution, verdict it a straggler
    and re-slice work away from it — and still land on the sync answer."""
    x, y = _workload()
    sync = lspia.lspia_fit(x, y, 5, basis="chebyshev")
    chaos = ChaosSchedule((FaultEvent(tick=5, worker=1, kind="stall",
                                      duration=40),))
    af = distributed.async_lspia_fit(x, y, _spec(), n_shards=4, chaos=chaos)
    assert bool(af.converged)
    # progress DURING the stall is the whole point of going barrier-free
    assert af.stats["updates_during_stall"] > 0
    # the paper's own LSE on reply gaps verdicts the stalled shard ...
    flagged = {s for _, ss in af.stats["straggler_verdicts"] for s in ss}
    assert 1 in flagged, af.stats["straggler_verdicts"]
    # ... and the reslice plan shifts work off it
    shares = af.stats["reslice"]
    assert shares is not None and shares[1] < max(shares)
    # same fixed point as the fault-free sync sweep
    gap = float(jnp.max(jnp.abs(af.poly(x) - sync.poly(x))))
    cond = float(af.poly.diagnostics.condition)
    assert gap <= max(50 * np.finfo(np.float32).eps * max(cond, 1.0), 1e-4)


def test_async_rejects_stale_contributions():
    """With staleness=0 every delta must be computed at the current
    version: delivery delays force recomputation, visibly counted."""
    x, y = _workload(n=512)
    chaos = ChaosSchedule((FaultEvent(tick=2, worker=0, kind="delay",
                                      duration=6),
                           FaultEvent(tick=4, worker=1, kind="delay",
                                      duration=6),))
    af = distributed.async_lspia_fit(x, y, _spec(staleness=0), n_shards=2,
                                     chaos=chaos)
    assert bool(af.converged)
    assert af.stats["stale_rejected"] > 0


def test_async_momentum_accelerates():
    x, y = _workload()
    plain = distributed.async_lspia_fit(x, y, _spec(), n_shards=4)
    mom = distributed.async_lspia_fit(x, y, _spec(momentum=0.5), n_shards=4)
    assert bool(plain.converged) and bool(mom.converged)
    assert mom.iterations < plain.iterations


def test_async_validation():
    x, y = _workload(n=64)
    with pytest.raises(ValueError, match="method"):
        distributed.async_lspia_fit(x, y, FitSpec(degree=3), n_shards=2)
    with pytest.raises(ValueError, match="decay"):
        distributed.async_lspia_fit(
            x, y, dataclasses.replace(_spec(), decay=0.9), n_shards=2)
    with pytest.raises(ValueError, match="shards"):
        distributed.async_lspia_fit(x[:2], y[:2], _spec(), n_shards=4)


# --------------------------------------------------- momentum acceleration
def test_momentum_halves_iterations():
    """The committed acceptance number: beta = 0.5 cuts iterations-to-tol
    by >= 2x vs the plain iteration on the reference workload."""
    x, y = _workload()
    plain = lspia.lspia_fit(x, y, 5, basis="chebyshev")
    mom = lspia.lspia_fit(x, y, 5, basis="chebyshev", momentum=0.5)
    assert bool(plain.converged) and bool(mom.converged)
    assert int(mom.iterations) * 2 <= int(plain.iterations), (
        int(mom.iterations), int(plain.iterations))
    # same fixed point
    gap = float(jnp.max(jnp.abs(mom.poly(x) - plain.poly(x))))
    assert gap < 1e-4, gap


def test_momentum_on_moment_surface():
    """The moment-space Richardson iteration honors the same momentum."""
    x, y = _workload(n=2048)
    spec_p = _spec()
    spec_m = _spec(momentum=0.5)
    fit_p = api.fit(np.asarray(x), np.asarray(y), spec=spec_p)
    fit_m = api.fit(np.asarray(x), np.asarray(y), spec=spec_m)
    gap = float(np.max(np.abs(np.asarray(fit_p.poly(x))
                              - np.asarray(fit_m.poly(x)))))
    assert gap < 1e-3, gap


# ------------------------------------------------------- step-size clamp
def test_step_clamp_adversarial_spectrum():
    """Adversarial spectrum: a near-rank-1 cluster of x values makes the
    top of the spectrum heavy and the power-iteration estimate slow to
    settle.  With few power iterations the unclamped 1/lambda-hat step
    would overshoot; the settledness-gated trace clamp must keep every
    sweep finite — and converged=False must be reported honestly if the
    budget runs out, never NaN coefficients."""
    rng = np.random.default_rng(11)
    # 99% of the mass piled at one point + a smattering of spread
    x = np.concatenate([np.full(4000, 2.0), rng.uniform(-3, 3, 40)])
    y = 0.5 * x ** 2 - x + 0.3 + 0.01 * rng.normal(size=x.size)
    xf = jnp.asarray(x, jnp.float32)
    yf = jnp.asarray(y, jnp.float32)
    for piters in (1, 2, 12):
        f = lspia.lspia_fit(xf, yf, 4, power_iters=piters, max_iter=200)
        assert bool(jnp.all(jnp.isfinite(f.poly.coeffs))), (
            f"non-finite coeffs at power_iters={piters}")
    # and an explicitly oversized step must freeze, not explode
    f = lspia.lspia_fit(xf, yf, 4, step=1e6, max_iter=50)
    assert bool(jnp.all(jnp.isfinite(f.poly.coeffs)))
    assert not bool(f.converged)


def test_lspia_options_validation():
    with pytest.raises(ValueError, match="momentum"):
        LSPIAOptions(momentum=1.0)
    with pytest.raises(ValueError, match="momentum"):
        LSPIAOptions(momentum=-0.1)
    with pytest.raises(ValueError, match="staleness"):
        LSPIAOptions(staleness=-1)


# ------------------------------------------------- async chunk ingestion
def _chunks(n_sources=3, per=4, width=64, seed=7):
    rng = np.random.default_rng(seed)
    out = []
    for s in range(n_sources):
        chunks = []
        for q in range(per):
            x = rng.uniform(-1, 1, width).astype(np.float32)
            y = (0.3 - 1.2 * x + 0.5 * x ** 3
                 + 0.01 * rng.normal(size=width)).astype(np.float32)
            chunks.append((x, y))
        out.append(chunks)
    return out


def _ingestor(degree=3, n_sources=3, **kw):
    st = streaming.StreamState.create(degree)
    return streaming.AsyncChunkIngestor(st, n_sources, **kw)


def test_ingestor_in_order_matches_batch():
    src = _chunks()
    ing = _ingestor()
    allx, ally = [], []
    for s, chunks in enumerate(src):
        for q, (x, y) in enumerate(chunks):
            assert ing.offer(s, q + 1, x, y)
            allx.append(x)
            ally.append(y)
    ref = polyfit(jnp.asarray(np.concatenate(allx)),
                  jnp.asarray(np.concatenate(ally)), 3)
    got = fit_from_moments(ing.state.moments)
    assert float(jnp.max(jnp.abs(got.coeffs - ref.coeffs))) < 1e-3
    assert ing.fresh() and ing.lag() == 0


def test_ingestor_duplicate_is_idempotent():
    src = _chunks(n_sources=1, per=2)
    ing = _ingestor(n_sources=1)
    x, y = src[0][0]
    assert ing.offer(0, 1, x, y)
    count_after_first = float(ing.state.moments.count)
    assert not ing.offer(0, 1, x, y)          # duplicate: acked, not folded
    assert float(ing.state.moments.count) == count_after_first
    assert ing.duplicates == 1


def test_ingestor_reorders_within_window():
    src = _chunks(n_sources=1, per=3)
    ing = _ingestor(n_sources=1, reorder_window=8)
    (x1, y1), (x2, y2), (x3, y3) = src[0]
    assert not ing.offer(0, 3, x3, y3)        # early: held
    assert not ing.offer(0, 2, x2, y2)        # early: held
    assert ing.buffered == 2
    assert ing.offer(0, 1, x1, y1)            # in-order: applies + drains
    assert ing.applied[0] == 3
    in_order = _ingestor(n_sources=1)
    for q, (x, y) in enumerate(src[0]):
        in_order.offer(0, q + 1, x, y)
    assert float(jnp.max(jnp.abs(
        ing.state.moments.gram - in_order.state.moments.gram))) < 1e-3


def test_ingestor_never_stalls_on_slow_source():
    """The tentpole property on the streaming surface: the fast source
    keeps folding while the slow one lags; freshness flags the lag
    without blocking ingestion."""
    src = _chunks(n_sources=2, per=8)
    ing = _ingestor(n_sources=2, staleness=4)
    for q in range(8):                        # source 0 races ahead
        assert ing.offer(0, q + 1, *src[0][q])
    assert ing.lag() == 8
    assert not ing.fresh() and ing.stale_sources() == [1]
    assert ing.offer(1, 1, *src[1][0])        # slow source still folds
    for q in range(1, 8):
        ing.offer(1, q + 1, *src[1][q])
    assert ing.fresh() and ing.lag() == 0


def test_ingestor_overflow_and_decay_rejection():
    src = _chunks(n_sources=1, per=1)
    ing = _ingestor(n_sources=1, reorder_window=2)
    x, y = src[0][0]
    assert not ing.offer(0, 9, x, y)          # far past the window
    assert ing.overflowed == 1
    st = streaming.StreamState.create(3, decay=0.9)
    with pytest.raises(ValueError, match="decay"):
        streaming.AsyncChunkIngestor(st, 2)


# --------------------------------------------------------- fleet surface
def _fleet_series(n=2048, seed=3):
    rng = np.random.default_rng(seed)
    x = np.sort(rng.uniform(-1, 1, n)).astype(np.float32)
    y = (0.3 - 1.2 * x + 0.5 * x ** 3
         + 0.02 * rng.normal(size=n)).astype(np.float32)
    return x, y


def test_fleet_async_lspia_matches_polyfit():
    x, y = _fleet_series()
    # degree 3 (the series IS a cubic): the merged Gram stays well inside
    # the f32 fast-solver envelope, so converged (= no fallback) must hold
    fleet = FitFleet(FleetConfig(fit=fe.FitServeConfig(degree=3),
                                 n_workers=4, chunk_width=256))
    h = fleet.submit_async_lspia(x, y, n_shards=4)
    fleet.run(max_ticks=5000)
    assert h.done and h.failed is None and bool(h.converged)
    assert h.harvested == 4
    assert fleet.stats["async_harvests"] == 4
    # partial re-solves happened before the last shard landed
    assert h.updates_while_partial >= 1
    ref = polyfit(jnp.asarray(x), jnp.asarray(y), 3)
    gap = float(np.max(np.abs(np.asarray(h.coeffs)
                              - np.asarray(ref.coeffs))))
    assert gap < 5e-3, gap


def test_fleet_async_lspia_survives_stalled_worker():
    """A chaos-stalled worker delays only its own shard: the handle keeps
    updating from harvested shards, and the final merged answer is
    IDENTICAL to the fault-free run (moments are additive; the journal
    replays, never double-counts)."""
    x, y = _fleet_series()
    clean = FitFleet(FleetConfig(fit=fe.FitServeConfig(degree=3),
                                 n_workers=4, chunk_width=256))
    hc = clean.submit_async_lspia(x, y, n_shards=4)
    clean.run(max_ticks=5000)

    chaos = ChaosSchedule((FaultEvent(tick=2, worker=0, kind="stall",
                                      duration=30),))
    fleet = FitFleet(FleetConfig(fit=fe.FitServeConfig(degree=3),
                                 n_workers=4, chunk_width=256, chaos=chaos))
    h = fleet.submit_async_lspia(x, y, n_shards=4)
    fleet.run(max_ticks=5000)
    assert h.done and bool(h.converged)
    np.testing.assert_array_equal(np.asarray(hc.coeffs),
                                  np.asarray(h.coeffs))


def test_fleet_async_lspia_validation():
    x, y = _fleet_series(n=128)
    fleet = FitFleet(FleetConfig(fit=fe.FitServeConfig(degree=5, decay=0.99),
                                 n_workers=2, chunk_width=64))
    with pytest.raises(ValueError, match="decay"):
        fleet.submit_async_lspia(x, y, n_shards=2)


# ------------------------------------------- decayed-then-refilled stream
def test_decayed_then_refilled_stream_returns_to_fast_solver():
    """Satellite 3: exponential forgetting drives weight_sum toward zero
    while the stream starves; the SHAPE-based condition estimate must not
    report spurious +inf for the tiny-but-well-shaped Gram, so a refilled
    stream returns to the fast solver rung instead of being pinned to the
    SVD fallback."""
    rng = np.random.default_rng(13)
    st = streaming.StreamState.create(2, decay=0.5)
    x = rng.uniform(-1, 1, 256).astype(np.float32)
    y = (1.0 + 2.0 * x - 0.5 * x ** 2).astype(np.float32)
    st = streaming.update(st, jnp.asarray(x), jnp.asarray(y))
    # starve: decay-only updates shrink the weighted mass toward underflow
    for _ in range(60):
        st = streaming.update(st, jnp.zeros(1, jnp.float32),
                              jnp.zeros(1, jnp.float32),
                              weights=jnp.zeros(1, jnp.float32))
    starved_cond = float(st.moments.condition())
    assert np.isfinite(starved_cond), (
        f"decayed-but-well-shaped Gram reported cond={starved_cond}")
    # refill and fit: fast path, correct coefficients
    st = streaming.update(st, jnp.asarray(x), jnp.asarray(y))
    fit = streaming.current_fit(st)
    assert fit.diagnostics is not None
    assert not bool(fit.diagnostics.fallback_used)
    got = np.asarray(fit.coeffs, np.float64)
    assert np.allclose(got, [1.0, 2.0, -0.5], atol=5e-2), got
