"""Sharding rules (spec construction, dedupe, divisibility fallback) and
roofline HLO-parsing units — no multi-device requirement."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import roofline as roof
from repro.launch.mesh import make_host_mesh
from repro.sharding import rules


class FakeMesh:
    """Minimal mesh stand-in: axis names + sizes (no devices needed)."""

    def __init__(self, shape: dict):
        self.axis_names = tuple(shape)
        self.shape = shape


M = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_spec_basic_mapping():
    # FSDP ("embed") extends over pod+data when the pod axis exists
    s = rules.spec_for(M, ("embed", "q_heads", "head_dim"))
    assert s == P(("pod", "data"), "model", None)
    s1 = rules.spec_for(FakeMesh({"data": 16, "model": 16}),
                        ("embed", "q_heads", "head_dim"))
    assert s1 == P("data", "model", None)


def test_spec_dedupes_repeated_mesh_axis():
    # zamba attn_out: both dims logical-map to the same mesh axes
    s = rules.spec_for(M, ("embed", "embed"))
    assert s == P(("pod", "data"), None)


def test_spec_divisibility_drops_axis():
    # yi-6b: 4 kv heads cannot shard over 16-way model axis
    s = rules.spec_for(M, ("batch", "kv_seq", "kv_heads", "head_dim"),
                       dims=(128, 32768, 4, 128))
    assert s == P(("pod", "data"), None, None, None)


def test_spec_batch_maps_to_all_data_axes():
    s = rules.spec_for(M, ("batch", None, "vocab"))
    assert s == P(("pod", "data"), None, "model")


def test_decode_overrides_cache_layout():
    r = dict(rules.BASE_RULES)
    r.update(rules.DECODE_OVERRIDES)
    # kv_seq stays local (in-place DUS); kv_heads take the TP axis
    s = rules.spec_for(M, ("batch", "kv_seq", "kv_heads", "head_dim"),
                       rules=r, dims=(128, 32768, 16, 128))
    assert s == P(("pod", "data"), None, "model", None)
    # heads that don't divide TP fall back to head_dim (qwen1.5 kv=20,
    # GQA kv=8 on a 16-way axis)
    s2 = rules.spec_for(M, ("batch", "kv_seq", "kv_heads", "head_dim"),
                        rules=r, dims=(128, 32768, 20, 128))
    assert s2 == P(("pod", "data"), None, None, "model")


def test_long_context_overrides():
    r = dict(rules.BASE_RULES)
    r.update(rules.LONG_CONTEXT_OVERRIDES)
    s = rules.spec_for(M, ("batch", "kv_seq", "kv_heads", "head_dim"),
                       rules=r, dims=(1, 524288, 32, 224))
    assert s == P(None, ("data", "model"), None, None)


def test_tree_shardings_with_real_mesh():
    mesh = make_host_mesh(data=1, model=1)
    spec_tree = {"w": ("embed", "mlp"), "scalar": ()}
    shape_tree = {"w": jax.ShapeDtypeStruct((64, 128), np.float32),
                  "scalar": jax.ShapeDtypeStruct((), np.int32)}
    out = rules.tree_shardings(mesh, spec_tree, shape_tree)
    # 1-device mesh: axes exist but have size 1 ⇒ fully replicated
    assert out["w"].is_fully_replicated


# ------------------------------------------------------------ HLO parsing
HLO = """
ENTRY main {
  %p = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p), replica_groups={{0,1}}
  %ag = bf16[64,512]{1,0} all-gather(%p), dimensions={0}
  %rs = f32[32]{0} reduce-scatter(%p), dimensions={0}
  %a2a = f32[16,16]{1,0} all-to-all(%p), dimensions={0}
  %cp = u8[1024]{0} collective-permute(%p)
  %t = (f32[10,10]{1,0}, f32[5]{0}) all-reduce(%x, %y)
  %start = f32[100]{0} all-gather-start(%p)
  %done = f32[100]{0} all-gather-done(%start)
}
"""


def test_collective_bytes_parsing():
    got = roof.collective_bytes(HLO)
    assert got["all-reduce"] == (128 * 256 * 4 + (100 + 5) * 4) * 2.0
    # all-gather counted once for start (done skipped) + plain ag
    assert got["all-gather"] == 64 * 512 * 2 + 100 * 4
    assert got["reduce-scatter"] == 32 * 4
    assert got["all-to-all"] == 16 * 16 * 4
    assert got["collective-permute"] == 1024


def test_shape_bytes_tuple_and_scalar():
    assert roof._shape_bytes("(f32[2,3]{1,0}, bf16[4]{0})") == 24 + 8
    assert roof._shape_bytes("f32[]") == 4  # scalar: empty dims


def test_roofline_terms():
    r = roof.Roofline(flops=197e12, bytes_accessed=819e9, coll_bytes=50e9,
                      coll_breakdown={}, peak_memory=8 << 30)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 1.0) < 1e-9
    assert abs(r.collective_s - 1.0) < 1e-9
    assert r.step_s == max(r.compute_s, r.memory_s, r.collective_s)


def test_model_flops():
    from repro import configs
    from repro.configs.base import SHAPES
    cfg = configs.get_config("yi-6b")
    mf = roof.model_flops(cfg, SHAPES["train_4k"], 1_048_576)
    # yi-6b ≈ 6.06B params → 6·N·D ≈ 3.8e16
    assert 2e16 < mf < 6e16
    cfg_moe = configs.get_config("dbrx-132b")
    act = cfg_moe.active_param_count()
    tot = cfg_moe.param_count()
    assert 0.2 < act / tot < 0.35   # 16 experts top-4 + attn + embed
