"""Property + regression tests for the condition-aware solver stack.

* hypothesis: on well-conditioned inputs every (engine, solver) combination
  the plan layer can produce agrees within NumericsPolicy tolerance, and
  IRLS with zero contamination converges to the plain LSE coefficients;
* regression: singular/near-singular Grams (constant x, zero-range
  ``Domain.from_data``) — previously silent inf/NaN out of Gaussian
  elimination — now produce finite coefficients with
  ``diagnostics.condition`` / ``diagnostics.fallback_used`` raised.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro import core, engine
from repro.core import streaming

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")

EXPLICIT = [s for s in core.SOLVERS]          # ("gauss","cholesky","qr","svd")


def _clean_data(seed, degree, n=192, noise=0.02):
    rng = np.random.default_rng(seed)
    x = np.sort(rng.uniform(-1.0, 1.0, n))
    coeffs = rng.normal(0, 1, degree + 1)
    y = np.polyval(coeffs[::-1], x) + noise * rng.normal(0, 1, n)
    return (jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32), coeffs)


# ------------------------------------------------------------- properties
@given(st.integers(0, 10_000), st.integers(1, 5))
def test_solver_invariance_on_well_conditioned(seed, degree):
    """Every rung of the explicit ladder solves the same well-conditioned
    normal equations to the same coefficients (within fp tolerance)."""
    x, y, _ = _clean_data(seed, degree)
    fits = {s: core.polyfit(x, y, degree, solver=s) for s in EXPLICIT}
    ref = np.asarray(fits["gauss"].coeffs, np.float64)
    scale = np.linalg.norm(ref) + 1e-9
    for s, poly in fits.items():
        assert not bool(poly.diagnostics.fallback_used), s
        gap = np.linalg.norm(np.asarray(poly.coeffs, np.float64) - ref)
        assert gap / scale < 5e-4, f"{s}: {gap / scale:.2e}"


@given(st.integers(0, 10_000), st.integers(1, 4))
def test_engine_solver_grid_agrees(seed, degree):
    """Every (engine, solver) combination plan_fit can produce agrees on
    well-conditioned batched input (the kernels force monomial/f32)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-1, 1, (3, 256)), jnp.float32)
    yv = rng.normal(0, 1, (3, 256))
    y = jnp.asarray(yv, jnp.float32)
    ref = None
    for eng in ("reference", "kernel_plain", "kernel_packed"):
        for solver in ("gauss", "svd"):
            poly = core.polyfit(x, y, degree, engine=eng, solver=solver)
            c = np.asarray(poly.coeffs, np.float64)
            if ref is None:
                ref = c
                scale = np.linalg.norm(ref) + 1e-9
            else:
                assert np.linalg.norm(c - ref) / scale < 1e-3, (eng, solver)


@given(st.integers(0, 10_000), st.integers(1, 4))
def test_irls_zero_contamination_matches_lse(seed, degree):
    """With no outliers the IRLS weights settle at ≈1 and robust_polyfit
    reproduces the plain LSE fit."""
    x, y, _ = _clean_data(seed, degree, noise=0.0)
    plain = core.polyfit(x, y, degree)
    rfit = core.robust_polyfit(x, y, degree)
    assert bool(rfit.converged)
    ref = np.asarray(plain.coeffs, np.float64)
    got = np.asarray(rfit.poly.coeffs, np.float64)
    assert np.linalg.norm(got - ref) / (np.linalg.norm(ref) + 1e-9) < 1e-3


@given(st.integers(0, 10_000), st.integers(2, 6))
def test_condition_estimate_matches_numpy(seed, m):
    rng = np.random.default_rng(seed)
    a = rng.normal(0, 1, (m, m))
    a = a @ a.T + 0.1 * np.eye(m)
    got = float(core.condition_estimate(jnp.asarray(a)))
    want = float(np.linalg.cond(a))
    assert got == pytest.approx(want, rel=2e-2)


# ----------------------------------------------------------- static table
def test_select_solver_escalates_with_degree():
    f32, f64 = jnp.float32, jnp.float64
    order = {s: i for i, s in enumerate(core.SOLVERS)}
    for dtype in (f32, f64):
        for normalized in (False, True):
            picks = [core.select_solver(d, dtype, normalized=normalized)
                     for d in range(1, 12)]
            ranks = [order[p] for p in picks]
            assert ranks == sorted(ranks), (dtype, normalized, picks)
    # the paper's regime stays paper-faithful
    assert core.select_solver(3, f32) == "gauss"
    # raw monomial high degree in f32 goes straight to the rank-revealer
    assert core.select_solver(9, f32) == "svd"
    # f64 buys more headroom
    assert core.select_solver(9, f64, normalized=True) == "qr"


def test_plan_resolves_auto_solver_and_autonorm():
    plan = engine.plan_fit((256,), 3, dtype=jnp.float32)
    assert plan.numerics.solver == "gauss"
    assert not plan.numerics.normalize
    plan9 = engine.plan_fit((256,), 9, dtype=jnp.float32)
    assert plan9.numerics.normalize          # auto-escalated pre-Gram
    assert plan9.numerics.solver != "gauss"
    forced = engine.plan_fit((256,), 9, dtype=jnp.float32, solver="gauss")
    assert forced.numerics.solver == "gauss"
    assert not forced.numerics.normalize
    with pytest.raises(ValueError, match="solver"):
        engine.plan_fit((256,), 3, solver="lu")
    with pytest.raises(ValueError, match="fallback"):
        engine.plan_fit((256,), 3, fallback="auto")


def test_lspia_workload_plan():
    plan = engine.plan_fit((4, 512), 3, workload="lspia", backend="tpu")
    assert plan.path == engine.REFERENCE
    assert plan.numerics.solver == "lspia"
    assert "Gram" in plan.reason


# ------------------------------------------------- degenerate-input rescue
def test_singular_gram_is_finite_and_flagged():
    """The PR-3 fix: GE on a singular Gram returned inf/NaN with no signal;
    now the rescue produces the finite minimum-norm solution and raises
    diagnostics.fallback_used / a huge condition estimate."""
    x = jnp.full(64, 2.0)                      # constant x: rank-1 Gram
    y = jnp.asarray(np.random.default_rng(0).normal(0, 1, 64), jnp.float32)
    # the raw failure mode, preserved when asked for
    raw = core.polyfit(x, y, 2, solver="gauss", fallback=None)
    assert not bool(jnp.all(jnp.isfinite(raw.coeffs)))
    assert not bool(raw.diagnostics.fallback_used)
    # the default: finite + flagged
    poly = core.polyfit(x, y, 2)
    assert bool(jnp.all(jnp.isfinite(poly.coeffs)))
    assert bool(poly.diagnostics.fallback_used)
    # κ reads +inf or huge-finite (f32 eigvalsh rounds the zero eigenvalue);
    # either way it is far beyond the dtype's cap — the "flagged" signal
    assert float(poly.diagnostics.condition) > core.cond_cap_for(jnp.float32)
    # and the fit is the sensible one: mean(y) at the only x seen
    assert float(poly(x)[0]) == pytest.approx(float(jnp.mean(y)), abs=1e-4)


def test_zero_range_domain_normalize_is_finite():
    """Domain.from_data on zero-range data degrades to identity scale; the
    normalized fit must still come out finite and flagged."""
    x = jnp.full(32, 7.0)
    y = jnp.ones(32, jnp.float32)
    poly = core.polyfit(x, y, 1, normalize=True)
    assert bool(jnp.all(jnp.isfinite(poly.coeffs)))
    assert bool(poly.diagnostics.fallback_used)
    assert float(poly(jnp.asarray([7.0]))[0]) == pytest.approx(1.0, abs=1e-5)


def test_near_singular_two_point_cluster():
    """Two distinct x values fitting a quadratic: rank 2 < 3 — finite,
    flagged, and exact on the observed points."""
    x = jnp.asarray([1.0, 1.0, 3.0, 3.0], jnp.float32)
    y = jnp.asarray([2.0, 2.0, 4.0, 4.0], jnp.float32)
    poly = core.polyfit(x, y, 2)
    assert bool(jnp.all(jnp.isfinite(poly.coeffs)))
    assert bool(poly.diagnostics.fallback_used)
    got = np.asarray(poly(jnp.asarray([1.0, 3.0])), np.float64)
    np.testing.assert_allclose(got, [2.0, 4.0], atol=1e-3)


def test_streaming_degenerate_state_is_finite():
    """A fresh stream solved before enough points arrive (ridge off) used
    to NaN; the condition-aware solve returns finite + flagged instead."""
    state = streaming.StreamState.create(3)
    state = streaming.update(state, jnp.asarray([1.0, 1.0]),
                             jnp.asarray([2.0, 2.0]))
    poly = streaming.current_fit(state)        # no ridge: rank-1 Gram
    assert bool(jnp.all(jnp.isfinite(poly.coeffs)))
    assert bool(poly.diagnostics.fallback_used)
    assert poly.diagnostics.solver == "gauss"


def test_degenerate_flagged_even_when_primary_is_svd():
    """At degrees where the plan's primary already is the rank-revealer
    (solver == fallback), the condition breach must still be reported —
    flagging is the guard's contract, the second solve just its remedy."""
    x = jnp.full(64, 2.0, jnp.float32)     # pinned: weak 2.0 goes f64
    y = jnp.ones(64, jnp.float32)          # under a global-x64 run
    poly = core.polyfit(x, y, 9)           # f32 degree 9 → primary "svd"
    assert poly.diagnostics.solver == "svd"
    assert bool(jnp.all(jnp.isfinite(poly.coeffs)))
    assert bool(poly.diagnostics.fallback_used)


def test_robust_polyfit_all_zero_weight_series_is_finite():
    """A fully-padded series (base weights all zero) in a batch must come
    back finite + flagged, like plain polyfit does — not NaN-poisoned
    through the MAD scale estimate."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.uniform(-1, 1, (2, 64)), jnp.float32)
    y = jnp.asarray(np.stack([np.asarray(x[0]) * 2 + 1,
                              rng.normal(0, 1, 64)]), jnp.float32)
    w = jnp.asarray(np.stack([np.ones(64), np.zeros(64)]), jnp.float32)
    rfit = core.robust_polyfit(x, y, 2, weights=w)
    assert bool(jnp.all(jnp.isfinite(rfit.poly.coeffs)))
    assert bool(rfit.poly.diagnostics.fallback_used[1])   # zero Gram slot
    assert not bool(rfit.poly.diagnostics.fallback_used[0])
    got = np.asarray(rfit.poly.coeffs[0], np.float64)
    np.testing.assert_allclose(got, [1.0, 2.0, 0.0], atol=2e-3)


def test_lspia_nonconvergence_is_flagged():
    """An LSPIA run that cannot meet tol (first-order rate vs monomial
    degree-9 κ) must say so through the same diagnostics channel the
    explicit solvers use — never silent garbage."""
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.uniform(-2, 2, 512), jnp.float32)
    y = jnp.asarray(rng.normal(0, 1, 512), jnp.float32)
    lf = core.lspia_fit(x, y, 9, max_iter=50)   # hopeless on purpose
    assert not bool(lf.converged)
    assert bool(lf.poly.diagnostics.fallback_used)
    # κ̂ from the observed rate is a lower bound; it must at least say
    # "slow" (κ̂ ≫ the Chebyshev regime's ~10) while the flag carries the
    # real no-silent-failure signal
    assert float(lf.poly.diagnostics.condition) > 30.0
    # and through the polyfit front door the flag survives
    front = core.polyfit(x, y, 9, solver="lspia")
    assert front.diagnostics is not None
    # converged-or-flagged: either is a legitimate outcome here, but a
    # non-converged run must carry the flag
    lf_ref = core.lspia_fit(x, y, 9)
    assert bool(lf_ref.converged) == (not bool(
        lf_ref.poly.diagnostics.fallback_used))


def test_fallback_reports_condition_on_healthy_solves_too():
    x, y, _ = _clean_data(5, 2)
    poly = core.polyfit(x, y, 2)
    cond = float(poly.diagnostics.condition)
    assert np.isfinite(cond) and 1.0 <= cond < float(core.cond_cap_for(
        jnp.float32))
    assert not bool(poly.diagnostics.fallback_used)


def test_serve_surfaces_solver_diagnostics():
    """The fit server's solve step reports per-request condition/fallback."""
    from repro.serve import FitServeConfig, FitServeEngine
    eng = FitServeEngine(FitServeConfig(degree=2, n_slots=2, buckets=(64,)))
    rng = np.random.default_rng(2)
    xs = rng.uniform(-1, 1, 40).astype(np.float32)
    good = eng.submit(xs, (xs * 2 + 1).astype(np.float32))
    degen = eng.submit(np.full(40, 3.0, np.float32),
                       np.full(40, 5.0, np.float32))
    eng.run()
    assert good.done and degen.done
    assert np.isfinite(good.condition) and not good.fallback_used
    # ridge keeps the degenerate slot's solve finite; its condition estimate
    # must still scream relative to the healthy request's
    assert degen.condition > 1e3 * good.condition
    assert np.all(np.isfinite(degen.coeffs))
