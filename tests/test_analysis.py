"""reprolint: fixture corpus, suppression semantics, JSON schema,
repo-cleanliness meta-test, and the runtime sanitizers.

The corpus contract (ISSUE 10): every checker code detects >= 1 finding
on its known-bad fixture, with zero false positives on the known-good
twins — and the committed repo itself lints clean.
"""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (ALL_CODES, CODE_SUPPRESS, CompileCounter,
                            Finding, NaNOriginError, Report,
                            assert_no_recompiles, lint_file, nan_origin,
                            run_lint)

REPO = Path(__file__).resolve().parent.parent
FIXDIR = REPO / "tests" / "fixtures" / "reprolint"

# (code, bad fixture, good fixture) — one pinned pair per checker code
CORPUS = [
    ("RL-RECOMPILE", "bad_recompile.py", "good_recompile.py"),
    ("RL-TRACERLEAK", "bad_tracerleak.py", "good_tracerleak.py"),
    ("RL-DETERMINISM", "bad__runtime__chaos.py", "good__runtime__chaos.py"),
    ("RL-PROTOCOL", "bad__serve__fleet.py", "good__serve__fleet.py"),
    ("RL-DTYPE", "bad__core__moments.py", "good__core__moments.py"),
    ("RL-VMEM", "bad__kernels__moments.py", "good__kernels__moments.py"),
    (CODE_SUPPRESS, "bad_suppress.py", "good_suppress.py"),
]


def live(findings):
    return [f for f in findings if not f.suppressed]


# ------------------------------------------------------------------ corpus
@pytest.mark.parametrize("code,bad,good", CORPUS,
                         ids=[c for c, _, _ in CORPUS])
def test_bad_fixture_detected_and_pure(code, bad, good):
    findings = live(lint_file(FIXDIR / bad))
    codes = {f.code for f in findings}
    assert code in codes, f"{bad} produced {codes}, wanted {code}"
    # the corpus is single-voiced: a bad fixture trips ONLY its own code
    assert codes == {code}, f"{bad} leaked extra codes: {codes - {code}}"


@pytest.mark.parametrize("code,bad,good", CORPUS,
                         ids=[c for c, _, _ in CORPUS])
def test_good_fixture_is_finding_free(code, bad, good):
    findings = live(lint_file(FIXDIR / good))
    assert findings == [], [f.render() for f in findings]


def test_every_code_has_a_fixture_pair():
    assert {c for c, _, _ in CORPUS} == set(ALL_CODES)


def test_bad_recompile_covers_fstring_cache_key():
    msgs = [f.message for f in live(lint_file(FIXDIR / "bad_recompile.py"))]
    assert any("f-string" in m for m in msgs)


# ------------------------------------------------------------ suppressions
def test_inline_suppression_with_reason(tmp_path):
    p = tmp_path / "bad__core__moments.py"
    p.write_text("import numpy as np\n"
                 "x = np.float64(1.0)"
                 "  # reprolint: disable=RL-DTYPE — deliberate demo\n")
    findings = lint_file(p)
    assert len(findings) == 1
    assert findings[0].suppressed
    assert findings[0].suppression_reason == "deliberate demo"


def test_standalone_suppression_covers_next_line(tmp_path):
    p = tmp_path / "bad__core__moments.py"
    p.write_text("import numpy as np\n"
                 "# reprolint: disable=RL-DTYPE — demo reason\n"
                 "x = np.float64(1.0)\n")
    findings = lint_file(p)
    assert [f.suppressed for f in findings] == [True]


def test_reasonless_disable_does_not_suppress(tmp_path):
    p = tmp_path / "bad__core__moments.py"
    p.write_text("import numpy as np\n"
                 "x = np.float64(1.0)  # reprolint: disable=RL-DTYPE\n")
    findings = lint_file(p)
    codes = {f.code: f.suppressed for f in findings}
    assert codes == {CODE_SUPPRESS: False, "RL-DTYPE": False}


def test_suppression_only_covers_named_code(tmp_path):
    p = tmp_path / "bad__core__moments.py"
    p.write_text("import numpy as np\n"
                 "x = np.float64(1.0)"
                 "  # reprolint: disable=RL-VMEM — wrong code named\n")
    findings = lint_file(p)
    assert [(f.code, f.suppressed) for f in findings] \
        == [("RL-DTYPE", False)]


# ------------------------------------------------------------- JSON schema
def test_report_json_round_trip():
    report = run_lint([FIXDIR / "bad_recompile.py",
                       FIXDIR / "bad_suppress.py"])
    d = json.loads(report.to_json())
    assert d["version"] == 1
    assert d["files_scanned"] == 2
    assert d["counts"]["RL-RECOMPILE"] >= 1
    back = Report.from_dict(d)
    assert back.findings == report.findings
    assert back.files_scanned == report.files_scanned


def test_report_rejects_unknown_version():
    with pytest.raises(ValueError, match="version"):
        Report.from_dict({"version": 99, "findings": [],
                          "files_scanned": 0})


def test_finding_dict_round_trip():
    f = Finding("RL-DTYPE", "a.py", 3, "msg", col=7, symbol="fn",
                suppressed=True, suppression_reason="why")
    assert Finding.from_dict(f.to_dict()) == f


# ----------------------------------------------------------- CLI contract
def test_cli_json_exit_codes(tmp_path):
    env_root = REPO
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--format=json",
         str(FIXDIR / "good_recompile.py")],
        capture_output=True, text=True, cwd=env_root,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"})
    assert out.returncode == 0, out.stderr
    assert json.loads(out.stdout)["counts"] == {}

    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--format=json",
         str(FIXDIR / "bad_recompile.py")],
        capture_output=True, text=True, cwd=env_root,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"})
    assert out.returncode == 1, out.stderr
    report = json.loads(out.stdout)
    assert report["counts_unsuppressed"]["RL-RECOMPILE"] >= 1


def test_cli_select_filters_codes():
    findings = live(lint_file(FIXDIR / "bad__core__moments.py",
                              select=("RL-VMEM",)))
    assert findings == []


# ---------------------------------------------------------- the meta-test
def test_committed_repo_is_finding_free():
    """The acceptance criterion: zero unsuppressed findings on the repo."""
    roots = [REPO / r for r in ("src", "benchmarks", "examples")
             if (REPO / r).exists()]
    report = run_lint(roots)
    assert report.files_scanned > 50
    bad = [f.render() for f in report.unsuppressed]
    assert bad == [], "\n".join(bad)
    # the deliberate f64 exceptions stay visible in the audit trail
    assert report.counts(suppressed=True).get("RL-DTYPE", 0) >= 4


# -------------------------------------------------------------- sanitizers
def test_compile_counter_sees_fresh_compile():
    @jax.jit
    def f(x):
        return x * 3.0

    with CompileCounter() as c:
        f(jnp.ones(5, jnp.float32)).block_until_ready()
    assert c.count >= 1

    with CompileCounter() as c2:
        f(jnp.ones(5, jnp.float32)).block_until_ready()
    assert c2.count == 0


def test_assert_no_recompiles_trips_on_new_shape():
    @jax.jit
    def g(x):
        return x + 1.0

    g(jnp.ones(3, jnp.float32)).block_until_ready()
    with assert_no_recompiles("warm"):
        g(jnp.ones(3, jnp.float32)).block_until_ready()
    with pytest.raises(AssertionError, match="zero executable compiles"):
        with assert_no_recompiles("cold"):
            g(jnp.ones(6, jnp.float32)).block_until_ready()


@pytest.fixture(scope="session")
def warmed_square():
    """Warmed jit fn + a same-shape/dtype input, both built at session
    scope so the function-scoped tripwire only sees the warm call."""
    f = jax.jit(lambda x: x * x)
    f(jnp.ones(4, jnp.float32)).block_until_ready()
    x = jnp.asarray(np.full(4, 2.0, dtype=np.float32))
    return f, x


@pytest.mark.no_recompile
def test_warm_jit_path_is_compile_free(warmed_square):
    """Exercised with REPRO_RECOMPILE_TRIPWIRE=1 in CI's lint-static leg:
    the autouse tripwire fails this test if anything compiles."""
    f, x = warmed_square
    out = f(x)
    assert float(np.asarray(out)[0]) == 4.0


def test_nan_origin_names_the_boundary():
    from repro.core import solve as solve_mod
    eye = jnp.eye(3, dtype=jnp.float32)
    b = jnp.ones(3, jnp.float32)
    with nan_origin():
        out = solve_mod.solve(eye, b)            # clean inputs pass through
        assert np.allclose(np.asarray(out), 1.0)
        poisoned = np.eye(3, dtype=np.float32)
        poisoned[1, 1] = np.nan
        with pytest.raises(NaNOriginError) as exc:
            solve_mod.solve(jnp.asarray(poisoned), b)
    assert "solve" in str(exc.value) and "non-finite" in str(exc.value)
    # restored on exit: the wrapper is gone
    assert not hasattr(solve_mod.solve, "__wrapped__")


def test_nan_origin_checks_solve_with_fallback_inputs():
    from repro.core import solve as solve_mod
    bad = np.full((3, 3), np.nan, dtype=np.float32)
    with nan_origin():
        with pytest.raises(NaNOriginError):
            solve_mod.solve_with_fallback(jnp.asarray(bad),
                                          jnp.ones(3, jnp.float32))
