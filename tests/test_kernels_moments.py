"""Pallas moments kernel: allclose vs the pure-jnp oracle across shapes,
degrees, dtypes, block sizes — plus hypothesis property sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import core
from repro.kernels import moments as kernel
from repro.kernels import ops, ref

settings.register_profile("kern", deadline=None, max_examples=20)
settings.load_profile("kern")


def _data(seed, b, n, dtype):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-2, 2, (b, n)), dtype)
    y = jnp.asarray(rng.normal(0, 1, (b, n)), dtype)
    return x, y


def _assert_moments_close(mk, mr, rtol=2e-5, atol=1e-3):
    for f in ("gram", "vty", "yty", "count"):
        np.testing.assert_allclose(
            np.asarray(getattr(mk, f), np.float64),
            np.asarray(getattr(mr, f), np.float64),
            rtol=rtol, atol=atol, err_msg=f)


@pytest.mark.parametrize("b,n,deg", [
    (1, 6, 3), (1, 128, 0), (2, 300, 2), (4, 1024, 5),
    (1, 8192, 1), (3, 4096, 8), (1, 5000, 3),
])
def test_kernel_matches_oracle_f32(b, n, deg):
    x, y = _data(0, b, n, jnp.float32)
    _assert_moments_close(ops.moments(x, y, deg),
                          ref.moments_reference(x, y, deg))


@pytest.mark.parametrize("deg", [1, 3])
def test_kernel_bf16_inputs_f32_accumulate(deg):
    x, y = _data(1, 2, 2048, jnp.bfloat16)
    mk = ops.moments(x, y, deg)
    mr = ref.moments_reference(x, y, deg)
    _assert_moments_close(mk, mr, rtol=1e-4, atol=5e-2)
    assert mk.gram.dtype == jnp.float32   # accumulation dtype


@pytest.mark.parametrize("block_n", [128, 512, 4096])
def test_kernel_block_size_invariance(block_n):
    x, y = _data(2, 1, 8192, jnp.float32)
    mk = ops.moments(x, y, 3, block_n=block_n)
    mr = ref.moments_reference(x, y, 3)
    _assert_moments_close(mk, mr)


def test_kernel_weights_mask():
    """Zero-weighted (padded) points contribute nothing."""
    x, y = _data(3, 1, 256, jnp.float32)
    w = jnp.concatenate([jnp.ones((1, 200)), jnp.zeros((1, 56))], axis=1)
    mk = ops.moments(x, y, 2, weights=w)
    mr = ref.moments_reference(x[:, :200], y[:, :200], 2)
    _assert_moments_close(mk, mr)


def test_kernel_flat_input():
    x, y = _data(4, 1, 777, jnp.float32)
    mk = ops.moments(x[0], y[0], 2)
    assert mk.gram.shape == (3, 3)
    mr = jax.tree.map(lambda a: a[0], ref.moments_reference(x, y, 2))
    _assert_moments_close(mk, mr)


def test_extended_gram_raw_output():
    """The kernel's raw 128x128 output equals the oracle extended Gram,
    including the zero padding."""
    x, y = _data(5, 2, 512, jnp.float32)
    w = jnp.ones_like(x)
    g = kernel.moments_extended(x, y, w, degree=3, block_n=256,
                                interpret=True)
    gr = ref.extended_gram(x, y, 3)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                               rtol=2e-5, atol=1e-3)
    # padding region is exactly zero
    assert np.all(np.asarray(g)[:, 6:, :] == 0)
    assert np.all(np.asarray(g)[:, :, 6:] == 0)


@given(st.integers(0, 10_000), st.integers(1, 64), st.integers(0, 6))
def test_kernel_property_sweep(seed, n, deg):
    x, y = _data(seed, 1, n, jnp.float32)
    _assert_moments_close(ops.moments(x, y, deg),
                          ref.moments_reference(x, y, deg),
                          rtol=1e-4, atol=1e-3)


@given(st.integers(0, 10_000))
def test_kernel_end_to_end_fit(seed):
    """polyfit(use_kernel=True) == polyfit(use_kernel=False)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-2, 2, 512), jnp.float32)
    y = jnp.asarray(rng.normal(0, 1, 512), jnp.float32)
    a = core.polyfit(x, y, 3, use_kernel=True).coeffs
    b = core.polyfit(x, y, 3, use_kernel=False).coeffs
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-3)
