"""Packed multi-series Pallas kernel + fused report pass: parity with the
pure-jnp reference paths across degrees, ragged shapes, dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core import streaming
from repro.kernels import moments as kernel
from repro.kernels import ops, ref


def _data(seed, b, n, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-2, 2, (b, n)), dtype)
    y = jnp.asarray(rng.normal(0, 1, (b, n)), dtype)
    return x, y


def _assert_moments_close(mk, mr, rtol=2e-5, atol=1e-3):
    for f in ("gram", "vty", "yty", "count"):
        np.testing.assert_allclose(
            np.asarray(getattr(mk, f), np.float64),
            np.asarray(getattr(mr, f), np.float64),
            rtol=rtol, atol=atol, err_msg=f)


def _jnp_moments(x, y, deg, weights=None):
    m = core.gram_moments(x, y, deg, weights=weights,
                          accum_dtype=jnp.float32)
    # kernel path reports the true contributing-point count, the jnp path Σw;
    # compare against the true count
    n_live = (x.shape[-1] if weights is None
              else jnp.sum(weights != 0, axis=-1))
    import dataclasses
    return dataclasses.replace(
        m, count=jnp.broadcast_to(n_live, m.count.shape).astype(m.count.dtype))


@pytest.mark.parametrize("deg", [1, 3, 7, 12])
@pytest.mark.parametrize("b,n", [
    (1, 300),        # single series (auto falls back to plain)
    (7, 1000),       # ragged n, batch < P for every degree here
    (26, 257),       # odd n; 26 not divisible by P at any tested degree
    (50, 128),       # exactly 2 packs at degree 3
])
def test_packed_matches_gram_moments_f32(deg, b, n):
    x, y = _data(deg * 100 + b, b, n)
    mk = ops.moments(x, y, deg)
    # high degrees produce ~1e9-magnitude power sums; blocked-vs-einsum f32
    # rounding alone reaches a few e-5 relative there
    rtol = 2e-5 if deg < 10 else 2e-4
    _assert_moments_close(mk, _jnp_moments(x, y, deg), rtol=rtol)


@pytest.mark.parametrize("deg", [1, 3, 12])
def test_packed_forced_vs_plain(deg):
    """packing='packed' == packing='plain' == jnp, even for b=1."""
    x, y = _data(10 + deg, 1, 513)
    mp = ops.moments(x, y, deg, packing="packed")
    ms = ops.moments(x, y, deg, packing="plain")
    _assert_moments_close(mp, ms, rtol=1e-5, atol=1e-4)
    _assert_moments_close(mp, _jnp_moments(x, y, deg))


@pytest.mark.parametrize("deg", [1, 3])
def test_packed_bf16_inputs_f32_accumulate(deg):
    x, y = _data(20 + deg, 9, 2048, jnp.bfloat16)
    mk = ops.moments(x, y, deg)
    mr = _jnp_moments(x.astype(jnp.float32), y.astype(jnp.float32), deg)
    _assert_moments_close(mk, mr, rtol=1e-2, atol=2e-1)
    assert mk.gram.dtype == jnp.float32


def test_packed_raw_tile_matches_oracle():
    """The packed kernel's raw (G,128,128) tile — diagonal blocks AND the
    never-read cross-series products — equals the explicit construction."""
    deg = 3
    p = kernel.packing_factor(deg)
    x, y = _data(3, 2 * p, 512)
    shape = (2, p, 512)
    w = jnp.ones(shape, jnp.float32)
    g = kernel.moments_packed_extended(
        x.reshape(shape), y.reshape(shape), w, degree=deg, block_n=256,
        interpret=True)
    gr = ref.packed_extended_gram(x.reshape(shape), y.reshape(shape), deg)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                               rtol=2e-5, atol=1e-3)
    # remainder sublanes (128 mod K) are exactly zero
    live = p * (deg + 2)
    assert np.all(np.asarray(g)[:, live:, :] == 0)
    assert np.all(np.asarray(g)[:, :, live:] == 0)


def test_packed_tail_series_masked():
    """Batch not divisible by P: zero-weight tail series vanish exactly."""
    deg = 7                       # P = 14
    b = kernel.packing_factor(deg) + 3
    x, y = _data(4, b, 321)
    _assert_moments_close(ops.moments(x, y, deg), _jnp_moments(x, y, deg))


def test_weights_and_true_count():
    """Weighted fits: gram/vty weighted, count = #points with w != 0."""
    x, y = _data(5, 6, 400)
    w = jnp.concatenate([jnp.ones((6, 300)), jnp.zeros((6, 100))], axis=1)
    w = w * jnp.asarray(np.random.default_rng(5).uniform(.5, 2, (6, 400)),
                        jnp.float32)
    mk = ops.moments(x, y, 3, weights=w)
    mr = core.gram_moments(x, y, 3, weights=w, accum_dtype=jnp.float32)
    for f in ("gram", "vty", "yty"):
        np.testing.assert_allclose(np.asarray(getattr(mk, f)),
                                   np.asarray(getattr(mr, f)),
                                   rtol=2e-5, atol=1e-3, err_msg=f)
    np.testing.assert_array_equal(np.asarray(mk.count), 300.0)
    # Σw (the old `count`) is still reachable as gram[..., 0, 0]
    np.testing.assert_allclose(np.asarray(mk.gram[:, 0, 0]),
                               np.asarray(jnp.sum(w, axis=-1)), rtol=2e-5)


@pytest.mark.parametrize("compensated", [False, True])
def test_compensated_accumulator(compensated):
    """Kahan path matches plain within tolerance; at many blocks it should
    be at least as close to the f64 truth."""
    x, y = _data(6, 4, 8192)
    mk = ops.moments(x, y, 3, block_n=256, compensated=compensated)
    _assert_moments_close(mk, _jnp_moments(x, y, 3))


def test_polyfit_use_kernel_batched_packed():
    """End-to-end: batched polyfit through the packed kernel == jnp path."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.uniform(-2, 2, (33, 512)), jnp.float32)
    y = jnp.asarray(rng.normal(0, 1, (33, 512)), jnp.float32)
    a = core.polyfit(x, y, 3, use_kernel=True).coeffs
    b = core.polyfit(x, y, 3, use_kernel=False).coeffs
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("b,n,deg", [(1, 777, 3), (5, 500, 2), (8, 1024, 5)])
def test_fused_report_matches_fit_report(b, n, deg):
    rng = np.random.default_rng(b * 10 + deg)
    x = jnp.asarray(rng.uniform(-2, 2, (b, n)), jnp.float32)
    y = jnp.asarray(np.asarray(x) ** 2 + rng.normal(0, .3, (b, n)),
                    jnp.float32)
    poly = core.polyfit(x, y, deg)
    srep = core.fit_report_streamed(poly, x, y)
    rep = core.fit_report(poly, x, y)
    np.testing.assert_allclose(np.asarray(srep.sse), np.asarray(rep.sse),
                               rtol=2e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(srep.r), np.asarray(rep.r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(srep.count), n)


def test_fused_report_normalized_domain():
    """Domain-normalized fits evaluate through the fused kernel too."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.uniform(0, 40, 600), jnp.float32)
    y = jnp.asarray(0.1 * np.asarray(x) ** 2 + rng.normal(0, .1, 600),
                    jnp.float32)
    poly = core.polyfit(x, y, 2, normalize=True)
    srep = core.fit_report_streamed(poly, x, y)
    rep = core.fit_report(poly, x, y)
    np.testing.assert_allclose(np.asarray(srep.sse), np.asarray(rep.sse),
                               rtol=2e-4, atol=1e-3)


def test_streaming_update_kernel_path():
    """Kernel-backed streaming update == jnp update (decay-weighted)."""
    st = streaming.StreamState.create(2, (5,), decay=0.999)
    x, y = _data(11, 5, 384)
    s_j = streaming.update(st, x, y)
    s_k = streaming.update(st, x, y, use_kernel=True)
    for f in ("gram", "vty", "yty"):
        np.testing.assert_allclose(np.asarray(getattr(s_j.moments, f)),
                                   np.asarray(getattr(s_k.moments, f)),
                                   rtol=2e-5, atol=1e-3, err_msg=f)
    # fits solved from both states agree
    np.testing.assert_allclose(
        np.asarray(streaming.current_fit(s_j, ridge=1e-6).coeffs),
        np.asarray(streaming.current_fit(s_k, ridge=1e-6).coeffs),
        rtol=5e-3, atol=5e-3)


# ------------------------------------------------- double-buffered DMA kernel
@pytest.mark.parametrize("deg,b,n,nbuf", [
    (1, 4, 700, 2),
    (3, 7, 1000, 2),
    (3, 7, 1000, 3),
    (5, 3, 2500, 4),
    (9, 2, 640, 2),
])
def test_double_buffered_bit_equals_grid_streamed(deg, b, n, nbuf):
    """The multi-buffered DMA pipeline shares ``_packed_tile_update`` with
    the grid-streamed kernel, so at the SAME block_n the two are bit-equal
    (identical summation grouping), not merely close."""
    x, y = _data(17 + deg, b, n)
    block_n = 256
    m0 = ops.moments(x, y, deg, packing="packed", block_n=block_n)
    m1 = ops.moments(x, y, deg, packing="packed", block_n=block_n, nbuf=nbuf)
    for f in ("gram", "vty", "yty", "count", "weight_sum"):
        np.testing.assert_array_equal(np.asarray(getattr(m0, f)),
                                      np.asarray(getattr(m1, f)), err_msg=f)


def test_double_buffered_weighted_and_compensated():
    x, y = _data(23, 5, 900)
    rng = np.random.default_rng(23)
    w = jnp.asarray(rng.uniform(0, 2, x.shape), jnp.float32)
    for comp in (False, True):
        m0 = ops.moments(x, y, 3, weights=w, packing="packed",
                         block_n=256, compensated=comp)
        m1 = ops.moments(x, y, 3, weights=w, packing="packed",
                         block_n=256, compensated=comp, nbuf=2)
        for f in ("gram", "vty", "yty", "weight_sum"):
            np.testing.assert_array_equal(np.asarray(getattr(m0, f)),
                                          np.asarray(getattr(m1, f)),
                                          err_msg=f"{f} comp={comp}")


def test_double_buffered_matches_jnp_reference():
    x, y = _data(29, 6, 1234)        # odd length: tail padding in play
    mk = ops.moments(x, y, 3, packing="packed", block_n=512, nbuf=2)
    _assert_moments_close(mk, _jnp_moments(x, y, 3))


def test_nbuf_validation():
    x, y = _data(31, 4, 256)
    with pytest.raises(ValueError):
        ops.moments(x, y, 3, packing="packed", nbuf=1)
    with pytest.raises(ValueError):
        ops.moments(x, y, 3, packing="plain", nbuf=2)


# ------------------------------------------------------------------- autotune
def test_autotune_feasible_and_cached():
    from repro.kernels import tune
    tune.clear_cache()
    try:
        ticks = iter(range(1000))
        bn = tune.autotune_block_n(3, 4096, reps=1,
                                   timer=lambda: next(ticks) * 1e-3)
        assert bn in tune.CANDIDATE_BLOCKS
        assert bn in tune.feasible_blocks(3)
        # cache hit: no more timer draws
        before = next(ticks)
        assert tune.autotune_block_n(3, 4096) == bn
        assert next(ticks) == before + 1
    finally:
        tune.clear_cache()


def test_autotune_vmem_model_monotone():
    from repro.kernels import tune
    assert (tune.ring_vmem_bytes(3, 2048) < tune.ring_vmem_bytes(3, 4096)
            < tune.ring_vmem_bytes(3, 4096, nbuf=3))
    # every feasible candidate respects the budget
    for deg in (1, 3, 9):
        for bn in tune.feasible_blocks(deg):
            assert tune.ring_vmem_bytes(deg, bn) <= tune.VMEM_BUDGET
