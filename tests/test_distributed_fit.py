"""Distributed (shard_map) fit == serial fit, on a fake 8-device mesh.

Runs in a subprocess-isolated pytest module? No — the whole test session
uses 8 host devices via conftest-free env guard: these tests SKIP unless the
process was started with XLA_FLAGS=--xla_force_host_platform_device_count=8
(tests/run_distributed.py wrapper and the CI target set it). A conftest
option would force 8 devices on every test; we keep the default session at
1 device per the dry-run isolation rule.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.launch import mesh as mesh_lib

needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@needs_devices
@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("normalize", [False, True])
def test_distributed_equals_serial(use_kernel, normalize):
    mesh = mesh_lib.make_host_mesh(data=4, model=2)
    rng = np.random.default_rng(0)
    n = 4096
    x = jnp.asarray(rng.uniform(-10, 10, n), jnp.float32)
    y = jnp.asarray(rng.normal(0, 1, n) + 3 * rng.uniform(-10, 10, n),
                    jnp.float32)
    fit = core.make_distributed_fit(mesh, degree=2, data_axes=("data",),
                                    normalize=normalize,
                                    use_kernel=use_kernel)
    poly, moments = fit(x, y)
    serial = core.polyfit(x, y, 2, normalize=normalize,
                          accum_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(poly.coeffs),
                               np.asarray(serial.coeffs),
                               rtol=5e-3, atol=5e-3)
    assert float(moments.count) == n


@needs_devices
def test_distributed_weighted_padding():
    """Ragged global dataset: padded tail carries weight 0."""
    mesh = mesh_lib.make_host_mesh(data=8, model=1)
    rng = np.random.default_rng(1)
    n_real, n_pad = 1000, 24
    x = np.zeros(n_real + n_pad, np.float32)
    y = np.zeros(n_real + n_pad, np.float32)
    w = np.zeros(n_real + n_pad, np.float32)
    x[:n_real] = rng.uniform(-5, 5, n_real)
    y[:n_real] = 2.0 + 0.5 * x[:n_real]
    w[:n_real] = 1.0
    fit = core.make_distributed_fit(mesh, degree=1)
    poly, m = fit(jnp.asarray(x), jnp.asarray(y), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(poly.coeffs), [2.0, 0.5],
                               rtol=1e-3, atol=1e-3)
    assert float(m.count) == n_real


@needs_devices
def test_collective_payload_is_tiny():
    """The paper's point at pod scale: the only collective moves O(m²)
    bytes, independent of n. Verified on the lowered HLO."""
    from repro.launch import roofline as roof
    mesh = mesh_lib.make_host_mesh(data=8, model=1)
    fit = core.make_distributed_fit(mesh, degree=3)
    n = 1 << 20
    s = jax.ShapeDtypeStruct((n,), jnp.float32)
    lowered = fit.lower(s, s, s)
    coll = roof.collective_bytes(lowered.compile().as_text())
    total = sum(coll.values())
    # all-reduce of gram(4x4)+vty(4)+yty+count floats ≈ 22 f32 ≈ 88B;
    # wire model doubles it; anything under 4KB proves O(m²) not O(n)
    assert total < 4096, coll
