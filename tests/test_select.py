"""Single-pass automatic model selection (``repro.select``).

* ISSUE-4 acceptance: ``select_degree`` / ``degree="auto"`` recover the
  planted degree on noisy synthetic data (degrees 2–6, ≥ 95% of trials at
  SNR ≥ 10) from EXACTLY ONE pass over the data — verified by the
  instrumented counter on moment-producing calls — and the moment-space
  k-fold CV scores match explicit held-out refits to fp tolerance.
* Nesting property (hypothesis): ``fit_from_moments(m.truncate(d))`` of a
  degree-8 state matches a direct ``polyfit(x, y, d)`` across degrees
  0–8, f32/f64, monomial/Chebyshev, identity/normalized domains, jnp and
  kernel engines — κ-scaled tolerances, same style as test_conformance.
* Plumbing: streaming ``current_selection()``, the fit server's
  auto-degree requests, the distributed fold-psum path, criteria edge
  cases (underdetermined rungs score +inf).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro import core, engine, select
from repro.core import streaming
from repro.select import criteria, crossval

enable_x64 = getattr(jax, "enable_x64", jax.experimental.enable_x64)

settings.register_profile("select", deadline=None, max_examples=20)
settings.load_profile("select")


def _planted(seed: int, degree: int, n: int, snr: float = 10.0,
             lo: float = -1.0, hi: float = 1.0):
    """Noisy series with an unambiguous planted degree.

    The signal is drawn in the CHEBYSHEV basis with the leading
    coefficient bounded away from zero: that guarantees the degree-d
    component is genuinely present (orthogonally to all lower degrees)
    above the noise floor.  A raw-monomial draw does not — x^d on [-1,1]
    is almost entirely explained by lower degrees (the orthogonal residual
    of x^6 is ~0.07·c₆), so its "planted degree" can be statistically
    absent, which no selector can recover (measured table in
    EXPERIMENTS.md §Degree selection)."""
    rng = np.random.default_rng(seed)
    c = rng.normal(0.0, 0.5, degree + 1)
    c[degree] = rng.choice([-1.0, 1.0]) * rng.uniform(0.5, 1.5)
    x = rng.uniform(lo, hi, n)
    sig = np.polynomial.chebyshev.chebval(
        (2.0 * x - (hi + lo)) / (hi - lo), c)
    y = sig + (np.std(sig) / snr) * rng.normal(0, 1, n)
    return (jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32), sig)


# ---------------------------------------------------------------- truncate
def test_truncate_slices_leading_submatrix():
    x, y, _ = _planted(0, 3, 200)
    m = core.gram_moments(x, y, 6)
    t = m.truncate(2)
    assert t.degree == 2
    np.testing.assert_array_equal(np.asarray(t.gram),
                                  np.asarray(m.gram[:3, :3]))
    np.testing.assert_array_equal(np.asarray(t.vty), np.asarray(m.vty[:3]))
    np.testing.assert_array_equal(np.asarray(t.yty), np.asarray(m.yty))
    np.testing.assert_array_equal(np.asarray(t.count), np.asarray(m.count))
    with pytest.raises(ValueError, match="truncate"):
        m.truncate(7)


@given(st.integers(0, 8), st.booleans(), st.booleans(),
       st.sampled_from(["f32_reference", "f32_kernel", "f64_reference"]))
def test_truncated_maxdegree_moments_match_direct_fit(degree, chebyshev,
                                                      normalize, mode):
    """The nesting property behind the whole subsystem: a degree-8 state,
    truncated to d, solves to the same polynomial a direct degree-d
    polyfit produces — every basis/domain/engine/dtype combination, with
    κ-scaled tolerances (test_conformance style)."""
    basis = core.CHEBYSHEV if chebyshev else core.MONOMIAL
    engine_name = "kernel" if mode == "f32_kernel" else "reference"
    if chebyshev and engine_name == "kernel":
        return  # the Pallas kernels are monomial-only (validated centrally)
    dtype = jnp.float64 if mode == "f64_reference" else jnp.float32
    ctx = enable_x64(True) if mode == "f64_reference" else None

    rng = np.random.default_rng(1000 + degree)
    n = 160
    x = np.sort(rng.uniform(-1.5, 1.5, n))
    y = (np.polyval(rng.normal(0, 1, degree + 1)[::-1], x)
         + 0.02 * rng.normal(0, 1, n))
    try:
        if ctx is not None:
            ctx.__enter__()
        xj = jnp.asarray(x, dtype)
        yj = jnp.asarray(y, dtype)
        # explicit solver: keeps the numerics policy identical on both
        # sides (polyfit's solver="auto" would escalate normalization per
        # degree, which is a plan property, not a nesting property)
        direct = core.polyfit(xj, yj, degree, basis=basis,
                              normalize=normalize, engine=engine_name,
                              solver="svd")
        dom = (core.Domain.from_data(xj) if normalize
               else core.Domain.identity(dtype))
        plan = engine.plan_fit(xj.shape, 8, basis=basis, dtype=dtype,
                               engine=engine_name)
        m8 = engine.compute_moments(plan, dom.apply(xj), yj)
        nested = core.fit_from_moments(m8.truncate(degree), solver="svd",
                                       domain=dom, basis=basis,
                                       normalized=normalize)
        cond = float(nested.diagnostics.condition)
        eps = float(jnp.finfo(dtype).eps)
        tol = max(200.0 * eps * np.sqrt(max(cond, 1.0)), 50.0 * eps)
        xs = jnp.asarray(np.linspace(-1.5, 1.5, 64), dtype)
        gold = np.asarray(direct(xs), np.float64)
        ours = np.asarray(nested(xs), np.float64)
        gap = (np.linalg.norm(ours - gold)
               / (np.linalg.norm(gold) + 1e-30))
        assert gap <= tol, (f"deg={degree} {basis} norm={normalize} "
                            f"{mode}: {gap:.3e} > {tol:.3e} (κ={cond:.2e})")
    finally:
        if ctx is not None:
            ctx.__exit__(None, None, None)


# ------------------------------------------------- acceptance: planted degree
def test_select_degree_recovers_planted_single_pass():
    """ISSUE-4 acceptance: degrees 2–6, SNR 10, ≥ 95% recovery across
    trials — and every trial costs exactly ONE moment-producing call."""
    trials = 0
    hits = 0
    for degree in range(2, 7):
        for t in range(8):
            x, y, _ = _planted(17 * degree + t, degree, 512)
            engine.reset_moment_counter()
            sel = core.select_degree(x, y, max_degree=8, folds=5)
            counter = engine.moment_counter()
            assert counter["calls"] == 1, (
                f"selection took {counter['calls']} moment passes")
            assert counter["points"] == 515  # 5 folds × 103 (incl. padding)
            trials += 1
            hits += int(sel.best_degree == degree)
    assert hits / trials >= 0.95, f"recovered {hits}/{trials}"


def test_polyfit_degree_auto_front_door():
    x, y, sig = _planted(5, 3, 512)
    poly = core.polyfit(x, y, "auto")
    assert poly.degree == 3
    # the winning fit is a real fit: values track the clean signal
    rel = (np.linalg.norm(np.asarray(poly(x), np.float64) - sig)
           / np.linalg.norm(sig))
    assert rel < 0.05, f"value error {rel:.3f}"
    custom = core.polyfit(x, y, core.DegreeSearch(max_degree=5, folds=3,
                                                  criterion="bic"))
    assert custom.degree == 3
    with pytest.raises(ValueError, match="auto"):
        core.polyfit(x, y, "automatic")


def test_select_degree_moment_criteria_no_folds():
    x, y, _ = _planted(9, 4, 512)
    engine.reset_moment_counter()
    sel = core.select_degree(x, y, max_degree=8, folds=0)
    assert engine.moment_counter()["calls"] == 1
    assert sel.criterion == "aicc"
    assert sel.best_degree == 4
    assert np.all(np.isinf(np.asarray(sel.sweep.scores.cv)))
    with pytest.raises(ValueError, match="folds"):
        core.select_degree(x, y, folds=0, criterion="cv")


# --------------------------------------------- acceptance: CV == explicit
def test_cv_scores_match_explicit_heldout_refits():
    """Moment-space k-fold CV == explicit held-out refits, to fp
    tolerance: for each fold, refit the complement FROM THE RAW DATA at
    every degree and score the held-out points directly."""
    k, max_deg, n = 4, 6, 240
    with enable_x64(True):
        rng = np.random.default_rng(7)
        x = rng.uniform(-1.0, 1.0, n)
        y = (np.polyval([0.9, 0.3, -1.0, 0.5], x)
             + 0.05 * rng.normal(0, 1, n))
        xj = jnp.asarray(x, jnp.float64)
        yj = jnp.asarray(y, jnp.float64)
        folds = crossval.fold_moments(xj, yj, k, max_deg)
        got, _ = crossval.cv_scores(folds, solver="qr", fallback=None)
        got = np.asarray(got)
        want = np.zeros(max_deg + 1)
        fold_of = np.arange(n) % k
        for j in range(k):
            tr, ho = fold_of != j, fold_of == j
            for d in range(max_deg + 1):
                m = core.gram_moments(jnp.asarray(x[tr]),
                                      jnp.asarray(y[tr]), d)
                poly = core.fit_from_moments(m, solver="qr", fallback=None)
                e = y[ho] - np.asarray(poly(jnp.asarray(x[ho])))
                want[d] += float(e @ e)
        np.testing.assert_allclose(got, want, rtol=1e-8)


def test_fold_moments_sum_to_total():
    x, y, _ = _planted(11, 3, 200)
    folds = crossval.fold_moments(x, y, 5, 4)
    total = crossval.sum_folds(folds)
    direct = core.gram_moments(x, y, 4)
    np.testing.assert_allclose(np.asarray(total.gram),
                               np.asarray(direct.gram), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(total.count), 200.0)
    # complement + fold == total, leaf by leaf
    comp = crossval.complement_moments(folds, total)
    back = jax.tree.map(lambda a, b: a + b, comp, folds)
    for leaf_b, leaf_t in zip(jax.tree.leaves(back),
                              jax.tree.leaves(total)):
        np.testing.assert_allclose(np.asarray(leaf_b)[0],
                                   np.asarray(leaf_t), rtol=1e-4,
                                   atol=1e-5)


# ------------------------------------------------------------ criteria edges
def test_underdetermined_degrees_score_inf():
    x, y, _ = _planted(13, 1, 6)   # 6 points, ladder to degree 8
    sel = core.select_degree(x, y, max_degree=8, folds=0)
    scores = sel.sweep.scores
    assert np.all(np.isinf(np.asarray(scores.aicc)[6:]))   # n <= k
    assert sel.best_degree <= 4                            # AICc dof guard
    assert np.all(np.isfinite(np.asarray(scores.sse)))


def test_best_degree_rejects_monotone_criteria():
    x, y, _ = _planted(14, 2, 64)
    sel = core.select_degree(x, y, max_degree=4, folds=0)
    with pytest.raises(ValueError, match="monotone"):
        criteria.best_degree(sel.sweep.scores, "r2")
    with pytest.raises(ValueError, match="criterion"):
        core.select_degree(x, y, criterion="press")


def test_batched_select_padded_winner_layout():
    """Batched series with different planted degrees: per-series winners,
    zero-padded winning coefficients that evaluate correctly."""
    xs, ys = [], []
    for i, d in enumerate((1, 3)):
        x, y, _ = _planted(20 + i, d, 256)
        xs.append(x)
        ys.append(y)
    xb = jnp.stack(xs)
    yb = jnp.stack(ys)
    sel = core.select_degree(xb, yb, max_degree=6, folds=4)
    np.testing.assert_array_equal(sel.best_degree, [1, 3])
    assert sel.poly.coeffs.shape == (2, 7)         # padded M+1 layout
    np.testing.assert_array_equal(np.asarray(sel.poly.coeffs[0, 2:]), 0.0)


# ----------------------------------------------------------------- streaming
def test_streaming_current_selection_converges():
    x, y, _ = _planted(31, 3, 1200)
    st = streaming.StreamState.create(8, cv_folds=5)
    for lo in range(0, 1200, 50):
        st = streaming.update(st, x[lo:lo + 50], y[lo:lo + 50])
    sel = st.current_selection()
    assert sel.criterion == "cv"
    assert sel.best_degree == 3
    assert st.current_selection(criterion="aicc").best_degree == 3
    # fold partials really partition the stream: they sum to the total
    total = crossval.sum_folds(st.fold_moments)
    np.testing.assert_allclose(np.asarray(total.gram),
                               np.asarray(st.moments.gram), rtol=1e-5)


def test_streaming_selection_needs_folds_for_cv():
    st = streaming.StreamState.create(4)
    x, y, _ = _planted(33, 2, 64)
    st = streaming.update(st, x, y)
    assert st.fold_moments is None
    assert st.current_selection().criterion == "aicc"
    with pytest.raises(ValueError, match="cv_folds"):
        st.current_selection(criterion="cv")


# --------------------------------------------------------------- fit server
def test_serve_auto_degree_requests():
    from repro.serve import FitServeConfig, FitServeEngine
    eng = FitServeEngine(FitServeConfig(degree=6, n_slots=4, buckets=(128,),
                                        select_criterion="aicc"))
    execs = eng.warmup()
    rng = np.random.default_rng(40)
    x = rng.uniform(-2, 2, 300).astype(np.float32)
    y = (1.0 + 0.5 * x - 2.0 * x * x
         + 0.05 * rng.normal(0, 1, 300)).astype(np.float32)
    auto = eng.submit(x, y, degree="auto")
    fixed = eng.submit(x, y)
    eng.run()
    assert auto.done and fixed.done
    assert auto.degree == 2
    assert auto.coeffs.shape == (3,)
    np.testing.assert_allclose(auto.coeffs, [1.0, 0.5, -2.0], atol=0.05)
    assert set(select.MOMENT_CRITERIA) <= set(auto.scores)
    assert all(v.shape == (7,) for v in auto.scores.values())
    assert auto.condition_ladder.shape == (7,)
    assert np.isfinite(auto.condition)
    assert fixed.degree == 6                       # fixed path reports too
    # the auto path added no executables beyond warmup's
    assert eng.compiled_executables() == execs
    with pytest.raises(ValueError, match="auto"):
        eng.submit(x, y, degree=4)


def test_serve_auto_degree_sse_consistent_under_ridge():
    """A visible ridge stabilizer must not leak into the reported scores:
    the auto path solves on the regularized state but scores on the raw
    moments, exactly like the fixed-degree path."""
    from repro.serve import FitServeConfig, FitServeEngine
    eng = FitServeEngine(FitServeConfig(degree=3, n_slots=2, buckets=(128,),
                                        ridge=1e-3))
    x, y, _ = _planted(41, 3, 256)
    auto = eng.submit(np.asarray(x), np.asarray(y), degree="auto")
    fixed = eng.submit(np.asarray(x), np.asarray(y))
    eng.run()
    assert auto.degree == 3 == fixed.degree
    np.testing.assert_allclose(auto.sse, fixed.sse, rtol=1e-5)
    np.testing.assert_allclose(auto.r, fixed.r, rtol=1e-5)


def test_serve_rejects_cv_criterion():
    from repro.serve import FitServeConfig, FitServeEngine
    with pytest.raises(ValueError, match="fold"):
        FitServeEngine(FitServeConfig(select_criterion="cv"))


# -------------------------------------------------------------- distributed
def test_distributed_select_host_mesh():
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_host_mesh(data=1, model=1)
    x, y, sig = _planted(50, 3, 600)
    sel_fn = core.make_distributed_select(mesh, 6, folds=4)
    poly, sweep, best = sel_fn(x, y)
    assert int(best) == 3
    assert np.asarray(sweep.scores.cv).shape == (7,)
    assert np.all(np.isfinite(np.asarray(sweep.scores.cv)))
    # the returned winning fit evaluates on RAW x (padded ladder layout)
    rel = (np.linalg.norm(np.asarray(poly(x), np.float64) - sig)
           / np.linalg.norm(sig))
    assert rel < 0.05, f"winning fit off by {rel:.3f}"
    # matches the single-host path on the same folds
    local = core.select_degree(x, y, max_degree=6, folds=4)
    np.testing.assert_allclose(np.asarray(sweep.scores.cv),
                               np.asarray(local.sweep.scores.cv),
                               rtol=1e-4)


def test_distributed_select_wide_domain_carries_domain():
    """The auto-normalized (degree >= 6, f32) distributed selection must
    return coefficients WITH their Domain — evaluating the winning poly on
    raw wide-domain x has to track the signal."""
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_host_mesh(data=1, model=1)
    x, y, sig = _planted(51, 3, 600, lo=0.0, hi=40.0)
    poly, sweep, best = core.make_distributed_select(mesh, 8, folds=4)(x, y)
    assert int(best) == 3
    assert float(poly.domain_scale) != 1.0         # auto-normalization on
    rel = (np.linalg.norm(np.asarray(poly(x), np.float64) - sig)
           / np.linalg.norm(sig))
    assert rel < 0.05, f"domain lost: rel error {rel:.3f}"
