"""Perf-gate units: measured-bandwidth ceilings, roofline fractions, and
``check_gate`` budget semantics — plus extra canned-HLO collective parsing
cases for ``launch.roofline`` (the static half the gate builds on)."""
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.launch import perfgate as pg
from repro.launch import roofline as roof

settings.register_profile("perfgate", deadline=None, max_examples=20)
settings.load_profile("perfgate")


# ------------------------------------------------------- HLO collective bytes
HLO_MIXED = """
ENTRY %main {
  %p = bf16[64,512]{1,0} parameter(0)
  %ag = bf16[128,512]{1,0} all-gather(%p), replica_groups={{0,1}}
  %rs = f32[32,512]{1,0} reduce-scatter(%q), replica_groups={{0,1}}
}
"""

HLO_NO_COLLECTIVES = """
ENTRY %main {
  %p = f32[128,128]{1,0} parameter(0)
  %d = f32[128,128]{1,0} dot(%p, %p)
}
"""


def test_collective_bytes_mixed_ops_and_dtypes():
    got = roof.collective_bytes(HLO_MIXED)
    # all-gather output is bf16 (2 bytes); reduce-scatter output is f32;
    # both carry wire factor 1.0 (only all-reduce moves the shape twice)
    assert got["all-gather"] == 128 * 512 * 2 * 1.0
    assert got["reduce-scatter"] == 32 * 512 * 4 * 1.0


def test_collective_bytes_empty_when_no_collectives():
    assert roof.collective_bytes(HLO_NO_COLLECTIVES) == {}


# ------------------------------------------------------------------- ceilings
def test_stream_bytes_counts_streams():
    assert pg.stream_bytes(1000) == 1000 * 2 * 4
    assert pg.stream_bytes(1000, streams=3) == 1000 * 3 * 4
    with pytest.raises(ValueError):
        pg.stream_bytes(-1)
    with pytest.raises(ValueError):
        pg.stream_bytes(10, streams=0)


@given(st.integers(0, 10**12), st.integers(1, 10**12))
def test_memory_s_monotone_in_bytes(extra, base):
    """More bytes can never take less time at fixed bandwidth."""
    bw = pg.Bandwidth(gbps=50.0, source="model", backend="cpu")
    assert pg.memory_s(base + extra, bw) >= pg.memory_s(base, bw)


def test_memory_s_validates_inputs():
    bw = pg.Bandwidth(gbps=10.0, source="model", backend="cpu")
    assert pg.memory_s(10e9, bw) == pytest.approx(1.0)
    with pytest.raises(ValueError):
        pg.memory_s(-1.0, bw)
    with pytest.raises(ValueError):
        pg.memory_s(1.0, 0.0)


def test_ceiling_and_fraction_roundtrip():
    bw = pg.Bandwidth(gbps=80.0, source="measured", backend="cpu")
    # 2 f32 streams/point at 80 GB/s -> 10,000 Mpts/s ceiling
    assert pg.ceiling_mpts(bw) == pytest.approx(10_000.0)
    assert pg.roofline_fraction(1_000.0, bw) == pytest.approx(0.1)
    # 3 streams lowers the ceiling, raising the achieved fraction
    assert (pg.roofline_fraction(1_000.0, bw, streams=3)
            > pg.roofline_fraction(1_000.0, bw, streams=2))


def test_measure_bandwidth_sane_and_cached():
    bw = pg.measure_bandwidth(n_mb=4, reps=2, iters=2, force=True)
    assert bw.backend == jax.default_backend()
    assert bw.source in ("measured", "model")
    assert 0.1 < bw.gbps < 1e5
    assert pg.measure_bandwidth() is bw          # cache hit


# ----------------------------------------------------------------------- gate
def _row(name, us, *, frac=None, interpret=False, status="ok", **kw):
    r = {"name": name, "us_per_call": us, "interpret": interpret,
         "status": status}
    if frac is not None:
        r["roofline_frac"] = frac
    r.update(kw)
    return r


BASELINE = {
    "default_max_slowdown": 3.0,
    "rows": {
        "moments_jnp": {"ref_us": 100.0},
        "serve_fit": {"ref_us": 200.0, "max_slowdown": 2.0},
        "moments_packed": {"ref_us": 50.0, "min_roofline_frac": 0.05},
    },
}


def test_gate_passes_within_budget():
    rows = [_row("moments_jnp", 250.0, frac=0.5),
            _row("serve_fit", 399.0),
            _row("moments_packed", 60.0, frac=0.10)]
    rep = pg.check_gate(rows, BASELINE)
    assert rep.ok and len(rep.checked) == 3
    assert "PASS" in rep.render()


def test_gate_regression_breach_names_row_and_budget():
    rows = [_row("moments_jnp", 100.0),
            _row("serve_fit", 401.0),                    # > 200 x 2.0
            _row("moments_packed", 50.0, frac=0.10)]
    rep = pg.check_gate(rows, BASELINE)
    assert not rep.ok
    (b,) = rep.breaches
    assert b.row == "serve_fit" and b.kind == "regression"
    assert b.budget == pytest.approx(400.0)
    assert b.measured == pytest.approx(401.0)
    assert "serve_fit" in rep.render() and "400.0" in b.detail


def test_gate_roofline_floor_binds_on_hardware_rows():
    rows = [_row("moments_jnp", 100.0),
            _row("serve_fit", 200.0),
            _row("moments_packed", 50.0, frac=0.01)]     # below 0.05 floor
    rep = pg.check_gate(rows, BASELINE)
    (b,) = rep.breaches
    assert b.row == "moments_packed" and b.kind == "roofline"
    assert b.budget == pytest.approx(0.05)


def test_gate_roofline_floor_excluded_for_interpret_rows():
    rows = [_row("moments_jnp", 100.0),
            _row("serve_fit", 200.0),
            _row("moments_packed", 50.0, frac=0.0001, interpret=True)]
    rep = pg.check_gate(rows, BASELINE)
    assert rep.ok
    assert any("interpret" in s for s in rep.skipped)


def test_gate_missing_and_failed_rows_breach():
    rows = [_row("moments_jnp", 100.0, status="failed", error="boom"),
            _row("moments_packed", 50.0, frac=0.10)]
    rep = pg.check_gate(rows, BASELINE)
    kinds = {b.row: b.kind for b in rep.breaches}
    assert kinds == {"moments_jnp": "failed", "serve_fit": "missing"}
    assert "boom" in next(b.detail for b in rep.breaches
                          if b.kind == "failed")


def test_make_baseline_sets_floors_only_on_hardware_rows():
    rows = [_row("a", 100.0, frac=0.2, interpret=False),
            _row("b", 50.0, frac=0.3, interpret=True),
            _row("c", 10.0, status="failed"),
            _row("d", 10.0)]
    base = pg.make_baseline(rows, roofline_margin=0.5, gated=("a", "b", "c"))
    assert base["rows"]["a"] == {"ref_us": 100.0, "min_roofline_frac": 0.1}
    assert base["rows"]["b"] == {"ref_us": 50.0}         # interpret: no floor
    assert "c" not in base["rows"]                       # failed: no budget
    assert "d" not in base["rows"]                       # not gated
    # and the derived baseline gates its own run clean
    assert pg.check_gate(rows, base).ok
