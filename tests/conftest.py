"""Session conftest: make the suite collect offline.

* Ensures ``src/`` is importable even when pytest is invoked without
  PYTHONPATH=src (pyproject's ``pythonpath`` handles the normal case; this
  covers direct ``pytest tests/...`` invocations from other cwds).
* Installs ``tests/_hypothesis_compat.py`` as the ``hypothesis`` module when
  the real package is unavailable (hermetic/offline environments), so the
  seven property-test modules collect and run on fixed example sets.
* Arms the recompile-counter tripwire (``repro.analysis.sanitizers``) when
  ``REPRO_RECOMPILE_TRIPWIRE=1``: any test marked ``no_recompile`` fails if
  it triggers an XLA executable compile — the serve warmup invariant,
  generalized to any test.  CI's ``lint-static`` job runs one pytest leg
  with the flag set.
"""
from __future__ import annotations

import importlib.util
import os
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:  # prefer the real thing when it exists
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join(os.path.dirname(__file__),
                                   "_hypothesis_compat.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    # `from hypothesis import strategies as st` resolves via attribute, but
    # register the submodule path too for plain `import hypothesis.strategies`.
    sys.modules["hypothesis.strategies"] = _mod.strategies


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "no_recompile: with REPRO_RECOMPILE_TRIPWIRE=1, fail this test if "
        "it triggers any XLA executable compile")


@pytest.fixture(autouse=True)
def _recompile_tripwire(request):
    if (os.environ.get("REPRO_RECOMPILE_TRIPWIRE") != "1"
            or request.node.get_closest_marker("no_recompile") is None):
        yield
        return
    from repro.analysis.sanitizers import CompileCounter
    with CompileCounter() as counter:
        yield
    if counter.count:
        pytest.fail(
            f"no_recompile test compiled {counter.count} executable(s): "
            f"{counter.names}")
