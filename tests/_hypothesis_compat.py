"""Offline fallback for ``hypothesis``: fixed-example property testing.

The real hypothesis package is not available in the hermetic CI/container
image, but seven test modules use ``@given`` property sweeps.  This shim
implements the tiny subset those tests rely on (``given``, ``settings``
profiles, ``strategies.integers/floats/sampled_from``) and runs each
``@given`` test on a small *deterministic* example set instead of a random
search: the strategy boundaries first, then seeded pseudo-random draws.

``tests/conftest.py`` installs this module as ``sys.modules["hypothesis"]``
only when the real package cannot be imported, so environments that do have
hypothesis get the genuine article.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

# examples per @given test (boundaries + seeded draws); kept small so the
# offline suite stays fast — the real hypothesis, when installed, explores
# the profile's full max_examples.
_N_EXAMPLES = 5


class _Strategy:
    """A value source: fixed boundary examples + seeded random draws."""

    def __init__(self, draw, boundaries):
        self._draw = draw
        self._boundaries = list(boundaries)

    def example_at(self, i, rng):
        if i < len(self._boundaries):
            return self._boundaries[i]
        return self._draw(rng)


class strategies:  # noqa: N801 — mimics the `hypothesis.strategies` module
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            [min_value, max_value])

    @staticmethod
    def floats(min_value, max_value, **_kw):
        mid = 0.5 * (min_value + max_value)
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)),
            [min_value, max_value, mid])

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(
            lambda rng: elements[int(rng.integers(len(elements)))],
            elements[:2])

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)), [False, True])


class settings:  # noqa: N801 — mimics the `hypothesis.settings` class
    _profiles: dict = {}
    _current: dict = {}

    def __init__(self, **kwargs):
        self.kwargs = kwargs

    def __call__(self, fn):  # used as a decorator: pass through
        return fn

    @classmethod
    def register_profile(cls, name, **kwargs):
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name):
        cls._current = cls._profiles.get(name, {})


def given(*strats, **kw_strats):
    """Run the wrapped test on a fixed, deterministic example set."""

    def decorate(fn):
        seed = zlib.crc32(fn.__qualname__.encode())

        @functools.wraps(fn)
        def wrapper():
            rng = np.random.default_rng(seed)
            for i in range(_N_EXAMPLES):
                args = tuple(s.example_at(i, rng) for s in strats)
                kwargs = {k: s.example_at(i, rng)
                          for k, s in kw_strats.items()}
                try:
                    fn(*args, **kwargs)
                except _UnsatisfiedAssumption:
                    continue  # skip examples the test assume()s away

        # hide the strategy-fed parameters from pytest's fixture resolution
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return decorate


class HealthCheck:  # accessed by some hypothesis configs; inert here
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    data_too_large = "data_too_large"


def assume(condition):
    if not condition:
        raise _UnsatisfiedAssumption()


class _UnsatisfiedAssumption(Exception):
    pass
