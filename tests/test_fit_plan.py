"""Unified fit-engine dispatch: plan selection, central validation, the
deprecated use_kernel alias, and the unified count/weight_sum semantics."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core, engine
from repro.core import streaming
from repro.kernels import ops as kernel_ops


def _data(seed, shape):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-2, 2, shape), jnp.float32)
    y = jnp.asarray(rng.normal(0, 1, shape), jnp.float32)
    return x, y


# ------------------------------------------------------------ plan selection
def test_auto_selects_packed_for_batched_monomial_on_tpu():
    plan = engine.plan_fit((33, 512), 3, backend="tpu")
    assert plan.path == engine.KERNEL_PACKED
    assert plan.packing == "packed"
    assert plan.uses_kernel


def test_auto_single_series_crossover_on_tpu():
    small = engine.plan_fit((1000,), 3, backend="tpu")
    big = engine.plan_fit((engine.KERNEL_MIN_POINTS,), 3, backend="tpu")
    assert small.path == engine.REFERENCE
    assert big.path == engine.KERNEL_PLAIN


def test_auto_stays_reference_off_tpu():
    plan = engine.plan_fit((33, 512), 3, backend="cpu")
    assert plan.path == engine.REFERENCE


def test_auto_reference_for_chebyshev_and_huge_degree():
    assert engine.plan_fit((8, 256), 3, basis="chebyshev",
                           backend="tpu").path == engine.REFERENCE
    assert engine.plan_fit((8, 256), 200,
                           backend="tpu").path == engine.REFERENCE


def test_report_workload_prefers_fused_kernel_everywhere():
    assert engine.plan_fit((4, 256), 3, backend="cpu",
                           workload="report").path == engine.KERNEL_PLAIN
    assert engine.plan_fit((4, 256), 3, basis="chebyshev",
                           workload="report").path == engine.REFERENCE


def test_mesh_marks_plan_distributed():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = engine.plan_fit((512,), 2, mesh=mesh, data_axes=("data",))
    assert not plan.distributed and plan.devices == 1
    assert "FitPlan" in plan.describe()


# -------------------------------------------------------- central validation
def test_forced_kernel_rejects_chebyshev_everywhere():
    x, y = _data(0, (4, 128))
    with pytest.raises(ValueError, match="monomial"):
        engine.plan_fit((4, 128), 2, basis="chebyshev", engine="kernel")
    with pytest.raises(ValueError, match="monomial"):
        core.polyfit(x, y, 2, basis="chebyshev", engine="kernel")
    with pytest.raises(ValueError, match="monomial"):
        # previously silently ignored the basis on the kernel path
        core.local_moments(x, y, 2, basis="chebyshev", engine="kernel")


def test_make_distributed_fit_validates_eagerly():
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_host_mesh(data=1, model=1)
    with pytest.raises(ValueError, match="monomial"):
        core.make_distributed_fit(mesh, 2, basis="chebyshev",
                                  engine="kernel")


def test_forced_packed_needs_packing_room():
    with pytest.raises(ValueError, match="pack"):
        engine.plan_fit((4, 128), 63, engine="kernel_packed")


def test_bad_engine_name():
    with pytest.raises(ValueError, match="engine"):
        engine.plan_fit((128,), 2, engine="cuda")


# ----------------------------------------------- execution matches old paths
def test_engine_kernel_bitwise_matches_use_kernel_true():
    x, y = _data(1, (33, 512))
    a = core.polyfit(x, y, 3, engine="kernel").coeffs
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        b = core.polyfit(x, y, 3, use_kernel=True).coeffs
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_use_kernel_alias_warns():
    x, y = _data(2, (257,))
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        core.polyfit(x, y, 2, use_kernel=False)


def test_use_kernel_alias_maps_to_engine():
    """The deprecation contract, pinned: the alias warns AND resolves to
    exactly the engine= spelling it documents."""
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        assert engine.resolve_engine("auto", True) == "kernel"
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        assert engine.resolve_engine("auto", False) == "reference"
    assert engine.resolve_engine("auto", None) == "auto"

    # polyfit: use_kernel=True/False produce the same moments/coeffs as
    # the engine= spelling they map to (fresh shapes force a trace, so
    # the warning fires inside the jitted wrapper too)
    x, y = _data(11, (3, 259))
    want_k = core.polyfit(x, y, 2, engine="kernel").coeffs
    want_r = core.polyfit(x, y, 2, engine="reference").coeffs
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        got_k = core.polyfit(x, y, 2, use_kernel=True).coeffs
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        got_r = core.polyfit(x, y, 2, use_kernel=False).coeffs
    np.testing.assert_array_equal(np.asarray(want_k), np.asarray(got_k))
    np.testing.assert_array_equal(np.asarray(want_r), np.asarray(got_r))


def test_streaming_update_use_kernel_alias_warns_and_maps():
    x, y = _data(12, (2, 263))
    st = streaming.StreamState.create(2, (2,))
    want_k = streaming.update(st, x, y, engine="kernel")
    want_r = streaming.update(st, x, y, engine="reference")
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        got_k = streaming.update(st, x, y, use_kernel=True)
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        got_r = streaming.update(st, x, y, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(want_k.moments.gram),
                                  np.asarray(got_k.moments.gram))
    np.testing.assert_array_equal(np.asarray(want_r.moments.gram),
                                  np.asarray(got_r.moments.gram))


def test_plan_execution_matches_direct_kernel_call():
    """compute_moments on a packed plan == calling ops.moments directly."""
    x, y = _data(3, (10, 300))
    plan = engine.plan_fit(x.shape, 3, engine="kernel_packed")
    mp = engine.compute_moments(plan, x, y)
    mk = kernel_ops.moments(x, y, 3, packing="packed")
    for f in ("gram", "vty", "yty", "count", "weight_sum"):
        np.testing.assert_array_equal(np.asarray(getattr(mp, f)),
                                      np.asarray(getattr(mk, f)), err_msg=f)


def test_auto_reference_matches_legacy_default():
    x, y = _data(4, (6, 400))
    a = core.polyfit(x, y, 2).coeffs                      # engine="auto", CPU
    b = core.polyfit(x, y, 2, engine="reference").coeffs
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------- unified count/weight_sum semantics
def test_jnp_count_is_true_count_weight_sum_is_mass():
    x, y = _data(5, (3, 200))
    w = jnp.concatenate([jnp.full((3, 150), 0.5), jnp.zeros((3, 50))], axis=1)
    mj = core.gram_moments(x, y, 2, weights=w)
    mk = kernel_ops.moments(x, y, 2, weights=w)
    np.testing.assert_array_equal(np.asarray(mj.count), 150.0)
    np.testing.assert_array_equal(np.asarray(mk.count), 150.0)
    np.testing.assert_allclose(np.asarray(mj.weight_sum), 75.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(mk.weight_sum), 75.0, rtol=1e-5)


def test_kernel_and_jnp_stream_states_mix():
    """The old caveat is gone: states from both paths fold together and the
    count stays the exact point total."""
    x, y = _data(6, (4, 160))
    st = streaming.StreamState.create(2, (4,))
    st = streaming.update(st, x, y, engine="reference")
    st = streaming.update(st, x, y, engine="kernel")
    np.testing.assert_array_equal(np.asarray(st.moments.count), 320.0)
    np.testing.assert_allclose(np.asarray(st.moments.weight_sum), 320.0,
                               rtol=1e-6)


def test_decay_underflow_does_not_undercount():
    """γ^age underflows to exactly 0 in f32 past age ~700 — count must
    still record every point of a long chunk."""
    x, y = _data(9, (2048,))
    st = streaming.StreamState.create(1, decay=0.9)
    st = streaming.update(st, x, y)
    np.testing.assert_array_equal(np.asarray(st.moments.count), 2048.0)


def test_use_kernel_conflicting_with_engine_raises():
    x, y = _data(10, (4, 128))
    with pytest.raises(ValueError, match="conflicting"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            core.polyfit(x, y, 2, engine="kernel_packed", use_kernel=False)


def test_decayed_stream_count_does_not_decay():
    x, y = _data(7, (96,))
    st = streaming.StreamState.create(1, decay=0.9)
    for lo in range(0, 96, 32):
        st = streaming.update(st, x[lo:lo + 32], y[lo:lo + 32])
    np.testing.assert_array_equal(np.asarray(st.moments.count), 96.0)
    # weighted mass decays: Σ γ^age over all 96 points
    want = float(np.sum(0.9 ** np.arange(96)))
    np.testing.assert_allclose(np.asarray(st.moments.weight_sum), want,
                               rtol=1e-5)


def test_sse_from_moments_shared_coeffs_against_batched_states():
    """One reference polynomial scored against many series' states (the
    streaming-monitor shape): coeffs rank BELOW the moments batch rank
    must keep broadcasting."""
    x, y = _data(13, (4, 200))
    m = core.gram_moments(x, y, 2)                 # batch (4,)
    ref = core.polyfit(x[0], y[0], 2)              # shared (3,) coeffs
    got = np.asarray(core.sse_from_moments(m, ref.coeffs))
    assert got.shape == (4,)
    want = np.asarray(core.fit_report(ref, x, y).sse)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)
    rep = core.report_from_moments(m, ref.coeffs)
    assert np.asarray(rep.sse).shape == (4,)
    np.testing.assert_allclose(np.asarray(rep.sse), want, rtol=1e-3,
                               atol=1e-2)


def test_report_from_moments_matches_fit_report():
    x, y = _data(8, (5, 300))
    poly = core.polyfit(x, y, 3)
    rep = core.fit_report(poly, x, y)
    got = core.report_from_moments(core.gram_moments(x, y, 3), poly.coeffs)
    np.testing.assert_allclose(np.asarray(got.sse), np.asarray(rep.sse),
                               rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(np.asarray(got.r), np.asarray(rep.r),
                               rtol=1e-3, atol=1e-3)
