"""Streaming fitter (O(1) state) and the LSE-powered training monitors."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro import core
from repro.core import streaming
from repro.train.monitors import LossCurveMonitor, StepTimeMonitor

settings.register_profile("stream", deadline=None, max_examples=20)
settings.load_profile("stream")


@given(st.integers(0, 10_000), st.integers(1, 3))
def test_streaming_equals_batch(seed, degree):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, 96).astype(np.float32)
    y = rng.normal(0, 1, 96).astype(np.float32)
    state = streaming.StreamState.create(degree)
    for lo in range(0, 96, 32):
        state = streaming.update(state, jnp.asarray(x[lo:lo + 32]),
                                 jnp.asarray(y[lo:lo + 32]))
    stream_poly = streaming.current_fit(state)
    batch_poly = core.polyfit(jnp.asarray(x), jnp.asarray(y), degree)
    np.testing.assert_allclose(np.asarray(stream_poly.coeffs),
                               np.asarray(batch_poly.coeffs),
                               rtol=2e-2, atol=2e-2)


def test_streaming_decay_is_exact_ewls():
    """γ-decayed streaming fit == direct weighted LSE with weights γ^age."""
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, 64).astype(np.float32)
    y = (2.0 + 3.0 * x + rng.normal(0, 0.1, 64)).astype(np.float32)
    gamma = 0.97
    state = streaming.StreamState.create(1, decay=gamma)
    for i in range(0, 64, 16):
        state = streaming.update(state, jnp.asarray(x[i:i + 16]),
                                 jnp.asarray(y[i:i + 16]))
    got = np.asarray(streaming.current_fit(state).coeffs)

    ages = np.arange(63, -1, -1)
    w = gamma ** ages
    m = core.gram_moments(jnp.asarray(x), jnp.asarray(y), 1,
                          weights=jnp.asarray(w, jnp.float32))
    want = np.asarray(core.fit_from_moments(m).coeffs)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_streaming_sse_tracks():
    rng = np.random.default_rng(1)
    x = rng.uniform(-1, 1, 128).astype(np.float32)
    y = (1.0 - 0.5 * x + rng.normal(0, 0.2, 128)).astype(np.float32)
    state = streaming.StreamState.create(1)
    state = streaming.update(state, jnp.asarray(x), jnp.asarray(y))
    poly = streaming.current_fit(state)
    sse = float(streaming.current_sse(state, poly))
    direct = float(np.sum((np.asarray(poly(jnp.asarray(x))) - y) ** 2))
    assert abs(sse - direct) / direct < 0.05


@given(st.integers(0, 10_000), st.integers(2, 5),
       st.sampled_from([0.9, 0.97, 0.999]),
       st.sampled_from(["reference", "kernel"]))
def test_streaming_decay_chunks_match_weighted_polyfit(seed, k_chunks,
                                                       gamma, engine):
    """Property: a γ-decayed StreamState folded over K chunks solves the
    exact γ-weighted LSE on the concatenated data — on the kernel path and
    the jnp path alike (the paths share count/weight_sum semantics now)."""
    chunk = 32
    n = chunk * k_chunks
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, n).astype(np.float32)
    y = (1.5 - 2.0 * x + 0.5 * x * x
         + rng.normal(0, 0.1, n)).astype(np.float32)

    state = streaming.StreamState.create(2, decay=gamma)
    for lo in range(0, n, chunk):
        state = streaming.update(state, jnp.asarray(x[lo:lo + chunk]),
                                 jnp.asarray(y[lo:lo + chunk]),
                                 engine=engine)
    got = np.asarray(streaming.current_fit(state).coeffs)

    ages = np.arange(n - 1, -1, -1, dtype=np.float64)
    w = jnp.asarray(gamma ** ages, jnp.float32)
    want = np.asarray(core.polyfit(jnp.asarray(x), jnp.asarray(y), 2,
                                   weights=w).coeffs)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)
    # count is the raw point total (undecayed); weight_sum the γ-mass
    np.testing.assert_allclose(np.asarray(state.moments.count), n)
    np.testing.assert_allclose(np.asarray(state.moments.weight_sum),
                               float(np.sum(gamma ** ages)), rtol=1e-4)


# -------------------------------------------------------------- monitors
def test_loss_monitor_detects_divergence():
    mon = LossCurveMonitor(degree=2, decay=0.9)
    for step in range(50):
        mon.observe(step, 5.0 * np.exp(-step / 30))     # improving
    assert not mon.diverging(49)
    for step in range(50, 90):
        mon.observe(step, 1.0 + 0.05 * (step - 50))     # diverging
    assert mon.diverging(89)


def test_loss_monitor_eta():
    mon = LossCurveMonitor(degree=1, decay=1.0)
    for step in range(100):
        mon.observe(step, 10.0 - 0.01 * step)           # linear descent
    eta = mon.eta_to(8.0, 99)
    assert eta is not None and 50 <= eta <= 150          # ~100 steps away
    assert mon.eta_to(-100.0, 99, horizon=1000) is None


def test_steptime_monitor_flags_straggler():
    mon = StepTimeMonitor(n_hosts=8, threshold=1.3)
    rng = np.random.default_rng(2)
    for step in range(20):
        t = 1.0 + rng.normal(0, 0.02, 8)
        t[5] = 1.8 + rng.normal(0, 0.05)                 # slow host
        mon.observe(step, t)
    assert mon.stragglers(20) == [5]


def test_steptime_monitor_no_false_positive():
    mon = StepTimeMonitor(n_hosts=8, threshold=1.3)
    rng = np.random.default_rng(3)
    for step in range(20):
        mon.observe(step, 1.0 + rng.normal(0, 0.03, 8))
    assert mon.stragglers(20) == []


def test_reslice_plan():
    from repro.runtime import plan_reslice
    mon = StepTimeMonitor(n_hosts=4, threshold=1.3)
    for step in range(10):
        mon.observe(step, [1.0, 1.0, 2.0, 1.0])          # host 2 at half speed
    plan = plan_reslice(mon, 10, global_batch=64)
    assert plan.total == 64
    assert plan.shares[2] < plan.shares[0]               # slow host gets less
    assert min(plan.shares) >= 1
