"""Differential conformance suite: ``polyfit`` vs ``numpy.polyfit``.

Golden-value tests across degrees 1–9, float32/float64, monomial vs
Chebyshev basis, identity vs normalized domain, and every engine path —
with tolerances *scaled by the estimated condition number* the fit itself
reports (``Polynomial.diagnostics.condition``), so the suite is tight
where the numerics allow it and honest where they cannot.

Also holds the two headline acceptance scenarios of the condition-aware
solver stack:

* a degree-9 fit on a wide un-normalized domain whose pure-Gaussian-
  elimination solve exceeds 1e-2 relative coefficient error is
  automatically rescued by the plan (auto-normalization + solver
  escalation) to ≤ 1e-3;
* ``robust_polyfit`` recovers true coefficients within 5% under 20%
  outlier contamination where plain ``polyfit`` misses by > 50%.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core

enable_x64 = getattr(jax, "enable_x64", jax.experimental.enable_x64)

DEGREES = list(range(1, 10))
# the conformance grid stays on a modest domain so numpy.polyfit (QR on the
# raw Vandermonde, f64) is itself a trustworthy golden reference at degree
# 9; wide-domain behavior is pinned by the rescue test against analytic
# truth below, where numpy is no longer golden either.
LO, HI = -1.5, 1.5


def _data(seed: int, n: int, degree: int, noise: float = 0.02,
          batch: tuple = ()):
    rng = np.random.default_rng(seed)
    x = np.sort(rng.uniform(LO, HI, batch + (n,)), axis=-1)
    coeffs = rng.normal(0.0, 1.0, batch + (degree + 1,))
    y = (np.vectorize(np.polyval, signature="(m),(n)->(n)")
         (coeffs[..., ::-1], x) + noise * rng.normal(0, 1, x.shape))
    return x, y


def _np_fit_values(x: np.ndarray, y: np.ndarray, degree: int) -> np.ndarray:
    """Golden fitted values: numpy.polyfit in float64."""
    c = np.polyfit(x.astype(np.float64), y.astype(np.float64), degree)
    return np.polyval(c, x.astype(np.float64))


def _np_coeffs(x: np.ndarray, y: np.ndarray, degree: int) -> np.ndarray:
    return np.polyfit(x.astype(np.float64), y.astype(np.float64),
                      degree)[::-1].copy()


def _check_against_numpy(x: np.ndarray, y: np.ndarray, degree: int,
                         dtype, *, basis: str, normalize: bool,
                         engine: str = "reference") -> None:
    xj = jnp.asarray(x, dtype)
    yj = jnp.asarray(y, dtype)
    poly = core.polyfit(xj, yj, degree, basis=basis, normalize=normalize,
                        engine=engine)
    assert poly.diagnostics is not None
    cond = float(poly.diagnostics.condition)
    assert np.isfinite(cond) and cond >= 1.0
    eps = float(jnp.finfo(dtype).eps)

    # value space: both fits minimize the same Σe², so fitted values agree
    # to ~eps·√κ(Gram) relative (κ(V) = √κ(VᵀV)) — scaled by the measured
    # condition estimate, floored at a few ulps of the value scale
    gold = _np_fit_values(x, y, degree)
    ours = np.asarray(poly(xj), np.float64)
    scale = float(np.linalg.norm(gold)) + 1e-30
    rel_gap = float(np.linalg.norm(ours - gold)) / scale
    tol_val = max(200.0 * eps * np.sqrt(cond), 50.0 * eps)
    assert rel_gap <= tol_val, (
        f"value gap {rel_gap:.3e} > tol {tol_val:.3e} "
        f"(cond={cond:.2e}, {poly.diagnostics.solver})")

    # coefficient space: only meaningful where the conditioning leaves
    # digits to compare — the honest part of "tolerances scaled by κ"
    pred_rel = 100.0 * eps * cond
    if basis == core.MONOMIAL and pred_rel < 1e-2:
        gold_c = _np_coeffs(x, y, degree)
        ours_c = np.asarray(poly.monomial_coeffs(), np.float64)
        rel_c = (np.linalg.norm(ours_c - gold_c)
                 / (np.linalg.norm(gold_c) + 1e-30))
        assert rel_c <= max(pred_rel, 1e3 * eps), (
            f"coeff gap {rel_c:.3e} (pred {pred_rel:.3e}, cond={cond:.2e})")


@pytest.mark.parametrize("degree", DEGREES)
def test_conformance_float32(degree):
    x, y = _data(degree, 256, degree)
    for basis in (core.MONOMIAL, core.CHEBYSHEV):
        for normalize in (False, True):
            _check_against_numpy(x, y, degree, jnp.float32,
                                 basis=basis, normalize=normalize)


@pytest.mark.parametrize("degree", DEGREES)
def test_conformance_float64(degree):
    x, y = _data(100 + degree, 256, degree)
    with enable_x64(True):
        for basis in (core.MONOMIAL, core.CHEBYSHEV):
            for normalize in (False, True):
                _check_against_numpy(x, y, degree, jnp.float64,
                                     basis=basis, normalize=normalize)


@pytest.mark.parametrize("degree", [1, 2, 3, 5, 7, 9])
def test_conformance_kernel_engines(degree):
    """The Pallas paths (plain + packed, interpret mode off-TPU) conform to
    the same numpy gold as the reference path (monomial/f32 — the kernels'
    domain)."""
    x, y = _data(200 + degree, 256, degree)
    _check_against_numpy(x, y, degree, jnp.float32, basis=core.MONOMIAL,
                         normalize=True, engine="kernel_plain")
    xb, yb = _data(300 + degree, 256, degree, batch=(3,))
    poly = core.polyfit(jnp.asarray(xb, jnp.float32),
                        jnp.asarray(yb, jnp.float32), degree,
                        normalize=True, engine="kernel_packed")
    eps = float(jnp.finfo(jnp.float32).eps)
    for i in range(xb.shape[0]):
        gold = _np_fit_values(xb[i], yb[i], degree)
        ours = np.asarray(poly(jnp.asarray(xb, jnp.float32))[i], np.float64)
        cond = float(poly.diagnostics.condition[i])
        tol = max(200.0 * eps * np.sqrt(cond), 50.0 * eps)
        gap = np.linalg.norm(ours - gold) / (np.linalg.norm(gold) + 1e-30)
        assert gap <= tol, f"series {i}: {gap:.3e} > {tol:.3e}"


# --------------------------------------------------- acceptance scenarios
def test_degree9_wide_domain_is_rescued():
    """ISSUE-3 acceptance: degree-9 on a wide un-normalized domain — pure
    GE normal equations exceed 1e-2 relative coefficient error; the
    condition-aware default routes around it and lands ≤ 1e-3."""
    with enable_x64(True):
        worst_ge, worst_auto = 0.0, 0.0
        for seed in (1, 7, 42):
            rng = np.random.default_rng(seed)
            true = rng.normal(0, 1, 10)
            x = jnp.asarray(np.linspace(0.0, 8.0, 400))
            y = jnp.asarray(np.polyval(true[::-1], np.linspace(0.0, 8.0,
                                                               400)))

            def rel(c):
                c = np.asarray(c, np.float64)
                return float(np.linalg.norm(c - true) / np.linalg.norm(true))

            # the paper's literal path: plain elimination, guard off
            ge = core.polyfit(x, y, 9, solver="gauss", fallback=None)
            # condition-aware default: auto-normalization + solver ladder
            auto = core.polyfit(x, y, 9)
            worst_ge = max(worst_ge, rel(ge.monomial_coeffs()))
            worst_auto = max(worst_auto, rel(auto.monomial_coeffs()))
            # the plan must actually have escalated, not gotten lucky
            assert auto.diagnostics.solver != "gauss"
            assert float(auto.domain_scale) != 1.0   # auto-normalized
        assert worst_ge > 1e-2, f"GE unexpectedly fine: {worst_ge:.2e}"
        assert worst_auto <= 1e-3, f"rescue too weak: {worst_auto:.2e}"


def test_robust_polyfit_survives_contamination():
    """ISSUE-3 acceptance: 20% gross outliers — plain polyfit misses the
    true coefficients by > 50%, robust_polyfit lands within 5%."""
    rng = np.random.default_rng(3)
    true = np.array([1.0, -2.0, 0.5, 0.8])
    n = 400
    x = rng.uniform(-2.0, 2.0, n)
    y = np.polyval(true[::-1], x) + rng.normal(0, 0.05, n)
    out = rng.choice(n, n // 5, replace=False)
    y[out] += rng.choice([-1.0, 1.0], out.size) * rng.uniform(30.0, 80.0,
                                                              out.size)
    xj = jnp.asarray(x, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)

    def rel(c):
        c = np.asarray(c, np.float64)
        return float(np.linalg.norm(c - true) / np.linalg.norm(true))

    plain = core.polyfit(xj, yj, 3)
    rfit = core.robust_polyfit(xj, yj, 3, loss=core.TUKEY)
    assert rel(core.fit_report(plain, xj, yj).coeffs) > 0.5
    assert bool(rfit.converged)
    assert rel(rfit.poly.monomial_coeffs()) < 0.05


def test_lspia_matches_lse_fit():
    """LSPIA (never forms the Gram) converges to the same polynomial the
    explicit normal-equation solve produces."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.uniform(-3.0, 3.0, 512), jnp.float32)
    y = jnp.asarray(np.sin(np.asarray(x)) + 0.02 * rng.normal(0, 1, 512),
                    jnp.float32)
    lf = core.lspia_fit(x, y, 5, basis=core.CHEBYSHEV, tol=1e-6)
    assert bool(lf.converged)
    assert int(lf.iterations) < 5000
    ref = core.polyfit(x, y, 5, basis=core.CHEBYSHEV, normalize=True)
    xs = jnp.linspace(-3.0, 3.0, 101)
    gap = float(jnp.max(jnp.abs(lf.poly(xs) - ref(xs))))
    assert gap < 1e-3, f"LSPIA vs LSE value gap {gap:.2e}"
    # and via the polyfit front door
    front = core.polyfit(x, y, 5, solver="lspia", basis=core.CHEBYSHEV)
    assert float(jnp.max(jnp.abs(front(xs) - ref(xs)))) < 1e-3
