"""Checkpointer: roundtrip, atomic commit, torn-write recovery, GC,
end-to-end train-resume determinism."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro import configs
from repro.models import get_model
from repro.train import TrainConfig, init_train_state, make_train_step


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(0, 1, (4, 8)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.normal(0, 1, (3,)), jnp.bfloat16),
                   "c": jnp.asarray(7, jnp.int32)},
        "list": [jnp.ones((2, 2)), jnp.zeros((5,))],
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    checkpoint.save(str(tmp_path), 10, tree)
    out = checkpoint.restore(str(tmp_path), 10, jax.eval_shape(lambda: tree))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), tree, out)
    assert out["nested"]["b"].dtype == jnp.bfloat16


def test_latest_step_ignores_uncommitted(tmp_path):
    tree = _tree()
    checkpoint.save(str(tmp_path), 5, tree)
    checkpoint.save(str(tmp_path), 10, tree)
    # fake a torn write: committed marker missing
    torn = tmp_path / "step_00000015"
    shutil.copytree(tmp_path / "step_00000010", torn)
    os.remove(torn / checkpoint.COMMIT_MARKER)
    assert checkpoint.latest_step(str(tmp_path)) == 10


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        checkpoint.restore(str(tmp_path), 1, _tree())


def test_shape_mismatch_raises(tmp_path):
    checkpoint.save(str(tmp_path), 1, _tree())
    bad = _tree()
    bad["a"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError):
        checkpoint.restore(str(tmp_path), 1, jax.eval_shape(lambda: bad))


def test_gc_old(tmp_path):
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(str(tmp_path), s, _tree())
    checkpoint.gc_old(str(tmp_path), keep=2)
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000004", "step_00000005"]


def test_train_resume_bitwise(tmp_path):
    """save at step k, keep training to k+n; restart from the checkpoint and
    replay — final losses match (deterministic pipeline + state restore)."""
    cfg = configs.get_smoke_config("internlm2-1.8b")
    model = get_model(cfg)
    tc = TrainConfig()
    step_fn = jax.jit(make_train_step(model, tc))
    from repro.data import DataConfig, TokenPipeline
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)

    state = init_train_state(model, jax.random.PRNGKey(0))
    pipe = TokenPipeline(dcfg)
    losses_a = []
    for step in range(6):
        if step == 3:
            checkpoint.save(str(tmp_path), 3, state)
        state, m = step_fn(state, pipe.next())
        losses_a.append(float(m["loss"]))

    # restart
    state_b = init_train_state(model, jax.random.PRNGKey(1))  # wrong rng
    state_b = checkpoint.restore(str(tmp_path), 3,
                                 jax.eval_shape(lambda: state_b))
    pipe_b = TokenPipeline(dcfg, start_batch=3)
    losses_b = []
    for step in range(3, 6):
        state_b, m = step_fn(state_b, pipe_b.next())
        losses_b.append(float(m["loss"]))
    np.testing.assert_allclose(losses_b, losses_a[3:], rtol=1e-5)
