"""Faithful reproduction of the paper's accuracy analysis (Tables I-V).

The paper fits orders 1-3 on the Table I dataset with the matricized
normal-equation method (Gaussian elimination) and compares against MATLAB
polyfit (QR on the Vandermonde). We assert our generated coefficients match
the paper's published values and that Σe² for the order-3 fit reproduces the
paper's 128.1999 (paper's polyfit column: 129.6512 — their polyfit ran at a
lower effective precision; in f64 both methods coincide, which we also
assert, and in f32 they diverge in the 3rd-4th decimal as the paper shows).

x64 is enabled per-test via the jax.experimental.enable_x64 context so the
rest of the suite keeps default f32 semantics.
"""
import jax
import jax.experimental
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core

# jax >= 0.4.38 exposes the x64 context as jax.enable_x64; older releases
# only have the jax.experimental one. Same context manager either way.
enable_x64 = getattr(jax, "enable_x64", jax.experimental.enable_x64)

X64 = [39.206, 29.74, 21.31, 12.087, 1.812, 0.001]
Y64 = [751.912, 567.121, 403.746, 221.738, 18.8418, 1.88672]

# Paper Tables II-IV
PAPER_POLYFIT = {
    1: [-8.356, 19.3496],
    2: [-6.5109, 18.8735, 0.0127],
    3: [-4.7551, 17.5109, 0.1086, -0.0016],
}
PAPER_SSE_F = 128.199937   # paper's Σe_f²
PAPER_FITTED_ORDER3 = [751.18396, 569.500305, 402.053284, 219.903793,
                       27.321678, -4.736779]


def _data():
    return (jnp.asarray(X64, jnp.float64), jnp.asarray(Y64, jnp.float64))


@pytest.mark.parametrize("order", [1, 2, 3])
def test_generated_coefficients_match_paper(order):
    with enable_x64(True):
        x, y = _data()
        poly = core.polyfit(x, y, order)          # paper-faithful path
        got = np.asarray(poly.coeffs)
    np.testing.assert_allclose(got, PAPER_POLYFIT[order], atol=2.5e-4)


@pytest.mark.parametrize("order", [1, 2, 3])
def test_gauss_equals_qr_in_f64(order):
    """In f64 the normal-equation and QR solutions coincide — the paper's
    accuracy gap is a precision artifact, which is itself informative."""
    with enable_x64(True):
        x, y = _data()
        a = np.asarray(core.polyfit(x, y, order).coeffs)
        b = np.asarray(
            core.polyfit(x, y, order, solver="qr_vandermonde").coeffs)
    np.testing.assert_allclose(a, b, rtol=1e-8, atol=1e-10)


def test_order3_sse_matches_paper():
    with enable_x64(True):
        x, y = _data()
        poly = core.polyfit(x, y, 3)
        rep = core.fit_report(poly, x, y)
        assert abs(float(rep.sse) - PAPER_SSE_F) < 5e-3


def test_order3_fitted_values_match_table_v():
    """Paper's Table V f(x) column was computed with their lower-precision
    coefficients; agreement holds to ~1e-2 absolute (4-5 significant
    digits), consistent with their printed rounding."""
    with enable_x64(True):
        x, y = _data()
        fitted = np.asarray(core.polyfit(x, y, 3)(x))
    np.testing.assert_allclose(fitted, PAPER_FITTED_ORDER3, atol=2e-2)


def test_correlation_coefficient_high():
    with enable_x64(True):
        x, y = _data()
        for order in (1, 2, 3):
            rep = core.fit_report(core.polyfit(x, y, order), x, y)
            assert float(rep.r) > 0.999   # paper: 0.9996-0.9998


def test_f32_reproduces_papers_precision_gap():
    """In f32, normal equations vs QR differ in the low decimals (the paper's
    Tables III/IV show exactly this scale of divergence)."""
    x32 = jnp.asarray(X64, jnp.float32)
    y32 = jnp.asarray(Y64, jnp.float32)
    a = np.asarray(core.polyfit(x32, y32, 3).coeffs, np.float64)
    b = np.asarray(
        core.polyfit(x32, y32, 3, solver="qr_vandermonde").coeffs,
        np.float64)
    gap = np.max(np.abs(a - b))
    assert 0 < gap < 0.5  # differ, but bounded


def test_power_sum_hankel_identity():
    """A == VᵀV and B == Vᵀy: the matricization is exact."""
    with enable_x64(True):
        x, y = _data()
        m = core.gram_moments(x, y, 3)
        s = core.power_sums(x, 3)
        np.testing.assert_allclose(
            np.asarray(m.gram),
            np.asarray(core.hankel_from_power_sums(s, 3)), rtol=1e-12)
        np.testing.assert_allclose(
            np.asarray(m.vty), np.asarray(core.moment_vector(x, y, 3)),
            rtol=1e-12)


def test_sse_from_moments_identity():
    """Σe² computed from sufficient statistics alone (no data pass)."""
    with enable_x64(True):
        x, y = _data()
        poly = core.polyfit(x, y, 3)
        m = core.gram_moments(x, y, 3)
        direct = float(core.fit_report(poly, x, y).sse)
        from_moments = float(core.sse_from_moments(m, poly.coeffs))
        assert abs(direct - from_moments) < 1e-6


def test_normalized_fit_recovers_raw_coefficients():
    """Beyond-paper hardened path (x→[-1,1]) converts back to the same raw
    monomial coefficients."""
    with enable_x64(True):
        x, y = _data()
        raw = np.asarray(core.polyfit(x, y, 3).coeffs)
        norm = np.asarray(core.polyfit(x, y, 3, normalize=True)
                          .monomial_coeffs())
    np.testing.assert_allclose(raw, norm, rtol=1e-7, atol=1e-8)
